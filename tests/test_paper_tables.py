"""Validation of the cycle model against every published table (Tables 2-7,
Fig. 8, and the Sec. 5.4/5.5 headline claims)."""

import pytest

from repro.core import cost_model as cm
from repro.core import paper_tables as pt
from repro.core.apps import aes_paper_accounting, evaluate_all
from repro.workloads import get_workload
from repro.core.cost_model import Layout, utilization, vector_add_cost
from repro.core.microkernels import table5_model_row
from repro.core.params import PAPER_SYSTEM, SINGLE_ARRAY
from repro.core.planner import (
    hybrid_profitability_threshold, plan, transpose_sensitivity,
)
from repro.core.transpose import round_trip_cycles, transpose_cycles


# ---------------------------------------------------------------- Table 2 --

def test_table2_primitives():
    assert cm.BP_LOGIC == 1 and cm.BP_ADD == 1 and cm.BP_SUB == 2
    assert cm.bp_mult(32) == 34 and cm.bp_mult(16) == 18
    assert cm.bp_shift(5) == 5
    assert cm.BS_ADD1 == 1 and cm.BS_SHIFT == 0 and cm.BS_MUX1 == 4


# ---------------------------------------------------------------- Table 3 --

@pytest.mark.parametrize("kernel,expect", sorted(pt.TABLE3.items()))
def test_table3_32bit_kernel_latency(kernel, expect):
    model = {
        "vector_add": (cm.BP_ADD, cm.bs_add(32)),
        "vector_mult": (cm.bp_mult(32), cm.bs_mult(32)),
        "min_max": (cm.minmax_bp(32), cm.minmax_bs(32)),
        "if_then_else": (cm.if_then_else_bp(32), cm.if_then_else_bs(32)),
    }[kernel]
    assert model == expect


# ---------------------------------------------------------------- Table 4 --

@pytest.mark.parametrize("row", pt.TABLE4, ids=lambda r: f"n{r.elements}")
def test_table4_vector_add_batching(row):
    bp = vector_add_cost(Layout.BP, row.elements)
    bs = vector_add_cost(Layout.BS, row.elements)
    assert bp.total == row.bp_cycles
    assert bs.total == row.bs_cycles
    assert PAPER_SYSTEM.bp_batches(row.elements, 16) == row.bp_batches
    assert bs.total / bp.total == pytest.approx(row.speedup, abs=0.005)


def test_batching_neutralizes_bp_advantage():
    """Paper Sec. 5.3: speedup monotonically decays to parity."""
    ratios = [vector_add_cost(Layout.BS, r.elements).total
              / vector_add_cost(Layout.BP, r.elements).total
              for r in pt.TABLE4]
    assert all(a >= b - 1e-9 for a, b in zip(ratios, ratios[1:]))
    assert ratios[-1] == pytest.approx(1.0, abs=0.005)


# ---------------------------------------------------------------- Table 5 --

_T5_KERNEL_MAP = {"1b Logic": "bitweave1", "2b Logic": "bitweave2",
                  "4b Logic": "bitweave4"}


@pytest.mark.parametrize(
    "row", pt.TABLE5, ids=lambda r: f"{r.kernel}-{r.mode}-{r.variant}")
def test_table5_microkernel_breakdown(row):
    name = _T5_KERNEL_MAP.get(row.variant, row.kernel) \
        if row.kernel == "bitweave" else row.kernel
    c = table5_model_row(name, Layout(row.mode))
    assert (c.load, c.compute, c.readout) == (row.load, row.compute, row.readout)
    assert c.total == row.total
    if row.consistent:
        assert row.load + row.compute + row.readout == row.total


def test_multu_14x_claim():
    """Sec. 5.3: BP's 18-cycle multiply is >14x faster than 256-cycle BS."""
    assert cm.bs_mult(16) / cm.bp_mult(16) > 14


def test_bitcount_bs_advantage():
    """Sec. 5.3: bitcount BS 128 vs BP 185 (~1.4x)."""
    bp = table5_model_row("bitcount", Layout.BP).total
    bs = table5_model_row("bitcount", Layout.BS).total
    assert (bp, bs) == (185, 128)
    assert bp / bs == pytest.approx(1.445, abs=0.01)


# ------------------------------------------------------- row overflow ------

def test_fir_row_overflow_challenge2():
    """11 words x 32-bit = 352 rows > 128 in BS; 11 rows in BP."""
    s = SINGLE_ARRAY
    assert s.bs_rows_required(11, 32, carry_rows=0) == 352
    assert s.bs_row_overflow(11, 32)
    assert not s.bp_row_overflow(11)


def test_predication_row_overflow_challenge5():
    """10 words x 32-bit = 320 rows > 128 in BS."""
    s = SINGLE_ARRAY
    assert s.bs_rows_required(10, 32, carry_rows=0) == 320
    assert s.bs_row_overflow(10, 32)


def test_keccak_es_bs_row_overflow_challenge3():
    """25 x 64-bit lanes = 1600 rows -- ES-BS impossible."""
    assert SINGLE_ARRAY.bs_rows_required(25, 64, carry_rows=0) == 1600


def test_challenge1_utilization():
    """DoP=16 @32-bit: BS uses 16/512 columns (3.1%), BP 100% (Fig. 3),
    on the single-array configuration."""
    assert utilization(Layout.BS, 16, 32, SINGLE_ARRAY) == pytest.approx(
        16 / 512)
    assert utilization(Layout.BP, 16, 32, SINGLE_ARRAY) == 1.0


# ------------------------------------------------------------- transpose ---

def test_transpose_aes_state_145_cycles():
    assert transpose_cycles(16, 128, "bp2bs") == 145
    assert transpose_cycles(16, 128, "bs2bp") == 145
    assert round_trip_cycles(16, 128) == pt.AES_TOTALS["transpose_per_round"]


# ------------------------------------------------- Table 7 / AES Sec. 5.4 --

def test_table7_stage_costs():
    from repro.core.apps import AES_STAGE
    for stage, (bp, bs) in pt.TABLE7.items():
        assert AES_STAGE[stage] == (bp, bs)
    assert sum(v[0] for v in pt.TABLE7.values()) == 1888
    assert sum(v[1] for v in pt.TABLE7.values()) == 2675


def test_aes_published_totals():
    acc = aes_paper_accounting()
    assert acc["BP"] == pt.AES_TOTALS["BP"] == 18624
    assert acc["BS"] == pt.AES_TOTALS["BS"] == 26750
    assert acc["hybrid"] == pt.AES_TOTALS["hybrid"] == 6994
    assert acc["per_round_hybrid"] == 725
    assert acc["speedup"] == pytest.approx(2.66, abs=0.005)


def test_aes_dp_planner_matches_or_beats_hand_schedule():
    """The DP planner must reproduce the paper's hybrid structure (SubBytes
    in BS, everything else BP) and may only be cheaper than the hand
    schedule (it saves one transpose by ending in BS)."""
    p = plan(get_workload("aes").to_phases())
    assert p.static_bp == 18624  # faithful-trace BP == published BP
    assert p.static_bs == pt.AES_TOTALS["BS_trace_faithful"]
    assert p.is_hybrid
    assert p.total_cycles <= pt.AES_TOTALS["hybrid"]
    assert pt.AES_TOTALS["hybrid"] - p.total_cycles < 145  # <= 1 transpose
    # every SubBytes phase runs in BS, every MixColumns in BP
    for ph, layout in zip(get_workload("aes").to_phases(), p.schedule):
        if ph.name.startswith("SB"):
            assert layout == Layout.BS
        if ph.name.startswith("MC"):
            assert layout == Layout.BP


def test_aes_transpose_sensitivity_10x():
    """Sec. 5.4: 10x transpose core => ~2.6% runtime, 2.59x hybrid speedup.
    (Our DP schedule has one fewer transpose, hence >= the published
    speedup and <= the published increase.)"""
    s = transpose_sensitivity(get_workload("aes").to_phases(), core_cycles=10)
    assert s["runtime_increase_pct"] < pt.AES_SENSITIVITY_10X[
        "runtime_increase_pct"] + 0.2
    assert s["hybrid_speedup"] >= pt.AES_SENSITIVITY_10X["hybrid_speedup"]


def test_hybrid_profitability_threshold():
    """Hybrid stays optimal for AES far beyond the paper's conservative
    51-cycle reference threshold (Sec. 5.5)."""
    thr = hybrid_profitability_threshold(get_workload("aes").to_phases())
    assert thr > pt.HYBRID_THRESHOLD_CYCLES


# ------------------------------------------------------------------ Fig 8 --

def test_fig8_vgg13_utilization():
    for layer, ch, spatial in pt.FIG8_LAYERS:
        ops = ch * spatial * spatial / 9  # 3x3 kernel reuse
        for layout in (Layout.BP, Layout.BS):
            quoted = pt.FIG8_QUOTED_UTIL.get((layer, layout.value))
            if quoted is None:
                continue
            u = utilization(layout, int(ops), 16)
            assert u == pytest.approx(quoted, abs=0.005), (layer, layout)


# ---------------------------------------------------------------- Table 6 --

def test_table6_all_apps_in_published_bands():
    res = evaluate_all()
    assert len(res) == 22  # paper: "22 full applications"
    for name, r in res.items():
        band = pt.TABLE6_BANDS[pt.TABLE6_APPS[name]]
        if band.category == "Hybrid recommended":
            assert r["is_hybrid"], name
            assert r["hybrid_speedup"] > 1.05, name
        else:
            assert band.lo <= r["bs_over_bp"] <= band.hi, (
                name, r["bs_over_bp"], band)


def test_table6_aes_hybrid_headline():
    r = evaluate_all()["aes"]
    assert r["hybrid_speedup"] >= 2.66  # DP >= the published hand schedule


def test_up_to_14x_between_static_layouts():
    """Abstract claim: 'up to 14x variations between static layouts'."""
    best = max(max(r["bs_over_bp"], 1 / r["bs_over_bp"])
               for r in evaluate_all().values())
    # the 14x shows at kernel level (MULTU compute); app level is bounded
    assert cm.bs_mult(16) / cm.bp_mult(16) >= 14
    assert best < 14  # batching keeps app-level spreads tighter
