"""Substrate tests: data pipeline, checkpointing (atomic + elastic), train
loop fault tolerance (resume, preemption, straggler watchdog), serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config, reduced_config
from repro.data.pipeline import (
    DataConfig, DataIterator, global_batch_at, shard_slice,
)
from repro.models import init_params, registry
from repro.optim import adamw
from repro.train.loop import LoopConfig, Trainer


# ------------------------------------------------------------- pipeline ----

def test_data_deterministic_and_stateless():
    cfg = DataConfig(vocab_size=101, seq_len=16, global_batch=8, seed=3)
    a = global_batch_at(cfg, 7)
    b = global_batch_at(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = global_batch_at(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["labels"].shape == (8, 16)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_sharding_covers_global_batch():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8)
    full = global_batch_at(cfg, 0)
    parts = [shard_slice(full, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])
    # elastic: a different shard count slices the SAME global batch
    parts2 = [shard_slice(full, i, 2)["tokens"] for i in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts2), full["tokens"])


def test_data_iterator_resume():
    cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=4)
    it = DataIterator(cfg)
    seq = [next(it)["tokens"] for _ in range(5)]
    it2 = DataIterator(cfg, start_step=3)
    np.testing.assert_array_equal(next(it2)["tokens"], seq[3])


# ----------------------------------------------------------- checkpoint ----

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.int32(7)}}
    for s in (10, 20, 30):
        mgr.save(s, tree, metadata={"tag": s})
    assert mgr.steps() == [20, 30]  # gc keeps last 2
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 30
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert int(restored["b"]["c"]) == 7


def test_checkpoint_atomic_no_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((4,))}
    mgr.save(1, tree)
    # a leftover tmp dir from a "preempted" save must not be visible
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert mgr.latest_step() == 1


# ------------------------------------------------------------ train loop ---

def _tiny_trainer(tmp_path, total_steps=6, ckpt_every=2):
    cfg = reduced_config(get_config("tinyllama_1_1b"))
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=total_steps)
    loop = LoopConfig(total_steps=total_steps, ckpt_every=ckpt_every,
                      log_every=2)
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4)
    return Trainer(cfg, opt, loop, data, str(tmp_path))


def test_train_loop_runs_and_checkpoints(tmp_path):
    tr = _tiny_trainer(tmp_path)
    out = tr.run()
    assert out["final_step"] == 6
    assert np.isfinite(out["loss"])
    assert tr.ckpt.latest_step() == 6


def test_preemption_restart_is_bit_identical(tmp_path):
    # uninterrupted run
    tr_ref = _tiny_trainer(tmp_path / "ref")
    ref = tr_ref.run()
    # preempted run: dies at step 4, restarts, finishes
    tr = _tiny_trainer(tmp_path / "pre")
    with pytest.raises(InterruptedError):
        tr.run(preempt_after=4)
    tr2 = _tiny_trainer(tmp_path / "pre")
    out = tr2.run()
    assert out["final_step"] == ref["final_step"]
    np.testing.assert_allclose(out["loss"], ref["loss"], rtol=1e-6)


def test_straggler_watchdog_flags_slow_steps(tmp_path):
    tr = _tiny_trainer(tmp_path)
    for dt in [0.1] * 10:
        tr._watch(len(tr.step_times), dt)
    tr._watch(10, 5.0)  # injected straggler
    assert 10 in tr.stragglers


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Save unsharded, restore with per-leaf shardings from a 1-device mesh
    of a different logical shape (the elastic path device_put exercises)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(5, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored, meta = mgr.restore(tree, shardings=shardings)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


# --------------------------------------------------------------- serving ---

def test_serve_session_prefill_and_decode():
    from repro.serve.decode import ServeSession

    cfg = reduced_config(get_config("tinyllama_1_1b"))
    fns = registry.model_fns(cfg)
    params = init_params(fns.param_structure(cfg), jax.random.key(0))
    sess = ServeSession(cfg, params, max_len=32)
    outs = sess.generate([[1, 2, 3], [4, 5]], max_new_tokens=4)
    assert len(outs) == 2
    assert len(outs[0]) == 3 + 4 and len(outs[1]) == 2 + 4
    assert all(0 <= t < cfg.vocab_size for o in outs for t in o)


def test_serve_matches_teacher_forcing():
    """Greedy generation continues exactly as teacher-forced argmax."""
    from repro.models.transformer import forward_logits
    from repro.serve.decode import ServeSession

    cfg = reduced_config(get_config("tinyllama_1_1b"))
    fns = registry.model_fns(cfg)
    params = init_params(fns.param_structure(cfg), jax.random.key(1))
    prompt = [5, 9, 2, 7]
    sess = ServeSession(cfg, params, max_len=16)
    out = sess.generate([prompt], max_new_tokens=1)[0]
    full = forward_logits(cfg, params,
                          {"tokens": jnp.asarray([prompt], jnp.int32)})
    expect = int(jnp.argmax(full[0, -1, : cfg.vocab_size]))
    assert out[-1] == expect


# ------------------------------------------------- grad compression --------

def test_compressed_psum_single_axis():
    from repro.optim.grad_compress import (
        init_errors, make_compressed_dp_step,
    )

    mesh = jax.make_mesh((1,), ("data",))
    params = {"w": jnp.asarray([[0.5, -0.25], [1.0, 0.0]])}

    def loss_fn(p, batch):
        return jnp.mean((batch @ p["w"]) ** 2)

    step = make_compressed_dp_step(loss_fn, mesh)
    errors = init_errors(params)
    batch = jnp.ones((2, 2))
    with mesh:
        grads, new_err, loss = jax.jit(step)(params, errors, batch)
    ref = jax.grad(loss_fn)(params, batch)
    # int8 quantization error is bounded by scale/2 = max|g|/254
    bound = float(jnp.max(jnp.abs(ref["w"]))) / 127
    np.testing.assert_allclose(np.asarray(grads["w"]),
                               np.asarray(ref["w"]), atol=bound)
    # error feedback captures exactly the quantization residual
    np.testing.assert_allclose(np.asarray(grads["w"] + 0 * new_err["w"]),
                               np.asarray(ref["w"]), atol=bound)
