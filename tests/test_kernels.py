"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) in
interpret mode."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.bitpack import bitpack, bitunpack
from repro.kernels.bitparallel_matmul import bitparallel_matmul
from repro.kernels.bitserial_matmul import bitserial_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels import ops


def _rand_words(rng, K, N, bits):
    return jnp.asarray(rng.integers(0, 2 ** bits, size=(K, N),
                                    dtype=np.uint32))


# ------------------------------------------------------------- bitpack -----

@settings(max_examples=12, deadline=None)
@given(bits=st.sampled_from([1, 2, 4, 8]),
       kg=st.integers(1, 4), n=st.sampled_from([8, 64, 96]))
def test_bitpack_matches_ref(bits, kg, n):
    rng = np.random.default_rng(bits * 100 + kg * 10 + n)
    w = _rand_words(rng, 32 * kg, n, bits)
    got = bitpack(w, bits)
    want = ref.bitpack_ref(w, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitpack_roundtrip():
    rng = np.random.default_rng(0)
    w = _rand_words(rng, 128, 64, 4)
    planes = bitpack(w, 4)
    back = ref.bitunpack_ref(planes, 128)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([1, 3, 8]),
       k=st.sampled_from([1, 17, 40, 63, 65]), n=st.sampled_from([8, 64]))
def test_bitpack_pads_ragged_k_and_unpack_strips(bits, k, n):
    """ISSUE-5 satellite: K need not be a multiple of 32 -- the packer
    zero-pads, bitunpack strips the padding, and the padded planes feed
    the BS matmul unchanged (zero rows contribute nothing)."""
    rng = np.random.default_rng(bits * 1000 + k * 10 + n)
    w = _rand_words(rng, k, n, bits)
    planes = bitpack(w, bits)
    assert planes.shape == (bits, -(-k // 32), n)
    back = bitunpack(planes, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))
    # padded rows really are zero
    full = np.asarray(bitunpack(planes))
    assert not full[k:].any()
    # ragged-K matmul through the padded planes == integer reference
    m = 8
    x = jnp.asarray(rng.integers(-8, 8, size=(m, k), dtype=np.int32))
    got = ops.matmul_bs(x.astype(jnp.int8), planes)
    want = np.asarray(x) @ np.asarray(w).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(got), want)


# -------------------------------------------------- bit-serial matmul ------

@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([1, 2, 4]),
       m=st.sampled_from([8, 32]), kg=st.integers(1, 3),
       n=st.sampled_from([16, 64]))
def test_bitserial_matmul_matches_ref(bits, m, kg, n):
    rng = np.random.default_rng(bits + m + kg + n)
    K = 32 * kg
    x = jnp.asarray(rng.integers(-64, 64, size=(m, K), dtype=np.int32)
                    ).astype(jnp.int8)
    w = _rand_words(rng, K, n, bits)
    planes = ref.bitpack_ref(w, bits)
    got = bitserial_matmul(x, planes, block_m=min(32, m), block_n=min(64, n))
    want = ref.bitserial_matmul_ref(x.astype(jnp.int32), planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------ bit-parallel matmul ------

@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([16, 64]), k=st.sampled_from([32, 128, 160]),
       n=st.sampled_from([16, 128]))
def test_bitparallel_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int32)
                    ).astype(jnp.int8)
    got = bitparallel_matmul(x, w, block_m=16, block_n=16, block_k=32)
    want = ref.bitparallel_matmul_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bs_equals_bp_semantics():
    """Both layouts compute the same GEMM (the paper's iso-function claim)."""
    rng = np.random.default_rng(7)
    K, N, bits = 64, 32, 4
    x = jnp.asarray(rng.integers(0, 16, (8, K), dtype=np.int32)).astype(
        jnp.int8)
    w = _rand_words(rng, K, N, bits)
    planes = ref.bitpack_ref(w, bits)
    y_bs = bitserial_matmul(x, planes, block_m=8, block_n=32)
    y_bp = bitparallel_matmul(x, w.astype(jnp.int8), block_m=8,
                              block_n=16, block_k=32)
    np.testing.assert_array_equal(np.asarray(y_bs), np.asarray(y_bp))


# --------------------------------------------------- flash attention -------

@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 2]), sq=st.sampled_from([32, 64]),
       h=st.sampled_from([1, 2]), d=st.sampled_from([32, 64]),
       causal=st.booleans())
def test_flash_attention_matches_ref(b, sq, h, d, causal):
    rng = np.random.default_rng(b + sq + h + d)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_vs_layers_streaming_attention():
    """The Pallas kernel and the pure-JAX streaming softmax agree."""
    from repro.models.layers import flash_attention as jflash
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    b = jflash(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# -------------------------------------------- layout-aware dispatch --------

def test_layout_aware_matmul_dispatch():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, 8, (128, 64), dtype=np.int32)).astype(
        jnp.int8)
    w2 = _rand_words(rng, 64, 128, 2)   # 2-bit, high DoP -> BS
    y, layout = ops.layout_aware_matmul(x, w2, weight_bits=2)
    assert layout.value == "BS"
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(x.astype(jnp.int32) @ w2.astype(jnp.int32)))

    w8 = _rand_words(rng, 64, 128, 8)   # 8-bit words -> BP
    y8, layout8 = ops.layout_aware_matmul(x, w8.astype(jnp.int32) - 0,
                                          weight_bits=8)
    assert layout8.value == "BP"
    np.testing.assert_array_equal(
        np.asarray(y8),
        np.asarray(x.astype(jnp.int32) @ w8.astype(jnp.int8).astype(
            jnp.int32)))
