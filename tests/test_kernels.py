"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) in
interpret mode."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.bitpack import bitpack, bitunpack
from repro.kernels.bitparallel_matmul import bitparallel_matmul
from repro.kernels.bitserial_matmul import bitserial_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels import ops


def _rand_words(rng, K, N, bits):
    return jnp.asarray(rng.integers(0, 2 ** bits, size=(K, N),
                                    dtype=np.uint32))


# ------------------------------------------------------------- bitpack -----

@settings(max_examples=12, deadline=None)
@given(bits=st.sampled_from([1, 2, 4, 8]),
       kg=st.integers(1, 4), n=st.sampled_from([8, 64, 96]))
def test_bitpack_matches_ref(bits, kg, n):
    rng = np.random.default_rng(bits * 100 + kg * 10 + n)
    w = _rand_words(rng, 32 * kg, n, bits)
    got = bitpack(w, bits)
    want = ref.bitpack_ref(w, bits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitpack_roundtrip():
    rng = np.random.default_rng(0)
    w = _rand_words(rng, 128, 64, 4)
    planes = bitpack(w, 4)
    back = ref.bitunpack_ref(planes, 128)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))


@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([1, 3, 8]),
       k=st.sampled_from([1, 17, 40, 63, 65]), n=st.sampled_from([8, 64]))
def test_bitpack_pads_ragged_k_and_unpack_strips(bits, k, n):
    """ISSUE-5 satellite: K need not be a multiple of 32 -- the packer
    zero-pads, bitunpack strips the padding, and the padded planes feed
    the BS matmul unchanged (zero rows contribute nothing)."""
    rng = np.random.default_rng(bits * 1000 + k * 10 + n)
    w = _rand_words(rng, k, n, bits)
    planes = bitpack(w, bits)
    assert planes.shape == (bits, -(-k // 32), n)
    back = bitunpack(planes, k)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(w))
    # padded rows really are zero
    full = np.asarray(bitunpack(planes))
    assert not full[k:].any()
    # ragged-K matmul through the padded planes == integer reference
    m = 8
    x = jnp.asarray(rng.integers(-8, 8, size=(m, k), dtype=np.int32))
    got = ops.matmul_bs(x.astype(jnp.int8), planes)
    want = np.asarray(x) @ np.asarray(w).astype(np.int64)
    np.testing.assert_array_equal(np.asarray(got), want)


# -------------------------------------------------- bit-serial matmul ------

@settings(max_examples=10, deadline=None)
@given(bits=st.sampled_from([1, 2, 4]),
       m=st.sampled_from([8, 32]), kg=st.integers(1, 3),
       n=st.sampled_from([16, 64]))
def test_bitserial_matmul_matches_ref(bits, m, kg, n):
    rng = np.random.default_rng(bits + m + kg + n)
    K = 32 * kg
    x = jnp.asarray(rng.integers(-64, 64, size=(m, K), dtype=np.int32)
                    ).astype(jnp.int8)
    w = _rand_words(rng, K, n, bits)
    planes = ref.bitpack_ref(w, bits)
    got = bitserial_matmul(x, planes, block_m=min(32, m), block_n=min(64, n))
    want = ref.bitserial_matmul_ref(x.astype(jnp.int32), planes)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------ bit-parallel matmul ------

@settings(max_examples=10, deadline=None)
@given(m=st.sampled_from([16, 64]), k=st.sampled_from([32, 128, 160]),
       n=st.sampled_from([16, 128]))
def test_bitparallel_matmul_matches_ref(m, k, n):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n), dtype=np.int32)
                    ).astype(jnp.int8)
    got = bitparallel_matmul(x, w, block_m=16, block_n=16, block_k=32)
    want = ref.bitparallel_matmul_ref(x, w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bs_equals_bp_semantics():
    """Both layouts compute the same GEMM (the paper's iso-function claim)."""
    rng = np.random.default_rng(7)
    K, N, bits = 64, 32, 4
    x = jnp.asarray(rng.integers(0, 16, (8, K), dtype=np.int32)).astype(
        jnp.int8)
    w = _rand_words(rng, K, N, bits)
    planes = ref.bitpack_ref(w, bits)
    y_bs = bitserial_matmul(x, planes, block_m=8, block_n=32)
    y_bp = bitparallel_matmul(x, w.astype(jnp.int8), block_m=8,
                              block_n=16, block_k=32)
    np.testing.assert_array_equal(np.asarray(y_bs), np.asarray(y_bp))


# --------------------------------------------------- flash attention -------

@settings(max_examples=8, deadline=None)
@given(b=st.sampled_from([1, 2]), sq=st.sampled_from([32, 64]),
       h=st.sampled_from([1, 2]), d=st.sampled_from([32, 64]),
       causal=st.booleans())
def test_flash_attention_matches_ref(b, sq, h, d, causal):
    rng = np.random.default_rng(b + sq + h + d)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_vs_layers_streaming_attention():
    """The Pallas kernel and the pure-JAX streaming softmax agree."""
    from repro.models.layers import flash_attention as jflash
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 32)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    b = jflash(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# -------------------------------------------- layout-aware dispatch --------

def test_layout_aware_matmul_dispatch():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(0, 8, (128, 64), dtype=np.int32)).astype(
        jnp.int8)
    w2 = _rand_words(rng, 64, 128, 2)   # 2-bit, high DoP -> BS
    y, layout = ops.layout_aware_matmul(x, w2, weight_bits=2)
    assert layout.value == "BS"
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(x.astype(jnp.int32) @ w2.astype(jnp.int32)))

    w8 = _rand_words(rng, 64, 128, 8)   # 8-bit words -> BP
    y8, layout8 = ops.layout_aware_matmul(x, w8.astype(jnp.int32) - 0,
                                          weight_bits=8)
    assert layout8.value == "BP"
    # lossless: unsigned 8-bit words no longer wrap through int8 (PR 9)
    np.testing.assert_array_equal(
        np.asarray(y8),
        np.asarray(x.astype(jnp.int32) @ w8.astype(jnp.int32)))


# ----------------------------------------- grid tiling (un-clamped) --------

def test_tiling_pads_only_to_hardware_minimum():
    from repro.kernels import tiling as tl

    t = tl.bp_tiling(1, 100, 10)
    assert t.dims == (1, 100, 10)
    assert t.padded_dims == (32, 128, 128)   # BP hw minimum, not 128^3
    assert t.grid == (1, 1, 1)
    big = tl.bp_tiling(300, 4096, 512)
    assert big.padded_dims == (384, 4096, 512)
    gm, gn, ks = big.grid   # (M tiles, N tiles, K steps)
    assert (gm * big.bm, ks * big.bk, gn * big.bn) == big.padded_dims
    # unfused BS streams packed uint32 groups: K minimum is 256 words
    bs = tl.bs_tiling(1, 100, 10)
    assert bs.padded_dims == (32, 256, 128)


def test_grid_tiled_equals_single_tile():
    """A problem that fits one tile gives the same result grid-tiled."""
    rng = np.random.default_rng(21)
    M, K, N = 96, 256, 192
    x = jnp.asarray(rng.integers(-128, 128, (M, K), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (K, N), dtype=np.int32)
                    ).astype(jnp.int8)
    one = bitparallel_matmul(x, w, block_m=96, block_n=192, block_k=256)
    grid = bitparallel_matmul(x, w, block_m=32, block_n=128, block_k=128)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(grid))

    bits = 4
    wq = _rand_words(rng, K, N, bits)
    planes = bitpack(wq, bits)
    one = bitserial_matmul(x, planes, block_m=96, block_n=192, block_k=256)
    grid = bitserial_matmul(x, planes, block_m=32, block_n=128, block_k=256)
    np.testing.assert_array_equal(np.asarray(one), np.asarray(grid))


def test_unclamped_deep_k_is_exact_int32():
    """Regression for the f32-accumulator era: at K=4096 the integer
    partial sums exceed f32's 24-bit mantissa, so only the int32
    accumulation path stays bit-exact once ops run un-clamped."""
    rng = np.random.default_rng(4096)
    M, K, N = 8, 4096, 128
    # same-sign operands: partial sums grow monotonically past 2^24
    x = jnp.asarray(rng.integers(64, 128, (M, K), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(64, 128, (K, N), dtype=np.int32)
                    ).astype(jnp.int8)
    got = np.asarray(bitparallel_matmul(x, w))
    want = np.asarray(x).astype(np.int64) @ np.asarray(w).astype(np.int64)
    assert got.dtype == np.int32
    np.testing.assert_array_equal(got, want)
    # and the magnitudes really do exercise the f32-unsafe range
    assert np.abs(want).max() > (1 << 24)


# ------------------------------------- fused bitpack-matmul (ISSUE 9) ------

@settings(max_examples=12, deadline=None)
@given(bits=st.sampled_from([1, 4, 8, 16]),
       m=st.sampled_from([1, 8, 33]),
       k=st.sampled_from([17, 100, 256]),
       n=st.sampled_from([10, 64, 129]))
def test_fused_matches_unfused_and_ref(bits, m, k, n):
    """Differential suite: one-kernel fused bitpack-matmul == the unfused
    pack_weights -> matmul_bs pipeline == the plain-integer reference --
    ragged K, signed activations, widths {1, 4, 8, 16}."""
    rng = np.random.default_rng(bits * 7919 + m * 131 + k * 17 + n)
    x = jnp.asarray(rng.integers(-128, 128, (m, k), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(0, 1 << bits, (k, n)).astype(np.int32))
    fused = np.asarray(ops.matmul_bs_fused(x, w, bits))
    planes = ops.pack_weights(w.astype(jnp.uint32), bits)
    unfused = np.asarray(ops.matmul_bs(x, planes))
    want = (np.asarray(x).astype(np.int64)
            @ np.asarray(w).astype(np.int64)).astype(np.int32)
    np.testing.assert_array_equal(fused, want)
    np.testing.assert_array_equal(unfused, want)


def test_planned_matmul_fuse_pack_dispatch():
    """fuse_pack=True routes the BS side through the fused kernel and
    stays bit-exact with the unfused plan path."""
    from repro.core.cost_model import Layout

    rng = np.random.default_rng(31)
    x = jnp.asarray(rng.integers(0, 8, (128, 64), dtype=np.int32)).astype(
        jnp.int8)
    w = _rand_words(rng, 64, 128, 2).astype(jnp.int32)
    y_f, lay_f = ops.planned_matmul(x, w, weight_bits=2, fuse_pack=True)
    y_u, lay_u = ops.planned_matmul(x, w, weight_bits=2, fuse_pack=False)
    assert lay_f is Layout.BS and lay_u is Layout.BS
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_u))


def test_bp_weight_dtype_is_lossless():
    assert ops.bp_weight_dtype(1) == jnp.int8
    assert ops.bp_weight_dtype(7) == jnp.int8
    assert ops.bp_weight_dtype(8) == jnp.int16   # 255 doesn't fit int8
    assert ops.bp_weight_dtype(15) == jnp.int16
    assert ops.bp_weight_dtype(16) == jnp.int32
    assert ops.bp_weight_dtype(32) == jnp.int32


# --------------------------------------- pallas-bench regression gate ------

def test_pallas_bench_regression_gate():
    from repro.kernels.bench import check_pallas_regression

    base = {"cases": [{"name": "gemv/w4/bp", "us": 10000.0},
                      {"name": "gemv/w4/bs_fused", "us": 500.0}]}
    ok, msg = check_pallas_regression(
        {"cases": [{"name": "gemv/w4/bp", "us": 11000.0}]}, base)
    assert ok and "0 regression" in msg
    # >50% over a super-floor baseline fails (exit-3 path in the CLI)
    ok, msg = check_pallas_regression(
        {"cases": [{"name": "gemv/w4/bp", "us": 16000.0}]}, base)
    assert not ok and "gemv/w4/bp" in msg
    # sub-floor baselines never gate: 4x over 500us is runner jitter
    ok, _ = check_pallas_regression(
        {"cases": [{"name": "gemv/w4/bs_fused", "us": 2000.0}]}, base,
        floor_us=2000.0)
    assert ok
    # unknown cases (new shapes/widths) pass with a note
    ok, msg = check_pallas_regression(
        {"cases": [{"name": "new/w1/bp", "us": 9e9}]}, base)
    assert ok and "1 new" in msg
