"""Scope-excluded boundary-byte accounting (the fused-attention lever)."""
import textwrap

from repro.dist.hlo_bytes import boundary_bytes

HLO = textwrap.dedent("""\
HloModule test
ENTRY %main (p0: f32[100]) -> f32[100] {
  %p0 = f32[100]{0} parameter(0)
  %q = f32[100]{0} add(%p0, %p0), metadata={op_name="jit(f)/proj/add"}
  %s = f32[100]{0} multiply(%q, %q), metadata={op_name="jit(f)/flash_internal/mul"}
  %t = f32[100]{0} exponential(%s), metadata={op_name="jit(f)/flash_internal/exp"}
  ROOT %o = f32[100]{0} add(%t, %p0), metadata={op_name="jit(f)/out/add"}
}
""")


def test_unscoped_counts_everything():
    # writes: q,s,t,o (1600); distinct reads: p0,q,s,t (1600)
    assert boundary_bytes(HLO) == 3200


def test_scope_excludes_kernel_internals():
    got = boundary_bytes(HLO, exclude_scope="flash_internal")
    # backward closure: q's only consumer is in-scope s (XLA drops metadata
    # on some ops, e.g. dots), so q joins the scope; s stays internal;
    # t escapes (read by out-of-scope o).
    # writes: t (400) + o (400); reads: p0 (kernel input + o, 400) + t (400)
    assert got == 1600


def test_scope_noop_when_absent():
    assert boundary_bytes(HLO, exclude_scope="not_there") == 3200
