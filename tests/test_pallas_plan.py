"""Plan -> Pallas lowering (DESIGN.md Sec. 14): a LayoutPlan executes as
a measured kernel sequence whose numbers match every other path bit-exactly
-- the plain-integer reference AND the pim micro-op executor's MAC
decomposition (the ISSUE-9 acceptance criterion)."""
import numpy as np
import pytest

from repro.core.cost_model import Layout
from repro.plan import (
    compile_plan,
    lower_plan_pallas,
    reference_results,
    run_schedule,
    synth_inputs,
    time_schedule,
)
from repro.workloads.ir import Op, Workload


def _hybrid_workload():
    """Two matmuls the planner splits: a 1-bit high-DoP op (BS wins) and
    a full-width INT16 op (BP wins)."""
    return Workload(name="hybrid_mm", ops=(
        Op(name="mm_lo", kind="matmul", m=1, k=32, n=512, width=1,
           bit_level_fraction=1.0),
        Op(name="mm_hi", kind="matmul", m=1, k=64, n=64, width=16),
    ))


@pytest.fixture(scope="module")
def hybrid_plan():
    w = _hybrid_workload()
    p = compile_plan(w, initial_layout=Layout.BS)
    assert p.is_hybrid, "fixture must compile to a genuine hybrid plan"
    return w, p


def test_hybrid_plan_lowers_to_kernel_sequence(hybrid_plan):
    w, p = hybrid_plan
    sched = lower_plan_pallas(p, w)
    assert sched.workload == "hybrid_mm"
    by_op = {s.op: s for s in sched.steps}
    lo, hi = by_op["mm_lo"], by_op["mm_hi"]
    assert lo.layout is Layout.BS and lo.kernel == "bitserial_matmul"
    assert hi.layout is Layout.BP and hi.kernel == "bitparallel_matmul"
    # the BS->BP boundary is an explicit repack, never an implicit cast
    assert hi.repack == "bs2bp"
    assert sched.n_repacks == 1
    # both true and padded dims are recorded (honest measurement contract)
    assert lo.dims == (1, 32, 512)
    assert lo.padded_dims is not None
    d = sched.to_dict()
    assert [s["measured"] for s in d["steps"]] == [True, True]


def test_hybrid_schedule_matches_reference(hybrid_plan):
    w, p = hybrid_plan
    sched = lower_plan_pallas(p, w)
    inputs = synth_inputs(sched, seed=3)
    got = run_schedule(sched, inputs)
    want = reference_results(sched, inputs)
    assert set(got) == {"mm_lo", "mm_hi"}
    for op in got:
        np.testing.assert_array_equal(got[op], want[op])


def test_hybrid_schedule_matches_executor_bit_exact(hybrid_plan):
    """ISSUE-9 acceptance: the Pallas kernel sequence of a hybrid plan
    returns bit-identical numbers to the pim micro-op executor's
    multu + vector_add MAC decomposition of the same ops."""
    from repro.pim import executor as ex
    from repro.pim import programs as pr
    from repro.pim.bitserial import unpack

    w, p = hybrid_plan
    sched = lower_plan_pallas(p, w)

    # operands valid on BOTH paths: multu is an unsigned w-bit multiply,
    # so draw values < 4 (fits the 1-bit op's plane count times nothing
    # -- weights stay < 2^width -- and keeps every MAC accumulator far
    # from its 32-bit limit)
    rng = np.random.default_rng(17)
    inputs = {}
    for s in sched.measured_steps:
        m, k, n = s.dims
        inputs[s.op] = (
            rng.integers(0, 2, (m, k)).astype(np.int8) if s.width == 1
            else rng.integers(0, 4, (m, k)).astype(np.int8),
            rng.integers(0, 1 << min(s.width, 2), (k, n)).astype(np.int32),
        )

    # thread=False: the executor replays each op on ITS synthetic
    # operands; threading would overwrite mm_hi's x with mm_lo's output
    pallas_out = run_schedule(sched, inputs, thread=False)

    def run_prog(prog, inp, n):
        cells = ex.init_cells(prog, n)
        for key, vals in inp.items():
            cells = ex.set_input(cells, prog, key, vals)
        return ex.execute(prog, cells)

    def mult_out(prog, res, n):
        if prog.layout is Layout.BS:
            return unpack(ex.get_output(res.array.cells, prog, "prod", n))
        # BP multu returns the product as a lo/hi word-row pair
        lo = np.asarray(ex.get_output(res.array.cells, prog, "prod_lo",
                                      n)).astype(np.uint64)
        hi = np.asarray(ex.get_output(res.array.cells, prog, "prod_hi",
                                      n)).astype(np.uint64)
        return lo | (hi << np.uint64(prog.width))

    for s in sched.measured_steps:
        x, wm = inputs[s.op]
        m, k, n = s.dims
        # the executor computes in the *assigned* layout's micro-ops;
        # a 32-bit vector_add accumulator keeps the chain exact
        mult = pr.build("multu", s.layout, width=max(s.width, 2))
        add = pr.build("vector_add", Layout.BS, width=32)
        executed = np.zeros((m, n), np.int64)
        for i in range(m):
            acc = np.zeros(n, np.uint64)
            for kk in range(k):
                res = run_prog(
                    mult, {"a": np.full(n, x[i, kk], np.uint64),
                           "b": wm[kk].astype(np.uint64)}, n)
                prod = mult_out(mult, res, n)
                acc = unpack(ex.get_output(
                    run_prog(add, {"a": acc, "b": prod}, n).array.cells,
                    add, "sum", n))
            executed[i] = acc.astype(np.int64)
        np.testing.assert_array_equal(
            pallas_out[s.op].astype(np.int64), executed,
            err_msg=f"{s.op}: Pallas kernel sequence != micro-op executor")


def test_fused_repack_on_bp2bs_boundary():
    """A BP->BS boundary folds the repack into the fused kernel by
    default; fuse_pack=False keeps the explicit pack->matmul pipeline.
    Both paths return identical numbers.

    The cost model never *chooses* BP->BS at sizes this small (a 1-wide
    matmul's BS saving is below the transpose price), so the hybrid
    assignment is constructed by hand -- lowering consumes any
    LayoutPlan, planner-compiled or not."""
    import dataclasses

    w = Workload(name="bp_then_bs", ops=(
        Op(name="mm_hi", kind="matmul", m=1, k=64, n=64, width=16),
        Op(name="mm_lo", kind="matmul", m=1, k=32, n=512, width=1,
           bit_level_fraction=1.0),
    ))
    p = compile_plan(w, initial_layout=Layout.BP)
    p = dataclasses.replace(p, steps=tuple(
        dataclasses.replace(s, layout=Layout.BS) if s.op == "mm_lo" else s
        for s in p.steps))
    assert p.is_hybrid
    fused = lower_plan_pallas(p, w)
    lo = {s.op: s for s in fused.steps}["mm_lo"]
    assert lo.repack == "bp2bs"
    assert lo.kernel == "fused_bitserial_matmul"
    unfused = lower_plan_pallas(p, w, fuse_pack=False)
    lo_u = {s.op: s for s in unfused.steps}["mm_lo"]
    assert lo_u.kernel == "bitserial_matmul"
    assert lo_u.repack == "bp2bs"
    inputs = synth_inputs(fused, seed=9)
    np.testing.assert_array_equal(
        run_schedule(fused, inputs)["mm_lo"],
        run_schedule(unfused, inputs)["mm_lo"])


def test_unsupported_and_over_budget_rows_are_honest():
    """Ops the kernels cannot measure lower to modelled-only rows with a
    reason -- never to a silently clamped launch."""
    w = Workload(name="mixed", ops=(
        Op(name="wide", kind="matmul", m=1, k=32, n=512, width=48,
           bit_level_fraction=1.0),
        Op(name="huge", kind="matmul", m=4096, k=4096, n=4096, width=8),
        Op(name="ker", kind="kernel", kernel="vector_add", n=4096,
           width=16),
    ))
    p = compile_plan(w)
    sched = lower_plan_pallas(p, w, max_macs=2 ** 20)
    by_op = {s.op: s for s in sched.steps}
    assert not by_op["ker"].measured
    assert "no Pallas lowering" in by_op["ker"].note
    assert not by_op["huge"].measured
    assert "over budget" in by_op["huge"].note
    assert by_op["huge"].padded_dims is not None  # reports what it priced
    wide = by_op["wide"]
    if wide.layout is Layout.BS:
        assert not wide.measured and "unsupported: width" in wide.note
    assert sched.measured_steps == ()


def test_conv_lowers_to_im2col_gemv():
    """Conv dims follow the ExecutorBackend lowering: op.n output
    elements x op.k-deep MACs (a GEMV), not an n x n square."""
    w = Workload(name="c", ops=(
        Op(name="cv", kind="conv", k=9, n=64, width=8),))
    p = compile_plan(w)
    sched = lower_plan_pallas(p, w)
    (step,) = sched.measured_steps
    assert step.dims == (64, 9, 1)
    inputs = synth_inputs(sched, seed=1)
    got = run_schedule(sched, inputs)
    want = reference_results(sched, inputs)
    np.testing.assert_array_equal(got["cv"], want["cv"])


def test_time_schedule_reports_every_step(hybrid_plan):
    w, p = hybrid_plan
    sched = lower_plan_pallas(p, w)
    rows = time_schedule(sched, synth_inputs(sched), reps=1)
    assert [r["op"] for r in rows] == [s.op for s in sched.steps]
    for r in rows:
        assert r["us"] is not None and r["us"] > 0
        assert r["dims"] is not None and r["padded_dims"] is not None
