"""resolve_pspec / use_mesh / shard edge cases: absent axes, non-divisible
dims, full replication, and the 3-axis (pod, data, model) production mesh.

Multi-device meshes cannot be built on the host's single CPU device, so
every check that needs one runs in a subprocess with
``--xla_force_host_platform_device_count=512`` (the dry-run pattern, same
as test_system.py); the in-process tests stick to size-1 meshes.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import current_mesh, resolve_pspec, shard, use_mesh


# ------------------------------------------- in-process (1-device-safe) ----

def test_axis_missing_from_mesh_replicates():
    mesh = jax.make_mesh((1,), ("data",))
    assert resolve_pspec((None, "model"), mesh, (4, 64)) == P(None, None)
    # unknown symbolic name degrades the same way
    assert resolve_pspec(("expert",), mesh, (64,)) == P(None)


def test_fully_replicated_spec():
    mesh = jax.make_mesh((1,), ("model",))
    assert resolve_pspec((None, None, None), mesh,
                         (4, 4, 4)) == P(None, None, None)


def test_use_mesh_nests_and_restores():
    assert current_mesh() is None
    m1 = jax.make_mesh((1,), ("data",))
    m2 = jax.make_mesh((1,), ("model",))
    with use_mesh(m1):
        assert current_mesh() is m1
        with use_mesh(m2):
            assert current_mesh() is m2
        assert current_mesh() is m1
    assert current_mesh() is None


def test_use_mesh_restores_on_exception():
    mesh = jax.make_mesh((1,), ("data",))
    try:
        with use_mesh(mesh):
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert current_mesh() is None


def test_shard_noop_off_mesh():
    x = jnp.ones((4, 4))
    assert shard(x, "batch", None) is x


def test_shard_constrains_on_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.ones((4, 4))
    with use_mesh(mesh):
        y = jax.jit(lambda a: shard(a, "batch", None))(x)
    assert (y == x).all()


# ----------------------------- multi-device meshes (512-dev subprocess) ----

_MESH_SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import json
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import resolve_pspec
    from repro.launch.mesh import make_production_mesh

    m24 = jax.make_mesh((2, 4), ("data", "model"))
    prod = make_production_mesh(multi_pod=True)  # (pod=2, data=16, model=16)
    checks = {
        # --- (data=2, model=4) test mesh ---
        # model axis has size 4; dim 6 is not divisible -> replicated
        "nondiv_repl": resolve_pspec((None, "model"), m24, (8, 6))
                       == P(None, None),
        "div_kept": resolve_pspec((None, "model"), m24, (8, 8))
                       == P(None, "model"),
        # batch of 3 can't split over data=2 -> replicated
        "batch_nondiv": resolve_pspec(("batch", None), m24, (3, 8))
                       == P(None, None),
        "batch_data": resolve_pspec(("batch", None), m24, (8, 16))
                       == P("data", None),
        # degradation is per-entry, not all-or-none
        "mixed": resolve_pspec(("batch", "model"), m24, (5, 8))
                       == P(None, "model"),
        # --- 3-axis (pod, data, model) production mesh ---
        # global batch shards over BOTH data-parallel axes
        "batch_both": resolve_pspec(("batch", None), prod, (256, 64))
                       == P(("pod", "data"), None),
        # 16 divides data(16) but not pod*data(32): outer axis dropped
        "batch_inner": resolve_pspec(("batch",), prod, (16,)) == P("data"),
        "model": resolve_pspec((None, "model"), prod, (64, 64))
                       == P(None, "model"),
        # MoE weight layout: experts over data (EP), FF over model (TP)
        "moe": resolve_pspec((None, "data", None, "model"), prod,
                             (4, 16, 64, 64))
                       == P(None, "data", None, "model"),
        # batch of 1 (long_500k decode) fully replicates
        "batch_one": resolve_pspec(("batch", None), prod, (1, 64))
                       == P(None, None),
    }
    print(json.dumps(checks))
""")


def test_resolve_pspec_multi_device_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    checks = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(checks.values()), checks
