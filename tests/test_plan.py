"""repro.plan: scheduler equivalence, DAG oracle, executability.

Pins the ISSUE-5 acceptance criteria:

* the DAG scheduler equals the legacy 2-state phase DP **bit-for-bit**
  (total AND schedule) on random linear chains (property test);
* a 2^n brute-force oracle confirms the scheduler's optimum on small
  random DAGs, including geometry feasibility constraints;
* for every Table-6 app and every iso-area sweep geometry,
  ``LayoutPlan.total_cycles <= min(static BP, static BS)`` with
  transposes charged;
* the AES plan (arriving in BP) reproduces the paper's Sec.-5.4
  hand-built hybrid schedule and its published 6994-cycle total;
* executor-replayed plan cycles match the planner's prediction exactly up
  to the documented Sec.-8 calibration deltas for all 13 executable
  Table-5 kernels;
* the Pallas/model layers dispatch through the same plan
  (``planned_matmul`` / ``pim_quantized_linear``).
"""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost_model import Layout
from repro.core.params import PAPER_SYSTEM
from repro.core.planner import Phase, plan
from repro.core.transpose import transpose_cycles
from repro.plan import (
    PlanError,
    compile_plan,
    replay_matches,
    replay_plan,
)
from repro.sweep import Geometry, iso_area_family
from repro.workloads import Op, Workload, get_workload, workload_names

LAYOUTS = (Layout.BP, Layout.BS)


# ---------------------------------------------------------------------------
# Chain equivalence: DAG scheduler == legacy 2-state DP, bit-for-bit
# ---------------------------------------------------------------------------

def _legacy_dp(phases, sys=PAPER_SYSTEM, initial_layout=None):
    """The pre-refactor ``core.planner.plan`` DP, kept verbatim as the
    reference implementation (so the shim cannot test itself)."""
    INF = float("inf")

    def switch(cur, frm, to):
        if frm == to:
            return 0
        d = "bp2bs" if to is Layout.BS else "bs2bp"
        return transpose_cycles(cur.rows_bp, cur.rows_bs, d, sys)

    cost, back = {}, []
    first = phases[0]
    for lay in LAYOUTS:
        c = first.cycles(lay)
        if initial_layout is not None and initial_layout != lay:
            c += switch(first, initial_layout, lay)
        cost[lay] = c
    for ph in phases[1:]:
        new_cost, back_i = {}, {}
        for lay in LAYOUTS:
            best, best_prev = INF, None
            for prev in LAYOUTS:
                c = cost[prev] + switch(ph, prev, lay) + ph.cycles(lay)
                if c < best:
                    best, best_prev = c, prev
            new_cost[lay] = best
            back_i[lay] = best_prev
        cost = new_cost
        back.append(back_i)
    end = min(LAYOUTS, key=lambda lay: cost[lay])
    sched = [end]
    for back_i in reversed(back):
        sched.append(back_i[sched[-1]])
    sched.reverse()
    return tuple(sched), int(cost[end])


@settings(max_examples=150, deadline=None)
@given(
    st.lists(st.tuples(st.integers(1, 10_000), st.integers(1, 10_000),
                       st.integers(1, 64), st.integers(1, 256)),
             min_size=1, max_size=12),
    st.sampled_from([None, Layout.BP, Layout.BS]),
)
def test_scheduler_equals_legacy_dp_on_chains(costs, init):
    """Property: identical total AND identical schedule (tie-breaking
    included) on random linear phase chains."""
    phases = [Phase(f"p{i}", bp, bs, rbp, rbs)
              for i, (bp, bs, rbp, rbs) in enumerate(costs)]
    want_sched, want_total = _legacy_dp(phases, initial_layout=init)
    p = plan(phases, initial_layout=init)
    assert p.total_cycles == want_total
    assert p.schedule == want_sched


def test_shim_plan_bookkeeping_unchanged():
    """The legacy Plan invariants survive the shim."""
    p = plan([Phase("a", 10, 10_000), Phase("b", 10_000, 10),
              Phase("c", 10, 10_000)])
    assert p.is_hybrid
    assert p.schedule == (Layout.BP, Layout.BS, Layout.BP)
    assert p.total_cycles == 30 + 2 * 145
    assert p.n_transposes == 2
    assert p.transpose_cycles_total == 2 * 145


# ---------------------------------------------------------------------------
# DAG oracle: exact optimum over all 2^n assignments, with geometry
# ---------------------------------------------------------------------------

def _dag_workload(rng, n_ops, p_edge=0.4):
    ops, deps = [], []
    for i in range(n_ops):
        ops.append(Op(
            name=f"op{i}", kind="compute",
            bp_cycles=int(rng.integers(1, 5_000)),
            bs_cycles=int(rng.integers(1, 5_000)),
            rows_bp=int(rng.integers(1, 64)),
            rows_bs=int(rng.integers(1, 256))))
    for a in range(n_ops):
        for b in range(a + 1, n_ops):
            if rng.random() < p_edge:
                deps.append((a, b))
    if not deps and n_ops > 1:
        deps.append((0, n_ops - 1))
    return Workload(name="dag", ops=tuple(ops), deps=tuple(deps))


def _oracle_total(w, sys, labels, initial_layout=None):
    """Independent cost of one full assignment over the DAG."""
    total = 0
    has_pred = {b for _, b in w.edges()}
    for i, op in enumerate(w.ops):
        total += op.bp_cycles if labels[i] is Layout.BP else op.bs_cycles
        if i not in has_pred and initial_layout is not None \
                and labels[i] != initial_layout:
            d = "bp2bs" if labels[i] is Layout.BS else "bs2bp"
            total += transpose_cycles(op.rows_bp, op.rows_bs, d, sys)
    for a, b in w.edges():
        if labels[a] != labels[b]:
            d = "bp2bs" if labels[b] is Layout.BS else "bs2bp"
            total += transpose_cycles(w.ops[b].rows_bp, w.ops[b].rows_bs,
                                      d, sys)
    return total


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(2, 9),
       init=st.sampled_from([None, Layout.BP, Layout.BS]))
def test_dag_scheduler_matches_bruteforce(seed, n_ops, init):
    """The min-cut solve is the true optimum over all 2^n assignments."""
    rng = np.random.default_rng(seed)
    w = _dag_workload(rng, n_ops)
    p = compile_plan(w, initial_layout=init)
    best = min(_oracle_total(w, PAPER_SYSTEM, labels, init)
               for labels in itertools.product(LAYOUTS, repeat=n_ops))
    assert p.total_cycles == best
    # the reported schedule re-prices to the reported total
    assert _oracle_total(w, PAPER_SYSTEM,
                         [p.layout_for(op.name) for op in w.ops],
                         init) == p.total_cycles


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_ops=st.integers(2, 8),
       rows=st.sampled_from([32, 64, 128]))
def test_dag_scheduler_matches_bruteforce_with_geometry(seed, n_ops, rows):
    """Feasibility-constrained oracle: enforce_feasibility=True excludes
    layouts whose row footprint overflows the geometry, and the scheduler
    still finds the constrained optimum."""
    rng = np.random.default_rng(seed)
    w = _dag_workload(rng, n_ops)
    geo = Geometry(rows=rows, cols=512, arrays=512)
    sys = geo.system()

    def ok(i, lay):
        op = w.ops[i]
        r = op.rows_bp if lay is Layout.BP else op.rows_bs
        return r <= rows

    if not all(ok(i, Layout.BP) or ok(i, Layout.BS)
               for i in range(n_ops)):
        with pytest.raises(PlanError):
            compile_plan(w, geometry=geo, enforce_feasibility=True)
        return
    p = compile_plan(w, geometry=geo, enforce_feasibility=True)
    feasible = [
        labels for labels in itertools.product(LAYOUTS, repeat=n_ops)
        if all(ok(i, lay) for i, lay in enumerate(labels))]
    best = min(_oracle_total(w, sys, labels) for labels in feasible)
    assert p.total_cycles == best
    assert p.feasible


def test_linear_chain_deps_equal_implicit_chain():
    """Explicit chain deps give the same plan as the default chain."""
    rng = np.random.default_rng(7)
    w = _dag_workload(rng, 6, p_edge=0.0)
    chain = Workload(name="dag", ops=w.ops,
                     deps=tuple((i, i + 1) for i in range(5)))
    implicit = Workload(name="dag", ops=w.ops)
    pc = compile_plan(chain)
    pi = compile_plan(implicit)
    assert pc.total_cycles == pi.total_cycles
    assert pc.schedule == pi.schedule


def test_workload_rejects_backward_edges():
    ops = (Op(name="a", kind="compute", bp_cycles=1, bs_cycles=1),
           Op(name="b", kind="compute", bp_cycles=1, bs_cycles=1))
    with pytest.raises(ValueError, match="bad dep edge"):
        Workload(name="w", ops=ops, deps=((1, 0),))
    with pytest.raises(ValueError, match="duplicate dep edge"):
        Workload(name="w", ops=ops, deps=((0, 1), (0, 1)))


def test_enforced_feasibility_survives_high_indegree():
    """Regression (code review): a node with many predecessors can rack
    up boundary switch charges that dwarf a too-small infeasibility
    sentinel -- the solver must still refuse the infeasible layout.

    Construction: 10 BP-only sources (BS overflows the rows) feed one
    BS-only sink (BP overflows) whose boundary switch costs 5001; the
    only feasible assignment pays 10 x 5001 in transposes, far more than
    a per-node sentinel, so an under-sized `inf` would let the min-cut
    label the sink BP instead of raising/refusing."""
    n_pred = 10
    geo = Geometry(rows=2048, cols=512, arrays=512)
    ops = [Op(name=f"src{i}", kind="compute", bp_cycles=1, bs_cycles=1,
              rows_bp=1, rows_bs=4096)      # BS infeasible at 2048 rows
           for i in range(n_pred)]
    ops.append(Op(name="sink", kind="compute", bp_cycles=1, bs_cycles=1,
                  rows_bp=3000, rows_bs=2000))  # BP infeasible
    w = Workload(name="fanin", ops=tuple(ops),
                 deps=tuple((i, n_pred) for i in range(n_pred)))
    p = compile_plan(w, geometry=geo, enforce_feasibility=True)
    assert p.layout_for("sink") == Layout.BS
    assert all(p.layout_for(f"src{i}") == Layout.BP
               for i in range(n_pred))
    assert p.feasible
    assert p.n_transposes == n_pred
    assert p.total_cycles == n_pred + 1 + n_pred * (3000 + 2000 + 1)


# ---------------------------------------------------------------------------
# Acceptance: plans across every app x geometry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("app", workload_names("table6"))
def test_plan_beats_statics_everywhere(app):
    """For every Table-6 app and every sweep geometry, the plan (with
    transposes charged) never loses to either static layout."""
    w = get_workload(app)
    for geo in iso_area_family():
        p = compile_plan(w, geometry=geo)
        assert p.total_cycles <= min(p.static_bp, p.static_bs), \
            (app, geo.label())
        assert p.geometry == geo


def test_plan_matches_planner_backend_pins():
    """The plan route reproduces the hard-pinned legacy headline numbers
    (same pins as tests/test_workloads.py)."""
    pins = {"aes": (18624, 24702, 6961), "vgg16": (3704282, 4794817, 3686062),
            "hdc": (134417, 108688, 101793), "keccak": (22896, 42072, 11582)}
    for app, (bp, bs, hybrid) in pins.items():
        p = compile_plan(get_workload(app))
        assert (p.static_bp, p.static_bs, p.total_cycles) == (bp, bs, hybrid)


def test_aes_plan_reproduces_hand_built_hybrid_schedule():
    """Sec. 5.4: arriving in BP, the compiled AES plan is exactly the
    paper's hand schedule (SubBytes in BS, everything else BP; two
    transposes per round) at the published 6994-cycle total."""
    from repro.core.apps import aes_paper_accounting

    p = compile_plan(get_workload("aes"), initial_layout=Layout.BP)
    for op_name, lay in p.op_schedule():
        assert (lay == "BS") == op_name.startswith("SB"), (op_name, lay)
    acc = aes_paper_accounting()
    assert p.total_cycles == acc["hybrid"] == 6994
    assert p.n_transposes == 20  # 2 per round x 10 rounds
    assert round(p.hybrid_speedup, 2) == 2.66


def test_planned_aes_encrypts_correctly():
    """The functional AES simulation driven by the compiled plan matches
    the FIPS-197 vector (the plan is executable, not just priceable)."""
    from repro.pim import aes

    key = np.frombuffer(bytes.fromhex("000102030405060708090a0b0c0d0e0f"),
                        np.uint8).copy()
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8).copy()
    p = compile_plan(get_workload("aes"), initial_layout=Layout.BP)
    ct = bytes(aes.encrypt_planned(pt, key, dict(p.op_schedule())))
    assert ct.hex() == "69c4e0d86a7b0430d8cdb78070b4c55a"


# ---------------------------------------------------------------------------
# Replay: executor cycles == plan prediction up to Sec.-8 deltas
# ---------------------------------------------------------------------------

def test_replay_matches_prediction_for_all_executable_kernels():
    from repro.pim.programs import EXECUTABLE_KERNELS

    assert len(EXECUTABLE_KERNELS) == 13
    for kernel in EXECUTABLE_KERNELS:
        w = get_workload(f"mk/{kernel}")
        p = compile_plan(w)
        rows = replay_plan(p, w, execute=(kernel in ("multu", "reduction")))
        assert len(rows) == 1
        assert replay_matches(rows), rows
        assert rows[0]["layout"] == p.layout_for(kernel).value


def test_replay_notes_surface_in_planner_backend():
    from repro.workloads import PlannerBackend

    rep = PlannerBackend(execute=True).estimate(get_workload("mk/multu"))
    assert any(n.startswith("replay multu") for n in rep.notes)
    # summary stays byte-compatible with the non-executing backend
    base = PlannerBackend().estimate(get_workload("mk/multu"))
    assert rep.summary == base.summary


def test_plan_programs_lower_kernel_steps():
    from repro.plan import plan_programs

    w = get_workload("mk/vector_add")
    p = compile_plan(w)
    progs = plan_programs(p, w)
    assert len(progs) == 1
    idx, prog = progs[0]
    assert prog.layout == p.steps[idx].layout
    assert prog.name == "vector_add"


# ---------------------------------------------------------------------------
# Model/Pallas dispatch through the same plan
# ---------------------------------------------------------------------------

def test_planned_matmul_follows_plan_layout():
    import jax.numpy as jnp

    from repro.kernels.ops import planned_matmul
    from repro.workloads.ir import workload

    rng = np.random.default_rng(3)
    m, k, n, bits = 8, 40, 16, 3
    x = jnp.asarray(rng.integers(-8, 8, (m, k), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(0, 2 ** bits, (k, n), dtype=np.uint32))
    want = np.asarray(x).astype(np.int64) @ np.asarray(w).astype(np.int64)
    wl = workload("one_mm", [Op(name="mm", kind="matmul", m=m, k=k, n=n,
                                width=bits)])
    p = compile_plan(wl)
    for plan_arg, op_name in ((p, "mm"), (p, None), (None, None)):
        y, layout = planned_matmul(x, w, weight_bits=bits, plan=plan_arg,
                                   op_name=op_name)
        np.testing.assert_array_equal(np.asarray(y), want)
        if plan_arg is not None:
            assert layout == p.layout_for("mm")


def test_pim_quantized_linear_consumes_plan():
    import jax.numpy as jnp

    from repro.models.layers import pim_quantized_linear
    from repro.workloads.ir import workload

    rng = np.random.default_rng(5)
    b, s, k, n, bits = 2, 4, 33, 8, 2
    x = jnp.asarray(rng.integers(-8, 8, (b, s, k), dtype=np.int32)
                    ).astype(jnp.int8)
    w = jnp.asarray(rng.integers(0, 2 ** bits, (k, n), dtype=np.uint32))
    wl = workload("lin", [Op(name="proj", kind="matmul", m=b * s, k=k,
                             n=n, width=bits)])
    p = compile_plan(wl)
    y, layout = pim_quantized_linear(x, w, weight_bits=bits, plan=p,
                                     op_name="proj")
    assert y.shape == (b, s, n)
    want = (np.asarray(x).reshape(-1, k).astype(np.int64)
            @ np.asarray(w).astype(np.int64)).reshape(b, s, n)
    np.testing.assert_array_equal(np.asarray(y), want)
    assert layout == p.layout_for("proj")


# ---------------------------------------------------------------------------
# Plan IR plumbing
# ---------------------------------------------------------------------------

def test_layout_plan_to_dict_roundtrips_schedule():
    p = compile_plan(get_workload("aes"))
    d = p.to_dict()
    assert d["total_cycles"] == p.total_cycles
    assert len(d["steps"]) == len(p.steps)
    assert d["op_schedule"] == p.op_schedule()
    assert sum(t["cycles"] for t in d["transposes"]) \
        == p.transpose_cycles_total


def test_layout_for_unknown_op_raises():
    p = compile_plan(get_workload("mk/multu"))
    assert p.layout_for() == p.layout_for("multu")
    with pytest.raises(KeyError):
        p.layout_for("nope")


def test_feasibility_recorded_at_shallow_geometry():
    """rows=8 starves the BS vertical footprint: the mk/multu plan must
    either assign BP or flag the BS steps infeasible -- and with
    enforcement on, BS is excluded outright."""
    geo = Geometry(rows=8, cols=512, arrays=8192)
    w = get_workload("mk/multu")
    p = compile_plan(w, geometry=geo, enforce_feasibility=True)
    assert p.layout_for("multu") == Layout.BP
    assert p.feasible
    for s in p.steps:
        assert not s.bs_feasible  # live_words * width + 1 = 65 > 8 rows


def test_cli_plan_quick_writes_artifact(tmp_path, monkeypatch, capsys):
    import json

    from repro.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_ARTIFACT_DIR", str(tmp_path))
    assert main(["plan", "--quick"]) == 0
    env = json.loads((tmp_path / "plans.json").read_text())
    assert env["artifact"] == "plans" and env["schema_version"] == 1
    data = env["payload"]
    assert set(data) == set(workload_names("table6"))
    assert data["aes"]["total_cycles"] == 6961
    capsys.readouterr()


def test_cli_plan_execute_and_geometry(capsys):
    from repro.__main__ import main

    assert main(["plan", "mk/multu", "--execute", "--steps",
                 "--geometry", "128x512x64"]) == 0
    out = capsys.readouterr().out
    assert "replay multu" in out and "OK" in out


def test_cli_plan_quick_json_keeps_full_steps(tmp_path, monkeypatch,
                                              capsys):
    """Regression (code review): --json dumps full plans (steps +
    transposes) even when combined with --quick's summary artifact."""
    import json

    from repro.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_ARTIFACT_DIR", str(tmp_path))
    out_json = tmp_path / "full.json"
    assert main(["plan", "aes", "--quick", "--json", str(out_json)]) == 0
    summary = json.loads((tmp_path / "plans.json").read_text())["payload"]
    full = json.loads(out_json.read_text())
    assert "steps" not in summary["aes"]
    assert len(full["aes"]["steps"]) == 40
    assert full["aes"]["transposes"]
    capsys.readouterr()
