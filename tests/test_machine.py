"""Machine-level schedule IR tests (ISSUE 8).

Partitioner invariants (shard sums, class coverage, ragged splits,
N=1 bit-for-bit reduction, the PlanError feasibility sentinel), the
end-to-end delta-catalogue identity, the execution engine's
executed-vs-scheduled gates, the serving hook, and the batched-runner
LRU regression.
"""
from __future__ import annotations

import pytest

from repro.core.cost_model import Layout
from repro.machine import (
    MachineError,
    class_boundaries,
    execute_schedule,
    plan_machine,
    run_diff,
    shard_sizes_for,
    shard_workload,
)
from repro.plan import PlanError, compile_plan
from repro.sweep.grid import Geometry, PAPER_GEOMETRY
from repro.workloads import get_workload
from repro.workloads.ir import Op, workload


# ---------------------------------------------------------------------------
# Partitioner invariants
# ---------------------------------------------------------------------------

def ragged_workload():
    """3 sharded ops with awkward extents (none divisible by 4)."""
    return workload("ragged", [
        Op(name="k1", kind="kernel", kernel="multu", n=1027, width=16),
        Op(name="mm", kind="matmul", m=4, k=64, n=10, width=16, chunk=8),
        Op(name="cv", kind="conv", k=9, n=333, width=16),
    ])


@pytest.mark.parametrize("n_parts", (1, 2, 4, 7, 512))
def test_shard_sizes_sum_to_extent(n_parts):
    w = ragged_workload()
    bounds = class_boundaries(w, n_parts)
    assert bounds[0] == 0 and bounds == sorted(set(bounds))
    groups = [(bounds[i], bounds[i + 1] if i + 1 < len(bounds) else n_parts)
              for i in range(len(bounds))]
    assert sum(e - s for s, e in groups) == n_parts  # classes cover N
    for i, op in enumerate(w.ops):
        total = sum((e - s) * shard_sizes_for(w, n_parts, s)[i]
                    for s, e in groups)
        assert total == op.n, op.name


def test_shard_workload_drops_empty_and_bridges_deps():
    w = workload("chain", [
        Op(name="a", kind="kernel", kernel="multu", n=8, width=16),
        Op(name="b", kind="kernel", kernel="multu", n=0, width=16),
        Op(name="c", kind="kernel", kernel="multu", n=8, width=16),
    ], deps=((0, 1), (1, 2)))
    sub, kept = shard_workload(w, (8, 0, 8))
    assert kept == (0, 2)
    assert [op.name for op in sub.ops] == ["a", "c"]
    assert sub.deps == ((0, 1),)  # a -> c bridged through dropped b


def test_conv_shard_scales_in_elems():
    w = workload("conv", [Op(name="cv", kind="conv", k=9, n=100,
                             in_elems=400, width=16)])
    sub, _ = shard_workload(w, (25,))
    assert sub.ops[0].n == 25
    assert sub.ops[0].in_elems == 100  # input scales with the shard


@pytest.mark.parametrize("name", ("vgg16", "aes", "mk/multu"))
def test_n1_reduces_bit_for_bit(name):
    w = get_workload(name)
    s = plan_machine(w, n_parts=1)
    p = compile_plan(w, PAPER_GEOMETRY.system())
    assert s.total_cycles == p.total_cycles == s.planner_total
    assert s.deltas == () and s.explained
    assert len(s.classes) == 1
    assert s.classes[0].plan.total_cycles == p.total_cycles


@pytest.mark.parametrize("name,n_parts", [
    ("vgg16", 4), ("vgg16", 512), ("aes", 512),
    ("mk/multu", 512), ("mk/reduction", 512), ("conv2d", 8),
])
def test_delta_catalogue_explains_every_cycle(name, n_parts):
    s = plan_machine(get_workload(name), n_parts=n_parts)
    assert s.explained, (s.total_cycles, s.planner_total, s.delta_total)
    assert sum(c.groups for c in s.classes) == n_parts
    assert s.arrays_total == PAPER_GEOMETRY.arrays


def test_ragged_split_explained_and_covers_extents():
    w = ragged_workload()
    s = plan_machine(w, n_parts=4)
    assert s.explained
    for op in w.ops:
        shards = s.classes_for(op.name)
        total = sum(p.shard_n * p.groups for p in shards)
        assert total == op.n, op.name


def test_bad_partition_count_raises():
    w = get_workload("mk/multu")
    with pytest.raises(MachineError):
        plan_machine(w, n_parts=3)  # 3 does not divide 512 arrays
    with pytest.raises(MachineError):
        plan_machine(w, n_parts=0)


def test_row_overflow_raises_plan_error_sentinel():
    # kernel feasibility is the live-words row model: at rows=2 even the
    # BP footprint of multu overflows, in every partition class
    w = workload("fat", [Op(name="fat", kind="kernel", kernel="multu",
                            n=64, width=16)])
    tiny = Geometry(rows=2, cols=512, arrays=4)
    # mis-pricing silently is the failure mode; the sentinel must fire
    with pytest.raises(PlanError):
        plan_machine(w, tiny, n_parts=4, enforce_feasibility=True)
    s = plan_machine(w, tiny, n_parts=4)  # advisory mode still schedules
    assert s.explained


def test_geometry_threading_changes_class_geometry():
    geo = Geometry(rows=64, cols=512, arrays=1024)
    s = plan_machine(get_workload("vgg16"), geo)
    assert s.geometry == geo and s.n_partitions == 1024
    for c in s.classes:
        assert c.geometry.rows == 64 and c.arrays_per_group == 1


# ---------------------------------------------------------------------------
# IR serialization
# ---------------------------------------------------------------------------

def test_schedule_to_dict_round_trips_summary():
    s = plan_machine(get_workload("vgg16"), n_parts=8)
    d = s.to_dict()
    assert d["n_partitions"] == 8
    assert d["total_cycles"] == s.total_cycles
    assert len(d["classes"]) == len(s.classes)
    assert len(d["deltas"]) == len(s.deltas)
    assert {m["phase"] for m in d["movement"]} <= {
        "load", "readout", "bus", "redistribute"}
    assert all(set(p) >= {"op", "cls", "shard_n", "layouts"}
               for p in d["placed"])


# ---------------------------------------------------------------------------
# Execution engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("vgg16", "aes", "mk/multu",
                                  "mk/reduction"))
def test_executed_rows_all_explained_static(name):
    w = get_workload(name)
    s = plan_machine(w, n_parts=8)
    res = execute_schedule(s, w, functional=False)
    assert res["unexplained"] == []
    assert all(r["explained"] for r in res["rows"])
    assert res["scheduled_compute"] == s.compute_cycles


def test_functional_execution_simulates_all_arrays():
    geo = Geometry(rows=128, cols=512, arrays=8)
    w = get_workload("vgg16")
    s = plan_machine(w, geo)
    res = execute_schedule(s, w, functional=True, collect_hlo=True)
    assert res["unexplained"] == []
    assert res["arrays_simulated"] >= 8
    assert {p["name"] for p in res["programs"]} == {"multu", "vector_add"}
    io = res["io"]
    assert io is not None and io["hlo_boundary_bytes"] > 0
    assert io["model_io_bytes"] > 0


def test_diff_harness_green_small_scope():
    rows, fails = run_diff(("mk/multu", "aes"), parts=(1, 4),
                           execute=True, functional=False)
    assert fails == []
    assert all(r.status == "ok" for r in rows)
    assert {r.n_parts for r in rows} == {1, 4}


# ---------------------------------------------------------------------------
# Serving hook
# ---------------------------------------------------------------------------

def test_plan_service_compile_machine_uses_cache():
    from repro.serve import PlanCache, PlanService, TrafficMix

    service = PlanService(cache=PlanCache(persist=False))
    req = TrafficMix.default().sample(1, seed=0)[0]
    s1 = service.compile_machine(req, n_parts=4)
    misses = service.cache.misses
    assert s1.explained and misses > 0
    s2 = service.compile_machine(req, n_parts=4)
    assert service.cache.misses == misses  # warm pass fully cache-served
    assert s2.total_cycles == s1.total_cycles


# ---------------------------------------------------------------------------
# Batched-runner LRU (satellite regression)
# ---------------------------------------------------------------------------

def test_batched_cache_lru_bounds_and_counts():
    import jax.numpy as jnp

    from repro.pim import executor as ex
    from repro.pim.programs import build

    progs = [build(k, lay, width=16)
             for k in ("multu", "vector_add", "equal")
             for lay in (Layout.BP, Layout.BS)]
    prev = ex.set_batched_cache_limit(2)
    try:
        ex.clear_batched_cache()
        for p in progs:
            cols = 512 if p.layout is Layout.BS else 512
            ex.run_batched(p, jnp.zeros((2, p.rows, cols), bool))
        stats = ex.batched_cache_stats()
        assert stats["size"] <= 2 and stats["limit"] == 2
        assert stats["misses"] == len(progs)
        assert stats["evictions"] == len(progs) - 2
        # LRU order: the most recent program is a hit, the oldest re-misses
        ex.run_batched(progs[-1], jnp.zeros((2, progs[-1].rows, 512), bool))
        assert ex.batched_cache_stats()["hits"] == 1
        ex.run_batched(progs[0], jnp.zeros((2, progs[0].rows, 512), bool))
        assert ex.batched_cache_stats()["misses"] == len(progs) + 1
    finally:
        ex.set_batched_cache_limit(prev)
        ex.clear_batched_cache()


def test_batched_cache_limit_validation():
    from repro.pim import executor as ex

    with pytest.raises(ValueError):
        ex.set_batched_cache_limit(0)
