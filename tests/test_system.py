"""End-to-end behaviour tests: HLO analyzers on known programs, small-mesh
sharded train/serve steps (8 forced host devices via subprocess), and the
mesh/launch plumbing."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.dist import hlo_analysis
from repro.dist.hlo_bytes import boundary_bytes


# ------------------------------------------------------ HLO analyzers ------

def test_collect_collectives_known_program():
    hlo = textwrap.dedent("""\
    HloModule test
    ENTRY %main (p0: f32[128,256]) -> f32[128,256] {
      %p0 = f32[128,256]{1,0} parameter(0)
      %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups=[16,16]<=[256]
      %ag = f32[128,256]{1,0} all-gather(%ar), replica_groups=[32,8]<=[256]
      ROOT %cp = f32[128,256]{1,0} collective-permute(%ag), source_target_pairs={{0,1}}
    }
    """)
    stats = hlo_analysis.collect_collectives(hlo, default_group=16)
    n = 128 * 256 * 4
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "collective-permute": 1}
    assert stats.wire_bytes["all-reduce"] == pytest.approx(2 * 15 / 16 * n)
    assert stats.wire_bytes["all-gather"] == pytest.approx(7 / 8 * n)
    assert stats.wire_bytes["collective-permute"] == pytest.approx(n)


def test_collect_collectives_start_not_double_counted():
    hlo = textwrap.dedent("""\
    HloModule test
    ENTRY %main (p0: f32[64]) -> f32[64] {
      %p0 = f32[64]{0} parameter(0)
      %ar0 = f32[64]{0} all-reduce-start(%p0), replica_groups=[2,2]<=[4]
      ROOT %ar1 = f32[64]{0} all-reduce-done(%ar0)
    }
    """)
    stats = hlo_analysis.collect_collectives(hlo)
    assert stats.counts == {"all-reduce": 1}


def test_boundary_bytes_counts_writes_and_distinct_reads():
    hlo = textwrap.dedent("""\
    HloModule test
    ENTRY %main (p0: f32[100]) -> f32[100] {
      %p0 = f32[100]{0} parameter(0)
      %a = f32[100]{0} add(%p0, %p0)
      %b = f32[100]{0} multiply(%a, %p0)
      ROOT %t = (f32[100]) tuple(%b)
    }
    """)
    b = boundary_bytes(hlo)
    # writes: a (400) + b (400); distinct reads: p0 (400) + a (400)
    assert b == 1600


# --------------------------------------------- small-mesh integration ------

_SMALL_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.configs import get_config, reduced_config
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_mesh
from repro.models import abstract_params, init_params, param_shardings, registry
from repro.optim import adamw
from repro.train.step import make_train_step, make_serve_step

cfg = reduced_config(get_config("{arch}"))
mesh = make_mesh((2, 4), ("data", "model"))
fns = registry.model_fns(cfg)
structure = fns.param_structure(cfg)
params = init_params(structure, jax.random.key(0))
shardings = param_shardings(structure, mesh)
params = jax.device_put(params, shardings)
opt_state = adamw.init_state(params)
step = make_train_step(cfg, adamw.AdamWConfig(lr=1e-3))
batch = {{
    "tokens": jnp.zeros((8, 16), jnp.int32),
    "labels": jnp.zeros((8, 16), jnp.int32),
    "mask": jnp.ones((8, 16), jnp.float32),
}}
if cfg.family == "vlm":
    batch["prefix_embeds"] = jnp.zeros((8, cfg.enc_seq, cfg.d_model))
if cfg.family == "audio":
    batch["frames"] = jnp.zeros((8, cfg.enc_seq, cfg.d_model))
with use_mesh(mesh):
    jstep = jax.jit(step)
    params2, opt2, metrics = jstep(params, opt_state, batch)
    loss1 = float(metrics["loss"])
    _, _, metrics2 = jstep(params2, opt2, batch)
    loss2 = float(metrics2["loss"])

    serve = jax.jit(make_serve_step(cfg))
    cache = init_params(fns.cache_structure(cfg, 8, 32), jax.random.key(1))
    if cfg.family == "audio":
        from repro.models import whisper
        enc = whisper.encode(cfg, params2, batch["frames"])
        cache["cross_kv"] = whisper.build_cross_kv(cfg, params2, enc)
    tok, cache = serve(params2, cache, jnp.zeros((8, 1), jnp.int32))
print(json.dumps({{"loss1": loss1, "loss2": loss2,
                   "tok_shape": list(tok.shape)}}))
"""


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "dbrx_132b",
                                  "mamba2_780m", "recurrentgemma_2b"])
def test_sharded_train_and_serve_step_8dev(arch):
    """Real sharded execution on 8 forced host devices: the train step must
    run, improve the loss, and the serve step must decode."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SMALL_MESH_SCRIPT.format(arch=arch)],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert np.isfinite(res["loss1"]) and np.isfinite(res["loss2"])
    assert res["loss2"] < res["loss1"]  # one optimizer step helps
    assert res["tok_shape"] == [8, 1]


# ------------------------------------------------------------- mesh --------

def test_make_mesh_helper():
    from repro.launch.mesh import make_mesh
    m = make_mesh((1,), ("data",))
    assert m.axis_names == ("data",)


def test_resolve_pspec_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import resolve_pspec
    mesh = jax.make_mesh((1,), ("data",))
    # batch dim of size 1 with data axis of size 1 divides -> kept
    assert resolve_pspec(("batch", None), mesh, (4, 8)) == P("data", None)
    # axis absent from mesh -> replicated
    assert resolve_pspec(("model",), mesh, (8,)) == P(None)
