"""Sweep-engine tests (ISSUE 4 tentpole).

Pins the four contracts of `repro.sweep`:

1. vectorized == scalar, bit-for-bit, exhaustively over every Table-5
   kernel x layout x width {4, 8, 16, 32} (the acceptance grid) -- plus
   the geometry axis;
2. the sweep engine (SweepSpec / run_sweep): shapes, chunking, content-hash
   disk cache, and mesh sharding all agree with the direct evaluation;
3. frontier extraction matches the golden ``[guidelines]`` snapshot and
   the CLI-emitted ``guidelines.json``;
4. the Backend protocol: batched ``estimate_many`` equals the sequential
   loop, and a non-default geometry actually changes reported cycles on
   every cycle backend (the silent-PAPER_SYSTEM regression).
"""
from __future__ import annotations

import dataclasses
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core.cost_model import Layout
from repro.core.microkernels import MICROKERNELS, kernel_cost
from repro.core.params import ArrayParams, SystemParams, PAPER_SYSTEM
from repro.sweep import (
    Geometry,
    PAPER_GEOMETRY,
    SweepSpec,
    guidelines,
    guidelines_lines,
    hybrid_win_set,
    iso_area_family,
    run_sweep,
)
from repro.sweep import vectorized as V

SRC = str(Path(__file__).parent.parent / "src")
GOLDEN = Path(__file__).parent / "golden" / "paper_tables.txt"

ACCEPTANCE_WIDTHS = (4, 8, 16, 32)


def _mk_n(name: str) -> int:
    return 8192 if name == "relu" else 1024


# ------------------------------------------------ 1. bit-for-bit ----------

@pytest.mark.parametrize("name", sorted(MICROKERNELS))
def test_vectorized_equals_scalar_exhaustive(name):
    """Every Table-5 kernel x layout x width {4,8,16,32}: the jnp recipe
    evaluation equals `microkernels.kernel_cost` exactly (acceptance)."""
    n = _mk_n(name)
    for lay in (Layout.BP, Layout.BS):
        for w in ACCEPTANCE_WIDTHS:
            c = kernel_cost(name, lay, n=n, width=w)
            load, comp, ro = V.kernel_cost_vec(
                name, lay, n=n, width=w, cols=PAPER_SYSTEM.array.cols,
                arrays=PAPER_SYSTEM.num_arrays)
            assert (int(load), int(comp), int(ro)) == \
                (c.load, c.compute, c.readout), (name, lay, w)


def test_vectorized_equals_scalar_across_geometries():
    """The geometry axis too: batching-engaged small systems included."""
    geos = [Geometry(128, 512, 512), Geometry(128, 512, 4),
            Geometry(64, 256, 2, row_bandwidth_bits=256),
            Geometry(1024, 512, 64)]
    for name in ("vector_add", "multu", "reduction", "relu", "bitweave2"):
        n = _mk_n(name)
        for g in geos:
            s = g.system()
            for lay in (Layout.BP, Layout.BS):
                for w in (8, 32):
                    c = kernel_cost(name, lay, n=n, width=w, sys=s)
                    load, comp, ro = V.kernel_cost_vec(
                        name, lay, n=n, width=w, cols=g.cols,
                        arrays=g.arrays,
                        row_bandwidth_bits=g.row_bandwidth_bits)
                    assert (int(load), int(comp), int(ro)) == \
                        (c.load, c.compute, c.readout), (name, lay, w, g)


def test_grid_is_one_batched_evaluation():
    """eval_grid returns the whole kernel x layout x width x geometry
    surface from one call, matching per-point scalar evaluation."""
    kernel_ns = tuple((k, _mk_n(k)) for k in sorted(MICROKERNELS))
    geo = iso_area_family()
    grid = np.asarray(V.eval_grid(
        kernel_ns, ACCEPTANCE_WIDTHS,
        [g.rows for g in geo], [g.cols for g in geo],
        [g.arrays for g in geo], [g.row_bandwidth_bits for g in geo]))
    assert grid.shape == (len(kernel_ns), 2, len(ACCEPTANCE_WIDTHS),
                          len(geo), 3)
    rng = np.random.default_rng(0)
    for _ in range(40):
        k = int(rng.integers(len(kernel_ns)))
        li = int(rng.integers(2))
        wi = int(rng.integers(len(ACCEPTANCE_WIDTHS)))
        gi = int(rng.integers(len(geo)))
        name, n = kernel_ns[k]
        c = kernel_cost(name, (Layout.BP, Layout.BS)[li], n=n,
                        width=ACCEPTANCE_WIDTHS[wi], sys=geo[gi].system())
        assert tuple(grid[k, li, wi, gi]) == (c.load, c.compute, c.readout)


# ------------------------------------------------ 2. sweep engine ---------

def test_iso_area_family_paper_point_and_default_size():
    fam = iso_area_family()
    assert PAPER_GEOMETRY in fam
    assert len(fam) >= 8  # acceptance: >= 8 iso-area geometries


def test_run_sweep_shapes_and_feasibility(tmp_path):
    spec = SweepSpec.default()
    r = run_sweep(spec, cache_dir=str(tmp_path))
    K, W, G = len(spec.workloads), len(spec.widths), len(spec.geometries)
    assert r.breakdown.shape == (K, 2, W, G, 3)
    assert r.totals.shape == (K, 2, W, G)
    assert r.bs_feasible.shape == (K, W, G)
    assert r.bp_feasible.shape == (K, G)
    # paper geometry @ w=16: feasibility mirrors the repo's Challenge-2
    # rule (SystemParams.bs_rows_required) per kernel -- if_then_else's
    # 10 live words overflow a 128-row BS column, everything else fits
    gi = spec.geometries.index(PAPER_GEOMETRY)
    wi = spec.widths.index(16)
    for k, name in enumerate(spec.workloads):
        mk = MICROKERNELS[name.removeprefix("mk/")]
        expected = not PAPER_SYSTEM.bs_row_overflow(mk.live_words, 16)
        assert bool(r.bs_feasible[k, wi, gi]) == expected, name
    assert r.bp_feasible[:, gi].all()
    # 8-row arrays cannot hold any 16-bit BS footprint (3+ live words)
    gi8 = next(i for i, g in enumerate(spec.geometries) if g.rows == 8)
    assert not r.bs_feasible[:, wi, gi8].any()


def test_run_sweep_chunking_invariant():
    spec = SweepSpec.default(workloads=("mk/vector_add", "mk/gt_0"))
    whole = run_sweep(spec, use_cache=False)
    chunked = run_sweep(dataclasses.replace(spec, chunk=2),
                        use_cache=False)
    assert (whole.breakdown == chunked.breakdown).all()


def test_sweep_cache_hit_and_invalidation(tmp_path):
    spec = SweepSpec.default(workloads=("mk/multu",), widths=(8, 16))
    r1 = run_sweep(spec, cache_dir=str(tmp_path))
    assert not r1.cache["hit"]
    r2 = run_sweep(spec, cache_dir=str(tmp_path))
    assert r2.cache["hit"]
    assert (r1.breakdown == r2.breakdown).all()
    # a different spec misses
    r3 = run_sweep(dataclasses.replace(spec, widths=(8, 32)),
                   cache_dir=str(tmp_path))
    assert not r3.cache["hit"]
    assert r1.cache["key"] != r3.cache["key"]


def test_sweep_sharded_matches_unsharded(tmp_path):
    """`mesh=` routes through repro.dist.shard; results are identical
    (graceful degradation makes this exact on any device count)."""
    import jax
    from jax.sharding import Mesh

    spec = SweepSpec.default(workloads=("mk/vector_add", "mk/multu"))
    base = run_sweep(spec, use_cache=False)
    mesh = Mesh(np.array(jax.devices()), ("data",))
    sharded = run_sweep(spec, use_cache=False, mesh=mesh)
    assert (base.breakdown == sharded.breakdown).all()


def test_sweep_rejects_multi_op_workloads():
    with pytest.raises(ValueError, match="single-kernel"):
        run_sweep(SweepSpec.default(workloads=("aes",)), use_cache=False)


# ------------------------------------------------ 3. frontier / golden ----

@pytest.fixture(scope="module")
def default_guidelines():
    return guidelines(use_cache=False)


def _golden_guidelines_lines() -> list[str]:
    text = GOLDEN.read_text()
    body = text.split("[guidelines]")[1].splitlines()[1:]
    lines = []
    for ln in body:
        if ln.startswith("["):  # next golden section (e.g. [traced])
            break
        if ln.strip():
            lines.append(ln)
    return lines


def test_guidelines_match_golden_snapshot(default_guidelines):
    """Crossover table + hybrid set == the pinned [guidelines] section."""
    assert guidelines_lines(default_guidelines) == \
        _golden_guidelines_lines()


def test_crossover_table_consistency(default_guidelines):
    cross = default_guidelines["crossover"]
    assert set(cross) == {f"mk/{k}" for k in MICROKERNELS}
    for name, c in cross.items():
        assert c["crossover_width"] == max(c["bs_win_widths"], default=0)
        # win / tie sets never overlap
        assert not set(c["bs_win_widths"]) & set(c["tie_widths"]), name
    # sanity of the headline shape: sign-read is BS-always, division never
    assert cross["mk/ge_0"]["bs_win_widths"] == [4, 8, 16, 32]
    assert cross["mk/divu"]["bs_win_widths"] == []


def test_hybrid_win_set_matches_planner():
    from repro.workloads import characterize

    hybrid = hybrid_win_set()
    assert "aes" in hybrid
    for app in hybrid:
        s = characterize(app, backends=("planner",))["planner"].summary
        assert s["is_hybrid"]
        assert s["hybrid_cycles"] < min(s["bp_cycles"], s["bs_cycles"])


def test_crossover_at_nondefault_geometry_differs():
    """Capacity batching flips winners across the iso-area family for at
    least one (workload, width) cell (the geometry axis is not inert)."""
    r = run_sweep(SweepSpec.default(), use_cache=False)
    from repro.sweep.frontier import bs_win_mask

    wins = bs_win_mask(r)
    assert (wins.any(axis=2) != wins.all(axis=2)).any()


# ------------------------------------------------ 4. backend protocol -----

SMALL_SYS = SystemParams(array=ArrayParams(rows=128, cols=512),
                         num_arrays=4)


def test_estimate_many_matches_sequential_loop():
    from repro.workloads import AnalyticBackend, get_workload

    b = AnalyticBackend()
    ws = [get_workload(f"mk/{k}") for k in sorted(MICROKERNELS)]
    for sys_ in (PAPER_SYSTEM, SMALL_SYS):
        batched = b.estimate_many(ws, sys_)
        for w, rep in zip(ws, batched):
            ref = b.estimate(w, sys_)
            assert rep.summary == ref.summary, w.name
            assert rep.ops[0].breakdown == ref.ops[0].breakdown, w.name


def test_estimate_many_falls_back_for_multi_op_workloads():
    from repro.workloads import AnalyticBackend, PlannerBackend, \
        get_workload

    ws = [get_workload("aes"), get_workload("mk/multu")]
    for backend in (AnalyticBackend(), PlannerBackend()):
        batched = backend.estimate_many(ws)
        assert [r.summary for r in batched] == \
            [backend.estimate(w).summary for w in ws]


@pytest.mark.parametrize("backend", ["analytic", "planner", "executor"])
def test_nondefault_geometry_changes_cycles(backend):
    """Regression (ISSUE 4 satellite): the Backend protocol's `sys` is
    honoured -- a 4-array system must re-batch BP compute."""
    from repro.workloads import characterize

    default = characterize("mk/multu", backends=(backend,))[backend]
    small = characterize("mk/multu", backends=(backend,),
                         sys=SMALL_SYS)[backend]
    assert small.summary["bp_cycles"] > default.summary["bp_cycles"]


def test_all_backends_expose_estimate_many():
    from repro.workloads import Backend, BACKENDS

    for name, cls in BACKENDS.items():
        b = cls()
        assert isinstance(b, Backend), name
        assert callable(b.estimate_many), name


# ------------------------------------------------ CLI artifact match ------

def test_cli_sweep_artifact_matches_golden(tmp_path):
    """`python -m repro sweep` emits guidelines.json whose crossover table
    matches the golden [guidelines] snapshot (acceptance)."""
    env_dir = str(tmp_path / "artifacts")
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "sweep", "--no-cache"],
        capture_output=True, text=True,
        env={"PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin",
             "REPRO_BENCH_ARTIFACT_DIR": env_dir},
        cwd=str(tmp_path))
    assert proc.returncode == 0, proc.stderr
    g = json.loads((tmp_path / "artifacts" / "guidelines.json").read_text())
    assert guidelines_lines(g) == _golden_guidelines_lines()


# ------------------------------------------------ int32 range guard -------

def test_vectorized_rejects_out_of_range_points():
    """The int32 path must refuse (not silently wrap) operating points
    whose movement terms exceed int32 (code-review regression)."""
    with pytest.raises(ValueError, match="int32"):
        V.kernel_cost_vec("multu", Layout.BP, n=2**26, width=32,
                          cols=512, arrays=512)
    with pytest.raises(ValueError, match="int32"):
        run_sweep(SweepSpec.default(workloads=("mk/multu",),
                                    n_override=2**26), use_cache=False)


def test_estimate_many_falls_back_on_out_of_range_points():
    """Huge-n single-kernel workloads take the scalar loop (exact python
    ints) instead of erroring out of the batched fast path."""
    from repro.workloads import AnalyticBackend
    from repro.workloads.registry import microkernel_workload

    w = microkernel_workload("multu", n=2**26, width=32)
    b = AnalyticBackend()
    (rep,) = b.estimate_many([w])
    assert rep.summary == b.estimate(w).summary


def test_guidelines_report_actual_crossover_geometry():
    """When the sweep omits the paper geometry, the report must say which
    geometry the crossover table was computed at (code-review fix)."""
    fam = iso_area_family()
    small = guidelines(run_sweep(SweepSpec.default(
        workloads=("mk/multu",), geometries=fam[:3]), use_cache=False),
        include_hybrid=False)
    assert not small["crossover_at_paper_geometry"]
    assert small["crossover_geometry"] == fam[0].to_dict()
    full = guidelines(run_sweep(SweepSpec.default(
        workloads=("mk/multu",)), use_cache=False), include_hybrid=False)
    assert full["crossover_at_paper_geometry"]
    assert full["crossover_geometry"] == PAPER_GEOMETRY.to_dict()
