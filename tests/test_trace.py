"""Differential + property tests for the jaxpr -> Workload tracer.

Three layers:

* the differential suite -- every ArchConfig's ``traced/<id>`` workload
  reconciles against the hand-written ``arch/<id>`` formulas through
  ``repro.workloads.trace_diff`` (exact ops to the cycle, divergent ops
  with documented reasons, every extra traced op explained), plus the
  traced-VGG-vs-``vgg16`` cross-check;
* property tests (``_hypothesis_compat``: hypothesis when installed,
  deterministic fallback otherwise) -- random MLP/conv programs trace to
  ops whose dims equal the jaxpr shapes, with a well-formed dep DAG and
  deterministic ``to_dict()``;
* IR regressions -- ``Workload.deps`` canonicalization (sorted tuples)
  survives the dict round-trip.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, st

from repro.workloads.ir import Op, Workload
from repro.workloads.registry import ARCH_IDS, arch_workload
from repro.workloads.trace import param_path_widths, trace_workload
from repro.workloads.trace_diff import (
    GATED_BACKENDS,
    expected_matmuls,
    expected_vgg,
    gate_failures,
    reconcile,
    reconcile_vgg,
)

#: the tests' operating point -- 8x smaller than the arch/<id> default
#: (tokens=4096) so the whole differential suite traces in ~1s; every
#: catalogue formula is parameterized by `tokens`, so the reconciliation
#: logic exercised is identical.
TOKENS = 512

_DESIGN = os.path.join(os.path.dirname(__file__), "..", "DESIGN.md")


def _traced(arch_id, tokens=TOKENS):
    from repro.configs import get_config
    from repro.models.registry import traced_workload

    return traced_workload(get_config(arch_id), tokens=tokens)


# ---------------------------------------------------------------------------
# Differential suite: traced/<id> vs arch/<id>
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_traced_reconciles_with_formulas(arch_id):
    """Every formula op matches a traced op at its predicted dims; every
    remaining traced op is explained; exact pairs agree to the cycle."""
    rows = reconcile(arch_id, tokens=TOKENS, backends=("analytic",))
    assert gate_failures(rows) == []
    # the match found every formula op and at least one exact pair
    statuses = {r.status for r in rows}
    assert "missing" not in statuses
    assert "exact" in statuses


@pytest.mark.parametrize("arch_id", ["tinyllama_1_1b", "dbrx_132b",
                                     "mamba2_780m"])
def test_exact_ops_agree_on_every_static_backend(arch_id):
    """Exact pairs (same m/k/n/width) cost identically on analytic,
    planner, AND executor -- the tracer and the formulas feed the same
    cost model the same inputs."""
    rows = reconcile(arch_id, tokens=TOKENS, backends=GATED_BACKENDS)
    assert gate_failures(rows) == []
    exact = [r for r in rows if r.status == "exact"]
    assert {r.backend for r in exact} == set(GATED_BACKENDS)
    for r in exact:
        assert r.bp_delta == 0 and r.bs_delta == 0, r


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_catalogue_tracks_arch_workload(arch_id):
    """expected_matmuls stays in formula-op order with formula names, and
    `exact` entries predict the formula's own dims."""
    from repro.configs import get_config

    cfg = get_config(arch_id)
    formula = arch_workload(cfg, tokens=TOKENS)
    expected = expected_matmuls(cfg, tokens=TOKENS)
    assert [e.formula for e in expected] == [op.name for op in formula.ops]
    for exp, op in zip(expected, formula.ops):
        if exp.status == "exact":
            assert exp.dims == (op.m, op.k, op.n, op.width)
        else:
            assert exp.dims != (op.m, op.k, op.n, op.width)
            assert exp.note  # every divergence carries its reason


def test_divergences_documented_in_design_md():
    """The divergent formula ops are catalogued in DESIGN.md Sec. 12."""
    with open(_DESIGN) as fh:
        text = fh.read()
    assert "## 12." in text
    for op_name in ("attn_scores", "expert_ffn", "ssd_scan"):
        assert op_name in text, f"{op_name} divergence not documented"


def test_traced_vgg_reconciles_with_table6():
    rows = reconcile_vgg(backends=("analytic",))
    assert gate_failures(rows) == []
    convs = [r for r in rows if r.kind == "conv"]
    assert len(convs) == 13  # VGG-16
    for r in convs:
        # output elements agree exactly; the documented divergence is the
        # contraction depth (formula k=9 spatial taps, trace k=9*C_in)
        assert r.n_formula == r.n_traced
        assert r.k_formula == 9 and r.k_traced % 9 == 0
    fcs = [r for r in rows if r.kind == "matmul" and r.op_formula]
    assert [r.op_formula for r in fcs] == ["fc0", "fc1", "fc2"]
    for r in fcs:
        assert (r.m_formula, r.m_traced) == (1, 128)  # per-image vs batch
        assert (r.k_formula, r.n_formula) == (r.k_traced, r.n_traced)


def test_expected_vgg_matches_formula_names():
    from repro.workloads.registry import get_workload

    formula = get_workload("vgg16")
    assert ([e.formula for e in expected_vgg("vgg16")]
            == [op.name for op in formula.ops])


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_traced_workloads_characterize_and_plan(arch_id):
    """traced/<id> flows through the standard entry points: analytic +
    planner characterization and plan compilation over the real DAG."""
    from repro.core.params import PAPER_SYSTEM
    from repro.plan import compile_plan
    from repro.workloads import characterize

    w = _traced(arch_id)
    assert w.name == f"traced/{arch_id}"
    assert w.source == "traced"
    reports = characterize(w, backends=("analytic", "planner"))
    for rep in reports.values():
        assert rep.summary["bp_cycles"] > 0
        assert rep.summary["bs_cycles"] > 0
    plan = compile_plan(w, PAPER_SYSTEM)
    assert plan.total_cycles > 0
    # one schedule entry per phase; ops may expand to several phases
    assert len(plan.schedule) == len(plan.steps) >= len(w.ops)


def test_precision_resolution_weight_bits():
    """Weight matmuls resolve to weight_bits; activation-only matmuls
    (flash scores et al) stay at the 16-bit default."""
    w = _traced("tinyllama_1_1b")
    mm = {op.name: op for op in w.ops if op.kind == "matmul"}
    assert mm["wqkv"].width == 4 and mm["wqkv"].mixed_precision
    assert mm["k"].width == 16  # scores: Q x K-cache, no weights
    assert mm["wo"].width == 4


# ---------------------------------------------------------------------------
# Property tests: random programs -> traced dims equal jaxpr shapes
# ---------------------------------------------------------------------------

@settings(max_examples=15)
@given(batch=st.sampled_from([1, 3, 8]),
       dims=st.lists(st.sampled_from([4, 8, 16, 32]), min_size=2,
                     max_size=5),
       weight_bits=st.sampled_from([2, 4, 8]))
def test_random_mlp_traces_to_jaxpr_shapes(batch, dims, weight_bits):
    params = {f"w{i}": jax.ShapeDtypeStruct((dims[i], dims[i + 1]),
                                            jnp.float32)
              for i in range(len(dims) - 1)}
    x = jax.ShapeDtypeStruct((batch, dims[0]), jnp.float32)

    def fn(p, x):
        for i in range(len(dims) - 1):
            x = jnp.maximum(x @ p[f"w{i}"], 0.0)
        return x

    pmap = param_path_widths(params, weight_bits=weight_bits,
                             dtype=jnp.float32)
    w = trace_workload(fn, params, x, precision_map=pmap)
    mms = [op for op in w.ops if op.kind == "matmul"]
    assert [(op.m, op.k, op.n) for op in mms] == \
        [(batch, dims[i], dims[i + 1]) for i in range(len(dims) - 1)]
    assert all(op.width == weight_bits for op in mms)
    # layer 0 sees two argument origins (x and w0) -> generic name;
    # deeper layers see only their weight leaf and inherit its path
    assert [op.name for op in mms] == \
        ["dot"] + [f"w{i}" for i in range(1, len(dims) - 1)]


@settings(max_examples=10)
@given(c_in=st.sampled_from([1, 3, 8]), c_out=st.sampled_from([4, 16]),
       spatial=st.sampled_from([8, 16]), kernel=st.sampled_from([1, 3]))
def test_random_conv_traces_to_jaxpr_shapes(c_in, c_out, spatial, kernel):
    from jax import lax

    kern = jax.ShapeDtypeStruct((kernel, kernel, c_in, c_out),
                                jnp.float32)
    img = jax.ShapeDtypeStruct((2, spatial, spatial, c_in), jnp.float32)

    def fn(k, x):
        return lax.conv_general_dilated(
            x, k, window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))

    w = trace_workload(fn, kern, img)
    (conv,) = [op for op in w.ops if op.kind == "conv"]
    assert conv.n == 2 * spatial * spatial * c_out
    assert conv.k == kernel * kernel * c_in
    assert conv.in_elems == 2 * spatial * spatial * c_in


@pytest.mark.parametrize("arch_id", ["tinyllama_1_1b", "dbrx_132b",
                                     "mamba2_780m", "recurrentgemma_2b"])
def test_traced_deps_form_a_dag(arch_id):
    """deps are forward (producer < consumer), in-range, deduplicated,
    sorted, and exactly what edges() reports."""
    w = _traced(arch_id)
    assert w.deps, "tracer should emit def-use edges, not a chain"
    n = len(w.ops)
    for a, b in w.deps:
        assert 0 <= a < b < n  # list order is topological -> acyclic
    assert list(w.deps) == sorted(set(w.deps))
    assert w.edges() == w.deps
    # the heavy ops are wired into the DAG (not floating islands)
    connected = ({a for a, _b in w.deps} | {b for _a, b in w.deps})
    for idx, op in enumerate(w.ops):
        if op.kind in ("matmul", "conv"):
            assert idx in connected, f"unwired {op.name}"


@settings(max_examples=5)
@given(batch=st.sampled_from([2, 4]),
       hidden=st.sampled_from([8, 16]))
def test_trace_is_deterministic(batch, hidden):
    def make():
        params = {"w": jax.ShapeDtypeStruct((hidden, hidden),
                                            jnp.float32)}
        x = jax.ShapeDtypeStruct((batch, hidden), jnp.float32)

        def fn(p, x):
            return jax.nn.softmax(x @ p["w"], axis=-1)

        return trace_workload(fn, params, x, name="det")

    assert make().to_dict() == make().to_dict()


def test_traced_arch_is_deterministic():
    assert _traced("tinyllama_1_1b").to_dict() == \
        _traced("tinyllama_1_1b").to_dict()


# ---------------------------------------------------------------------------
# IR regression: deps canonicalization + round-trip
# ---------------------------------------------------------------------------

def test_workload_deps_canonicalized_sorted():
    ops = tuple(Op(name=f"o{i}", kind="compute", bp_cycles=1, bs_cycles=1)
                for i in range(4))
    w = Workload(name="t", ops=ops, source="table5",
                 deps=((2, 3), (0, 1), (1, 3)))
    # canonical order regardless of construction order
    assert w.deps == ((0, 1), (1, 3), (2, 3))


def test_workload_deps_round_trip():
    ops = tuple(Op(name=f"o{i}", kind="compute", bp_cycles=1, bs_cycles=1)
                for i in range(4))
    w = Workload(name="t", ops=ops, source="table5",
                 deps=((2, 3), (0, 2), (0, 1)))
    again = Workload.from_dict(w.to_dict())
    assert again.deps == w.deps == ((0, 1), (0, 2), (2, 3))
    assert again.to_dict() == w.to_dict()


def test_traced_workload_round_trip():
    w = _traced("tinyllama_1_1b")
    again = Workload.from_dict(w.to_dict())
    assert again.to_dict() == w.to_dict()
    assert again.deps == w.deps
