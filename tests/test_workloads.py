"""Workload-IR tests (ISSUE 3): golden equivalence of the IR route
against the legacy surfaces, the Backend protocol, the deprecation
shims, and the `python -m repro` CLI."""
from __future__ import annotations

import json
import subprocess
import sys
import warnings
from pathlib import Path

import pytest

from repro.core import apps
from repro.core.cost_model import Layout
from repro.core.microkernels import MICROKERNELS, kernel_cost
from repro.core.planner import plan
from repro.workloads import (
    AnalyticBackend,
    Backend,
    BACKENDS,
    ExecutorBackend,
    Op,
    PlannerBackend,
    Report,
    characterize,
    get_workload,
    microkernel_workload,
    op_phases,
    workload_names,
)

SRC = str(Path(__file__).parent.parent / "src")


# ------------------------------------------------- golden equivalence ------

@pytest.mark.parametrize("name", sorted(MICROKERNELS))
@pytest.mark.parametrize("width", [8, 16, 32])
def test_analytic_backend_matches_kernel_cost(name, width):
    """AnalyticBackend on a Table-5 IR workload reproduces the legacy
    `kernel_cost` load/compute/readout bit-for-bit, at every width and
    in both layouts."""
    n = 8192 if name == "relu" else 1024
    rep = AnalyticBackend().estimate(microkernel_workload(name, n=n,
                                                          width=width))
    assert isinstance(rep, Report)
    (op_rep,) = rep.ops
    for layout in (Layout.BP, Layout.BS):
        c = kernel_cost(name, layout, n=n, width=width)
        assert op_rep.breakdown[layout.value] == \
            (c.load, c.compute, c.readout), (name, layout, width)
    assert rep.summary["bp_cycles"] == kernel_cost(name, Layout.BP,
                                                   n=n, width=width).total


@pytest.mark.parametrize("app", apps.workload_names("table6"))
def test_planner_backend_matches_legacy_evaluate(app):
    """Planner/Analytic backends on the IR reproduce the legacy
    `evaluate_app` numbers exactly (the golden snapshot pins the values
    themselves; see tests/golden/paper_tables.txt [table6])."""
    legacy = apps.evaluate_app(app)
    reports = characterize(app, backends=("analytic", "planner"))
    a, p = reports["analytic"].summary, reports["planner"].summary
    assert a["bp_cycles"] == legacy["bp_cycles"] == p["bp_cycles"]
    assert a["bs_cycles"] == legacy["bs_cycles"] == p["bs_cycles"]
    assert p["hybrid_cycles"] == legacy["hybrid_cycles"]
    assert p["n_transposes"] == legacy["n_transposes"]
    assert p["is_hybrid"] == legacy["is_hybrid"]


def test_pinned_headline_numbers():
    """Hard pins (captured from the pre-IR builders) so equivalence does
    not become tautological after the legacy path delegates to the IR."""
    pins = {  # app: (bp, bs, hybrid)
        "aes": (18624, 24702, 6961),
        "vgg16": (3704282, 4794817, 3686062),
        "hdc": (134417, 108688, 101793),
        "keccak": (22896, 42072, 11582),
    }
    for app, (bp, bs, hybrid) in pins.items():
        s = characterize(app, backends=("planner",))["planner"].summary
        assert (s["bp_cycles"], s["bs_cycles"], s["hybrid_cycles"]) == \
            (bp, bs, hybrid), app
    aes = characterize("aes", backends=("planner",))["planner"].summary
    assert aes["hybrid_speedup"] >= 2.66  # DP >= published hand schedule


def test_vgg_alias_resolves():
    assert get_workload("vgg").name == "vgg16"


# ------------------------------------------------- backend protocol --------

def test_all_backends_satisfy_protocol():
    vgg = get_workload("vgg16")
    for name, cls in BACKENDS.items():
        b = cls()
        assert isinstance(b, Backend), name
        assert b.name == name
        assert isinstance(b.supports(vgg), bool)


def test_executor_backend_matches_executed_programs():
    """ExecutorBackend on Table-5 IR workloads reports exactly the
    micro-op program cycle counts (single batch at N=1024)."""
    from repro.pim import programs as pr

    for name in ("vector_add", "multu", "bitcount", "gt_0"):
        rep = ExecutorBackend().estimate(microkernel_workload(name))
        (row,) = rep.ops
        assert row.supported
        assert row.bp_cycles == pr.build(name, Layout.BP, width=16).cycles
        assert row.bs_cycles == pr.build(name, Layout.BS, width=16).cycles
    # documented calibration deltas surface in the report notes
    rep = ExecutorBackend().estimate(microkernel_workload("gt_0"))
    assert any("delta" in n for n in rep.notes)


def test_executor_backend_unsupported_kernels_are_flagged():
    rep = ExecutorBackend().estimate(microkernel_workload("divu"))
    (row,) = rep.ops
    assert not row.supported and "no micro-op program" in row.note
    assert rep.summary["coverage"] == 0.0


def test_executor_backend_lowers_vgg_macs():
    """The acceptance workload: executor coverage on VGG is total (every
    conv/matmul op lowers to multu + vector_add programs)."""
    rep = ExecutorBackend().estimate(get_workload("vgg"))
    assert rep.summary["coverage"] == 1.0
    assert rep.summary["bp_cycles"] > 0 and rep.summary["bs_cycles"] > 0


def test_planner_backend_schedule_maps_back_to_ops():
    rep = PlannerBackend().estimate(get_workload("aes"))
    assert all(r.note.startswith("sched=") for r in rep.ops)
    assert rep.summary["is_hybrid"]


def test_characterize_entry_point_accepts_instances_and_names():
    import repro

    w = get_workload("mk/vector_add")
    out = repro.characterize(w, backends=("analytic", AnalyticBackend()))
    assert set(out) == {"analytic"}
    out = characterize("mk/vector_add", backends=("analytic", "executor"))
    assert set(out) == {"analytic", "executor"}


def test_unknown_workload_and_backend_raise():
    with pytest.raises(KeyError, match="unknown workload"):
        get_workload("nope")
    with pytest.raises(KeyError, match="unknown backend"):
        characterize("aes", backends=("nope",))


def test_pallas_backend_measures_matmul_tiles():
    from repro.workloads import PallasBackend

    rep = PallasBackend(tile=32).estimate(get_workload("gemv"))
    (row,) = rep.ops
    assert row.supported and row.bp_us > 0 and row.bs_us > 0
    assert rep.summary["measured_ops"] == 1
    # un-clamped: true and padded dims are both on the report (gemv is
    # 1 x 4096 x 512; padding only lifts m to the sublane minimum)
    assert row.dims == (1, 4096, 512)
    assert row.padded_dims[1:] == (4096, 512) and row.padded_dims[0] >= 1


def test_pallas_backend_conv_dims_match_executor_lowering():
    """PR-9 regression: conv lowers to the im2col GEMV ExecutorBackend
    prices -- (op.n, op.k, 1) -- not the (op.n, op.k, op.n) square the
    old `m, k, n = op.n, op.k, op.n` bug measured."""
    from repro.workloads import PallasBackend

    be = PallasBackend()
    vgg_convs = [op for op in get_workload("vgg").ops if op.kind == "conv"]
    assert vgg_convs
    for op in vgg_convs:
        assert be._dims(op) == (op.n, op.k, 1)
    # and the full estimate records those dims on every conv row, even
    # ones too large to measure (over budget -> honest modelled row)
    rep = be.estimate(get_workload("vgg13"))
    conv_rows = [r for r in rep.ops if r.kind == "conv"]
    by_name = {op.name: op for op in get_workload("vgg13").ops}
    for r in conv_rows:
        op = by_name[r.op]
        assert r.dims == (op.n, op.k, 1)
        if not r.supported:
            assert "over budget" in r.note


def test_pallas_backend_runs_true_width_and_rejects_over_32():
    """PR-9 regression: no `min(width, 8)` clamp. A 16-bit op really
    runs 16 plane passes (the note says so); width > 32 is an honest
    unsupported row, never a silently narrowed launch."""
    from repro.workloads import PallasBackend, Workload as W

    w16 = W(name="w16", ops=(
        Op(name="mm", kind="matmul", m=4, k=64, n=64, width=16),))
    rep = PallasBackend(tile=32, reps=1).estimate(w16)
    (row,) = rep.ops
    assert row.supported and "@16b" in row.note

    w48 = W(name="w48", ops=(
        Op(name="mm", kind="matmul", m=4, k=64, n=64, width=48),))
    rep = PallasBackend(tile=32, reps=1).estimate(w48)
    (row,) = rep.ops
    assert not row.supported and "unsupported: width 48" in row.note
    assert row.dims == (4, 64, 64)


def test_pallas_backend_over_budget_row_reports_padded_work():
    from repro.workloads import PallasBackend, Workload as W

    w = W(name="big", ops=(
        Op(name="mm", kind="matmul", m=512, k=512, n=512, width=8),))
    rep = PallasBackend(max_macs=2 ** 20).estimate(w)
    (row,) = rep.ops
    assert not row.supported and "over budget" in row.note
    assert row.dims == (512, 512, 512)
    assert row.padded_dims is not None
    assert rep.summary["measured_ops"] == 0


# ------------------------------------------------- arch (advisor) route ----

def test_arch_workload_and_advisor_shim():
    """`advisor.arch_op_trace` emits a single DeprecationWarning and
    returns rows identical to the IR route; `advise_op` classifies IR
    ops and legacy OpTraces identically."""
    from repro.configs import get_config
    from repro.core.advisor import OpTrace, advise_op, arch_op_trace
    from repro.workloads import arch_workload

    cfg = get_config("tinyllama_1_1b")
    w = arch_workload(cfg)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        legacy = arch_op_trace(cfg)
    assert len([x for x in rec
                if issubclass(x.category, DeprecationWarning)]) == 1
    assert [(t.name, t.m, t.k, t.n, t.weight_bits, t.control_intensity)
            for t in legacy] == \
        [(o.name, o.m, o.k, o.n, o.width, o.control_intensity)
         for o in w.ops]
    for t, o in zip(legacy, w.ops):
        assert advise_op(t) == advise_op(o)
    assert isinstance(legacy[0], OpTrace)


def test_arch_workloads_registered():
    names = workload_names("arch")
    assert "arch/tinyllama_1_1b" in names and len(names) == 10
    w = get_workload("arch/tinyllama_1_1b")
    assert all(op.kind == "matmul" for op in w.ops)


# ------------------------------------------------- deprecation shims -------

@pytest.mark.parametrize("app", sorted(apps.APP_TRACES))
def test_apps_shims_warn_once_and_match_ir(app):
    """Every old `core.apps` constructor emits exactly one
    DeprecationWarning and returns the IR lowering verbatim."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        old = apps.APP_TRACES[app]()
    assert len([x for x in rec
                if issubclass(x.category, DeprecationWarning)]) == 1
    assert old == get_workload(app).to_phases()


def test_vgg_trace_shim_honours_which():
    with pytest.warns(DeprecationWarning):
        assert apps.vgg_trace("vgg19") == get_workload("vgg19").to_phases()


def test_evaluate_all_does_not_warn():
    """The supported APIs route through the IR without deprecation."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = apps.evaluate_all()
    assert len(res) == 22


# ------------------------------------------------- IR lowering details -----

def test_workload_cost_equals_sum_of_phases():
    w = get_workload("fir")
    for layout in (Layout.BP, Layout.BS):
        total = w.cost(layout).total
        phases = w.to_phases()
        key = "bp_cycles" if layout is Layout.BP else "bs_cycles"
        assert total == sum(getattr(p, key) for p in phases)


def test_op_validation():
    with pytest.raises(ValueError, match="unknown op kind"):
        Op(name="x", kind="bogus")
    with pytest.raises(ValueError, match="microkernel name"):
        Op(name="x", kind="kernel")
    with pytest.raises(ValueError, match="positive dims"):
        Op(name="x", kind="matmul", m=1, n=8)  # forgot k
    with pytest.raises(ValueError, match="positive dims"):
        Op(name="x", kind="conv", n=8)  # forgot taps
    with pytest.raises(ValueError, match="no ops"):
        from repro.workloads import Workload
        Workload(name="empty", ops=())


def test_matmul_streamed_vs_chunked_phase_shapes():
    chunked = Op(name="mm", kind="matmul", m=1, k=512, n=512, chunk=64)
    streamed = Op(name="mm", kind="matmul", m=64, k=64, n=64, chunk=0)
    assert len(op_phases(chunked)) == 3
    assert len(op_phases(streamed)) == 1


def test_planner_dp_still_beats_or_ties_statics():
    """Sanity over the whole registry: the DP never loses to a static."""
    for app in workload_names("table6"):
        p = plan(get_workload(app).to_phases())
        assert p.total_cycles <= min(p.static_bp, p.static_bs)


# ------------------------------------------------- CLI --------------------

def test_cli_list(capsys):
    from repro.__main__ import main

    assert main(["list", "--source", "table6"]) == 0
    out = capsys.readouterr().out
    assert "vgg16" in out and "aes" in out and "backends" in out


def test_cli_characterize_acceptance(capsys):
    """The ISSUE-3 acceptance command, in-process."""
    from repro.__main__ import main

    rc = main(["characterize", "vgg",
               "--backends", "analytic,planner,executor"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "[analytic]" in out and "[planner]" in out \
        and "[executor]" in out
    assert "hybrid_cycles" in out and "bs_cycles" in out


def test_cli_characterize_quick_writes_artifact(tmp_path, monkeypatch,
                                                capsys):
    from repro.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_ARTIFACT_DIR", str(tmp_path))
    assert main(["characterize", "--quick", "--backends", "analytic"]) == 0
    env = json.loads((tmp_path / "characterize.json").read_text())
    assert env["artifact"] == "characterize"
    assert env["schema_version"] == 1
    data = env["payload"]
    assert len(data) == len(workload_names("table5")) \
        + len(workload_names("table6"))
    assert data["aes"]["analytic"]["bp_cycles"] == 18624
    capsys.readouterr()


def test_cli_tables_matches_golden(capsys):
    from repro.__main__ import main

    assert main(["tables"]) == 0
    out = capsys.readouterr().out
    golden = (Path(__file__).parent / "golden" / "paper_tables.txt")
    assert out == golden.read_text()


def test_cli_module_entrypoint_subprocess():
    """`python -m repro` works as shipped (the CI smoke invocation)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro", "characterize", "mk/vector_add",
         "--backends", "analytic"],
        capture_output=True, text=True, timeout=120,
        env={"PYTHONPATH": SRC, "PATH": "/usr/bin:/bin"},
    )
    assert r.returncode == 0, r.stderr
    assert "mk/vector_add" in r.stdout


# ------------------------------------------------- choose_layout fix -------

def test_choose_layout_flips_for_deep_contractions():
    """Regression for the ISSUE-3 satellite: `working_set_bits` used to
    be hardcoded to `weight_bits * 4`, ignoring the dims -- every 4-bit
    matmul classified BS regardless of contraction depth.  The footprint
    is now the real weight-stationary operand set (k*width + double-width
    accumulator), so deep-k matmuls overflow the 128-row BS column and
    flip to BP (Challenge 2)."""
    from repro.kernels.ops import choose_layout
    from repro.workloads import matmul_working_set_bits

    shallow = choose_layout(weight_bits=4, m=128, n=128, k=16)
    deep = choose_layout(weight_bits=4, m=128, n=128, k=2048)
    assert shallow.value == "BS"
    assert deep.value == "BP"
    assert shallow != deep  # the flip the old hardcoding could not produce
    # footprint actually tracks k
    assert matmul_working_set_bits(2048, 4) > \
        matmul_working_set_bits(16, 4) > 4 * 4
    # the existing dispatch operating points keep their recommendations
    assert choose_layout(weight_bits=2, m=128, n=128, k=64).value == "BS"
    assert choose_layout(weight_bits=8, m=128, n=128, k=64).value == "BP"
