"""Property-based sweep invariants (ISSUE 4 satellite).

Uses the `_hypothesis_compat` shim: real hypothesis when installed,
otherwise the deterministic seeded fallback. Three invariant families:

* vectorized-vs-scalar cost equality on *random* (kernel, layout, width,
  n, geometry) points -- the exhaustive acceptance grid lives in
  tests/test_sweep.py; this fuzzes far off it;
* monotonicity: BS per-batch compute non-decreasing in width for every
  kernel; the BP multiply *total* turns superlinear in width once
  capacity batching engages (Challenge 1 -- wider words both cost more
  per op AND halve the word lanes);
* the iso-area geometry family preserves total bit capacity and keeps
  cols/bus width fixed, for arbitrary base systems.
"""
from __future__ import annotations

from _hypothesis_compat import given, settings, st

from repro.core.cost_model import KERNEL_RECIPES, Layout, SCALAR_OPS
from repro.core.microkernels import MICROKERNELS, kernel_cost
from repro.core.params import ArrayParams, SystemParams
from repro.sweep import iso_area_family
from repro.sweep.grid import Geometry
from repro.sweep.vectorized import kernel_cost_vec

KERNELS = sorted(MICROKERNELS)
POW2_WIDTHS = (4, 8, 16, 32, 64)

kernel_st = st.sampled_from(KERNELS)
layout_st = st.sampled_from((Layout.BP, Layout.BS))
width_st = st.sampled_from(POW2_WIDTHS)
n_st = st.integers(1, 1 << 16)
rows_st = st.sampled_from((8, 64, 128, 512, 2048))
cols_st = st.sampled_from((128, 256, 512, 1024))
arrays_st = st.integers(1, 1024)
bw_st = st.sampled_from((128, 256, 512, 1024))


@settings(max_examples=120, deadline=None)
@given(kernel_st, layout_st, width_st, n_st, rows_st, cols_st, arrays_st,
       bw_st)
def test_vectorized_equals_scalar_random_points(kernel, layout, width, n,
                                                rows, cols, arrays, bw):
    """Bit-for-bit equality at arbitrary integer operating points."""
    sys = SystemParams(array=ArrayParams(rows=rows, cols=cols),
                       num_arrays=arrays, row_bandwidth_bits=bw)
    c = kernel_cost(kernel, layout, n=n, width=width, sys=sys)
    load, comp, ro = kernel_cost_vec(
        kernel, layout, n=n, width=width, cols=cols, arrays=arrays,
        row_bandwidth_bits=bw)
    assert (int(load), int(comp), int(ro)) == \
        (c.load, c.compute, c.readout), (kernel, layout, width, n)


@settings(max_examples=60, deadline=None)
@given(kernel_st, st.sampled_from(POW2_WIDTHS[:-1]), n_st)
def test_bs_compute_nondecreasing_in_width(kernel, width, n):
    """Serial kernels never get cheaper per batch as operands widen."""
    f = KERNEL_RECIPES[kernel].compute[Layout.BS]
    assert f(SCALAR_OPS, 2 * width, n) >= f(SCALAR_OPS, width, n)


@settings(max_examples=60, deadline=None)
@given(st.sampled_from((4, 8, 16)), st.integers(1, 8), arrays_st)
def test_bp_mult_total_superlinear_once_batched(width, n_factor, arrays):
    """Doubling the width more than doubles the BP multiply total when
    the workload exceeds one capacity batch: movement doubles exactly,
    but compute pays (2w+2) cycles over half the word lanes."""
    sys = SystemParams(array=ArrayParams(rows=128, cols=512),
                       num_arrays=arrays)
    # n large enough that both widths run > 1 full batch of word lanes
    n = n_factor * sys.total_columns
    t1 = kernel_cost("multu", Layout.BP, n=n, width=width, sys=sys).total
    t2 = kernel_cost("multu", Layout.BP, n=n, width=2 * width,
                     sys=sys).total
    assert t2 > 2 * t1, (width, n, arrays)


@settings(max_examples=60, deadline=None)
@given(st.sampled_from((64, 128, 256)), cols_st,
       st.sampled_from((64, 128, 512, 1024)), bw_st)
def test_iso_area_family_preserves_capacity(rows, cols, arrays, bw):
    base = SystemParams(array=ArrayParams(rows=rows, cols=cols),
                        num_arrays=arrays, row_bandwidth_bits=bw)
    fam = iso_area_family(base)
    assert fam, (rows, arrays)
    cap = rows * cols * arrays
    for g in fam:
        assert g.capacity_bits == cap
        assert g.cols == cols and g.row_bandwidth_bits == bw
        assert g.rows * g.arrays == rows * arrays
    # the family genuinely trades rows for arrays (not one point)
    assert len({g.rows for g in fam}) == len(fam)


def test_paper_family_contains_paper_point():
    fam = iso_area_family()
    assert Geometry(128, 512, 512) in fam
