"""Chained schedule execution (DESIGN.md Sec. 15): one compiled program
per schedule, bit-exact against the per-step differential reference and
the plain-integer reference on hybrid BP<->BS plans of real Table-6
apps; donation-safe re-runs; content-addressed executable caching."""
import dataclasses

import numpy as np
import pytest

from repro.core.cost_model import Layout
from repro.plan import (
    ExecutableCache,
    compile_plan,
    compile_schedule,
    lower_plan_pallas,
    reference_results,
    run_schedule,
    schedule_key,
    synth_inputs,
)
from repro.workloads import get_workload
from repro.workloads.ir import Op, Workload

#: Table-6 multi-step apps: 3 measured classifier FCs each (the convs
#: exceed any honest interpret-mode budget and stay modelled)
APPS = ("vgg13", "vgg16", "vgg19")


def _hybrid_schedule(app):
    """Force the middle classifier FC to BS: a BP->BS boundary into fc1
    (bp2bs, fused) and a BS->BP boundary into fc2 (bs2bp).  The cost
    model never picks BS at these widths, so the hybrid is constructed
    by hand -- lowering consumes any LayoutPlan."""
    w = get_workload(app)
    p = compile_plan(w)
    p = dataclasses.replace(p, steps=tuple(
        dataclasses.replace(s, layout=Layout.BS) if s.op == "fc1" else s
        for s in p.steps))
    sched = lower_plan_pallas(p, w)
    by_op = {s.op: s for s in sched.steps}
    assert by_op["fc1"].repack == "bp2bs"
    assert by_op["fc1"].kernel == "fused_bitserial_matmul"
    assert by_op["fc2"].repack == "bs2bp"
    return w, sched


@pytest.mark.parametrize("app", APPS)
def test_chained_matches_per_step_and_reference_on_hybrid(app):
    """The ISSUE-10 acceptance: the ONE-program executable of a hybrid
    plan returns bit-identical results to per-step run_schedule AND the
    plain-integer reference, repacks folded in-program."""
    _, sched = _hybrid_schedule(app)
    inputs = synth_inputs(sched, seed=5)
    exe = compile_schedule(sched, inputs, seed=5)
    got = exe.run()
    per = run_schedule(sched, inputs)
    ref = reference_results(sched, inputs)
    assert set(got) == {"fc0", "fc1", "fc2"}
    for op in got:
        np.testing.assert_array_equal(got[op], per[op], err_msg=op)
        np.testing.assert_array_equal(got[op], ref[op], err_msg=op)
    # outputs thread through the deps DAG, not synthetic operands:
    # perturbing fc0's weights must change fc2's threaded result
    x0, w0 = inputs["fc0"]
    inputs2 = dict(inputs)
    inputs2["fc0"] = (x0, (w0 + 1).astype(w0.dtype))
    got2 = compile_schedule(sched, inputs2, seed=5).run()
    assert not np.array_equal(got2["fc2"], got["fc2"])


def test_buffer_donation_rerun_is_identical():
    """Donated intermediates must not leak across calls: running the
    same executable twice returns bit-identical outputs (run() re-places
    the entry buffers each call)."""
    _, sched = _hybrid_schedule("vgg16")
    exe = compile_schedule(sched, synth_inputs(sched, seed=2), seed=2)
    a, b = exe.run(), exe.run()
    for op in a:
        np.testing.assert_array_equal(a[op], b[op], err_msg=op)
    assert exe.runs >= 2


def test_executable_cache_hits_on_recompile():
    cache = ExecutableCache()
    _, sched = _hybrid_schedule("vgg13")
    exe1, key1, hit1 = cache.get_or_compile(sched, seed=0)
    exe2, key2, hit2 = cache.get_or_compile(sched, seed=0)
    assert (hit1, hit2) == (False, True)
    assert key1 == key2 and exe1 is exe2
    s = cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["entries"] == 1
    assert s["hit_rate"] == 0.5
    # a different seed is a different executable (different operands)
    _, _, hit3 = cache.get_or_compile(sched, seed=1)
    assert hit3 is False


def test_schedule_key_is_content_addressed():
    _, s13 = _hybrid_schedule("vgg13")
    _, s16 = _hybrid_schedule("vgg16")
    assert schedule_key(s13) == schedule_key(s13)
    assert schedule_key(s13) != schedule_key(s16)
    assert schedule_key(s13) != schedule_key(s13, seed=1)
    assert schedule_key(s13) != schedule_key(s13, fingerprint="other")


def test_compile_cost_charged_separately_from_run():
    _, sched = _hybrid_schedule("vgg13")
    exe = compile_schedule(sched, synth_inputs(sched))
    assert exe.compile_us > 0
    assert exe.params_bytes > 0          # weights are device-resident
    assert exe.n_measured == 3
    warm_us = exe.time(reps=3)
    assert 0 < warm_us < exe.compile_us  # steady state beats compile
    summ = exe.summary()
    assert summ["key"] == exe.key and summ["n_measured"] == 3


def test_synth_inputs_cover_the_top_bit_at_width_32():
    """Width-32 weights must exercise the sign bit: the old
    ``1 << min(width, 31)`` bound silently halved the sampled range."""
    w = Workload(name="w32", ops=(
        Op(name="mm", kind="matmul", m=4, k=64, n=64, width=32),))
    sched = lower_plan_pallas(compile_plan(w), w)
    (step,) = sched.measured_steps
    assert step.width == 32
    inputs = synth_inputs(sched, seed=0)
    _, wm = inputs["mm"]
    assert (wm < 0).any(), "top bit never set: width-32 range is halved"
    got = compile_schedule(sched, inputs).run()
    ref = reference_results(sched, inputs)
    np.testing.assert_array_equal(got["mm"], ref["mm"])
