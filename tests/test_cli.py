"""CLI subprocess tests for ``python -m repro`` (ISSUE 4 satellite).

Exit codes, artifact JSON schemas, geometry threading, and the sweep
cache-hit behaviour on a second invocation -- all through real
subprocesses, so argument parsing and artifact writing are exercised the
way CI's bench-smoke job runs them.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).parent.parent / "src")


def run_cli(*args, artifact_dir=None, cwd=None):
    env = {"PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu",
           "PATH": os.environ.get("PATH", "/usr/bin")}
    if artifact_dir is not None:
        env["REPRO_BENCH_ARTIFACT_DIR"] = str(artifact_dir)
    return subprocess.run([sys.executable, "-m", "repro", *args],
                          capture_output=True, text=True, env=env,
                          cwd=cwd)


def test_list_exits_zero_and_names_everything():
    proc = run_cli("list")
    assert proc.returncode == 0
    for needle in ("mk/vector_add", "aes", "arch/tinyllama_1_1b",
                   "# backends", "analytic", "planner"):
        assert needle in proc.stdout, needle


def test_characterize_quick_writes_schema_valid_artifact(tmp_path):
    proc = run_cli("characterize", "--quick", "mk/vector_add", "aes",
                   artifact_dir=tmp_path)
    assert proc.returncode == 0, proc.stderr
    env = json.loads((tmp_path / "characterize.json").read_text())
    assert env["artifact"] == "characterize"
    assert env["schema_version"] == 1
    art = env["payload"]
    assert set(art) == {"mk/vector_add", "aes"}
    for summaries in art.values():
        assert set(summaries) >= {"analytic", "planner", "executor"}
        for s in summaries.values():
            assert isinstance(s.get("bp_cycles"), int)
            assert isinstance(s.get("bs_cycles"), int)


def test_characterize_geometry_changes_reported_cycles():
    base = run_cli("characterize", "mk/multu", "--backends", "analytic")
    small = run_cli("characterize", "mk/multu", "--backends", "analytic",
                    "--geometry", "128x512x4")
    assert base.returncode == 0 and small.returncode == 0
    assert base.stdout != small.stdout
    assert "bp_cycles=210" in base.stdout
    assert "bp_cycles=336" in small.stdout


def test_characterize_bad_geometry_exits_nonzero():
    proc = run_cli("characterize", "mk/multu", "--geometry", "banana")
    assert proc.returncode != 0
    assert "bad --geometry" in proc.stderr


def test_characterize_unknown_workload_fails():
    proc = run_cli("characterize", "no/such_workload")
    assert proc.returncode != 0


@pytest.fixture(scope="module")
def sweep_runs(tmp_path_factory):
    """Two identical sweep invocations against one artifact dir (small
    spec to keep the subprocess cheap)."""
    art = tmp_path_factory.mktemp("artifacts")
    args = ("sweep", "mk/vector_add", "mk/multu",
            "--widths", "4,8", "--geometries", "3", "--no-hybrid")
    first = run_cli(*args, artifact_dir=art)
    second = run_cli(*args, artifact_dir=art)
    return art, first, second


def test_sweep_exit_codes_and_artifacts(sweep_runs):
    art, first, second = sweep_runs
    assert first.returncode == 0, first.stderr
    assert second.returncode == 0, second.stderr
    for name in ("sweep.json", "guidelines.json"):
        assert (art / name).exists(), name


def test_sweep_artifact_schema(sweep_runs):
    art, _, _ = sweep_runs
    sweep = json.loads((art / "sweep.json").read_text())
    assert set(sweep) >= {"spec", "summary", "cache", "cache_stats",
                          "elapsed_s"}
    assert sweep["spec"]["workloads"] == ["mk/vector_add", "mk/multu"]
    assert sweep["spec"]["widths"] == [4, 8]
    assert sweep["summary"]["grid_points"] == 2 * 2 * 2 * 3
    assert sweep["cache_stats"]["entries"] >= 1

    g = json.loads((art / "guidelines.json").read_text())
    assert set(g) >= {"spec", "crossover", "hybrid_recommended", "rules",
                      "geometry_profile", "sweep_summary"}
    assert set(g["crossover"]) == {"mk/vector_add", "mk/multu"}
    for c in g["crossover"].values():
        assert {"crossover_width", "bs_win_widths", "tie_widths",
                "prefix", "bs_feasible_widths"} <= set(c)
    assert g["hybrid_recommended"] == []  # --no-hybrid


def test_sweep_second_invocation_hits_cache(sweep_runs):
    art, first, second = sweep_runs
    assert "cache: miss" in first.stdout
    assert "cache: hit" in second.stdout
    assert json.loads((art / "sweep.json").read_text())["cache"]["hit"]


def test_guidelines_prints_rules(tmp_path):
    proc = run_cli("guidelines", "--no-cache", artifact_dir=tmp_path)
    assert proc.returncode == 0, proc.stderr
    assert "# derived rules" in proc.stdout
    assert "hybrid_recommended" in proc.stdout
    g = json.loads((tmp_path / "guidelines.json").read_text())
    assert g["rules"]


def test_characterize_bad_bandwidth_suffix_exits_cleanly():
    proc = run_cli("characterize", "mk/multu", "--geometry",
                   "128x512x64@abc")
    assert proc.returncode != 0
    assert "bad --geometry" in proc.stderr
    assert "Traceback" not in proc.stderr


# ---------------------------------------------------------------------------
# --arrays threading + machine-bench (ISSUE 8)
# ---------------------------------------------------------------------------

def test_characterize_arrays_override_changes_cycles():
    base = run_cli("characterize", "mk/multu", "--backends", "analytic")
    scaled = run_cli("characterize", "mk/multu", "--backends", "analytic",
                     "--arrays", "4")
    assert base.returncode == 0 and scaled.returncode == 0
    assert base.stdout != scaled.stdout


def test_characterize_bad_arrays_exits_cleanly():
    proc = run_cli("characterize", "mk/multu", "--arrays", "-1")
    assert proc.returncode != 0
    assert "--arrays" in proc.stderr
    assert "Traceback" not in proc.stderr


def test_plan_arrays_override_threads_geometry(tmp_path):
    base = run_cli("plan", "vgg16")
    scaled = run_cli("plan", "vgg16", "--arrays", "16", "--geometry",
                     "128x512x512")
    assert base.returncode == 0 and scaled.returncode == 0
    assert base.stdout != scaled.stdout  # fewer arrays -> more batches


def test_machine_bench_writes_schema_valid_artifact(tmp_path):
    proc = run_cli("machine-bench", "--workload", "vgg16",
                   "--geometries", "2", "--no-execute", "--no-diff",
                   artifact_dir=tmp_path)
    assert proc.returncode == 0, proc.stderr
    env = json.loads((tmp_path / "machine.json").read_text())
    assert env["artifact"] == "machine"
    assert env["schema_version"] == 1
    art = env["payload"]
    assert art["workload"] == "vgg16"
    assert art["gate_failures"] == []
    assert len(art["curve"]) == 2
    for pt in art["curve"]:
        if "error" in pt:
            continue
        assert pt["explained"] is True
        assert pt["total_cycles"] == (pt["compute_cycles"]
                                      + pt["movement_cycles"]
                                      + pt["transpose_cycles"])


def test_machine_bench_unknown_workload_fails():
    proc = run_cli("machine-bench", "--workload", "no/such_app",
                   "--no-execute", "--no-diff")
    assert proc.returncode != 0


def test_pallas_bench_writes_artifact_and_gates(tmp_path):
    """pallas-bench (ISSUE 9): envelope-valid artifact, per-case rows,
    and the regression gate's two verdicts -- pass against itself,
    exit 3 against a doctored too-fast baseline."""
    proc = run_cli("pallas-bench", "--quick", "--reps", "1",
                   "--shape", "vgg_fc_out", artifact_dir=tmp_path)
    assert proc.returncode == 0, proc.stderr
    env = json.loads((tmp_path / "BENCH_pallas.json").read_text())
    assert env["artifact"] == "pallas"
    assert env["schema_version"] == 1
    cases = env["payload"]["cases"]
    # quick widths {4,8,16} x paths {bp, bs_fused, bs_unfused}
    assert {c["name"] for c in cases} == {
        f"vgg_fc_out/w{b}/{p}" for b in (4, 8, 16)
        for p in ("bp", "bs_fused", "bs_unfused")}
    for c in cases:
        assert c["shape"] == [1, 512, 10]
        assert c["us"] > 0
        assert c["padded"][0] >= 1 and c["padded"][2] >= 10

    # a fresh run against its own artifact passes the gate (a generous
    # threshold keeps single-rep jitter from flaking the test; the
    # regression verdict itself is pinned below and in test_kernels)
    proc = run_cli("pallas-bench", "--quick", "--reps", "1",
                   "--shape", "vgg_fc_out", "--regress-threshold", "20",
                   "--baseline", str(tmp_path / "BENCH_pallas.json"),
                   artifact_dir=tmp_path)
    assert proc.returncode == 0, proc.stderr
    assert "regression gate" in proc.stdout

    # doctor every baseline median to ~0 and drop the noise floor: every
    # case is now a regression -> exit 3 (the CI failure mode)
    for c in env["payload"]["cases"]:
        c["us"] = 0.001
    slow = tmp_path / "baseline_doctored.json"
    slow.write_text(json.dumps(env))
    proc = run_cli("pallas-bench", "--quick", "--reps", "1",
                   "--shape", "vgg_fc_out", "--baseline", str(slow),
                   "--regress-floor-us", "0", artifact_dir=tmp_path)
    assert proc.returncode == 3
    assert "regression(s)" in proc.stdout


def test_pallas_bench_unknown_shape_fails():
    proc = run_cli("pallas-bench", "--shape", "nope")
    assert proc.returncode == 2
    assert "unknown shape" in proc.stderr


def test_plan_pallas_flag_times_kernel_schedule(tmp_path):
    """`plan <app> --pallas` lowers the compiled LayoutPlan to the Pallas
    kernel schedule and prints a measured median per step."""
    proc = run_cli("plan", "gemv", "--quick", "--pallas", "--reps", "1",
                   artifact_dir=tmp_path)
    assert proc.returncode == 0, proc.stderr
    assert "pallas" in proc.stdout and "median_us=" in proc.stdout
    env = json.loads((tmp_path / "plans.json").read_text())
    pallas = env["payload"]["gemv"]["pallas"]
    assert pallas["steps"] and all(r["dims"] for r in pallas["steps"])
