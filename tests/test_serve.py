"""Layout-aware serving tests (ISSUE 6): the plan cache's key contract,
counters and tiers; the plan service; phase-grouped batching invariants;
the versioned Report/artifact schema; the `get_backend` factory; and the
serve-bench CLI (including the >=90%-warm second run, via subprocess).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.core.params import PAPER_SYSTEM
from repro.plan.ir import LayoutPlan
from repro.serve import (
    PhaseBatcher,
    PlanCache,
    PlanService,
    Request,
    TrafficMix,
    arch_ids,
    check_regression,
    plan_key,
    run_serve_bench,
)
from repro.sweep import Geometry
from repro.workloads import (
    Report,
    backend_names,
    get_backend,
    get_workload,
    register_backend,
)

SMALL_GEO = Geometry(rows=128, cols=512, arrays=64)


def _req(i=0, arch="tinyllama_1_1b", tokens=256, bits=4):
    return Request(id=i, arch=arch, tokens=tokens, weight_bits=bits)


# ---------------------------------------------------------------------------
# plan_key: the content-address contract
# ---------------------------------------------------------------------------

def test_plan_key_is_deterministic():
    w = get_workload("aes")
    assert plan_key(w, PAPER_SYSTEM) == plan_key(w, PAPER_SYSTEM)
    assert len(plan_key(w, PAPER_SYSTEM)) == 24


def test_plan_key_separates_workload_geometry_and_arrival_layout():
    w1, w2 = get_workload("aes"), get_workload("vgg")
    k = plan_key(w1, PAPER_SYSTEM)
    assert plan_key(w2, PAPER_SYSTEM) != k
    assert plan_key(w1, SMALL_GEO.system()) != k
    assert plan_key(w1, PAPER_SYSTEM, initial_layout="BP") != k


def test_plan_key_misses_on_scheduler_fingerprint_change():
    """Editing the scheduler source must invalidate every cached plan."""
    w = get_workload("aes")
    real = plan_key(w, PAPER_SYSTEM)
    stale = plan_key(w, PAPER_SYSTEM, fingerprint="deadbeef")
    assert real != stale

    cache = PlanCache(persist=False)
    from repro.plan import compile_plan

    cache.put(cache.key(w, PAPER_SYSTEM), compile_plan(w, PAPER_SYSTEM))
    stale_cache = PlanCache(persist=False, fingerprint="deadbeef")
    assert stale_cache.get(stale_cache.key(w, PAPER_SYSTEM)) is None


# ---------------------------------------------------------------------------
# PlanCache: counters, LRU, disk tier
# ---------------------------------------------------------------------------

def test_cache_counters_and_hit_rate():
    service = PlanService(cache=PlanCache(persist=False))
    reqs = [_req(0), _req(1), _req(2, tokens=512), _req(3), _req(4)]
    compiled = service.compile_many(reqs)
    stats = service.cache.stats()
    # 2 distinct operating points -> 2 misses, 3 hits
    assert stats["misses"] == 2
    assert stats["hits"] == stats["mem_hits"] == 3
    assert stats["lookups"] == 5
    assert stats["hit_rate"] == pytest.approx(3 / 5)
    assert [c.cache_hit for c in compiled] == [False, True, False, True,
                                               True]
    # a cache hit returns the identical compiled plan
    assert compiled[1].plan is compiled[0].plan
    assert compiled[1].key == compiled[0].key


def test_cache_lru_eviction():
    cache = PlanCache(capacity=2, persist=False)
    service = PlanService(cache=cache)
    service.compile(_req(0, tokens=256))
    service.compile(_req(1, tokens=512))
    service.compile(_req(2, tokens=1024))  # evicts the tokens=256 plan
    assert cache.evictions == 1
    c = service.compile(_req(3, tokens=256))  # must recompile
    assert not c.cache_hit


def test_cache_disk_tier_survives_the_process(tmp_path):
    d = str(tmp_path / "plan-cache")
    first = PlanService(cache_dir=d)
    c0 = first.compile(_req(0))
    assert not c0.cache_hit

    second = PlanService(cache_dir=d)  # fresh memory, same disk
    c1 = second.compile(_req(1))
    assert c1.cache_hit
    assert second.cache.disk_hits == 1 and second.cache.mem_hits == 0
    assert c1.plan.total_cycles == c0.plan.total_cycles
    assert c1.plan.schedule == c0.plan.schedule

    entry = json.loads(
        (tmp_path / "plan-cache" / f"{c0.key}.json").read_text())
    prov = entry["provenance"]
    assert prov["arch"] == "tinyllama_1_1b"
    assert prov["scheduler_fingerprint"] == first.cache.fingerprint


def test_cache_no_persist_writes_nothing(tmp_path):
    d = str(tmp_path / "plan-cache")
    PlanService(cache_dir=d, persist=False).compile(_req(0))
    assert not os.path.exists(d)


# ---------------------------------------------------------------------------
# LayoutPlan serialization (the disk-cache format)
# ---------------------------------------------------------------------------

def test_layout_plan_round_trip():
    from repro.plan import compile_plan

    p = compile_plan(get_workload("aes"), PAPER_SYSTEM)
    q = LayoutPlan.from_dict(p.to_dict(include_steps=True))
    assert q.total_cycles == p.total_cycles
    assert q.schedule == p.schedule
    assert q.workload == p.workload
    assert q.geometry == p.geometry
    assert len(q.steps) == len(p.steps)
    assert [t.cycles for t in q.transposes] == \
        [t.cycles for t in p.transposes]
    assert q.feasible == p.feasible


def test_layout_plan_summary_dump_cannot_round_trip():
    from repro.plan import compile_plan

    p = compile_plan(get_workload("aes"), PAPER_SYSTEM)
    with pytest.raises(ValueError, match="steps"):
        LayoutPlan.from_dict(p.to_dict(include_steps=False))


# ---------------------------------------------------------------------------
# Versioned Report schema + artifact envelope (satellite 2)
# ---------------------------------------------------------------------------

def test_report_schema_round_trip():
    rep = get_backend("analytic").estimate(get_workload("aes"))
    d = rep.to_dict()
    assert d["schema_version"] == 1
    back = Report.from_dict(d)
    assert back == rep
    # through JSON too (the committed-artifact path)
    assert Report.from_dict(json.loads(json.dumps(d))) == rep


def test_report_refuses_newer_schema():
    rep = get_backend("analytic").estimate(get_workload("mk/multu"))
    d = rep.to_dict()
    d["schema_version"] = 999
    with pytest.raises(ValueError, match="newer"):
        Report.from_dict(d)


def test_artifact_envelope_round_trip(tmp_path):
    from repro.artifacts import (
        ArtifactError, read_artifact, read_envelope, write_artifact,
    )

    path = str(tmp_path / "x.json")
    write_artifact(path, "serve", {"a": 1}, generated_by="test")
    assert read_artifact(path, "serve") == {"a": 1}
    assert read_envelope(path)["generated_by"] == "test"
    with pytest.raises(ArtifactError, match="kind"):
        read_artifact(path, "plans")

    env = json.loads(Path(path).read_text())
    env["schema_version"] = 999
    Path(path).write_text(json.dumps(env))
    with pytest.raises(ArtifactError, match="newer"):
        read_artifact(path, "serve")


# ---------------------------------------------------------------------------
# get_backend factory (satellite 1)
# ---------------------------------------------------------------------------

def test_get_backend_resolves_every_registered_name():
    for name in backend_names():
        b = get_backend(name)
        assert b.name == name


def test_get_backend_unknown_name_lists_registry():
    with pytest.raises(KeyError, match="analytic"):
        get_backend("no_such_backend")


def test_get_backend_passes_constructor_options():
    assert get_backend("planner", execute=True).execute is True


def test_get_backend_accepts_instances_but_not_with_options():
    inst = get_backend("analytic")
    assert get_backend(inst) is inst
    with pytest.raises(TypeError):
        get_backend(inst, execute=True)


def test_register_backend_extends_the_registry():
    class FakeBackend:
        name = "fake_for_test"

        def supports(self, w):
            return False

        def estimate(self, w, sys=PAPER_SYSTEM):
            raise NotImplementedError

    register_backend("fake_for_test", FakeBackend)
    try:
        assert "fake_for_test" in backend_names()
        assert isinstance(get_backend("fake_for_test"), FakeBackend)
    finally:
        from repro.workloads.backends import BACKENDS

        del BACKENDS["fake_for_test"]


def test_plan_service_rejects_backends_without_compile():
    with pytest.raises(TypeError, match="compile"):
        PlanService(backend="analytic")


# ---------------------------------------------------------------------------
# PhaseBatcher: grouping + amortization invariants
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def compiled_sample():
    service = PlanService(cache=PlanCache(persist=False))
    return service.compile_many(TrafficMix.default().sample(96, seed=3))


def test_batcher_groups_share_one_signature(compiled_sample):
    groups = PhaseBatcher(max_batch=16).group(compiled_sample)
    assert sum(g.size for g in groups) == len(compiled_sample)
    for g in groups:
        assert 1 <= g.size <= 16
        assert all(m.signature == g.signature for m in g.members)


def test_batcher_grouping_is_stable(compiled_sample):
    groups = PhaseBatcher(max_batch=1024).group(compiled_sample)
    for g in groups:
        ids = [m.request.id for m in g.members]
        assert ids == sorted(ids)  # arrival order preserved


def test_batcher_amortization_accounting(compiled_sample):
    for g in PhaseBatcher(max_batch=32).group(compiled_sample):
        tr = g.member_transpose_cycles()
        comp = g.member_compute_cycles()
        assert g.amortized_transpose_cycles == max(tr, default=0)
        assert g.transpose_cycles_saved == sum(tr) - max(tr, default=0)
        assert g.transpose_cycles_saved >= 0
        assert g.latency_cycles == max(comp, default=0) \
            + g.amortized_transpose_cycles
        assert g.machine_cycles == sum(comp) \
            + g.amortized_transpose_cycles
        # grouping never charges more than running members alone
        alone = sum(c + t for c, t in zip(comp, tr))
        assert g.machine_cycles <= alone


def test_batcher_execute_runs_compiled_pallas_schedule(compiled_sample):
    batcher = PhaseBatcher(max_batch=8)
    g = batcher.group(compiled_sample)[0]
    row = batcher.execute(g)
    assert g.execute_us is not None and g.execute_us > 0
    # first execution compiles the group's schedule (a cache miss)...
    assert row["executable_hit"] is False
    assert row["execute_compile_us"] > 0
    assert g.execute_compile_us == row["execute_compile_us"]
    # ...and the budget admits real kernels for the serving shapes:
    # execute latency is measured Pallas wall-clock, not a proxy
    assert row["measured_steps"] >= 1
    assert row["modelled_steps"] >= 0
    # exact cycle totals still come from the host integers
    assert row["latency_cycles"] == g.latency_cycles
    assert row["machine_cycles"] == g.machine_cycles
    # re-executing the same group hits the executable cache: warm path
    # only, zero compile charge
    row2 = batcher.execute(g)
    assert row2["executable_hit"] is True
    assert row2["execute_compile_us"] == 0.0
    assert row2["executable_key"] == row["executable_key"]


def test_arrival_layout_charges_the_bp2bs_transpose():
    """Serving operands arrive bit-parallel; an all-BS plan must carry
    the arrival transpose (what the batcher amortizes)."""
    service = PlanService(cache=PlanCache(persist=False))
    c = service.compile(_req(0))
    assert c.plan.n_transposes >= 1
    none_service = PlanService(cache=PlanCache(persist=False),
                               initial_layout=None)
    c_none = none_service.compile(_req(0))
    assert c_none.plan.n_transposes == 0
    assert c_none.key != c.key  # arrival layout is part of the address


# ---------------------------------------------------------------------------
# Traffic mix
# ---------------------------------------------------------------------------

def test_traffic_mix_sampling_is_deterministic():
    mix = TrafficMix.default()
    a = mix.sample(64, seed=7)
    b = mix.sample(64, seed=7)
    assert a == b
    assert mix.sample(64, seed=8) != a
    assert {r.arch for r in a} <= set(mix.archs)
    assert mix.distinct_plans == len(mix.archs) * 5 * 4
    assert set(arch_ids()) >= {"tinyllama_1_1b"}


def test_traffic_mix_validates_weight_lengths():
    with pytest.raises(ValueError, match="arch"):
        TrafficMix(archs=("a", "b"), arch_weights=(1.0,))


# ---------------------------------------------------------------------------
# serve-bench scenario + regression gate
# ---------------------------------------------------------------------------

def test_run_serve_bench_payload_shape(tmp_path):
    p = run_serve_bench(64, seed=0, cache_dir=str(tmp_path))
    assert p["requests"] == 64
    assert set(p) >= {"plan_compile_us", "execute_us", "execute_compile_us",
                      "executables", "cache", "batches", "simulated", "mix",
                      "throughput_rps"}
    for pct in (p["plan_compile_us"], p["execute_us"],
                p["execute_compile_us"]):
        assert pct["p50"] <= pct["p99"] <= pct["max"]
    assert p["cache"]["lookups"] == 64
    assert p["batches"]["count"] >= p["batches"]["signatures"] >= 1
    assert p["simulated"]["transpose_cycles_saved"] >= 0
    # executable-cache accounting: every group ran a compiled schedule,
    # and the budget admitted real kernels (measured steps > 0)
    ex = p["executables"]
    assert ex["misses"] >= 1 and ex["entries"] >= 1
    assert ex["measured_steps"] >= 1
    assert ex["execute_budget"] > 0


def test_check_regression_thresholds():
    base = {"execute_us": {"p99": 100.0}}
    ok, _ = check_regression({"execute_us": {"p99": 120.0}}, base,
                             floor_us=5.0)
    assert ok
    bad, msg = check_regression({"execute_us": {"p99": 130.0}}, base,
                                floor_us=5.0)
    assert not bad and "p99" in msg
    # sub-noise baselines are floored, not divided by: a p99 under
    # floor_us * (1 + threshold) always passes
    ok, _ = check_regression({"execute_us": {"p99": 310.0}},
                             {"execute_us": {"p99": 70.0}})
    assert ok
    bad, _ = check_regression({"execute_us": {"p99": 320.0}},
                              {"execute_us": {"p99": 70.0}})
    assert not bad


def test_cli_serve_bench_gate_fails_on_regression(tmp_path, monkeypatch,
                                                  capsys):
    from repro.__main__ import main
    from repro.artifacts import write_artifact

    monkeypatch.setenv("REPRO_BENCH_ARTIFACT_DIR", str(tmp_path))
    baseline = tmp_path / "baseline.json"
    write_artifact(str(baseline), "serve",
                   {"execute_us": {"p99": 0.001}}, generated_by="test")
    rc = main(["serve-bench", "--requests", "32",
               "--baseline", str(baseline),
               "--regress-floor-us", "0.0001"])
    out = capsys.readouterr().out
    assert rc == 3
    assert "FAIL" in out


def test_cli_serve_bench_missing_baseline_skips_gate(tmp_path, monkeypatch,
                                                     capsys):
    from repro.__main__ import main

    monkeypatch.setenv("REPRO_BENCH_ARTIFACT_DIR", str(tmp_path))
    rc = main(["serve-bench", "--requests", "32",
               "--baseline", str(tmp_path / "missing.json")])
    out = capsys.readouterr().out
    assert rc == 0
    assert "gate skipped" in out


# ---------------------------------------------------------------------------
# serve-bench CLI, the way CI runs it (subprocess)
# ---------------------------------------------------------------------------

SRC = str(Path(__file__).parent.parent / "src")


def _run_serve_cli(artifact_dir, *extra):
    env = {"PYTHONPATH": SRC, "JAX_PLATFORMS": "cpu",
           "PATH": os.environ.get("PATH", "/usr/bin"),
           "REPRO_BENCH_ARTIFACT_DIR": str(artifact_dir)}
    return subprocess.run(
        [sys.executable, "-m", "repro", "serve-bench", "--quick",
         "--requests", "256", *extra],
        capture_output=True, text=True, env=env)


def test_cli_serve_bench_second_run_is_cache_served(tmp_path):
    """The acceptance criterion: a repeat --quick run against the same
    artifact dir is >=90% plan-cache served (disk tier, new process)."""
    first = _run_serve_cli(tmp_path)
    assert first.returncode == 0, first.stderr
    env1 = json.loads((tmp_path / "serve.json").read_text())
    assert env1["artifact"] == "serve" and env1["schema_version"] == 1
    p1 = env1["payload"]
    assert p1["requests"] == 256

    # huge threshold: this asserts the gate plumbing runs, not timing
    second = _run_serve_cli(tmp_path, "--baseline",
                            str(tmp_path / "serve.json"),
                            "--regress-threshold", "50")
    assert second.returncode == 0, \
        second.stdout + second.stderr
    p2 = json.loads((tmp_path / "serve.json").read_text())["payload"]
    assert p2["cache"]["hit_rate"] >= 0.90
    assert p2["cache"]["disk_hits"] > 0
    assert "# regression gate" in second.stdout
