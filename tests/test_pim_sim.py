"""Functional-simulator tests: bitline primitives, bit-serial arithmetic
(property tests vs integer semantics), transpose unit, AES/Keccak/FIR."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.pim import bitserial as bs
from repro.pim.array_sim import CSArray
from repro.pim.transpose_sim import round_trip
from repro.pim import aes, fir, keccak


# --------------------------------------------------------- bitline array ---

def test_multi_row_activation_truth_tables():
    a = CSArray.zeros(rows=4, cols=4)
    a = a.write_row(0, jnp.array([0, 0, 1, 1], bool))
    a = a.write_row(1, jnp.array([0, 1, 0, 1], bool))
    np.testing.assert_array_equal(np.asarray(a.activate_and(0, 1)),
                                  [0, 0, 0, 1])
    np.testing.assert_array_equal(np.asarray(a.activate_nor(0, 1)),
                                  [1, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(a.activate_xor(0, 1)),
                                  [0, 1, 1, 0])
    np.testing.assert_array_equal(np.asarray(a.activate_or(0, 1)),
                                  [0, 1, 1, 1])


def test_op_into_writeback():
    a = CSArray.zeros(rows=4, cols=2)
    a = a.write_row(0, jnp.array([1, 0], bool))
    a = a.write_row(1, jnp.array([1, 1], bool))
    a = a.op_into("xor", 0, 1, dst=2)
    np.testing.assert_array_equal(np.asarray(a.read_row(2)), [0, 1])
    a = a.not_into(2, 3)
    np.testing.assert_array_equal(np.asarray(a.read_row(3)), [1, 0])


# ----------------------------------------------- bit-serial arithmetic -----

W = 12
MASK = (1 << W) - 1
vals = st.lists(st.integers(0, MASK), min_size=1, max_size=16)


@settings(max_examples=60, deadline=None)
@given(vals, vals)
def test_bs_add_matches_integers(xs, ys):
    n = min(len(xs), len(ys))
    x, y = np.array(xs[:n], np.uint32), np.array(ys[:n], np.uint32)
    out = bs.unpack(bs.bs_add(bs.pack(jnp.asarray(x), W),
                              bs.pack(jnp.asarray(y), W)))
    np.testing.assert_array_equal(np.asarray(out), (x + y) & MASK)


@settings(max_examples=60, deadline=None)
@given(vals, vals)
def test_bs_sub_matches_integers(xs, ys):
    n = min(len(xs), len(ys))
    x, y = np.array(xs[:n], np.uint32), np.array(ys[:n], np.uint32)
    out = bs.unpack(bs.bs_sub(bs.pack(jnp.asarray(x), W),
                              bs.pack(jnp.asarray(y), W)))
    np.testing.assert_array_equal(np.asarray(out), (x - y) & MASK)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 255), min_size=1, max_size=8),
       st.lists(st.integers(0, 255), min_size=1, max_size=8))
def test_bs_mult_matches_integers(xs, ys):
    n = min(len(xs), len(ys))
    x, y = np.array(xs[:n], np.uint32), np.array(ys[:n], np.uint32)
    out = bs.unpack(bs.bs_mult(bs.pack(jnp.asarray(x), 8),
                               bs.pack(jnp.asarray(y), 8)))
    np.testing.assert_array_equal(np.asarray(out), x * y)


# --------------------------------------- unpack uint64 overflow (ISSUE 2) --

def test_unpack_accumulates_in_uint64():
    """Plane k >= 32 must not shift past a uint32 container."""
    planes = jnp.zeros((40, 3), bool).at[35, 1].set(True).at[0, 2].set(True)
    out = bs.unpack(planes)
    assert out.dtype == np.uint64
    np.testing.assert_array_equal(out, np.array([0, 1 << 35, 1], np.uint64))


def test_bs_mult_width32_unpack_regression():
    """bs_mult products carry 2w planes; at width 32 the top half lives in
    planes 32..63 and needs the uint64 accumulation."""
    x = np.array([0xFFFFFFFF, 0xDEADBEEF, 1 << 31, 3], np.uint64)
    y = np.array([0xFFFFFFFB, 0x12345678, 1 << 31, 0xFFFFFFFF], np.uint64)
    planes = bs.bs_mult(
        bs.pack(jnp.asarray(x.astype(np.uint32)), 32),
        bs.pack(jnp.asarray(y.astype(np.uint32)), 32))
    assert planes.shape[0] == 64
    np.testing.assert_array_equal(bs.unpack(planes), x * y)


# ------------------------------- signed (two's-complement) bit-serial ------

SW = 12
SMOD = 1 << SW
signed_vals = st.lists(
    st.integers(-(SMOD >> 1), (SMOD >> 1) - 1), min_size=1, max_size=16)


def _swrap(v, w):
    """Two's-complement wraparound of python/numpy ints to w bits."""
    m = 1 << w
    return ((v + (m >> 1)) % m) - (m >> 1)


def _pack_signed(x, w):
    return bs.pack(jnp.asarray((x % (1 << w)).astype(np.uint32)), w)


@settings(max_examples=60, deadline=None)
@given(signed_vals)
def test_bs_neg_signed(xs):
    """bs_neg == numpy int negation incl. the INT_MIN wraparound."""
    x = np.array(xs, np.int64)
    out = bs.unpack_signed(bs.bs_neg(_pack_signed(x, SW)))
    np.testing.assert_array_equal(out, _swrap(-x, SW))


@settings(max_examples=60, deadline=None)
@given(signed_vals, signed_vals)
def test_bs_sub_signed(xs, ys):
    n = min(len(xs), len(ys))
    x, y = np.array(xs[:n], np.int64), np.array(ys[:n], np.int64)
    out = bs.unpack_signed(bs.bs_sub(_pack_signed(x, SW),
                                     _pack_signed(y, SW)))
    np.testing.assert_array_equal(out, _swrap(x - y, SW))


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(-128, 127), min_size=1, max_size=8),
       st.lists(st.integers(-128, 127), min_size=1, max_size=8))
def test_bs_mult_signed_low_planes(xs, ys):
    """The low w planes of the unsigned shift-add product of two's-
    complement encodings ARE the signed product mod 2^w (the full 2w-plane
    product is unsigned-only -- signed use truncates)."""
    n = min(len(xs), len(ys))
    x, y = np.array(xs[:n], np.int64), np.array(ys[:n], np.int64)
    planes = bs.bs_mult(_pack_signed(x, 8), _pack_signed(y, 8))
    out = bs.unpack_signed(planes[:8])
    np.testing.assert_array_equal(out, _swrap(x * y, 8))


halfvals = st.lists(st.integers(0, (1 << (W - 1)) - 1), min_size=1,
                    max_size=16)


@settings(max_examples=40, deadline=None)
@given(halfvals, halfvals)
def test_bs_minmax(xs, ys):
    """The sign-bit compare requires |a-b| < 2^(W-1) (no subtraction
    overflow) -- the usual operating contract of the iterative-compare
    variant; operands are drawn from the half-range."""
    n = min(len(xs), len(ys))
    x, y = np.array(xs[:n], np.uint32), np.array(ys[:n], np.uint32)
    mn = bs.unpack(bs.bs_min(bs.pack(jnp.asarray(x), W),
                             bs.pack(jnp.asarray(y), W)))
    mx = bs.unpack(bs.bs_max(bs.pack(jnp.asarray(x), W),
                             bs.pack(jnp.asarray(y), W)))
    np.testing.assert_array_equal(np.asarray(mn), np.minimum(x, y))
    np.testing.assert_array_equal(np.asarray(mx), np.maximum(x, y))


@settings(max_examples=40, deadline=None)
@given(vals)
def test_bs_popcount(xs):
    x = np.array(xs, np.uint32)
    out = bs.unpack(bs.bs_popcount(bs.pack(jnp.asarray(x), W), out_width=5))
    expect = np.array([bin(v).count("1") for v in x])
    np.testing.assert_array_equal(np.asarray(out), expect)


@settings(max_examples=40, deadline=None)
@given(vals, vals, st.lists(st.booleans(), min_size=1, max_size=16))
def test_bs_mux(xs, ys, cs):
    n = min(len(xs), len(ys), len(cs))
    x, y = np.array(xs[:n], np.uint32), np.array(ys[:n], np.uint32)
    c = np.array(cs[:n], bool)
    out = bs.unpack(bs.bs_mux(jnp.asarray(c), bs.pack(jnp.asarray(x), W),
                              bs.pack(jnp.asarray(y), W)))
    np.testing.assert_array_equal(np.asarray(out), np.where(c, x, y))


def test_bs_shift_is_free_row_rename():
    x = np.array([3, 5], np.uint32)
    planes = bs.pack(jnp.asarray(x), 8)
    shifted = bs.bs_shift_up(planes, 3)
    np.testing.assert_array_equal(np.asarray(bs.unpack(shifted)), x << 3)


# ------------------------------------------------------------- transpose ---

@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 2**16 - 1), min_size=1, max_size=32))
def test_transpose_round_trip(xs):
    x = jnp.asarray(np.array(xs, np.uint32))
    np.testing.assert_array_equal(np.asarray(round_trip(x, 16)),
                                  np.array(xs, np.uint32))


# ------------------------------------------------------------------- AES ---

FIPS_KEY = np.array(bytearray.fromhex("000102030405060708090a0b0c0d0e0f"))
FIPS_PT = np.array(bytearray.fromhex("00112233445566778899aabbccddeeff"))
FIPS_CT = np.array(bytearray.fromhex("69c4e0d86a7b0430d8cdb78070b4c55a"))


def test_aes_reference_fips197():
    np.testing.assert_array_equal(aes.encrypt_reference(FIPS_PT, FIPS_KEY),
                                  FIPS_CT)


def test_aes_bp_layout_fips197():
    np.testing.assert_array_equal(aes.encrypt_bp(FIPS_PT, FIPS_KEY), FIPS_CT)


def test_aes_bs_layout_fips197():
    """Bit-sliced GF-inversion SubBytes + physical-shuffle ShiftRows."""
    np.testing.assert_array_equal(aes.encrypt_bs(FIPS_PT, FIPS_KEY), FIPS_CT)


def test_aes_hybrid_layout_fips197():
    """The paper's hybrid schedule encrypts identically."""
    np.testing.assert_array_equal(aes.encrypt_hybrid(FIPS_PT, FIPS_KEY),
                                  FIPS_CT)


@settings(max_examples=10, deadline=None)
@given(st.binary(min_size=16, max_size=16), st.binary(min_size=16, max_size=16))
def test_aes_layouts_agree_random(pt_bytes, key_bytes):
    pt = np.frombuffer(pt_bytes, np.uint8).copy()
    key = np.frombuffer(key_bytes, np.uint8).copy()
    ref = aes.encrypt_reference(pt, key)
    np.testing.assert_array_equal(aes.encrypt_bp(pt, key), ref)
    np.testing.assert_array_equal(aes.encrypt_bs(pt, key), ref)
    np.testing.assert_array_equal(aes.encrypt_hybrid(pt, key), ref)


def test_bs_gf_inverse_matches_table():
    xs = np.arange(256, dtype=np.uint32)
    planes = bs.pack(jnp.asarray(xs), 8)
    inv = np.asarray(bs.unpack(aes.bs_gf_inverse(planes)))
    for x in range(1, 256):
        assert aes.gf_mul_int(int(x), int(inv[x])) == 1
    assert inv[0] == 0  # x^254 of 0


def test_bs_sub_bytes_matches_sbox_table():
    xs = np.arange(256, dtype=np.uint32)
    planes = bs.pack(jnp.asarray(xs), 8)
    out = np.asarray(bs.unpack(aes.bs_sub_bytes(planes)))
    np.testing.assert_array_equal(out, np.array(aes.sbox_table()))


# ---------------------------------------------------------------- Keccak ---

def test_keccak_pi_logical_equals_physical():
    rng = np.random.default_rng(0)
    state = jnp.asarray(rng.integers(0, 2**63, size=25, dtype=np.uint64))
    np.testing.assert_array_equal(np.asarray(keccak.pi_logical(state)),
                                  np.asarray(keccak.pi_physical(state)))


def test_keccak_pi_is_permutation():
    idx = keccak.pi_index_map()
    assert sorted(idx.tolist()) == list(range(25))


def test_keccak_theta_then_pi_runs():
    rng = np.random.default_rng(1)
    state = jnp.asarray(rng.integers(0, 2**63, size=25, dtype=np.uint64))
    out = keccak.pi_logical(keccak.theta(state))
    assert out.shape == (25,)
    assert not np.array_equal(np.asarray(out), np.asarray(state))


# ------------------------------------------------------------------- FIR ---

@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(-100, 100), min_size=4, max_size=64),
       st.lists(st.integers(-8, 8), min_size=4, max_size=4))
def test_fir_matches_convolve(samples, coeffs):
    s = np.array(samples, np.int64)
    c = np.array(coeffs, np.int64)
    out = np.asarray(fir.fir_bp(jnp.asarray(s), jnp.asarray(c)))
    np.testing.assert_array_equal(out, fir.fir_reference(s, c))
