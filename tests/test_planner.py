"""Planner + taxonomy unit/property tests."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost_model import Layout
from repro.core.params import SystemParams
from repro.core.planner import (
    Phase, hybrid_profitability_threshold, plan,
)
from repro.core.taxonomy import (
    CASE_STUDIES, Recommendation, WorkloadFeatures, classify,
    paper_threshold_rule,
)


def _mkphases(costs):
    return [Phase(f"p{i}", bp, bs) for i, (bp, bs) in enumerate(costs)]


def test_static_bp_when_bp_dominates():
    p = plan(_mkphases([(10, 100), (20, 200), (5, 50)]))
    assert p.schedule == (Layout.BP,) * 3
    assert p.total_cycles == 35
    assert not p.is_hybrid


def test_static_bs_when_bs_dominates():
    p = plan(_mkphases([(100, 10), (200, 20)]))
    assert p.schedule == (Layout.BS,) * 2
    assert p.total_cycles == 30


def test_hybrid_when_switch_pays():
    # phase 2 saves 10_000 in BS; transpose costs 145 each way
    p = plan(_mkphases([(10, 10_000), (10_000, 10), (10, 10_000)]))
    assert p.is_hybrid
    assert p.schedule == (Layout.BP, Layout.BS, Layout.BP)
    assert p.total_cycles == 30 + 2 * 145


def test_no_switch_when_transpose_too_expensive():
    # saving of 100 < 2x145 transpose cost
    p = plan(_mkphases([(10, 10_000), (110, 10), (10, 10_000)]))
    assert not p.is_hybrid
    assert p.schedule == (Layout.BP,) * 3


def test_initial_layout_charged():
    ph = _mkphases([(10, 10_000)])
    p = plan(ph, initial_layout=Layout.BS)
    # must transpose BS->BP first: 128 + 1 + 16
    assert p.total_cycles == 10 + 145


def test_profitability_threshold_monotone():
    ph = _mkphases([(10, 2000), (2000, 10)])
    thr = hybrid_profitability_threshold(ph)
    assert thr > 0
    sys_ok = SystemParams(transpose_core_cycles=thr)
    sys_bad = SystemParams(transpose_core_cycles=thr + 1)
    assert plan(ph, sys_ok).is_hybrid
    assert not plan(ph, sys_bad).is_hybrid


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 10_000), st.integers(1, 10_000)),
                min_size=1, max_size=12))
def test_plan_never_worse_than_static(costs):
    """Property: the DP schedule is <= both static choices."""
    p = plan(_mkphases(costs))
    assert p.total_cycles <= p.static_bp
    assert p.total_cycles <= p.static_bs


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 10_000), st.integers(1, 10_000)),
                min_size=1, max_size=10))
def test_plan_matches_bruteforce(costs):
    """Property: DP equals brute-force enumeration over all 2^n schedules."""
    import itertools

    phases = _mkphases(costs)
    p = plan(phases)
    best = None
    for sched in itertools.product((Layout.BP, Layout.BS),
                                   repeat=len(phases)):
        total, prev = 0, None
        for ph, l in zip(phases, sched):
            if prev is not None and prev != l:
                total += 145  # rows 16/128 default footprint
            total += ph.cycles(l)
            prev = l
        best = total if best is None else min(best, total)
    assert p.total_cycles == best


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.tuples(st.integers(1, 5_000), st.integers(1, 5_000),
                       st.integers(1, 64), st.integers(1, 256)),
             min_size=1, max_size=10),
    st.sampled_from([None, Layout.BP, Layout.BS]),
)
def test_plan_matches_bruteforce_with_footprints(costs, init):
    """Property (ISSUE 2): the DP returns the true optimum over all 2^k
    layout schedules for random per-phase footprints, with transpose
    switch costs derived independently from `transpose_cycles` (including
    the initial-layout switch)."""
    import itertools

    from repro.core.transpose import transpose_cycles

    phases = [Phase(f"p{i}", bp, bs, rbp, rbs)
              for i, (bp, bs, rbp, rbs) in enumerate(costs)]
    p = plan(phases, initial_layout=init)
    best = None
    for sched in itertools.product((Layout.BP, Layout.BS),
                                   repeat=len(phases)):
        total, prev = 0, init
        for ph, l in zip(phases, sched):
            if prev is not None and prev != l:
                direction = "bp2bs" if l is Layout.BS else "bs2bp"
                total += transpose_cycles(ph.rows_bp, ph.rows_bs, direction)
            total += ph.cycles(l)
            prev = l
        best = total if best is None else min(best, total)
    assert p.total_cycles == best


# ------------------------------------------------------------- taxonomy ----

def test_taxonomy_case_studies():
    assert classify(CASE_STUDIES["aes"]).recommendation == Recommendation.HYBRID
    assert classify(CASE_STUDIES["hdc"]).recommendation == Recommendation.BS
    assert classify(CASE_STUDIES["fir"]).recommendation == Recommendation.BP
    assert classify(
        CASE_STUDIES["vgg_late_layer"]).recommendation == Recommendation.BP
    assert classify(
        CASE_STUDIES["edge_ai_int4"]).recommendation == Recommendation.BS
    assert classify(
        CASE_STUDIES["mixed_precision_dnn"]).recommendation == Recommendation.BP


def test_taxonomy_reasons_cite_challenges():
    v = classify(CASE_STUDIES["fir"])
    assert any("Challenge 2" in r for r in v.reasons)


def test_paper_threshold_rule():
    """Sec. 5.5: 2% of the ~2550-cycle reference phase = 51 cycles."""
    assert paper_threshold_rule(2550) == pytest.approx(51)


@settings(max_examples=100, deadline=None)
@given(
    precision=st.sampled_from([1, 4, 8, 16, 32]),
    dop=st.integers(1, 1 << 22),
    control=st.floats(0, 1),
    bitfrac=st.floats(0, 1),
)
def test_taxonomy_total_order(precision, dop, control, bitfrac):
    """classify() always returns a verdict with scores and reasons."""
    f = WorkloadFeatures(
        precision_bits=precision, dop=dop, control_intensity=control,
        bit_level_fraction=bitfrac, working_set_bits=precision * 4)
    v = classify(f)
    assert v.recommendation in tuple(Recommendation)
    assert v.bp_score >= 0 and v.bs_score >= 0
