"""Differential validation of the micro-op executor (tentpole suite).

Two oracles per (kernel, layout, width):
  1. semantics: executed program output == integer reference (numpy/python
     ints, signed or unsigned per kernel contract);
  2. cycles: executed cycle count == analytic `cost_model` compute formula,
     up to the *documented* calibration delta carried by the program
     (DESIGN.md Sec. 8) -- an undocumented mismatch fails.

Plus ISA unit tests (Table-2 charges, transposes, shift-as-renaming) and
the jit/vmap batched-execution contract.
"""
import zlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.cost_model import Layout
from repro.core.microkernels import MICROKERNELS
from repro.pim import executor as ex
from repro.pim import programs as pr
from repro.pim.array_sim import CSArray
from repro.pim.bitserial import unpack
from repro.pim.microcode import Op, Program, op_cycles

WIDTHS = (8, 16, 32)
KERNELS = pr.EXECUTABLE_KERNELS
LAYOUTS = (Layout.BP, Layout.BS)


# ---------------------------------------------------------------- helpers --

def _edge_vals(w):
    return [0, 1, (1 << w) - 1, 1 << (w - 1), (1 << (w - 1)) - 1]


def _inputs(name, w, rng):
    """(inputs dict, n) with deliberate sign/overflow boundary values."""
    m = 1 << w
    if name in ("min", "max"):
        # iterative-compare contract: |a-b| < 2^(w-1) => half-range operands
        lo, hi = -(1 << (w - 2)), (1 << (w - 2))
        a = np.r_[rng.integers(lo, hi, 8), [lo, hi - 1, 0, -1, 1]]
        b = np.r_[rng.integers(lo, hi, 8), [hi - 1, lo, 0, 1, -1]]
        return {"a": a % m, "b": b % m}, len(a)
    if name == "if_then_else":
        t = np.r_[rng.integers(0, m, 8), _edge_vals(w)].astype(np.uint64)
        f = np.r_[rng.integers(0, m, 8), _edge_vals(w)[::-1]]
        c = rng.integers(0, 2, len(t))
        return {"cond": c, "t": t, "f": f % m}, len(t)
    if name == "reduction":
        # small values: the BS peripheral accumulator is uint32
        a = rng.integers(0, min(m, 1 << 20), 16).astype(np.uint64)
        return {"a": a}, 16
    a = np.r_[rng.integers(0, m, 8), _edge_vals(w)].astype(np.uint64)
    b = np.r_[rng.integers(0, m, 8), _edge_vals(w)[::-1]].astype(np.uint64)
    b[-1] = a[-1]  # give `equal` at least one equal pair
    if name in ("vector_add", "vector_sub", "multu", "equal"):
        return {"a": a, "b": b}, len(a)
    return {"a": a}, len(a)


def _reference(name, w, inp):
    """Unsigned-encoded expected outputs (python-int semantics, mod 2^w)."""
    m = 1 << w
    half = m >> 1

    def signed(u):
        return int(u) - m if int(u) >= half else int(u)

    if name == "vector_add":
        return (inp["a"] + inp["b"]) % m
    if name == "vector_sub":
        return (inp["a"].astype(np.int64) - inp["b"].astype(np.int64)) % m
    if name == "multu":
        return np.array([int(x) * int(y)
                         for x, y in zip(inp["a"], inp["b"])], np.uint64)
    if name in ("min", "max"):
        fn = min if name == "min" else max
        return np.array([fn(signed(x), signed(y)) % m
                         for x, y in zip(inp["a"], inp["b"])], np.uint64)
    if name == "abs":
        return np.array([abs(signed(x)) % m for x in inp["a"]], np.uint64)
    if name == "relu":
        return np.array([x if signed(x) >= 0 else 0 for x in inp["a"]],
                        np.uint64)
    if name == "equal":
        return (inp["a"] == inp["b"]).astype(np.uint64)
    if name == "ge_0":
        return np.array([1 if signed(x) >= 0 else 0 for x in inp["a"]],
                        np.uint64)
    if name == "gt_0":
        return np.array([1 if signed(x) > 0 else 0 for x in inp["a"]],
                        np.uint64)
    if name == "if_then_else":
        return np.where(inp["cond"] == 1, inp["t"], inp["f"]).astype(
            np.uint64)
    if name == "reduction":
        return int(inp["a"].sum())
    if name == "bitcount":
        return np.array([bin(int(x)).count("1") for x in inp["a"]],
                        np.uint64)
    raise AssertionError(name)


_OUT = {
    "vector_add": "sum", "vector_sub": "diff", "multu": "prod",
    "min": "min", "max": "max", "abs": "abs", "relu": "relu",
    "equal": "eq", "ge_0": "ge0", "gt_0": "gt0", "if_then_else": "out",
    "reduction": "sum", "bitcount": "count",
}


def _run(name, layout, w, inp, n):
    prog = pr.build(name, layout, width=w,
                    n=(n if (name == "reduction" and layout is Layout.BP)
                       else None))
    cells = ex.init_cells(prog, n)
    for k, v in inp.items():
        cells = ex.set_input(cells, prog, k, v)
    return prog, ex.execute(prog, cells)


def _decode(prog, res, name, n):
    """Executed output in the unsigned reference encoding."""
    if name == "reduction":
        if prog.layout is Layout.BS:
            return int(res.acc)
        return int(np.asarray(
            ex.get_output(res.array.cells, prog, "sum", 1))[0])
    if name == "multu" and prog.layout is Layout.BP:
        # lo/hi row pair -> full 2w-bit product
        lo = np.asarray(ex.get_output(
            res.array.cells, prog, "prod_lo", n)).astype(np.uint64)
        hi = np.asarray(ex.get_output(
            res.array.cells, prog, "prod_hi", n)).astype(np.uint64)
        return lo | (hi << np.uint64(prog.width))
    out = ex.get_output(res.array.cells, prog, _OUT[name], n)
    if prog.layout is Layout.BS:
        return unpack(out)
    return np.asarray(out).astype(np.uint64)


# ----------------------------------------------- executed semantics oracle --

@pytest.mark.parametrize("width", WIDTHS)
@pytest.mark.parametrize("layout", LAYOUTS, ids=lambda l: l.value)
@pytest.mark.parametrize("name", KERNELS)
def test_executed_matches_integer_reference(name, layout, width):
    seed = zlib.crc32(f"{name}-{layout.value}-{width}".encode())
    rng = np.random.default_rng(seed)
    inp, n = _inputs(name, width, rng)
    prog, res = _run(name, layout, width, inp, n)
    got = _decode(prog, res, name, n)
    want = _reference(name, width, inp)
    if name == "multu" and layout is Layout.BS:
        got = got[: n]
    if name == "reduction":
        if layout is Layout.BP:
            want = want % (1 << width)  # word lanes wrap mod 2^w
        assert got == want
    else:
        np.testing.assert_array_equal(np.asarray(got), want)


# --------------------------------------------------- executed cycle oracle --

@pytest.mark.parametrize("width", WIDTHS)
@pytest.mark.parametrize("layout", LAYOUTS, ids=lambda l: l.value)
@pytest.mark.parametrize("name", KERNELS)
def test_executed_cycles_match_cost_model(name, layout, width):
    """executed - analytic == documented delta; undocumented deltas fail."""
    n = 16 if name == "reduction" else None
    d = MICROKERNELS[name].executed_vs_analytic(layout, width, n=n)
    assert d["delta"] == d["expected_delta"], d
    if d["delta"] != 0:
        assert d["note"], f"undocumented calibration delta: {d}"


def test_table5_point_is_exact_except_documented():
    """At the published 16-bit calibration point, only min/max (BP is
    calibrated per-width in the source) and gt_0/BP (dual-issued combine)
    carry deltas -- and those are annotated."""
    annotated = {}
    for name in KERNELS:
        for layout in LAYOUTS:
            n = 16 if name == "reduction" else None
            d = MICROKERNELS[name].executed_vs_analytic(layout, 16, n=n)
            if d["delta"]:
                annotated[(name, layout.value)] = d["delta"]
    assert annotated == {("gt_0", "BP"): 1}


def test_executed_cycles_hook():
    mk = MICROKERNELS["multu"]
    assert mk.executed_cycles(Layout.BP, 16) == 18      # Table 2: w+2
    assert mk.executed_cycles(Layout.BS, 16) == 256     # Table 3/5: w^2
    assert mk.executed_cycles(Layout.BS, 32) == 1024
    with pytest.raises(KeyError):
        MICROKERNELS["divu"].executed_cycles(Layout.BP, 16)


# ----------------------------------------------------------- ISA contract --

def test_table2_op_charges():
    w = 16
    assert op_cycles(Op("fa", src0=0, dst=1), w) == 1
    assert op_cycles(Op("row_op", alu="and", src0=0, src1=1, dst=2), w) == 1
    assert op_cycles(Op("mux", src0=0, src1=1, src2=2, dst=3), w) == 4
    assert op_cycles(Op("shift", src0=0, dst=1, aux=4), w) == 0
    assert op_cycles(Op("const", dst=0), w) == 0
    assert op_cycles(Op("setc", aux=1), w) == 0
    assert op_cycles(Op("wadd", src0=0, src1=1, dst=2), w) == 1
    assert op_cycles(Op("wsub", src0=0, src1=1, dst=2), w) == 2
    assert op_cycles(Op("wmult", src0=0, src1=1, dst=2, aux=3), w) == 18
    assert op_cycles(Op("wshift", alu="rl", aux=5, src0=0, dst=1), w) == 5


def test_unknown_op_kind_rejected():
    with pytest.raises(ValueError):
        Op("bogus", dst=0)


def test_program_validation_rejects_out_of_range_rows():
    with pytest.raises(ValueError):
        Program("bad", Layout.BS, 8,
                (Op("copy", src0=0, dst=99),), rows=4,
                inputs=(), outputs=()).validate()


def test_bs_shift_is_free_renaming():
    """A shifted operand costs 0 cycles and multiplies by 2^k."""
    w, n, k = 8, 6, 3
    rng = np.random.default_rng(3)
    vals = rng.integers(0, 1 << (w - k), n).astype(np.uint64)
    # zero-fill the k low planes (free consts), rename [0..w) to [8+k..)
    ops = tuple([Op("const", dst=8 + j, aux=0) for j in range(k)]
                + [Op("shift", src0=0, dst=8 + k, aux=w)])
    prog = Program("shiftk", Layout.BS, w, ops, rows=8 + k + w,
                   inputs=(("a", (0, w)),),
                   outputs=(("shifted", (8, w + k)),)).validate()
    assert prog.cycles == 0
    cells = ex.init_cells(prog, n)
    cells = ex.set_input(cells, prog, "a", vals)
    res = ex.execute(prog, cells)
    out = unpack(ex.get_output(res.array.cells, prog, "shifted", n))
    np.testing.assert_array_equal(out, vals << k)


def test_transpose_ops_round_trip():
    """BP row -> BS planes -> BP row through the transpose unit micro-ops,
    each charged rows_read + core + rows_written."""
    w, n = 8, 4
    vals = np.array([3, 250, 17, 128], np.uint64)
    ops = (Op("t_bp2bs", src0=0, dst=2, aux=w),
           Op("t_bs2bp", src0=2, dst=1, aux=w))
    prog = Program("tr", Layout.BP, w, ops, rows=2 + w,
                   inputs=(("a", (0, 1)),),
                   outputs=(("back", (1, 1)), ("planes", (2, w)))).validate()
    assert prog.cycles == 2 * (w + 2)
    cells = ex.init_cells(prog, n)
    cells = ex.set_input(cells, prog, "a", vals)
    res = ex.execute(prog, cells)
    planes = res.array.cells[2:2 + w, :n]
    np.testing.assert_array_equal(unpack(planes), vals)
    back = np.asarray(ex.get_output(res.array.cells, prog, "back", n))
    np.testing.assert_array_equal(back.astype(np.uint64), vals)


def test_execute_accepts_csarray_and_checks_rows():
    prog = pr.build("vector_add", Layout.BS, width=8)
    arr = CSArray.zeros(rows=prog.rows, cols=4)
    arr = arr.write_rows(0, jnp.zeros((8, 4), bool))
    res = ex.execute(prog, arr)
    assert isinstance(res.array, CSArray)
    assert res.cycles == 8
    with pytest.raises(ValueError):
        ex.execute(prog, CSArray.zeros(rows=4, cols=4))


# ------------------------------------------------------- batched execution --

def test_batched_jit_vmap_across_arrays():
    """1024 elements of a 16-bit kernel across 8 simulated arrays execute
    in ONE jitted call (the acceptance-criterion operating point)."""
    w, n_arrays, cols = 16, 8, 128     # 8 * 128 = 1024 elements
    prog = pr.build("vector_add", Layout.BS, width=w)
    rng = np.random.default_rng(7)
    a = rng.integers(0, 1 << w, (n_arrays, cols)).astype(np.uint64)
    b = rng.integers(0, 1 << w, (n_arrays, cols)).astype(np.uint64)
    cells = np.zeros((n_arrays, prog.rows, cols), bool)
    for i in range(n_arrays):
        c = ex.init_cells(prog, cols)
        c = ex.set_input(c, prog, "a", a[i])
        c = ex.set_input(c, prog, "b", b[i])
        cells[i] = np.asarray(c)
    state = ex.run_batched(prog, jnp.asarray(cells))
    start, nrows = prog.output_region("sum")
    got = np.stack([unpack(state.cells[i, start:start + nrows])
                    for i in range(n_arrays)])
    np.testing.assert_array_equal(got, (a + b) % (1 << w))
    # second call reuses the compiled executable (cache keys on the full
    # hashable Program, so same-named hand-built programs never collide)
    assert prog in ex._BATCHED_CACHE
    ex.run_batched(prog, jnp.asarray(cells))
