"""Per-arch smoke tests: reduced config of the same family, one forward /
train-grad step + decode steps on CPU; asserts shapes + no NaNs.
(Full-size configs are exercised only via the dry-run.)"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import init_params, registry
from repro.models.base import init_params as init_p


def _smoke_batch(cfg, rng, batch=2, seq=16):
    tk, lk = jax.random.split(rng)
    b = {
        "tokens": jax.random.randint(tk, (batch, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(lk, (batch, seq), 0, cfg.vocab_size),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.family == "vlm":
        b["prefix_embeds"] = jax.random.normal(
            tk, (batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jax.random.normal(
            tk, (batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    return b


@functools.lru_cache(maxsize=None)
def _setup(arch_id):
    cfg = reduced_config(get_config(arch_id))
    fns = registry.model_fns(cfg)
    params = init_params(fns.param_structure(cfg), jax.random.key(0))
    return cfg, fns, params


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_forward_and_grad_step(arch_id):
    cfg, fns, params = _setup(arch_id)
    batch = _smoke_batch(cfg, jax.random.key(1))
    loss, grads = jax.value_and_grad(
        lambda p: fns.forward_train(cfg, p, batch))(params)
    assert np.isfinite(float(loss)), (arch_id, loss)
    # a random model should sit near ln(vocab)
    assert 0.2 * np.log(cfg.vocab_size) < float(loss) \
        < 3.0 * np.log(cfg.vocab_size)
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_steps(arch_id):
    cfg, fns, params = _setup(arch_id)
    B, MAXLEN = 2, 32
    cache = init_p(fns.cache_structure(cfg, B, MAXLEN), jax.random.key(2))
    if cfg.family == "audio":  # cross-KV built from stub frames
        from repro.models import whisper
        frames = jax.random.normal(jax.random.key(3),
                                   (B, cfg.enc_seq, cfg.d_model), jnp.float32)
        enc = whisper.encode(cfg, params, frames)
        cache["cross_kv"] = whisper.build_cross_kv(cfg, params, enc)
    tok = jnp.zeros((B, 1), jnp.int32)
    for step in range(3):
        logits, cache = fns.decode_step(cfg, params, cache, tok)
        assert logits.shape == (B, 1, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(
            logits[..., : cfg.vocab_size].astype(jnp.float32))))
        assert int(cache["len"][0]) == step + 1
        tok = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(
            jnp.int32)


@pytest.mark.parametrize("arch_id", ["tinyllama_1_1b", "mamba2_780m",
                                     "recurrentgemma_2b"])
def test_decode_matches_teacher_forcing(arch_id):
    """Incremental decode must reproduce full-forward logits."""
    cfg, fns, params = _setup(arch_id)
    if cfg.family in ("vlm", "audio"):
        pytest.skip("prefix models validated separately")
    mod_name = "mamba2" if cfg.family == "ssm" else "transformer"
    mod = __import__(f"repro.models.{mod_name}",
                     fromlist=["forward_logits"])
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.key(4), (B, S), 0, cfg.vocab_size)
    full = mod.forward_logits(cfg, params, {"tokens": tokens})
    cache = init_p(fns.cache_structure(cfg, B, S), jax.random.key(5))
    outs = []
    for i in range(S):
        logits, cache = fns.decode_step(cfg, params, cache, tokens[:, i:i+1])
        outs.append(logits[:, 0])
    inc = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(inc[..., : cfg.vocab_size], np.float32),
        np.asarray(full[..., : cfg.vocab_size], np.float32),
        rtol=2e-3, atol=2e-3)


def test_param_counts_match_published_scale():
    """Sanity: analytic parameter counts are in the advertised ballpark."""
    expect = {
        "mamba2_780m": (0.6e9, 1.1e9),
        "dbrx_132b": (115e9, 145e9),
        "llama4_maverick_400b_a17b": (330e9, 460e9),
        "yi_6b": (5e9, 7.5e9),
        "tinyllama_1_1b": (0.9e9, 1.4e9),
        "mistral_nemo_12b": (10e9, 14.5e9),
        "stablelm_1_6b": (1.2e9, 2.1e9),
        "internvl2_2b": (1.5e9, 2.6e9),
        "recurrentgemma_2b": (2e9, 3.5e9),
        "whisper_small": (0.2e9, 0.35e9),
    }
    for arch_id, (lo, hi) in expect.items():
        n = registry.param_count(get_config(arch_id))
        assert lo <= n <= hi, (arch_id, n)


def test_moe_active_params():
    cfg = get_config("dbrx_132b")
    total = registry.param_count(cfg)
    active = registry.active_param_count(cfg)
    assert active < 0.5 * total  # top-4 of 16 experts
    assert active > 0.2 * total
