"""Golden-snapshot gate for the cost-model tables (ISSUE 2 satellite).

The snapshot text is *computed* from the cost formulas, so silent
calibration drift in `repro.core.cost_model` / `repro.core.microkernels`
fails tier-1 here instead of only the benchmark smoke.
"""
from pathlib import Path

from repro.core.paper_tables import TABLE5, golden_snapshot

GOLDEN = Path(__file__).parent / "golden" / "paper_tables.txt"


def test_paper_tables_golden_snapshot():
    assert GOLDEN.read_text() == golden_snapshot(), (
        "cost-model output drifted from tests/golden/paper_tables.txt. "
        "If the change is intentional, regenerate with: PYTHONPATH=src "
        "python -m repro.core.paper_tables > tests/golden/paper_tables.txt")


def test_golden_snapshot_covers_all_table5_rows():
    text = GOLDEN.read_text()
    t5 = text.split("[table5]")[1].split("[table6]")[0]
    lines = [ln for ln in t5.strip().splitlines() if ln.strip()]
    rows = lines[1:]  # drop the column-header remainder
    assert len(rows) == len(TABLE5)


def test_golden_snapshot_covers_all_table6_apps():
    """The workload-IR route's per-app numbers are pinned too (ISSUE 3
    golden-equivalence satellite)."""
    from repro.workloads import workload_names

    text = GOLDEN.read_text()
    t6 = text.split("[table6]")[1].split("[table7]")[0]
    lines = [ln for ln in t6.strip().splitlines() if ln.strip()]
    rows = lines[1:]
    assert [ln.split()[0] for ln in rows] == workload_names("table6")
