"""Differential fuzz of the micro-op executor (ISSUE 4 satellite).

Generates random short programs over the full `pim.microcode` ISA (BS
plane ops, BP word ops, and the physical transposes), runs them through
`pim.executor`, and checks, seeded and deterministically:

* **cycles**: `ExecResult.cycles` equals an *independently tabulated*
  per-op charge sum (the Table-2 contract re-stated here, so drift in
  `microcode.CYCLE_TABLE` fails this file, not just its own users);
* **semantics**: final cells / carry latch / reduction accumulator equal a
  pure-Python bit-level interpreter written against the ISA documentation
  (no jax, no numpy broadcasting -- an intentionally independent oracle).
"""
from __future__ import annotations

import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cost_model import Layout
from repro.pim.executor import execute
from repro.pim.microcode import Op, Program, op_cycles

MASK32 = (1 << 32) - 1


# ---------------------------------------------------------------------------
# Independent cycle table (Table 2 contract, restated)
# ---------------------------------------------------------------------------

def expected_op_cycles(op: Op, width: int) -> int:
    if op.cycles is not None:
        return op.cycles
    fixed = {"row_op": 1, "not": 1, "copy": 1, "const": 0, "setc": 0,
             "fa": 1, "mux": 4, "shift": 0, "col_reduce": 1,
             "wadd": 1, "wsub": 2, "wlogic": 1, "wnot": 1, "wcopy": 1,
             "wconst": 0}
    if op.kind in fixed:
        return fixed[op.kind]
    if op.kind == "wmult":
        return width + 2
    if op.kind == "wshift":
        return op.aux
    if op.kind in ("t_bp2bs", "t_bs2bp"):
        return width + 2
    raise AssertionError(op.kind)


# ---------------------------------------------------------------------------
# Pure-Python reference interpreter (bit lists, no numpy semantics)
# ---------------------------------------------------------------------------

class PyState:
    def __init__(self, cells, cols):
        self.cells = [list(row) for row in cells]  # rows x cols of 0/1
        self.carry = [0] * cols
        self.acc = 0
        self.cols = cols


def _words(state: PyState, r: int, width: int) -> list[int]:
    lanes = state.cols // width
    out = []
    for j in range(lanes):
        v = 0
        for i in range(width):
            v |= state.cells[r][j * width + i] << i
        out.append(v)
    return out


def _put_words(state: PyState, r: int, words: list[int], width: int):
    m = (1 << width) - 1
    row = []
    for v in words:
        v &= m
        row.extend((v >> i) & 1 for i in range(width))
    row.extend([0] * (state.cols - len(row)))  # words_to_row zero-pads
    state.cells[r] = row[:state.cols]


def py_apply(op: Op, st: PyState, width: int) -> None:
    cells, cols = st.cells, st.cols
    if op.kind == "row_op":
        a, b = cells[op.src0], cells[op.src1]
        if op.invert1:
            b = [1 - x for x in b]
        fn = {"and": lambda x, y: x & y, "or": lambda x, y: x | y,
              "nor": lambda x, y: 1 - (x | y),
              "xor": lambda x, y: x ^ y}[op.alu]
        cells[op.dst] = [fn(x, y) for x, y in zip(a, b)]
    elif op.kind == "not":
        cells[op.dst] = [1 - x for x in cells[op.src0]]
    elif op.kind == "copy":
        cells[op.dst] = list(cells[op.src0])
    elif op.kind == "const":
        cells[op.dst] = [int(bool(op.aux))] * cols
    elif op.kind == "setc":
        st.carry = [int(bool(op.aux))] * cols
    elif op.kind == "fa":
        a = cells[op.src0]
        b = cells[op.src1] if op.src1 is not None else [0] * cols
        if op.mask is not None:
            b = [x & y for x, y in zip(b, cells[op.mask])]
        if op.invert1:
            b = [1 - x for x in b]
        s = [x ^ y ^ c for x, y, c in zip(a, b, st.carry)]
        cnew = [(x & y) | (c & (x ^ y))
                for x, y, c in zip(a, b, st.carry)]
        cells[op.dst] = s
        if op.cout is not None:
            cells[op.cout] = list(cnew)
        st.carry = cnew
    elif op.kind == "mux":
        c = cells[op.src0]
        cells[op.dst] = [(t & ci) | (f & (1 - ci)) for ci, t, f in
                         zip(c, cells[op.src1], cells[op.src2])]
    elif op.kind == "shift":
        block = [list(cells[op.src0 + k]) for k in range(op.aux)]
        for k in range(op.aux):
            cells[op.dst + k] = block[k]
    elif op.kind == "col_reduce":
        st.acc = (st.acc + (1 << op.aux) * sum(cells[op.src0])) & MASK32
    elif op.kind == "t_bp2bs":
        lanes = cols // width
        # snapshot: the executor reads the source row functionally, even
        # when it sits inside the destination plane span
        row = list(cells[op.src0])
        for k in range(width):
            for j in range(lanes):
                cells[op.dst + k][j] = row[j * width + k]
    elif op.kind == "t_bs2bp":
        lanes = cols // width
        row = [0] * cols
        for j in range(lanes):
            for k in range(width):
                row[j * width + k] = cells[op.src0 + k][j]
        cells[op.dst] = row
    elif op.kind == "wadd":
        _put_words(st, op.dst, [a + b for a, b in
                                zip(_words(st, op.src0, width),
                                    _words(st, op.src1, width))], width)
    elif op.kind == "wsub":
        _put_words(st, op.dst, [a - b for a, b in
                                zip(_words(st, op.src0, width),
                                    _words(st, op.src1, width))], width)
    elif op.kind == "wmult":
        m = (1 << width) - 1
        a = _words(st, op.src0, width)
        b = _words(st, op.src1, width)
        prods = [x * y for x, y in zip(a, b)]
        _put_words(st, op.dst, [p & m for p in prods], width)
        _put_words(st, op.aux, [(p >> width) & m for p in prods], width)
    elif op.kind == "wlogic":
        m = (1 << width) - 1
        a = _words(st, op.src0, width)
        b = _words(st, op.src1, width)
        if op.invert1:
            b = [~x & m for x in b]
        fn = {"and": lambda x, y: x & y, "or": lambda x, y: x | y,
              "xor": lambda x, y: x ^ y}[op.alu]
        _put_words(st, op.dst, [fn(x, y) for x, y in zip(a, b)], width)
    elif op.kind == "wnot":
        m = (1 << width) - 1
        _put_words(st, op.dst, [~x & m for x in _words(st, op.src0, width)],
                   width)
    elif op.kind == "wcopy":
        _put_words(st, op.dst, _words(st, op.src0, width), width)
    elif op.kind == "wconst":
        lanes = cols // width
        _put_words(st, op.dst, [op.aux] * lanes, width)
    elif op.kind == "wshift":
        m = (1 << width) - 1
        vals = _words(st, op.src0, width)
        k = op.aux
        if k == 0:
            out = vals
        elif op.alu == "l":
            out = [(v << k) & m for v in vals]
        elif op.alu == "rl":
            out = [v >> k for v in vals]
        else:  # ra
            out = []
            for v in vals:
                sign = (v >> (width - 1)) & 1
                fill = (m ^ ((1 << (width - k)) - 1)) if sign else 0
                out.append((v >> k) | fill)
        _put_words(st, op.dst, out, width)
    elif op.kind == "tree_stage":
        vals = _words(st, op.src0, width)
        half = op.aux
        for i in range(half):
            vals[i] = vals[i] + vals[half + i]
        for i in range(half, 2 * half):
            vals[i] = 0
        _put_words(st, op.src0, vals, width)
    else:
        raise AssertionError(op.kind)


def py_run(program: Program, cells) -> PyState:
    st = PyState(cells, len(cells[0]))
    for op in program.ops:
        py_apply(op, st, program.width)
    return st


# ---------------------------------------------------------------------------
# Random program generator (seeded, deterministic)
# ---------------------------------------------------------------------------

ROWS = 28


def random_op(rng: random.Random, width: int, lanes: int) -> Op:
    r = lambda: rng.randrange(ROWS)
    kind = rng.choice([
        "row_op", "not", "copy", "const", "setc", "fa", "mux", "shift",
        "col_reduce", "t_bp2bs", "t_bs2bp",
        "wadd", "wsub", "wmult", "wlogic", "wnot", "wcopy", "wconst",
        "wshift", "tree_stage",
    ])
    if kind == "row_op":
        return Op(kind, dst=r(), src0=r(), src1=r(),
                  alu=rng.choice(["and", "or", "nor", "xor"]),
                  invert1=rng.random() < 0.3)
    if kind in ("not", "copy"):
        return Op(kind, dst=r(), src0=r())
    if kind in ("const", "setc"):
        return Op(kind, dst=r() if kind == "const" else None,
                  aux=rng.randrange(2))
    if kind == "fa":
        return Op(kind, dst=r(), src0=r(),
                  src1=r() if rng.random() < 0.8 else None,
                  mask=r() if rng.random() < 0.3 else None,
                  invert1=rng.random() < 0.3,
                  cout=r() if rng.random() < 0.3 else None)
    if kind == "mux":
        return Op(kind, dst=r(), src0=r(), src1=r(), src2=r())
    if kind == "shift":
        span = rng.randrange(1, 5)
        return Op(kind, dst=rng.randrange(ROWS - span),
                  src0=rng.randrange(ROWS - span), aux=span)
    if kind == "col_reduce":
        return Op(kind, src0=r(), aux=rng.randrange(8))
    if kind == "t_bp2bs":
        return Op(kind, dst=rng.randrange(ROWS - width), src0=r())
    if kind == "t_bs2bp":
        return Op(kind, dst=r(), src0=rng.randrange(ROWS - width))
    if kind in ("wadd", "wsub", "wmult"):
        extra = {"aux": r()} if kind == "wmult" else {}
        return Op(kind, dst=r(), src0=r(), src1=r(), **extra)
    if kind == "wlogic":
        return Op(kind, dst=r(), src0=r(), src1=r(),
                  alu=rng.choice(["and", "or", "xor"]),
                  invert1=rng.random() < 0.3)
    if kind in ("wnot", "wcopy"):
        return Op(kind, dst=r(), src0=r())
    if kind == "wconst":
        return Op(kind, dst=r(), aux=rng.randrange(1 << width))
    if kind == "wshift":
        return Op(kind, dst=r(), src0=r(),
                  alu=rng.choice(["l", "rl", "ra"]),
                  aux=rng.randrange(width))
    if kind == "tree_stage":
        half = rng.choice([h for h in (1, 2) if 2 * h <= lanes])
        return Op(kind, src0=r(), aux=half,
                  cycles=rng.choice([1, 2]))
    raise AssertionError(kind)


def random_program(rng: random.Random, width: int, cols: int) -> Program:
    lanes = cols // width
    n_ops = rng.randrange(1, 25)
    ops = tuple(random_op(rng, width, lanes) for _ in range(n_ops))
    return Program(
        name=f"fuzz_w{width}", layout=Layout.BS, width=width, ops=ops,
        rows=ROWS, inputs=(), outputs=()).validate()


# ---------------------------------------------------------------------------
# The differential tests
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("width,cols", [(8, 32), (16, 40), (8, 28)])
def test_random_programs_match_reference(seed, width, cols):
    """Semantics: executor == pure-Python interpreter; cycles: static
    charge sum == the independent Table-2 tabulation."""
    rng = random.Random(1000 * seed + width + cols)
    prog = random_program(rng, width, cols)
    cells = [[rng.randrange(2) for _ in range(cols)] for _ in range(ROWS)]

    expected = sum(expected_op_cycles(op, width) for op in prog.ops)
    assert prog.cycles == expected
    for op in prog.ops:  # the ISA's own charge fn agrees op-by-op
        assert op_cycles(op, width) == expected_op_cycles(op, width)

    res = execute(prog, jnp.array(np.array(cells), dtype=bool))
    assert res.cycles == expected

    ref = py_run(prog, cells)
    np.testing.assert_array_equal(
        np.asarray(res.array.cells), np.array(ref.cells, dtype=bool))
    np.testing.assert_array_equal(
        np.asarray(res.carry), np.array(ref.carry, dtype=bool))
    assert int(res.acc) == ref.acc


def test_fuzz_covers_every_isa_kind():
    """The generator reaches the full ISA surface (except the explicit
    zero-charge rows already exercised): no silent coverage loss."""
    rng = random.Random(0)
    seen = set()
    for _ in range(400):
        seen.add(random_op(rng, 8, 4).kind)
    from repro.pim.microcode import CYCLE_TABLE

    assert seen == set(CYCLE_TABLE)


def test_builder_programs_match_reference_interpreter():
    """The real Table-5 kernel programs agree with the independent
    interpreter too (not just the random ones)."""
    from repro.pim import programs as pr

    rng = random.Random(7)
    for (name, layout) in sorted(pr.BUILDERS, key=str):
        prog = pr.build(name, layout, width=8)
        # BP word programs need one lane per element (the tree reduction
        # folds prog.n lanes); BS programs take one element per column
        cols = max(32, (prog.n or 1) * prog.width) \
            if layout is Layout.BP else 32
        cells = [[rng.randrange(2) for _ in range(cols)]
                 for _ in range(prog.rows)]
        res = execute(prog, jnp.array(np.array(cells), dtype=bool))
        ref = py_run(prog, cells)
        np.testing.assert_array_equal(
            np.asarray(res.array.cells), np.array(ref.cells, dtype=bool),
            err_msg=f"{name}/{layout.value}")
        assert int(res.acc) == ref.acc, (name, layout)
