"""Use `hypothesis` when installed; otherwise a deterministic fallback.

These tests only need a modest strategy vocabulary (`sampled_from`,
`integers`, `booleans`, `floats`, `lists`, `tuples`, `binary`). When
hypothesis is available (CI installs it via the `test` extra) it is
re-exported untouched; when it is missing (minimal containers) the
fallback draws `settings(max_examples=...)` examples per test from a
per-test seeded PRNG -- reproducible across runs, no external dependency,
no shrinking.
"""
from __future__ import annotations

import random
import zlib

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample  # rng -> value

    class st:  # noqa: N801 -- mirrors `hypothesis.strategies as st`
        @staticmethod
        def sampled_from(values):
            values = list(values)
            return _Strategy(lambda rng: rng.choice(values))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def sample(rng):
                n = rng.randint(min_size, max_size)
                return [elements.sample(rng) for _ in range(n)]
            return _Strategy(sample)

        @staticmethod
        def tuples(*elements):
            return _Strategy(
                lambda rng: tuple(e.sample(rng) for e in elements))

        @staticmethod
        def binary(min_size=0, max_size=10):
            def sample(rng):
                n = rng.randint(min_size, max_size)
                return bytes(rng.randrange(256) for _ in range(n))
            return _Strategy(sample)

    def settings(max_examples=None, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            # NOTE: no functools.wraps -- pytest would follow __wrapped__
            # and mistake the strategy parameters for fixtures.
            def wrapper():
                n = getattr(wrapper, "_max_examples", None) \
                    or _DEFAULT_EXAMPLES
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    args = [s.sample(rng) for s in arg_strats]
                    kwargs = {k: s.sample(rng)
                              for k, s in kw_strats.items()}
                    fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
