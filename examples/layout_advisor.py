"""Layout advisor over the 10 assigned architectures: the paper's
workload-driven framework (Table 8) applied to quantized LM serving.

The per-arch op traces live in the canonical workload IR
(`repro.workloads.arch_workload`); the same workloads are addressable as
`arch/<id>` from the CLI, e.g.

    PYTHONPATH=src python examples/layout_advisor.py [--bits 4]
    PYTHONPATH=src python -m repro characterize arch/tinyllama_1_1b --ops
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.core.advisor import advise_arch
from repro.workloads import arch_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=4,
                    help="quantized weight width")
    args = ap.parse_args()
    print(f"layout verdicts at int{args.bits} weights "
          f"(BS = bitplane kernels, BP = word/MXU kernels):\n")
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        r = advise_arch(cfg, weight_bits=args.bits)
        w = arch_workload(cfg, weight_bits=args.bits)
        dims = {op.name: f"{op.m}x{op.k}x{op.n}@{op.width}b" for op in w.ops}
        print(f"{r['arch']:28s} overall={r['overall']}")
        for op in r["ops"]:
            print(f"   {op['op']:14s} {dims[op['op']]:22s} -> "
                  f"{op['recommendation']:6s} "
                  f"(bp {op['bp_score']:.1f} / bs {op['bs_score']:.1f})")
        print()


if __name__ == "__main__":
    main()
