"""Layout advisor over the 10 assigned architectures: the paper's
workload-driven framework (Table 8) applied to quantized LM serving.

    PYTHONPATH=src python examples/layout_advisor.py [--bits 4]
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.core.advisor import advise_arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bits", type=int, default=4,
                    help="quantized weight width")
    args = ap.parse_args()
    print(f"layout verdicts at int{args.bits} weights "
          f"(BS = bitplane kernels, BP = word/MXU kernels):\n")
    for arch_id in ARCH_IDS:
        r = advise_arch(get_config(arch_id), weight_bits=args.bits)
        print(f"{r['arch']:28s} overall={r['overall']}")
        for op in r["ops"]:
            print(f"   {op['op']:14s} -> {op['recommendation']:6s} "
                  f"(bp {op['bp_score']:.1f} / bs {op['bs_score']:.1f})")
        print()


if __name__ == "__main__":
    main()
