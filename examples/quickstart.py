"""Quickstart: the paper's cost model, planner, and taxonomy in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import Layout
from repro.core.cost_model import vector_add_cost
from repro.core.apps import aes_paper_accounting
from repro.workloads import get_workload
from repro.core.planner import plan
from repro.core.taxonomy import CASE_STUDIES, classify


def main():
    # 1. Cycle-accurate layout comparison (paper Table 4)
    print("== vector add (16-bit) ==")
    for n in (1024, 65536, 262144):
        bp = vector_add_cost(Layout.BP, n).total
        bs = vector_add_cost(Layout.BS, n).total
        print(f"  n={n:7d}: BP {bp:6d} cy | BS {bs:6d} cy | BS/BP {bs/bp:.2f}")

    # 2. Hybrid scheduling (paper Sec. 5.4): AES-128
    p = plan(get_workload("aes").to_phases())
    acc = aes_paper_accounting()
    print("\n== AES-128 ==")
    print(f"  static BP {p.static_bp} cy | static BS {p.static_bs} cy")
    print(f"  paper hand-schedule hybrid: {acc['hybrid']} cy "
          f"({acc['speedup']}x)")
    print(f"  DP planner hybrid:          {p.total_cycles} cy "
          f"({p.hybrid_speedup:.2f}x, {p.n_transposes} transposes)")

    # 3. Workload taxonomy (paper Table 8)
    print("\n== layout recommendations ==")
    for name, feats in CASE_STUDIES.items():
        v = classify(feats)
        print(f"  {name:20s} -> {v.recommendation.value:6s} "
              f"({v.reasons[0] if v.reasons else ''})")


if __name__ == "__main__":
    main()
