"""AES-128 under BP / BS / hybrid layouts: functional bitplane simulation
plus the paper's cycle accounting side by side (paper Sec. 5.4).

The hybrid execution is no longer hand-built only: ``repro.plan`` compiles
the ``aes`` workload into a :class:`LayoutPlan` (arriving in BP, the
paper's setup), the plan's per-op schedule drives the functional
simulation (``pim.aes.encrypt_planned``), and the same plan's
``total_cycles`` is the number the cost model priced -- one plan, priced
and executed.

    PYTHONPATH=src python examples/aes_hybrid_demo.py
"""
import numpy as np

from repro.core.apps import aes_paper_accounting
from repro.core.cost_model import Layout
from repro.plan import compile_plan
from repro.workloads import get_workload
from repro.pim import aes


def main():
    key = np.frombuffer(bytes.fromhex("000102030405060708090a0b0c0d0e0f"),
                        np.uint8).copy()
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8).copy()
    want = "69c4e0d86a7b0430d8cdb78070b4c55a"

    # compile the plan the functional simulation will follow
    workload = get_workload("aes")
    plan = compile_plan(workload, initial_layout=Layout.BP)
    schedule = dict(plan.op_schedule())

    for name, fn in (("BP (word lookup)", aes.encrypt_bp),
                     ("BS (bit-sliced GF inversion)", aes.encrypt_bs),
                     ("hybrid (transpose at SubBytes)", aes.encrypt_hybrid),
                     ("planned (repro.plan schedule)",
                      lambda p, k: aes.encrypt_planned(p, k, schedule))):
        ct = bytes(fn(pt, key)).hex()
        print(f"{name:34s}: {ct}  {'OK' if ct == want else 'MISMATCH'}")

    acc = aes_paper_accounting()
    hand = all((lay == "BS") == op.startswith("SB")
               for op, lay in schedule.items())
    print(f"\nplan: {plan.total_cycles} cycles, "
          f"{plan.n_transposes} transposes "
          f"({plan.transpose_cycles_total} cycles), "
          f"reproduces the Sec.-5.4 hand schedule: {hand}")
    print(f"cycles: BP {acc['BP']} | BS {acc['BS']} | "
          f"hybrid(hand) {acc['hybrid']} | hybrid(plan) {plan.total_cycles}")
    print(f"hybrid speedup over best static: {plan.hybrid_speedup:.2f}x "
          f"(paper: 2.66x)")


if __name__ == "__main__":
    main()
