"""AES-128 under BP / BS / hybrid layouts: functional bitplane simulation
plus the paper's cycle accounting side by side (paper Sec. 5.4).

    PYTHONPATH=src python examples/aes_hybrid_demo.py
"""
import numpy as np

from repro.core.apps import aes_paper_accounting
from repro.workloads import get_workload
from repro.core.planner import plan
from repro.pim import aes


def main():
    key = np.frombuffer(bytes.fromhex("000102030405060708090a0b0c0d0e0f"),
                        np.uint8).copy()
    pt = np.frombuffer(bytes.fromhex("00112233445566778899aabbccddeeff"),
                       np.uint8).copy()
    want = "69c4e0d86a7b0430d8cdb78070b4c55a"
    for name, fn in (("BP (word lookup)", aes.encrypt_bp),
                     ("BS (bit-sliced GF inversion)", aes.encrypt_bs),
                     ("hybrid (transpose at SubBytes)", aes.encrypt_hybrid)):
        ct = bytes(fn(pt, key)).hex()
        print(f"{name:34s}: {ct}  {'OK' if ct == want else 'MISMATCH'}")

    acc = aes_paper_accounting()
    p = plan(get_workload("aes").to_phases())
    print(f"\ncycles: BP {acc['BP']} | BS {acc['BS']} | "
          f"hybrid(hand) {acc['hybrid']} | hybrid(DP) {p.total_cycles}")
    print(f"hybrid speedup over best static: {p.hybrid_speedup:.2f}x "
          f"(paper: 2.66x)")


if __name__ == "__main__":
    main()
