"""Batched serving demo: prefill + greedy decode with the KV cache.

    PYTHONPATH=src python examples/serve_decode.py [--arch tinyllama_1_1b]
"""
import argparse

import jax

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import init_params, registry
from repro.serve.decode import ServeSession


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama_1_1b", choices=ARCH_IDS)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    fns = registry.model_fns(cfg)
    params = init_params(fns.param_structure(cfg), jax.random.key(0))
    sess = ServeSession(cfg, params, max_len=64)
    prompts = [[1, 2, 3, 4], [9, 8, 7], [5]]
    outs = sess.generate(prompts, max_new_tokens=args.new_tokens)
    for p, o in zip(prompts, outs):
        print(f"prompt {p} -> {o[len(p):]}")


if __name__ == "__main__":
    main()
