"""End-to-end training driver: reduced TinyLlama on the synthetic pipeline
with checkpoint/restart. Loss must fall below the uniform baseline ln(V).

    PYTHONPATH=src python examples/train_tinyllama.py [--steps 200]
"""
import argparse
import math
import tempfile

from repro.configs import get_config, reduced_config
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.loop import LoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced_config(get_config("tinyllama_1_1b"))
    data = DataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                      global_batch=8, structure=31)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=20,
                            total_steps=args.steps)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=50, log_every=10)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="tinyllama_ckpt_")
    tr = Trainer(cfg, opt, loop, data, ckpt)
    out = tr.run()
    for m in out["metrics"]:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  lr {m['lr']:.2e}")
    base = math.log(cfg.vocab_size)
    print(f"\nfinal loss {out['loss']:.4f} vs uniform baseline {base:.4f}")
    print(f"stragglers flagged: {out['stragglers']}")
    assert out["loss"] < base, "model failed to beat the uniform baseline"
    print("OK: learned structure in the synthetic stream")


if __name__ == "__main__":
    main()
