"""Device-resident schedule execution: one jitted program per plan.

``run_schedule`` (``plan.pallas``) dispatches a :class:`PallasSchedule`
one step at a time from the host -- a device round-trip, a fresh weight
conversion, and a ``block_until_ready`` per kernel.  A PIM controller
pays none of that: weights are resident in the arrays, step results feed
successors directly, and the host sees one completion.  This module is
that execution model (DESIGN.md Sec. 15):

* :func:`compile_schedule` lowers an entire schedule -- every measured
  step plus its bp2bs/bs2bp repack -- into ONE jitted program.  Weights
  are converted/packed once at *compile* time into a device-resident
  param pytree: BP steps hold words at ``bp_weight_dtype``, BS-resident
  steps hold pre-packed ``[bits, K/32, N]`` planes.  Boundary repacks the
  plan charges stay *in* the program: a ``bp2bs`` step keeps word-form
  params and packs in-flight (through the fused bitpack-matmul when the
  schedule fused it), a ``bs2bp`` step keeps plane-form params and
  unpacks in-flight.
* Step results thread to successor activations along the Workload
  ``deps`` DAG (``kernels.ops.thread_activations``) -- real dataflow, so
  XLA cannot elide or reorder the chain, and synthetic operands exist
  only at entry steps.
* Entry activations are donated (``donate_argnums``): XLA may alias
  intermediates into their buffers.  The executable keeps host copies
  and re-places them on every ``run()``, so re-running is always safe
  and bit-identical.

Per-step ``run_schedule`` stays authoritative as the differential
reference: with the same threading it is bit-exact with the chained
program and with the numpy ``reference_results`` (pinned by
``tests/test_pallas_exec.py``).

Executables are content-addressed (:class:`ExecutableCache`, the
``serve.plan_cache`` sha256 pattern) by canonical schedule dict + kernel
source fingerprint + seed + interpret flag -- in-memory only, because an
executable holds live jitted closures and device buffers.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import statistics
import time
import warnings
from collections import OrderedDict
from typing import Any, Optional

import numpy as np

from repro.core.cost_model import Layout
from repro.plan.pallas import MAX_BS_WIDTH, PallasSchedule, synth_inputs

#: default :class:`ExecutableCache` capacity -- live executables are far
#: heavier than cached plans (jitted closures + device-resident params),
#: but one serve-bench traffic mix lowers to only a few dozen distinct
#: schedules under one execute budget
DEFAULT_CAPACITY = 64


def kernel_fingerprint() -> str:
    """Source fingerprint of the executor and every module that
    determines what a compiled schedule computes.

    The provenance rule of ``serve.plan_cache``: editing any of these
    must miss the executable cache, so the address hashes their source.
    """
    import repro.plan.pallas as pallas_mod
    import repro.plan.pallas_exec as exec_mod
    from repro.kernels import (bitpack, bitparallel_matmul, bitserial_matmul,
                               fused_bitserial_matmul, ops, tiling)
    from repro.util import source_fingerprint

    return source_fingerprint(
        exec_mod, pallas_mod, ops, tiling, bitpack, bitparallel_matmul,
        bitserial_matmul, fused_bitserial_matmul)


def schedule_key(schedule: PallasSchedule, *, seed: int = 0,
                 interpret: bool = True,
                 fingerprint: Optional[str] = None) -> str:
    """Content address of a compiled schedule: sha256 over the canonical
    schedule dict (steps, layouts, dims, repacks, deps, fuse_pack), the
    synth seed, the interpret flag, and the kernel source fingerprint."""
    blob = json.dumps(
        {"schedule": schedule.to_dict(), "seed": seed,
         "interpret": interpret,
         "fingerprint": fingerprint or kernel_fingerprint()},
        sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


@dataclasses.dataclass
class ScheduleExecutable:
    """A :class:`PallasSchedule` compiled to one jitted device program.

    ``compile_us`` charges everything the steady state never pays again:
    operand synthesis, weight conversion/packing into device residency,
    tracing, XLA compilation, and the first (warming) execution.
    ``run()``/``time()`` are the warm path.
    """

    schedule: PallasSchedule
    key: str
    compile_us: float
    n_measured: int
    n_modelled: int
    entry_ops: tuple[str, ...]     #: steps consuming synthetic operands
    threaded: dict                 #: {consumer op: producer op}
    donate: bool
    params_bytes: int              #: device-resident weight footprint
    _fn: Any = dataclasses.field(repr=False)
    _params: Any = dataclasses.field(repr=False)
    _entry: dict = dataclasses.field(repr=False)   #: host entry copies
    runs: int = 0

    def run(self) -> dict:
        """Execute the whole chained program once; returns
        {op: int32 [m, n] numpy result} for every measured step.

        Entry activations are re-placed from host copies each call (the
        program donates its input buffers), so running twice is safe and
        bit-identical -- the donation-regression contract.
        """
        import jax
        import jax.numpy as jnp

        placed = {op: jnp.asarray(v) for op, v in self._entry.items()}
        out = jax.block_until_ready(self._fn(placed, self._params))
        self.runs += 1
        return {op: np.asarray(y) for op, y in out.items()}

    def time(self, reps: int = 5) -> float:
        """Median warm wall-clock (us) of the whole chained program."""
        self.run()  # warm (compile already ran once at build time)
        samples = []
        for _ in range(reps):
            t0 = time.perf_counter()
            self.run()
            samples.append((time.perf_counter() - t0) * 1e6)
        return statistics.median(samples)

    def summary(self) -> dict:
        return {"key": self.key, "workload": self.schedule.workload,
                "compile_us": self.compile_us,
                "n_measured": self.n_measured,
                "n_modelled": self.n_modelled,
                "entry_ops": list(self.entry_ops),
                "threaded": dict(self.threaded),
                "donate": self.donate,
                "params_bytes": self.params_bytes, "runs": self.runs}


def compile_schedule(schedule: PallasSchedule,
                     inputs: Optional[dict] = None, *, seed: int = 0,
                     interpret: bool = True, donate: bool = True,
                     key: Optional[str] = None) -> ScheduleExecutable:
    """Compile ``schedule`` into ONE jitted program (module doc).

    ``inputs``: optional ``{op: (x, w)}`` word-form operands (default:
    :func:`plan.pallas.synth_inputs` with ``seed``).  Weights must be
    canonical ``width``-bit words -- a boundary repack round-trips them
    through the plane form, which truncates any bits above ``width``
    (synthetic operands satisfy this by construction).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.kernels.bitpack import bitpack, bitunpack
    from repro.kernels.bitparallel_matmul import bitparallel_matmul
    from repro.kernels.bitserial_matmul import bitserial_matmul
    from repro.kernels.fused_bitserial_matmul import fused_bitserial_matmul

    t0 = time.perf_counter()
    if inputs is None:
        inputs = synth_inputs(schedule, seed=seed)
    if key is None:
        key = schedule_key(schedule, seed=seed, interpret=interpret)
    producer = schedule.threaded_producers()
    steps = schedule.measured_steps

    def _as_planes(w, width):
        return kops.pack_weights(w.astype(jnp.uint32), width,
                                 interpret=interpret)

    # ---- compile-time residency: convert/pack every weight once ------
    params: dict[str, Any] = {}
    entry: dict[str, np.ndarray] = {}
    for s in steps:
        x, w = inputs[s.op]
        if s.op not in producer:
            entry[s.op] = np.asarray(x)
        w = jnp.asarray(w)
        if s.layout is Layout.BP:
            if s.repack == "bs2bp" and s.width <= MAX_BS_WIDTH:
                # the operand arrives plane-resident; the plan-charged
                # unpack is part of the program, not of compile
                params[s.op] = _as_planes(w, s.width)
            else:
                params[s.op] = w.astype(kops.bp_weight_dtype(s.width))
        elif s.repack == "bp2bs":
            # word-resident: the plan-charged pack runs in-program
            # (folded into the fused kernel when the schedule fused it)
            params[s.op] = w
        else:
            params[s.op] = _as_planes(w, s.width)

    def _bs(x, planes):
        # mirror kops.matmul_bs: bitpack zero-pads K to a multiple of 32
        k_planes = planes.shape[1] * 32
        if x.shape[1] != k_planes:
            x = jnp.pad(x, ((0, 0), (0, k_planes - x.shape[1])))
        return bitserial_matmul(x, planes, interpret=interpret)

    def program(xs, ps):
        out = {}
        for s in steps:
            m, k, _n = s.dims
            src = producer.get(s.op)
            x = (kops.thread_activations(out[src], m, k)
                 if src is not None else xs[s.op])
            w = ps[s.op]
            if s.layout is Layout.BP:
                if s.repack == "bs2bp" and s.width <= MAX_BS_WIDTH:
                    w = bitunpack(w, k).astype(
                        kops.bp_weight_dtype(s.width))
                y = bitparallel_matmul(x, w, interpret=interpret)
            elif s.kernel == "fused_bitserial_matmul":
                y = fused_bitserial_matmul(x, w, s.width,
                                           interpret=interpret)
            elif s.repack == "bp2bs":
                y = _bs(x, bitpack(w.astype(jnp.uint32), s.width,
                                   interpret=interpret))
            else:
                y = _bs(x, w)
            out[s.op] = y
        return out

    fn = jax.jit(program, donate_argnums=(0,) if donate else ())
    # build = trace + lower + compile + first (warming) run; the run
    # consumes the placed entry buffers, which is why run() re-places
    placed = {op: jnp.asarray(v) for op, v in entry.items()}
    with warnings.catch_warnings():
        # donation is best-effort: entries whose dtype/shape matches no
        # output stay undonated, which is fine -- not worth a warning
        # per compiled schedule
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        jax.block_until_ready(fn(placed, params))
    compile_us = (time.perf_counter() - t0) * 1e6

    return ScheduleExecutable(
        schedule=schedule, key=key, compile_us=compile_us,
        n_measured=len(steps),
        n_modelled=len(schedule.steps) - len(steps),
        entry_ops=tuple(entry), threaded=producer, donate=donate,
        params_bytes=sum(int(np.prod(p.shape)) * p.dtype.itemsize
                         for p in params.values()),
        _fn=fn, _params=params, _entry=entry)


class ExecutableCache:
    """In-memory LRU of :class:`ScheduleExecutable`, content-addressed
    by :func:`schedule_key`.

    The serving steady state: every batch group whose representative
    lowers to an identical schedule (same steps, layouts, dims, repacks,
    deps) reuses one compiled program and its device-resident weights.
    Unlike :class:`serve.plan_cache.PlanCache` there is no disk tier --
    an executable holds live jitted closures and device buffers, so the
    cache is per-process by nature; the source fingerprint still
    guarantees an edit to any kernel misses.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 fingerprint: Optional[str] = None) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1 (got {capacity})")
        self.capacity = capacity
        self.fingerprint = fingerprint or kernel_fingerprint()
        self._mem: OrderedDict[str, ScheduleExecutable] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.puts = 0

    def get_or_compile(self, schedule: PallasSchedule,
                       inputs: Optional[dict] = None, *, seed: int = 0,
                       interpret: bool = True, donate: bool = True
                       ) -> tuple[ScheduleExecutable, str, bool]:
        """-> ``(executable, key, hit)``."""
        key = schedule_key(schedule, seed=seed, interpret=interpret,
                           fingerprint=self.fingerprint)
        exe = self._mem.get(key)
        if exe is not None:
            self._mem.move_to_end(key)
            self.hits += 1
            return exe, key, True
        self.misses += 1
        exe = compile_schedule(schedule, inputs, seed=seed,
                               interpret=interpret, donate=donate, key=key)
        self._mem[key] = exe
        self.puts += 1
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1
        return exe, key, False

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"entries": len(self._mem), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0,
                "evictions": self.evictions, "puts": self.puts,
                "fingerprint": self.fingerprint}
