"""Lower a :class:`LayoutPlan` to a measured Pallas kernel schedule.

``plan.lower`` replays a plan on the simulated CSA (micro-op programs);
this module is the *wall-clock* twin (DESIGN.md Sec. 14): the plan's
op-level schedule lowers to a sequence of Pallas kernel launches --
BP steps to the word matmul kernel, BS steps to the bitplane kernel,
layout boundaries to weight *repacks* (``bp2bs`` = bitpack, ``bs2bp`` =
bitunpack) -- so a hybrid plan runs as a measured kernel sequence, not
only as simulator programs.

The lowering contract:

* **Activations always flow in word (BP) form.**  The layout decision
  applies to the *stationary* weights -- exactly the paper's framing,
  where the array-resident operand carries the layout and the streamed
  operand is broadcast bit-parallel on the bitlines.
* **A layout boundary is a weight repack.**  When the plan's op-level
  layout flips BP->BS the incoming word weights are bitpacked (the
  transpose unit's read(M)+core+write(N) pass); BS->BP is a bitunpack.
  With ``fuse_pack=True`` (default) a ``bp2bs`` repack feeding a BS
  matmul is *folded into* the fused kernel -- no plane tensor is ever
  materialized, mirroring how a transpose unit feeds the array directly.
* **Only matmul/conv steps are measured.**  Conv lowers to the same
  im2col GEMV the ``ExecutorBackend`` prices (``(m, k, n) = (op.n,
  op.k, 1)``).  ``kernel``/``movement``/``compute`` ops have no Pallas
  kernel; they appear in the schedule as modelled-only rows so the
  sequence never silently drops plan steps.
* **Results are exact** (int32 wraparound semantics, see
  ``kernels/bitparallel_matmul.py``): ``run_schedule`` output is
  bit-identical to the unfused pack->matmul path and to the pim
  micro-op executor's MAC decomposition of the same op.

Ops whose *padded* MAC volume (times plane passes for BS) exceeds
``max_macs`` are lowered as modelled-only too -- an honest
"too large to time here" note, never a silently clamped measurement.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Optional

import numpy as np

from repro.core.cost_model import Layout
from repro.plan.ir import LayoutPlan

#: kinds that lower to a Pallas matmul launch
_MEASURABLE = ("matmul", "conv")
#: widest weight the BS plane loop supports (uint32 plane words)
MAX_BS_WIDTH = 32
#: default padded-MAC budget per kernel launch (interpret-mode throughput
#: is ~10^8 MAC/s; 2^31 keeps a single launch under ~30 s)
DEFAULT_MAX_MACS = 2 ** 31


@dataclasses.dataclass(frozen=True)
class PallasStep:
    """One op of the lowered schedule: a kernel launch or a modelled row."""

    op: str              #: workload op name
    kind: str            #: IR op kind
    layout: Layout       #: plan-assigned op-level layout
    width: int           #: weight precision (plane passes for BS)
    kernel: Optional[str]    #: Pallas kernel name; None => modelled-only
    repack: Optional[str]    #: ``bp2bs`` | ``bs2bp`` at this boundary
    dims: Optional[tuple[int, int, int]] = None         #: true (m, k, n)
    padded_dims: Optional[tuple[int, int, int]] = None  #: as padded/run
    note: str = ""

    @property
    def measured(self) -> bool:
        return self.kernel is not None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["layout"] = self.layout.value
        d["measured"] = self.measured
        return d


@dataclasses.dataclass(frozen=True)
class PallasSchedule:
    """A plan lowered to an ordered Pallas kernel sequence."""

    workload: str
    steps: tuple[PallasStep, ...]
    fuse_pack: bool
    #: step-index dataflow edges (producer < consumer), copied from
    #: ``Workload.edges()`` at lowering (step i == op i); empty means
    #: "none declared" and falls back to the same linear chain the
    #: Workload IR defaults to
    deps: tuple[tuple[int, int], ...] = ()

    @property
    def measured_steps(self) -> tuple[PallasStep, ...]:
        return tuple(s for s in self.steps if s.measured)

    @property
    def n_repacks(self) -> int:
        return sum(1 for s in self.steps if s.repack)

    def edges(self) -> tuple[tuple[int, int], ...]:
        """Step-index dataflow edges, linear chain when none declared."""
        if self.deps:
            return self.deps
        return tuple((i, i + 1) for i in range(len(self.steps) - 1))

    def threaded_producers(self) -> dict[str, str]:
        """``{consumer op: producer op}`` for every measured step fed by
        an earlier *measured* step along :meth:`edges`.

        The dataflow contract shared by the chained executor
        (``plan.pallas_exec``), per-step :func:`run_schedule`, and the
        numpy :func:`reference_results`: a consumer's activation is its
        nearest measured producer's result through
        ``kernels.ops.thread_activations``.  Steps with no measured
        producer (entry steps, or steps fed only by modelled-only rows --
        there is no computed tensor to thread) consume synthetic
        operands instead.
        """
        measured = {i for i, s in enumerate(self.steps) if s.measured}
        best: dict[int, int] = {}
        for i, j in self.edges():
            if i in measured and j in measured and i < j:
                if best.get(j, -1) < i:
                    best[j] = i
        return {self.steps[j].op: self.steps[i].op
                for j, i in sorted(best.items())}

    def to_dict(self) -> dict:
        return {"workload": self.workload, "fuse_pack": self.fuse_pack,
                "n_repacks": self.n_repacks,
                "deps": [list(e) for e in self.deps],
                "steps": [s.to_dict() for s in self.steps]}


def _op_dims(op) -> tuple[int, int, int]:
    """(m, k, n) of the matmul a measurable op lowers to.

    Conv uses the ExecutorBackend lowering: ``op.n`` im2col output
    elements, each a ``op.k``-deep (taps x C_in) MAC chain -- a GEMV
    ``(op.n, op.k) @ (op.k, 1)``.  The pre-PR-9 ``(op.n, op.k, op.n)``
    mapping squared the output count.
    """
    if op.kind == "matmul":
        return (op.m, op.k, op.n)
    return (op.n, op.k, 1)


def _tiling(layout: Layout, fused: bool, m: int, k: int, n: int):
    from repro.kernels import tiling as tl

    if layout is Layout.BP:
        return tl.bp_tiling(m, k, n)
    return tl.fused_tiling(m, k, n) if fused else tl.bs_tiling(m, k, n)


def lower_plan_pallas(plan: LayoutPlan, workload, *,
                      fuse_pack: bool = True,
                      max_macs: int = DEFAULT_MAX_MACS) -> PallasSchedule:
    """Lower ``plan``'s op-level schedule to a Pallas kernel sequence."""
    current = plan.initial_layout
    steps: list[PallasStep] = []
    for op in workload.ops:
        layout = plan.layout_for(op.name)
        repack = None
        if current is not None and layout is not current:
            repack = "bp2bs" if layout is Layout.BS else "bs2bp"
        current = layout
        if op.kind not in _MEASURABLE:
            steps.append(PallasStep(
                op=op.name, kind=op.kind, layout=layout, width=op.width,
                kernel=None, repack=repack,
                note="modelled only: no Pallas lowering for "
                     f"{op.kind!r} ops (DESIGN.md Sec. 14)"))
            continue
        m, k, n = _op_dims(op)
        if layout is Layout.BS and op.width > MAX_BS_WIDTH:
            steps.append(PallasStep(
                op=op.name, kind=op.kind, layout=layout, width=op.width,
                kernel=None, repack=repack, dims=(m, k, n),
                note=f"unsupported: width {op.width} > {MAX_BS_WIDTH} "
                     "plane passes (uint32 plane words)"))
            continue
        fused = fuse_pack and layout is Layout.BS and repack == "bp2bs"
        t = _tiling(layout, fused, m, k, n)
        planes = op.width if layout is Layout.BS else 1
        if t.padded_macs * planes > max_macs:
            steps.append(PallasStep(
                op=op.name, kind=op.kind, layout=layout, width=op.width,
                kernel=None, repack=repack, dims=(m, k, n),
                padded_dims=t.padded_dims,
                note=f"over budget: {t.padded_macs * planes} padded MACs "
                     f"> max_macs={max_macs} -- not timed"))
            continue
        if layout is Layout.BP:
            kernel = "bitparallel_matmul"
        elif fused:
            kernel = "fused_bitserial_matmul"
        else:
            kernel = "bitserial_matmul"
        steps.append(PallasStep(
            op=op.name, kind=op.kind, layout=layout, width=op.width,
            kernel=kernel, repack=repack, dims=(m, k, n),
            padded_dims=t.padded_dims,
            note="repack folded into fused kernel" if fused else ""))
    return PallasSchedule(workload=workload.name, steps=tuple(steps),
                          fuse_pack=fuse_pack,
                          deps=tuple(workload.edges()))


def synth_inputs(schedule: PallasSchedule, seed: int = 0) -> dict:
    """Random (x, w) operand pairs for every measured step.

    x: int8 activations; w: unsigned ``width``-bit words (int32 storage,
    full uint32 range at width 32 -- see ``util.rand_words``) -- the
    canonical word form both kernels consume.  Threaded steps ignore
    their synthetic x at execution; it is still generated so per-step and
    chained modes share one input pytree.
    """
    from repro.util import rand_words

    rng = np.random.default_rng(seed)
    out = {}
    for s in schedule.measured_steps:
        m, k, n = s.dims
        out[s.op] = (
            rng.integers(-128, 128, (m, k), dtype=np.int8),
            rand_words(rng, s.width, (k, n)),
        )
    return out


def _thread_np(y: np.ndarray, m: int, k: int) -> np.ndarray:
    """numpy twin of ``kernels.ops.thread_activations`` (bit-identical:
    same flatten/tile/truncate/reshape and the same mod-2^8 wrap)."""
    flat = y.reshape(-1)
    need = m * k
    if flat.size < need:
        flat = np.tile(flat, -(-need // flat.size))
    return flat[:need].reshape(m, k).astype(np.int8)


def run_schedule(schedule: PallasSchedule, inputs: dict, *,
                 interpret: bool = True, thread: bool = True) -> dict:
    """Execute every measured step from the host; return
    {op: int32 [m, n] result}.

    ``inputs`` maps op name -> (x, w) with w in word form (see
    :func:`synth_inputs`).  BS steps pack (or fuse the pack of) their
    weights per the schedule; BP steps run the word kernel losslessly.

    ``thread=True`` (default) feeds each step's activation from its
    nearest measured producer along ``schedule.edges()`` via
    ``kernels.ops.thread_activations`` -- the same dataflow the chained
    executor (``plan.pallas_exec``) compiles, making per-step mode its
    bit-exact differential reference (DESIGN.md Sec. 15).
    ``thread=False`` runs every step on its own synthetic operands (the
    per-kernel differential mode the executor-vs-simulator tests use).
    """
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    producer = schedule.threaded_producers() if thread else {}
    results = {}
    for s in schedule.measured_steps:
        x, w = inputs[s.op]
        src = producer.get(s.op)
        if src in results:
            m, k, _ = s.dims
            x = kops.thread_activations(jnp.asarray(results[src]), m, k)
        else:
            x = jnp.asarray(x)
        w = jnp.asarray(w)
        if s.layout is Layout.BP:
            y = kops.matmul_bp(x, w.astype(kops.bp_weight_dtype(s.width)),
                               interpret=interpret)
        elif s.kernel == "fused_bitserial_matmul":
            y = kops.matmul_bs_fused(x, w, s.width, interpret=interpret)
        else:
            planes = kops.pack_weights(w.astype(jnp.uint32), s.width,
                                       interpret=interpret)
            y = kops.matmul_bs(x, planes, interpret=interpret)
        results[s.op] = np.asarray(y)
    return results


def reference_results(schedule: PallasSchedule, inputs: dict, *,
                      thread: bool = True) -> dict:
    """Plain-integer references (int32 wraparound) for every measured
    step, with the same producer->consumer threading as
    :func:`run_schedule` (``thread=False`` for synthetic operands)."""
    producer = schedule.threaded_producers() if thread else {}
    out = {}
    for s in schedule.measured_steps:
        x, w = inputs[s.op]
        src = producer.get(s.op)
        if src in out:
            m, k, _ = s.dims
            x = _thread_np(out[src], m, k)
        out[s.op] = (x.astype(np.int64) @ w.astype(np.int64)).astype(
            np.int32)
    return out


def time_schedule(schedule: PallasSchedule, inputs: dict, *,
                  reps: int = 5, interpret: bool = True) -> list[dict]:
    """Median-of-``reps`` wall-clock per measured step (plus modelled rows).

    Returns one record per schedule step: ``{op, kind, layout, kernel,
    repack, dims, padded_dims, width, us, note}`` -- ``us`` is None for
    modelled-only rows.  One warmup launch per step amortizes tracing.

    Timing is memoized by ``(padded_dims, width, kernel)`` within one
    call: a repeated layer (VGG-style fc0/fc1 at identical shape) would
    otherwise re-trace and re-warm a fresh closure per step for a number
    that is shape-determined anyway.  Memoized rows carry a note naming
    the step they reuse.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    rows = []
    memo: dict[tuple, tuple[float, str]] = {}
    for s in schedule.steps:
        rec = {"op": s.op, "kind": s.kind, "layout": s.layout.value,
               "kernel": s.kernel, "repack": s.repack, "dims": s.dims,
               "padded_dims": s.padded_dims, "width": s.width,
               "us": None, "note": s.note}
        if s.measured:
            memo_key = (s.padded_dims, s.width, s.kernel)
            hit = memo.get(memo_key)
            if hit is not None:
                rec["us"] = hit[0]
                memo_note = (f"timing memoized from {hit[1]} "
                             "(identical padded dims/width/path)")
                rec["note"] = (f"{rec['note']}; {memo_note}"
                               if rec["note"] else memo_note)
                rows.append(rec)
                continue
            x, w = inputs[s.op]
            x = jnp.asarray(x)
            w = jnp.asarray(w)

            if s.layout is Layout.BP:
                wt = w.astype(kops.bp_weight_dtype(s.width))

                def fn(x=x, wt=wt):
                    return kops.matmul_bp(x, wt, interpret=interpret)
            elif s.kernel == "fused_bitserial_matmul":
                def fn(x=x, w=w, bits=s.width):
                    return kops.matmul_bs_fused(x, w, bits,
                                                interpret=interpret)
            else:
                # unfused: the pack pass is part of the measured path --
                # that is exactly the artifact fusion removes
                def fn(x=x, w=w, bits=s.width):
                    planes = kops.pack_weights(w.astype(jnp.uint32), bits,
                                               interpret=interpret)
                    return kops.matmul_bs(x, planes, interpret=interpret)
            jax.block_until_ready(fn())  # warmup: trace + compile
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                ts.append((time.perf_counter() - t0) * 1e6)
            rec["us"] = statistics.median(ts)
            memo[memo_key] = (rec["us"], s.op)
        rows.append(rec)
    return rows
