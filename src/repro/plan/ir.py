"""The layout-plan IR: an executable per-op BP/BS assignment.

A :class:`LayoutPlan` is the compilation target of the planning layer
(``repro.plan.scheduler.compile_plan``): one layout decision per
*schedulable step* of a :class:`repro.workloads.ir.Workload` DAG, with the
transposes required at every layout boundary materialized as explicit
:class:`TransposeStep`s (the paper's Sec.-4.1 read(M)+core+write(N)
accounting -- never an implicit surcharge).  A plan is therefore

* **priceable** -- ``total_cycles`` is the exact DP/min-cut objective, and
  ``static_bp``/``static_bs`` keep the uniform-assignment baselines the
  acceptance bound is stated against (plan <= min static, always);
* **checkable** -- every step carries its per-layout row footprint and the
  geometry-feasibility verdict derived from ``sweep.Geometry`` rows and
  the Table-5 ``live_words`` model (``SystemParams.bs_rows_required``);
* **executable** -- ``repro.plan.lower`` maps kernel steps to their
  ``pim.programs`` micro-op program in the *assigned* layout and replays
  them on the executor, and ``kernels.ops.planned_matmul`` /
  ``models.layers.pim_quantized_linear`` dispatch the Pallas matmuls per
  ``layout_for(op)`` -- the same plan the cost model priced.

Steps vs ops: an op lowers to 1..3 planner phases (``workloads.ir.
op_phases``; matmul/conv split into load/mac/out).  Each phase is one
step -- one layout choice point -- so linear workloads reproduce the
legacy 2-state phase DP bit-for-bit.  ``layout_for`` reports the op-level
layout as the assignment of the op's *dominant* (most expensive) step.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cost_model import Layout
from repro.sweep.grid import Geometry


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One scheduled step: a planner phase with its layout assignment."""

    index: int           #: position in the plan's topological step order
    op: str              #: owning op name (workload.ops[op_index].name)
    op_index: int
    phase: str           #: phase name (e.g. ``gemv.load`` / ``gemv.mac``)
    kind: str            #: owning op kind
    layout: Layout
    bp_cycles: int
    bs_cycles: int
    #: rows the step's live state occupies per layout (transpose feed/drain
    #: granularity AND the row-capacity feasibility footprint)
    rows_bp: int
    rows_bs: int
    bp_feasible: bool = True
    bs_feasible: bool = True

    @property
    def cycles(self) -> int:
        return self.bp_cycles if self.layout is Layout.BP else self.bs_cycles

    @property
    def feasible(self) -> bool:
        """Does the *assigned* layout fit the geometry's rows?"""
        return self.bp_feasible if self.layout is Layout.BP \
            else self.bs_feasible


@dataclasses.dataclass(frozen=True)
class TransposeStep:
    """An explicit layout conversion inserted at a plan boundary."""

    before_step: int     #: step index whose input is transposed
    direction: str       #: ``bp2bs`` | ``bs2bp``
    cycles: int          #: read(rows_src) + core + write(rows_dst)


@dataclasses.dataclass(frozen=True)
class LayoutPlan:
    """A compiled, executable layout assignment for one workload."""

    workload: str
    geometry: Geometry
    steps: tuple[PlanStep, ...]
    transposes: tuple[TransposeStep, ...]
    total_cycles: int
    static_bp: int
    static_bs: int
    initial_layout: Optional[Layout] = None

    # ------------------------------------------------------------- totals
    @property
    def n_transposes(self) -> int:
        return len(self.transposes)

    @property
    def transpose_cycles_total(self) -> int:
        return sum(t.cycles for t in self.transposes)

    @property
    def best_static(self) -> int:
        return min(self.static_bp, self.static_bs)

    @property
    def best_static_layout(self) -> Layout:
        return Layout.BP if self.static_bp <= self.static_bs else Layout.BS

    @property
    def hybrid_speedup(self) -> float:
        return self.best_static / self.total_cycles

    @property
    def schedule(self) -> tuple[Layout, ...]:
        """Per-step layout sequence (the legacy ``Plan.schedule`` shape)."""
        return tuple(s.layout for s in self.steps)

    @property
    def is_hybrid(self) -> bool:
        return len(set(self.schedule)) > 1

    @property
    def feasible(self) -> bool:
        """Every step's assigned layout fits the geometry's rows."""
        return all(s.feasible for s in self.steps)

    @property
    def infeasible_steps(self) -> tuple[PlanStep, ...]:
        return tuple(s for s in self.steps if not s.feasible)

    # ---------------------------------------------------------- op lookup
    def steps_for(self, op: str) -> tuple[PlanStep, ...]:
        return tuple(s for s in self.steps if s.op == op)

    def layout_for(self, op: Optional[str] = None) -> Layout:
        """Op-level layout: the assignment of the op's dominant (most
        expensive) step.  With ``op=None`` the workload must have exactly
        one op (the single-matmul dispatch convenience)."""
        if op is None:
            idxs = {s.op for s in self.steps}
            if len(idxs) != 1:
                raise ValueError(
                    f"plan for {self.workload!r} has {len(idxs)} ops; "
                    "name one (layout_for(op=...))")
            steps = self.steps
        else:
            steps = self.steps_for(op)
            if not steps:
                known = ", ".join(dict.fromkeys(s.op for s in self.steps))
                raise KeyError(f"plan for {self.workload!r} has no op "
                               f"{op!r} (ops: {known})")
        return max(steps, key=lambda s: s.cycles).layout

    def op_schedule(self) -> list[tuple[str, str]]:
        """[(op name, op-level layout value)] in topological order."""
        seen: dict[str, None] = dict.fromkeys(s.op for s in self.steps)
        return [(op, self.layout_for(op).value) for op in seen]

    # ------------------------------------------------------ serialization
    def to_dict(self, include_steps: bool = True) -> dict:
        d = {
            "workload": self.workload,
            "geometry": self.geometry.to_dict(),
            "total_cycles": self.total_cycles,
            "static_bp": self.static_bp,
            "static_bs": self.static_bs,
            "hybrid_speedup": self.hybrid_speedup,
            "is_hybrid": self.is_hybrid,
            "feasible": self.feasible,
            "n_transposes": self.n_transposes,
            "transpose_cycles": self.transpose_cycles_total,
            "initial_layout": (self.initial_layout.value
                               if self.initial_layout else None),
            "op_schedule": self.op_schedule(),
        }
        if include_steps:
            d["steps"] = [
                {"index": s.index, "op": s.op, "op_index": s.op_index,
                 "phase": s.phase, "kind": s.kind,
                 "layout": s.layout.value, "cycles": s.cycles,
                 "bp_cycles": s.bp_cycles, "bs_cycles": s.bs_cycles,
                 "rows_bp": s.rows_bp, "rows_bs": s.rows_bs,
                 "bp_feasible": s.bp_feasible,
                 "bs_feasible": s.bs_feasible,
                 "feasible": s.feasible}
                for s in self.steps]
            d["transposes"] = [dataclasses.asdict(t)
                               for t in self.transposes]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "LayoutPlan":
        """Rebuild a plan from a full ``to_dict(include_steps=True)`` dump
        (the serving plan-cache disk format; round-trip pinned in
        tests/test_serve.py).  Summary-only dumps cannot round-trip."""
        if "steps" not in d:
            raise ValueError(
                f"plan dump for {d.get('workload')!r} has no steps "
                "(serialized with include_steps=False?) -- cannot rebuild")
        steps = tuple(
            PlanStep(index=s["index"], op=s["op"], op_index=s["op_index"],
                     phase=s["phase"], kind=s["kind"],
                     layout=Layout(s["layout"]),
                     bp_cycles=s["bp_cycles"], bs_cycles=s["bs_cycles"],
                     rows_bp=s["rows_bp"], rows_bs=s["rows_bs"],
                     bp_feasible=s["bp_feasible"],
                     bs_feasible=s["bs_feasible"])
            for s in d["steps"])
        transposes = tuple(TransposeStep(**t) for t in d["transposes"])
        init = d.get("initial_layout")
        return cls(
            workload=d["workload"], geometry=Geometry(**d["geometry"]),
            steps=steps, transposes=transposes,
            total_cycles=d["total_cycles"], static_bp=d["static_bp"],
            static_bs=d["static_bs"],
            initial_layout=Layout(init) if init else None)
