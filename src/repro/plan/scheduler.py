"""Compile a Workload DAG into a :class:`LayoutPlan`.

Two exact solvers behind one entry point (:func:`compile_plan`):

* **Linear chains** (every registered workload today) run the 2-state
  Viterbi DP -- the direct generalization of the legacy
  ``core.planner.plan`` loop, with identical iteration order and
  tie-breaking (BP preferred on equal cost), so plans over chains are
  bit-for-bit the legacy schedules (property-pinned in
  tests/test_plan.py).
* **General DAGs** (a workload with explicit ``deps`` edges) run an exact
  s-t min-cut: a 2-label assignment with direction-symmetric boundary
  costs (``transpose_cycles`` charges read+core+write both ways) is a
  binary submodular labeling, so max-flow gives the true optimum --
  verified against a 2^n brute-force oracle in tests/test_plan.py.

Switch-cost model (unchanged from the legacy DP): entering step *v* in a
layout different from its predecessor's charges
``transpose_cycles(v.rows_bp, v.rows_bs, direction)`` -- the *consumer*
step's footprint is what the on-chip transpose unit feeds and drains.
``initial_layout`` charges the same cost at every root step whose
assigned layout differs from the arrival layout.

Geometry feasibility: each step is checked against ``Geometry.rows`` --
Table-5 kernels via the ``live_words`` row model
(``SystemParams.bs_rows_required`` / ``bp_rows_required``), other ops via
their declared ``rows_bp``/``rows_bs`` footprint.  By default the verdict
is *recorded* on the plan (``LayoutPlan.feasible`` and per-step flags;
the cost model already charges explicit spill ops where the paper's
workloads overflow); ``enforce_feasibility=True`` turns it into a hard
constraint -- infeasible layouts are excluded from the search, and
:class:`PlanError` is raised when a step fits in neither layout.
"""
from __future__ import annotations

from collections import deque
from typing import Optional, Sequence

from repro.core.cost_model import Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.core.transpose import transpose_cycles
from repro.plan.ir import LayoutPlan, PlanStep, TransposeStep
from repro.sweep.grid import Geometry


class PlanError(ValueError):
    """No feasible layout assignment exists under the constraints."""


# ---------------------------------------------------------------------------
# Internal node form (one schedulable step before layout assignment)
# ---------------------------------------------------------------------------

class _Node:
    __slots__ = ("bp", "bs", "rows_bp", "rows_bs", "bp_ok", "bs_ok")

    def __init__(self, bp, bs, rows_bp, rows_bs, bp_ok=True, bs_ok=True):
        self.bp, self.bs = int(bp), int(bs)
        self.rows_bp, self.rows_bs = rows_bp, rows_bs
        self.bp_ok, self.bs_ok = bp_ok, bs_ok

    def cost(self, layout: Layout) -> int:
        return self.bp if layout is Layout.BP else self.bs

    def switch_cost(self, sys: SystemParams) -> int:
        # read + core + write; transpose_cycles is direction-symmetric in
        # total, so one weight serves both boundary orientations
        return transpose_cycles(self.rows_bp, self.rows_bs, "bp2bs", sys)


_LAYOUTS = (Layout.BP, Layout.BS)


def _unary(node: _Node, inf: int, enforce: bool) -> tuple[int, int]:
    bp = node.bp if (node.bp_ok or not enforce) else inf
    bs = node.bs if (node.bs_ok or not enforce) else inf
    return bp, bs


# ---------------------------------------------------------------------------
# Chain solver (the legacy 2-state DP, verbatim semantics)
# ---------------------------------------------------------------------------

def _solve_chain(nodes: Sequence[_Node], sys: SystemParams,
                 initial_layout: Optional[Layout],
                 inf: int, enforce: bool) -> list[Layout]:
    first = nodes[0]
    cost = {}
    back: list[dict[Layout, Layout]] = []
    for lay in _LAYOUTS:
        c = _unary(first, inf, enforce)[0 if lay is Layout.BP else 1]
        if initial_layout is not None and initial_layout != lay:
            c += first.switch_cost(sys)
        cost[lay] = c
    for i in range(1, len(nodes)):
        nd = nodes[i]
        u_bp, u_bs = _unary(nd, inf, enforce)
        sw = nd.switch_cost(sys)
        new_cost, back_i = {}, {}
        for lay in _LAYOUTS:
            u = u_bp if lay is Layout.BP else u_bs
            best, best_prev = None, None
            for prev in _LAYOUTS:
                c = cost[prev] + (0 if prev == lay else sw) + u
                if best is None or c < best:
                    best, best_prev = c, prev
            new_cost[lay] = best
            back_i[lay] = best_prev
        cost = new_cost
        back.append(back_i)
    end = min(_LAYOUTS, key=lambda lay: cost[lay])
    sched = [end]
    for back_i in reversed(back):
        sched.append(back_i[sched[-1]])
    sched.reverse()
    return sched


# ---------------------------------------------------------------------------
# DAG solver (exact binary labeling via s-t min-cut / Edmonds-Karp)
# ---------------------------------------------------------------------------

def _solve_dag(nodes: Sequence[_Node], edges: Sequence[tuple[int, int]],
               sys: SystemParams, initial_layout: Optional[Layout],
               inf: int, enforce: bool) -> list[Layout]:
    n = len(nodes)
    s, t = n, n + 1
    cap: list[dict[int, int]] = [dict() for _ in range(n + 2)]

    def add(u, v, c):
        if c <= 0:
            return
        cap[u][v] = cap[u].get(v, 0) + c
        cap[v].setdefault(u, 0)

    has_pred = set(v for _, v in edges)
    for v, nd in enumerate(nodes):
        u_bp, u_bs = _unary(nd, inf, enforce)
        if initial_layout is not None and v not in has_pred:
            # arrival-layout switch folded into the root's unary costs
            sw = nd.switch_cost(sys)
            if initial_layout is Layout.BS:
                u_bp += sw
            else:
                u_bs += sw
        add(s, v, u_bs)   # cut when v labeled BS (v on the sink side)
        add(v, t, u_bp)   # cut when v labeled BP (v on the source side)
    for u, v in edges:
        w = nodes[v].switch_cost(sys)
        add(u, v, w)
        add(v, u, w)

    # Edmonds-Karp: BFS augmenting paths on the residual graph
    while True:
        parent = {s: s}
        q = deque([s])
        while q and t not in parent:
            u = q.popleft()
            for v, c in cap[u].items():
                if c > 0 and v not in parent:
                    parent[v] = u
                    q.append(v)
        if t not in parent:
            break
        # bottleneck along the path
        bott, v = None, t
        while v != s:
            u = parent[v]
            c = cap[u][v]
            bott = c if bott is None else min(bott, c)
            v = u
        v = t
        while v != s:
            u = parent[v]
            cap[u][v] -= bott
            cap[v][u] += bott
            v = u

    # source side of the cut = BP
    seen = {s}
    q = deque([s])
    while q:
        u = q.popleft()
        for v, c in cap[u].items():
            if c > 0 and v not in seen:
                seen.add(v)
                q.append(v)
    return [Layout.BP if v in seen else Layout.BS for v in range(n)]


# ---------------------------------------------------------------------------
# Assembly shared by both solvers
# ---------------------------------------------------------------------------

def _assemble(nodes: Sequence[_Node], labels: Sequence[Layout],
              edges: Sequence[tuple[int, int]], sys: SystemParams,
              initial_layout: Optional[Layout]):
    """(transposes, total, static_bp, static_bs) for a solved labeling."""
    transposes = []
    has_pred = set(v for _, v in edges)
    for v, lay in enumerate(labels):
        if v not in has_pred and initial_layout is not None \
                and lay != initial_layout:
            direction = "bp2bs" if lay is Layout.BS else "bs2bp"
            transposes.append(TransposeStep(
                before_step=v, direction=direction,
                cycles=transpose_cycles(nodes[v].rows_bp, nodes[v].rows_bs,
                                        direction, sys)))
    for u, v in edges:
        if labels[u] != labels[v]:
            direction = "bp2bs" if labels[v] is Layout.BS else "bs2bp"
            transposes.append(TransposeStep(
                before_step=v, direction=direction,
                cycles=transpose_cycles(nodes[v].rows_bp, nodes[v].rows_bs,
                                        direction, sys)))
    transposes.sort(key=lambda tr: tr.before_step)
    total = sum(nd.cost(lay) for nd, lay in zip(nodes, labels)) \
        + sum(tr.cycles for tr in transposes)

    static_bp = sum(nd.bp for nd in nodes)
    static_bs = sum(nd.bs for nd in nodes)
    roots = [v for v in range(len(nodes)) if v not in has_pred]
    if initial_layout is Layout.BS:
        static_bp += sum(nodes[v].switch_cost(sys) for v in roots)
    if initial_layout is Layout.BP:
        static_bs += sum(nodes[v].switch_cost(sys) for v in roots)
    return tuple(transposes), total, static_bp, static_bs


def _solve(nodes, edges, sys, initial_layout, enforce):
    if enforce:
        for i, nd in enumerate(nodes):
            if not (nd.bp_ok or nd.bs_ok):
                raise PlanError(
                    f"step {i} fits the geometry in neither layout "
                    f"(rows_bp={nd.rows_bp}, rows_bs={nd.rows_bs}, "
                    f"array rows={sys.array.rows})")
    # the infeasibility sentinel must exceed ANY genuine assignment cost:
    # every unary plus a boundary switch per edge (a node with in-degree
    # > 1 can be charged its switch cost once per incoming edge) plus the
    # arrival switch at every root
    has_pred = set(v for _, v in edges)
    inf = 1 + sum(nd.bp + nd.bs for nd in nodes) \
        + sum(nodes[v].switch_cost(sys) for _, v in edges) \
        + sum(nd.switch_cost(sys) for v, nd in enumerate(nodes)
              if v not in has_pred)
    is_chain = list(edges) == [(i, i + 1) for i in range(len(nodes) - 1)]
    if is_chain:
        labels = _solve_chain(nodes, sys, initial_layout, inf, enforce)
    else:
        labels = _solve_dag(nodes, edges, sys, initial_layout, inf, enforce)
    if enforce:
        for i, (nd, lay) in enumerate(zip(nodes, labels)):
            ok = nd.bp_ok if lay is Layout.BP else nd.bs_ok
            if not ok:  # unreachable with a correct sentinel; hard guard
                raise PlanError(
                    f"solver assigned step {i} an infeasible layout "
                    f"({lay.value}) under enforce_feasibility")
    return labels


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def solve_phases(phases, sys: SystemParams = PAPER_SYSTEM,
                 initial_layout: Optional[Layout] = None):
    """Chain-solve a legacy ``core.planner.Phase`` list.

    The compatibility route ``core.planner.plan`` shims over; returns
    ``(labels, transposes, total, static_bp, static_bs)``.
    """
    nodes = [_Node(p.bp_cycles, p.bs_cycles, p.rows_bp, p.rows_bs)
             for p in phases]
    edges = [(i, i + 1) for i in range(len(nodes) - 1)]
    labels = _solve(nodes, edges, sys, initial_layout, enforce=False)
    transposes, total, st_bp, st_bs = _assemble(
        nodes, labels, edges, sys, initial_layout)
    return labels, transposes, total, st_bp, st_bs


def _step_feasibility(op, sys: SystemParams) -> tuple[bool, bool]:
    """(bp fits, bs fits) under the geometry's row budget.

    Table-5 kernels use the live-words row model the sweep feasibility
    masks use (DESIGN.md Sec. 9); other op kinds use their declared
    planner footprint.
    """
    if op.kind == "kernel":
        from repro.core.microkernels import MICROKERNELS

        lw = MICROKERNELS[op.kernel].live_words
        return (sys.bp_rows_required(lw) <= sys.array.rows,
                sys.bs_rows_required(lw, op.width) <= sys.array.rows)
    return op.rows_bp <= sys.array.rows, op.rows_bs <= sys.array.rows


def compile_plan(workload, sys: SystemParams = PAPER_SYSTEM, *,
                 geometry: Optional[Geometry] = None,
                 initial_layout: Optional[Layout] = None,
                 enforce_feasibility: bool = False) -> LayoutPlan:
    """Compile a Workload (DAG) into an executable :class:`LayoutPlan`.

    ``geometry`` overrides ``sys`` with ``geometry.system()``; the plan
    records the geometry it was compiled against either way.
    """
    if geometry is not None:
        sys = geometry.system()
    from repro.workloads.ir import op_phases

    nodes: list[_Node] = []
    meta: list[tuple[int, str, str, str, bool, bool]] = []
    edges: list[tuple[int, int]] = []
    op_first: list[int] = []
    op_last: list[int] = []
    for oi, op in enumerate(workload.ops):
        bp_ok, bs_ok = _step_feasibility(op, sys)
        first = len(nodes)
        for ph in op_phases(op, sys):
            meta.append((oi, op.name, ph.name, op.kind, bp_ok, bs_ok))
            nodes.append(_Node(ph.bp_cycles, ph.bs_cycles,
                               ph.rows_bp, ph.rows_bs, bp_ok, bs_ok))
        op_first.append(first)
        op_last.append(len(nodes) - 1)
        # phases within an op are a dependent sub-chain
        edges.extend((i, i + 1) for i in range(first, len(nodes) - 1))
    for a, b in workload.edges():
        edges.append((op_last[a], op_first[b]))
    edges.sort()

    labels = _solve(nodes, edges, sys, initial_layout,
                    enforce=enforce_feasibility)
    transposes, total, st_bp, st_bs = _assemble(
        nodes, labels, edges, sys, initial_layout)

    steps = tuple(
        PlanStep(index=i, op_index=m[0], op=m[1], phase=m[2], kind=m[3],
                 layout=labels[i], bp_cycles=nd.bp, bs_cycles=nd.bs,
                 rows_bp=nd.rows_bp, rows_bs=nd.rows_bs,
                 bp_feasible=m[4], bs_feasible=m[5])
        for i, (nd, m) in enumerate(zip(nodes, meta)))
    return LayoutPlan(
        workload=workload.name, geometry=Geometry.from_system(sys),
        steps=steps, transposes=transposes, total_cycles=total,
        static_bp=st_bp, static_bs=st_bs, initial_layout=initial_layout)
