"""repro.plan: layout plans as a first-class, executable IR.

Public surface (see README.md in this directory and DESIGN.md Sec. 10)::

    from repro.plan import (
        LayoutPlan, PlanStep, TransposeStep,   # the plan IR
        compile_plan, PlanError,               # Workload DAG -> plan
        plan_programs, replay_plan,            # lowering + executor replay
    )

    p = compile_plan(get_workload("aes"))
    p.total_cycles, p.op_schedule(), p.feasible
    replay_plan(p, get_workload("aes"))        # predicted vs executed

    from repro.plan import lower_plan_pallas, run_schedule
    sched = lower_plan_pallas(p, get_workload("aes"))   # measured twin
    run_schedule(sched, synth_inputs(sched))            # per-step mode

    from repro.plan import compile_schedule              # chained mode
    exe = compile_schedule(sched)   # ONE jitted program, weights resident
    exe.run(); exe.time()           # warm steady-state wall-clock

CLI: ``python -m repro plan <workload> [--geometry RxCxA] [--execute]
[--pallas]``.
"""
from repro.plan.ir import (  # noqa: F401
    LayoutPlan,
    PlanStep,
    TransposeStep,
)
from repro.plan.lower import (  # noqa: F401
    plan_programs,
    replay_matches,
    replay_plan,
    step_program,
)
from repro.plan.pallas import (  # noqa: F401
    PallasSchedule,
    PallasStep,
    lower_plan_pallas,
    reference_results,
    run_schedule,
    synth_inputs,
    time_schedule,
)
from repro.plan.pallas_exec import (  # noqa: F401
    ExecutableCache,
    ScheduleExecutable,
    compile_schedule,
    schedule_key,
)
from repro.plan.scheduler import (  # noqa: F401
    PlanError,
    compile_plan,
    solve_phases,
)
