"""Lower a :class:`LayoutPlan` to executable micro-op programs and replay.

The lowering contract (DESIGN.md Sec. 10):

* ``kernel`` steps whose Table-5 kernel has a ``pim.programs`` builder
  lower to the micro-op program of the *assigned* layout
  (:func:`step_program`); replay runs that program functionally on the
  simulated CSA (``pim.executor.execute``) and scales its static cycle
  count by the capacity batches at the op's element count.
* ``matmul``/``conv`` steps lower to the ``multu`` + ``vector_add``
  MAC decomposition (the ``ExecutorBackend`` route) in the assigned
  layout; the decomposition intentionally differs from the analytic
  chunked-tree pricing, so these rows are informational, not differenced.
* ``movement`` / bespoke ``compute`` steps have no micro-op program (bus
  and hand-calibrated phases are modelled analytically only).

``replay_plan`` is the predicted-vs-executed differ: for every
executable kernel op it returns the planner's predicted compute cycles
(the analytic formula at the plan's operating point) next to the
executor-replayed cycles, plus the documented Sec.-8 calibration delta
the pair is *expected* to show.  The acceptance gate (tests/test_plan.py)
asserts ``executed - predicted == expected`` for all 13 executable
Table-5 kernels in whichever layout the plan assigned.
"""
from __future__ import annotations

from typing import Optional

from repro.core.cost_model import Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.plan.ir import LayoutPlan

#: element count used for the functional replay arrays (cycle counts are
#: static per program; batches scale them to the op's real n)
_REPLAY_N = 64


def _kernel_program(kernel: str, layout: Layout, width: int,
                    n: Optional[int]):
    from repro.pim import programs as pr

    if (kernel, layout) not in pr.BUILDERS:
        return None
    n_eff = n if (kernel == "reduction" and layout is Layout.BP) else None
    return pr.build(kernel, layout, width=width, n=n_eff)


def step_program(plan: LayoutPlan, workload, step_index: int):
    """The micro-op program for one plan step (None when not lowerable)."""
    step = plan.steps[step_index]
    op = workload.ops[step.op_index]
    if op.kind != "kernel":
        return None
    return _kernel_program(op.kernel, step.layout, op.width, op.n)


def plan_programs(plan: LayoutPlan, workload) -> list[tuple[int, object]]:
    """All lowerable (step index, Program) pairs, in plan order."""
    out = []
    for i in range(len(plan.steps)):
        prog = step_program(plan, workload, i)
        if prog is not None:
            out.append((i, prog))
    return out


def _batches(layout: Layout, n: int, width: int, sys: SystemParams) -> int:
    return sys.bp_batches(n, width) if layout is Layout.BP \
        else sys.bs_batches(n)


def replay_plan(plan: LayoutPlan, workload,
                sys: SystemParams = PAPER_SYSTEM, *,
                execute: bool = True) -> list[dict]:
    """Replay every executable op of the plan; return per-op records.

    Each record: ``{op, kind, layout, predicted, executed, delta,
    expected_delta, note}`` (cycle totals at the op's element count).
    ``execute=False`` skips the functional array simulation and keeps the
    static program cycle accounting (identical numbers, no jax work).
    """
    from repro.pim import programs as pr

    rows: list[dict] = []
    for op in workload.ops:
        layout = plan.layout_for(op.name)
        if op.kind == "kernel":
            prog = _kernel_program(op.kernel, layout, op.width, op.n)
            if prog is None:
                continue
            if execute:
                from repro.pim.executor import execute as run, init_cells

                # BP tree reduction bakes its element count into the
                # program; everything else replays on a small array
                run(prog, init_cells(prog,
                                     prog.n or min(op.n, _REPLAY_N)))
            batches = _batches(layout, op.n, op.width, sys)
            predicted = pr.analytic_compute(op.kernel, layout, op.width,
                                            n=op.n) * batches
            executed = prog.cycles * batches
            rows.append({
                "op": op.name, "kind": op.kind, "layout": layout.value,
                "predicted": predicted, "executed": executed,
                "delta": executed - predicted,
                "expected_delta": prog.expected_delta * batches,
                "note": prog.calibration_note,
            })
        elif op.kind in ("matmul", "conv"):
            outs = op.m * op.n if op.kind == "matmul" else op.n
            mult = pr.build("multu", layout, width=op.width)
            add = pr.build("vector_add", layout, width=2 * op.width)
            if execute:
                from repro.pim.executor import execute as run, init_cells

                run(mult, init_cells(mult, _REPLAY_N))
            batches = _batches(layout, outs, op.width, sys)
            executed = (op.k * mult.cycles
                        + (op.k - 1) * add.cycles) * batches
            rows.append({
                "op": op.name, "kind": op.kind, "layout": layout.value,
                "predicted": None, "executed": executed,
                "delta": None, "expected_delta": None,
                "note": "MAC decomposition (multu + vector_add); priced "
                        "analytically as a chunked tree -- not differenced",
            })
    return rows


def replay_matches(rows: list[dict]) -> bool:
    """True when every differenced row shows exactly its documented
    Sec.-8 calibration delta."""
    return all(r["delta"] == r["expected_delta"] for r in rows
               if r["predicted"] is not None)
