"""train subpackage of the repro framework."""
