"""Fault-tolerant training loop.

* **checkpoint/restart**: atomic versioned saves every `ckpt_every` steps;
  `run()` resumes from the latest checkpoint (step counter drives the
  deterministic data pipeline, so restarts are bit-identical).
* **preemption-safe**: a `preempt_after` hook (tests inject it) raises
  mid-run; the next `run()` picks up from the last published checkpoint.
* **straggler mitigation**: a per-step timing watchdog flags steps slower
  than `straggler_zscore` sigmas over the trailing window -- at multi-host
  scale this signal drives hot-spare promotion / re-meshing; here it feeds
  the metrics log and the elastic-restore path (restore onto a different
  mesh) is tested directly.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataIterator
from repro.dist.sharding import place_on_mesh, use_mesh
from repro.models import init_params, registry
from repro.models.base import ArchConfig
from repro.optim import adamw
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10
    straggler_window: int = 20
    straggler_zscore: float = 3.0
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ArchConfig, opt: adamw.AdamWConfig,
                 loop: LoopConfig, data: DataConfig, ckpt_dir: str,
                 remat: bool = False, mesh=None):
        self.cfg, self.opt, self.loop, self.data = cfg, opt, loop, data
        self.mesh = mesh  # None => single-device; shard() no-ops off-mesh
        self.ckpt = CheckpointManager(ckpt_dir)
        self.fns = registry.model_fns(cfg)
        self.step_fn = jax.jit(make_train_step(cfg, opt, remat=remat))
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self.metrics_log: list[dict] = []

    # ------------------------------------------------------------ state ----
    def init_state(self):
        structure = self.fns.param_structure(self.cfg)
        params = init_params(structure, jax.random.key(self.loop.seed))
        params = place_on_mesh(params, structure, self.mesh)
        return params, adamw.init_state(params)

    def _restore_or_init(self):
        latest = self.ckpt.latest_step()
        params, opt_state = self.init_state()
        if latest is None:
            return params, opt_state, 0
        tree = {"params": params, "opt": opt_state}
        restored, meta = self.ckpt.restore(tree)
        return restored["params"], restored["opt"], int(meta["step"])

    # ------------------------------------------------------- watchdog ------
    def _watch(self, step: int, dt: float):
        self.step_times.append(dt)
        w = self.step_times[-self.loop.straggler_window:]
        if len(w) >= 5:
            mu = statistics.mean(w[:-1])
            sd = statistics.pstdev(w[:-1]) or 1e-9
            if (dt - mu) / sd > self.loop.straggler_zscore:
                self.stragglers.append(step)

    # ----------------------------------------------------------- run -------
    def run(self, preempt_after: Optional[int] = None) -> dict:
        params, opt_state, start = self._restore_or_init()
        it = DataIterator(self.data, start_step=start)
        last_loss = None
        with use_mesh(self.mesh):
            for step in range(start, self.loop.total_steps):
                batch = {k: jax.numpy.asarray(v)
                         for k, v in next(it).items()}
                t0 = time.perf_counter()
                params, opt_state, metrics = self.step_fn(params, opt_state,
                                                          batch)
                last_loss = float(metrics["loss"])
                self._watch(step, time.perf_counter() - t0)
                if step % self.loop.log_every == 0:
                    self.metrics_log.append(
                        {"step": step, "loss": last_loss,
                         "grad_norm": float(metrics["grad_norm"]),
                         "lr": float(metrics["lr"])})
                done = step + 1
                if done % self.loop.ckpt_every == 0 or \
                        done == self.loop.total_steps:
                    self.ckpt.save(done,
                                   {"params": params, "opt": opt_state},
                                   metadata={"loss": last_loss,
                                             "arch": self.cfg.name})
                if preempt_after is not None and done >= preempt_after:
                    raise InterruptedError(f"preempted at step {done}")
        return {"final_step": self.loop.total_steps, "loss": last_loss,
                "stragglers": self.stragglers, "metrics": self.metrics_log}
