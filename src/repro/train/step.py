"""Train / serve step builders: the functions the launcher jits and the
dry-run lowers.

`make_train_step(cfg)` returns
    step(params, opt_state, batch) -> (params, opt_state, metrics)
with optional gradient accumulation (microbatching) and remat.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import registry
from repro.models.base import ArchConfig
from repro.optim import adamw


def make_loss_fn(cfg: ArchConfig):
    fns = registry.model_fns(cfg)

    def loss_fn(params, batch):
        return fns.forward_train(cfg, params, batch)

    return loss_fn


def make_train_step(cfg: ArchConfig, opt: adamw.AdamWConfig, *,
                    remat: bool = True, microbatches: int = 1):
    from repro import util
    loss_fn = make_loss_fn(cfg)
    util.set_remat(remat)  # per-layer remat inside the block scans

    def step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(i):
                mb = jax.tree.map(
                    lambda x: x.reshape(microbatches, -1, *x.shape[1:])[i],
                    batch)
                return jax.value_and_grad(loss_fn)(params, mb)

            def body(carry, i):
                tot_loss, acc = carry
                l, g = micro(i)
                acc = jax.tree.map(jnp.add, acc, g)
                return (tot_loss + l, acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0), zeros), jnp.arange(microbatches))
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, metrics = adamw.update(opt, grads, opt_state,
                                                  params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return step


def make_prefill_step(cfg: ArchConfig):
    """Prefill: hidden states over the prompt, next-token logits only (full
    [B, S, V] logits are never materialized)."""
    fns = registry.model_fns(cfg)

    def prefill_step(params, batch):
        x = fns.forward_hidden(cfg, params, batch)  # [B, S, D]
        from repro.models.transformer import _logits_fn
        return _logits_fn(cfg, params)(x[:, -1:, :])

    return prefill_step


def make_serve_step(cfg: ArchConfig):
    fns = registry.model_fns(cfg)

    def serve_step(params, cache, tokens):
        """One new token per sequence with the KV/SSM cache: the function
        the decode_* dry-run shapes lower."""
        logits, cache = fns.decode_step(cfg, params, cache, tokens)
        next_tok = jnp.argmax(
            logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step
