"""Versioned bench-artifact envelope: one schema for every committed file.

Every committed bench artifact (``bench-artifacts/characterize.json``,
``plans.json``, ``serve.json``) used to be a bespoke top-level layout;
consumers had to know three shapes.  They now share one envelope::

    {
      "artifact": "<kind>",          # "characterize" | "plans" | "serve"
      "schema_version": 1,           # REPORT_SCHEMA_VERSION
      "generated_by": "python -m repro <cmd>",
      "payload": { ... }             # the kind-specific content
    }

``payload`` entries that describe backend results are
``repro.workloads.Report.to_dict()`` summaries (same version number), so
one reader handles all artifacts: ``read_artifact(path, kind)`` validates
the envelope and returns the payload.

The version is bumped on breaking payload-shape changes; readers refuse
artifacts newer than themselves and accept older ones.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.workloads.backends import REPORT_SCHEMA_VERSION


class ArtifactError(ValueError):
    """Envelope mismatch: wrong kind, missing fields, or a newer schema."""


def envelope(kind: str, payload, generated_by: str = "") -> dict:
    return {
        "artifact": kind,
        "schema_version": REPORT_SCHEMA_VERSION,
        "generated_by": generated_by,
        "payload": payload,
    }


def write_artifact(path: str, kind: str, payload,
                   generated_by: str = "") -> str:
    """Write ``payload`` under the versioned envelope; returns ``path``."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(envelope(kind, payload, generated_by), f, indent=1,
                  sort_keys=True)
        f.write("\n")
    return path


def read_artifact(path: str, kind: Optional[str] = None):
    """Validate the envelope at ``path`` and return its payload.

    ``kind=None`` accepts any artifact kind (the caller can inspect the
    envelope itself via :func:`read_envelope`).
    """
    env = read_envelope(path)
    if kind is not None and env["artifact"] != kind:
        raise ArtifactError(
            f"{path}: artifact kind {env['artifact']!r}, expected {kind!r}")
    return env["payload"]


def read_envelope(path: str) -> dict:
    with open(path) as f:
        env = json.load(f)
    missing = {"artifact", "schema_version", "payload"} - set(env)
    if missing:
        raise ArtifactError(f"{path}: not a bench artifact envelope "
                            f"(missing {sorted(missing)})")
    if env["schema_version"] > REPORT_SCHEMA_VERSION:
        raise ArtifactError(
            f"{path}: schema v{env['schema_version']} is newer than this "
            f"reader (v{REPORT_SCHEMA_VERSION})")
    return env
