"""Simulated serving traffic over the ``arch/<id>`` workload registry.

A :class:`TrafficMix` is the configurable request-population description
serve-bench replays: which architectures are hot (Zipf-weighted by
default, the shape of real multi-tenant serving), which context lengths
arrive, and which weight precisions the quantized deployments use.
Sampling is seeded and deterministic, so a serve-bench run is exactly
reproducible and its cache hit-rate is a function of the mix, not of RNG
drift.

The distinct-plan space of a mix is ``archs x token buckets x precisions``
(each combination lowers to a different workload IR, hence a different
plan-cache key); the request count over that space is what makes the
content-addressed cache pay.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One simulated serving request (a decode step to schedule).

    ``slot`` is the continuous-batching slot the request occupies (the
    cache row ``ServeSession`` would decode it in); it identifies the
    request within a batch group and never enters the plan-cache key --
    plans depend on (arch, tokens, weight_bits) only.
    """

    id: int
    arch: str
    tokens: int
    weight_bits: int
    slot: int = 0

    @property
    def workload_name(self) -> str:
        return f"arch/{self.arch}"


def arch_ids() -> list[str]:
    """The ``arch/<id>`` registry ids (no jax import needed)."""
    from repro.workloads.registry import workload_names

    return [n.split("/", 1)[1] for n in workload_names("arch")]


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """A request-population description: categorical distributions over
    architecture, context length, and weight precision."""

    archs: tuple[str, ...]
    arch_weights: tuple[float, ...]
    token_buckets: tuple[int, ...] = (256, 512, 1024, 2048, 4096)
    token_weights: tuple[float, ...] = (0.35, 0.25, 0.20, 0.15, 0.05)
    weight_bits: tuple[int, ...] = (2, 4, 8, 16)
    bits_weights: tuple[float, ...] = (0.15, 0.55, 0.20, 0.10)
    #: continuous-batching slots per scheduling round
    max_slots: int = 64

    def __post_init__(self):
        for name, vals, w in (("arch", self.archs, self.arch_weights),
                              ("token", self.token_buckets,
                               self.token_weights),
                              ("bits", self.weight_bits,
                               self.bits_weights)):
            if len(vals) != len(w):
                raise ValueError(f"{name}: {len(vals)} values vs "
                                 f"{len(w)} weights")

    @classmethod
    def default(cls, archs: Optional[Sequence[str]] = None) -> "TrafficMix":
        """Zipf-weighted mix over the registered ``arch/<id>`` traces."""
        archs = tuple(archs if archs is not None else arch_ids())
        ranks = np.arange(1, len(archs) + 1, dtype=np.float64)
        w = 1.0 / ranks
        w /= w.sum()
        return cls(archs=archs, arch_weights=tuple(float(x) for x in w))

    @property
    def distinct_plans(self) -> int:
        """Upper bound on distinct plan-cache keys this mix can emit."""
        return (len(self.archs) * len(self.token_buckets)
                * len(self.weight_bits))

    def sample(self, n: int, seed: int = 0) -> list[Request]:
        """``n`` concurrent requests, deterministically from ``seed``."""
        rng = np.random.default_rng(seed)
        ai = rng.choice(len(self.archs), size=n, p=self.arch_weights)
        ti = rng.choice(len(self.token_buckets), size=n,
                        p=self.token_weights)
        bi = rng.choice(len(self.weight_bits), size=n, p=self.bits_weights)
        return [Request(id=i, arch=self.archs[ai[i]],
                        tokens=self.token_buckets[ti[i]],
                        weight_bits=self.weight_bits[bi[i]],
                        slot=i % self.max_slots)
                for i in range(n)]

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)
