"""Batched serving: prefill + greedy decode over the model zoo.

`decode_step` handles S >= 1 token writes, so prefill is just a wide decode
onto an empty cache; generation then proceeds one token per step. The
request batcher pads a set of prompts to a common length and serves them as
one batch (continuous batching at real scale slots new requests into
finished cache rows; the slot logic is the same dynamic-update the cache
already uses).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import place_on_mesh, use_mesh
from repro.models import init_params, registry
from repro.models.base import ArchConfig


@dataclasses.dataclass
class ServeSession:
    cfg: ArchConfig
    params: dict
    max_len: int
    mesh: Optional[jax.sharding.Mesh] = None  # None => single-device

    def __post_init__(self):
        self.fns = registry.model_fns(self.cfg)
        self.params = place_on_mesh(
            self.params, self.fns.param_structure(self.cfg), self.mesh)
        self._decode = jax.jit(
            lambda p, c, t: self.fns.decode_step(self.cfg, p, c, t))

    def _empty_cache(self, batch: int):
        structure = self.fns.cache_structure(self.cfg, batch, self.max_len)
        cache = init_params(structure, jax.random.key(0))
        return place_on_mesh(cache, structure, self.mesh)

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 8) -> list[list[int]]:
        B = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((B, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad
        cache = self._empty_cache(B)
        out = [list(p) for p in prompts]
        with use_mesh(self.mesh):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks))  # prefill
            cur = jnp.argmax(logits[:, -1:, : self.cfg.vocab_size], axis=-1
                             ).astype(jnp.int32)
            for _ in range(max_new_tokens):
                # one device->host transfer for the whole batch per step
                # (a per-request int(cur[i, 0]) would sync B times/step)
                step_toks = np.asarray(cur)[:, 0]
                for o, t in zip(out, step_toks.tolist()):
                    o.append(t)
                logits, cache = self._decode(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1:, : self.cfg.vocab_size],
                                 axis=-1).astype(jnp.int32)
        return out

    def layout_plan(self, *, tokens: Optional[int] = None,
                    weight_bits: int = 4, service=None):
        """The layout plan serving this session's architecture trace.

        Compiles (or fetches from the content-addressed plan cache) the
        ``arch/<id>`` workload at this session's context length via
        ``repro.serve.PlanService`` -- the same plan the serve-bench
        traffic path dispatches.
        """
        from repro.serve.service import PlanService, Request

        if service is None:
            service = PlanService()
        req = Request(id=0, arch=self.cfg.name,
                      tokens=tokens or self.max_len,
                      weight_bits=weight_bits)
        return service.compile(req).plan
