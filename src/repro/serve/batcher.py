"""Continuous batching of decode steps by shared layout phase.

Phase-grouping rule (DESIGN.md Sec. 11): two requests batch together iff
their compiled plans have the *identical per-step layout sequence*
(``CompiledRequest.signature``).  Members of a group are then in the same
layout at every boundary, so each boundary transpose runs **once per
group** on the shared transpose unit -- the batch stages every member's
operands through the same read(M)+core+write(N) pass -- instead of once
per request.  The amortized charge is the widest member's transpose total
(``max``), and the saving is ``sum - max``.

Simulated accounting (exact, host integers):

* ``latency_cycles``  = max member compute + amortized transposes
  (members decode in parallel across the machine's arrays);
* ``machine_cycles``  = sum member compute + amortized transposes
  (the throughput/occupancy charge).

``execute`` additionally runs the same reduction *on device* -- one jitted
call per group, the member axis sharded over ``repro.dist`` data axes
(``shard(cycles, "batch", None)``; a no-op off-mesh) -- and that call's
wall-clock is serve-bench's per-request execute latency.  Device math is
float32 (cycle counts can exceed int32), so artifact cycle totals always
come from the exact host integers.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro.serve.service import CompiledRequest


@dataclasses.dataclass
class BatchGroup:
    """Requests whose plans share one layout-phase signature."""

    signature: tuple[str, ...]
    members: list[CompiledRequest]

    #: wall-clock of the device step (filled by ``execute``)
    execute_us: Optional[float] = None

    @property
    def size(self) -> int:
        return len(self.members)

    # ------------------------------------------------- exact host totals
    def member_compute_cycles(self) -> list[int]:
        """Per-member assigned-layout cycles, transposes excluded."""
        return [sum(s.cycles for s in m.plan.steps) for m in self.members]

    def member_transpose_cycles(self) -> list[int]:
        return [m.plan.transpose_cycles_total for m in self.members]

    @property
    def amortized_transpose_cycles(self) -> int:
        """One shared pass per boundary, sized by the widest member."""
        return max(self.member_transpose_cycles(), default=0)

    @property
    def transpose_cycles_saved(self) -> int:
        tr = self.member_transpose_cycles()
        return sum(tr) - (max(tr) if tr else 0)

    @property
    def latency_cycles(self) -> int:
        return max(self.member_compute_cycles(), default=0) \
            + self.amortized_transpose_cycles

    @property
    def machine_cycles(self) -> int:
        return sum(self.member_compute_cycles()) \
            + self.amortized_transpose_cycles


class PhaseBatcher:
    """Group compiled requests by layout-phase signature and execute each
    group as one batched, mesh-sharded decode step."""

    def __init__(self, max_batch: int = 64, mesh=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
        self.max_batch = max_batch
        self.mesh = mesh

    # ------------------------------------------------------------- group
    def group(self, compiled: Sequence[CompiledRequest]
              ) -> list[BatchGroup]:
        """Stable grouping: arrival order within a group is preserved and
        groups emit in first-arrival order; oversize groups split at
        ``max_batch`` (the continuous-batching slot budget)."""
        by_sig: dict[tuple[str, ...], list[CompiledRequest]] = {}
        for c in compiled:
            by_sig.setdefault(c.signature, []).append(c)
        out = []
        for sig, members in by_sig.items():
            for i in range(0, len(members), self.max_batch):
                out.append(BatchGroup(signature=sig,
                                      members=members[i:i + self.max_batch]))
        return out

    # ----------------------------------------------------------- execute
    def execute(self, group: BatchGroup, warmup: bool = True) -> dict:
        """Run the group's batched decode-step reduction on device and
        record its wall-clock on the group (``execute_us``)."""
        import jax

        from repro.dist.sharding import use_mesh

        step_cycles = np.zeros((group.size, len(group.signature)),
                               np.float32)
        for b, m in enumerate(group.members):
            for s_i, s in enumerate(m.plan.steps):
                step_cycles[b, s_i] = float(s.cycles)
        transposes = np.asarray(group.member_transpose_cycles(), np.float32)
        # pad the member axis to a power of two: bounds the number of
        # retraces AND gives the mesh's data axes an even divisor
        b_pad = 1
        while b_pad < group.size:
            b_pad *= 2
        pad = b_pad - group.size
        if pad:
            step_cycles = np.pad(step_cycles, ((0, pad), (0, 0)))
            transposes = np.pad(transposes, (0, pad))
        mask = np.arange(b_pad) < group.size

        with use_mesh(self.mesh):
            if warmup:  # compile outside the timed window
                jax.block_until_ready(
                    _batched_step(step_cycles, transposes, mask))
            t0 = time.perf_counter()
            latency, machine = jax.block_until_ready(
                _batched_step(step_cycles, transposes, mask))
            group.execute_us = (time.perf_counter() - t0) * 1e6

        return {
            "size": group.size,
            "execute_us": group.execute_us,
            "device_latency_cycles": float(latency),
            "device_machine_cycles": float(machine),
            "latency_cycles": group.latency_cycles,
            "machine_cycles": group.machine_cycles,
            "transpose_cycles_saved": group.transpose_cycles_saved,
        }

    def run(self, compiled: Sequence[CompiledRequest]
            ) -> tuple[list[BatchGroup], list[dict]]:
        groups = self.group(compiled)
        return groups, [self.execute(g) for g in groups]


def _make_batched_step():
    import jax
    import jax.numpy as jnp

    from repro.dist.sharding import shard

    @jax.jit
    def step(step_cycles, transposes, mask):
        step_cycles = shard(step_cycles, "batch", None)
        transposes = shard(transposes, "batch")
        per_member = jnp.where(mask, step_cycles.sum(axis=1), 0.0)
        tr = jnp.where(mask, transposes, 0.0)
        amortized = tr.max()               # one shared pass per boundary
        latency = per_member.max() + amortized
        machine = per_member.sum() + amortized
        return latency, machine

    return step


class _LazyStep:
    """Defer jax import (and jit construction) to first execution."""

    _fn = None

    def __call__(self, *args):
        if _LazyStep._fn is None:
            _LazyStep._fn = _make_batched_step()
        return _LazyStep._fn(*args)


_batched_step = _LazyStep()
