"""Continuous batching of decode steps by shared layout phase.

Phase-grouping rule (DESIGN.md Sec. 11): two requests batch together iff
their compiled plans have the *identical per-step layout sequence*
(``CompiledRequest.signature``).  Members of a group are then in the same
layout at every boundary, so each boundary transpose runs **once per
group** on the shared transpose unit -- the batch stages every member's
operands through the same read(M)+core+write(N) pass -- instead of once
per request.  The amortized charge is the widest member's transpose total
(``max``), and the saving is ``sum - max``.

Simulated accounting (exact, host integers):

* ``latency_cycles``  = max member compute + amortized transposes
  (members decode in parallel across the machine's arrays);
* ``machine_cycles``  = sum member compute + amortized transposes
  (the throughput/occupancy charge).

``execute`` runs each group through the *measured Pallas path*: the
group's representative plan (the member whose schedule measures the most
padded MACs under ``execute_budget``; ties break toward the widest plan,
the group's latency bound) lowers to a
:class:`repro.plan.pallas.PallasSchedule` and compiles to ONE jitted
device program
(``plan.pallas_exec.compile_schedule``; weights device-resident, step
outputs threaded, repacks in-program).  The warm wall-clock of that
program is serve-bench's per-request execute latency; compile cost is
charged separately (``execute_compile_us``, zero on an executable-cache
hit) so the p99 gate sees the steady state.  Until PR 10 this was an
analytic float32 cycle reduction -- a proxy, not the kernels.

Ops the budget refuses (interpret mode is ~10^8 MAC/s; serving shapes
can exceed any honest window) stay modelled-only rows per the DESIGN.md
Sec. 14 contract -- the row reports ``measured_steps``/``modelled_steps``
so the artifact says exactly how much of each plan was run vs modelled.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

from repro.serve.service import CompiledRequest

#: default padded-MAC budget per serve-side kernel launch: admits the
#: short-context attention/classifier matmuls (a warm chained program is
#: tens of ms in interpret mode) while refusing the multi-second
#: long-context GEMMs -- honest refusal, never silent clamping
DEFAULT_EXECUTE_BUDGET = 2 ** 28


@dataclasses.dataclass
class BatchGroup:
    """Requests whose plans share one layout-phase signature."""

    signature: tuple[str, ...]
    members: list[CompiledRequest]

    #: warm wall-clock of the compiled schedule (filled by ``execute``)
    execute_us: Optional[float] = None
    #: executable compile cost (0.0 on an executable-cache hit)
    execute_compile_us: Optional[float] = None

    @property
    def size(self) -> int:
        return len(self.members)

    # ------------------------------------------------- exact host totals
    def member_compute_cycles(self) -> list[int]:
        """Per-member assigned-layout cycles, transposes excluded."""
        return [sum(s.cycles for s in m.plan.steps) for m in self.members]

    def member_transpose_cycles(self) -> list[int]:
        return [m.plan.transpose_cycles_total for m in self.members]

    @property
    def amortized_transpose_cycles(self) -> int:
        """One shared pass per boundary, sized by the widest member."""
        return max(self.member_transpose_cycles(), default=0)

    @property
    def transpose_cycles_saved(self) -> int:
        tr = self.member_transpose_cycles()
        return sum(tr) - (max(tr) if tr else 0)

    @property
    def latency_cycles(self) -> int:
        return max(self.member_compute_cycles(), default=0) \
            + self.amortized_transpose_cycles

    @property
    def machine_cycles(self) -> int:
        return sum(self.member_compute_cycles()) \
            + self.amortized_transpose_cycles


class PhaseBatcher:
    """Group compiled requests by layout-phase signature and execute
    each group as one compiled Pallas schedule (module doc).

    ``executables`` is the content-addressed executable cache shared
    across groups (constructed on demand); ``execute_budget`` is the
    per-launch padded-MAC budget passed to ``lower_plan_pallas``."""

    def __init__(self, max_batch: int = 64,
                 execute_budget: int = DEFAULT_EXECUTE_BUDGET,
                 executables=None, interpret: bool = True, seed: int = 0):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
        self.max_batch = max_batch
        self.execute_budget = execute_budget
        self.interpret = interpret
        self.seed = seed
        self._executables = executables

    @property
    def executables(self):
        if self._executables is None:
            from repro.plan.pallas_exec import ExecutableCache

            self._executables = ExecutableCache()
        return self._executables

    # ------------------------------------------------------------- group
    def group(self, compiled: Sequence[CompiledRequest]
              ) -> list[BatchGroup]:
        """Stable grouping: arrival order within a group is preserved and
        groups emit in first-arrival order; oversize groups split at
        ``max_batch`` (the continuous-batching slot budget)."""
        by_sig: dict[tuple[str, ...], list[CompiledRequest]] = {}
        for c in compiled:
            by_sig.setdefault(c.signature, []).append(c)
        out = []
        for sig, members in by_sig.items():
            for i in range(0, len(members), self.max_batch):
                out.append(BatchGroup(signature=sig,
                                      members=members[i:i + self.max_batch]))
        return out

    # ----------------------------------------------------------- execute
    def execute(self, group: BatchGroup, warmup: bool = True) -> dict:
        """Run the group's representative plan as one compiled Pallas
        schedule; record warm wall-clock + compile cost on the group.

        The representative is the member whose lowered schedule measures
        the MOST padded MACs under ``execute_budget`` -- the heaviest
        program the budget can honestly time (DESIGN.md Sec. 14 refuses
        over-budget steps, so the widest member of a mixed-token group
        usually lowers to all-modelled rows; picking it would "measure"
        an empty program).  Ties break toward the largest planned cycle
        total, the group's latency bound.  Exact cycle totals in the
        returned row still come from the host integers (the simulated
        accounting is layout math, not wall-clock).
        """
        from repro.core.cost_model import Layout
        from repro.plan.pallas import lower_plan_pallas

        def measurable_macs(sched) -> int:
            total = 0
            for s in sched.measured_steps:
                m_p, k_p, n_p = s.padded_dims
                planes = s.width if s.layout is Layout.BS else 1
                total += m_p * k_p * n_p * planes
            return total

        rep, sched, best = None, None, (-1, -1)
        for m in group.members:
            cand_sched = lower_plan_pallas(m.plan, m.workload,
                                           max_macs=self.execute_budget)
            cand = (measurable_macs(cand_sched), m.plan.total_cycles)
            if cand > best:
                rep, sched, best = m, cand_sched, cand
        exe, key, hit = self.executables.get_or_compile(
            sched, seed=self.seed, interpret=self.interpret)
        if warmup:  # steady-state: warm outside the timed window
            exe.run()
        t0 = time.perf_counter()
        exe.run()
        group.execute_us = (time.perf_counter() - t0) * 1e6
        group.execute_compile_us = 0.0 if hit else exe.compile_us

        return {
            "size": group.size,
            "execute_us": group.execute_us,
            "execute_compile_us": group.execute_compile_us,
            "executable_key": key,
            "executable_hit": hit,
            "representative": rep.request.arch,
            "measured_steps": exe.n_measured,
            "modelled_steps": exe.n_modelled,
            "latency_cycles": group.latency_cycles,
            "machine_cycles": group.machine_cycles,
            "transpose_cycles_saved": group.transpose_cycles_saved,
        }

    def run(self, compiled: Sequence[CompiledRequest]
            ) -> tuple[list[BatchGroup], list[dict]]:
        groups = self.group(compiled)
        return groups, [self.execute(g) for g in groups]
