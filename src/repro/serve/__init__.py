"""repro.serve: layout-aware serving (plans, cache, batching, decode).

Public surface (see README.md in this directory and DESIGN.md Sec. 11)::

    from repro.serve import (
        ServeSession,                    # model-zoo prefill+decode
        Request, TrafficMix,             # simulated serving traffic
        PlanService, CompiledRequest,    # per-request plan compilation
        PlanCache, plan_key,             # content-addressed plan cache
        PhaseBatcher, BatchGroup,        # phase-grouped continuous batching
        run_serve_bench,                 # the serve-bench scenario
    )

CLI: ``python -m repro serve-bench [--quick]`` replays the arch traffic
mix and commits ``bench-artifacts/serve.json``.

``ServeSession`` (the jax model-zoo decoder) imports jax at module load;
it is exposed lazily so the plan/cache/traffic layers stay importable on
the analytic-only stack.
"""
from repro.serve.batcher import BatchGroup, PhaseBatcher  # noqa: F401
from repro.serve.bench import check_regression, run_serve_bench  # noqa: F401
from repro.serve.plan_cache import (  # noqa: F401
    PlanCache,
    plan_key,
    scheduler_fingerprint,
)
from repro.serve.service import CompiledRequest, PlanService  # noqa: F401
from repro.serve.traffic import Request, TrafficMix, arch_ids  # noqa: F401


def __getattr__(name):
    if name == "ServeSession":
        from repro.serve.decode import ServeSession

        return ServeSession
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
