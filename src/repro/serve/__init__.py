"""serve subpackage of the repro framework."""
