"""Per-request plan compilation: the serving-path plan compiler.

``PlanService`` is the machine-level scheduling substrate of the serving
path: every incoming request (architecture, context length, weight
precision -- :class:`repro.serve.traffic.Request`) lowers to its
``arch/<id>`` workload IR at the request's operating point and compiles to
an executable :class:`~repro.plan.ir.LayoutPlan`, through the
content-addressed :class:`~repro.serve.plan_cache.PlanCache` so identical
operating points compile once per fingerprint, not once per request.

The planner itself is resolved through the one backend factory
(``repro.workloads.get_backend("planner")``) -- the serving path
constructs no backend classes directly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.core.cost_model import Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.plan.ir import LayoutPlan
from repro.serve.plan_cache import PlanCache
from repro.serve.traffic import Request
from repro.workloads.ir import Workload


@dataclasses.dataclass(frozen=True)
class CompiledRequest:
    """A request with its compiled (or cache-served) layout plan."""

    request: Request
    workload: Workload
    plan: LayoutPlan
    key: str              #: content address (plan-cache key)
    cache_hit: bool
    compile_us: float     #: wall-clock of lower+hash+lookup(+compile)

    @property
    def signature(self) -> tuple[str, ...]:
        """The plan's layout-phase sequence -- the batcher's grouping key
        (requests sharing it execute as one batched decode step)."""
        return tuple(lay.value for lay in self.plan.schedule)


class PlanService:
    """Compile a layout plan per request, content-addressed-cached.

    ``backend`` is a registry name resolved via the
    ``repro.workloads.get_backend`` factory; it must expose
    ``compile(workload, sys) -> LayoutPlan`` (the planner backend does).

    ``initial_layout`` is the layout request operands arrive in.  Serving
    traffic lands bit-parallel (row-major DRAM order), so the default
    "BP" charges the arrival transpose whenever the plan's first phase is
    BS -- which is what the phase batcher amortizes across a group.  It
    is part of the plan-cache key.

    ``trace=True`` lowers requests through the jaxpr tracer
    (``models.registry.traced_workload`` -- the real forward pass as a
    DAG) instead of the hand-written ``arch_workload`` formulas.  Traced
    workloads are memoized per operating point: tracing costs ~100ms
    while the formula build is microseconds, and the content-addressed
    plan cache keys on the workload either way.
    """

    def __init__(self, sys: SystemParams = PAPER_SYSTEM, *,
                 cache: Optional[PlanCache] = None,
                 cache_dir: Optional[str] = None, persist: bool = True,
                 backend: str = "planner",
                 initial_layout: Optional[str] = "BP",
                 trace: bool = False, **backend_opts):
        from repro.workloads import get_backend

        self.sys = sys
        self.initial_layout = initial_layout
        self.trace = trace
        self._traced: dict[tuple, Workload] = {}
        self.planner = get_backend(backend, **backend_opts)
        if not hasattr(self.planner, "compile"):
            raise TypeError(
                f"backend {backend!r} cannot compile plans "
                "(needs a .compile(workload, sys) -> LayoutPlan)")
        self.cache = cache if cache is not None else PlanCache(
            cache_dir=cache_dir, persist=persist)

    # ------------------------------------------------------------ lowering
    def workload_for(self, request: Request) -> Workload:
        """Lower the request to its workload IR at the request's operating
        point (context length + weight precision)."""
        from repro.configs import get_config

        if self.trace:
            from repro.models.registry import traced_workload

            key = (request.arch, request.tokens, request.weight_bits)
            if key not in self._traced:
                self._traced[key] = traced_workload(
                    get_config(request.arch), tokens=request.tokens,
                    weight_bits=request.weight_bits)
            return self._traced[key]
        from repro.workloads.registry import arch_workload

        return arch_workload(get_config(request.arch),
                             tokens=request.tokens,
                             weight_bits=request.weight_bits)

    # ------------------------------------------------------------- compile
    def compile(self, request: Request) -> CompiledRequest:
        """Lower + (cache-lookup or compile) one request; the measured
        ``compile_us`` is the full per-request plan-service latency."""
        t0 = time.perf_counter()
        w = self.workload_for(request)
        init = (Layout(self.initial_layout)
                if self.initial_layout is not None else None)
        plan, key, hit = self.cache.get_or_compile(
            w, self.sys,
            lambda: self.planner.compile(w, self.sys, initial_layout=init),
            provenance={"arch": request.arch, "tokens": request.tokens,
                        "weight_bits": request.weight_bits,
                        "initial_layout": self.initial_layout},
            initial_layout=self.initial_layout)
        us = (time.perf_counter() - t0) * 1e6
        return CompiledRequest(request=request, workload=w, plan=plan,
                               key=key, cache_hit=hit, compile_us=us)

    def compile_many(self, requests) -> list[CompiledRequest]:
        return [self.compile(r) for r in requests]

    # ------------------------------------------------------------- machine
    def compile_machine(self, request: Request, *, geometry=None,
                        n_parts: Optional[int] = None):
        """Compile the request into a machine-level
        :class:`~repro.machine.ir.MachineSchedule` (the whole-machine
        layer above the per-request LayoutPlan).

        Every per-class plan compiles through the content-addressed plan
        cache -- partition classes repeat across requests sharing an
        operating point, so a hot serving mix compiles each shard shape
        once per fingerprint.  Returns the schedule; its per-class plans
        are genuine planner products, so the batcher's phase signatures
        keep working on ``schedule.classes[i].plan``.
        """
        from repro.machine.partition import plan_machine
        from repro.sweep.grid import Geometry

        geo = geometry or Geometry.from_system(self.sys)
        w = self.workload_for(request)

        def cached_compile(wl, sys, *, initial_layout=None,
                           enforce_feasibility=False):
            init = (Layout(initial_layout)
                    if isinstance(initial_layout, str) else initial_layout)
            plan, _key, _hit = self.cache.get_or_compile(
                wl, sys,
                lambda: self.planner.compile(wl, sys, initial_layout=init),
                provenance={"arch": request.arch,
                            "tokens": request.tokens,
                            "weight_bits": request.weight_bits,
                            "machine": geo.label()},
                initial_layout=(init.value if init is not None else None))
            return plan

        init = (Layout(self.initial_layout)
                if self.initial_layout is not None else None)
        return plan_machine(w, geo, n_parts, initial_layout=init,
                            compile_fn=cached_compile)
