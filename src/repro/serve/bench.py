"""serve-bench: replay an arch traffic mix through the plan-serving path.

One run = sample ``n`` concurrent requests from a :class:`TrafficMix`,
compile each through :class:`PlanService` (content-addressed plan cache),
group the compiled decode steps with :class:`PhaseBatcher`, and execute
every group as ONE compiled Pallas schedule
(``plan.pallas_exec.compile_schedule``) -- so the artifact's execute
latencies are measured kernel wall-clock, not the pre-PR-10 analytic
float32 reduction.  The result dict -- p50/p99 plan-compile latency,
*warm* execute latency and executable-compile cost (split so the p99
gate sees the steady state), cache counters for both the plan cache and
the executable cache, batching and simulated-cycle totals -- is
committed to ``bench-artifacts/serve.json`` under the versioned artifact
envelope and gated in CI (p99 warm execute, regression budget + floor).

``python -m repro serve-bench [--quick]`` is the CLI entry.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.serve.batcher import DEFAULT_EXECUTE_BUDGET, PhaseBatcher
from repro.serve.plan_cache import PlanCache
from repro.serve.service import PlanService
from repro.serve.traffic import TrafficMix


def _percentiles(us: Sequence[float]) -> dict:
    if not us:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    arr = np.asarray(us, np.float64)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()), "max": float(arr.max())}


def run_serve_bench(n_requests: int = 2048, *, seed: int = 0,
                    mix: Optional[TrafficMix] = None,
                    sys: SystemParams = PAPER_SYSTEM,
                    cache: Optional[PlanCache] = None,
                    cache_dir: Optional[str] = None, persist: bool = True,
                    max_batch: int = 64,
                    execute_budget: int = DEFAULT_EXECUTE_BUDGET) -> dict:
    """Replay the traffic mix; returns the serve.json payload dict.

    ``execute_budget`` is the per-launch padded-MAC budget for the Pallas
    execute path (``PhaseBatcher.execute``); plans whose steps exceed it
    run as modelled-only rows, counted in ``executables`` below.
    """
    mix = mix or TrafficMix.default()
    service = PlanService(sys, cache=cache, cache_dir=cache_dir,
                          persist=persist)
    batcher = PhaseBatcher(max_batch=max_batch,
                           execute_budget=execute_budget, seed=seed)

    t0 = time.perf_counter()
    requests = mix.sample(n_requests, seed=seed)
    compiled = service.compile_many(requests)
    compile_done = time.perf_counter()
    groups, rows = batcher.run(compiled)
    elapsed = time.perf_counter() - t0

    # per-request latency = its group's compiled-schedule wall-clock
    # (warm) / executable-compile cost (0 on an executable-cache hit)
    execute_us = [g.execute_us for g in groups for _ in g.members]
    execute_compile_us = [g.execute_compile_us for g in groups
                          for _ in g.members]
    compile_us = [c.compile_us for c in compiled]
    sizes = [g.size for g in groups]
    stats = service.cache.stats()

    return {
        "requests": n_requests,
        "seed": seed,
        "mix": mix.to_dict(),
        "distinct_plans_bound": mix.distinct_plans,
        "geometry": _geometry_dict(service.sys),
        "plan_compile_us": _percentiles(compile_us),
        "execute_us": _percentiles(execute_us),
        "execute_compile_us": _percentiles(execute_compile_us),
        "compile_phase_s": compile_done - t0,
        "elapsed_s": elapsed,
        "throughput_rps": n_requests / elapsed if elapsed else 0.0,
        "cache": stats,
        "executables": {
            **batcher.executables.stats(),
            "execute_budget": execute_budget,
            "measured_steps": sum(r["measured_steps"] for r in rows),
            "modelled_steps": sum(r["modelled_steps"] for r in rows),
        },
        "batches": {
            "count": len(groups),
            "signatures": len({g.signature for g in groups}),
            "mean_size": float(np.mean(sizes)) if sizes else 0.0,
            "max_size": max(sizes, default=0),
        },
        "simulated": {
            "machine_cycles": sum(r["machine_cycles"] for r in rows),
            "latency_cycles_max": max(
                (r["latency_cycles"] for r in rows), default=0),
            "transpose_cycles_saved": sum(
                r["transpose_cycles_saved"] for r in rows),
            "hybrid_plans": sum(1 for c in compiled if c.plan.is_hybrid),
        },
    }


def _geometry_dict(sys: SystemParams) -> dict:
    from repro.sweep.grid import Geometry

    return Geometry.from_system(sys).to_dict()


def check_regression(payload: dict, baseline_payload: dict,
                     threshold: float = 0.25,
                     metric: str = "execute_us", floor_us: float = 250.0
                     ) -> tuple[bool, str]:
    """CI gate: ``(ok, message)``; fails when the new p99 of ``metric``
    exceeds the committed baseline by more than ``threshold``.

    ``floor_us`` clamps the baseline: a committed p99 of ~70us doubling
    under shared-runner jitter is noise, not a regression, so p99s under
    ``floor_us * (1 + threshold)`` always pass and the gate targets
    systematic multi-x regressions (per-request execution creeping back,
    a plan blow-up in the batched step).
    """
    new = payload[metric]["p99"]
    old = baseline_payload[metric]["p99"]
    ref = max(old, floor_us)
    ratio = new / ref if ref else 0.0
    msg = (f"p99 {metric}: {new:.1f}us vs baseline {old:.1f}us "
           f"(x{ratio:.2f}, budget x{1 + threshold:.2f})")
    return ratio <= 1.0 + threshold, msg
