"""serve-bench: replay an arch traffic mix through the plan-serving path.

One run = sample ``n`` concurrent requests from a :class:`TrafficMix`,
compile each through :class:`PlanService` (content-addressed plan cache),
group the compiled decode steps with :class:`PhaseBatcher`, and execute
every group as one mesh-sharded batched step.  The result dict -- p50/p99
plan-compile and execute latencies, cache hit/miss/eviction counters,
batching and simulated-cycle totals -- is committed to
``bench-artifacts/serve.json`` under the versioned artifact envelope and
gated in CI (p99 execute latency, >25% regression budget).

``python -m repro serve-bench [--quick]`` is the CLI entry.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence

import numpy as np

from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.serve.batcher import PhaseBatcher
from repro.serve.plan_cache import PlanCache
from repro.serve.service import PlanService
from repro.serve.traffic import TrafficMix


def _percentiles(us: Sequence[float]) -> dict:
    if not us:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    arr = np.asarray(us, np.float64)
    return {"p50": float(np.percentile(arr, 50)),
            "p99": float(np.percentile(arr, 99)),
            "mean": float(arr.mean()), "max": float(arr.max())}


def default_mesh():
    """A 1-D ``("data",)`` mesh over every local device, or None on a
    single device (``shard`` degrades to a no-op either way)."""
    import jax

    devs = jax.devices()
    if len(devs) < 2:
        return None
    from jax.sharding import Mesh

    return Mesh(np.array(devs), ("data",))


def run_serve_bench(n_requests: int = 2048, *, seed: int = 0,
                    mix: Optional[TrafficMix] = None,
                    sys: SystemParams = PAPER_SYSTEM,
                    cache: Optional[PlanCache] = None,
                    cache_dir: Optional[str] = None, persist: bool = True,
                    max_batch: int = 64, mesh=None,
                    use_mesh_if_available: bool = True) -> dict:
    """Replay the traffic mix; returns the serve.json payload dict."""
    mix = mix or TrafficMix.default()
    service = PlanService(sys, cache=cache, cache_dir=cache_dir,
                          persist=persist)
    if mesh is None and use_mesh_if_available:
        mesh = default_mesh()
    batcher = PhaseBatcher(max_batch=max_batch, mesh=mesh)

    t0 = time.perf_counter()
    requests = mix.sample(n_requests, seed=seed)
    compiled = service.compile_many(requests)
    compile_done = time.perf_counter()
    groups, rows = batcher.run(compiled)
    elapsed = time.perf_counter() - t0

    # per-request execute latency = its group's batched-step wall-clock
    execute_us = [g.execute_us for g in groups for _ in g.members]
    compile_us = [c.compile_us for c in compiled]
    sizes = [g.size for g in groups]
    stats = service.cache.stats()

    return {
        "requests": n_requests,
        "seed": seed,
        "mix": mix.to_dict(),
        "distinct_plans_bound": mix.distinct_plans,
        "geometry": _geometry_dict(service.sys),
        "mesh_devices": int(np.prod(mesh.devices.shape)) if mesh else 1,
        "plan_compile_us": _percentiles(compile_us),
        "execute_us": _percentiles(execute_us),
        "compile_phase_s": compile_done - t0,
        "elapsed_s": elapsed,
        "throughput_rps": n_requests / elapsed if elapsed else 0.0,
        "cache": stats,
        "batches": {
            "count": len(groups),
            "signatures": len({g.signature for g in groups}),
            "mean_size": float(np.mean(sizes)) if sizes else 0.0,
            "max_size": max(sizes, default=0),
        },
        "simulated": {
            "machine_cycles": sum(r["machine_cycles"] for r in rows),
            "latency_cycles_max": max(
                (r["latency_cycles"] for r in rows), default=0),
            "transpose_cycles_saved": sum(
                r["transpose_cycles_saved"] for r in rows),
            "hybrid_plans": sum(1 for c in compiled if c.plan.is_hybrid),
        },
    }


def _geometry_dict(sys: SystemParams) -> dict:
    from repro.sweep.grid import Geometry

    return Geometry.from_system(sys).to_dict()


def check_regression(payload: dict, baseline_payload: dict,
                     threshold: float = 0.25,
                     metric: str = "execute_us", floor_us: float = 250.0
                     ) -> tuple[bool, str]:
    """CI gate: ``(ok, message)``; fails when the new p99 of ``metric``
    exceeds the committed baseline by more than ``threshold``.

    ``floor_us`` clamps the baseline: a committed p99 of ~70us doubling
    under shared-runner jitter is noise, not a regression, so p99s under
    ``floor_us * (1 + threshold)`` always pass and the gate targets
    systematic multi-x regressions (per-request execution creeping back,
    a plan blow-up in the batched step).
    """
    new = payload[metric]["p99"]
    old = baseline_payload[metric]["p99"]
    ref = max(old, floor_us)
    ratio = new / ref if ref else 0.0
    msg = (f"p99 {metric}: {new:.1f}us vs baseline {old:.1f}us "
           f"(x{ratio:.2f}, budget x{1 + threshold:.2f})")
    return ratio <= 1.0 + threshold, msg
