"""Content-addressed compiled-plan cache for the serving path.

The sweep engine already caches whole design-space surfaces under
``sha256(spec + model-source)`` (``repro.sweep.grid``); serving needs the
same idea at per-request granularity: thousands of requests over the
``arch/<id>`` traffic mix resolve to a few hundred distinct
``(workload IR, geometry)`` points, so the plan compiler must run once per
point, not once per request.

Key contract (DESIGN.md Sec. 11)::

    key = sha256( canonical-JSON(workload.to_dict())
                + canonical-JSON(geometry.to_dict())
                + scheduler-source fingerprint )[:24]

The fingerprint hashes the *source* of ``repro.plan.scheduler`` and
``repro.core.cost_model`` -- edit either and every cached plan misses
(stale plans can never be served), exactly like the sweep cache's
model fingerprint.

Two tiers behind one `get`:

* in-memory LRU (``capacity`` entries; eviction counter) -- the steady
  state at serving rates;
* content-addressed disk entries (``<dir>/<key>.json``) holding the full
  ``LayoutPlan.to_dict()`` plus provenance (workload name, geometry label,
  fingerprint, creation time) -- what makes a *second* ``serve-bench``
  process start >=90% warm.

Counters (``hits`` = ``mem_hits`` + ``disk_hits``, ``misses``,
``evictions``, ``puts``) feed the ``serve.json`` artifact.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import time
from typing import Callable, Optional

from repro.core.params import SystemParams
from repro.plan.ir import LayoutPlan
from repro.sweep.grid import Geometry
from repro.workloads.ir import Workload


def default_cache_dir() -> str:
    return os.path.join(
        os.environ.get("REPRO_BENCH_ARTIFACT_DIR", "bench-artifacts"),
        "plan-cache")


def scheduler_fingerprint() -> str:
    """Source fingerprint of everything that determines a compiled plan:
    the scheduler (solvers + assembly) and the cost model its node
    weights come from.  Any edit invalidates every cached plan.  Shares
    ``util.source_fingerprint`` with the executable cache
    (``plan.pallas_exec.kernel_fingerprint``)."""
    from repro.core import cost_model
    from repro.plan import scheduler
    from repro.util import source_fingerprint

    return source_fingerprint(scheduler, cost_model)


def plan_key(workload: Workload, sys: SystemParams,
             fingerprint: Optional[str] = None,
             initial_layout: Optional[str] = None) -> str:
    """The content address of ``compile_plan(workload, sys,
    initial_layout=...)``; ``initial_layout`` is the layout the operands
    arrive in ("BP"/"BS"/None) and changes the compiled plan, so it is
    part of the address."""
    blob = json.dumps(
        {"workload": workload.to_dict(),
         "geometry": Geometry.from_system(sys).to_dict(),
         "initial_layout": initial_layout},
        sort_keys=True) + (fingerprint or scheduler_fingerprint())
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


class PlanCache:
    """Two-tier (memory LRU + content-addressed disk) compiled-plan store.

    ``persist=False`` keeps the cache purely in-memory (unit tests /
    throwaway sweeps); otherwise every compiled plan lands on disk with
    its provenance and survives the process.
    """

    def __init__(self, capacity: int = 1024,
                 cache_dir: Optional[str] = None, persist: bool = True,
                 fingerprint: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1 (got {capacity})")
        self.capacity = capacity
        self.cache_dir = default_cache_dir() if cache_dir is None \
            else cache_dir
        self.persist = persist
        self.fingerprint = fingerprint or scheduler_fingerprint()
        self._mem: collections.OrderedDict[str, LayoutPlan] = \
            collections.OrderedDict()
        self.mem_hits = 0
        self.disk_hits = 0
        self.misses = 0
        self.evictions = 0
        self.puts = 0

    # ------------------------------------------------------------- keying
    def key(self, workload: Workload, sys: SystemParams,
            initial_layout: Optional[str] = None) -> str:
        return plan_key(workload, sys, self.fingerprint, initial_layout)

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.json")

    # ------------------------------------------------------------- access
    def get(self, key: str) -> Optional[LayoutPlan]:
        """Memory first, then disk (which re-warms memory); None = miss."""
        plan = self._mem.get(key)
        if plan is not None:
            self._mem.move_to_end(key)
            self.mem_hits += 1
            return plan
        if self.persist:
            path = self._path(key)
            if os.path.exists(path):
                with open(path) as f:
                    entry = json.load(f)
                plan = LayoutPlan.from_dict(entry["plan"])
                self.disk_hits += 1
                self._remember(key, plan)
                return plan
        self.misses += 1
        return None

    def put(self, key: str, plan: LayoutPlan,
            provenance: Optional[dict] = None) -> None:
        self.puts += 1
        self._remember(key, plan)
        if not self.persist:
            return
        os.makedirs(self.cache_dir, exist_ok=True)
        entry = {
            "key": key,
            "plan": plan.to_dict(include_steps=True),
            "provenance": {
                "workload": plan.workload,
                "geometry": plan.geometry.label(),
                "scheduler_fingerprint": self.fingerprint,
                "created_unix": time.time(),
                **(provenance or {}),
            },
        }
        tmp = self._path(key) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(entry, f, indent=1, sort_keys=True)
        os.replace(tmp, self._path(key))  # atomic vs concurrent readers

    def get_or_compile(self, workload: Workload, sys: SystemParams,
                       compile_fn: Callable[[], LayoutPlan],
                       provenance: Optional[dict] = None,
                       initial_layout: Optional[str] = None
                       ) -> tuple[LayoutPlan, str, bool]:
        """``(plan, key, hit)`` -- the one call sites actually want."""
        key = self.key(workload, sys, initial_layout)
        plan = self.get(key)
        if plan is not None:
            return plan, key, True
        plan = compile_fn()
        self.put(key, plan, provenance)
        return plan, key, False

    # ----------------------------------------------------------- internal
    def _remember(self, key: str, plan: LayoutPlan) -> None:
        self._mem[key] = plan
        self._mem.move_to_end(key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)
            self.evictions += 1

    # -------------------------------------------------------------- stats
    @property
    def hits(self) -> int:
        return self.mem_hits + self.disk_hits

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def disk_entries(self) -> int:
        if not (self.persist and os.path.isdir(self.cache_dir)):
            return 0
        return sum(1 for p in os.listdir(self.cache_dir)
                   if p.endswith(".json"))

    def stats(self) -> dict:
        """Counter snapshot (recorded verbatim in serve.json)."""
        return {
            "lookups": self.hits + self.misses,
            "hits": self.hits,
            "mem_hits": self.mem_hits,
            "disk_hits": self.disk_hits,
            "misses": self.misses,
            "hit_rate": self.hit_rate,
            "evictions": self.evictions,
            "puts": self.puts,
            "capacity": self.capacity,
            "mem_entries": len(self._mem),
            "disk_entries": self.disk_entries(),
            "dir": self.cache_dir if self.persist else None,
            "fingerprint": self.fingerprint,
        }
