"""Machine-scale bench: a Table-6 app across the iso-area array axis.

``run_machine_bench`` compiles one workload into a
:class:`MachineSchedule` at every iso-area geometry (rows traded for
arrays, capacity constant), executes the critical class functionally on
the batched micro-op simulator at the widest machine point
(>= 1024 simulated arrays, mesh-sharded), and runs the three-way
differential harness.  The payload behind
``bench-artifacts/machine.json``::

    {"workload": ..., "quick": ...,
     "curve": [{geometry, arrays, classes, compute/movement/transpose/
                redistribute breakdown, planner_total, delta_total,
                explained, executed: {...}|null}, ...],
     "executed": {"arrays_simulated", "mesh_devices", "programs", "io"},
     "diff": {"rows": [...], "fails": [...]},
     "gate_failures": [...]}

``gate_failures`` non-empty => the CLI exits 3 (the trace-diff pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.machine import diff as machine_diff
from repro.machine.engine import execute_schedule
from repro.machine.partition import plan_machine
from repro.sweep.grid import Geometry, iso_area_family

DEFAULT_WORKLOAD = "traced/vgg16"
#: quick-mode rows axis: the acceptance point (rows=64 -> 1024 arrays),
#: the paper point (128 -> 512), and one deep point (512 -> 128)
QUICK_ROWS = (64, 128, 512)


def _curve_geometries(quick: bool,
                      geometries: Optional[Sequence[Geometry]]):
    if geometries:
        return tuple(geometries)
    fam = iso_area_family()
    if quick:
        fam = tuple(g for g in fam if g.rows in QUICK_ROWS)
    return fam


def run_machine_bench(workload: str = DEFAULT_WORKLOAD, *,
                      quick: bool = False,
                      geometries: Optional[Sequence[Geometry]] = None,
                      execute: bool = True, mesh=None,
                      run_diff: bool = True) -> dict:
    """Build the machine.json payload (see module docstring)."""
    from repro.workloads import get_workload

    w = get_workload(workload)
    fam = _curve_geometries(quick, geometries)
    gate_failures: list[str] = []
    curve = []
    executed_summary = None
    # functional execution at the widest machine on the curve -- the
    # acceptance point (>= 1024 simulated arrays when the family allows)
    exec_geo = max(fam, key=lambda g: g.arrays) if execute else None

    for geo in fam:
        try:
            sched = plan_machine(w, geo)
        except Exception as exc:  # infeasible point: report, don't gate
            curve.append({"geometry": geo.label(), "arrays": geo.arrays,
                          "error": str(exc)})
            continue
        if not sched.explained:
            gate_failures.append(
                f"{workload} @ {geo.label()}: unexplained machine-vs-"
                f"planner divergence ({sched.total_cycles} - "
                f"{sched.planner_total} != {sched.delta_total})")
        point = sched.summary()
        point["executed"] = None
        if execute and geo == exec_geo:
            res = execute_schedule(sched, w, functional=True, mesh=mesh)
            for msg in res["unexplained"]:
                gate_failures.append(f"{workload} @ {geo.label()}: {msg}")
            point["executed"] = {
                "scheduled_compute": res["scheduled_compute"],
                "executed_compute": res["executed_compute"],
                "rows": res["rows"],
            }
            executed_summary = {
                "geometry": geo.label(),
                "arrays_simulated": res["arrays_simulated"],
                "mesh_devices": res["mesh_devices"],
                "programs": res["programs"],
                "io": res["io"],
            }
        curve.append(point)

    diff_payload = None
    if run_diff:
        if quick:
            d_workloads: Sequence[str] = (workload, "mk/multu",
                                          "mk/vector_add")
            d_parts: Sequence[int] = (1, 4, 512)
        else:
            d_workloads = tuple(dict.fromkeys(
                (workload,) + machine_diff.DEFAULT_WORKLOADS))
            d_parts = machine_diff.DEFAULT_PARTS
        rows, fails = machine_diff.run_diff(
            d_workloads, parts=d_parts, execute=True, functional=False)
        gate_failures.extend(fails)
        diff_payload = {
            "rows": [dataclasses.asdict(r) for r in rows],
            "fails": fails,
        }

    return {
        "workload": workload,
        "quick": quick,
        "geometries": [g.label() for g in fam],
        "curve": curve,
        "executed": executed_summary,
        "diff": diff_payload,
        "gate_failures": gate_failures,
    }
