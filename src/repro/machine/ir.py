"""The machine-level schedule IR: placement above :class:`LayoutPlan`.

A :class:`MachineSchedule` describes how one `repro.workloads` Workload
runs across a *whole machine* of N simulated CSA array groups (the
per-array view the rest of the repo prices is one group of it), under an
iso-area `sweep.Geometry` budget:

* :class:`PartitionClass` -- a set of array groups that received the same
  shard shapes.  Balanced ragged splits produce few distinct shapes
  (one per distinct remainder boundary), so a 4096-way partition compiles
  a handful of `LayoutPlan`s, not 4096.  Each class's plan is a genuine
  `plan.compile_plan` product at the class's *per-group* geometry, so
  every shard still gets its optimal BP/BS/hybrid phase assignment.
* :class:`PlacedOp` -- one op's placement in one class: its shard of the
  parallel axis, the per-step layouts its class plan assigned, and the
  class-local compute/movement split.
* :class:`MovementStep` -- machine-level bus traffic, priced once on the
  shared row bus through the same Table-2 charge tables
  (``SystemParams.xfer_cycles``): operand loads, result readouts, and
  explicit inter-array ``redistribute`` halo traffic for convolutions.
* :class:`TransposeTrafficStep` -- the executed class's boundary
  transposes with their per-group replication count (groups transpose in
  parallel; the machine charges the per-group cycles once).
* :class:`DeltaRow` -- the end-to-end delta catalogue: every cycle of
  ``total_cycles - planner_total`` (machine vs the whole-machine
  `LayoutPlan`) must be itemized here, or ``explained`` is False and the
  differential harness (`repro.machine.diff`) exits non-zero.

Accounting contract (DESIGN.md Sec. 13, normative):

    total_cycles = movement_cycles            (serial on the shared bus)
                 + compute_cycles             (parallel across groups: the
                                               slowest class's per-group
                                               compute)
                 + transpose_cycles           (the same class's per-group
                                               boundary transposes)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.plan.ir import LayoutPlan
from repro.sweep.grid import Geometry


class MachineError(ValueError):
    """Invalid machine-schedule construction (bad partition count,
    inconsistent decomposition, or an unsatisfiable geometry)."""


@dataclasses.dataclass(frozen=True)
class PlacedOp:
    """One workload op's placement in one partition class."""

    op: str              #: op name in the machine workload
    op_index: int        #: index in the machine workload's op tuple
    kind: str
    cls: int             #: owning partition-class index
    shard_n: int         #: this class's share of the op's parallel axis
    groups: int          #: array groups carrying this shard
    layouts: tuple       #: per-step layout values the class plan assigned
    compute_cycles: int  #: per-group compute at the class geometry
    movement_cycles: int  #: class-local shard load/readout (informational)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class MovementStep:
    """Machine-level bus traffic, bandwidth-serial on the shared row bus."""

    op: str
    phase: str           #: ``load`` | ``readout`` | ``bus`` | ``redistribute``
    bits: float          #: modeled bus occupancy (cycles x bus width)
    cycles: int
    layout: str = ""     #: layout the traffic was priced in ("" = neutral)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class TransposeTrafficStep:
    """A boundary transpose of the executed class, replicated per group."""

    cls: int             #: partition class performing it
    before_step: int     #: class-plan step index whose input is transposed
    direction: str       #: ``bp2bs`` | ``bs2bp``
    cycles: int          #: per-group cycles (charged once; groups run in
                         #: parallel)
    groups: int          #: concurrent per-group replicas

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class DeltaRow:
    """One itemized component of ``total_cycles - planner_total``."""

    source: str          #: ``compute`` | ``movement`` | ``transpose`` |
                         #: ``redistribute``
    op: str              #: op name ("" for workload-level rows)
    cycles: int          #: signed machine-minus-planner contribution
    reason: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PartitionClass:
    """Array groups sharing one shard shape (and thus one LayoutPlan)."""

    index: int
    groups: int               #: number of array groups in this class
    arrays_per_group: int
    geometry: Geometry        #: per-group geometry the plan compiled at
    #: per machine-op shard of the parallel axis (0 = idle for that op;
    #: unshardable kinds carry their full extent)
    shard_sizes: tuple
    plan: Optional[LayoutPlan]   #: None when every op sharded to zero
    compute_cycles: int       #: per-group compute (sum over placed ops)
    movement_cycles: int      #: per-group shard load/readout
    transpose_cycles: int     #: per-group boundary transposes

    @property
    def total_cycles(self) -> int:
        """Per-group plan total; equals ``plan.total_cycles`` (asserted
        at construction by the partitioner)."""
        return (self.compute_cycles + self.movement_cycles
                + self.transpose_cycles)

    def to_dict(self) -> dict:
        return {
            "index": self.index, "groups": self.groups,
            "arrays_per_group": self.arrays_per_group,
            "geometry": self.geometry.to_dict(),
            "shard_sizes": list(self.shard_sizes),
            "compute_cycles": self.compute_cycles,
            "movement_cycles": self.movement_cycles,
            "transpose_cycles": self.transpose_cycles,
            "total_cycles": self.total_cycles,
            "plan": (self.plan.to_dict(include_steps=False)
                     if self.plan is not None else None),
        }


@dataclasses.dataclass(frozen=True)
class MachineSchedule:
    """A compiled machine-level schedule for one workload."""

    workload: str
    geometry: Geometry            #: whole-machine geometry
    n_partitions: int
    exec_class: int               #: index of the slowest (critical) class
    classes: tuple                #: tuple[PartitionClass, ...]
    placed: tuple                 #: tuple[PlacedOp, ...] (all classes)
    movement: tuple               #: tuple[MovementStep, ...] (machine bus)
    transposes: tuple             #: tuple[TransposeTrafficStep, ...]
    compute_cycles: int           #: executed class per-group compute
    movement_cycles: int          #: sum of machine-level movement steps
    transpose_cycles: int         #: executed class per-group transposes
    planner_total: int            #: whole-machine LayoutPlan total
    planner_static_bp: int
    planner_static_bs: int
    deltas: tuple                 #: tuple[DeltaRow, ...]
    initial_layout: Optional[str] = None

    # ------------------------------------------------------------- totals
    @property
    def total_cycles(self) -> int:
        return (self.movement_cycles + self.compute_cycles
                + self.transpose_cycles)

    @property
    def redistribute_cycles(self) -> int:
        return sum(m.cycles for m in self.movement
                   if m.phase == "redistribute")

    @property
    def delta_total(self) -> int:
        return sum(d.cycles for d in self.deltas)

    @property
    def explained(self) -> bool:
        """Does the itemized delta catalogue account for every cycle of
        machine-vs-planner divergence?  The differential gate."""
        return self.total_cycles - self.planner_total == self.delta_total

    @property
    def arrays_total(self) -> int:
        return sum(c.groups * c.arrays_per_group for c in self.classes)

    # ---------------------------------------------------------- accessors
    def classes_for(self, op: str):
        """PlacedOps of one op across every class (class order)."""
        return tuple(p for p in self.placed if p.op == op)

    def exec_placed(self):
        """PlacedOps of the executed (critical) class, op order."""
        return tuple(p for p in self.placed if p.cls == self.exec_class)

    def summary(self) -> dict:
        return {
            "workload": self.workload,
            "geometry": self.geometry.label(),
            "arrays": self.geometry.arrays,
            "n_partitions": self.n_partitions,
            "classes": len(self.classes),
            "compute_cycles": self.compute_cycles,
            "movement_cycles": self.movement_cycles,
            "redistribute_cycles": self.redistribute_cycles,
            "transpose_cycles": self.transpose_cycles,
            "total_cycles": self.total_cycles,
            "planner_total": self.planner_total,
            "planner_static_bp": self.planner_static_bp,
            "planner_static_bs": self.planner_static_bs,
            "delta_total": self.delta_total,
            "explained": self.explained,
        }

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        d = self.summary()
        d.update({
            "geometry": self.geometry.to_dict(),
            "exec_class": self.exec_class,
            "initial_layout": self.initial_layout,
            "classes": [c.to_dict() for c in self.classes],
            "placed": [p.to_dict() for p in self.placed],
            "movement": [m.to_dict() for m in self.movement],
            "transposes": [t.to_dict() for t in self.transposes],
            "deltas": [x.to_dict() for x in self.deltas],
        })
        return d
