"""Machine-level scheduling: Table-6 apps across thousands of arrays.

The subsystem above `repro.plan`: partition a Workload across N
simulated CSA array groups (:func:`plan_machine`), price machine-level
movement/transpose traffic through the same Table-2 charge tables, run
the critical partition class on the batched micro-op simulator
(:func:`execute_schedule`), and gate the three-way analytic / planner /
executed accounting (`repro.machine.diff`).  See README.md and
DESIGN.md Sec. 13.
"""
from repro.machine.ir import (
    DeltaRow,
    MachineError,
    MachineSchedule,
    MovementStep,
    PartitionClass,
    PlacedOp,
    TransposeTrafficStep,
)
from repro.machine.partition import (
    class_boundaries,
    plan_machine,
    shard_sizes_for,
    shard_workload,
)
from repro.machine.engine import execute_schedule
from repro.machine.diff import DiffRow, run_diff
from repro.machine.bench import run_machine_bench

__all__ = [
    "DeltaRow",
    "DiffRow",
    "MachineError",
    "MachineSchedule",
    "MovementStep",
    "PartitionClass",
    "PlacedOp",
    "TransposeTrafficStep",
    "class_boundaries",
    "execute_schedule",
    "plan_machine",
    "run_diff",
    "run_machine_bench",
    "shard_sizes_for",
    "shard_workload",
]
