"""Partition a Workload across N array groups and compile the schedule.

:func:`plan_machine` is the machine-level compiler: it splits every
parallel op of a Workload across ``n_parts`` array groups under an
iso-area :class:`sweep.Geometry` budget, compiles one
``plan.compile_plan`` LayoutPlan per *distinct shard shape* (partition
class), prices the machine-level bus traffic once, and itemizes every
cycle of machine-vs-planner divergence into the
:class:`~repro.machine.ir.DeltaRow` catalogue.

Sharding rules (DESIGN.md Sec. 13, normative):

* ``kernel`` / ``conv`` ops shard their element axis ``n``; ``matmul``
  ops shard the output-column axis ``n`` (weight-stationary: each group
  holds the full k-deep weight columns of its outputs).  Balanced ragged
  splits: group ``p`` gets ``n//N + 1`` elements iff ``p < n % N``.
* ``compute`` ops carry explicit machine-calibrated cycles and
  ``movement`` ops are bus-serial -- neither shards; every class charges
  them unchanged (compute) or the machine charges them once (movement).
* An op whose shard is empty in some class is dropped there (the groups
  idle through it); its dependence edges are bridged so the class DAG
  stays connected.
* ``n_parts`` must divide ``geometry.arrays`` -- each class's plan
  compiles at the per-group geometry ``rows x cols x (arrays//n_parts)``.
  ``n_parts=1`` passes the whole workload and geometry through
  unchanged, reducing bit-for-bit to the existing LayoutPlan path.

Movement pricing: operand loads and result readouts are charged *once*
at machine level, in the executed class's per-step layouts, through the
same ``op_cost`` Table-2 bus accounting every other layer uses --
operands broadcast on the shared row bus are not multiplied by N.
Convolutions additionally charge explicit inter-array ``redistribute``
halo traffic: ``(active_groups - 1) * (taps - 1) * width`` bits.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.cost_model import Layout
from repro.core.params import SystemParams
from repro.machine.ir import (DeltaRow, MachineError, MachineSchedule,
                              MovementStep, PartitionClass, PlacedOp,
                              TransposeTrafficStep)
from repro.plan.ir import LayoutPlan
from repro.plan.scheduler import compile_plan
from repro.sweep.grid import Geometry, PAPER_GEOMETRY
from repro.workloads.ir import Op, Workload, op_cost

#: op kinds whose parallel axis shards across array groups
SHARDED_KINDS = ("kernel", "conv", "matmul")


# ---------------------------------------------------------------------------
# Sharding
# ---------------------------------------------------------------------------

def shard_extent(op: Op) -> Optional[int]:
    """The op's shardable parallel extent (None for unshardable kinds)."""
    if op.kind in SHARDED_KINDS:
        return op.n
    return None


def class_boundaries(workload: Workload, n_parts: int) -> list[int]:
    """Group indices where the shard-shape vector changes.

    Group ``p`` gets a ceil shard of op *i* iff ``p < n_i % N``, so the
    shape vector is constant between consecutive distinct remainders --
    the returned sorted boundaries start with 0 and partition ``[0, N)``
    into the schedule's partition classes.
    """
    cuts = {0}
    for op in workload.ops:
        ext = shard_extent(op)
        if ext is None:
            continue
        r = ext % n_parts
        if 0 < r < n_parts:
            cuts.add(r)
        # ops smaller than N idle the groups beyond their extent; the
        # boundary at the extent itself separates busy from idle groups
        if ext < n_parts:
            cuts.add(ext)
    return sorted(cuts)


def shard_sizes_for(workload: Workload, n_parts: int,
                    group_start: int) -> tuple:
    """Per-op shard sizes for the class starting at ``group_start``."""
    sizes = []
    for op in workload.ops:
        ext = shard_extent(op)
        if ext is None:
            sizes.append(op.n if op.kind in SHARDED_KINDS else 0)
            continue
        base, r = divmod(ext, n_parts)
        sizes.append(base + (1 if group_start < r else 0))
    return tuple(sizes)


def _shard_op(op: Op, n_p: int) -> Optional[Op]:
    """The op restricted to one group's shard (None when empty)."""
    if op.kind not in SHARDED_KINDS:
        return op
    if n_p <= 0:
        return None
    if n_p == op.n:
        return op
    fields = {"n": n_p}
    if op.kind == "conv" and op.in_elems is not None:
        # input elements scale with the output shard (nearest integer;
        # halo overlap is charged explicitly as redistribute traffic)
        fields["in_elems"] = max(1, (op.in_elems * n_p + op.n // 2) // op.n)
    return dataclasses.replace(op, **fields)


def shard_workload(workload: Workload, shard_sizes) -> \
        tuple[Optional[Workload], tuple]:
    """One class's Workload (ops resized to the shard; empty ops dropped
    with their dependence edges bridged).  Returns ``(workload, kept)``
    where ``kept`` maps surviving op positions to original indices;
    ``(None, ())`` when every op dropped (a fully idle class)."""
    ops, kept = [], []
    for i, op in enumerate(workload.ops):
        ext = shard_extent(op)
        sh = _shard_op(op, shard_sizes[i] if ext is not None else 0)
        if sh is None:
            continue
        ops.append(sh)
        kept.append(i)
    if not ops:
        return None, ()
    new_index = {orig: j for j, orig in enumerate(kept)}
    # bridge dropped nodes: successors inherit the dropped op's preds
    preds: dict[int, set] = {i: set() for i in range(len(workload.ops))}
    for a, b in workload.edges():
        preds[b].add(a)
    resolved: dict[int, set] = {}

    def surviving_preds(i: int) -> set:
        if i in resolved:
            return resolved[i]
        out: set = set()
        for a in preds[i]:
            if a in new_index:
                out.add(a)
            else:
                out |= surviving_preds(a)
        resolved[i] = out
        return out

    edges = set()
    for orig in kept:
        for a in surviving_preds(orig):
            edges.add((new_index[a], new_index[orig]))
    # linear chains stay implicit (deps=()) so the chain DP route -- and
    # therefore bit-for-bit N=1 reduction -- is preserved
    chain = {(j, j + 1) for j in range(len(ops) - 1)}
    deps = () if (not workload.deps and edges <= chain) else tuple(
        sorted(edges))
    return Workload(name=workload.name, ops=tuple(ops),
                    source=workload.source,
                    description=workload.description, deps=deps), tuple(kept)


# ---------------------------------------------------------------------------
# Plan decomposition (movement vs compute per op)
# ---------------------------------------------------------------------------

def plan_movement_compute(plan: LayoutPlan, workload: Workload,
                          sys: SystemParams) -> dict:
    """Per-op ``(movement, compute)`` cycle split of a compiled plan.

    Movement = bus-serial load/readout phases; compute = the
    capacity-parallel in-array work.  The split is exact:
    ``sum(mov + comp) + plan.transpose_cycles_total ==
    plan.total_cycles`` (asserted by the caller).
    """
    out: dict[str, tuple[int, int]] = {}
    for op in workload.ops:
        mov = comp = 0
        for s in plan.steps_for(op.name):
            if op.kind == "movement":
                mov += s.cycles
            elif op.kind == "compute":
                comp += s.cycles
            elif op.kind == "kernel":
                c = op_cost(op, s.layout, sys)
                comp += c.compute
                mov += s.cycles - c.compute
            elif s.phase.endswith(".mac"):
                comp += s.cycles
            elif op.kind == "matmul" and op.chunk == 0:
                comp += s.cycles   # streamed MAC: single compute phase
            else:
                mov += s.cycles    # .load / .out phases
        out[op.name] = (mov, comp)
    return out


def _op_step_layouts(plan: LayoutPlan, op_name: str) -> tuple:
    return tuple(s.layout.value for s in plan.steps_for(op_name))


# ---------------------------------------------------------------------------
# Machine-level movement pricing
# ---------------------------------------------------------------------------

def _machine_movement(workload: Workload, sys: SystemParams,
                      layouts_for: Callable[[str], tuple],
                      active_groups: dict, n_parts: int) -> list:
    """Machine-level MovementSteps: whole-op loads/readouts charged once
    on the shared bus, plus explicit conv halo redistribution."""
    bw = sys.row_bandwidth_bits
    steps: list[MovementStep] = []
    for op in workload.ops:
        if op.kind == "compute":
            continue
        if op.kind == "movement":
            steps.append(MovementStep(
                op=op.name, phase="bus", bits=op.bits,
                cycles=op_cost(op, Layout.BP, sys).load))
            continue
        if op.kind == "matmul" and op.chunk == 0:
            continue   # streamed MAC: movement is explicit movement ops
        lays = layouts_for(op.name)
        if op.kind == "kernel":
            lay = Layout(lays[0]) if lays else Layout.BP
            c = op_cost(op, lay, sys)
            if c.load:
                steps.append(MovementStep(
                    op=op.name, phase="load", bits=float(c.load * bw),
                    cycles=c.load, layout=lay.value))
            if c.readout:
                steps.append(MovementStep(
                    op=op.name, phase="readout",
                    bits=float(c.readout * bw), cycles=c.readout,
                    layout=lay.value))
        else:   # conv / chunked matmul: 3 phases, per-phase layouts
            load_lay = Layout(lays[0]) if lays else Layout.BP
            out_lay = Layout(lays[2]) if len(lays) > 2 else load_lay
            steps.append(MovementStep(
                op=op.name, phase="load",
                bits=float(op_cost(op, load_lay, sys).load * bw),
                cycles=op_cost(op, load_lay, sys).load,
                layout=load_lay.value))
            steps.append(MovementStep(
                op=op.name, phase="readout",
                bits=float(op_cost(op, out_lay, sys).readout * bw),
                cycles=op_cost(op, out_lay, sys).readout,
                layout=out_lay.value))
            if op.kind == "conv" and n_parts > 1:
                groups = active_groups.get(op.name, n_parts)
                if groups > 1:
                    bits = (groups - 1) * max(0, op.k - 1) * op.width
                    if bits:
                        steps.append(MovementStep(
                            op=op.name, phase="redistribute",
                            bits=float(bits), cycles=sys.xfer_cycles(bits),
                            layout=load_lay.value))
    return steps


# ---------------------------------------------------------------------------
# The machine compiler
# ---------------------------------------------------------------------------

def _default_compile(wl: Workload, sys: SystemParams, *,
                     initial_layout=None,
                     enforce_feasibility=False) -> LayoutPlan:
    return compile_plan(wl, sys, initial_layout=initial_layout,
                        enforce_feasibility=enforce_feasibility)


def plan_machine(workload: Workload,
                 geometry: Geometry = PAPER_GEOMETRY,
                 n_parts: Optional[int] = None, *,
                 initial_layout: Optional[Layout] = None,
                 enforce_feasibility: bool = False,
                 compile_fn: Optional[Callable] = None) -> MachineSchedule:
    """Compile ``workload`` into a :class:`MachineSchedule` over
    ``n_parts`` array groups of ``geometry`` (default: one group per
    array).

    ``compile_fn(workload, sys, *, initial_layout, enforce_feasibility)
    -> LayoutPlan`` overrides the per-partition plan compiler -- the
    serving path routes it through the content-addressed plan cache
    (``PlanService.compile_machine``).
    """
    if n_parts is None:
        n_parts = geometry.arrays
    if n_parts < 1:
        raise MachineError(f"n_parts must be >= 1 (got {n_parts})")
    if geometry.arrays % n_parts:
        raise MachineError(
            f"n_parts={n_parts} does not divide the machine's "
            f"{geometry.arrays} arrays (iso-area groups must be equal)")
    compile_fn = compile_fn or _default_compile
    arrays_per_group = geometry.arrays // n_parts
    group_geom = Geometry(rows=geometry.rows, cols=geometry.cols,
                          arrays=arrays_per_group,
                          row_bandwidth_bits=geometry.row_bandwidth_bits)
    sys_g = geometry.system()          # whole machine
    sys_p = group_geom.system()        # one array group

    # ---- whole-machine reference plan (the N=1 path) -------------------
    planner_plan = compile_fn(workload, sys_g,
                              initial_layout=initial_layout,
                              enforce_feasibility=enforce_feasibility)
    planner_mc = plan_movement_compute(planner_plan, workload, sys_g)
    _check_split(planner_plan, planner_mc, workload.name, "planner")

    # ---- partition classes ---------------------------------------------
    bounds = class_boundaries(workload, n_parts)
    classes: list[PartitionClass] = []
    placed: list[PlacedOp] = []
    class_mc: list[dict] = []
    for ci, start in enumerate(bounds):
        end = bounds[ci + 1] if ci + 1 < len(bounds) else n_parts
        sizes = shard_sizes_for(workload, n_parts, start)
        if n_parts == 1:
            cls_w, kept = workload, tuple(range(len(workload.ops)))
            plan: Optional[LayoutPlan] = planner_plan   # bit-for-bit reuse
        else:
            cls_w, kept = shard_workload(workload, sizes)
            plan = None if cls_w is None else compile_fn(
                cls_w, sys_p, initial_layout=initial_layout,
                enforce_feasibility=enforce_feasibility)
        mc = ({} if plan is None
              else plan_movement_compute(plan, cls_w, sys_p))
        if plan is not None:
            _check_split(plan, mc, workload.name, f"class {ci}")
        class_mc.append(mc)
        comp = sum(c for _, c in mc.values())
        mov = sum(m for m, _ in mc.values())
        classes.append(PartitionClass(
            index=ci, groups=end - start, arrays_per_group=arrays_per_group,
            geometry=group_geom, shard_sizes=sizes, plan=plan,
            compute_cycles=comp, movement_cycles=mov,
            transpose_cycles=(plan.transpose_cycles_total
                              if plan is not None else 0)))
        for j, orig in enumerate(kept):
            op = workload.ops[orig]
            m, c = mc[op.name]
            placed.append(PlacedOp(
                op=op.name, op_index=orig, kind=op.kind, cls=ci,
                shard_n=(sizes[orig] if shard_extent(op) is not None
                         else op.n),
                groups=end - start,
                layouts=_op_step_layouts(plan, op.name),
                compute_cycles=c, movement_cycles=m))

    # ---- executed (critical) class: slowest per-group parallel section -
    exec_class = max(range(len(classes)),
                     key=lambda i: (classes[i].compute_cycles
                                    + classes[i].transpose_cycles))
    crit = classes[exec_class]
    exec_mc = class_mc[exec_class]

    def layouts_for(op_name: str) -> tuple:
        if crit.plan is not None:
            lays = _op_step_layouts(crit.plan, op_name)
            if lays:
                return lays
        return _op_step_layouts(planner_plan, op_name)

    active_groups = {}
    for op in workload.ops:
        ext = shard_extent(op)
        if ext is not None:
            active_groups[op.name] = min(ext, n_parts)
    movement = _machine_movement(workload, sys_g, layouts_for,
                                 active_groups, n_parts)

    transposes = tuple(
        TransposeTrafficStep(cls=exec_class, before_step=t.before_step,
                             direction=t.direction, cycles=t.cycles,
                             groups=crit.groups)
        for t in (crit.plan.transposes if crit.plan is not None else ()))

    compute_cycles = crit.compute_cycles
    movement_cycles = sum(m.cycles for m in movement)
    transpose_cycles = crit.transpose_cycles

    # ---- delta catalogue (machine minus planner, itemized) -------------
    deltas: list[DeltaRow] = []
    for op in workload.ops:
        p_mov, p_comp = planner_mc[op.name]
        m_comp = exec_mc.get(op.name, (0, 0))[1]
        if m_comp != p_comp:
            if op.name not in exec_mc:
                reason = "idle-in-exec-class (shard empty)"
            elif layouts_for(op.name) != _op_step_layouts(planner_plan,
                                                          op.name):
                reason = "layout-divergence (class plan chose differently)"
            else:
                reason = ("partition-batching (ragged ceil at the "
                          "per-group geometry)")
            deltas.append(DeltaRow(source="compute", op=op.name,
                                   cycles=m_comp - p_comp, reason=reason))
        m_mov = sum(m.cycles for m in movement
                    if m.op == op.name and m.phase != "redistribute")
        if m_mov != p_mov:
            deltas.append(DeltaRow(
                source="movement", op=op.name, cycles=m_mov - p_mov,
                reason="layout-divergence movement pricing"))
    for m in movement:
        if m.phase == "redistribute":
            deltas.append(DeltaRow(
                source="redistribute", op=m.op, cycles=m.cycles,
                reason="conv halo redistribution (inter-array)"))
    t_delta = transpose_cycles - planner_plan.transpose_cycles_total
    if t_delta:
        deltas.append(DeltaRow(
            source="transpose", op="", cycles=t_delta,
            reason="per-group boundary transposes (parallel replicas "
                   "charged once)"))

    return MachineSchedule(
        workload=workload.name, geometry=geometry, n_partitions=n_parts,
        exec_class=exec_class, classes=tuple(classes), placed=tuple(placed),
        movement=tuple(movement), transposes=transposes,
        compute_cycles=compute_cycles, movement_cycles=movement_cycles,
        transpose_cycles=transpose_cycles,
        planner_total=planner_plan.total_cycles,
        planner_static_bp=planner_plan.static_bp,
        planner_static_bs=planner_plan.static_bs,
        deltas=tuple(deltas),
        initial_layout=initial_layout.value if initial_layout else None)


def _check_split(plan: LayoutPlan, mc: dict, name: str, what: str) -> None:
    """The movement/compute split must be exact (internal invariant)."""
    total = sum(m + c for m, c in mc.values()) + plan.transpose_cycles_total
    if total != plan.total_cycles:
        raise MachineError(
            f"{name}: {what} movement/compute split ({total}) does not "
            f"reproduce the plan total ({plan.total_cycles})")
