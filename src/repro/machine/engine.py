"""Execute a :class:`MachineSchedule` on the batched micro-op simulator.

The multi-array execution engine closes the last loop of the machine
model: every compute step of the executed (critical) partition class is
lowered to its ``pim.programs`` micro-op program in the *assigned*
layout and replayed functionally across **all** of the machine's
simulated arrays via ``pim.executor.run_batched`` (``jit(vmap(...))``),
with the leading array axis sharded over the ``repro.dist`` data mesh.

Cycle accounting (static -- micro-op charges are data-independent):

* ``kernel`` ops: ``program.cycles x batches`` at the class geometry,
  differenced against the analytic compute formula; the pair must show
  exactly the documented Sec.-8 calibration delta or the row is
  *unexplained* (the harness gate).
* ``matmul`` / ``conv`` ops: the ``multu`` + ``vector_add`` MAC
  decomposition (the ``ExecutorBackend`` / ``replay_plan`` route); the
  decomposition intentionally differs from the analytic chunked-tree
  pricing, so the row's delta is itemized as explained, never gated.
* ``compute`` ops carry hand-calibrated cycles with no micro-op
  lowering; executed == scheduled by definition.

Movement reconciliation: the schedule's charged bus traffic (model
bytes) is reported next to the HLO-boundary bytes
(``dist.hlo_bytes.boundary_bytes``) of the largest lowered batched
computation -- the two accountings price different machines (the shared
CSA row bus vs the simulating host's HBM), so the reconciliation is a
sanity ratio, not an equality gate.
"""
from __future__ import annotations

from repro.core.cost_model import Layout
from repro.machine.ir import MachineSchedule
from repro.sweep.grid import Geometry
from repro.workloads.ir import Workload


def _batches(layout: Layout, n: int, width: int, sys) -> int:
    return sys.bp_batches(n, width) if layout is Layout.BP \
        else sys.bs_batches(n)


def _compute_layout(placed) -> Layout:
    """The layout of a placed op's compute step (.mac for matmul/conv)."""
    lays = placed.layouts
    if placed.kind in ("matmul", "conv") and len(lays) == 3:
        return Layout(lays[1])
    return Layout(lays[0]) if lays else Layout.BP


def execute_schedule(schedule: MachineSchedule, workload: Workload, *,
                     functional: bool = True, mesh=None,
                     collect_hlo: bool = True) -> dict:
    """Execute the schedule's critical class across every array group.

    Returns the executed-vs-scheduled record::

        {"rows": [...], "programs": [...], "arrays_simulated": int,
         "mesh_devices": int, "scheduled_compute": int,
         "executed_compute": int, "unexplained": [...], "io": {...}}

    ``functional=False`` keeps the static program-cycle accounting but
    skips the jax array simulation (identical numbers, no jax work).
    ``mesh`` shards the leading array axis of every batched run; the
    array count is padded up to a device multiple when needed.
    """
    from repro.pim import programs as pr

    crit = schedule.classes[schedule.exec_class]
    sys_p = crit.geometry.system()
    ops_by_index = {op.name: op for op in workload.ops}

    rows: list[dict] = []
    unexplained: list[str] = []
    #: Program -> number of simulated arrays that run it (all classes)
    prog_arrays: dict = {}

    def note_program(prog, op_name: str) -> None:
        arrays = sum(c.groups * c.arrays_per_group for c in schedule.classes
                     if c.plan is not None
                     and any(p.op == op_name and p.cls == c.index
                             for p in schedule.placed))
        prog_arrays[prog] = max(prog_arrays.get(prog, 0), arrays)

    for placed in schedule.exec_placed():
        op = ops_by_index[placed.op]
        scheduled = placed.compute_cycles
        layout = _compute_layout(placed)
        if op.kind == "kernel":
            if (op.kernel, layout) not in pr.BUILDERS:
                rows.append({
                    "op": op.name, "kind": op.kind, "layout": layout.value,
                    "shard_n": placed.shard_n, "scheduled": scheduled,
                    "executed": scheduled, "delta": 0, "expected_delta": 0,
                    "note": "no micro-op program; analytic charge",
                    "explained": True})
                continue
            n_eff = (placed.shard_n if layout is Layout.BP
                     and op.kernel == "reduction" else None)
            prog = pr.build(op.kernel, layout, width=op.width, n=n_eff)
            note_program(prog, op.name)
            batches = _batches(layout, placed.shard_n, op.width, sys_p)
            predicted = pr.analytic_compute(
                op.kernel, layout, op.width, n=placed.shard_n) * batches
            executed = prog.cycles * batches
            expected = prog.expected_delta * batches
            ok = executed - predicted == expected
            if not ok:
                unexplained.append(
                    f"{op.name} [{layout.value}]: executed-predicted = "
                    f"{executed - predicted}, documented delta = {expected}")
            if predicted != scheduled:
                # the plan priced this step with the same analytic recipe;
                # a mismatch means the decomposition drifted -- gate it
                ok = False
                unexplained.append(
                    f"{op.name} [{layout.value}]: scheduled compute "
                    f"{scheduled} != analytic route {predicted}")
            rows.append({
                "op": op.name, "kind": op.kind, "layout": layout.value,
                "shard_n": placed.shard_n, "scheduled": scheduled,
                "executed": executed, "delta": executed - predicted,
                "expected_delta": expected,
                "note": prog.calibration_note or "exact",
                "explained": ok})
        elif op.kind in ("matmul", "conv"):
            outs = (op.m * placed.shard_n if op.kind == "matmul"
                    else placed.shard_n)
            mult = pr.build("multu", layout, width=op.width)
            add = pr.build("vector_add", layout, width=2 * op.width)
            note_program(mult, op.name)
            note_program(add, op.name)
            batches = _batches(layout, outs, op.width, sys_p)
            executed = (op.k * mult.cycles
                        + (op.k - 1) * add.cycles) * batches
            rows.append({
                "op": op.name, "kind": op.kind, "layout": layout.value,
                "shard_n": placed.shard_n, "scheduled": scheduled,
                "executed": executed, "delta": executed - scheduled,
                "expected_delta": executed - scheduled,
                "note": "MAC decomposition (multu + vector_add); priced "
                        "analytically as a chunked tree -- itemized, "
                        "not gated",
                "explained": True})
        else:   # compute / movement: no micro-op lowering
            rows.append({
                "op": op.name, "kind": op.kind,
                "layout": placed.layouts[0] if placed.layouts else "",
                "shard_n": placed.shard_n, "scheduled": scheduled,
                "executed": scheduled, "delta": 0, "expected_delta": 0,
                "note": "no micro-op lowering; hand-calibrated charge",
                "explained": True})

    result = {
        "rows": rows,
        "scheduled_compute": sum(r["scheduled"] for r in rows),
        "executed_compute": sum(r["executed"] for r in rows),
        "unexplained": unexplained,
        "arrays_simulated": 0,
        "mesh_devices": 1,
        "programs": [],
        "io": None,
    }
    if functional and prog_arrays:
        result.update(_run_programs(prog_arrays, crit.geometry, mesh,
                                    collect_hlo))
    return result


# ---------------------------------------------------------------------------
# Functional batched execution (mesh-sharded jit+vmap)
# ---------------------------------------------------------------------------

def _run_programs(prog_arrays: dict, geometry: Geometry, mesh,
                  collect_hlo: bool) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.pim.executor import make_runner, run_batched

    n_dev = 1
    sharding = None
    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        n_dev = mesh.devices.size
        sharding = NamedSharding(mesh, P(mesh.axis_names[0], None, None))

    programs = []
    arrays_simulated = 0
    biggest = None
    for prog, arrays in sorted(prog_arrays.items(),
                               key=lambda kv: kv[0].key):
        n_arrays = arrays
        if n_dev > 1 and n_arrays % n_dev:
            n_arrays += n_dev - n_arrays % n_dev   # pad to device multiple
        # the functional replay needs the program's row footprint; the
        # geometry's column width is kept (feasibility is recorded on the
        # plan, not re-enforced by the simulator)
        cols = geometry.cols
        if prog.layout is Layout.BP and cols % prog.width:
            cols += prog.width - cols % prog.width
        cells = jnp.zeros((n_arrays, prog.rows, cols), bool)
        if sharding is not None:
            cells = jax.device_put(cells, sharding)
        state = run_batched(prog, cells)
        jax.block_until_ready(state.cells)
        arrays_simulated = max(arrays_simulated, n_arrays)
        programs.append({
            "name": prog.name, "layout": prog.layout.value,
            "width": prog.width, "cycles": prog.cycles,
            "arrays": n_arrays, "rows": prog.rows, "cols": cols})
        if biggest is None or n_arrays * prog.rows > \
                biggest[1].shape[0] * biggest[1].shape[1]:
            biggest = (prog, cells)

    io = None
    if collect_hlo and biggest is not None:
        prog, cells = biggest
        hlo = jax.jit(jax.vmap(make_runner(prog))).lower(cells)\
            .compile().as_text()
        from repro.dist.hlo_bytes import boundary_bytes

        n, rows, cols = cells.shape
        model = {
            "cells_in": n * rows * cols,            # bool = 1 byte
            "cells_out": n * rows * cols,
            "carry_out": n * cols,
            "acc_out": n * 4,
        }
        model_total = sum(model.values())
        hlo_total = boundary_bytes(hlo)
        io = {
            "program": prog.name,
            "model_io_bytes": model_total,
            "model_io_breakdown": model,
            "hlo_boundary_bytes": hlo_total,
            "ratio": (hlo_total / model_total) if model_total else 0.0,
        }
    return {"programs": programs, "arrays_simulated": arrays_simulated,
            "mesh_devices": n_dev, "io": io}


def default_mesh():
    """A 1-D ``("data",)`` mesh over every local device, or None on a
    single device (``shard`` degrades to a no-op either way).

    Lived in ``serve.bench`` until PR 10; the serve execute path now
    runs compiled Pallas schedules (single-program, no mesh reduction),
    so machine-bench owns the mesh helper."""
    import jax

    devs = jax.devices()
    if len(devs) < 2:
        return None
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.array(devs), ("data",))
