"""End-to-end differential harness: analytic vs planner vs machine.

``run_diff`` closes the three-way loop the per-kernel replay gates
cannot see (DESIGN.md Sec. 13):

1. **analytic <-> planner** -- the whole-machine plan's static BP/BS
   totals must equal the summed analytic ``op_cost`` totals to the
   cycle (the plan IR and the analytic route price the same machine).
2. **planner <-> machine** -- every cycle of
   ``MachineSchedule.total_cycles - planner_total`` must be itemized in
   the schedule's :class:`~repro.machine.ir.DeltaRow` catalogue
   (``schedule.explained``); N=1 must reduce to the LayoutPlan path
   exactly (zero deltas, equal totals).
3. **machine <-> executed** -- the critical class's micro-op-executed
   compute must match the scheduled compute up to the documented
   Sec.-8 calibration deltas (kernels) and the itemized MAC
   decomposition rows (matmul/conv); any other divergence is
   unexplained.

Any unexplained divergence lands in ``fails`` and the CLI
(``python -m repro machine-bench``) exits 3 -- mirroring the
``trace-diff`` gate.
"""
from __future__ import annotations

import csv
import dataclasses
from typing import Optional, Sequence

from repro.core.cost_model import Layout
from repro.machine.engine import execute_schedule
from repro.machine.partition import plan_machine
from repro.sweep.grid import Geometry, PAPER_GEOMETRY

#: default differential scope: the Table-6 VGG16 app (conv/matmul route)
#: plus kernel-op workloads that exercise the Sec.-8 calibration gate
DEFAULT_WORKLOADS = ("vgg16", "aes", "mk/multu", "mk/vector_add",
                     "mk/reduction")
DEFAULT_PARTS = (1, 4, 512)


@dataclasses.dataclass(frozen=True)
class DiffRow:
    """One (workload, partition count) machine-vs-planner record."""

    workload: str
    n_parts: int
    classes: int
    machine_total: int
    planner_total: int
    delta_total: int
    explained: bool
    executed_compute: Optional[int]
    scheduled_compute: Optional[int]
    status: str          #: ``ok`` | ``unexplained``
    note: str = ""


def _check_analytic(workload, sys, fails: list) -> None:
    """Gate 1: planner statics == summed analytic op costs, exactly."""
    from repro.plan import compile_plan

    plan = compile_plan(workload, sys)
    for lay, static in ((Layout.BP, plan.static_bp),
                        (Layout.BS, plan.static_bs)):
        analytic = workload.cost(lay, sys).total
        if int(analytic) != static:
            fails.append(
                f"{workload.name}: planner static_{lay.value.lower()} "
                f"{static} != analytic total {int(analytic)}")


def run_diff(workloads: Optional[Sequence[str]] = None, *,
             geometry: Geometry = PAPER_GEOMETRY,
             parts: Sequence[int] = DEFAULT_PARTS,
             execute: bool = True, functional: bool = False,
             mesh=None) -> tuple[list[DiffRow], list[str]]:
    """Run the three-way differential over ``workloads`` x ``parts``.

    ``execute`` runs the static micro-op accounting (gate 3);
    ``functional`` additionally replays the batched jax simulation
    (identical cycle numbers -- the arrays are simulated for real, which
    is what the bench does at the acceptance point).
    """
    from repro.workloads import get_workload

    rows: list[DiffRow] = []
    fails: list[str] = []
    names = list(workloads) if workloads else list(DEFAULT_WORKLOADS)
    sys_g = geometry.system()
    for name in names:
        w = get_workload(name)
        _check_analytic(w, sys_g, fails)
        for n_parts in parts:
            if geometry.arrays % n_parts:
                continue
            sched = plan_machine(w, geometry, n_parts)
            note = ""
            ok = sched.explained
            if not ok:
                fails.append(
                    f"{name} N={n_parts}: machine total "
                    f"{sched.total_cycles} - planner "
                    f"{sched.planner_total} != itemized delta "
                    f"{sched.delta_total}")
            if n_parts == 1:
                if sched.total_cycles != sched.planner_total:
                    ok = False
                    fails.append(
                        f"{name} N=1: machine total {sched.total_cycles} "
                        f"!= planner total {sched.planner_total} "
                        "(must reduce bit-for-bit)")
                if sched.deltas:
                    ok = False
                    fails.append(
                        f"{name} N=1: {len(sched.deltas)} delta rows "
                        "(must be empty)")
            executed = scheduled = None
            if execute:
                res = execute_schedule(sched, w, functional=functional,
                                       mesh=mesh, collect_hlo=False)
                executed = res["executed_compute"]
                scheduled = res["scheduled_compute"]
                if res["unexplained"]:
                    ok = False
                    for msg in res["unexplained"]:
                        fails.append(f"{name} N={n_parts}: {msg}")
                bad = [r for r in res["rows"] if not r["explained"]]
                if bad:
                    ok = False
                note = f"{len(res['rows'])} executed rows"
            rows.append(DiffRow(
                workload=name, n_parts=n_parts, classes=len(sched.classes),
                machine_total=sched.total_cycles,
                planner_total=sched.planner_total,
                delta_total=sched.delta_total, explained=sched.explained,
                executed_compute=executed, scheduled_compute=scheduled,
                status="ok" if ok else "unexplained", note=note))
    return rows, fails


def write_csv(rows: Sequence[DiffRow], path: str) -> None:
    fields = [f.name for f in dataclasses.fields(DiffRow)]
    with open(path, "w", newline="") as fh:
        out = csv.writer(fh)
        out.writerow(fields)
        for r in rows:
            out.writerow([getattr(r, f) for f in fields])
