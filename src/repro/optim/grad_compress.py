"""Int8 error-feedback gradient compression for the DP all-reduce.

The cross-pod (DCI) all-reduce is the scarcest bandwidth at multi-pod scale:
compressing gradients to int8 with error feedback cuts its wire bytes 4x
s while keeping convergence (the quantization residual is carried into the
next step, so the compression error telescopes instead of accumulating).

Implemented as a shard_map-based data-parallel step: per-shard grads are
quantized against a pmax-shared scale, psum'd in int32, and dequantized;
the residual is returned as optimizer-side state.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def compressed_psum_leaf(g: jax.Array, err: jax.Array, axes):
    """(mean-reduced gradient, new error) with int8 wire payload."""
    y = g.astype(jnp.float32) + err
    n = lax.psum(1, axes)  # reduction-group size (jax<0.5: no axis_size)
    m = lax.pmax(jnp.max(jnp.abs(y)), axes)
    scale = jnp.maximum(m, 1e-12) / 127.0
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    new_err = y - q.astype(jnp.float32) * scale
    total = lax.psum(q.astype(jnp.int32), axes)  # int8-wire all-reduce
    mean = total.astype(jnp.float32) * scale / n
    return mean, new_err


def compressed_psum(grads, errors, axes: Sequence[str]):
    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    out = [compressed_psum_leaf(g, e, tuple(axes))
           for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tree, [o[0] for o in out]),
            jax.tree.unflatten(tree, [o[1] for o in out]))


def make_compressed_dp_step(loss_fn, mesh: Mesh, axes: Sequence[str] = ("data",)):
    """Data-parallel grad step with int8-EF all-reduce.

    loss_fn(params, batch) -> scalar. Params replicated; batch sharded on
    its leading dim over `axes`. Returns step(params, errors, batch) ->
    (grads_mean, new_errors, loss_mean).
    """
    axes = tuple(axes)

    def local(params, errors, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads_mean, new_errors = compressed_psum(grads, errors, axes)
        return grads_mean, new_errors, lax.pmean(loss, axes)

    pspec_batch = P(axes)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(), pspec_batch),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )


def init_errors(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
