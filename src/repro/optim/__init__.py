"""optim subpackage of the repro framework."""
