"""AdamW with global-norm clipping and optional ZeRO-1 moment sharding.

Pure-JAX (no optax dependency): moments in f32, params may be bf16.
ZeRO-1: optimizer moments are additionally sharded over the `data` axis on
the largest dimension not already model-sharded (helper below), cutting
optimizer memory by the DP degree -- the standard distributed-optimizer
trick at scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.base import ParamSpec
from repro.dist.sharding import resolve_pspec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / bc1
        nhat = nu / bc2
        step_vec = mhat / (jnp.sqrt(nhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_vec).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, metrics


# --------------------------------------------------- ZeRO-1 moment specs ---

def zero1_pspec(spec: ParamSpec, data_divisor: int) -> tuple:
    """Moment partition spec: param spec + `data` on the largest
    still-replicated, divisible dim (ZeRO-1)."""
    entries = list(spec.pspec)
    if "data" in entries:  # already FSDP/EP-sharded over data (e.g. MoE)
        return tuple(entries)
    best, best_size = None, 0
    for i, (dim, e) in enumerate(zip(spec.shape, entries)):
        if e is None and dim % data_divisor == 0 and dim > best_size:
            best, best_size = i, dim
    if best is not None:
        entries[best] = "data"
    return tuple(entries)


def moment_shardings(structure, mesh: Mesh, zero1: bool = True):
    """NamedSharding tree for mu/nu given the ParamSpec structure."""
    data = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)

    def one(s: ParamSpec):
        pspec = zero1_pspec(s, data) if zero1 else s.pspec
        return NamedSharding(mesh, resolve_pspec(pspec, mesh, s.shape))

    tree = jax.tree.map(one, structure,
                        is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"mu": tree, "nu": tree,
            "step": NamedSharding(mesh, P())}
