"""repro: layout-aware PIM characterization + multi-pod JAX LM framework.

Reproduction of "No One-Size-Fits-All: A Workload-Driven Characterization of
Bit-Parallel vs. Bit-Serial Data Layouts for Processing-using-Memory"
(Zhang & Sadredini, 2025), embedded as the planning layer of a production
JAX training/serving framework. See DESIGN.md for the system inventory.
"""

__version__ = "0.1.0"


def characterize(workload, backends=("analytic", "planner"), **kw):
    """One workload, many backends -> {backend: Report}.

    Thin re-export of :func:`repro.workloads.characterize` (imported
    lazily so `import repro` stays dependency-free).  See
    ``python -m repro --help`` for the CLI equivalent.
    """
    from repro.workloads import characterize as _characterize

    return _characterize(workload, backends=backends, **kw)
