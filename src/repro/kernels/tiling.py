"""Grid-tiling arithmetic shared by the matmul kernels and PallasBackend.

One source of truth for how a full (M, K, N) problem maps onto a Pallas
grid: block sizes are clamped *down* to the problem (never the problem
down to a tile -- the pre-PR-9 ``max(32, min(tile, dim))`` clamp is gone),
and every dimension is padded **only up to the kernel's hardware minimum
tile multiple** (TPU tiling constraints: the last dim is always a lane
multiple of 128; the second-to-last dim a dtype-dependent sublane
multiple).  Zero padding is exact for integer matmuls -- padded rows and
columns contribute nothing -- so the kernels compute the whole op and
slice the true result back out.

``OpReport`` rows record both the true and the padded dims from these
tilings, so measured wall-clocks never misstate what was actually run
(ISSUE 9 satellite: reports must not inflate small ops silently).
"""
from __future__ import annotations

import dataclasses

#: TPU lane count: the last dim of any tile is a multiple of this.
LANE = 128
#: Min sublane (second-to-last dim) multiples by operand byte width.
SUBLANE = {1: 32, 2: 16, 4: 8}


def ceil_to(x: int, m: int) -> int:
    """Round ``x`` up to the next multiple of ``m`` (min one ``m``)."""
    return max(m, ((x + m - 1) // m) * m)


def block_dim(dim: int, want: int, minimum: int) -> int:
    """Pick a grid block edge for a dimension of true size ``dim``.

    The block is a multiple of ``minimum`` (the hardware tile multiple),
    at most ``want`` rounded down to that multiple, and never larger than
    the padded problem itself -- so small problems run as a single
    hardware-minimum tile instead of being inflated to ``want``.
    """
    want = max(minimum, (want // minimum) * minimum)
    return min(want, ceil_to(dim, minimum))


@dataclasses.dataclass(frozen=True)
class MatmulTiling:
    """A full (M, K, N) problem mapped onto a Pallas grid."""

    m: int
    k: int
    n: int
    bm: int
    bk: int
    bn: int

    @property
    def pm(self) -> int:
        return ceil_to(self.m, self.bm)

    @property
    def pk(self) -> int:
        return ceil_to(self.k, self.bk)

    @property
    def pn(self) -> int:
        return ceil_to(self.n, self.bn)

    @property
    def grid(self) -> tuple[int, int, int]:
        """(M tiles, N tiles, K steps) -- K is the sequential axis."""
        return (self.pm // self.bm, self.pn // self.bn, self.pk // self.bk)

    @property
    def padded_macs(self) -> int:
        """MACs the padded problem actually performs (one plane pass)."""
        return self.pm * self.pk * self.pn

    @property
    def dims(self) -> tuple[int, int, int]:
        return (self.m, self.k, self.n)

    @property
    def padded_dims(self) -> tuple[int, int, int]:
        return (self.pm, self.pk, self.pn)


#: Hardware minimum (m, k, n) multiples per kernel family.  BP and the
#: fused BS kernel stream int8 activations [bm, bk] (sublane 32, lane
#: 128); the unfused BS kernel's packed-plane block [bits, bkg, bn] is
#: uint32 (sublane 8 *packed groups* of 32 K-rows each => K multiple 256).
BP_MIN = (SUBLANE[1], LANE, LANE)           # (32, 128, 128)
BS_MIN = (SUBLANE[1], 32 * SUBLANE[4], LANE)  # (32, 256, 128)
FUSED_MIN = BP_MIN                           # word weights, int8 x


def bp_tiling(m: int, k: int, n: int, *, block_m: int = 128,
              block_n: int = 128, block_k: int = 128) -> MatmulTiling:
    """Tiling for the bit-parallel (word) matmul kernel."""
    mm, mk, mn = BP_MIN
    return MatmulTiling(m, k, n, block_dim(m, block_m, mm),
                        block_dim(k, block_k, mk), block_dim(n, block_n, mn))


def bs_tiling(m: int, k: int, n: int, *, block_m: int = 128,
              block_n: int = 128, block_k: int = 512) -> MatmulTiling:
    """Tiling for the unfused bit-serial (packed bitplane) matmul kernel.

    ``k`` here is the *word* contraction depth; the kernel streams K in
    blocks of ``bk`` words = ``bk/32`` packed uint32 groups.
    """
    mm, mk, mn = BS_MIN
    return MatmulTiling(m, k, n, block_dim(m, block_m, mm),
                        block_dim(k, block_k, mk), block_dim(n, block_n, mn))


def fused_tiling(m: int, k: int, n: int, *, block_m: int = 128,
                 block_n: int = 128, block_k: int = 128) -> MatmulTiling:
    """Tiling for the fused bitpack-matmul kernel (word weights in VMEM)."""
    mm, mk, mn = FUSED_MIN
    return MatmulTiling(m, k, n, block_dim(m, block_m, mm),
                        block_dim(k, block_k, mk), block_dim(n, block_n, mn))
