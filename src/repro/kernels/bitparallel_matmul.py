"""Bit-parallel (word-level) int8 matmul Pallas kernel -- the BP layout.

Words stay horizontal: one MXU pass over the full-width int8 operands with
K-blocked accumulation in a VMEM scratch accumulator. 128-aligned tiles
match the MXU systolic dimensions.

Grid: (M/bm, N/bn, K/bk) with the K axis sequential ("arbitrary") so the
accumulator scratch carries across K steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(jnp.int32)


def bitparallel_matmul(x: jax.Array, w: jax.Array, *,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128,
                       interpret: bool = True) -> jax.Array:
    """x: int8 [M, K]; w: int8 [K, N] -> int32 [M, N]."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    k_steps = K // bk
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        # VMEM accumulator persisted across the sequential K axis
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
