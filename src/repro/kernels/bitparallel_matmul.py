"""Bit-parallel (word-level) integer matmul Pallas kernel -- the BP layout.

Words stay horizontal: one MXU pass over the full-width integer operands
with K-blocked accumulation in a VMEM scratch accumulator.  The kernel is
grid-tiled over the *whole* problem: arbitrary (M, K, N) are padded only
up to the hardware-minimum tile multiples (``kernels.tiling``), never
clamped down to a representative tile, and the true result is sliced back
out (zero padding is exact for integer contractions).

Accumulation is int32 (``preferred_element_type``), not float32: un-clamped
K reaches depths where f32's 24-bit mantissa silently rounds integer
partial sums (K=4096 int8 products exceed 2^24), so exactness at full
problem sizes requires the integer path.  Operands may be any integer
dtype -- int8 activations against int8/int16/int32 words -- so full-width
(>8-bit) BP passes measure honestly instead of wrapping through int8.

Grid: (M/bm, N/bn, K/bk) with the K axis sequential ("arbitrary") so the
accumulator scratch carries across K steps -- the same streaming-
accumulation idiom as ``kernels/flash_attention.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import bp_tiling


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        x_ref[...].astype(jnp.int32), w_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def bitparallel_matmul(x: jax.Array, w: jax.Array, *,
                       block_m: int = 128, block_n: int = 128,
                       block_k: int = 128,
                       interpret: bool = True) -> jax.Array:
    """x: int [M, K]; w: int [K, N] -> int32 [M, N] (exact mod 2^32)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    t = bp_tiling(M, K, N, block_m=block_m, block_n=block_n,
                  block_k=block_k)
    if (t.pm, t.pk) != (M, K):
        x = jnp.pad(x, ((0, t.pm - M), (0, t.pk - K)))
    if (t.pk, t.pn) != (K, N):
        w = jnp.pad(w, ((0, t.pk - K), (0, t.pn - N)))
    gm, gn, k_steps = t.grid
    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=(gm, gn, k_steps),
        in_specs=[
            pl.BlockSpec((t.bm, t.bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((t.bk, t.bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((t.bm, t.bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t.pm, t.pn), jnp.int32),
        # VMEM accumulator persisted across the sequential K axis
        scratch_shapes=[pltpu.VMEM((t.bm, t.bn), jnp.int32)],
        interpret=interpret,
    )(x, w)
    return out[:M, :N] if (t.pm, t.pn) != (M, N) else out
