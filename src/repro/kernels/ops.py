"""Jitted public wrappers for the Pallas kernels + the layout-aware
quantized linear op the planner drives (the paper's technique as a
first-class kernel-selection decision).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.cost_model import Layout
from repro.core.taxonomy import Recommendation, classify
from repro.kernels.bitpack import bitpack, bitunpack
from repro.kernels.bitparallel_matmul import bitparallel_matmul
from repro.kernels.bitserial_matmul import bitserial_matmul
from repro.kernels.fused_bitserial_matmul import fused_bitserial_matmul
from repro.workloads.ir import Op


def bp_weight_dtype(weight_bits: int):
    """Smallest signed dtype that holds unsigned ``weight_bits`` words
    losslessly for the BP (word) kernel.  The pre-PR-9 path cast every
    weight to int8, silently wrapping widths >= 8."""
    if weight_bits <= 7:
        return jnp.int8
    if weight_bits <= 15:
        return jnp.int16
    return jnp.int32


def thread_activations(y: jax.Array, m: int, k: int) -> jax.Array:
    """Adapt a producer step's int32 ``[M', N']`` result into a consumer
    step's int8 ``[m, k]`` activation operand.

    The deterministic dataflow adapter of the chained executor
    (DESIGN.md Sec. 15): flatten, tile/truncate to ``m * k`` elements,
    reshape, and wrap to int8 -- activations always flow in word form,
    and int32 -> int8 is the mod-2^8 requantize numpy and XLA define
    identically.  The chained program, per-step ``run_schedule``, and the
    numpy ``reference_results`` all use this exact adapter, which is what
    keeps the three bit-exact with real (not synthetic) dataflow between
    steps.  Pure jnp, so it traces into the one jitted schedule program.
    """
    flat = y.reshape(-1)
    need = m * k
    if flat.shape[0] < need:
        flat = jnp.tile(flat, -(-need // flat.shape[0]))
    return flat[:need].reshape(m, k).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack_weights(w: jax.Array, bits: int, interpret: bool = True):
    """BP -> BS layout conversion (the transpose unit)."""
    return bitpack(w, bits, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k",))
def unpack_weights(planes: jax.Array, k: int | None = None):
    """BS -> BP layout conversion (strips bitpack's K padding)."""
    return bitunpack(planes, k)


@functools.partial(jax.jit, static_argnames=(
    "interpret", "block_m", "block_n", "block_k"))
def matmul_bs(x: jax.Array, planes: jax.Array, interpret: bool = True,
              block_m: int = 128, block_n: int = 128, block_k: int = 512):
    # bitpack zero-pads K to a multiple of 32; mirror the padding on the
    # activation side (zero rows contribute nothing to the contraction)
    k_planes = planes.shape[1] * 32
    if x.shape[1] != k_planes:
        x = jnp.pad(x, ((0, 0), (0, k_planes - x.shape[1])))
    return bitserial_matmul(x, planes, interpret=interpret,
                            block_m=block_m, block_n=block_n,
                            block_k=max(block_k, 256))


@functools.partial(jax.jit, static_argnames=(
    "interpret", "block_m", "block_n", "block_k"))
def matmul_bp(x: jax.Array, w: jax.Array, interpret: bool = True,
              block_m: int = 128, block_n: int = 128, block_k: int = 128):
    return bitparallel_matmul(x, w, interpret=interpret, block_m=block_m,
                              block_n=block_n, block_k=block_k)


@functools.partial(jax.jit, static_argnames=(
    "bits", "interpret", "block_m", "block_n", "block_k"))
def matmul_bs_fused(x: jax.Array, w: jax.Array, bits: int,
                    interpret: bool = True, block_m: int = 128,
                    block_n: int = 128, block_k: int = 128):
    """One-kernel BS path: packs plane slices in VMEM and accumulates the
    plane loop without materializing the ``[bits, K/32, N]`` artifact.
    Bit-exact with ``pack_weights`` -> ``matmul_bs``."""
    return fused_bitserial_matmul(x, w, bits, interpret=interpret,
                                  block_m=block_m, block_n=block_n,
                                  block_k=block_k)


def choose_layout(*, weight_bits: int, m: int, n: int, k: int,
                  mixed_precision: bool = False) -> Recommendation:
    """Layout advisor for one quantized matmul (Table-8 features).

    Builds a canonical IR matmul op and classifies its feature lowering.
    The resident working set is derived from the *actual* operand
    footprint of the weight-stationary k-deep dot product
    (``ir.matmul_working_set_bits``: the k-element weight column plus the
    double-width accumulator) -- so deep contractions overflow the
    128-row BS column and correctly flip the recommendation to BP
    (Challenge 2).  The old implementation hardcoded ``weight_bits * 4``
    and ignored k entirely.
    """
    op = Op(name="matmul", kind="matmul", m=m, k=k, n=n, width=weight_bits,
            bit_level_fraction=1.0 if weight_bits <= 2 else
            0.7 if weight_bits <= 4 else 0.2,
            mixed_precision=mixed_precision)
    return classify(op.features()).recommendation


def planned_matmul(x: jax.Array, w: jax.Array, *, weight_bits: int,
                   plan=None, op_name: str | None = None,
                   fuse_pack: bool = False, interpret: bool = True):
    """Dispatch x @ w to the BS (bitplane) or BP (word) kernel per a
    compiled :class:`repro.plan.ir.LayoutPlan` -- the same plan the cost
    model priced.  ``plan.layout_for(op_name)`` picks the kernel; with no
    plan, fall back to the Table-8 advisor (:func:`choose_layout`).
    ``fuse_pack=True`` folds the BP->BS repack into the BS kernel itself
    (no materialized plane tensor).  w: unsigned ints < 2^weight_bits,
    [K, N].  Returns (y, Layout)."""
    m, k = x.shape
    n = w.shape[1]
    if plan is not None:
        layout = plan.layout_for(op_name)
    else:
        rec = choose_layout(weight_bits=weight_bits, m=m, n=n, k=k)
        layout = Layout.BS if rec == Recommendation.BS else Layout.BP
    if layout is Layout.BS:
        if fuse_pack:
            return (matmul_bs_fused(x, w, weight_bits, interpret=interpret),
                    Layout.BS)
        planes = pack_weights(w.astype(jnp.uint32), weight_bits,
                              interpret=interpret)
        return matmul_bs(x, planes, interpret=interpret), Layout.BS
    return (matmul_bp(x, w.astype(bp_weight_dtype(weight_bits)),
                      interpret=interpret), Layout.BP)


def layout_aware_matmul(x: jax.Array, w: jax.Array, *, weight_bits: int,
                        interpret: bool = True):
    """Advisor-driven dispatch (no plan): x @ w via the BS or BP kernel
    per the Table-8 verdict. w: unsigned ints < 2^weight_bits, [K, N]."""
    return planned_matmul(x, w, weight_bits=weight_bits,
                          interpret=interpret)
