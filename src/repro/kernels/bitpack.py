"""Bit-transposition (packing) Pallas kernel -- the on-chip transpose unit.

Converts word-layout (BP) weights into bitplane (BS) layout: words [K, N]
with values < 2^bits become uint32 planes [bits, ceil(K/32), N]. This is
the hardware transposer of paper Sec. 4.1 as a TPU kernel; the hybrid
executor charges its cost exactly like the paper charges
read(M)+core+write(N).

K need not be a multiple of 32: the packer zero-pads the K axis to the
next multiple (zero rows pack to zero bits, so downstream bit-serial
contractions are unaffected) and :func:`bitunpack` strips the padding on
the way back (round-trip pinned in tests/test_kernels.py).

Grid: (bits, K/32/bg, N/bn): each program packs `bg` groups of 32 rows for
one bit position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, o_ref, *, bg: int):
    b = pl.program_id(0)
    w = w_ref[...].astype(jnp.uint32)  # [bg*32, bn]
    bit = (w >> b) & jnp.uint32(1)
    grouped = bit.reshape(bg, 32, w.shape[-1])
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    o_ref[0] = jnp.sum(grouped * weights[None, :, None], axis=1,
                       dtype=jnp.uint32)


def bitpack(w: jax.Array, bits: int, *, block_groups: int = 4,
            block_n: int = 256, interpret: bool = True) -> jax.Array:
    """w: unsigned words [K, N] (values < 2^bits) -> uint32
    [bits, ceil(K/32), N]; K is zero-padded to the next multiple of 32."""
    K, N = w.shape
    pad = -K % 32
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    Kg = (K + pad) // 32
    bg = min(block_groups, Kg)
    while Kg % bg:
        bg -= 1
    bn = min(block_n, N)
    while N % bn:
        bn //= 2
    return pl.pallas_call(
        functools.partial(_kernel, bg=bg),
        grid=(bits, Kg // bg, N // bn),
        in_specs=[pl.BlockSpec((bg * 32, bn), lambda b, g, n: (g, n))],
        out_specs=pl.BlockSpec((1, bg, bn), lambda b, g, n: (b, g, n)),
        out_shape=jax.ShapeDtypeStruct((bits, Kg, N), jnp.uint32),
        interpret=interpret,
    )(w)


def bitunpack(planes: jax.Array, k: int | None = None) -> jax.Array:
    """Inverse of :func:`bitpack`: uint32 planes [bits, Kg, N] -> words
    [k, N] (uint32), stripping the zero rows the packer added
    (``k`` defaults to the full ``Kg * 32``)."""
    bits, Kg, N = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    rows = ((planes[:, :, None, :] >> shifts[None, None, :, None])
            & jnp.uint32(1)).reshape(bits, Kg * 32, N)
    words = jnp.zeros((Kg * 32, N), jnp.uint32)
    for b in range(bits):
        words = words | (rows[b] << jnp.uint32(b))
    return words if k is None else words[:k]
