"""Bit-transposition (packing) Pallas kernel -- the on-chip transpose unit.

Converts word-layout (BP) weights into bitplane (BS) layout: words [K, N]
with values < 2^bits become uint32 planes [bits, K//32, N]. This is the
hardware transposer of paper Sec. 4.1 as a TPU kernel; the hybrid executor
charges its cost exactly like the paper charges read(M)+core+write(N).

Grid: (bits, K/32/bg, N/bn): each program packs `bg` groups of 32 rows for
one bit position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, o_ref, *, bg: int):
    b = pl.program_id(0)
    w = w_ref[...].astype(jnp.uint32)  # [bg*32, bn]
    bit = (w >> b) & jnp.uint32(1)
    grouped = bit.reshape(bg, 32, w.shape[-1])
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    o_ref[0] = jnp.sum(grouped * weights[None, :, None], axis=1,
                       dtype=jnp.uint32)


def bitpack(w: jax.Array, bits: int, *, block_groups: int = 4,
            block_n: int = 256, interpret: bool = True) -> jax.Array:
    """w: unsigned words [K, N] (values < 2^bits) -> uint32 [bits, K//32, N]."""
    K, N = w.shape
    assert K % 32 == 0
    Kg = K // 32
    bg = min(block_groups, Kg)
    while Kg % bg:
        bg -= 1
    bn = min(block_n, N)
    while N % bn:
        bn //= 2
    return pl.pallas_call(
        functools.partial(_kernel, bg=bg),
        grid=(bits, Kg // bg, N // bn),
        in_specs=[pl.BlockSpec((bg * 32, bn), lambda b, g, n: (g, n))],
        out_specs=pl.BlockSpec((1, bg, bn), lambda b, g, n: (b, g, n)),
        out_shape=jax.ShapeDtypeStruct((bits, Kg, N), jnp.uint32),
        interpret=interpret,
    )(w)
