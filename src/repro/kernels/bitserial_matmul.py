"""Bit-serial (bitplane) matmul Pallas kernel -- the TPU-native BS layout.

The paper's BS column ALU processes one bit-position of every element per
cycle.  The TPU analogue is *bit-slicing*: an unsigned `bits`-wide weight
matrix is stored as `bits` 1-bit planes (32 K-rows packed per uint32 word),
and y = x @ W is computed plane-by-plane:

    y = sum_b 2^b * (x @ plane_b)

Each plane's product is a binary-matrix contraction: the kernel unpacks the
plane tile in VMEM (shift+mask -- the "sense amplifier read" of the slice)
and feeds the MXU with a 0/1 operand.  Low-precision weights cost
proportionally fewer plane passes -- exactly the BS latency scaling
(Table 2: N-bit -> N cycles), while dense full-width BP costs one pass.

Grid: (M/bm, N/bn, Kg/bkg) -- the whole problem, with K streamed in
packed-group blocks along the sequential axis and partial sums carried in
a VMEM int32 accumulator (flash-attention-style streaming; f32
accumulation would round un-clamped K, see bitparallel_matmul).  Arbitrary
(M, N, K) are padded to the hardware-minimum tile multiples only
(``kernels.tiling.bs_tiling``) and the true result sliced back out.
Results are exact integers mod 2^32 (int32 wraparound arithmetic agrees
with the unbounded-integer reference mod 2^32 at any width <= 32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import bs_tiling


def _kernel(x_ref, planes_ref, o_ref, acc_ref, *, bits: int, bk: int,
            k_steps: int):
    # x_ref: [bm, bk] int ; planes_ref: [bits, bk//32, bn] uint32
    # o_ref / acc_ref: [bm, bn] int32
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)  # MXU operand
    shifts = jnp.arange(32, dtype=jnp.uint32)
    acc = acc_ref[...]
    for b in range(bits):  # bit-serial plane loop
        packed = planes_ref[b]  # [bk//32, bn] uint32
        bits_kn = ((packed[:, None, :] >> shifts[None, :, None])
                   & jnp.uint32(1))  # [bk//32, 32, bn]
        plane = bits_kn.reshape(bk, -1).astype(jnp.int32)
        acc = acc + (jax.lax.dot(x, plane,
                                 preferred_element_type=jnp.int32) << b)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def bitserial_matmul(x: jax.Array, planes: jax.Array, *,
                     block_m: int = 128, block_n: int = 128,
                     block_k: int = 512,
                     interpret: bool = True) -> jax.Array:
    """x: int [M, K]; planes: uint32 [bits, K//32, N] -> int32 [M, N]."""
    M, K = x.shape
    bits, Kg, N = planes.shape
    # bitpack zero-pads ragged K into whole 32-row groups; those zero plane
    # rows kill whatever x carries there, so padding x up is exact too.
    assert Kg * 32 >= K, (K, Kg)
    K = Kg * 32
    t = bs_tiling(M, K, N, block_m=block_m, block_n=block_n,
                  block_k=block_k)
    if (t.pm, t.pk) != x.shape:
        x = jnp.pad(x, ((0, t.pm - M), (0, t.pk - x.shape[1])))
    pkg = t.pk // 32
    if (pkg, t.pn) != (Kg, N):
        # zero plane groups / columns contribute nothing to the dot
        planes = jnp.pad(planes, ((0, 0), (0, pkg - Kg), (0, t.pn - N)))
    gm, gn, k_steps = t.grid
    bkg = t.bk // 32
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, bk=t.bk, k_steps=k_steps),
        grid=(gm, gn, k_steps),
        in_specs=[
            pl.BlockSpec((t.bm, t.bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bits, bkg, t.bn), lambda i, j, k: (0, k, j)),
        ],
        out_specs=pl.BlockSpec((t.bm, t.bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t.pm, t.pn), jnp.int32),
        # VMEM accumulator persisted across the sequential K axis
        scratch_shapes=[pltpu.VMEM((t.bm, t.bn), jnp.int32)],
        interpret=interpret,
    )(x, planes)
    return out[:M, :N] if (t.pm, t.pn) != (M, N) else out
