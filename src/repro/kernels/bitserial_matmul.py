"""Bit-serial (bitplane) matmul Pallas kernel -- the TPU-native BS layout.

The paper's BS column ALU processes one bit-position of every element per
cycle. The TPU analogue is *bit-slicing*: an unsigned `bits`-wide weight
matrix is stored as `bits` 1-bit planes (32 K-rows packed per uint32 word),
and y = x @ W is computed plane-by-plane:

    y = sum_b 2^b * (x @ plane_b)

Each plane's product is a binary-matrix contraction: the kernel unpacks the
plane tile in VMEM (shift+mask -- the "sense amplifier read" of the slice)
and feeds the MXU with a 0/1 operand. Low-precision weights cost
proportionally fewer plane passes -- exactly the BS latency scaling
(Table 2: N-bit -> N cycles), while dense int8 BP costs one full-width pass.

Grid: (M/bm, N/bn); K is kept resident per tile (weights stream plane-wise).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, planes_ref, o_ref, *, bits: int, K: int):
    # x_ref: [bm, K] int8 ; planes_ref: [bits, K//32, bn] uint32
    # o_ref: [bm, bn] int32
    x = x_ref[...].astype(jnp.float32)  # MXU operand
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    for b in range(bits):  # bit-serial plane loop
        packed = planes_ref[b]  # [K//32, bn] uint32
        bits_kn = ((packed[:, None, :] >> shifts[None, :, None])
                   & jnp.uint32(1))  # [K//32, 32, bn]
        plane = bits_kn.reshape(K, -1).astype(jnp.float32)
        acc = acc + jnp.float32(1 << b) * jax.lax.dot(
            x, plane, precision=jax.lax.Precision.HIGHEST)
    o_ref[...] = acc.astype(jnp.int32)


def bitserial_matmul(x: jax.Array, planes: jax.Array, *,
                     block_m: int = 128, block_n: int = 128,
                     interpret: bool = True) -> jax.Array:
    """x: int8 [M, K]; planes: uint32 [bits, K//32, N] -> int32 [M, N]."""
    M, K = x.shape
    bits, Kg, N = planes.shape
    assert Kg * 32 == K, (K, Kg)
    bm, bn = min(block_m, M), min(block_n, N)
    assert M % bm == 0 and N % bn == 0
    grid = (M // bm, N // bn)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, K=K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bits, Kg, bn), lambda i, j: (0, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(x, planes)
