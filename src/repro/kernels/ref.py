"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------- bitpack oracle ----

def bitpack_ref(w: jax.Array, bits: int) -> jax.Array:
    """Words [K, N] (unsigned values < 2^bits) -> packed bitplanes
    uint32 [bits, K//32, N]: plane b, word g packs bit b of rows
    32g..32g+31 (row r -> bit position r%32). The transpose-unit analogue."""
    K, N = w.shape
    assert K % 32 == 0
    w = w.astype(jnp.uint32)
    out = []
    for b in range(bits):
        bitsel = (w >> b) & 1  # [K, N]
        grouped = bitsel.reshape(K // 32, 32, N)
        weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
        out.append(jnp.sum(grouped * weights[None, :, None], axis=1,
                           dtype=jnp.uint32))
    return jnp.stack(out)


def bitunpack_ref(planes: jax.Array, K: int) -> jax.Array:
    """Inverse of bitpack_ref -> words [K, N] uint32."""
    bits, Kg, N = planes.shape
    assert Kg * 32 == K
    shifts = jnp.arange(32, dtype=jnp.uint32)
    w = jnp.zeros((K, N), jnp.uint32)
    for b in range(bits):
        bitsel = (planes[b][:, None, :] >> shifts[None, :, None]) & 1
        w = w | (bitsel.reshape(K, N) << b)
    return w


# ----------------------------------------- bit-serial matmul oracle --------

def bitserial_matmul_ref(x: jax.Array, planes: jax.Array) -> jax.Array:
    """y = x @ W where W is bitplane-packed (uint32 [bits, K//32, N],
    unsigned). x: int8/int32 [M, K]. Returns int32 [M, N]."""
    bits, Kg, N = planes.shape
    K = Kg * 32
    w = bitunpack_ref(planes, K).astype(jnp.int32)  # [K, N]
    return x.astype(jnp.int32) @ w


# ----------------------------------------- bit-parallel matmul oracle ------

def bitparallel_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Word-level int8 matmul -> int32 (the MXU analogue of BP)."""
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


# --------------------------------------------- flash attention oracle ------

def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Plain quadratic attention (MHA, no GQA grouping), f32 math."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(jnp.float32(D))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
