"""pallas-bench: the measured wall-clock trajectory of the Pallas kernels.

One run times every (shape, width, kernel-path) case over the *full*
un-clamped problem -- the Table-5/Table-6 matmul shapes (GEMM 400^3,
GEMV 1x4096x512, the VGG classifier FCs) at weight widths {1, 4, 8, 16}
-- through three paths:

* ``bp``          -- the grid-tiled bit-parallel word kernel,
* ``bs_fused``    -- the one-kernel fused bitpack-matmul,
* ``bs_unfused``  -- ``pack_weights`` -> ``matmul_bs`` with the pack pass
  *on* the timed path (the materialized-plane-artifact cost fusion
  removes; the fused-vs-unfused delta is the point of the comparison).

Each case is the median of ``reps`` post-warmup calls with
``block_until_ready``.  The payload is committed to ``BENCH_pallas.json``
under the ``repro.artifacts`` envelope and gated in CI by
:func:`check_pallas_regression` (per-case medians, noise-tolerant
threshold + floor, exit 3 on regression -- the serve-bench idiom).

On this CPU container the absolute numbers are interpret-mode
correctness-path timings, not TPU performance; the *trajectory* (ratios
across widths, fused vs unfused, and run-over-run regressions) is what
the gate protects.
"""
from __future__ import annotations

import statistics
import time
from typing import Optional

import numpy as np

#: Table-5/Table-6 matmul shapes (name, (m, k, n)) -- the full problem
#: sizes the un-clamped kernels now measure end to end.
BENCH_SHAPES: tuple[tuple[str, tuple[int, int, int]], ...] = (
    ("gemm", (400, 400, 400)),     # Table-5/6 GEMM (mk/gemm op)
    ("gemv", (1, 4096, 512)),      # Table-6 GEMV
    ("vgg_fc", (1, 512, 512)),     # VGG classifier fc0/fc1
    ("vgg_fc_out", (1, 512, 10)),  # VGG classifier fc2 (ragged N)
)
#: weight widths: the paper's low-precision sweep + full INT16
BENCH_WIDTHS: tuple[int, ...] = (1, 4, 8, 16)
#: quick (CI smoke) subset: the committed acceptance widths
QUICK_WIDTHS: tuple[int, ...] = (4, 8, 16)


def _clock(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # warmup / compile
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def run_pallas_bench(*, quick: bool = False, reps: Optional[int] = None,
                     seed: int = 0, interpret: bool = True,
                     shapes=None, widths=None) -> dict:
    """Time every case; returns the BENCH_pallas.json payload dict."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.kernels import tiling as tl

    if shapes is None:
        shapes = BENCH_SHAPES
    if reps is None:
        reps = 2 if quick else 5
    if widths is None:
        widths = QUICK_WIDTHS if quick else BENCH_WIDTHS
    rng = np.random.default_rng(seed)
    cases = []
    for shape_name, (m, k, n) in shapes:
        x = jnp.asarray(rng.integers(-8, 8, (m, k), dtype=np.int32)
                        ).astype(jnp.int8)
        for bits in widths:
            w = jnp.asarray(rng.integers(0, 1 << min(bits, 31),
                                         (k, n)).astype(np.int32))
            wp = w.astype(kops.bp_weight_dtype(bits))
            wu = w.astype(jnp.uint32)

            def bs_unfused(wu=wu, x=x, bits=bits):
                planes = kops.pack_weights(wu, bits, interpret=interpret)
                return kops.matmul_bs(x, planes, interpret=interpret)

            paths = (
                ("bp", tl.bp_tiling(m, k, n),
                 lambda x=x, wp=wp: kops.matmul_bp(
                     x, wp, interpret=interpret)),
                ("bs_fused", tl.fused_tiling(m, k, n),
                 lambda x=x, w=w, bits=bits: kops.matmul_bs_fused(
                     x, w, bits, interpret=interpret)),
                ("bs_unfused", tl.bs_tiling(m, k, n), bs_unfused),
            )
            for path, tiling, fn in paths:
                cases.append({
                    "name": f"{shape_name}/w{bits}/{path}",
                    "shape": [m, k, n], "width": bits, "path": path,
                    "padded": list(tiling.padded_dims),
                    "us": _clock(fn, reps),
                })
    return {"reps": reps, "quick": quick, "interpret": interpret,
            "seed": seed, "cases": cases}


def check_pallas_regression(payload: dict, baseline_payload: dict,
                            threshold: float = 0.5,
                            floor_us: float = 2000.0
                            ) -> tuple[bool, str]:
    """CI gate: ``(ok, message)``; fails when any case's median exceeds
    its committed baseline by more than ``threshold``.

    ``floor_us`` clamps the baseline: sub-millisecond interpret-mode
    medians double under shared-runner jitter without meaning anything,
    so cases under ``floor_us * (1 + threshold)`` always pass and the
    gate targets systematic multi-x regressions (a kernel falling off
    the grid-tiled path, a fusion silently re-materializing planes).
    Cases with no baseline entry (new shapes/widths) pass with a note.
    """
    base = {c["name"]: c for c in baseline_payload.get("cases", ())}
    failures, checked, new = [], 0, 0
    for c in payload.get("cases", ()):
        b = base.get(c["name"])
        if b is None:
            new += 1
            continue
        checked += 1
        ref = max(b["us"], floor_us)
        if c["us"] > ref * (1.0 + threshold):
            failures.append(f"{c['name']}: {c['us']:.0f}us vs baseline "
                            f"{b['us']:.0f}us (x{c['us'] / ref:.2f}, "
                            f"budget x{1 + threshold:.2f})")
    msg = (f"{checked} case(s) gated, {new} new, "
           f"{len(failures)} regression(s)")
    if failures:
        msg += " -- " + "; ".join(failures)
    return not failures, msg
