"""pallas-bench: the measured wall-clock trajectory of the Pallas kernels.

One run times every (shape, width, kernel-path) case over the *full*
un-clamped problem -- the Table-5/Table-6 matmul shapes (GEMM 400^3,
GEMV 1x4096x512, the VGG classifier FCs) at weight widths {1, 4, 8, 16}
-- through three paths:

* ``bp``          -- the grid-tiled bit-parallel word kernel,
* ``bs_fused``    -- the one-kernel fused bitpack-matmul,
* ``bs_unfused``  -- ``pack_weights`` -> ``matmul_bs`` with the pack pass
  *on* the timed path (the materialized-plane-artifact cost fusion
  removes; the fused-vs-unfused delta is the point of the comparison).

With ``chained=True`` the run also times whole-schedule execution for
the multi-step Table-6 apps (:data:`CHAINED_APPS`): the per-step host
dispatch of ``run_schedule`` vs the ONE-jitted-program executor of
``plan.pallas_exec`` (weights device-resident, step outputs threaded,
one host round-trip).  The ``chained/<app>/{per_step,chained}`` pair per
app is the measured cost of host-side schedule dispatch -- the delta a
real PIM controller never pays -- and both paths are asserted bit-exact
before their timings enter the artifact.

Each case is the median of ``reps`` post-warmup calls with
``block_until_ready``.  The payload is committed to ``BENCH_pallas.json``
under the ``repro.artifacts`` envelope and gated in CI by
:func:`check_pallas_regression` (per-case medians, noise-tolerant
threshold + floor, exit 3 on regression -- the serve-bench idiom).

On this CPU container the absolute numbers are interpret-mode
correctness-path timings, not TPU performance; the *trajectory* (ratios
across widths, fused vs unfused, and run-over-run regressions) is what
the gate protects.
"""
from __future__ import annotations

import statistics
import time
from typing import Optional

import numpy as np

#: Table-5/Table-6 matmul shapes (name, (m, k, n)) -- the full problem
#: sizes the un-clamped kernels now measure end to end.
BENCH_SHAPES: tuple[tuple[str, tuple[int, int, int]], ...] = (
    ("gemm", (400, 400, 400)),     # Table-5/6 GEMM (mk/gemm op)
    ("gemv", (1, 4096, 512)),      # Table-6 GEMV
    ("vgg_fc", (1, 512, 512)),     # VGG classifier fc0/fc1
    ("vgg_fc_out", (1, 512, 10)),  # VGG classifier fc2 (ragged N)
)
#: weight widths: the paper's low-precision sweep + full INT16
BENCH_WIDTHS: tuple[int, ...] = (1, 4, 8, 16)
#: quick (CI smoke) subset: the committed acceptance widths
QUICK_WIDTHS: tuple[int, ...] = (4, 8, 16)
#: apps for the chained-vs-per-step pair: the VGG classifier chains
#: (3 measured FC steps each; convs exceed any honest interpret-mode
#: budget and stay modelled) + the single-step GEMV control
CHAINED_APPS: tuple[str, ...] = ("vgg13", "vgg16", "vgg19", "gemv")


def _clock(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # warmup / compile
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)


def run_chained_bench(*, apps=CHAINED_APPS, reps: int = 5, seed: int = 0,
                      interpret: bool = True,
                      max_macs: Optional[int] = None
                      ) -> tuple[list[dict], dict]:
    """Chained-vs-per-step pairs: ``(cases, per-app meta)``.

    ``chained/<app>/per_step`` times :func:`plan.pallas.run_schedule` --
    one jitted-wrapper dispatch, weight conversion, and host transfer
    per measured step.  ``chained/<app>/chained`` times the warm
    ``ScheduleExecutable.run()`` of the same schedule -- weights already
    device-resident, outputs threaded in-program, one host round-trip.
    Identical threaded dataflow on both paths, asserted bit-exact before
    either timing enters the artifact.
    """
    from repro.plan import (compile_plan, compile_schedule,
                            lower_plan_pallas, run_schedule, synth_inputs)
    from repro.workloads import get_workload

    cases: list[dict] = []
    meta: dict = {}
    for app in apps:
        w = get_workload(app)
        kwargs = {} if max_macs is None else {"max_macs": max_macs}
        sched = lower_plan_pallas(compile_plan(w), w, **kwargs)
        n_meas = len(sched.measured_steps)
        if not n_meas:
            meta[app] = {"skipped": "no measured steps under budget"}
            continue
        inputs = synth_inputs(sched, seed=seed)
        per_us = _clock(
            lambda: run_schedule(sched, inputs, interpret=interpret), reps)
        exe = compile_schedule(sched, inputs, interpret=interpret)
        chained_us = _clock(exe.run, reps)
        per = run_schedule(sched, inputs, interpret=interpret)
        got = exe.run()
        for op, y in got.items():
            assert np.array_equal(y, per[op]), \
                f"chained/per-step divergence at {app}:{op}"
        base = {"app": app, "steps": n_meas,
                "width": sched.measured_steps[0].width}
        cases.append({**base, "name": f"chained/{app}/per_step",
                      "path": "per_step", "us": per_us})
        cases.append({**base, "name": f"chained/{app}/chained",
                      "path": "chained", "us": chained_us})
        meta[app] = {"steps": n_meas,
                     "modelled": len(sched.steps) - n_meas,
                     "compile_us": exe.compile_us,
                     "per_step_us": per_us, "chained_us": chained_us,
                     "speedup": per_us / chained_us}
    return cases, meta


def run_pallas_bench(*, quick: bool = False, reps: Optional[int] = None,
                     seed: int = 0, interpret: bool = True,
                     shapes=None, widths=None, chained: bool = False,
                     chained_apps=None) -> dict:
    """Time every case; returns the BENCH_pallas.json payload dict."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops
    from repro.kernels import tiling as tl
    from repro.util import rand_words

    if shapes is None:
        shapes = BENCH_SHAPES
    if reps is None:
        reps = 2 if quick else 5
    if widths is None:
        widths = QUICK_WIDTHS if quick else BENCH_WIDTHS
    rng = np.random.default_rng(seed)
    cases = []
    for shape_name, (m, k, n) in shapes:
        x = jnp.asarray(rng.integers(-8, 8, (m, k), dtype=np.int32)
                        ).astype(jnp.int8)
        for bits in widths:
            w = jnp.asarray(rand_words(rng, bits, (k, n)))
            wp = w.astype(kops.bp_weight_dtype(bits))
            wu = w.astype(jnp.uint32)

            def bs_unfused(wu=wu, x=x, bits=bits):
                planes = kops.pack_weights(wu, bits, interpret=interpret)
                return kops.matmul_bs(x, planes, interpret=interpret)

            paths = (
                ("bp", tl.bp_tiling(m, k, n),
                 lambda x=x, wp=wp: kops.matmul_bp(
                     x, wp, interpret=interpret)),
                ("bs_fused", tl.fused_tiling(m, k, n),
                 lambda x=x, w=w, bits=bits: kops.matmul_bs_fused(
                     x, w, bits, interpret=interpret)),
                ("bs_unfused", tl.bs_tiling(m, k, n), bs_unfused),
            )
            for path, tiling, fn in paths:
                cases.append({
                    "name": f"{shape_name}/w{bits}/{path}",
                    "shape": [m, k, n], "width": bits, "path": path,
                    "padded": list(tiling.padded_dims),
                    "us": _clock(fn, reps),
                })
    payload = {"reps": reps, "quick": quick, "interpret": interpret,
               "seed": seed, "cases": cases}
    if chained:
        ch_cases, ch_meta = run_chained_bench(
            apps=chained_apps or CHAINED_APPS, reps=reps, seed=seed,
            interpret=interpret)
        cases.extend(ch_cases)
        payload["chained"] = ch_meta
    return payload


def check_pallas_regression(payload: dict, baseline_payload: dict,
                            threshold: float = 0.5,
                            floor_us: float = 2000.0
                            ) -> tuple[bool, str]:
    """CI gate: ``(ok, message)``; fails when any case's median exceeds
    its committed baseline by more than ``threshold``.

    ``floor_us`` clamps the baseline: sub-millisecond interpret-mode
    medians double under shared-runner jitter without meaning anything,
    so cases under ``floor_us * (1 + threshold)`` always pass and the
    gate targets systematic multi-x regressions (a kernel falling off
    the grid-tiled path, a fusion silently re-materializing planes).
    Cases with no baseline entry (new shapes/widths) pass with a note.
    """
    base = {c["name"]: c for c in baseline_payload.get("cases", ())}
    failures, checked, new = [], 0, 0
    for c in payload.get("cases", ()):
        b = base.get(c["name"])
        if b is None:
            new += 1
            continue
        checked += 1
        ref = max(b["us"], floor_us)
        if c["us"] > ref * (1.0 + threshold):
            failures.append(f"{c['name']}: {c['us']:.0f}us vs baseline "
                            f"{b['us']:.0f}us (x{c['us'] / ref:.2f}, "
                            f"budget x{1 + threshold:.2f})")
    msg = (f"{checked} case(s) gated, {new} new, "
           f"{len(failures)} regression(s)")
    if failures:
        msg += " -- " + "; ".join(failures)
    return not failures, msg
