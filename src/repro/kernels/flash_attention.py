"""Flash attention Pallas kernel (TPU target, validated in interpret mode).

The pure-JAX streaming attention in models/layers.py materializes the
per-chunk score/probability tensors at HLO boundaries -- the dominant memory
term in the train/prefill rooflines. This kernel keeps the q-tile, running
max/denominator and output accumulator in VMEM scratch across the sequential
KV axis, so HBM traffic is exactly q+k+v read once and o written once.

Grid: (batch*heads, Sq/bq, Sk/bk); KV axis sequential, scratch carries.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int, k_steps: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            precision=jax.lax.Precision.HIGHEST) * scale
    if causal:
        iq = pl.program_id(1)
        q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, precision=jax.lax.Precision.HIGHEST)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == k_steps - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B, Sq, H, D]; k, v: [B, Sk, H, D] (MHA; GQA callers repeat KV).
    Returns [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    scale = 1.0 / math.sqrt(D)
    k_steps = Sk // bk

    qr = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * H, Sk, D)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, k_steps=k_steps),
        grid=(B * H, Sq // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
