"""Fused bitpack + bit-serial matmul: one kernel, no plane artifact.

The unfused BS hot path is two passes -- ``bitpack`` materialises a
``[bits, K/32, N]`` uint32 plane tensor in HBM, then ``bitserial_matmul``
streams it back in.  This kernel fuses the pack into the matmul: each grid
step loads the *word* weight tile ``[bk, bn]``, slices plane ``b`` in VMEM
with a shift+mask (``(w >> b) & 1`` -- the bitpack inner loop, minus the
popcount packing that only existed to make an HBM-resident artifact), and
accumulates ``(x @ plane_b) << b`` into the int32 scratch carried across
the sequential K axis -- the flash-attention streaming idiom: no
intermediate tensor ever round-trips to HBM.

The layout story is unchanged -- the weight matrix is still *consumed*
bit-serially, ``bits`` MXU plane passes, so latency scales with precision
exactly as the unfused kernel (Table 2) -- only the pack pass stops being
a separately timed, separately stored artifact.  Weights must be
unsigned ``bits``-wide values (any int dtype holding them); results are
bit-exact with ``bitpack`` -> ``bitserial_matmul`` and with
``ref.bitserial_matmul_ref`` (int32 wraparound semantics, see
``bitparallel_matmul``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiling import fused_tiling


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, bits: int, k_steps: int):
    # x_ref: [bm, bk] int ; w_ref: [bk, bn] unsigned words (int storage)
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.uint32)
    acc = acc_ref[...]
    for b in range(bits):  # in-register bitpack: slice plane b of the tile
        plane = ((w >> b) & jnp.uint32(1)).astype(jnp.int32)
        acc = acc + (jax.lax.dot(x, plane,
                                 preferred_element_type=jnp.int32) << b)
    acc_ref[...] = acc

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def fused_bitserial_matmul(x: jax.Array, w: jax.Array, bits: int, *,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """x: int [M, K]; w: unsigned ``bits``-wide words [K, N] -> int32 [M, N]."""
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (K, K2)
    t = fused_tiling(M, K, N, block_m=block_m, block_n=block_n,
                     block_k=block_k)
    if (t.pm, t.pk) != (M, K):
        x = jnp.pad(x, ((0, t.pm - M), (0, t.pk - K)))
    if (t.pk, t.pn) != (K, N):
        w = jnp.pad(w, ((0, t.pk - K), (0, t.pn - N)))
    gm, gn, k_steps = t.grid
    out = pl.pallas_call(
        functools.partial(_kernel, bits=bits, k_steps=k_steps),
        grid=(gm, gn, k_steps),
        in_specs=[
            pl.BlockSpec((t.bm, t.bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((t.bk, t.bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((t.bm, t.bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t.pm, t.pn), jnp.int32),
        # VMEM accumulator persisted across the sequential K axis
        scratch_shapes=[pltpu.VMEM((t.bm, t.bn), jnp.int32)],
        interpret=interpret,
    )(x, w)
    return out[:M, :N] if (t.pm, t.pn) != (M, N) else out
