"""``python -m repro``: the workload-IR command line.

Subcommands:

* ``list``          -- registered workloads (Table-5 / Table-6 / arch) and
                       backends.
* ``characterize``  -- run one or more workloads through one or more
                       backends and print per-backend BP/BS/hybrid
                       reports.  ``--quick`` is the CI smoke mode: every
                       table5+table6 workload through the cycle backends,
                       summaries written to
                       ``bench-artifacts/characterize.json``.
* ``tables``        -- the model-reproduced paper tables (the golden
                       snapshot text; see tests/golden/paper_tables.txt).

Examples::

    python -m repro list
    python -m repro characterize vgg --backends analytic,planner,executor
    python -m repro characterize mk/multu aes --ops
    python -m repro characterize --quick
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

def _artifact_dir() -> str:
    return os.environ.get("REPRO_BENCH_ARTIFACT_DIR", "bench-artifacts")


def _fmt_summary(summary: dict) -> str:
    parts = []
    for key, val in summary.items():
        if isinstance(val, float):
            parts.append(f"{key}={val:.3f}")
        else:
            parts.append(f"{key}={val}")
    return " ".join(parts)


def _print_report(report, show_ops: bool, max_ops: int = 24) -> None:
    print(f"  [{report.backend}] {_fmt_summary(report.summary)}")
    for note in report.notes:
        print(f"    note: {note}")
    if not show_ops:
        return
    shown = report.ops[:max_ops]
    for op in shown:
        if not op.supported:
            print(f"    {op.op:20s} {op.kind:9s} unsupported: {op.note}")
        elif op.bp_us is not None:
            print(f"    {op.op:20s} {op.kind:9s} "
                  f"bp={op.bp_us:9.1f}us bs={op.bs_us:9.1f}us  {op.note}")
        else:
            print(f"    {op.op:20s} {op.kind:9s} "
                  f"bp={op.bp_cycles:>12d} bs={op.bs_cycles:>12d}  {op.note}")
    if len(report.ops) > max_ops:
        print(f"    ... ({len(report.ops) - max_ops} more ops; "
              "use --json for the full report)")


def cmd_list(args) -> int:
    from repro.workloads import BACKENDS, list_workloads
    from repro.workloads.registry import ALIASES

    rows = list_workloads(args.source)
    width = max(len(r["name"]) for r in rows) + 2
    cur = None
    for r in rows:
        if r["source"] != cur:
            cur = r["source"]
            print(f"\n# source: {cur}")
        print(f"{r['name']:{width}s}{r['description']}")
    print("\n# aliases")
    for alias, target in sorted(ALIASES.items()):
        print(f"{alias:{width}s}-> {target}")
    print("\n# backends")
    print(", ".join(sorted(BACKENDS)))
    return 0


def cmd_characterize(args) -> int:
    from repro.workloads import characterize, workload_names

    spec = args.backends or ("analytic,planner,executor" if args.quick
                             else "analytic,planner")
    backends = [b.strip() for b in spec.split(",") if b.strip()]
    names = list(args.workloads)
    if args.quick and not names:
        # CI smoke scope: the analytic registries (arch/ workloads need
        # the jax model stack and are opt-in by name)
        names = workload_names("table5") + workload_names("table6")
    if not names:
        print("error: no workloads given (or use --quick)", file=sys.stderr)
        return 2
    artifact: dict[str, dict] = {}
    full: dict[str, dict] = {}
    for name in names:
        reports = characterize(name, backends=backends)
        print(f"{name}:")
        for rep in reports.values():
            _print_report(rep, show_ops=args.ops)
        artifact[name] = {b: rep.summary for b, rep in reports.items()}
        if args.json:
            full[name] = {b: dataclasses.asdict(rep)
                          for b, rep in reports.items()}
    if args.quick:
        os.makedirs(_artifact_dir(), exist_ok=True)
        path = os.path.join(_artifact_dir(), "characterize.json")
        with open(path, "w") as f:
            json.dump(artifact, f, indent=1, sort_keys=True)
        print(f"\n# wrote per-workload per-backend summaries to {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(full, f, indent=1, sort_keys=True)
        print(f"# wrote full reports to {args.json}")
    return 0


def cmd_tables(args) -> int:
    del args
    from repro.core.paper_tables import golden_snapshot

    print(golden_snapshot(), end="")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="registered workloads and backends")
    p_list.add_argument("--source", choices=("table5", "table6", "arch"),
                        default=None)
    p_list.set_defaults(fn=cmd_list)

    p_char = sub.add_parser(
        "characterize", help="run workloads through backends")
    p_char.add_argument("workloads", nargs="*",
                        help="registry names (e.g. vgg, mk/multu, "
                             "arch/tinyllama_1_1b)")
    p_char.add_argument("--backends", default=None,
                        help="comma list: analytic,planner,executor,pallas "
                             "(default analytic,planner; --quick adds "
                             "executor)")
    p_char.add_argument("--ops", action="store_true",
                        help="print per-op rows, not just summaries")
    p_char.add_argument("--quick", action="store_true",
                        help="CI smoke: all table5+table6 workloads, "
                             "summaries to bench-artifacts/characterize.json")
    p_char.add_argument("--json", default=None, metavar="PATH",
                        help="dump full reports (per-op rows) as JSON")
    p_char.set_defaults(fn=cmd_characterize)

    p_tab = sub.add_parser("tables", help="model-reproduced paper tables")
    p_tab.set_defaults(fn=cmd_tables)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
