"""``python -m repro``: the workload-IR command line.

Subcommands:

* ``list``          -- registered workloads (Table-5 / Table-6 / arch) and
                       backends.
* ``characterize``  -- run one or more workloads through one or more
                       backends and print per-backend BP/BS/hybrid
                       reports.  ``--quick`` is the CI smoke mode: every
                       table5+table6 workload through the cycle backends,
                       summaries written to
                       ``bench-artifacts/characterize.json``.
                       ``--geometry RxCxA[@BW]`` re-costs under a
                       non-default system geometry.
* ``plan``          -- compile workloads into executable layout plans
                       (repro.plan): per-op BP/BS assignment with explicit
                       transposes, geometry feasibility, optional executor
                       replay (``--execute``).  ``--quick`` is the CI
                       smoke: every Table-6 app's plan to
                       ``bench-artifacts/plans.json``.
* ``sweep``         -- the design-space sweep engine (repro.sweep):
                       workloads x widths x iso-area geometries in one
                       jitted batched evaluation, content-hash cached;
                       writes ``bench-artifacts/sweep.json`` and
                       ``bench-artifacts/guidelines.json``.
* ``guidelines``    -- print the machine-derived layout guidelines
                       (crossover table + rules + hybrid-win set) and
                       write ``bench-artifacts/guidelines.json``.
* ``serve-bench``   -- layout-aware serving at scale: replay thousands of
                       simulated concurrent requests from the arch traffic
                       mix through per-request plan compilation
                       (content-addressed plan cache) and phase-grouped
                       continuous batching; every batch group executes as
                       ONE compiled Pallas schedule (plan.pallas_exec),
                       so p50/p99 execute latencies are warm measured
                       kernel wall-clock with executable-compile cost
                       split out, landing with cache counters in
                       ``bench-artifacts/serve.json``.  ``--baseline``
                       gates p99 warm execute latency against a committed
                       artifact (the CI bench-smoke regression check).
* ``pallas-bench``  -- time the grid-tiled Pallas kernels over the full
                       (un-clamped) Table-5/6 matmul shapes: BP word
                       kernel vs fused and unfused BS bitplane kernels
                       per weight width.  ``--chained`` adds the
                       chained-vs-per-step pair per multi-step app (ONE
                       jitted schedule program vs host dispatch).  Writes
                       ``BENCH_pallas.json`` (versioned envelope);
                       ``--baseline`` gates every per-case median against
                       the committed artifact (exit 3 on regression,
                       like serve-bench).
* ``trace-diff``    -- the differential harness: reconcile the
                       jaxpr-traced ``traced/<id>`` workloads against the
                       hand-written ``arch/<id>`` formulas op by op
                       (repro.workloads.trace_diff).  Writes
                       ``bench-artifacts/traced_vs_formula.csv`` and
                       exits non-zero on any unexplained per-op delta.
                       ``--quick`` is the CI smoke: the smallest arch
                       plus VGG.
* ``tables``        -- the model-reproduced paper tables (the golden
                       snapshot text; see tests/golden/paper_tables.txt).

Committed artifacts (characterize.json, plans.json, serve.json) share the
versioned ``repro.artifacts`` envelope:
``{"artifact": kind, "schema_version": N, "payload": ...}``.

Examples::

    python -m repro list
    python -m repro characterize vgg --backends analytic,planner,executor
    python -m repro characterize mk/multu aes --ops
    python -m repro characterize aes --geometry 128x512x64
    python -m repro characterize --quick
    python -m repro plan aes --initial-layout BP --steps
    python -m repro plan vgg --geometry 8x512x8192 --execute
    python -m repro plan --quick
    python -m repro sweep --widths 4,8,16,32
    python -m repro guidelines
    python -m repro serve-bench --requests 4096
    python -m repro serve-bench --quick --baseline bench-artifacts/serve.json
    python -m repro plan traced/vgg16 --initial-layout BP --pallas
    python -m repro pallas-bench --quick --baseline BENCH_pallas.json
    python -m repro list --source traced
    python -m repro characterize traced/tinyllama_1_1b --ops
    python -m repro trace-diff --quick
    python -m repro trace-diff --pallas-archs tinyllama_1_1b
"""
from __future__ import annotations

import argparse
import json
import os
import sys

def _artifact_dir() -> str:
    return os.environ.get("REPRO_BENCH_ARTIFACT_DIR", "bench-artifacts")


def _fmt_summary(summary: dict) -> str:
    parts = []
    for key, val in summary.items():
        if isinstance(val, float):
            parts.append(f"{key}={val:.3f}")
        else:
            parts.append(f"{key}={val}")
    return " ".join(parts)


def _print_report(report, show_ops: bool, max_ops: int = 24) -> None:
    print(f"  [{report.backend}] {_fmt_summary(report.summary)}")
    for note in report.notes:
        print(f"    note: {note}")
    if not show_ops:
        return
    shown = report.ops[:max_ops]
    for op in shown:
        if not op.supported:
            print(f"    {op.op:20s} {op.kind:9s} unsupported: {op.note}")
        elif op.bp_us is not None:
            print(f"    {op.op:20s} {op.kind:9s} "
                  f"bp={op.bp_us:9.1f}us bs={op.bs_us:9.1f}us  {op.note}")
        else:
            print(f"    {op.op:20s} {op.kind:9s} "
                  f"bp={op.bp_cycles:>12d} bs={op.bs_cycles:>12d}  {op.note}")
    if len(report.ops) > max_ops:
        print(f"    ... ({len(report.ops) - max_ops} more ops; "
              "use --json for the full report)")


def cmd_list(args) -> int:
    from repro.workloads import BACKENDS, list_workloads
    from repro.workloads.registry import ALIASES

    rows = list_workloads(args.source)
    width = max(len(r["name"]) for r in rows) + 2
    cur = None
    for r in rows:
        if r["source"] != cur:
            cur = r["source"]
            print(f"\n# source: {cur}")
        print(f"{r['name']:{width}s}{r['description']}")
    print("\n# aliases")
    for alias, target in sorted(ALIASES.items()):
        print(f"{alias:{width}s}-> {target}")
    print("\n# backends")
    print(", ".join(sorted(BACKENDS)))
    return 0


def _parse_geometry(text):
    """``ROWSxCOLSxARRAYS[@ROW_BW]`` -> SystemParams (e.g. 128x512x64)."""
    from repro.sweep import Geometry

    body, _, bw = text.partition("@")
    try:
        rows, cols, arrays = (int(p) for p in body.lower().split("x"))
        bw_bits = int(bw) if bw else 512
    except ValueError:
        raise SystemExit(
            f"error: bad --geometry {text!r} (want ROWSxCOLSxARRAYS[@BW], "
            "e.g. 128x512x64 or 128x512x512@512)") from None
    return Geometry(rows=rows, cols=cols, arrays=arrays,
                    row_bandwidth_bits=bw_bits).system()


def _resolve_system(geometry_text, arrays):
    """--geometry / --arrays -> SystemParams (arrays overrides the count
    so single-array and machine-level numbers share one CLI surface)."""
    import dataclasses

    from repro.core.params import PAPER_SYSTEM
    from repro.sweep import Geometry

    system = _parse_geometry(geometry_text) if geometry_text \
        else PAPER_SYSTEM
    if arrays:
        if arrays < 1:
            raise SystemExit(f"error: --arrays must be >= 1, got {arrays}")
        system = dataclasses.replace(
            Geometry.from_system(system), arrays=arrays).system()
    return system


def cmd_characterize(args) -> int:
    from repro.workloads import backend_names, characterize, workload_names

    spec = args.backends or ("analytic,planner,executor" if args.quick
                             else "analytic,planner")
    backends = [b.strip() for b in spec.split(",") if b.strip()]
    unknown = [b for b in backends if b not in backend_names()]
    if unknown:
        print(f"error: unknown backend(s) {', '.join(unknown)} "
              f"(registered: {', '.join(backend_names())})", file=sys.stderr)
        return 2
    names = list(args.workloads)
    if args.quick and not names:
        # CI smoke scope: the analytic registries (arch/ workloads need
        # the jax model stack and are opt-in by name)
        names = workload_names("table5") + workload_names("table6")
    if not names:
        print("error: no workloads given (or use --quick)", file=sys.stderr)
        return 2
    system = _resolve_system(args.geometry, args.arrays)
    artifact: dict[str, dict] = {}
    full: dict[str, dict] = {}
    for name in names:
        reports = characterize(name, backends=backends, sys=system)
        print(f"{name}:")
        for rep in reports.values():
            _print_report(rep, show_ops=args.ops)
        artifact[name] = {b: rep.summary for b, rep in reports.items()}
        if args.json:
            full[name] = {b: rep.to_dict() for b, rep in reports.items()}
    if args.quick:
        from repro.artifacts import write_artifact

        path = os.path.join(_artifact_dir(), "characterize.json")
        write_artifact(path, "characterize", artifact,
                       generated_by="python -m repro characterize --quick")
        print(f"\n# wrote per-workload per-backend summaries to {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(full, f, indent=1, sort_keys=True)
        print(f"# wrote full reports to {args.json}")
    return 0


def cmd_plan(args) -> int:
    from repro.core.cost_model import Layout
    from repro.plan import compile_plan, replay_plan
    from repro.workloads import get_workload, workload_names

    names = list(args.workloads)
    if args.quick and not names:
        names = workload_names("table6")
    if not names:
        print("error: no workloads given (or use --quick)", file=sys.stderr)
        return 2
    system = _resolve_system(args.geometry, args.arrays)
    init = Layout(args.initial_layout) if args.initial_layout else None
    artifact: dict[str, dict] = {}
    full: dict[str, dict] = {}
    for name in names:
        w = get_workload(name)
        p = compile_plan(w, system, initial_layout=init)
        sched = "".join("S" if lay is not Layout.BP else "P"
                        for lay in p.schedule)
        print(f"{name}: total={p.total_cycles} "
              f"static_bp={p.static_bp} static_bs={p.static_bs} "
              f"speedup={p.hybrid_speedup:.2f}x "
              f"n_transposes={p.n_transposes} feasible={p.feasible}")
        if args.steps:
            print(f"  schedule [P=BP S=BS]: {sched}")
            for s in p.steps:
                flag = "" if s.feasible else "  !row-overflow"
                print(f"  {s.phase:24s} {s.layout.value} "
                      f"{s.cycles:>12d}{flag}")
        d = p.to_dict(include_steps=not args.quick)
        if args.json:
            full[name] = p.to_dict()
        if args.pallas:
            from repro.plan import (lower_plan_pallas, synth_inputs,
                                    time_schedule)

            sched = lower_plan_pallas(p, w)
            rows = time_schedule(sched, synth_inputs(sched),
                                 reps=args.reps)
            d["pallas"] = {"fuse_pack": sched.fuse_pack,
                           "n_repacks": sched.n_repacks, "steps": rows}
            if args.json:
                full[name]["pallas"] = d["pallas"]
            for r in rows:
                tag = f" +{r['repack']}" if r["repack"] else ""
                if r["us"] is None:
                    print(f"  pallas {r['op']} [{r['layout']}{tag}]: "
                          f"-- ({r['note']})")
                else:
                    print(f"  pallas {r['op']} [{r['layout']}{tag}]: "
                          f"{r['kernel']} dims={r['dims']} "
                          f"padded={r['padded_dims']} "
                          f"median_us={r['us']:.0f}")
        if args.execute:
            rows = replay_plan(p, w, system)
            d["replay"] = rows
            if args.json:
                full[name]["replay"] = rows
            for r in rows:
                if r["predicted"] is None:
                    print(f"  replay {r['op']} [{r['layout']}]: "
                          f"executed={r['executed']} ({r['note']})")
                else:
                    ok = "OK" if r["delta"] == r["expected_delta"] \
                        else "UNEXPECTED"
                    print(f"  replay {r['op']} [{r['layout']}]: "
                          f"predicted={r['predicted']} "
                          f"executed={r['executed']} "
                          f"delta={r['delta']:+d} "
                          f"(expected {r['expected_delta']:+d}) {ok}")
        artifact[name] = d
    if args.quick:
        from repro.artifacts import write_artifact

        path = os.path.join(_artifact_dir(), "plans.json")
        write_artifact(path, "plans", artifact,
                       generated_by="python -m repro plan --quick")
        print(f"\n# wrote per-workload plan summaries to {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(full, f, indent=1, sort_keys=True)
        print(f"# wrote full plans to {args.json}")
    return 0


def _build_sweep_spec(args):
    from repro.sweep import SweepSpec, iso_area_family

    widths = tuple(int(w) for w in args.widths.split(",") if w.strip())
    geometries = iso_area_family()
    if args.geometries:
        geometries = geometries[:args.geometries]
    return SweepSpec.default(
        workloads=args.workloads or None, widths=widths,
        geometries=geometries, n_override=args.n)


def cmd_sweep(args) -> int:
    from repro.sweep import cache_stats, guidelines, run_sweep

    spec = _build_sweep_spec(args)
    result = run_sweep(spec, use_cache=not args.no_cache)
    print(f"sweep: {result.breakdown.shape[0]} workloads x 2 layouts x "
          f"{len(spec.widths)} widths x {len(spec.geometries)} geometries "
          f"({result.summary()['grid_points']} grid points)")
    print(f"cache: {'hit' if result.cache['hit'] else 'miss'} "
          f"(key {result.cache['key']})")
    g = guidelines(result, include_hybrid=not args.no_hybrid)
    for name in sorted(g["crossover"]):
        c = g["crossover"][name]
        ws = "/".join(str(w) for w in c["bs_win_widths"]) or "-"
        print(f"  {name:20s} crossover_width={c['crossover_width']:<3d} "
              f"bs_wins={ws}")

    os.makedirs(_artifact_dir(), exist_ok=True)
    gpath = os.path.join(_artifact_dir(), "guidelines.json")
    with open(gpath, "w") as f:
        json.dump(g, f, indent=1, sort_keys=True)
    spath = os.path.join(_artifact_dir(), "sweep.json")
    with open(spath, "w") as f:
        json.dump({"spec": spec.to_dict(), "summary": result.summary(),
                   "cache": result.cache,
                   "cache_stats": cache_stats(),
                   "elapsed_s": result.elapsed_s}, f, indent=1,
                  sort_keys=True)
    print(f"# wrote {gpath} and {spath}")
    if args.json:
        full = {"guidelines": g, "totals": result.totals.tolist(),
                "breakdown": result.breakdown.tolist(),
                "bs_feasible": result.bs_feasible.tolist(),
                "bp_feasible": result.bp_feasible.tolist()}
        with open(args.json, "w") as f:
            json.dump(full, f, indent=1, sort_keys=True)
        print(f"# wrote full surfaces to {args.json}")
    return 0


def cmd_guidelines(args) -> int:
    from repro.sweep import guidelines, guidelines_lines

    g = guidelines(use_cache=not args.no_cache)
    print("# crossover table (paper geometry; "
          "workload crossover_width bs_win_widths)")
    for line in guidelines_lines(g):
        print(line)
    print("\n# derived rules")
    for rule in g["rules"]:
        print(f"- {rule}")
    os.makedirs(_artifact_dir(), exist_ok=True)
    gpath = os.path.join(_artifact_dir(), "guidelines.json")
    with open(gpath, "w") as f:
        json.dump(g, f, indent=1, sort_keys=True)
    print(f"\n# wrote {gpath}")
    return 0


def cmd_serve_bench(args) -> int:
    from repro.artifacts import ArtifactError, read_artifact, write_artifact
    from repro.core.params import PAPER_SYSTEM
    from repro.serve import check_regression, run_serve_bench

    n = args.requests if args.requests else (1024 if args.quick else 2048)
    system = (_parse_geometry(args.geometry) if args.geometry
              else PAPER_SYSTEM)

    # read the baseline BEFORE the run: the committed artifact and this
    # run's output default to the same path (CI gates in place)
    baseline = None
    if args.baseline:
        try:
            baseline = read_artifact(args.baseline, "serve")
        except FileNotFoundError:
            print(f"# no baseline at {args.baseline}; gate skipped")
        except ArtifactError as e:
            print(f"error: bad baseline: {e}", file=sys.stderr)
            return 2

    payload = run_serve_bench(
        n, seed=args.seed, sys=system,
        cache_dir=args.cache_dir or None, persist=not args.no_cache,
        max_batch=args.max_batch, execute_budget=args.execute_budget)

    cache = payload["cache"]
    exes = payload["executables"]
    comp, execu = payload["plan_compile_us"], payload["execute_us"]
    ecomp = payload["execute_compile_us"]
    print(f"serve-bench: {n} requests, "
          f"{payload['distinct_plans_bound']} distinct operating points, "
          f"{payload['batches']['count']} batches "
          f"({payload['batches']['signatures']} layout phases)")
    print(f"  plan cache: {cache['hits']}/{cache['lookups']} served "
          f"(hit_rate={cache['hit_rate']:.3f} mem={cache['mem_hits']} "
          f"disk={cache['disk_hits']} miss={cache['misses']} "
          f"evict={cache['evictions']})")
    print(f"  executables: {exes['entries']} compiled "
          f"(hit_rate={exes['hit_rate']:.3f}), "
          f"{exes['measured_steps']} measured / "
          f"{exes['modelled_steps']} modelled step(s) "
          f"@ budget {exes['execute_budget']} padded MACs")
    print(f"  plan compile: p50={comp['p50']:.0f}us p99={comp['p99']:.0f}us")
    print(f"  execute (warm Pallas): p50={execu['p50']:.0f}us "
          f"p99={execu['p99']:.0f}us; "
          f"exe compile: p50={ecomp['p50']:.0f}us p99={ecomp['p99']:.0f}us")
    print(f"  throughput: {payload['throughput_rps']:.0f} req/s; "
          f"transposes amortized: "
          f"{payload['simulated']['transpose_cycles_saved']} cycles saved")

    path = os.path.join(_artifact_dir(), "serve.json")
    write_artifact(path, "serve", payload,
                   generated_by="python -m repro serve-bench")
    print(f"# wrote {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote full payload to {args.json}")

    if baseline is not None:
        ok, msg = check_regression(payload, baseline,
                                   threshold=args.regress_threshold,
                                   floor_us=args.regress_floor_us)
        print(f"# regression gate: {msg} -> {'OK' if ok else 'FAIL'}")
        if not ok:
            return 3
    return 0


def cmd_pallas_bench(args) -> int:
    from repro.artifacts import ArtifactError, read_artifact, write_artifact
    from repro.kernels.bench import (check_pallas_regression,
                                     run_pallas_bench)

    # read the baseline BEFORE the run (the serve-bench idiom: committed
    # artifact and fresh output may point at the same path)
    baseline = None
    if args.baseline:
        try:
            baseline = read_artifact(args.baseline, "pallas")
        except FileNotFoundError:
            print(f"# no baseline at {args.baseline}; gate skipped")
        except ArtifactError as e:
            print(f"error: bad baseline: {e}", file=sys.stderr)
            return 2

    shapes = None
    if args.shape:
        from repro.kernels.bench import BENCH_SHAPES
        known = dict(BENCH_SHAPES)
        bad = [s for s in args.shape if s not in known]
        if bad:
            print(f"error: unknown shape(s) {bad}; "
                  f"known: {sorted(known)}", file=sys.stderr)
            return 2
        shapes = tuple((s, known[s]) for s in args.shape)

    payload = run_pallas_bench(quick=args.quick, reps=args.reps,
                               seed=args.seed, shapes=shapes,
                               chained=args.chained)
    print(f"pallas-bench: {len(payload['cases'])} cases, "
          f"reps={payload['reps']} quick={payload['quick']}")
    for c in payload["cases"]:
        if "shape" in c:
            m, k, n = c["shape"]
            print(f"  {c['name']:24s} {m}x{k}x{n} "
                  f"padded={'x'.join(map(str, c['padded']))} "
                  f"median_us={c['us']:.0f}")
        else:  # chained-vs-per-step pair rows (whole-schedule timings)
            print(f"  {c['name']:24s} steps={c['steps']} w{c['width']} "
                  f"median_us={c['us']:.0f}")
    for app, m in payload.get("chained", {}).items():
        if "skipped" in m:
            print(f"  chained {app}: skipped ({m['skipped']})")
        else:
            print(f"  chained {app}: x{m['speedup']:.2f} vs per-step "
                  f"({m['steps']} measured step(s), "
                  f"compile {m['compile_us'] / 1e3:.0f}ms)")

    path = args.out or os.path.join(_artifact_dir(), "BENCH_pallas.json")
    write_artifact(path, "pallas", payload,
                   generated_by="python -m repro pallas-bench"
                                + (" --quick" if args.quick else ""))
    print(f"# wrote {path}")

    if baseline is not None:
        ok, msg = check_pallas_regression(
            payload, baseline, threshold=args.regress_threshold,
            floor_us=args.regress_floor_us)
        print(f"# regression gate: {msg} -> {'OK' if ok else 'FAIL'}")
        if not ok:
            return 3
    return 0


def cmd_trace_diff(args) -> int:
    from repro.workloads.trace_diff import run_diff, write_csv

    archs = list(args.archs)
    if args.quick and not archs:
        archs = ["tinyllama_1_1b"]
    backends = [b.strip() for b in args.backends.split(",") if b.strip()]
    pallas_archs = [a.strip() for a in (args.pallas_archs or "").split(",")
                    if a.strip()]
    rows, fails = run_diff(
        archs or None, tokens=args.tokens, weight_bits=args.weight_bits,
        backends=backends, pallas_archs=pallas_archs,
        include_vgg=not args.no_vgg)
    for r in rows:
        if r.status == "total":
            print(f"{r.arch:28s} [{r.backend:8s}] "
                  f"formula bp={r.bp_formula:.0f} bs={r.bs_formula:.0f}  "
                  f"traced bp={r.bp_traced:.0f} bs={r.bs_traced:.0f} "
                  f"{r.unit} ({r.note})")
    n_exact = sum(1 for r in rows if r.status == "exact")
    n_div = sum(1 for r in rows if r.status == "divergent")
    n_extra = sum(1 for r in rows if r.status == "traced-only")
    print(f"# {n_exact} exact pairs, {n_div} documented-divergent pairs, "
          f"{n_extra} traced-only rows (x backends)")
    out = args.out or os.path.join(_artifact_dir(),
                                   "traced_vs_formula.csv")
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    write_csv(rows, out)
    print(f"# wrote {len(rows)} rows to {out}")
    if fails:
        for f in fails:
            print(f"FAIL: {f}", file=sys.stderr)
        print(f"# gate: {len(fails)} unexplained delta(s)", file=sys.stderr)
        return 3
    print("# gate: every formula op matched, every traced op explained, "
          "exact pairs agree to the cycle")
    return 0


def cmd_machine_bench(args) -> int:
    from repro.artifacts import write_artifact
    from repro.machine.bench import run_machine_bench
    from repro.sweep import iso_area_family

    geometries = None
    if args.geometries:
        geometries = iso_area_family()[:args.geometries]
    mesh = None
    if not args.no_execute:
        from repro.machine.engine import default_mesh

        mesh = default_mesh()
    payload = run_machine_bench(
        args.workload, quick=args.quick, geometries=geometries,
        execute=not args.no_execute, mesh=mesh,
        run_diff=not args.no_diff)
    for pt in payload["curve"]:
        if "error" in pt:
            print(f"{pt['geometry']:>16s} arrays={pt['arrays']:<5d} "
                  f"infeasible: {pt['error']}")
            continue
        tag = "  [executed]" if pt["executed"] else ""
        print(f"{pt['geometry']:>16s} arrays={pt['arrays']:<5d} "
              f"classes={pt['classes']} total={pt['total_cycles']:>10d} "
              f"(compute={pt['compute_cycles']} "
              f"movement={pt['movement_cycles']} "
              f"transpose={pt['transpose_cycles']}) "
              f"planner={pt['planner_total']} "
              f"delta={pt['delta_total']:+d}{tag}")
    ex = payload["executed"]
    if ex:
        print(f"# executed {ex['arrays_simulated']} simulated arrays "
              f"across {ex['mesh_devices']} device(s) @ {ex['geometry']}; "
              f"{len(ex['programs'])} distinct micro-op programs")
        if ex["io"]:
            io = ex["io"]
            print(f"# io reconciliation ({io['program']}): model "
                  f"{io['model_io_bytes']} B vs HLO boundary "
                  f"{io['hlo_boundary_bytes']} B "
                  f"(x{io['ratio']:.1f} host-side)")
    path = os.path.join(_artifact_dir(), "machine.json")
    write_artifact(path, "machine", payload,
                   generated_by="python -m repro machine-bench")
    print(f"# wrote machine scaling curve to {path}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote full payload to {args.json}")
    if payload["gate_failures"]:
        for msg in payload["gate_failures"]:
            print(f"FAIL: {msg}", file=sys.stderr)
        print(f"# gate: {len(payload['gate_failures'])} unexplained "
              "divergence(s)", file=sys.stderr)
        return 3
    print("# gate: analytic, planner, machine, and executed totals "
          "reconcile; every delta itemized")
    return 0


def cmd_tables(args) -> int:
    del args
    from repro.core.paper_tables import golden_snapshot

    print(golden_snapshot(), end="")
    return 0


def main(argv=None) -> int:
    from repro.serve.batcher import DEFAULT_EXECUTE_BUDGET

    ap = argparse.ArgumentParser(prog="python -m repro", description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="registered workloads and backends")
    p_list.add_argument("--source",
                        choices=("table5", "table6", "arch", "traced"),
                        default=None)
    p_list.set_defaults(fn=cmd_list)

    p_char = sub.add_parser(
        "characterize", help="run workloads through backends")
    p_char.add_argument("workloads", nargs="*",
                        help="registry names (e.g. vgg, mk/multu, "
                             "arch/tinyllama_1_1b)")
    p_char.add_argument("--backends", default=None,
                        help="comma list: analytic,planner,executor,pallas "
                             "(default analytic,planner; --quick adds "
                             "executor)")
    p_char.add_argument("--ops", action="store_true",
                        help="print per-op rows, not just summaries")
    p_char.add_argument("--quick", action="store_true",
                        help="CI smoke: all table5+table6 workloads, "
                             "summaries to bench-artifacts/characterize.json")
    p_char.add_argument("--json", default=None, metavar="PATH",
                        help="dump full reports (per-op rows) as JSON")
    p_char.add_argument("--geometry", default=None, metavar="RxCxA[@BW]",
                        help="system geometry rows x cols x arrays "
                             "(optional @row-bus-bits), e.g. 128x512x64")
    p_char.add_argument("--arrays", type=int, default=0, metavar="N",
                        help="override the geometry's array count (machine "
                             "scale from the single-array CLI surface)")
    p_char.set_defaults(fn=cmd_characterize)

    p_plan = sub.add_parser(
        "plan", help="compile workloads into executable layout plans")
    p_plan.add_argument("workloads", nargs="*",
                        help="registry names (e.g. aes, vgg, mk/multu)")
    p_plan.add_argument("--geometry", default=None, metavar="RxCxA[@BW]",
                        help="system geometry rows x cols x arrays "
                             "(optional @row-bus-bits), e.g. 128x512x64")
    p_plan.add_argument("--arrays", type=int, default=0, metavar="N",
                        help="override the geometry's array count (machine "
                             "scale from the single-array CLI surface)")
    p_plan.add_argument("--initial-layout", default=None,
                        choices=("BP", "BS"),
                        help="layout the data arrives in (charges the "
                             "arrival transpose)")
    p_plan.add_argument("--steps", action="store_true",
                        help="print per-step schedule rows")
    p_plan.add_argument("--execute", action="store_true",
                        help="replay executable ops on the micro-op "
                             "executor (predicted vs executed cycles)")
    p_plan.add_argument("--pallas", action="store_true",
                        help="lower the plan to a Pallas kernel schedule "
                             "and time each measured step (median wall-"
                             "clock over --reps launches)")
    p_plan.add_argument("--reps", type=int, default=5,
                        help="timing repetitions per --pallas step "
                             "(default 5)")
    p_plan.add_argument("--quick", action="store_true",
                        help="CI smoke: all table6 apps, summaries to "
                             "bench-artifacts/plans.json")
    p_plan.add_argument("--json", default=None, metavar="PATH",
                        help="dump full plans (steps + transposes) as JSON")
    p_plan.set_defaults(fn=cmd_plan)

    p_sweep = sub.add_parser(
        "sweep", help="design-space sweep over workload x width x geometry")
    p_sweep.add_argument("workloads", nargs="*",
                         help="mk/* workload names (default: all mk/*)")
    p_sweep.add_argument("--widths", default="4,8,16,32",
                         help="comma list of operand widths")
    p_sweep.add_argument("--geometries", type=int, default=0, metavar="N",
                         help="use only the first N iso-area geometries "
                              "(default: the full family)")
    p_sweep.add_argument("--n", type=int, default=None,
                         help="override every workload's element count")
    p_sweep.add_argument("--no-cache", action="store_true",
                         help="skip the sweep-cache (force re-evaluation)")
    p_sweep.add_argument("--no-hybrid", action="store_true",
                         help="skip the Table-6 planner hybrid-win pass")
    p_sweep.add_argument("--quick", action="store_true",
                         help="CI smoke mode (the default grid is already "
                              "one jitted call; kept for CI symmetry)")
    p_sweep.add_argument("--json", default=None, metavar="PATH",
                         help="dump the full cost surfaces as JSON")
    p_sweep.set_defaults(fn=cmd_sweep)

    p_guide = sub.add_parser(
        "guidelines", help="machine-derived layout guidelines")
    p_guide.add_argument("--no-cache", action="store_true",
                         help="skip the sweep-cache (force re-evaluation)")
    p_guide.set_defaults(fn=cmd_guidelines)

    p_serve = sub.add_parser(
        "serve-bench",
        help="replay the arch traffic mix through per-request plan "
             "compilation, the content-addressed plan cache, and "
             "phase-grouped batching")
    p_serve.add_argument("--requests", type=int, default=0, metavar="N",
                         help="simulated concurrent requests "
                              "(default 2048; --quick default 1024)")
    p_serve.add_argument("--seed", type=int, default=0,
                         help="traffic-mix sampling seed")
    p_serve.add_argument("--quick", action="store_true",
                         help="CI smoke: 1024 requests (unless --requests)")
    p_serve.add_argument("--geometry", default=None, metavar="RxCxA[@BW]",
                         help="system geometry rows x cols x arrays "
                              "(optional @row-bus-bits), e.g. 128x512x64")
    p_serve.add_argument("--max-batch", type=int, default=64,
                         help="continuous-batching slot budget per group")
    p_serve.add_argument("--execute-budget", type=int,
                         default=DEFAULT_EXECUTE_BUDGET, metavar="MACS",
                         help="padded-MAC budget per Pallas launch on the "
                              "execute path; over-budget steps stay "
                              "modelled-only rows (default "
                              f"{DEFAULT_EXECUTE_BUDGET})")
    p_serve.add_argument("--cache-dir", default=None, metavar="DIR",
                         help="plan-cache directory (default "
                              "<artifact-dir>/plan-cache)")
    p_serve.add_argument("--no-cache", action="store_true",
                         help="disable the disk tier of the plan cache")
    p_serve.add_argument("--baseline", default=None, metavar="PATH",
                         help="committed serve.json to gate p99 execute "
                              "latency against (read before this run's "
                              "artifact is written)")
    p_serve.add_argument("--regress-threshold", type=float, default=0.25,
                         help="p99 execute-latency regression budget "
                              "(fraction over baseline; default 0.25)")
    p_serve.add_argument("--regress-floor-us", type=float, default=250.0,
                         help="timer-noise floor: baselines are clamped "
                              "up to this before the ratio, so sub-floor "
                              "p99s never gate (default 250)")
    p_serve.add_argument("--json", default=None, metavar="PATH",
                         help="dump the full payload (pre-envelope) as JSON")
    p_serve.set_defaults(fn=cmd_serve_bench)

    p_pb = sub.add_parser(
        "pallas-bench",
        help="time the grid-tiled Pallas kernels over the full "
             "Table-5/6 matmul shapes (BP vs fused/unfused BS per "
             "width); writes + gates BENCH_pallas.json")
    p_pb.add_argument("--quick", action="store_true",
                      help="CI smoke: reps=2, widths {4,8,16}")
    p_pb.add_argument("--reps", type=int, default=None,
                      help="timing repetitions per case "
                           "(default 5; --quick default 2)")
    p_pb.add_argument("--seed", type=int, default=0,
                      help="operand sampling seed")
    p_pb.add_argument("--shape", action="append", default=[],
                      metavar="NAME",
                      help="restrict to named bench shape(s) (e.g. "
                           "gemv, vgg_fc_out); repeatable; default all")
    p_pb.add_argument("--chained", action="store_true",
                      help="also time chained-vs-per-step schedule "
                           "execution (ONE jitted program via "
                           "plan.pallas_exec vs host dispatch) for the "
                           "multi-step Table-6 apps")
    p_pb.add_argument("--out", default=None, metavar="PATH",
                      help="artifact path (default "
                           "<artifact-dir>/BENCH_pallas.json)")
    p_pb.add_argument("--baseline", default=None, metavar="PATH",
                      help="committed BENCH_pallas.json to gate per-case "
                           "medians against (read before this run's "
                           "artifact is written); exit 3 on regression")
    p_pb.add_argument("--regress-threshold", type=float, default=0.5,
                      help="per-case median regression budget "
                           "(fraction over baseline; default 0.5)")
    p_pb.add_argument("--regress-floor-us", type=float, default=2000.0,
                      help="timer-noise floor: baselines are clamped up "
                           "to this before the ratio, so sub-floor "
                           "medians never gate (default 2000)")
    p_pb.set_defaults(fn=cmd_pallas_bench)

    p_diff = sub.add_parser(
        "trace-diff",
        help="reconcile traced/<id> workloads against the arch/<id> "
             "formulas (differential gate + CSV artifact)")
    p_diff.add_argument("archs", nargs="*",
                        help="arch ids (e.g. tinyllama_1_1b; default: "
                             "all 10)")
    p_diff.add_argument("--tokens", type=int, default=4096,
                        help="decode batch / KV length (default 4096, the "
                             "arch/<id> operating point)")
    p_diff.add_argument("--weight-bits", type=int, default=4,
                        help="weight precision (default 4)")
    p_diff.add_argument("--backends", default="analytic,planner,executor",
                        help="comma list of static backends (default "
                             "analytic,planner,executor)")
    p_diff.add_argument("--pallas-archs", default=None, metavar="IDS",
                        help="comma list of archs to additionally time "
                             "on the Pallas tile backend (us, recorded "
                             "but never gated)")
    p_diff.add_argument("--no-vgg", action="store_true",
                        help="skip the traced-VGG-vs-vgg16 cross-check")
    p_diff.add_argument("--quick", action="store_true",
                        help="CI smoke: smallest arch (tinyllama_1_1b) "
                             "+ VGG")
    p_diff.add_argument("--out", default=None, metavar="PATH",
                        help="CSV path (default "
                             "<artifact-dir>/traced_vs_formula.csv)")
    p_diff.set_defaults(fn=cmd_trace_diff)

    p_mach = sub.add_parser(
        "machine-bench",
        help="compile + execute a Table-6 app across the iso-area machine "
             "axis (MachineSchedule IR; three-way differential gate)")
    p_mach.add_argument("--workload", default="traced/vgg16",
                        help="registry name to scale (default traced/vgg16)")
    p_mach.add_argument("--quick", action="store_true",
                        help="CI smoke: 3 geometries (1024/512/128 arrays) "
                             "and a reduced differential scope")
    p_mach.add_argument("--geometries", type=int, default=0, metavar="N",
                        help="use only the first N iso-area geometries "
                             "(widest machines first)")
    p_mach.add_argument("--no-execute", action="store_true",
                        help="skip the functional batched simulation "
                             "(static accounting only)")
    p_mach.add_argument("--no-diff", action="store_true",
                        help="skip the analytic/planner/executed "
                             "differential harness")
    p_mach.add_argument("--json", default=None, metavar="PATH",
                        help="dump the full payload (pre-envelope) as JSON")
    p_mach.set_defaults(fn=cmd_machine_bench)

    p_tab = sub.add_parser("tables", help="model-reproduced paper tables")
    p_tab.set_defaults(fn=cmd_tables)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
