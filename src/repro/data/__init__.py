"""data subpackage of the repro framework."""
