"""Deterministic, shardable synthetic LM data pipeline.

Batches are a stateless function of (seed, step): every host can generate
exactly its own shard with no coordination, restarts resume bit-identically
from the step counter (fault tolerance comes for free), and elastic
re-sharding is just a different slice of the same global batch.

The token stream is a order-k Markov-ish mixture (hash-chained), so a model
CAN learn it -- losses fall below ln(V) within a few hundred steps, which
the end-to-end example asserts.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: int = 97  # modulus giving the stream learnable structure


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """xorshift-mult avalanche hash (vectorized, stateless)."""
    x = x.astype(np.uint64)
    x = (x ^ (x >> np.uint64(16))) * np.uint64(0x45D9F3B)
    x = (x ^ (x >> np.uint64(16))) * np.uint64(0x45D9F3B)
    x = x ^ (x >> np.uint64(16))
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def global_batch_at(cfg: DataConfig, step: int) -> dict:
    """The full global batch for `step` (all data shards)."""
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    base = np.uint64(cfg.seed) * np.uint64(1_000_003) + np.uint64(step)
    rows = np.arange(B, dtype=np.uint64)[:, None]
    cols = np.arange(S + 1, dtype=np.uint64)[None, :]
    # structured stream: token depends on (row-chain, position mod m)
    chain = _hash_u32(base + rows * np.uint64(7919))
    raw = _hash_u32(chain.astype(np.uint64) + (cols % np.uint64(cfg.structure)))
    toks = (raw % np.uint32(V)).astype(np.int32)
    return {
        "tokens": toks[:, :-1],
        "labels": toks[:, 1:],
        "mask": np.ones((B, S), np.float32),
    }


def shard_slice(batch: dict, shard_index: int, num_shards: int) -> dict:
    """This host's rows of the global batch (elastic: any num_shards that
    divides the global batch)."""
    B = batch["tokens"].shape[0]
    assert B % num_shards == 0, (B, num_shards)
    per = B // num_shards
    lo = shard_index * per
    return {k: v[lo:lo + per] for k, v in batch.items()}


class DataIterator:
    """Stateful convenience wrapper with step-resume."""

    def __init__(self, cfg: DataConfig, shard_index: int = 0,
                 num_shards: int = 1, start_step: int = 0):
        self.cfg = cfg
        self.shard_index = shard_index
        self.num_shards = num_shards
        self.step = start_step

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        b = shard_slice(global_batch_at(self.cfg, self.step),
                        self.shard_index, self.num_shards)
        self.step += 1
        return b
