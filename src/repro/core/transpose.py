"""On-chip transpose unit cost model (paper Sec. 4.1).

The unit attaches to the column sense lines; its *core* transpose is
``transpose_core_cycles`` (1 cycle at GHz-class speeds, consistent with
bitline-shuffle hardware). End-to-end latency is dominated by feeding/draining
the unit: for a logical object occupying M rows in BP form and N rows in BS
form,

    BP -> BS : read(M) + core + write(N)
    BS -> BP : read(N) + core + write(M)

For the AES state (16 bytes): M = 16 rows (1 byte/row), N = 128 rows
(1 bit/row) => 16 + 1 + 128 = 145 cycles each way (paper footnote 1).
"""
from __future__ import annotations

from repro.core.params import SystemParams, PAPER_SYSTEM


def transpose_cycles(
    rows_bp: int,
    rows_bs: int,
    direction: str,
    sys: SystemParams = PAPER_SYSTEM,
) -> int:
    """Cycles to convert one logical object between layouts.

    Args:
      rows_bp: rows the object occupies in BP form (read/write granularity).
      rows_bs: rows the object occupies in BS form.
      direction: "bp2bs" or "bs2bp".
    """
    core = sys.transpose_core_cycles
    if direction == "bp2bs":
        return rows_bp + core + rows_bs
    if direction == "bs2bp":
        return rows_bs + core + rows_bp
    raise ValueError(f"unknown direction {direction!r}")


def round_trip_cycles(rows_bp: int, rows_bs: int,
                      sys: SystemParams = PAPER_SYSTEM) -> int:
    return (transpose_cycles(rows_bp, rows_bs, "bp2bs", sys)
            + transpose_cycles(rows_bp, rows_bs, "bs2bp", sys))
