"""Workload-aware layout-selection framework (paper Table 8 + Sec. 5.5).

Maps workload characteristics to a recommended layout:

    BP-friendly                      BS-friendly
    ---------------------------      -----------------------------
    word-level arithmetic            bit-level ops (popcount, XOR)
    conditional logic / predication  uniform, data-independent control
    mixed-precision vectors          high DoP, full utilization
    latency-critical tasks           large working sets
    low degrees of parallelism       logical transpositions? (no: BP)

plus the hybrid rule (Sec. 5.5): if the workload has at least one
BS-favourable and one BP-favourable phase and the transpose cost is below the
profitability threshold, recommend HYBRID.
"""
from __future__ import annotations

import dataclasses
import enum

from repro.core.params import SystemParams, PAPER_SYSTEM


class Recommendation(str, enum.Enum):
    BP = "BP"
    BS = "BS"
    HYBRID = "HYBRID"


@dataclasses.dataclass(frozen=True)
class WorkloadFeatures:
    """Characteristics the paper identifies as first-order (Sec. 5.5)."""

    precision_bits: int  # dominant operand width
    dop: int  # degree of parallelism (concurrent independent ops)
    control_intensity: float  # 0..1 fraction of predicated/branchy ops
    bit_level_fraction: float  # 0..1 fraction of popcount/XOR-style bit ops
    working_set_bits: int  # resident footprint needed
    latency_critical: bool = False
    mixed_precision: bool = False
    intra_vector_shuffles: bool = False  # e.g. crypto permutations
    phase_diverse: bool = False  # both BP- and BS-favourable phases present


@dataclasses.dataclass(frozen=True)
class Verdict:
    recommendation: Recommendation
    bp_score: float
    bs_score: float
    reasons: tuple[str, ...]


def classify(f: WorkloadFeatures, sys: SystemParams = PAPER_SYSTEM) -> Verdict:
    """Score both layouts per the Table-8 rules; HYBRID if phase-diverse."""
    reasons: list[str] = []
    bp, bs = 0.0, 0.0

    # Granularity mismatch (Challenge 1): low DoP wastes BS columns.
    bs_util = min(1.0, f.dop / sys.bs_parallel_elems())
    bp_util = min(1.0, f.dop * f.precision_bits / sys.total_columns)
    if bs_util < 0.25 and bp_util > 2 * bs_util:
        bp += 2
        reasons.append(
            f"low DoP: BS utilization {bs_util:.1%} vs BP {bp_util:.1%} "
            "(Challenge 1)")
    elif bs_util >= 0.9:
        bs += 2
        reasons.append("massive DoP saturates 1-bit PEs (BS-friendly)")

    # Vertical storage bottleneck (Challenges 2/3/5).
    live_words = max(1, f.working_set_bits // max(1, f.precision_bits))
    if sys.bs_row_overflow(live_words, f.precision_bits):
        bp += 2
        reasons.append(
            f"BS row overflow: {sys.bs_rows_required(live_words, f.precision_bits)}"
            f" rows needed > {sys.array.rows} (Challenge 2)")

    # Control flow (Challenges 4/5).
    if f.control_intensity > 0.2:
        bp += 1 + f.control_intensity
        reasons.append("predication/control favours word-level MUX "
                       "(Challenges 4/5)")
    elif f.control_intensity < 0.05:
        bs += 0.5
        reasons.append("uniform data-independent control (BS-friendly)")

    # Bit-level operations.
    if f.bit_level_fraction > 0.5:
        bs += 1 + f.bit_level_fraction
        reasons.append("bit-centric ops (popcount/XOR) use full BS density")

    # Precision.
    if f.precision_bits <= 8 and not f.mixed_precision:
        bs += 1
        reasons.append(f"low precision ({f.precision_bits}b) shortens "
                       "bit-serial latency")
    if f.mixed_precision:
        bp += 2.5  # Challenge 4 is disqualifying for lockstep BS control
        reasons.append("mixed precision breaks BS lockstep control "
                       "(Challenge 4)")

    # Latency criticality (Challenge 6).
    if f.latency_critical:
        bp += 1.5
        reasons.append(f"latency-critical: BS needs >= {f.precision_bits} "
                       "cycles/op (Challenge 6)")

    # Intra-vector shuffles (Challenge 3).
    if f.intra_vector_shuffles:
        bp += 1.5
        reasons.append("intra-vector permutations are zero-cost logical "
                       "shuffles in ES-BP (Challenge 3)")

    if f.phase_diverse and abs(bp - bs) < 2.5:
        return Verdict(Recommendation.HYBRID, bp, bs,
                       tuple(reasons + ["phase diversity: hybrid schedule "
                                        "(Sec. 5.5)"]))
    rec = Recommendation.BP if bp >= bs else Recommendation.BS
    return Verdict(rec, bp, bs, tuple(reasons))


# Canonical feature vectors for the paper's case studies -------------------

CASE_STUDIES: dict[str, WorkloadFeatures] = {
    "aes": WorkloadFeatures(
        # CTR-mode bulk encryption: DoP = parallel blocks; the 128-bit state
        # spills the 128-row column (129 rows with carry) in BS.
        precision_bits=8, dop=1 << 20, control_intensity=0.1,
        bit_level_fraction=0.45, working_set_bits=128,
        intra_vector_shuffles=True, phase_diverse=True),
    "vgg_late_layer": WorkloadFeatures(
        precision_bits=16, dop=100352 // 9, control_intensity=0.05,
        bit_level_fraction=0.0, working_set_bits=16 * 11,
        latency_critical=False),
    "hdc": WorkloadFeatures(
        precision_bits=1, dop=1 << 25, control_intensity=0.0,
        bit_level_fraction=0.95, working_set_bits=3),
    "fir": WorkloadFeatures(
        precision_bits=32, dop=512, control_intensity=0.1,
        bit_level_fraction=0.0, working_set_bits=11 * 32,
        latency_critical=True),
    "edge_ai_int4": WorkloadFeatures(
        precision_bits=4, dop=1 << 20, control_intensity=0.02,
        bit_level_fraction=0.6, working_set_bits=4 * 4),
    "mixed_precision_dnn": WorkloadFeatures(
        precision_bits=8, dop=1 << 18, control_intensity=0.05,
        bit_level_fraction=0.2, working_set_bits=8 * 4,
        mixed_precision=True),
}


def paper_threshold_rule(per_phase_runtime_cycles: float) -> float:
    """Sec. 5.5: hybrid is profitable for any phase-diverse app when the
    transpose cost stays below 2% of per-phase runtime (51 cycles at the
    paper's ~2550-cycle reference phase)."""
    return 0.02 * per_phase_runtime_cycles
