"""Hybrid layout planner: choose BP / BS / per-phase hybrid schedules.

.. deprecated::
    This module is now a thin legacy shim over the DAG scheduler in
    ``repro.plan`` (``repro.plan.scheduler.solve_phases`` solves the
    phase chain; results are bit-for-bit the old 2-state DP, pinned by
    tests/test_plan.py).  New call sites should compile workloads
    directly::

        from repro.plan import compile_plan
        compile_plan(get_workload("aes"))      # -> LayoutPlan

    ``Phase``/``Plan`` and :func:`plan` remain supported as the
    flat-phase-list compatibility surface (DESIGN.md Sec. 10).

The paper evaluates one hand-built hybrid schedule (AES, Sec. 5.4). We
generalize it: a workload is a sequence of :class:`Phase`s, each with BP/BS
cycle costs and a layout-dependent resident footprint; the planner charges
the on-chip transpose cost at every layout switch and returns the optimal
schedule plus both static baselines. This is the paper's "compiler
analyses that automatically partition code into layout-optimal regions"
future-work item, made concrete.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.cost_model import Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.core.transpose import transpose_cycles


@dataclasses.dataclass(frozen=True)
class Phase:
    """One layout-homogeneous region of a workload."""

    name: str
    bp_cycles: int
    bs_cycles: int
    #: rows occupied by the live state in each layout -- determines the
    #: transpose cost charged when entering/leaving this phase with a
    #: different layout than its neighbour.
    rows_bp: int = 16
    rows_bs: int = 128

    def cycles(self, layout: Layout) -> int:
        return self.bp_cycles if layout is Layout.BP else self.bs_cycles


@dataclasses.dataclass(frozen=True)
class Plan:
    schedule: tuple[Layout, ...]
    total_cycles: int
    static_bp: int
    static_bs: int
    n_transposes: int
    transpose_cycles_total: int

    @property
    def best_static(self) -> int:
        return min(self.static_bp, self.static_bs)

    @property
    def best_static_layout(self) -> Layout:
        return Layout.BP if self.static_bp <= self.static_bs else Layout.BS

    @property
    def hybrid_speedup(self) -> float:
        return self.best_static / self.total_cycles

    @property
    def is_hybrid(self) -> bool:
        return len(set(self.schedule)) > 1


def _switch_cost(prev: Phase, cur: Phase, frm: Layout, to: Layout,
                 sys: SystemParams) -> int:
    """Transpose cost for carrying `cur`'s working state into layout `to`
    when the previous phase ran in `frm`."""
    if frm == to:
        return 0
    direction = "bp2bs" if to is Layout.BS else "bs2bp"
    return transpose_cycles(cur.rows_bp, cur.rows_bs, direction, sys)


def plan(phases: Sequence[Phase], sys: SystemParams = PAPER_SYSTEM,
         initial_layout: Optional[Layout] = None) -> Plan:
    """Optimal layout schedule over the phase sequence.

    `initial_layout` is the layout the data arrives in; if given, a switch
    before the first phase is charged too.

    Legacy shim: the solve lives in ``repro.plan.scheduler`` (the chain
    case of the DAG scheduler, identical iteration order and BP-preferred
    tie-breaking as the original 2-state DP).
    """
    if not phases:
        raise ValueError("empty phase list")
    from repro.plan.scheduler import solve_phases

    sched, transposes, total, static_bp, static_bs = solve_phases(
        phases, sys, initial_layout)
    tr_total = sum(t.cycles for t in transposes)
    return Plan(tuple(sched), total, static_bp, static_bs,
                len(transposes), tr_total)


def hybrid_profitability_threshold(phases: Sequence[Phase],
                                   sys: SystemParams = PAPER_SYSTEM,
                                   max_core: int = 100_000) -> int:
    """Largest transpose *core* latency for which the optimal plan is still
    hybrid (paper Sec. 5.5: 51 cycles / 2%-of-phase-runtime in the paper's
    configuration). Binary-searches the core-cycle knob."""
    lo, hi = 0, max_core
    base = plan(phases, sys)
    if not base.is_hybrid:
        return -1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        s = dataclasses.replace(sys, transpose_core_cycles=mid)
        if plan(phases, s).is_hybrid:
            lo = mid
        else:
            hi = mid - 1
    return lo


def transpose_sensitivity(phases: Sequence[Phase], core_cycles: int,
                          sys: SystemParams = PAPER_SYSTEM) -> dict:
    """Re-plan with a slower transpose core; report runtime delta & speedup
    (paper Sec. 5.4 sensitivity study: 10x core => +~2.6%, 2.59x)."""
    base = plan(phases, sys)
    slow_sys = dataclasses.replace(sys, transpose_core_cycles=core_cycles)
    # Paper holds the *schedule* fixed and re-costs it.
    sched = base.schedule
    total = 0
    prev: Optional[Layout] = None
    for ph, l in zip(phases, sched):
        if prev is not None and prev != l:
            total += _switch_cost(ph, ph, prev, l, slow_sys)
        total += ph.cycles(l)
        prev = l
    return {
        "base_total": base.total_cycles,
        "slow_total": total,
        "runtime_increase_pct": 100.0 * (total - base.total_cycles)
        / base.total_cycles,
        "hybrid_speedup": base.best_static / total,
    }
