"""Hybrid layout planner: choose BP / BS / per-phase hybrid schedules.

The paper evaluates one hand-built hybrid schedule (AES, Sec. 5.4). We
generalize it: a workload is a sequence of :class:`Phase`s, each with BP/BS
cycle costs and a layout-dependent resident footprint; the planner runs a
2-state dynamic program over phases, charging the on-chip transpose cost at
every layout switch, and returns the optimal schedule plus both static
baselines. This is the paper's "compiler analyses that automatically
partition code into layout-optimal regions" future-work item, made concrete.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.core.cost_model import Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.core.transpose import transpose_cycles


@dataclasses.dataclass(frozen=True)
class Phase:
    """One layout-homogeneous region of a workload."""

    name: str
    bp_cycles: int
    bs_cycles: int
    #: rows occupied by the live state in each layout -- determines the
    #: transpose cost charged when entering/leaving this phase with a
    #: different layout than its neighbour.
    rows_bp: int = 16
    rows_bs: int = 128

    def cycles(self, layout: Layout) -> int:
        return self.bp_cycles if layout is Layout.BP else self.bs_cycles


@dataclasses.dataclass(frozen=True)
class Plan:
    schedule: tuple[Layout, ...]
    total_cycles: int
    static_bp: int
    static_bs: int
    n_transposes: int
    transpose_cycles_total: int

    @property
    def best_static(self) -> int:
        return min(self.static_bp, self.static_bs)

    @property
    def best_static_layout(self) -> Layout:
        return Layout.BP if self.static_bp <= self.static_bs else Layout.BS

    @property
    def hybrid_speedup(self) -> float:
        return self.best_static / self.total_cycles

    @property
    def is_hybrid(self) -> bool:
        return len(set(self.schedule)) > 1


def _switch_cost(prev: Phase, cur: Phase, frm: Layout, to: Layout,
                 sys: SystemParams) -> int:
    """Transpose cost for carrying `cur`'s working state into layout `to`
    when the previous phase ran in `frm`."""
    if frm == to:
        return 0
    direction = "bp2bs" if to is Layout.BS else "bs2bp"
    return transpose_cycles(cur.rows_bp, cur.rows_bs, direction, sys)


def plan(phases: Sequence[Phase], sys: SystemParams = PAPER_SYSTEM,
         initial_layout: Optional[Layout] = None) -> Plan:
    """2-state DP over the phase sequence.

    `initial_layout` is the layout the data arrives in; if given, a switch
    before the first phase is charged too.
    """
    if not phases:
        raise ValueError("empty phase list")
    layouts = (Layout.BP, Layout.BS)

    INF = float("inf")
    # cost[l] = best cost ending with layout l; back[i][l] = predecessor layout
    cost = {}
    back: list[dict[Layout, Layout]] = []
    first = phases[0]
    for l in layouts:
        c = first.cycles(l)
        if initial_layout is not None and initial_layout != l:
            c += _switch_cost(first, first, initial_layout, l, sys)
        cost[l] = c
    for i in range(1, len(phases)):
        ph = phases[i]
        new_cost = {}
        back_i = {}
        for l in layouts:
            best, best_prev = INF, None
            for p in layouts:
                c = cost[p] + _switch_cost(phases[i - 1], ph, p, l, sys) \
                    + ph.cycles(l)
                if c < best:
                    best, best_prev = c, p
            new_cost[l] = best
            back_i[l] = best_prev
        cost = new_cost
        back.append(back_i)

    # traceback
    end = min(layouts, key=lambda l: cost[l])
    sched = [end]
    for back_i in reversed(back):
        sched.append(back_i[sched[-1]])
    sched.reverse()
    total = int(cost[end])

    static_bp = sum(p.bp_cycles for p in phases)
    static_bs = sum(p.bs_cycles for p in phases)
    if initial_layout is Layout.BS:
        static_bp += _switch_cost(first, first, Layout.BS, Layout.BP, sys)
    if initial_layout is Layout.BP:
        static_bs += _switch_cost(first, first, Layout.BP, Layout.BS, sys)

    n_tr = sum(1 for a, b in zip(sched, sched[1:]) if a != b)
    if initial_layout is not None and sched[0] != initial_layout:
        n_tr += 1
    tr_total = total - sum(p.cycles(l) for p, l in zip(phases, sched))
    return Plan(tuple(sched), total, static_bp, static_bs, n_tr, tr_total)


def hybrid_profitability_threshold(phases: Sequence[Phase],
                                   sys: SystemParams = PAPER_SYSTEM,
                                   max_core: int = 100_000) -> int:
    """Largest transpose *core* latency for which the optimal plan is still
    hybrid (paper Sec. 5.5: 51 cycles / 2%-of-phase-runtime in the paper's
    configuration). Binary-searches the core-cycle knob."""
    lo, hi = 0, max_core
    base = plan(phases, sys)
    if not base.is_hybrid:
        return -1
    while lo < hi:
        mid = (lo + hi + 1) // 2
        s = dataclasses.replace(sys, transpose_core_cycles=mid)
        if plan(phases, s).is_hybrid:
            lo = mid
        else:
            hi = mid - 1
    return lo


def transpose_sensitivity(phases: Sequence[Phase], core_cycles: int,
                          sys: SystemParams = PAPER_SYSTEM) -> dict:
    """Re-plan with a slower transpose core; report runtime delta & speedup
    (paper Sec. 5.4 sensitivity study: 10x core => +~2.6%, 2.59x)."""
    base = plan(phases, sys)
    slow_sys = dataclasses.replace(sys, transpose_core_cycles=core_cycles)
    # Paper holds the *schedule* fixed and re-costs it.
    sched = base.schedule
    total = 0
    prev: Optional[Layout] = None
    for ph, l in zip(phases, sched):
        if prev is not None and prev != l:
            total += _switch_cost(ph, ph, prev, l, slow_sys)
        total += ph.cycles(l)
        prev = l
    return {
        "base_total": base.total_cycles,
        "slow_total": total,
        "runtime_increase_pct": 100.0 * (total - base.total_cycles)
        / base.total_cycles,
        "hybrid_speedup": base.best_static / total,
    }
