"""Layout advisor over the assigned LM architectures.

Runs the paper's classification framework (the Table-8 taxonomy) over
each architecture's per-layer operator trace -- which now lives in the
canonical workload IR (``repro.workloads.registry.arch_workload``); the
advisor consumes IR :class:`repro.workloads.ir.Op`s and classifies their
``features()`` lowering.  Used by examples/layout_advisor.py and the
``python -m repro characterize arch/<id>`` CLI route.

.. deprecated::
    :func:`arch_op_trace` (the old bespoke ``OpTrace`` extraction) is a
    shim over the IR route: it emits a :class:`DeprecationWarning` and
    returns ``OpTrace`` rows converted from the IR ops -- values
    identical to what it always returned (tests/test_workloads.py).
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.core.taxonomy import classify
from repro.models.base import ArchConfig
from repro.workloads.ir import Op
from repro.workloads.registry import arch_workload


@dataclasses.dataclass(frozen=True)
class OpTrace:
    """Deprecated pre-IR op record (kept for one release)."""

    name: str
    m: int  # output rows (tokens)
    k: int  # contraction
    n: int  # output cols
    weight_bits: int
    control_intensity: float = 0.0
    bit_level_fraction: float = 0.0
    mixed_precision: bool = False


def arch_op_trace(cfg: ArchConfig, *, tokens: int = 4096,
                  weight_bits: int = 4) -> list[OpTrace]:
    """Deprecated: use ``repro.workloads.arch_workload(cfg).ops``."""
    warnings.warn(
        "repro.core.advisor.arch_op_trace is deprecated; use "
        "repro.workloads.arch_workload(cfg).ops (the canonical IR route)",
        DeprecationWarning, stacklevel=2)
    w = arch_workload(cfg, tokens=tokens, weight_bits=weight_bits)
    return [OpTrace(name=op.name, m=op.m, k=op.k, n=op.n,
                    weight_bits=op.width,
                    control_intensity=op.control_intensity,
                    mixed_precision=op.mixed_precision)
            for op in w.ops]


def advise_op(op) -> dict:
    """Classify one op (IR :class:`Op` or legacy :class:`OpTrace`)."""
    if isinstance(op, OpTrace):  # legacy record -> IR op (one release)
        op = Op(name=op.name, kind="matmul", m=op.m, k=op.k, n=op.n,
                width=op.weight_bits,
                control_intensity=op.control_intensity,
                bit_level_fraction=(op.bit_level_fraction
                                    if op.weight_bits > 4 else None),
                mixed_precision=op.mixed_precision,
                working_set_bits=op.weight_bits * 8)
    v = classify(op.features())
    return {"op": op.name, "recommendation": v.recommendation.value,
            "bp_score": v.bp_score, "bs_score": v.bs_score,
            "reasons": v.reasons}


def advise_arch(cfg: ArchConfig, *, weight_bits: int = 4) -> dict:
    verdicts = [advise_op(op) for op in
                arch_workload(cfg, weight_bits=weight_bits).ops]
    kinds = {v["recommendation"] for v in verdicts}
    overall = ("HYBRID" if len(kinds - {"HYBRID"}) > 1 or "HYBRID" in kinds
               else kinds.pop())
    return {"arch": cfg.name, "weight_bits": weight_bits,
            "overall": overall, "ops": verdicts}
