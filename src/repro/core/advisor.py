"""Layout advisor over the assigned LM architectures.

Extracts each architecture's per-layer operator trace (matmul dims,
precision, control mix) from its ArchConfig and runs the paper's
classification framework over it -- the Table-8 taxonomy applied to modern
LM workloads (DESIGN.md §Arch-applicability). Used by
examples/layout_advisor.py and the EXPERIMENTS.md applicability table.
"""
from __future__ import annotations

import dataclasses

from repro.core.taxonomy import WorkloadFeatures, classify
from repro.models.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class OpTrace:
    name: str
    m: int  # output rows (tokens)
    k: int  # contraction
    n: int  # output cols
    weight_bits: int
    control_intensity: float = 0.0
    bit_level_fraction: float = 0.0
    mixed_precision: bool = False


def arch_op_trace(cfg: ArchConfig, *, tokens: int = 4096,
                  weight_bits: int = 4) -> list[OpTrace]:
    """Representative per-layer ops for quantized serving at `weight_bits`."""
    D = cfg.d_model
    ops: list[OpTrace] = []
    if cfg.family == "ssm":
        Din = cfg.d_inner
        ops.append(OpTrace("in_proj", tokens, D, 2 * Din + 2 * cfg.ssm_state
                           + cfg.ssm_heads, weight_bits))
        ops.append(OpTrace("ssd_scan", tokens, cfg.ssm_state,
                           cfg.ssm_head_dim, 16, control_intensity=0.3))
        ops.append(OpTrace("out_proj", tokens, Din, D, weight_bits))
        return ops
    if cfg.n_heads and cfg.n_kv_heads:
        ops.append(OpTrace("qkv_proj", tokens, D, cfg.qkv_dim, weight_bits))
        ops.append(OpTrace("attn_scores", tokens, cfg.head_dim, tokens, 16,
                           control_intensity=0.25))  # softmax/masking
        ops.append(OpTrace("o_proj", tokens, cfg.n_heads * cfg.head_dim, D,
                           weight_bits))
    if cfg.n_experts:
        ops.append(OpTrace("router", tokens, D, cfg.n_experts, 16,
                           control_intensity=0.6))  # top-k / dispatch
        ops.append(OpTrace("expert_ffn", tokens * cfg.top_k, D, cfg.d_ff,
                           weight_bits))
    elif cfg.d_ff:
        ops.append(OpTrace("ffn", tokens, D, cfg.d_ff, weight_bits))
    if cfg.family == "hybrid":
        W = cfg.lru_width
        ops.append(OpTrace("rg_lru_gates", tokens, W, W, 16,
                           control_intensity=0.4))
    return ops


def advise_op(op: OpTrace) -> dict:
    f = WorkloadFeatures(
        precision_bits=op.weight_bits,
        dop=op.m * op.n,
        control_intensity=op.control_intensity,
        bit_level_fraction=(1.0 if op.weight_bits <= 2 else
                            0.7 if op.weight_bits <= 4 else
                            op.bit_level_fraction),
        working_set_bits=op.weight_bits * 8,
        mixed_precision=op.mixed_precision,
    )
    v = classify(f)
    return {"op": op.name, "recommendation": v.recommendation.value,
            "bp_score": v.bp_score, "bs_score": v.bs_score,
            "reasons": v.reasons}


def advise_arch(cfg: ArchConfig, *, weight_bits: int = 4) -> dict:
    verdicts = [advise_op(op) for op in
                arch_op_trace(cfg, weight_bits=weight_bits)]
    kinds = {v["recommendation"] for v in verdicts}
    overall = ("HYBRID" if len(kinds - {"HYBRID"}) > 1 or "HYBRID" in kinds
               else kinds.pop())
    return {"arch": cfg.name, "weight_bits": weight_bits,
            "overall": overall, "ops": verdicts}
