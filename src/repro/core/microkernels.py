"""Tier-1 micro-kernel cost definitions (paper Sec. 4.3.1 / Table 5).

Each kernel assembles a :class:`CycleCost` from the Table-2 primitives and the
row-serial movement model. Calibration points: Table 5 (16-bit, N=1024; ReLU
N=8192) and Table 3 (32-bit compute-only). See DESIGN.md Sec. 8 for the few
rows where the source's own components disagree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import cost_model as cm
from repro.core.cost_model import CycleCost, Layout
from repro.core.params import SystemParams, PAPER_SYSTEM


@dataclasses.dataclass(frozen=True)
class Footprint:
    """Physical footprint per element (Table 5 Rows/Elem, Cols/Elem)."""

    rows_per_elem: float
    cols_per_elem: float


@dataclasses.dataclass(frozen=True)
class MicroKernel:
    name: str
    challenge: str
    variant: dict  # layout -> variant name
    cost_fn: Callable[[Layout, int, int, SystemParams], CycleCost]
    footprint: dict  # layout -> Footprint
    live_words: int = 3  # resident word-level variables (row-overflow analysis)

    def cost(self, layout: Layout, n: int = 1024, width: int = 16,
             sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
        return self.cost_fn(layout, n, width, sys)

    def compute_only(self, layout: Layout, width: int = 32,
                     n: int = 1, sys: SystemParams = PAPER_SYSTEM) -> int:
        return self.cost_fn(layout, n, width, sys).compute

    # -- canonical IR counterpart (repro.workloads) --------------------------
    def workload(self, n: int = 1024, width: int = 16):
        """This kernel as a single-op canonical workload
        (`repro.workloads.ir.Workload`) -- the hook every evaluation
        backend plugs into (lazy import: core stays IR-free)."""
        from repro.workloads.registry import microkernel_workload

        return microkernel_workload(self.name, n=n, width=width)

    # -- executable counterpart (repro.pim.executor) -------------------------
    def executed_cycles(self, layout: Layout, width: int = 16,
                        n: int | None = None) -> int:
        """Cycle count of this kernel's micro-op program on the simulated
        array -- the executable counterpart of `compute_only`.  Raises
        KeyError for kernels without a program (divu, bitweave*,
        multu_const)."""
        from repro.pim.programs import build

        return build(self.name, layout, width=width, n=n).cycles

    def executed_vs_analytic(self, layout: Layout, width: int = 16,
                             n: int | None = None) -> dict:
        """Differential record: executed program cycles vs the analytic
        compute formula, plus the documented calibration delta (DESIGN.md
        Sec. 8) the executor is expected to show at this width."""
        from repro.pim.programs import analytic_compute, build

        prog = build(self.name, layout, width=width, n=n)
        analytic = analytic_compute(self.name, layout, width, n=n)
        return {
            "kernel": self.name,
            "layout": Layout(layout).value,
            "width": width,
            "executed": prog.cycles,
            "analytic": analytic,
            "delta": prog.cycles - analytic,
            "expected_delta": prog.expected_delta,
            "note": prog.calibration_note,
        }


def _recipe_cost(name: str):
    """cost_fn factory: assemble load/compute/readout from the kernel's
    declarative recipe (`cost_model.KERNEL_RECIPES`) -- the same recipe the
    vectorized sweep path (`repro.sweep.vectorized`) evaluates under jit,
    so the scalar and grid evaluations cannot drift apart."""
    def fn(l, n, w, s):
        load, comp, ro = cm.eval_recipe(
            name, l, n=n, width=w, total_columns=s.total_columns,
            row_bandwidth_bits=s.row_bandwidth_bits)
        return CycleCost(load, comp, ro)
    return fn


_vector_add = _recipe_cost("vector_add")
_vector_sub = _recipe_cost("vector_sub")
_multu = _recipe_cost("multu")
_divu = _recipe_cost("divu")
_minmax = _recipe_cost("min")        # min/max share one recipe shape
_reduction = _recipe_cost("reduction")
_bitcount = _recipe_cost("bitcount")
_abs = _recipe_cost("abs")
_if_then_else = _recipe_cost("if_then_else")
_equal = _recipe_cost("equal")
_ge0 = _recipe_cost("ge_0")
_gt0 = _recipe_cost("gt_0")
_relu = _recipe_cost("relu")


def _bitweave(bits: int):
    return _recipe_cost(f"bitweave{bits}")


_FP = Footprint

MICROKERNELS: dict[str, MicroKernel] = {
    "vector_add": MicroKernel(
        "vector_add", "6", {Layout.BP: "Standard", Layout.BS: "Standard"},
        _vector_add,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(49, 1)}, live_words=3),
    "vector_sub": MicroKernel(
        "vector_sub", "6", {Layout.BP: "Standard", Layout.BS: "Standard"},
        _vector_sub,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(49, 1)}, live_words=3),
    "multu": MicroKernel(
        "multu", "6", {Layout.BP: "HW Mult", Layout.BS: "Shift+Add"},
        _multu,
        {Layout.BP: _FP(4, 16), Layout.BS: _FP(64, 1)}, live_words=4),
    "multu_const": MicroKernel(
        "multu_const", "6", {Layout.BP: "HW Mult", Layout.BS: "Shift+Add"},
        _multu,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(48, 1)}, live_words=3),
    "divu": MicroKernel(
        "divu", "6", {Layout.BP: "Restoring", Layout.BS: "Restoring"},
        _divu,
        {Layout.BP: _FP(4, 16), Layout.BS: _FP(64, 1)}, live_words=4),
    "min": MicroKernel(
        "min", "6", {Layout.BP: "Shift Mask", Layout.BS: "Iter. Comp."},
        _minmax,
        {Layout.BP: _FP(5, 16), Layout.BS: _FP(50, 1)}, live_words=5),
    "max": MicroKernel(
        "max", "6", {Layout.BP: "Shift Mask", Layout.BS: "Iter. Comp."},
        _minmax,
        {Layout.BP: _FP(5, 16), Layout.BS: _FP(50, 1)}, live_words=5),
    "reduction": MicroKernel(
        "reduction", "6", {Layout.BP: "Tree", Layout.BS: "Native"},
        _reduction,
        {Layout.BP: _FP(2, 16), Layout.BS: _FP(17, 1)}, live_words=2),
    "bitcount": MicroKernel(
        "bitcount", "1", {Layout.BP: "D&C", Layout.BS: "Summation"},
        _bitcount,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(26, 1)}, live_words=3),
    "bitweave1": MicroKernel(
        "bitweave1", "1", {Layout.BP: "1b Logic", Layout.BS: "1b Logic"},
        _bitweave(1),
        {Layout.BP: _FP(53, 1024), Layout.BS: _FP(53, 1024)}, live_words=3),
    "bitweave2": MicroKernel(
        "bitweave2", "1", {Layout.BP: "2b Logic", Layout.BS: "2b Logic"},
        _bitweave(2),
        {Layout.BP: _FP(74, 512), Layout.BS: _FP(74, 512)}, live_words=3),
    "bitweave4": MicroKernel(
        "bitweave4", "1", {Layout.BP: "4b Logic", Layout.BS: "4b Logic"},
        _bitweave(4),
        {Layout.BP: _FP(116, 256), Layout.BS: _FP(116, 256)}, live_words=3),
    "abs": MicroKernel(
        "abs", "4", {Layout.BP: "Shift Mask", Layout.BS: "Serialised"},
        _abs,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(48, 1)}, live_words=3),
    "if_then_else": MicroKernel(
        "if_then_else", "2/6", {Layout.BP: "Mask 0-s", Layout.BS: "Synth. MUX"},
        _if_then_else,
        {Layout.BP: _FP(5, 16), Layout.BS: _FP(52, 1)}, live_words=10),
    "equal": MicroKernel(
        "equal", "6", {Layout.BP: "XOR+Reduce", Layout.BS: "Serial XOR"},
        _equal,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(49, 1)}, live_words=3),
    "ge_0": MicroKernel(
        "ge_0", "6", {Layout.BP: "Shift", Layout.BS: "Sign Bit"},
        _ge0,
        {Layout.BP: _FP(1, 16), Layout.BS: _FP(16, 1)}, live_words=2),
    "gt_0": MicroKernel(
        "gt_0", "6", {Layout.BP: "Synth.", Layout.BS: "Serial Red."},
        _gt0,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(17, 1)}, live_words=3),
    "relu": MicroKernel(
        "relu", "4", {Layout.BP: "Standard", Layout.BS: "Standard"},
        _relu,
        {Layout.BP: _FP(2, 16), Layout.BS: _FP(17, 1)}, live_words=2),
}


def kernel_cost(name: str, layout: Layout, n: int = 1024, width: int = 16,
                sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    return MICROKERNELS[name].cost(layout, n, width, sys)


def table5_model_row(kernel: str, layout: Layout,
                     sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    """Reproduce the Table-5 operating point for a kernel (16-bit, N=1024,
    except ReLU at N=8192)."""
    n = 8192 if kernel in ("relu", "relu8k") else 1024
    name = "relu" if kernel == "relu8k" else kernel
    if name.startswith("bitweave") and name[-1].isdigit():
        return MICROKERNELS[name].cost(layout, n=1024, width=16, sys=sys)
    return MICROKERNELS[name].cost(layout, n=n, width=16, sys=sys)
