"""Tier-1 micro-kernel cost definitions (paper Sec. 4.3.1 / Table 5).

Each kernel assembles a :class:`CycleCost` from the Table-2 primitives and the
row-serial movement model. Calibration points: Table 5 (16-bit, N=1024; ReLU
N=8192) and Table 3 (32-bit compute-only). See DESIGN.md Sec. 8 for the few
rows where the source's own components disagree.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core import cost_model as cm
from repro.core.cost_model import CycleCost, Layout
from repro.core.params import SystemParams, PAPER_SYSTEM


@dataclasses.dataclass(frozen=True)
class Footprint:
    """Physical footprint per element (Table 5 Rows/Elem, Cols/Elem)."""

    rows_per_elem: float
    cols_per_elem: float


@dataclasses.dataclass(frozen=True)
class MicroKernel:
    name: str
    challenge: str
    variant: dict  # layout -> variant name
    cost_fn: Callable[[Layout, int, int, SystemParams], CycleCost]
    footprint: dict  # layout -> Footprint
    live_words: int = 3  # resident word-level variables (row-overflow analysis)

    def cost(self, layout: Layout, n: int = 1024, width: int = 16,
             sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
        return self.cost_fn(layout, n, width, sys)

    def compute_only(self, layout: Layout, width: int = 32,
                     n: int = 1, sys: SystemParams = PAPER_SYSTEM) -> int:
        return self.cost_fn(layout, n, width, sys).compute

    # -- canonical IR counterpart (repro.workloads) --------------------------
    def workload(self, n: int = 1024, width: int = 16):
        """This kernel as a single-op canonical workload
        (`repro.workloads.ir.Workload`) -- the hook every evaluation
        backend plugs into (lazy import: core stays IR-free)."""
        from repro.workloads.registry import microkernel_workload

        return microkernel_workload(self.name, n=n, width=width)

    # -- executable counterpart (repro.pim.executor) -------------------------
    def executed_cycles(self, layout: Layout, width: int = 16,
                        n: int | None = None) -> int:
        """Cycle count of this kernel's micro-op program on the simulated
        array -- the executable counterpart of `compute_only`.  Raises
        KeyError for kernels without a program (divu, bitweave*,
        multu_const)."""
        from repro.pim.programs import build

        return build(self.name, layout, width=width, n=n).cycles

    def executed_vs_analytic(self, layout: Layout, width: int = 16,
                             n: int | None = None) -> dict:
        """Differential record: executed program cycles vs the analytic
        compute formula, plus the documented calibration delta (DESIGN.md
        Sec. 8) the executor is expected to show at this width."""
        from repro.pim.programs import analytic_compute, build

        prog = build(self.name, layout, width=width, n=n)
        analytic = analytic_compute(self.name, layout, width, n=n)
        return {
            "kernel": self.name,
            "layout": Layout(layout).value,
            "width": width,
            "executed": prog.cycles,
            "analytic": analytic,
            "delta": prog.cycles - analytic,
            "expected_delta": prog.expected_delta,
            "note": prog.calibration_note,
        }


def _mk(layout: Layout, sys: SystemParams, *, n: int, width: int,
        in_bits: float, out_bits: float, bp: int, bs: int) -> CycleCost:
    load = sys.xfer_cycles(in_bits)
    readout = sys.xfer_cycles(out_bits)
    if layout is Layout.BP:
        compute = bp * sys.bp_batches(n, width)
    else:
        compute = bs * sys.bs_batches(n)
    return CycleCost(load, compute, readout)


# --- arithmetic -------------------------------------------------------------

def _vector_add(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=2 * n * w, out_bits=n * w,
               bp=cm.BP_ADD, bs=cm.bs_add(w))


def _vector_sub(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=2 * n * w, out_bits=n * w,
               bp=cm.BP_SUB, bs=cm.bs_sub(w))


def _multu(l, n, w, s):
    # BP widens both operands to the 2w product width before compute
    # (Table 5: load 128 rows @16b/N=1024); BS loads native-width operands
    # and grows the product in place (load 64).
    in_bits = 2 * n * 2 * w if l is Layout.BP else 2 * n * w
    return _mk(l, s, n=n, width=w, in_bits=in_bits, out_bits=n * 2 * w,
               bp=cm.bp_mult(w), bs=cm.bs_mult(w))


def _divu(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=2 * n * w, out_bits=n * w,
               bp=cm.div_bp(w), bs=cm.div_bs(w))


def _minmax(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=2 * n * w, out_bits=n * w,
               bp=cm.minmax_bp(w), bs=cm.minmax_bs(w))


# --- logical / bit-manipulation ----------------------------------------------

def _reduction(l, n, w, s):
    # Tree reduction: readout is the final-stage partial-sum region
    # (n*w/2 bits; Table 5 readout 16 rows @ N=1024).
    return _mk(l, s, n=n, width=w, in_bits=n * w, out_bits=n * w / 2,
               bp=cm.reduction_bp(n), bs=cm.reduction_bs(w))


def _bitcount(l, n, w, s):
    # BP D&C stages keep data + two shifted-mask operands resident
    # (4*n*w load bits; Table 5 load 128 rows); BS reads data only.
    in_bits = 4 * n * w if l is Layout.BP else n * w
    out_bits = n * w if l is Layout.BP else n * w / 2
    return _mk(l, s, n=n, width=w, in_bits=in_bits, out_bits=out_bits,
               bp=cm.bitcount_bp(w), bs=cm.bitcount_bs(w))


def _bitweave(bits: int):
    def fn(l, n, w, s):  # noqa: ARG001 (w unused: code width is `bits`)
        # Packed b-bit codes + (2/b) predicate-constant planes
        # (load rows 96/64/48 for b=1/2/4 @ N=1024); output is a result
        # bitvector (n bits).
        in_bits = n * 16 * (1 + 2.0 / bits) / 1  # 16 = word container width
        comp = cm.bitweave_compute(bits, l)
        load = s.xfer_cycles(in_bits)
        readout = s.xfer_cycles(n)
        return CycleCost(load, comp, readout)
    return fn


# --- control / predicate ------------------------------------------------------

def _abs(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=n * w, out_bits=n * w,
               bp=cm.abs_bp(w), bs=cm.abs_bs(w))


def _if_then_else(l, n, w, s):
    # BP holds cond/true/false words (3 operands). BS stores the condition as
    # a packed half-width flag plane => 2.5 operand loads (Table 5: 80 rows).
    in_bits = 3 * n * w if l is Layout.BP else 2.5 * n * w
    return _mk(l, s, n=n, width=w, in_bits=in_bits, out_bits=n * w,
               bp=cm.if_then_else_bp(w), bs=cm.if_then_else_bs(w))


def _equal(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=2 * n * w, out_bits=n * w,
               bp=cm.equal_bp(w), bs=cm.equal_bs(w))


def _ge0(l, n, w, s):
    return _mk(l, s, n=n, width=w, in_bits=n * w, out_bits=n * w / 2,
               bp=cm.ge0_bp(w), bs=cm.ge0_bs(w))


def _gt0(l, n, w, s):
    # BS keeps a packed zero-test scratch plane => 1.5 operand loads
    # (reconciles the inconsistent published row; DESIGN.md Sec. 8).
    in_bits = n * w if l is Layout.BP else 1.5 * n * w
    out_bits = n * w if l is Layout.BP else n * w / 2
    return _mk(l, s, n=n, width=w, in_bits=in_bits, out_bits=out_bits,
               bp=cm.gt0_bp(w), bs=cm.gt0_bs(w))


def _relu(l, n, w, s):
    # Published row (N=8192): load 512 / readout 512 in both modes -- the
    # kernel streams data + zero-mask in, result + mask out (2x each way).
    return _mk(l, s, n=n, width=w, in_bits=2 * n * w, out_bits=2 * n * w,
               bp=cm.relu_k(w), bs=cm.relu_k(w))


_FP = Footprint

MICROKERNELS: dict[str, MicroKernel] = {
    "vector_add": MicroKernel(
        "vector_add", "6", {Layout.BP: "Standard", Layout.BS: "Standard"},
        _vector_add,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(49, 1)}, live_words=3),
    "vector_sub": MicroKernel(
        "vector_sub", "6", {Layout.BP: "Standard", Layout.BS: "Standard"},
        _vector_sub,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(49, 1)}, live_words=3),
    "multu": MicroKernel(
        "multu", "6", {Layout.BP: "HW Mult", Layout.BS: "Shift+Add"},
        _multu,
        {Layout.BP: _FP(4, 16), Layout.BS: _FP(64, 1)}, live_words=4),
    "multu_const": MicroKernel(
        "multu_const", "6", {Layout.BP: "HW Mult", Layout.BS: "Shift+Add"},
        _multu,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(48, 1)}, live_words=3),
    "divu": MicroKernel(
        "divu", "6", {Layout.BP: "Restoring", Layout.BS: "Restoring"},
        _divu,
        {Layout.BP: _FP(4, 16), Layout.BS: _FP(64, 1)}, live_words=4),
    "min": MicroKernel(
        "min", "6", {Layout.BP: "Shift Mask", Layout.BS: "Iter. Comp."},
        _minmax,
        {Layout.BP: _FP(5, 16), Layout.BS: _FP(50, 1)}, live_words=5),
    "max": MicroKernel(
        "max", "6", {Layout.BP: "Shift Mask", Layout.BS: "Iter. Comp."},
        _minmax,
        {Layout.BP: _FP(5, 16), Layout.BS: _FP(50, 1)}, live_words=5),
    "reduction": MicroKernel(
        "reduction", "6", {Layout.BP: "Tree", Layout.BS: "Native"},
        _reduction,
        {Layout.BP: _FP(2, 16), Layout.BS: _FP(17, 1)}, live_words=2),
    "bitcount": MicroKernel(
        "bitcount", "1", {Layout.BP: "D&C", Layout.BS: "Summation"},
        _bitcount,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(26, 1)}, live_words=3),
    "bitweave1": MicroKernel(
        "bitweave1", "1", {Layout.BP: "1b Logic", Layout.BS: "1b Logic"},
        _bitweave(1),
        {Layout.BP: _FP(53, 1024), Layout.BS: _FP(53, 1024)}, live_words=3),
    "bitweave2": MicroKernel(
        "bitweave2", "1", {Layout.BP: "2b Logic", Layout.BS: "2b Logic"},
        _bitweave(2),
        {Layout.BP: _FP(74, 512), Layout.BS: _FP(74, 512)}, live_words=3),
    "bitweave4": MicroKernel(
        "bitweave4", "1", {Layout.BP: "4b Logic", Layout.BS: "4b Logic"},
        _bitweave(4),
        {Layout.BP: _FP(116, 256), Layout.BS: _FP(116, 256)}, live_words=3),
    "abs": MicroKernel(
        "abs", "4", {Layout.BP: "Shift Mask", Layout.BS: "Serialised"},
        _abs,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(48, 1)}, live_words=3),
    "if_then_else": MicroKernel(
        "if_then_else", "2/6", {Layout.BP: "Mask 0-s", Layout.BS: "Synth. MUX"},
        _if_then_else,
        {Layout.BP: _FP(5, 16), Layout.BS: _FP(52, 1)}, live_words=10),
    "equal": MicroKernel(
        "equal", "6", {Layout.BP: "XOR+Reduce", Layout.BS: "Serial XOR"},
        _equal,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(49, 1)}, live_words=3),
    "ge_0": MicroKernel(
        "ge_0", "6", {Layout.BP: "Shift", Layout.BS: "Sign Bit"},
        _ge0,
        {Layout.BP: _FP(1, 16), Layout.BS: _FP(16, 1)}, live_words=2),
    "gt_0": MicroKernel(
        "gt_0", "6", {Layout.BP: "Synth.", Layout.BS: "Serial Red."},
        _gt0,
        {Layout.BP: _FP(3, 16), Layout.BS: _FP(17, 1)}, live_words=3),
    "relu": MicroKernel(
        "relu", "4", {Layout.BP: "Standard", Layout.BS: "Standard"},
        _relu,
        {Layout.BP: _FP(2, 16), Layout.BS: _FP(17, 1)}, live_words=2),
}


def kernel_cost(name: str, layout: Layout, n: int = 1024, width: int = 16,
                sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    return MICROKERNELS[name].cost(layout, n, width, sys)


def table5_model_row(kernel: str, layout: Layout,
                     sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    """Reproduce the Table-5 operating point for a kernel (16-bit, N=1024,
    except ReLU at N=8192)."""
    n = 8192 if kernel in ("relu", "relu8k") else 1024
    name = "relu" if kernel == "relu8k" else kernel
    if name.startswith("bitweave") and name[-1].isdigit():
        return MICROKERNELS[name].cost(layout, n=1024, width=16, sys=sys)
    return MICROKERNELS[name].cost(layout, n=n, width=16, sys=sys)
