"""Canonical published numbers from the paper — ground truth for validation.

Benchmarks and tests compare the model's outputs against these values. Rows
whose published components do not sum to the published total (OCR/typesetting
noise in the source) carry ``consistent=False`` and are validated
component-wise only where meaningful (DESIGN.md Sec. 8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# --------------------------- Table 2: primitives ---------------------------

TABLE2_BP = {"logic": 1, "add": 1, "sub": 2, "shift_per_bit": 1}
TABLE2_BP_MULT = lambda w: w + 2  # noqa: E731
TABLE2_BS = {"add1": 1, "sub1": 1, "shift": 0, "mux1": 4}


# ----------------- Table 3: 32-bit kernel compute latency ------------------

TABLE3 = {
    # kernel: (BP cycles, BS cycles) @ 32-bit, compute-only
    "vector_add": (1, 32),
    "vector_mult": (34, 1024),
    "min_max": (36, 192),
    "if_then_else": (7, 97),
}


# ------------- Table 4: vector-add latency vs workload size ----------------

@dataclasses.dataclass(frozen=True)
class T4Row:
    elements: int
    bp_batches: int
    bp_cycles: int
    bs_cycles: int
    speedup: float  # BS/BP


TABLE4 = [
    T4Row(1024, 1, 97, 112, 1.15),
    T4Row(4096, 1, 385, 400, 1.04),
    T4Row(16384, 1, 1537, 1552, 1.01),
    T4Row(65536, 4, 6148, 6160, 1.00),
    T4Row(262144, 16, 24592, 24592, 1.00),
]


# --------------- Table 5: micro-kernel cycle breakdown (16-bit) ------------

@dataclasses.dataclass(frozen=True)
class T5Row:
    kernel: str
    variant: str
    mode: str  # "BP" | "BS"
    load: int
    compute: int
    readout: int
    total: int
    challenge: str
    consistent: bool = True  # load+compute+readout == total in the source?


TABLE5 = [
    # Arithmetic kernels (N=1024 elements, 16-bit)
    T5Row("vector_add", "Standard", "BP", 64, 1, 32, 97, "6"),
    T5Row("vector_add", "Standard", "BS", 64, 16, 32, 112, "6"),
    T5Row("vector_sub", "Standard", "BP", 64, 2, 32, 98, "6"),
    T5Row("vector_sub", "Standard", "BS", 64, 16, 32, 112, "6"),
    T5Row("multu", "HW Mult", "BP", 128, 18, 64, 210, "6"),
    T5Row("multu", "Shift+Add", "BS", 64, 256, 64, 384, "6"),
    T5Row("multu_const", "HW Mult", "BP", 128, 18, 64, 210, "6"),
    T5Row("multu_const", "Shift+Add", "BS", 64, 256, 64, 384, "6"),
    T5Row("divu", "Restoring", "BP", 64, 640, 32, 736, "6"),
    T5Row("divu", "Restoring", "BS", 64, 1280, 32, 1376, "6"),
    T5Row("min", "Shift Mask", "BP", 64, 21, 32, 117, "6"),
    T5Row("min", "Iter. Comp.", "BS", 64, 96, 32, 192, "6"),
    T5Row("max", "Shift Mask", "BP", 64, 21, 32, 117, "6"),
    T5Row("max", "Iter. Comp.", "BS", 64, 96, 32, 192, "6"),
    # Logical / bit-manipulation kernels
    T5Row("reduction", "Tree", "BP", 32, 19, 16, 67, "6"),
    T5Row("reduction", "Native", "BS", 32, 16, 16, 64, "6"),
    T5Row("bitcount", "D&C", "BP", 128, 25, 32, 185, "1"),
    T5Row("bitcount", "Summation", "BS", 32, 80, 16, 128, "1"),
    T5Row("bitweave", "1b Logic", "BP", 96, 225, 2, 323, "1"),
    T5Row("bitweave", "2b Logic", "BS", 64, 434, 2, 500, "1"),
    T5Row("bitweave", "4b Logic", "BS", 48, 852, 2, 902, "1"),
    # Control / predicate kernels
    T5Row("abs", "Shift Mask", "BP", 32, 18, 32, 82, "4"),
    T5Row("abs", "Serialised", "BS", 32, 48, 32, 112, "4"),
    T5Row("if_then_else", "Mask 0-s", "BP", 96, 7, 32, 135, "2/6"),
    T5Row("if_then_else", "Synth. MUX", "BS", 80, 49, 32, 161, "2/6"),
    T5Row("equal", "XOR+Reduce", "BP", 64, 22, 32, 118, "6"),
    T5Row("equal", "Serial XOR", "BS", 64, 33, 32, 129, "6"),
    T5Row("ge_0", "Shift", "BP", 32, 17, 16, 65, "6"),
    T5Row("ge_0", "Sign Bit", "BS", 32, 1, 16, 49, "6"),
    T5Row("gt_0", "Synth.", "BP", 32, 35, 32, 99, "6"),
    # Published BS row: 32+17+16 != 81 (source inconsistency; we keep the
    # published total and reproduce load=48 so components sum).
    T5Row("gt_0", "Serial Red.", "BS", 48, 17, 16, 81, "6", consistent=False),
    T5Row("relu8k", "Standard", "BP", 512, 17, 512, 1041, "4"),
    T5Row("relu8k", "Standard", "BS", 512, 17, 512, 1041, "4"),
]


def t5_rows(kernel: str, mode: Optional[str] = None) -> list[T5Row]:
    rows = [r for r in TABLE5 if r.kernel == kernel]
    if mode is not None:
        rows = [r for r in rows if r.mode == mode]
    return rows


# ------------------- Table 7 / Sec. 5.4: AES-128 per round ------------------

TABLE7 = {
    # stage: (BP cycles, BS cycles) per round, 16-byte state
    "add_round_key": (16, 128),
    "sub_bytes": (1568, 115),
    "shift_rows": (32, 256),
    "mix_columns": (272, 2176),
}
TABLE7_ROUND_TOTALS = {"BP": 1888, "BS": 2675}

AES_TOTALS = {
    # Published end-to-end AES-128 totals (10 rounds). NOTE (DESIGN.md Sec. 8):
    # the published BP total uses the faithful AES structure (initial ARK +
    # 10 rounds - final-round MixColumns) while the published BS total is the
    # flat 10x round cost; we reproduce both with the paper's own accounting.
    "BP": 18624,
    "BS": 26750,
    "BS_trace_faithful": 24702,  # what the faithful trace gives for pure BS
    "hybrid": 6994,
    "hybrid_speedup_vs_best_static": 2.66,
    "per_round_hybrid": 725,
    "transpose_per_round": 290,
    "transpose_one_way": 145,
}

AES_SENSITIVITY_10X = {
    # transpose core 1 -> 10 cycles (Sec. 5.4 sensitivity)
    "runtime_increase_pct": 2.6,
    "hybrid_speedup": 2.59,
}

HYBRID_THRESHOLD_CYCLES = 51  # Sec. 5.5: 2% of per-phase runtime


# ----------------------- Fig. 8: VGG-13 utilization -------------------------

# (block, out_channels, spatial) for ImageNet VGG-13; parallel ops = out/9
# (3x3 kernel reuse), capacity = 262,144 bits (= 512 x 512 columns).
FIG8_LAYERS = [
    ("conv1", 64, 224),
    ("conv2", 128, 112),
    ("conv3", 256, 56),
    ("conv4", 512, 28),
    ("conv5", 512, 14),
]

FIG8_QUOTED_UTIL = {
    # (layer, layout) -> utilization fraction quoted in the text. The text's
    # narrative "Conv1-Conv3 achieve 100%" does not follow from the /9 model
    # for conv2/conv3 BS (68%/34%) -- only the explicitly quoted numbers
    # (conv4/conv5) plus conv1 are asserted (DESIGN.md Sec. 8).
    ("conv4", "BS"): 0.17,
    ("conv5", "BS"): 0.04,
    ("conv4", "BP"): 1.00,
    ("conv5", "BP"): 0.68,
    ("conv1", "BP"): 1.00,
    ("conv1", "BS"): 1.00,
}


# -------------------- Table 6: application classification -------------------

@dataclasses.dataclass(frozen=True)
class T6Class:
    category: str
    lo: float  # BS/BP speedup band (values < 1 => BS faster)
    hi: float
    factor: str


TABLE6_BANDS = {
    "strong_bp": T6Class("Strong BP preference", 1.5, 3.0,
                         "Mixed arithmetic / control (Ch. 4,6)"),
    "moderate_bp": T6Class("Moderate BP preference", 1.2, 1.5,
                           "High arithmetic intensity, limited batching (6)"),
    "balanced": T6Class("Balanced", 1.0, 1.15,
                        "Batching neutralises latency (2)"),
    "bs": T6Class("BS preference", 0.6, 0.9,
                  "Bit-centric, full-density layouts (1)"),
    "hybrid": T6Class("Hybrid recommended", 0.0, 0.0,
                      "Phase diversity (3,4,5)"),
}

# ---------------------- golden snapshot (tests/golden/) ---------------------

#: Table-5 variant names that select a bitweave code width, not a kernel
T5_VARIANT_KERNELS = {"1b Logic": "bitweave1", "2b Logic": "bitweave2",
                      "4b Logic": "bitweave4"}


def golden_snapshot() -> str:
    """Deterministic text rendering of the *model-reproduced* Table 3/5/7
    rows, committed under tests/golden/paper_tables.txt.

    Every number here is computed from the cost formulas (not copied from
    the static tables above), so silent calibration drift in
    `repro.core.cost_model` / `repro.core.microkernels` changes this text
    and fails tier-1 instead of only the benchmark smoke.

    Regenerate after an intentional model change:

        PYTHONPATH=src python -m repro.core.paper_tables \\
            > tests/golden/paper_tables.txt
    """
    from repro.core import cost_model as cm
    from repro.core.apps import AES_STAGE, aes_paper_accounting
    from repro.core.cost_model import Layout
    from repro.core.microkernels import table5_model_row

    lines = [
        "# Golden snapshot: model-reproduced paper tables "
        "(repro.core.paper_tables.golden_snapshot).",
        "# Regenerate: PYTHONPATH=src python -m repro.core.paper_tables "
        "> tests/golden/paper_tables.txt",
        "",
        "[table3] kernel bp bs  (32-bit compute-only)",
    ]
    t3_model = {
        "vector_add": (cm.BP_ADD, cm.bs_add(32)),
        "vector_mult": (cm.bp_mult(32), cm.bs_mult(32)),
        "min_max": (cm.minmax_bp(32), cm.minmax_bs(32)),
        "if_then_else": (cm.if_then_else_bp(32), cm.if_then_else_bs(32)),
    }
    for k in sorted(t3_model):
        bp, bs = t3_model[k]
        lines.append(f"{k} {bp} {bs}")

    lines += ["", "[table5] kernel mode load compute readout total "
                  "(16-bit, N=1024; relu8k N=8192)"]
    for row in TABLE5:
        name = T5_VARIANT_KERNELS.get(row.variant, row.kernel) \
            if row.kernel == "bitweave" else row.kernel
        c = table5_model_row(name, Layout(row.mode))
        lines.append(f"{row.kernel} {row.mode} {c.load} {c.compute} "
                     f"{c.readout} {c.total}")

    lines += ["", "[table6] app bp bs hybrid n_transposes "
                  "(workload-IR route: repro.workloads + PlannerBackend)"]
    from repro.workloads import characterize, workload_names
    for app in workload_names("table6"):
        s = characterize(app, backends=("planner",))["planner"].summary
        lines.append(f"{app} {s['bp_cycles']} {s['bs_cycles']} "
                     f"{s['hybrid_cycles']} {s['n_transposes']}")

    lines += ["", "[table7] stage bp bs  (AES per-round, 16-byte state)"]
    for stage in sorted(AES_STAGE):
        bp, bs = AES_STAGE[stage]
        lines.append(f"{stage} {bp} {bs}")
    acc = aes_paper_accounting()
    lines.append(f"aes_total BP={acc['BP']} BS={acc['BS']} "
                 f"hybrid={acc['hybrid']} "
                 f"speedup={acc['speedup']:.2f}")

    # Compiled layout plans (repro.plan): per-app plan totals, transpose
    # counts, and the BS share of the step schedule at the paper geometry.
    # Totals must equal the [table6] hybrid column (the plan IR route and
    # the legacy phase DP are equivalence-pinned); the step-shape columns
    # catch schedule drift the totals alone would hide.
    from repro.plan import compile_plan
    from repro.workloads import get_workload
    lines += ["", "[plans] app total n_transposes bs_steps/steps feasible "
                  "(repro.plan.compile_plan @ paper geometry)"]
    for app in workload_names("table6"):
        p = compile_plan(get_workload(app))
        bs_steps = sum(1 for s in p.steps if s.layout is Layout.BS)
        lines.append(f"{app} {p.total_cycles} {p.n_transposes} "
                     f"{bs_steps}/{len(p.steps)} {int(p.feasible)}")

    # Machine-derived guidelines (repro.sweep): per-workload crossover
    # widths at the paper geometry plus the planner hybrid-win set --
    # pinned so guideline drift fails tier-1 (DESIGN.md Sec. 9).
    from repro.sweep import guidelines, guidelines_lines
    lines += ["", "[guidelines] workload crossover_width bs_win_widths "
                  "(mk/* sweep @ paper geometry, widths 4/8/16/32; "
                  "crossover = max width with BS total < BP total)"]
    lines += guidelines_lines(guidelines(use_cache=False))

    # jaxpr-traced decode op tables (repro.workloads.trace): the traced
    # matmul inventory of one dense, one SSM, and one MoE arch at the
    # arch/<id> operating point -- pinned so tracer lowering drift
    # (dims, widths, op inventory) fails tier-1 (DESIGN.md Sec. 12).
    from repro.configs import get_config
    from repro.models.registry import traced_workload
    lines += ["", "[traced] arch op m k n width "
                  "(trace_workload decode @ tokens=4096, int4 weights; "
                  "matmul ops + per-arch totals)"]
    for arch in ("tinyllama_1_1b", "mamba2_780m", "dbrx_132b"):
        w = traced_workload(get_config(arch))
        mms = [op for op in w.ops if op.kind == "matmul"]
        for op in mms:
            lines.append(f"{arch} {op.name} {op.m} {op.k} {op.n} "
                         f"{op.width}")
        lines.append(f"{arch} total ops={len(w.ops)} matmuls={len(mms)} "
                     f"deps={len(w.deps)}")

    # Machine-level schedules (repro.machine): the VGG16 partition /
    # movement summary across array counts at the paper-point geometry --
    # pinned so partitioner, movement-pricing, or delta-catalogue drift
    # fails tier-1 (DESIGN.md Sec. 13).
    from repro.machine import plan_machine
    lines += ["", "[machine] app N classes compute movement transpose "
                  "total planner delta explained "
                  "(plan_machine(vgg16) @ paper geometry)"]
    for n_parts in (1, 8, 512):
        s = plan_machine(get_workload("vgg16"), n_parts=n_parts)
        lines.append(f"vgg16 {n_parts} {len(s.classes)} "
                     f"{s.compute_cycles} {s.movement_cycles} "
                     f"{s.transpose_cycles} {s.total_cycles} "
                     f"{s.planner_total} {s.delta_total:+d} "
                     f"{int(s.explained)}")
    return "\n".join(lines) + "\n"


TABLE6_APPS = {
    # app -> band key (paper Table 6; xnor_net / db_query are the two apps of
    # the 22 not named in the table's grouping -- classified by our model).
    "brightness": "strong_bp",
    "kmeans": "strong_bp",
    "keccak": "strong_bp",
    "fir": "strong_bp",
    "vgg13": "moderate_bp",
    "vgg16": "moderate_bp",
    "vgg19": "moderate_bp",
    "gemm": "moderate_bp",
    "gemv": "moderate_bp",
    "conv2d": "moderate_bp",
    "downsample": "moderate_bp",
    "vector_add": "balanced",
    "axpy": "balanced",
    "pooling": "balanced",
    "prefix_sum": "balanced",
    "histogram": "bs",
    "hdc": "bs",
    "bitweave_db": "bs",
    "aes": "hybrid",
    "radix_sort": "hybrid",
    "xnor_net": "bs",
    "db_query": "hybrid",
}


if __name__ == "__main__":
    print(golden_snapshot(), end="")
