"""Paper core: cycle-accurate BP/BS PIM layout characterization.

Public API:
  params         -- ArrayParams / SystemParams (iso-area study configuration)
  cost_model     -- Table-2 primitives + derived kernel cycle formulas
  microkernels   -- Tier-1 micro-kernel registry (Table 5)
  apps           -- Tier-2 application traces (Table 6)
  transpose      -- on-chip transpose unit cost (Sec. 4.1)
  planner        -- hybrid BP/BS DP scheduler (Sec. 5.4 generalized)
  taxonomy       -- workload -> layout classification (Table 8)
  paper_tables   -- canonical published numbers (validation ground truth)
"""
from repro.core.cost_model import CycleCost, Layout  # noqa: F401
from repro.core.params import (  # noqa: F401
    ArrayParams, SystemParams, PAPER_SYSTEM, SINGLE_ARRAY,
)
from repro.core.planner import Phase, Plan, plan  # noqa: F401
from repro.core.taxonomy import (  # noqa: F401
    Recommendation, Verdict, WorkloadFeatures, classify,
)
