"""Architectural parameters for the iso-area BP/BS PIM study (paper Table 1).

The paper models a single Computing SRAM Array (CSA) of 128 rows x 512 columns
with dual peripherals (word-level BP datapath / 1-bit BS datapath) sharing the
cell core, scaled to a 512-array system for application workloads (Sec. 5.4:
"we assume a system with 512 parallel arrays").

Two system-level terms follow from the paper's accounting (Table 4/5):
  * load/readout are *bandwidth-serial*: one 512-bit row per cycle, regardless
    of how many arrays consume it (the external bus feeds rows sequentially);
  * compute is *capacity-parallel*: all resident elements compute together, so
    compute cycles = per-op cycles x ceil(N / parallel_capacity).
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ArrayParams:
    """One computing-SRAM array (paper Table 1)."""

    rows: int = 128
    cols: int = 512

    @property
    def bits(self) -> int:
        return self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class SystemParams:
    """A PIM system of `num_arrays` CSAs behind a row-serial load/store bus."""

    array: ArrayParams = dataclasses.field(default_factory=ArrayParams)
    num_arrays: int = 512
    row_bandwidth_bits: int = 512  # bits transferred per load/readout cycle
    clock_ghz: float = 1.0
    transpose_core_cycles: int = 1  # on-chip transpose unit core latency

    # ---- capacity ----------------------------------------------------------
    @property
    def total_columns(self) -> int:
        return self.num_arrays * self.array.cols

    def bp_parallel_elems(self, width: int) -> int:
        """Elements processed per BP compute step (word PEs of `width` bits)."""
        return self.total_columns // width

    def bs_parallel_elems(self) -> int:
        """Elements processed per BS compute step (one column = one 1-bit PE)."""
        return self.total_columns

    def bp_batches(self, n: int, width: int) -> int:
        return max(1, math.ceil(n / self.bp_parallel_elems(width)))

    def bs_batches(self, n: int) -> int:
        return max(1, math.ceil(n / self.bs_parallel_elems()))

    # ---- data movement -----------------------------------------------------
    def xfer_cycles(self, bits: float) -> int:
        """Cycles to move `bits` over the row-serial bus (load or readout)."""
        return int(math.ceil(bits / self.row_bandwidth_bits))

    # ---- row-overflow analysis (Challenge 2/5) ------------------------------
    def bs_rows_required(self, live_words: int, width: int, carry_rows: int = 1) -> int:
        """Vertical rows needed to keep `live_words` W-bit variables resident
        in a BS column (plus carry scratch)."""
        return live_words * width + carry_rows

    def bp_rows_required(self, live_words: int) -> int:
        """BP keeps each word-level variable in (a slice of) its own row."""
        return live_words

    def bs_row_overflow(self, live_words: int, width: int) -> bool:
        return self.bs_rows_required(live_words, width) > self.array.rows

    def bp_row_overflow(self, live_words: int) -> bool:
        return self.bp_rows_required(live_words) > self.array.rows


#: The paper's Tier-1/Tier-2 system (512 arrays; Sec. 5.4). Tier-1 numbers in
#: Table 5 are consistent with the same capacity model (see tests).
PAPER_SYSTEM = SystemParams()

#: A single-array instance, used for row-overflow arguments in Sec. 3.
SINGLE_ARRAY = SystemParams(num_arrays=1)
