"""Tier-2 application evaluation (paper Sec. 4.3.2 / Table 6).

.. deprecated::
    The hand-built per-app ``*_trace()`` phase-list constructors that used
    to live here moved to the canonical workload IR
    (``repro.workloads.registry``); every constructor below is now a thin
    shim that emits a :class:`DeprecationWarning` and returns the IR
    route's lowering -- values are bit-for-bit identical (enforced by
    tests/test_workloads.py and the tests/golden/paper_tables.txt
    snapshot).  New call sites should use::

        from repro.workloads import get_workload, characterize
        get_workload("vgg16").to_phases()      # planner phase list
        characterize("vgg16", backends=("analytic", "planner"))

``evaluate_app`` / ``evaluate_all`` remain the supported in-process API
(they consume the IR internally), as do the AES accounting helpers used
by ``paper_tables.golden_snapshot``.
"""
from __future__ import annotations

import warnings
from typing import Callable

from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.core.planner import Phase, Plan, plan
from repro.workloads.registry import (  # noqa: F401  (AES_STAGE re-export)
    AES_STAGE,
    get_workload,
    workload_names,
)

SYS = PAPER_SYSTEM


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.apps.{old} is deprecated; use {new} "
        "(repro.workloads is the canonical workload registry)",
        DeprecationWarning, stacklevel=3)


def _trace(name: str) -> list[Phase]:
    """The IR route the deprecated constructors now lower through."""
    return get_workload(name).to_phases()


# ---------------------------------------------------------------------------
# AES paper accounting (supported; consumed by paper_tables.golden_snapshot)
# ---------------------------------------------------------------------------

def aes_paper_accounting() -> dict:
    """The published totals, using the paper's own per-case accounting
    (DESIGN.md Sec. 8): BP follows the faithful trace; BS is the flat
    10x round cost; hybrid follows the faithful trace with 2 transposes
    per round."""
    bp_round = sum(b for b, _ in AES_STAGE.values())
    bs_round = sum(s for _, s in AES_STAGE.values())
    bp_total = 16 + 10 * bp_round - AES_STAGE["mix_columns"][0]
    bs_total = 10 * bs_round
    hybrid_round = (AES_STAGE["add_round_key"][0] + AES_STAGE["sub_bytes"][1]
                    + AES_STAGE["shift_rows"][0] + AES_STAGE["mix_columns"][0]
                    + 290)
    hybrid_total = 16 + 10 * hybrid_round - AES_STAGE["mix_columns"][0]
    return {
        "BP": bp_total, "BS": bs_total, "hybrid": hybrid_total,
        "per_round_hybrid": hybrid_round,
        "speedup": round(min(bp_total, bs_total) / hybrid_total, 2),
    }


# ---------------------------------------------------------------------------
# Deprecated trace constructors (shims over the IR registry)
# ---------------------------------------------------------------------------

def aes_trace() -> list[Phase]:
    _deprecated("aes_trace()", 'get_workload("aes").to_phases()')
    return _trace("aes")


def vgg_trace(which: str = "vgg13") -> list[Phase]:
    _deprecated("vgg_trace()", f'get_workload("{which}").to_phases()')
    return _trace(which)


def _shim(name: str) -> Callable[[], list[Phase]]:
    def fn() -> list[Phase]:
        _deprecated(f"{name}_trace()", f'get_workload("{name}").to_phases()')
        return _trace(name)
    fn.__name__ = f"{name}_trace"
    fn.__qualname__ = fn.__name__
    fn.__doc__ = (f"Deprecated shim for the {name!r} workload; see "
                  "repro.workloads.registry.")
    return fn


brightness_trace = _shim("brightness")
kmeans_trace = _shim("kmeans")
keccak_trace = _shim("keccak")
fir_trace = _shim("fir")
gemm_trace = _shim("gemm")
gemv_trace = _shim("gemv")
conv2d_trace = _shim("conv2d")
downsample_trace = _shim("downsample")
vector_add_trace = _shim("vector_add")
axpy_trace = _shim("axpy")
pooling_trace = _shim("pooling")
prefix_sum_trace = _shim("prefix_sum")
histogram_trace = _shim("histogram")
hdc_trace = _shim("hdc")
bitweave_db_trace = _shim("bitweave_db")
xnor_net_trace = _shim("xnor_net")
radix_sort_trace = _shim("radix_sort")
db_query_trace = _shim("db_query")

#: Deprecated registry of shim constructors -- iterate
#: ``repro.workloads.workload_names("table6")`` instead.
APP_TRACES: dict[str, Callable[[], list[Phase]]] = {
    "brightness": brightness_trace,
    "kmeans": kmeans_trace,
    "keccak": keccak_trace,
    "fir": fir_trace,
    "vgg13": lambda: vgg_trace("vgg13"),
    "vgg16": lambda: vgg_trace("vgg16"),
    "vgg19": lambda: vgg_trace("vgg19"),
    "gemm": gemm_trace,
    "gemv": gemv_trace,
    "conv2d": conv2d_trace,
    "downsample": downsample_trace,
    "vector_add": vector_add_trace,
    "axpy": axpy_trace,
    "pooling": pooling_trace,
    "prefix_sum": prefix_sum_trace,
    "histogram": histogram_trace,
    "hdc": hdc_trace,
    "bitweave_db": bitweave_db_trace,
    "xnor_net": xnor_net_trace,
    "aes": aes_trace,
    "radix_sort": radix_sort_trace,
    "db_query": db_query_trace,
}


# ---------------------------------------------------------------------------
# Evaluation (supported API; consumes the IR)
# ---------------------------------------------------------------------------

def evaluate_app(name: str, sys: SystemParams = PAPER_SYSTEM) -> dict:
    # Phases are built at the registry's PAPER_SYSTEM calibration (the
    # bespoke `compute` op cycles are baked there); `sys` scales only the
    # planner's transpose accounting -- the exact semantics of the pre-IR
    # trace builders, which also pinned SYS = PAPER_SYSTEM.
    phases = get_workload(name).to_phases()
    p: Plan = plan(phases, sys)
    return {
        "app": name,
        "bp_cycles": p.static_bp,
        "bs_cycles": p.static_bs,
        "bs_over_bp": p.static_bs / p.static_bp,
        "hybrid_cycles": p.total_cycles,
        "hybrid_speedup": p.hybrid_speedup,
        "is_hybrid": p.is_hybrid,
        "n_transposes": p.n_transposes,
    }


def evaluate_all(sys: SystemParams = PAPER_SYSTEM) -> dict[str, dict]:
    return {name: evaluate_app(name, sys)
            for name in workload_names("table6")}
