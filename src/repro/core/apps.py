"""Tier-2 application traces (paper Sec. 4.3.2 / Table 6).

Each application is a sequence of :class:`Phase`s built from the Table-2
primitives and the micro-kernel cost model. The paper publishes the *bands*
(BS/BP speedup classes) but not per-app input sizes; sizes below are chosen to
be representative of the cited datasets (CIFAR/ImageNet for VGG, 1M points for
K-means, ...) and are documented per app. The validation target is the
published classification (Table 6), plus the exact AES totals (Table 7).

Movement accounting follows the paper: iterative algorithms keep state
resident (load once, compute many; Challenge 2), BS pays row-overflow spills
when vertical footprints exceed 128 rows, and BS convolutions replicate
window elements across columns (no horizontal shift reuse) while ES-BP reuses
them via logical row addressing (Challenge 3).
"""
from __future__ import annotations

import math
from typing import Callable

from repro.core import cost_model as cm
from repro.core.cost_model import Layout
from repro.core.microkernels import MICROKERNELS
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.core.planner import Phase, Plan, plan

SYS = PAPER_SYSTEM


def _xfer(bits: float) -> int:
    return SYS.xfer_cycles(bits)


def _bp_batches(n: int, w: int) -> int:
    return SYS.bp_batches(n, w)


def _bs_batches(n: int) -> int:
    return SYS.bs_batches(n)


def _phase(name, bp, bs, rows_bp=16, rows_bs=128) -> Phase:
    return Phase(name, int(bp), int(bs), rows_bp, rows_bs)


def _movement(name, bits) -> Phase:
    """Layout-neutral data movement (row-serial bus)."""
    c = _xfer(bits)
    return _phase(name, c, c)


# ---------------------------------------------------------------------------
# AES-128 (paper Sec. 5.4, Table 7) -- the canonical hybrid case study
# ---------------------------------------------------------------------------

AES_STAGE = {  # per-round costs, 16-byte state (paper Table 7)
    "add_round_key": (16, 128),
    "sub_bytes": (1568, 115),
    "shift_rows": (32, 256),
    "mix_columns": (272, 2176),
}
# AES state: 16 rows in BP (1 byte/row) vs 128 rows in BS (1 bit/row)
_AES_ROWS = dict(rows_bp=16, rows_bs=128)


def aes_trace() -> list[Phase]:
    """Faithful AES-128: initial ARK, 9 full rounds, final round w/o MixColumns."""
    ph: list[Phase] = [_phase("ARK0", *AES_STAGE["add_round_key"], **_AES_ROWS)]
    for r in range(1, 11):
        ph.append(_phase(f"SB{r}", *AES_STAGE["sub_bytes"], **_AES_ROWS))
        ph.append(_phase(f"SR{r}", *AES_STAGE["shift_rows"], **_AES_ROWS))
        if r < 10:
            ph.append(_phase(f"MC{r}", *AES_STAGE["mix_columns"], **_AES_ROWS))
        ph.append(_phase(f"ARK{r}", *AES_STAGE["add_round_key"], **_AES_ROWS))
    return ph


def aes_paper_accounting() -> dict:
    """The published totals, using the paper's own per-case accounting
    (DESIGN.md Sec. 8): BP follows the faithful trace; BS is the flat
    10x round cost; hybrid follows the faithful trace with 2 transposes
    per round."""
    bp_round = sum(b for b, _ in AES_STAGE.values())
    bs_round = sum(s for _, s in AES_STAGE.values())
    bp_total = 16 + 10 * bp_round - AES_STAGE["mix_columns"][0]
    bs_total = 10 * bs_round
    hybrid_round = (AES_STAGE["add_round_key"][0] + AES_STAGE["sub_bytes"][1]
                    + AES_STAGE["shift_rows"][0] + AES_STAGE["mix_columns"][0]
                    + 290)
    hybrid_total = 16 + 10 * hybrid_round - AES_STAGE["mix_columns"][0]
    return {
        "BP": bp_total, "BS": bs_total, "hybrid": hybrid_total,
        "per_round_hybrid": hybrid_round,
        "speedup": round(min(bp_total, bs_total) / hybrid_total, 2),
    }


# ---------------------------------------------------------------------------
# Strong-BP applications (band 1.5 - 3.0x)
# ---------------------------------------------------------------------------

def brightness_trace() -> list[Phase]:
    """Per-tile brightness with saturation: real-time, low-DoP tiles
    (Challenge 1/6). 64 tiles x 1024 px, 16-bit; per tile: stream in,
    offset (add), saturate (if-then-else), stream out."""
    w, n, tiles = 16, 1024, 64
    ph = []
    for t in range(tiles):
        ph.append(_movement(f"load{t}", n * w))
        ph.append(_phase(f"offset{t}", cm.BP_ADD, cm.bs_add(w)))
        ph.append(_phase(f"sat{t}", cm.if_then_else_bp(w), cm.if_then_else_bs(w)))
        ph.append(_movement(f"store{t}", n * w))
    return ph


def kmeans_trace() -> list[Phase]:
    """K-means (PIMBench 1M points processed in 48K-point resident tiles --
    the per-tile BS/BP ratio is scale-invariant, so one tile is traced):
    d=2, k=8, 10 iterations; distance = sub+mult+reduce, argmin = k-1
    iterative min, per-iter centroid broadcast."""
    w, k, iters = 16, 8, 10
    n = 49152
    ph = [_movement("load_points", n * w)]
    bpb, bsb = _bp_batches(n, w), _bs_batches(n)
    for i in range(iters):
        ph.append(_movement(f"bcast_centroids{i}", k * 2 * w * 4096))
        dist_bp = k * (cm.BP_SUB + cm.bp_mult(w) + cm.reduction_bp(2)) * bpb
        dist_bs = k * (cm.bs_sub(w) + cm.bs_mult(w) + cm.reduction_bs(w)) * bsb
        ph.append(_phase(f"dist{i}", dist_bp, dist_bs))
        amin_bp = (k - 1) * cm.minmax_bp(w) * bpb
        amin_bs = (k - 1) * cm.minmax_bs(w) * bsb
        ph.append(_phase(f"argmin{i}", amin_bp, amin_bs))
    ph.append(_movement("labels_out", n * 8))
    return ph


def keccak_trace() -> list[Phase]:
    """Keccak-f[1600] (Challenge 3): 24 rounds. BP keeps 25 64-bit lanes in
    ES-BP rows; pi is a zero-cost logical shuffle, rho costs word shifts.
    BS is forced into EP-BS (1600 vertical rows overflow 128): logic costs
    w cycles/op, shifts are free, but pi is a physical inter-column shuffle
    and the state spills (row overflow) every round."""
    w, rounds = 64, 24
    lanes = 25
    ph = [_movement("absorb", 1088 * 512)]  # rate x 512 parallel instances
    spill_bits = (lanes * w - 128) * 512  # per-round BS working-set spill
    for r in range(rounds):
        theta_bp = 5 * 4 * cm.BP_LOGIC + 5 * (1 + cm.BP_LOGIC) + lanes
        theta_bs = (5 * 4 + 5 + lanes) * 1  # row-wise ops, shifts free
        ph.append(_phase(f"theta{r}", theta_bp, theta_bs,
                         rows_bp=lanes, rows_bs=128))
        rho_bp = 24 * (w // 2)  # avg rotation distance
        rho_bs = 0
        ph.append(_phase(f"rho{r}", rho_bp, rho_bs, rows_bp=lanes, rows_bs=128))
        pi_bp = 0  # logical shuffle (address remap)
        pi_bs = 2 * lanes * 2  # physical shuffle: read+write per lane (x2 pass)
        ph.append(_phase(f"pi{r}", pi_bp, pi_bs, rows_bp=lanes, rows_bs=128))
        chi_bp = lanes * 3 * cm.BP_LOGIC
        chi_bs = lanes * 3
        ph.append(_phase(f"chi{r}", chi_bp, chi_bs, rows_bp=lanes, rows_bs=128))
        ph.append(_phase(f"spill{r}", 0, _xfer(spill_bits),
                         rows_bp=lanes, rows_bs=128))
    ph.append(_movement("squeeze", 256 * 512))
    return ph


def fir_trace() -> list[Phase]:
    """4-tap FIR over 64k samples, 16-bit samples / 24-bit accumulators
    (Challenge 2). The 11 live word-level variables need 11 rows in BP
    (resident) but 265 vertical rows in BS -- a row overflow: the BS layout
    parks the overflowed accumulator plane (24 rows) in a neighbour array
    and evicts/reloads it once per tap phase."""
    w, acc_w, taps, n = 16, 24, 4, 65536
    live_words = 11
    assert SYS.bs_row_overflow(live_words, acc_w)
    spill_bits = acc_w * n  # one word-plane evict+reload per tap phase
    ph = [_movement("coeffs", taps * w * 512)]
    for t in range(taps):
        ph.append(_movement(f"tap{t}.in", n * w))
        mac_bp = cm.bp_mult(w) * _bp_batches(n, w)
        mac_bs = cm.bs_mult(w) * _bs_batches(n)
        ph.append(_phase(f"tap{t}.mac", mac_bp, mac_bs, rows_bp=11, rows_bs=128))
        ph.append(_phase(f"tap{t}.spill", 0, _xfer(spill_bits),
                         rows_bp=11, rows_bs=128))
    for t in range(taps - 1):
        add_bp = cm.BP_ADD * _bp_batches(n, w)
        add_bs = cm.bs_add(acc_w) * _bs_batches(n)
        ph.append(_phase(f"acc{t}", add_bp, add_bs, rows_bp=11, rows_bs=128))
    ph.append(_movement("out", n * acc_w))
    return ph


# ---------------------------------------------------------------------------
# Moderate-BP applications (band 1.2 - 1.5x)
# ---------------------------------------------------------------------------

def _conv_layer(name: str, n_out: int, k_elems: int = 9, w: int = 16,
                in_elems: int | None = None) -> list[Phase]:
    """One conv layer: n_out outputs, k_elems MACs each. ES-BP reuses window
    elements via logical row addressing (1x load); EP-BS reuses the vertical
    kernel extent via free row shifts but replicates across columns for the
    horizontal extent (effective 2x load; Challenge 3)."""
    in_e = n_out if in_elems is None else in_elems
    repl = 2.0
    load_bp = _xfer(in_e * w + k_elems * w * 512)
    load_bs = _xfer(in_e * w * repl + k_elems * w * 512)
    comp_bp = (k_elems * cm.bp_mult(w) + (k_elems - 1) * cm.BP_ADD) \
        * _bp_batches(n_out, w)
    comp_bs = (k_elems * cm.bs_mult(w) + (k_elems - 1) * cm.bs_add(2 * w)) \
        * _bs_batches(n_out)
    out = _xfer(n_out * 2 * w)
    return [
        _phase(f"{name}.load", load_bp, load_bs),
        _phase(f"{name}.mac", comp_bp, comp_bs),
        _phase(f"{name}.out", out, out),
    ]


_VGG_BLOCKS = {  # (channels, spatial, convs) per block, CIFAR-10 input
    # (the paper's Tier-2 setup: "CIFAR-10 for VGG-16", Sec. 5.2)
    "vgg13": [(64, 32, 2), (128, 16, 2), (256, 8, 2), (512, 4, 2), (512, 2, 2)],
    "vgg16": [(64, 32, 2), (128, 16, 2), (256, 8, 3), (512, 4, 3), (512, 2, 3)],
    "vgg19": [(64, 32, 2), (128, 16, 2), (256, 8, 4), (512, 4, 4), (512, 2, 4)],
}
_VGG_BATCH = 128  # batch inference


def vgg_trace(which: str = "vgg13") -> list[Phase]:
    ph: list[Phase] = []
    for bi, (c, s, reps) in enumerate(_VGG_BLOCKS[which]):
        n_out = c * s * s * _VGG_BATCH
        for r in range(reps):
            ph += _conv_layer(f"b{bi}c{r}", n_out)
    # CIFAR classifier: FC 512->512->10
    for fi, (m, n) in enumerate([(512, 512), (512, 512), (512, 10)]):
        ph += _gemv_phases(f"fc{fi}", m, n)
    return ph


def _gemv_phases(name: str, m: int, n: int, w: int = 16,
                 chunk: int = 64) -> list[Phase]:
    """y[n] = W[n,m] x[m]: n dot-products of length m, tree-split into
    `chunk`-way partial sums. DoP = n*chunk -- usually far below the 262,144
    1-bit PEs, so BS columns idle (Challenge 1)."""
    chunk = min(chunk, m)
    dop = n * chunk
    load = _xfer(n * m * w + m * w)
    macs_bp = (m // chunk) * (cm.bp_mult(w) + cm.BP_ADD) * _bp_batches(dop, w) \
        + cm.reduction_bp(chunk) * _bp_batches(n, w)
    macs_bs = (m // chunk) * (cm.bs_mult(w) + cm.bs_add(2 * w)) * _bs_batches(dop) \
        + cm.reduction_bs(2 * w) * _bs_batches(n)
    out = _xfer(n * 2 * w)
    return [_phase(f"{name}.load", load, load),
            _phase(f"{name}.mac", macs_bp, macs_bs),
            _phase(f"{name}.out", out, out)]


def gemm_trace() -> list[Phase]:
    """C = A B at 400x400, 16-bit, output-stationary: the 160k outputs fill
    only 61% of the BS columns while BP batches 10x (limited batching --
    the moderate-BP regime of Table 6)."""
    w, dim = 16, 400
    n_out = dim * dim
    ph = [_movement("loadAB", 2 * dim * dim * w)]
    comp_bp = dim * (cm.bp_mult(w) + cm.BP_ADD) * _bp_batches(n_out, w)
    comp_bs = dim * (cm.bs_mult(w) + cm.bs_add(2 * w)) * _bs_batches(n_out)
    ph.append(_phase("mac", comp_bp, comp_bs))
    ph.append(_movement("storeC", dim * dim * 2 * w))
    return ph


def gemv_trace() -> list[Phase]:
    return _gemv_phases("gemv", 4096, 512)


def conv2d_trace() -> list[Phase]:
    """Single 3x3 conv, 256x56x56 output (an ImageNet mid layer)."""
    return _conv_layer("conv", 256 * 56 * 56)


def downsample_trace() -> list[Phase]:
    """2x2 average downsample of a 1024x1024 16-bit image: 3 adds + shift
    per output. The stride-2 window regroup is a zero-cost logical remap in
    ES-BP but a physical inter-column shuffle in EP-BS (Challenge 3),
    costing a half-density restream."""
    w = 16
    n_out = 512 * 512
    ph = [_movement("in", 4 * n_out * w)]
    ph.append(_phase("regroup", 0, _xfer(4 * n_out * w * 0.5)))
    comp_bp = (3 * cm.BP_ADD + cm.bp_shift(2)) * _bp_batches(n_out, w)
    comp_bs = 3 * cm.bs_add(w) * _bs_batches(n_out)
    ph.append(_phase("avg", comp_bp, comp_bs))
    ph.append(_movement("out", n_out * w))
    return ph


# ---------------------------------------------------------------------------
# Balanced applications (band 1.0 - 1.15x)
# ---------------------------------------------------------------------------

def vector_add_trace() -> list[Phase]:
    """The Table-4 running example at 2K elements (band-interior; the 1K
    point sits exactly at the published 1.15x band edge)."""
    c_bp = MICROKERNELS["vector_add"].cost(Layout.BP, 2048, 16)
    c_bs = MICROKERNELS["vector_add"].cost(Layout.BS, 2048, 16)
    return [_phase("vadd", c_bp.total, c_bs.total)]


def axpy_trace() -> list[Phase]:
    """y = a*x + y, 64K elements, 32-bit (movement-dominated at this size)."""
    w, n = 32, 65536
    ph = [_movement("load", 2 * n * w)]
    comp_bp = (cm.bp_mult(w) + cm.BP_ADD) * _bp_batches(n, w)
    comp_bs = (cm.bs_mult(w) + cm.bs_add(w)) * _bs_batches(n)
    ph.append(_phase("fma", comp_bp, comp_bs))
    ph.append(_movement("store", n * w))
    return ph


def pooling_trace() -> list[Phase]:
    """2x2 max-pool over 512x512 outputs, 16-bit, streamed."""
    w, n_out = 16, 256 * 256
    ph = [_movement("in", 4 * n_out * w)]
    comp_bp = 3 * cm.minmax_bp(w) * _bp_batches(n_out, w)
    comp_bs = 3 * cm.minmax_bs(w) * _bs_batches(n_out)
    ph.append(_phase("max", comp_bp, comp_bs))
    ph.append(_movement("out", n_out * w))
    return ph


def prefix_sum_trace() -> list[Phase]:
    """Hillis-Steele scan over 64k 16-bit elements: log2(n) add sweeps,
    movement-dominated (Challenge 2 batching)."""
    w, n = 16, 65536
    steps = int(math.log2(n))
    ph = [_movement("in", n * w)]
    comp_bp = steps * cm.BP_ADD * _bp_batches(n, w)
    comp_bs = steps * cm.bs_add(w) * _bs_batches(n)
    # each sweep re-streams the shifted operand
    ph.append(_movement("shift_streams", steps * n * w / 8))
    ph.append(_phase("sweeps", comp_bp, comp_bs))
    ph.append(_movement("out", n * w))
    return ph


# ---------------------------------------------------------------------------
# BS-preference applications (band 0.6 - 0.9x: BS faster)
# ---------------------------------------------------------------------------

def histogram_trace() -> list[Phase]:
    """256-bin histogram of 64k 8-bit samples via bit-sliced bin matching
    (equal) + popcount accumulation: bit-centric, full-density (Challenge 1
    favours BS)."""
    w, n, bins_groups = 8, 65536, 16
    ph = [_movement("in", n * w)]
    for g in range(bins_groups):
        eq_bp = cm.equal_bp(w) * _bp_batches(n, w)
        eq_bs = cm.equal_bs(w) * _bs_batches(n)
        ph.append(_phase(f"match{g}", eq_bp, eq_bs))
        # BP must popcount the match masks (D&C); BS counts serially in place
        ph.append(_phase(f"count{g}", cm.bitcount_bp(w) * _bp_batches(n, w),
                         cm.reduction_bs(w) * _bs_batches(n)))
    ph.append(_movement("bins_out", 256 * 32))
    return ph


def hdc_trace() -> list[Phase]:
    """Hyperdimensional computing: hamming distance of a 8192-bit query
    against 4096 class vectors: XOR + popcount. Bit-level DoP saturates the
    1-bit PEs; BS also emits half-width counts (Table-5 bitcount
    convention), while BP pays the D&C popcount and word-width readout."""
    d, classes, w = 8192, 4096, 16
    n_bits = d * classes
    n_words = n_bits // w
    ph = [_movement("load_vectors", n_bits)]
    xor_bp = cm.BP_LOGIC * _bp_batches(n_words, w)
    xor_bs = 1 * _bs_batches(n_bits)
    ph.append(_phase("xor", xor_bp, xor_bs))
    pc_bp = cm.bitcount_bp(w) * _bp_batches(n_words, w)
    pc_bs = cm.bitcount_bs(w) * _bs_batches(n_bits)
    ph.append(_phase("popcount", pc_bp, pc_bs))
    red_bp = cm.reduction_bp(d // w) * _bp_batches(classes, w)
    red_bs = cm.reduction_bs(w) * _bs_batches(classes)
    ph.append(_phase("reduce", red_bp, red_bs))
    ph.append(_phase("scores_out", _xfer(n_words * w), _xfer(n_words * w / 2)))
    return ph


def bitweave_db_trace() -> list[Phase]:
    """BitWeaving column scans (database predicates over 2b/4b codes, 64k
    rows each): BS streams full-density vertical bit planes (b bits + 0.5b
    predicate planes per code); BP must pad codes to byte containers."""
    ph = []
    n = 65536
    for reps, bits in [(4, 2), (4, 4)]:
        for r in range(reps):
            load_bp = _xfer(n * 8)  # byte-padded codes
            load_bs = _xfer(n * bits * 1.5)  # density = code + predicate planes
            comp = cm.bitweave_compute(bits, Layout.BP)
            ph.append(_phase(f"scan{bits}b_{r}.load", load_bp, load_bs))
            ph.append(_phase(f"scan{bits}b_{r}.pred", comp, comp))
            ph.append(_movement(f"scan{bits}b_{r}.out", n / 8))
    return ph


def xnor_net_trace() -> list[Phase]:
    """Binary conv net (XNOR-Net): xnor + popcount MACs, binary activations
    (the paper's canonical BS-friendly AI workload). Same density/readout
    conventions as HDC."""
    w = 16
    ph = []
    for name, n_out, k in [("c1", 128 * 28 * 28, 288), ("c2", 256 * 14 * 14, 576)]:
        n_macs = n_out * k
        n_words = n_macs // w
        ph.append(_movement(f"{name}.in", n_macs))
        xnor_bp = cm.BP_LOGIC * _bp_batches(n_words, w)
        xnor_bs = 1 * _bs_batches(n_macs)
        pc_bp = cm.bitcount_bp(w) * _bp_batches(n_words, w)
        pc_bs = cm.bitcount_bs(w) * _bs_batches(n_macs)
        ph.append(_phase(f"{name}.xnor", xnor_bp, xnor_bs))
        ph.append(_phase(f"{name}.popc", pc_bp, pc_bs))
        ph.append(_phase(f"{name}.out", _xfer(n_words * w), _xfer(n_words * w / 2)))
    return ph


# ---------------------------------------------------------------------------
# Hybrid-recommended applications
# ---------------------------------------------------------------------------

def radix_sort_trace() -> list[Phase]:
    """LSD radix sort, 64k 16-bit keys, 4-bit digits: per pass, digit
    extraction + match counting is bit-level (BS-friendly); the scatter is a
    word-level permutation (BP-friendly logical shuffle)."""
    w, n, digit = 16, 65536, 4
    passes = w // digit
    ph = [_movement("keys_in", n * w)]
    for p in range(passes):
        cnt_bp = (16 * cm.equal_bp(digit) + cm.bitcount_bp(16)) \
            * _bp_batches(n, w)
        cnt_bs = (16 * cm.equal_bs(digit) + cm.reduction_bs(digit)) \
            * _bs_batches(n)
        ph.append(_phase(f"count{p}", cnt_bp, cnt_bs, rows_bp=8, rows_bs=64))
        scan_bp = cm.reduction_bp(16) * 2
        scan_bs = cm.reduction_bs(16) * 16
        ph.append(_phase(f"scan{p}", scan_bp, scan_bs, rows_bp=8, rows_bs=64))
        scat_bp = _xfer(n * w / 4)  # logical-shuffle assisted gather
        scat_bs = _xfer(n * w) + 2 * n // 512  # physical inter-column moves
        ph.append(_phase(f"scatter{p}", scat_bp, scat_bs, rows_bp=8, rows_bs=64))
    ph.append(_movement("keys_out", n * w))
    return ph


def db_query_trace() -> list[Phase]:
    """SELECT ... WHERE pred GROUP-BY aggregate: bitweave scan (BS) feeding a
    word-level aggregation (BP)."""
    n = 65536
    ph = []
    load_bp = _xfer(n * 16 * 2 * 1.25)
    load_bs = _xfer(n * 16 * 2 * 0.5)
    ph.append(_phase("scan.load", load_bp, load_bs, rows_bp=32, rows_bs=96))
    comp = cm.bitweave_compute(4, Layout.BP) * 8
    ph.append(_phase("scan.pred", int(comp * 1.6), comp, rows_bp=32, rows_bs=96))
    agg_bp = (cm.BP_ADD + cm.minmax_bp(32)) * 64
    agg_bs = (cm.bs_add(32) + cm.minmax_bs(32)) * 64
    ph.append(_phase("aggregate", agg_bp, agg_bs, rows_bp=32, rows_bs=96))
    ph.append(_movement("out", n))
    return ph


# ---------------------------------------------------------------------------
# Registry + evaluation
# ---------------------------------------------------------------------------

APP_TRACES: dict[str, Callable[[], list[Phase]]] = {
    "brightness": brightness_trace,
    "kmeans": kmeans_trace,
    "keccak": keccak_trace,
    "fir": fir_trace,
    "vgg13": lambda: vgg_trace("vgg13"),
    "vgg16": lambda: vgg_trace("vgg16"),
    "vgg19": lambda: vgg_trace("vgg19"),
    "gemm": gemm_trace,
    "gemv": gemv_trace,
    "conv2d": conv2d_trace,
    "downsample": downsample_trace,
    "vector_add": vector_add_trace,
    "axpy": axpy_trace,
    "pooling": pooling_trace,
    "prefix_sum": prefix_sum_trace,
    "histogram": histogram_trace,
    "hdc": hdc_trace,
    "bitweave_db": bitweave_db_trace,
    "xnor_net": xnor_net_trace,
    "aes": aes_trace,
    "radix_sort": radix_sort_trace,
    "db_query": db_query_trace,
}


def evaluate_app(name: str, sys: SystemParams = PAPER_SYSTEM) -> dict:
    phases = APP_TRACES[name]()
    p: Plan = plan(phases, sys)
    return {
        "app": name,
        "bp_cycles": p.static_bp,
        "bs_cycles": p.static_bs,
        "bs_over_bp": p.static_bs / p.static_bp,
        "hybrid_cycles": p.total_cycles,
        "hybrid_speedup": p.hybrid_speedup,
        "is_hybrid": p.is_hybrid,
        "n_transposes": p.n_transposes,
    }


def evaluate_all(sys: SystemParams = PAPER_SYSTEM) -> dict[str, dict]:
    return {name: evaluate_app(name, sys) for name in APP_TRACES}
