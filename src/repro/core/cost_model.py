"""Cycle-accurate BP/BS primitive and kernel cost model (paper Table 2/3).

The model decomposes every kernel as

    total = load + compute + readout            (paper Sec. 5.2)

with `load`/`readout` charged on the row-serial bus (SystemParams.xfer_cycles)
and `compute` charged per Table 2 primitives, multiplied by the number of
capacity batches.

Primitive costs (paper Table 2)
-------------------------------
Bit-Parallel (word-level PEs):      Bit-Serial (1-bit column PEs):
  logic (N-bit)      1                1-bit add/sub   1
  ADD  (N-bit)       1                shift           0 (adjacent rows)
  SUB  (N-bit)       2                1-bit MUX       4 (synthesized)
  MULT (N-bit)       N+2
  SHIFT (k bits)     k

Derived kernel formulas are calibrated against the published Tables 3/5; the
few per-width constants that cannot be expressed by one closed form across
both published widths (see DESIGN.md Sec. 8) are kept in explicit calibration
dicts with a documented fallback.

Every Table-5 kernel formula here has an *executable* counterpart: a
micro-op program (`repro.pim.programs`) replayed with per-op Table-2 charges
by `repro.pim.executor` on the simulated array.  `MicroKernel.
executed_vs_analytic` differences the two, and tests/test_microcode.py fails
if a formula drifts from what the primitives actually require (the
validation contract is documented in src/repro/pim/README.md; the few
documented per-width deltas live in DESIGN.md Sec. 8).

Single source of truth (design-space sweep engine)
--------------------------------------------------
Each Table-5 kernel is described once, declaratively, by a
:class:`KernelRecipe`: per-layout compute cycles and input/output movement
written against the tiny numeric namespace :class:`ScalarOps` provides
(``ceil_div`` / ``floor_log2`` / ``ceil_log2`` / ``where`` / ``by_width``).
The scalar public functions below (``bp_mult``, ``bs_add``, ...) and the
`repro.core.microkernels` assembly are thin wrappers evaluating the recipes
with :data:`SCALAR_OPS`; `repro.sweep.vectorized` evaluates the *same*
recipes with a jnp namespace so a whole (width x geometry) grid costs one
jitted call.  tests/test_sweep.py pins the two evaluations bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

from repro.core.params import SystemParams, PAPER_SYSTEM


class Layout(str, enum.Enum):
    BP = "BP"
    BS = "BS"


@dataclasses.dataclass(frozen=True)
class CycleCost:
    """load/compute/readout decomposition of one kernel execution."""

    load: int
    compute: int
    readout: int

    @property
    def total(self) -> int:
        return self.load + self.compute + self.readout

    def __add__(self, other: "CycleCost") -> "CycleCost":
        return CycleCost(
            self.load + other.load,
            self.compute + other.compute,
            self.readout + other.readout,
        )

    def scale(self, k: int) -> "CycleCost":
        return CycleCost(self.load * k, self.compute * k, self.readout * k)


# ---------------------------------------------------------------------------
# Table 2 primitives
# ---------------------------------------------------------------------------

BP_LOGIC = 1
BP_ADD = 1
BP_SUB = 2
BS_ADD1 = 1  # per bit
BS_SHIFT = 0
BS_MUX1 = 4  # per bit


# ---------------------------------------------------------------------------
# The numeric namespace the shared kernel formulas are written against
# ---------------------------------------------------------------------------

class ScalarOps:
    """Python-int evaluation of the shared kernel formulas.

    `repro.sweep.vectorized.JnpOps` provides the same vocabulary over jnp
    arrays; every recipe below must stay exact under both (the sweep
    equality suite enforces it).
    """

    @staticmethod
    def ceil_div(a: int, b: int) -> int:
        return -(-a // b)

    @staticmethod
    def maximum(a: int, b: int) -> int:
        return max(a, b)

    @staticmethod
    def where(cond: bool, a: int, b: int) -> int:
        return a if cond else b

    @staticmethod
    def floor_log2(x: int) -> int:
        return int(x).bit_length() - 1

    @staticmethod
    def ceil_log2(x: int) -> int:
        """ceil(log2(max(2, x))) without floats (exact at powers of two)."""
        return (max(2, int(x)) - 1).bit_length()

    @staticmethod
    def by_width(width: int, table: dict, fallback: int) -> int:
        """Per-width calibration-dict select with a closed-form fallback."""
        return table.get(width, fallback)


SCALAR_OPS = ScalarOps()


# ---------------------------------------------------------------------------
# Kernel recipes: ONE declarative description per Table-5 kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelRecipe:
    """Backend-parameterized Table-5 kernel description.

    ``compute[layout](ops, width, n)`` is the per-batch compute-cycle
    formula; ``in_half_bits`` / ``out_half_bits`` give data movement in
    *half-bit* units (2x the bit count) so the fractional operand densities
    of Table 5 (1.5x, 2.5x, nw/2, ...) stay exact integers under both the
    scalar and the jnp evaluator.  ``batched=False`` kernels (the BitWeaving
    scans) publish flat compute costs that do not scale with capacity
    batches.
    """

    name: str
    compute: dict        # Layout -> Callable[(ops, width, n)] -> cycles
    in_half_bits: dict   # Layout -> Callable[(width, n)] -> 2x input bits
    out_half_bits: dict  # Layout -> Callable[(width, n)] -> 2x output bits
    batched: bool = True


def _r(name, *, bp, bs, in_bp, in_bs=None, out_bp, out_bs=None, batched=True):
    return KernelRecipe(
        name=name,
        compute={Layout.BP: bp, Layout.BS: bs},
        in_half_bits={Layout.BP: in_bp,
                      Layout.BS: in_bp if in_bs is None else in_bs},
        out_half_bits={Layout.BP: out_bp,
                       Layout.BS: out_bp if out_bs is None else out_bs},
        batched=batched,
    )


# MIN/MAX (BP, "shift-mask" variant): sub + sign-extract shift + mask ops.
# Published: 21 @16b (Table 5), 36 @32b (Table 3) -- no single shift-count
# formula fits both (DESIGN.md Sec. 8); calibrated per width, fallback w+5.
_MINMAX_BP_CALIB = {16: 21, 32: 36}


def bitweave_compute(bits: int, mode: Layout) -> int:
    """BitWeaving predicate scan (1b/2b/4b codes). Published compute cycles
    follow the doubling recurrence c(2b) = 2*c(b) - 16 from c(1)=225
    (225 / 434 / 852 for 1b/2b/4b; Table 5). Mode does not change the
    published compute term -- the published rows pick the better mode per
    code width."""
    del mode
    c = 225
    b = 1
    while b < bits:
        c = 2 * c - 16
        b *= 2
    return c


def _bitweave_recipe(bits: int) -> KernelRecipe:
    # Packed b-bit codes + (2/b) predicate-constant planes (load rows
    # 96/64/48 for b=1/2/4 @ N=1024); output is a result bitvector (n
    # bits). Compute is the flat published scan cost (not batch-scaled).
    c = bitweave_compute(bits, Layout.BP)
    return _r(f"bitweave{bits}",
              bp=lambda o, w, n: c, bs=lambda o, w, n: c,
              in_bp=lambda w, n: 32 * n + (64 // bits) * n,
              out_bp=lambda w, n: 2 * n,
              batched=False)


#: kernel name -> recipe; keys match `repro.core.microkernels.MICROKERNELS`.
KERNEL_RECIPES: dict[str, KernelRecipe] = {
    # --- arithmetic --------------------------------------------------------
    "vector_add": _r(
        "vector_add",
        bp=lambda o, w, n: BP_ADD,
        bs=lambda o, w, n: w * BS_ADD1,
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 2 * n * w),
    "vector_sub": _r(
        "vector_sub",
        bp=lambda o, w, n: BP_SUB,
        bs=lambda o, w, n: w * BS_ADD1,
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 2 * n * w),
    # BP widens both operands to the 2w product width before compute
    # (Table 5: load 128 rows @16b/N=1024); BS loads native-width operands
    # and grows the product in place (load 64).
    "multu": _r(
        "multu",
        bp=lambda o, w, n: w + 2,           # N-bit word multiply (Table 2)
        bs=lambda o, w, n: w * w,           # shift-and-add: W adds of W bits
        in_bp=lambda w, n: 8 * n * w, in_bs=lambda w, n: 4 * n * w,
        out_bp=lambda w, n: 4 * n * w),
    "multu_const": _r(
        "multu_const",
        bp=lambda o, w, n: w + 2,
        bs=lambda o, w, n: w * w,
        in_bp=lambda w, n: 8 * n * w, in_bs=lambda w, n: 4 * n * w,
        out_bp=lambda w, n: 4 * n * w),
    "divu": _r(
        "divu",
        # Restoring division: word datapath calibrated 2.5*w^2 (640 @16b);
        # bit-serial per quotient bit a w-bit sub + 4-cycle restore MUX.
        bp=lambda o, w, n: o.ceil_div(5 * w * w, 2),
        bs=lambda o, w, n: 5 * w * w,
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 2 * n * w),
    "min": _r(
        "min",
        bp=lambda o, w, n: o.by_width(w, _MINMAX_BP_CALIB, w + 5),
        bs=lambda o, w, n: 6 * w,  # sub (w) + MUX select (4w) + commit (w)
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 2 * n * w),
    "max": _r(
        "max",
        bp=lambda o, w, n: o.by_width(w, _MINMAX_BP_CALIB, w + 5),
        bs=lambda o, w, n: 6 * w,
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 2 * n * w),
    # --- logical / bit-manipulation ---------------------------------------
    # Tree reduction: readout is the final-stage partial-sum region
    # (n*w/2 bits; Table 5 readout 16 rows @ N=1024).
    "reduction": _r(
        "reduction",
        bp=lambda o, w, n: 2 * o.ceil_log2(n) - 1,
        bs=lambda o, w, n: w,      # native serial column summation pipeline
        in_bp=lambda w, n: 2 * n * w, out_bp=lambda w, n: n * w),
    # BP D&C stages keep data + two shifted-mask operands resident
    # (4*n*w load bits; Table 5 load 128 rows); BS reads data only.
    "bitcount": _r(
        "bitcount",
        bp=lambda o, w, n: 6 * o.floor_log2(w) + 1,  # D&C popcount
        bs=lambda o, w, n: 5 * w,                    # serial summation
        in_bp=lambda w, n: 8 * n * w, in_bs=lambda w, n: 2 * n * w,
        out_bp=lambda w, n: 2 * n * w, out_bs=lambda w, n: n * w),
    "bitweave1": _bitweave_recipe(1),
    "bitweave2": _bitweave_recipe(2),
    "bitweave4": _bitweave_recipe(4),
    # --- control / predicate ----------------------------------------------
    "abs": _r(
        "abs",
        bp=lambda o, w, n: w + 2,  # sign broadcast + xor + sub-ish fixup
        bs=lambda o, w, n: 3 * w,  # serialized conditional negate
        in_bp=lambda w, n: 2 * n * w, out_bp=lambda w, n: 2 * n * w),
    # BP holds cond/true/false words (3 operands). BS stores the condition
    # as a packed half-width flag plane => 2.5 operand loads (Table 5: 80).
    "if_then_else": _r(
        "if_then_else",
        bp=lambda o, w, n: 7,          # width-independent mask-0s variant
        bs=lambda o, w, n: 3 * w + 1,  # cond sub + 2w masked-and + combine
        in_bp=lambda w, n: 6 * n * w, in_bs=lambda w, n: 5 * n * w,
        out_bp=lambda w, n: 2 * n * w),
    "equal": _r(
        "equal",
        bp=lambda o, w, n: w + 6,      # XOR + OR-reduce tree + flag fixups
        bs=lambda o, w, n: 2 * w + 1,  # serial XOR (w) + OR-reduce (w) + 1
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 2 * n * w),
    "ge_0": _r(
        "ge_0",
        bp=lambda o, w, n: w + 1,  # sign shift (w-1) + xor + incr
        bs=lambda o, w, n: 1,      # read the sign-bit row
        in_bp=lambda w, n: 2 * n * w, out_bp=lambda w, n: n * w),
    # BS keeps a packed zero-test scratch plane => 1.5 operand loads
    # (reconciles the inconsistent published row; DESIGN.md Sec. 8).
    "gt_0": _r(
        "gt_0",
        bp=lambda o, w, n: 2 * w + 3,  # ge_0 (w+1) + nonzero test (w+2)
        bs=lambda o, w, n: w + 1,      # sign bit + serial OR-reduce
        in_bp=lambda w, n: 2 * n * w, in_bs=lambda w, n: 3 * n * w,
        out_bp=lambda w, n: 2 * n * w, out_bs=lambda w, n: n * w),
    # Published row (N=8192): load 512 / readout 512 in both modes -- the
    # kernel streams data + zero-mask in, result + mask out (2x each way).
    "relu": _r(
        "relu",
        bp=lambda o, w, n: w + 1,
        bs=lambda o, w, n: w + 1,
        in_bp=lambda w, n: 4 * n * w, out_bp=lambda w, n: 4 * n * w),
}


def eval_recipe(kernel, layout: Layout, ops=SCALAR_OPS, *, n, width,
                total_columns, row_bandwidth_bits):
    """Evaluate one kernel recipe -> (load, compute, readout) cycles.

    `ops` selects the evaluation backend (SCALAR_OPS here, JnpOps in
    `repro.sweep.vectorized`); `n`/`width`/`total_columns`/
    `row_bandwidth_bits` may be python ints or broadcastable jnp arrays.
    """
    r = KERNEL_RECIPES[kernel] if isinstance(kernel, str) else kernel
    layout = Layout(layout)
    load = ops.ceil_div(r.in_half_bits[layout](width, n),
                        2 * row_bandwidth_bits)
    readout = ops.ceil_div(r.out_half_bits[layout](width, n),
                           2 * row_bandwidth_bits)
    comp = r.compute[layout](ops, width, n)
    if r.batched:
        # compute is capacity-parallel: all resident elements step together
        elems = total_columns // width if layout is Layout.BP else total_columns
        comp = comp * ops.maximum(1, ops.ceil_div(n, elems))
    return load, comp, readout


def _compute(kernel: str, layout: Layout, width: int, n: int = 1) -> int:
    """Per-batch compute cycles of `kernel` via the shared recipe table."""
    return KERNEL_RECIPES[kernel].compute[layout](SCALAR_OPS, width, n)


# ---------------------------------------------------------------------------
# Scalar primitive/kernel compute API (thin wrappers over the recipes)
# ---------------------------------------------------------------------------

def bp_mult(width: int) -> int:
    """N-bit word multiply: N+2 cycles (Table 2)."""
    return _compute("multu", Layout.BP, width)


def bp_shift(k: int) -> int:
    return k


def bs_add(width: int) -> int:
    """Ripple bit-serial add: 1 cycle per bit."""
    return _compute("vector_add", Layout.BS, width)


def bs_sub(width: int) -> int:
    return _compute("vector_sub", Layout.BS, width)


def bs_mult(width: int) -> int:
    """Shift-and-add multiply: W partial adds of W bits each => W^2.
    (Table 3: 1024 cycles @32b; Table 5: 256 @16b.)"""
    return _compute("multu", Layout.BS, width)


def bs_mux(width: int) -> int:
    return BS_MUX1 * width


def minmax_bp(width: int) -> int:
    """Shift-mask variant: published 21 @16b / 36 @32b, fallback w+5."""
    return _compute("min", Layout.BP, width)


def minmax_bs(width: int) -> int:
    """sub (w) + synthesized per-bit MUX select (4w) + conditional copy (w)."""
    return _compute("min", Layout.BS, width)  # 96 @16b, 192 @32b (Tables 5/3)


def div_bp(width: int) -> int:
    """Restoring division, word datapath: calibrated 2.5*w^2 (640 @16b, T5)."""
    return _compute("divu", Layout.BP, width)


def div_bs(width: int) -> int:
    """Restoring division, bit-serial: per quotient bit a w-bit sub + 4-cycle
    restore MUX => 5*w^2 (1280 @16b, Table 5)."""
    return _compute("divu", Layout.BS, width)


def abs_bp(width: int) -> int:
    """shift(w-1) sign broadcast + xor + sub-ish fixup: w+2 (18 @16b)."""
    return _compute("abs", Layout.BP, width)


def abs_bs(width: int) -> int:
    """serialized conditional negate: 3w (48 @16b)."""
    return _compute("abs", Layout.BS, width)


def if_then_else_bp(width: int) -> int:
    """Predicated select with word mask ops: 7 cycles at any width
    (7 @16b Table 5; 7 @32b Table 3)."""
    return _compute("if_then_else", Layout.BP, width)


def if_then_else_bs(width: int) -> int:
    """Condition (sub w) + 2w masked-and + 1 combine: 3w+1 (49 @16b, 97 @32b)."""
    return _compute("if_then_else", Layout.BS, width)


def equal_bp(width: int) -> int:
    """XOR + OR-reduce tree + flag fixups: calibrated w+6 (22 @16b)."""
    return _compute("equal", Layout.BP, width)


def equal_bs(width: int) -> int:
    """serial XOR (w) + serial OR-reduce (w) + flag (1): 2w+1 (33 @16b)."""
    return _compute("equal", Layout.BS, width)


def ge0_bp(width: int) -> int:
    """sign shift (w-1) + xor + incr: w+1 (17 @16b)."""
    return _compute("ge_0", Layout.BP, width)


def ge0_bs(width: int) -> int:
    """read the sign-bit row: 1 cycle."""
    return _compute("ge_0", Layout.BS, width)


def gt0_bp(width: int) -> int:
    """ge_0 (w+1) + nonzero test (w+2): 2w+3 (35 @16b)."""
    return _compute("gt_0", Layout.BP, width)


def gt0_bs(width: int) -> int:
    """sign bit + serial OR-reduce over bits: w+1 (17 @16b)."""
    return _compute("gt_0", Layout.BS, width)


def relu_k(width: int) -> int:
    """ReLU mask-and: w+1 in both modes (17 @16b; published row shows equal
    compute for BP and BS)."""
    return _compute("relu", Layout.BP, width)


def reduction_bp(n: int) -> int:
    """Tree reduction over n elements: 2*ceil(log2 n) - 1 (19 @1024, T5)."""
    return _compute("reduction", Layout.BP, 16, n=n)


def reduction_bs(width: int) -> int:
    """Native serial column summation pipeline: w cycles (16 @16b, T5)."""
    return _compute("reduction", Layout.BS, width)


def bitcount_bp(width: int) -> int:
    """Divide-and-conquer popcount: 6*log2(w)+1 (25 @16b, T5)."""
    return _compute("bitcount", Layout.BP, width)


def bitcount_bs(width: int) -> int:
    """Serial summation of bit rows: 5w (80 @16b, T5)."""
    return _compute("bitcount", Layout.BS, width)


# ---------------------------------------------------------------------------
# Generic kernel cost assembly
# ---------------------------------------------------------------------------


def movement(
    sys: SystemParams,
    *,
    in_bits: float,
    out_bits: float,
) -> tuple[int, int]:
    return sys.xfer_cycles(in_bits), sys.xfer_cycles(out_bits)


def elementwise_cost(
    layout: Layout,
    *,
    n: int,
    width: int,
    per_op_bp: int,
    per_op_bs: int,
    n_inputs: int = 2,
    in_width: Optional[int] = None,
    out_width: Optional[int] = None,
    sys: SystemParams = PAPER_SYSTEM,
) -> CycleCost:
    """Assemble load/compute/readout for an elementwise kernel over n words."""
    in_w = width if in_width is None else in_width
    out_w = width if out_width is None else out_width
    load, readout = movement(sys, in_bits=n_inputs * n * in_w, out_bits=n * out_w)
    if layout is Layout.BP:
        compute = per_op_bp * sys.bp_batches(n, width)
    else:
        compute = per_op_bs * sys.bs_batches(n)
    return CycleCost(load, compute, readout)


def vector_add_cost(layout: Layout, n: int, width: int = 16,
                    sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    """The paper's running example (Table 4)."""
    return elementwise_cost(
        layout, n=n, width=width, per_op_bp=BP_ADD, per_op_bs=bs_add(width), sys=sys
    )


# ---------------------------------------------------------------------------
# Utilization (Challenge 1 / Fig. 8)
# ---------------------------------------------------------------------------


def utilization(layout: Layout, parallel_ops: int, width: int,
                sys: SystemParams = PAPER_SYSTEM) -> float:
    """Fraction of compute columns used by `parallel_ops` concurrent W-bit ops.

    BS: one column per op; BP: `width` columns per op. (Fig. 8 definition.)
    """
    if layout is Layout.BS:
        used = parallel_ops
    else:
        used = parallel_ops * width
    return min(1.0, used / sys.total_columns)


def seconds(cycles: int, sys: SystemParams = PAPER_SYSTEM) -> float:
    return cycles / (sys.clock_ghz * 1e9)
