"""Cycle-accurate BP/BS primitive and kernel cost model (paper Table 2/3).

The model decomposes every kernel as

    total = load + compute + readout            (paper Sec. 5.2)

with `load`/`readout` charged on the row-serial bus (SystemParams.xfer_cycles)
and `compute` charged per Table 2 primitives, multiplied by the number of
capacity batches.

Primitive costs (paper Table 2)
-------------------------------
Bit-Parallel (word-level PEs):      Bit-Serial (1-bit column PEs):
  logic (N-bit)      1                1-bit add/sub   1
  ADD  (N-bit)       1                shift           0 (adjacent rows)
  SUB  (N-bit)       2                1-bit MUX       4 (synthesized)
  MULT (N-bit)       N+2
  SHIFT (k bits)     k

Derived kernel formulas are calibrated against the published Tables 3/5; the
few per-width constants that cannot be expressed by one closed form across
both published widths (see DESIGN.md Sec. 8) are kept in explicit calibration
dicts with a documented fallback.

Every Table-5 kernel formula here has an *executable* counterpart: a
micro-op program (`repro.pim.programs`) replayed with per-op Table-2 charges
by `repro.pim.executor` on the simulated array.  `MicroKernel.
executed_vs_analytic` differences the two, and tests/test_microcode.py fails
if a formula drifts from what the primitives actually require (the
validation contract is documented in src/repro/pim/README.md; the few
documented per-width deltas live in DESIGN.md Sec. 8).
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional

from repro.core.params import SystemParams, PAPER_SYSTEM


class Layout(str, enum.Enum):
    BP = "BP"
    BS = "BS"


@dataclasses.dataclass(frozen=True)
class CycleCost:
    """load/compute/readout decomposition of one kernel execution."""

    load: int
    compute: int
    readout: int

    @property
    def total(self) -> int:
        return self.load + self.compute + self.readout

    def __add__(self, other: "CycleCost") -> "CycleCost":
        return CycleCost(
            self.load + other.load,
            self.compute + other.compute,
            self.readout + other.readout,
        )

    def scale(self, k: int) -> "CycleCost":
        return CycleCost(self.load * k, self.compute * k, self.readout * k)


# ---------------------------------------------------------------------------
# Table 2 primitives
# ---------------------------------------------------------------------------

BP_LOGIC = 1
BP_ADD = 1
BP_SUB = 2
BS_ADD1 = 1  # per bit
BS_SHIFT = 0
BS_MUX1 = 4  # per bit


def bp_mult(width: int) -> int:
    """N-bit word multiply: N+2 cycles (Table 2)."""
    return width + 2


def bp_shift(k: int) -> int:
    return k


def bs_add(width: int) -> int:
    """Ripple bit-serial add: 1 cycle per bit."""
    return width * BS_ADD1


def bs_sub(width: int) -> int:
    return width * BS_ADD1


def bs_mult(width: int) -> int:
    """Shift-and-add multiply: W partial adds of W bits each => W^2.
    (Table 3: 1024 cycles @32b; Table 5: 256 @16b.)"""
    return width * width


def bs_mux(width: int) -> int:
    return BS_MUX1 * width


# ---------------------------------------------------------------------------
# Derived word-level kernels (compute-only cycles), Table 3 / Table 5 calibrated
# ---------------------------------------------------------------------------

# MIN/MAX (BP, "shift-mask" variant): sub + sign-extract shift + mask ops.
# Published: 21 @16b (Table 5), 36 @32b (Table 3) -- no single shift-count
# formula fits both (DESIGN.md Sec. 8); calibrated per width, fallback w+5.
_MINMAX_BP_CALIB = {16: 21, 32: 36}


def minmax_bp(width: int) -> int:
    return _MINMAX_BP_CALIB.get(width, width + 5)


def minmax_bs(width: int) -> int:
    """sub (w) + synthesized per-bit MUX select (4w) + conditional copy (w)."""
    return 6 * width  # 96 @16b, 192 @32b  (Tables 5/3)


def div_bp(width: int) -> int:
    """Restoring division, word datapath: calibrated 2.5*w^2 (640 @16b, T5)."""
    return int(math.ceil(2.5 * width * width))


def div_bs(width: int) -> int:
    """Restoring division, bit-serial: per quotient bit a w-bit sub + 4-cycle
    restore MUX => 5*w^2 (1280 @16b, Table 5)."""
    return 5 * width * width


def abs_bp(width: int) -> int:
    """shift(w-1) sign broadcast + xor + sub-ish fixup: w+2 (18 @16b)."""
    return width + 2


def abs_bs(width: int) -> int:
    """serialized conditional negate: 3w (48 @16b)."""
    return 3 * width


def if_then_else_bp(width: int) -> int:  # noqa: ARG001  (width-independent)
    """Predicated select with word mask ops: 7 cycles at any width
    (7 @16b Table 5; 7 @32b Table 3)."""
    return 7


def if_then_else_bs(width: int) -> int:
    """Condition (sub w) + 2w masked-and + 1 combine: 3w+1 (49 @16b, 97 @32b)."""
    return 3 * width + 1


def equal_bp(width: int) -> int:
    """XOR + OR-reduce tree + flag fixups: calibrated w+6 (22 @16b)."""
    return width + 6


def equal_bs(width: int) -> int:
    """serial XOR (w) + serial OR-reduce (w) + flag (1): 2w+1 (33 @16b)."""
    return 2 * width + 1


def ge0_bp(width: int) -> int:
    """sign shift (w-1) + xor + incr: w+1 (17 @16b)."""
    return width + 1


def ge0_bs(width: int) -> int:  # noqa: ARG001
    """read the sign-bit row: 1 cycle."""
    return 1


def gt0_bp(width: int) -> int:
    """ge_0 (w+1) + nonzero test (w+2): 2w+3 (35 @16b)."""
    return 2 * width + 3


def gt0_bs(width: int) -> int:
    """sign bit + serial OR-reduce over bits: w+1 (17 @16b)."""
    return width + 1


def relu_k(width: int) -> int:
    """ReLU mask-and: w+1 in both modes (17 @16b; published row shows equal
    compute for BP and BS)."""
    return width + 1


def reduction_bp(n: int) -> int:
    """Tree reduction over n elements: 2*ceil(log2 n) - 1 (19 @1024, T5)."""
    return 2 * int(math.ceil(math.log2(max(2, n)))) - 1


def reduction_bs(width: int) -> int:
    """Native serial column summation pipeline: w cycles (16 @16b, T5)."""
    return width


def bitcount_bp(width: int) -> int:
    """Divide-and-conquer popcount: 6*log2(w)+1 (25 @16b, T5)."""
    return 6 * int(math.log2(width)) + 1


def bitcount_bs(width: int) -> int:
    """Serial summation of bit rows: 5w (80 @16b, T5)."""
    return 5 * width


def bitweave_compute(bits: int, mode: Layout) -> int:
    """BitWeaving predicate scan (1b/2b/4b codes). Published compute cycles
    follow the doubling recurrence c(2b) = 2*c(b) - 16 from c(1)=225
    (225 / 434 / 852 for 1b/2b/4b; Table 5). Mode does not change the
    published compute term -- the published rows pick the better mode per
    code width."""
    del mode
    c = 225
    b = 1
    while b < bits:
        c = 2 * c - 16
        b *= 2
    return c


# ---------------------------------------------------------------------------
# Generic kernel cost assembly
# ---------------------------------------------------------------------------


def movement(
    sys: SystemParams,
    *,
    in_bits: float,
    out_bits: float,
) -> tuple[int, int]:
    return sys.xfer_cycles(in_bits), sys.xfer_cycles(out_bits)


def elementwise_cost(
    layout: Layout,
    *,
    n: int,
    width: int,
    per_op_bp: int,
    per_op_bs: int,
    n_inputs: int = 2,
    in_width: Optional[int] = None,
    out_width: Optional[int] = None,
    sys: SystemParams = PAPER_SYSTEM,
) -> CycleCost:
    """Assemble load/compute/readout for an elementwise kernel over n words."""
    in_w = width if in_width is None else in_width
    out_w = width if out_width is None else out_width
    load, readout = movement(sys, in_bits=n_inputs * n * in_w, out_bits=n * out_w)
    if layout is Layout.BP:
        compute = per_op_bp * sys.bp_batches(n, width)
    else:
        compute = per_op_bs * sys.bs_batches(n)
    return CycleCost(load, compute, readout)


def vector_add_cost(layout: Layout, n: int, width: int = 16,
                    sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    """The paper's running example (Table 4)."""
    return elementwise_cost(
        layout, n=n, width=width, per_op_bp=BP_ADD, per_op_bs=bs_add(width), sys=sys
    )


# ---------------------------------------------------------------------------
# Utilization (Challenge 1 / Fig. 8)
# ---------------------------------------------------------------------------


def utilization(layout: Layout, parallel_ops: int, width: int,
                sys: SystemParams = PAPER_SYSTEM) -> float:
    """Fraction of compute columns used by `parallel_ops` concurrent W-bit ops.

    BS: one column per op; BP: `width` columns per op. (Fig. 8 definition.)
    """
    if layout is Layout.BS:
        used = parallel_ops
    else:
        used = parallel_ops * width
    return min(1.0, used / sys.total_columns)


def seconds(cycles: int, sys: SystemParams = PAPER_SYSTEM) -> float:
    return cycles / (sys.clock_ghz * 1e9)
