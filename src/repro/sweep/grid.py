"""Declarative design-space sweeps over (workload x width x geometry).

The paper's guidelines are crossover claims over design parameters, but a
point cost model can only answer one ``(workload, layout, width)`` query at
the fixed `PAPER_SYSTEM` geometry.  This module turns the model into a
characterization engine:

* :class:`Geometry` -- one CSA system operating point (rows / cols /
  arrays / row bus width), convertible to/from `SystemParams`.
* :func:`iso_area_family` -- the paper-faithful geometry axis: hold the
  total bit capacity ``arrays * rows * cols`` constant while trading array
  depth (rows) for array count, cols and bus width fixed.  Deeper arrays
  concentrate capacity into fewer columns (fewer 1-bit BS lanes, more
  capacity batches); shallower arrays multiply columns but starve the BS
  vertical footprint (row overflow, Challenge 2/5).
* :class:`SweepSpec` -- declarative sweep description (workloads x widths
  x geometries), content-hashable for the disk cache.
* :func:`run_sweep` -- chunked/jitted execution via
  `repro.sweep.vectorized` (one compiled call per chunk, every kernel and
  layout batched inside it), with a content-hash cache under
  ``bench-artifacts/sweep-cache/`` and optional multi-device sharding via
  `repro.dist` (pass ``mesh=``).

Sweepable workloads are the single-kernel ``mk/*`` registry entries (the
Table-5 suite); multi-op applications keep their planner/executor routes
(`repro.workloads`), which `repro.sweep.frontier` combines with the grid
for the hybrid-win analysis.
"""
from __future__ import annotations

import dataclasses
import hashlib
import inspect
import json
import os
import time
from typing import Optional

import numpy as np

from repro.core.params import ArrayParams, SystemParams, PAPER_SYSTEM

#: default rows options for the iso-area family: the paper point (128) plus
#: power-of-two trades in both directions. rows=8..16 starve the BS
#: vertical footprint; rows >= 1024 shrink total columns enough that
#: capacity batching engages at the Table-5 operating points.
ISO_AREA_ROWS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048)


def _artifact_dir() -> str:
    return os.environ.get("REPRO_BENCH_ARTIFACT_DIR", "bench-artifacts")


def default_cache_dir() -> str:
    return os.path.join(_artifact_dir(), "sweep-cache")


# ---------------------------------------------------------------------------
# Geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Geometry:
    """One CSA system geometry (the sweepable subset of `SystemParams`)."""

    rows: int
    cols: int
    arrays: int
    row_bandwidth_bits: int = 512

    @property
    def capacity_bits(self) -> int:
        return self.rows * self.cols * self.arrays

    @property
    def total_columns(self) -> int:
        return self.cols * self.arrays

    def system(self) -> SystemParams:
        return SystemParams(
            array=ArrayParams(rows=self.rows, cols=self.cols),
            num_arrays=self.arrays,
            row_bandwidth_bits=self.row_bandwidth_bits)

    @classmethod
    def from_system(cls, sys: SystemParams) -> "Geometry":
        return cls(rows=sys.array.rows, cols=sys.array.cols,
                   arrays=sys.num_arrays,
                   row_bandwidth_bits=sys.row_bandwidth_bits)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def label(self) -> str:
        return (f"{self.rows}x{self.cols}x{self.arrays}"
                f"@{self.row_bandwidth_bits}")


PAPER_GEOMETRY = Geometry.from_system(PAPER_SYSTEM)


def iso_area_family(base: SystemParams = PAPER_SYSTEM,
                    rows_options=ISO_AREA_ROWS) -> tuple[Geometry, ...]:
    """Geometries with the base system's exact bit capacity, trading rows
    for arrays (cols and bus width fixed). Options that do not divide the
    vertical capacity evenly are skipped."""
    vertical = base.array.rows * base.num_arrays  # rows * arrays, constant
    fam = []
    for r in rows_options:
        if vertical % r:
            continue
        fam.append(Geometry(rows=r, cols=base.array.cols,
                            arrays=vertical // r,
                            row_bandwidth_bits=base.row_bandwidth_bits))
    return tuple(fam)


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """Declarative (workloads x widths x geometries) sweep description."""

    workloads: tuple[str, ...]
    widths: tuple[int, ...] = (4, 8, 16, 32)
    geometries: tuple[Geometry, ...] = dataclasses.field(
        default_factory=iso_area_family)
    #: override every workload's registry element count (None = registry
    #: operating point, Table-5 calibration sizes)
    n_override: Optional[int] = None
    #: geometries per jitted call (grid chunking; the default family fits
    #: one chunk -- raise for very long custom geometry axes)
    chunk: int = 64

    @classmethod
    def default(cls, workloads=None, widths=(4, 8, 16, 32),
                geometries=None, n_override=None) -> "SweepSpec":
        """All ``mk/*`` workloads over the iso-area family."""
        from repro.workloads.registry import workload_names

        return cls(
            workloads=tuple(workloads or workload_names("table5")),
            widths=tuple(widths),
            geometries=tuple(geometries or iso_area_family()),
            n_override=n_override)

    def to_dict(self) -> dict:
        return {
            "workloads": list(self.workloads),
            "widths": list(self.widths),
            "geometries": [g.to_dict() for g in self.geometries],
            "n_override": self.n_override,
        }

    def content_hash(self) -> str:
        """Cache key: the spec content plus a model-source fingerprint, so
        edits to the cost recipes or the vectorized evaluator invalidate
        cached sweeps automatically."""
        blob = json.dumps(self.to_dict(), sort_keys=True) \
            + _model_fingerprint()
        return hashlib.sha256(blob.encode()).hexdigest()[:24]


def _model_fingerprint() -> str:
    from repro.core import cost_model
    from repro.sweep import vectorized

    src = inspect.getsource(cost_model) + inspect.getsource(vectorized)
    return hashlib.sha256(src.encode()).hexdigest()[:16]


def _kernel_specs(spec: SweepSpec) -> list[tuple[str, int, int]]:
    """Resolve spec workloads -> [(kernel, n, live_words)]; only
    single-kernel-op (mk/*) workloads are vectorizable."""
    from repro.core.microkernels import MICROKERNELS
    from repro.workloads.registry import get_workload

    out = []
    for name in spec.workloads:
        w = get_workload(name)
        if len(w.ops) != 1 or w.ops[0].kind != "kernel":
            raise ValueError(
                f"sweep supports single-kernel (mk/*) workloads; "
                f"{name!r} has {len(w.ops)} op(s) of kind(s) "
                f"{sorted({op.kind for op in w.ops})}")
        op = w.ops[0]
        n = spec.n_override or op.n
        out.append((op.kernel, n, MICROKERNELS[op.kernel].live_words))
    return out


# ---------------------------------------------------------------------------
# SweepResult
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    """Dense sweep output plus feasibility masks and cache provenance.

    ``breakdown[k, l, w, g, c]``: workload k, layout l (BP=0/BS=1), width
    index w, geometry index g, component c (load/compute/readout), int64.
    """

    spec: SweepSpec
    breakdown: np.ndarray    # (K, 2, W, G, 3) int64
    bs_feasible: np.ndarray  # (K, W, G) bool -- vertical footprint fits
    bp_feasible: np.ndarray  # (K, G) bool -- one row per live word fits
    cache: dict = dataclasses.field(default_factory=dict)
    elapsed_s: float = 0.0

    @property
    def totals(self) -> np.ndarray:
        """(K, 2, W, G) total cycles."""
        return self.breakdown.sum(axis=-1)

    def workload_index(self, name: str) -> int:
        return self.spec.workloads.index(name)

    def geometry_index(self, geometry: Geometry) -> int:
        return self.spec.geometries.index(geometry)

    def summary(self) -> dict:
        k, _, w, g, _ = self.breakdown.shape
        return {
            "workloads": k, "widths": w, "geometries": g,
            "grid_points": k * 2 * w * g,
            "bs_feasible_frac": float(self.bs_feasible.mean()),
            "bp_feasible_frac": float(self.bp_feasible.mean()),
        }


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def _evaluate(spec: SweepSpec, mesh=None) -> tuple[np.ndarray, np.ndarray,
                                                   np.ndarray]:
    from repro.sweep import vectorized as V

    specs = _kernel_specs(spec)
    kernel_ns = tuple((k, n) for k, n, _ in specs)
    live_words = np.array([lw for _, _, lw in specs], np.int32)
    widths = np.asarray(spec.widths, np.int32)
    geo = spec.geometries
    rows = np.array([g.rows for g in geo], np.int32)
    cols = np.array([g.cols for g in geo], np.int32)
    arrays = np.array([g.arrays for g in geo], np.int32)
    bw = np.array([g.row_bandwidth_bits for g in geo], np.int32)

    # chunk the geometry axis; pad the tail chunk so every call shares one
    # compiled shape
    G = len(geo)
    c = max(1, min(spec.chunk, G))
    if mesh is not None:
        import jax
        from repro.dist.sharding import use_mesh

        fn = jax.jit(V.make_grid_fn(kernel_ns, sharded=True))
        run = lambda *a: _run_sharded(fn, mesh, use_mesh, *a)
    else:
        run = lambda *a: np.asarray(V.eval_grid(kernel_ns, *a))
    parts = []
    for i in range(0, G, c):
        sl = slice(i, i + c)
        chunk = [x[sl] for x in (rows, cols, arrays, bw)]
        pad = c - chunk[0].shape[0]
        if pad:
            chunk = [np.concatenate([x, np.repeat(x[-1:], pad)])
                     for x in chunk]
        out = run(widths, *chunk)
        if pad:
            out = out[:, :, :, :c - pad]
        parts.append(out)
    breakdown = np.concatenate(parts, axis=3).astype(np.int64)

    bs_ok, bp_ok = V.feasible_masks(live_words, widths, rows)
    return breakdown, np.asarray(bs_ok), np.asarray(bp_ok)


def _run_sharded(fn, mesh, use_mesh, widths, rows, cols, arrays, bw):
    import jax.numpy as jnp

    with use_mesh(mesh):
        to = lambda x: jnp.asarray(x, jnp.int32)
        return np.asarray(fn(to(widths), to(rows), to(cols), to(arrays),
                             to(bw)))


def run_sweep(spec: SweepSpec, *, cache_dir: Optional[str] = None,
              use_cache: bool = True, mesh=None) -> SweepResult:
    """Execute (or load from cache) a sweep.

    The cache key hashes the spec content AND the cost-model/vectorizer
    sources, so model edits never serve stale surfaces.  ``mesh`` shards
    the geometry axis over `repro.dist` data axes (results identical).
    """
    cache_dir = default_cache_dir() if cache_dir is None else cache_dir
    key = spec.content_hash()
    npz_path = os.path.join(cache_dir, f"{key}.npz")
    meta_path = os.path.join(cache_dir, f"{key}.json")
    cache_info = {"hit": False, "key": key, "path": npz_path,
                  "enabled": bool(use_cache)}

    if use_cache and os.path.exists(npz_path):
        with np.load(npz_path) as z:
            arrs = {k: z[k] for k in
                    ("breakdown", "bs_feasible", "bp_feasible")}
        cache_info["hit"] = True
        return SweepResult(spec=spec, cache=cache_info, **arrs)

    t0 = time.perf_counter()
    breakdown, bs_ok, bp_ok = _evaluate(spec, mesh=mesh)
    elapsed = time.perf_counter() - t0

    if use_cache:
        os.makedirs(cache_dir, exist_ok=True)
        np.savez_compressed(npz_path, breakdown=breakdown,
                            bs_feasible=bs_ok, bp_feasible=bp_ok)
        with open(meta_path, "w") as f:
            json.dump({"spec": spec.to_dict(), "key": key,
                       "fingerprint": _model_fingerprint(),
                       "elapsed_s": elapsed}, f, indent=1, sort_keys=True)
    return SweepResult(spec=spec, breakdown=breakdown, bs_feasible=bs_ok,
                       bp_feasible=bp_ok, cache=cache_info,
                       elapsed_s=elapsed)


def cache_stats(cache_dir: Optional[str] = None) -> dict:
    """Entry count / byte size of the sweep cache (CI artifact)."""
    cache_dir = default_cache_dir() if cache_dir is None else cache_dir
    if not os.path.isdir(cache_dir):
        return {"dir": cache_dir, "entries": 0, "bytes": 0}
    paths = [os.path.join(cache_dir, p) for p in os.listdir(cache_dir)
             if p.endswith(".npz")]
    return {"dir": cache_dir, "entries": len(paths),
            "bytes": sum(os.path.getsize(p) for p in paths)}
