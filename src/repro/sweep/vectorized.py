"""jnp-broadcastable evaluation of the Table-5 kernel cost recipes.

`repro.core.cost_model.KERNEL_RECIPES` describes each kernel once against a
tiny numeric namespace; this module provides the jnp instance of that
namespace (:class:`JnpOps`) plus jitted evaluators, so a whole
``(kernel x layout x width x geometry)`` grid costs ONE compiled call
instead of thousands of python-scalar evaluations:

* :func:`kernel_cost_vec` -- broadcastable (load, compute, readout) for one
  kernel/layout over arrays of ``width`` / ``cols`` / ``arrays`` /
  ``row_bandwidth_bits``.
* :func:`eval_grid` -- the sweep engine's workhorse: every requested kernel
  in both layouts over a ``widths x geometries`` grid, returned as an
  int32 array of shape ``(K, 2, W, G, 3)`` (layout axis BP=0/BS=1; last
  axis load/compute/readout).  Jitted once per kernel set.
* :func:`eval_points` -- one operating point per kernel (the
  ``AnalyticBackend.estimate_many`` fast path): shape ``(K, 2, 3)``.
* :func:`bs_rows_required_vec` / :func:`feasible_masks` -- the
  row-overflow side conditions (Challenge 2/5) as broadcastable arrays.

Bit-for-bit contract: for every recipe and every integer operating point,
these evaluations equal the scalar `cost_model` / `microkernels` path
exactly (tests/test_sweep.py exhaustive suite + tests/
test_sweep_properties.py property suite).  Keep :class:`JnpOps` integral --
no floats -- so the contract survives any grid size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import cost_model as cm
from repro.core.cost_model import Layout

import numpy as np

LAYOUTS = (Layout.BP, Layout.BS)  # fixed layout-axis order of all outputs

_INT32_MAX = 2**31 - 1


def _check_int32_range(n, width, cols, arrays) -> None:
    """Reject operating points whose cycle terms could wrap int32.

    The bit-for-bit contract is meaningless if the vectorized path wraps
    where the scalar path does not, so the largest movement term
    (8*n*width half-bits) and the largest compute term (div_bs = 5*w^2
    per batch, times BP capacity batches) are bounded conservatively.
    Inputs are concrete at every public entry point; inside a jit trace
    they are tracers and the check is a no-op (the entry point already
    ran it).
    """
    try:
        n_max = int(np.max(n))
        w_max = int(np.max(width))
        tc_min = int(np.min(np.asarray(cols) * np.asarray(arrays)))
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return
    move = 8 * n_max * w_max
    lanes = max(1, tc_min // w_max)
    comp = 5 * w_max * w_max * max(1, -(-n_max // lanes))
    if max(move, comp) > _INT32_MAX:
        raise ValueError(
            f"operating point too large for the int32 vectorized path "
            f"(n={n_max}, width={w_max}, total_columns={tc_min}: worst "
            f"term {max(move, comp)} > {_INT32_MAX}); use the scalar "
            "microkernels.kernel_cost path for this point")


class JnpOps:
    """The jnp instance of the recipe numeric namespace (all-integer)."""

    @staticmethod
    def ceil_div(a, b):
        # jnp floor-division rounds toward -inf (numpy semantics), so the
        # classic sign trick is exact for the non-negative operands here.
        return -((-a) // b)

    @staticmethod
    def maximum(a, b):
        return jnp.maximum(a, b)

    @staticmethod
    def where(cond, a, b):
        return jnp.where(cond, a, b)

    @staticmethod
    def floor_log2(x):
        """Exact integer floor(log2(x)) for x >= 1 (no float log)."""
        x = jnp.asarray(x, jnp.int32)
        r = jnp.zeros_like(x)
        for k in (16, 8, 4, 2, 1):
            m = x >= (1 << k)
            r = r + jnp.where(m, k, 0)
            x = jnp.where(m, x >> k, x)
        return r

    @staticmethod
    def ceil_log2(x):
        """ceil(log2(max(2, x))), exact (mirrors ScalarOps.ceil_log2)."""
        x = jnp.maximum(jnp.asarray(x, jnp.int32), 2)
        return JnpOps.floor_log2(x - 1) + 1

    @staticmethod
    def by_width(width, table, fallback):
        out = fallback + jnp.zeros_like(jnp.asarray(width, jnp.int32))
        for k in sorted(table):
            out = jnp.where(width == k, table[k], out)
        return out


JNP_OPS = JnpOps()


def kernel_cost_vec(kernel: str, layout: Layout, *, n, width, cols, arrays,
                    row_bandwidth_bits=512):
    """Broadcast (load, compute, readout) int32 arrays for one kernel.

    Every argument may be a python int or a broadcastable integer array;
    the result shape is their common broadcast shape.  Equal bit-for-bit
    to ``microkernels.kernel_cost`` at every integer point.
    """
    _check_int32_range(n, width, cols, arrays)
    width = jnp.asarray(width, jnp.int32)
    tc = jnp.asarray(cols, jnp.int32) * jnp.asarray(arrays, jnp.int32)
    load, comp, ro = cm.eval_recipe(
        kernel, layout, JNP_OPS, n=n, width=width, total_columns=tc,
        row_bandwidth_bits=jnp.asarray(row_bandwidth_bits, jnp.int32))
    # width-independent terms (bitweave, BP ite) collapse to scalars --
    # broadcast everything to the full requested grid shape
    shape = jnp.broadcast_shapes(jnp.shape(load), jnp.shape(comp),
                                 jnp.shape(ro), width.shape, tc.shape)
    return tuple(jnp.broadcast_to(jnp.asarray(x, jnp.int32), shape)
                 for x in (load, comp, ro))


# ---------------------------------------------------------------------------
# Grid evaluation (the sweep engine's one-jitted-call path)
# ---------------------------------------------------------------------------

def make_grid_fn(kernel_ns: tuple, sharded: bool = False):
    """Build the (un-jitted) grid evaluator for a static kernel set.

    ``kernel_ns`` is a tuple of ``(kernel_name, n)`` pairs.  The returned
    function maps ``(widths (W,), rows (G,), cols (G,), arrays (G,),
    row_bw (G,))`` to an int32 array ``(K, 2, W, G, 3)``.  With
    ``sharded=True`` the geometry axis is constrained onto the ambient
    `repro.dist` mesh data axes (a no-op off-mesh), so multi-device hosts
    partition the grid.
    """
    def fn(widths, rows, cols, arrays, row_bw):
        del rows  # geometry rows gate feasibility, not cycle cost
        if sharded:
            from repro.dist.sharding import shard
            cols, arrays, row_bw = (shard(x, "batch")
                                    for x in (cols, arrays, row_bw))
        w = widths[:, None]                      # (W, 1) vs geometry (G,)
        shape = (widths.shape[0], cols.shape[0])
        per_kernel = []
        for name, n in kernel_ns:
            per_layout = []
            for lay in LAYOUTS:
                l, c, r = kernel_cost_vec(
                    name, lay, n=n, width=w, cols=cols, arrays=arrays,
                    row_bandwidth_bits=row_bw)
                per_layout.append(jnp.stack(
                    [jnp.broadcast_to(x, shape) for x in (l, c, r)],
                    axis=-1))
            per_kernel.append(jnp.stack(per_layout))
        return jnp.stack(per_kernel)
    return fn


@functools.lru_cache(maxsize=None)
def _jitted_grid_fn(kernel_ns: tuple):
    return jax.jit(make_grid_fn(kernel_ns, sharded=False))


def eval_grid(kernel_ns, widths, rows, cols, arrays, row_bw):
    """One jitted call: every (kernel, n) x layout x width x geometry.

    Returns int32 ``(K, 2, W, G, 3)``; compiled once per (kernel set,
    grid shape).
    """
    for _, n in kernel_ns:
        _check_int32_range(n, widths, cols, arrays)
    fn = _jitted_grid_fn(tuple(kernel_ns))
    to = lambda x: jnp.asarray(x, jnp.int32)
    return fn(to(widths), to(rows), to(cols), to(arrays), to(row_bw))


@functools.lru_cache(maxsize=None)
def _jitted_points_fn(kernel_nws: tuple):
    """kernel_nws: tuple of (kernel_name, n, width) -- all static."""
    def fn(cols, arrays, row_bw):
        out = []
        for name, n, w in kernel_nws:
            per_layout = []
            for lay in LAYOUTS:
                l, c, r = kernel_cost_vec(
                    name, lay, n=n, width=w, cols=cols, arrays=arrays,
                    row_bandwidth_bits=row_bw)
                per_layout.append(jnp.stack([
                    jnp.broadcast_to(x, ()) for x in (l, c, r)]))
            out.append(jnp.stack(per_layout))
        return jnp.stack(out)
    return jax.jit(fn)


def eval_points(kernel_nws, cols: int, arrays: int, row_bw: int):
    """Batched per-kernel operating points -> int32 ``(K, 2, 3)``.

    ``kernel_nws`` is a tuple of ``(kernel, n, width)`` triples; geometry
    is one system (scalars).  This is the ``estimate_many`` fast path.
    """
    for _, n, w in kernel_nws:
        _check_int32_range(n, w, cols, arrays)
    fn = _jitted_points_fn(tuple(kernel_nws))
    to = lambda x: jnp.asarray(x, jnp.int32)
    return fn(to(cols), to(arrays), to(row_bw))


# ---------------------------------------------------------------------------
# Row-overflow feasibility (Challenge 2/5 side conditions)
# ---------------------------------------------------------------------------

def bs_rows_required_vec(live_words, width, carry_rows: int = 1):
    """Vertical rows to keep `live_words` W-bit variables resident in a BS
    column (broadcastable mirror of ``SystemParams.bs_rows_required``)."""
    return (jnp.asarray(live_words, jnp.int32)
            * jnp.asarray(width, jnp.int32) + carry_rows)


def feasible_masks(live_words, widths, rows):
    """Row-overflow masks over a (kernel, width, geometry) grid.

    ``live_words (K,)``, ``widths (W,)``, ``rows (G,)`` ->
    ``(bs_feasible (K, W, G), bp_feasible (K, G))``: BS needs
    ``live_words * width + 1`` vertical rows, BP one row per live word.
    """
    lw = jnp.asarray(live_words, jnp.int32)
    widths = jnp.asarray(widths, jnp.int32)
    rows = jnp.asarray(rows, jnp.int32)
    bs = (bs_rows_required_vec(lw[:, None, None], widths[None, :, None])
          <= rows[None, None, :])
    bp = lw[:, None] <= rows[None, :]
    return bs, bp
