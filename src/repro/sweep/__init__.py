"""repro.sweep: vectorized BP/BS design-space characterization.

Public surface (see README.md in this directory and DESIGN.md Sec. 9)::

    from repro.sweep import (
        Geometry, PAPER_GEOMETRY, iso_area_family,   # the geometry axis
        SweepSpec, SweepResult, run_sweep,           # sweep execution
        crossover_table, guidelines, hybrid_win_set, # frontier extraction
    )

    result = run_sweep(SweepSpec.default())
    report = guidelines(result)

CLI: ``python -m repro sweep`` / ``python -m repro guidelines``.
"""
from repro.sweep.frontier import (  # noqa: F401
    bs_win_mask,
    crossover_table,
    geometry_profile,
    guidelines,
    guidelines_lines,
    hybrid_win_set,
)
from repro.sweep.grid import (  # noqa: F401
    Geometry,
    ISO_AREA_ROWS,
    PAPER_GEOMETRY,
    SweepResult,
    SweepSpec,
    cache_stats,
    default_cache_dir,
    iso_area_family,
    run_sweep,
)
