"""Crossover extraction and machine-derived layout guidelines.

Consumes a :class:`repro.sweep.grid.SweepResult` (the dense
workload x layout x width x geometry surface) and reduces it to the
paper's Sec.-7-style deliverables:

* :func:`bs_win_mask` / :func:`crossover_table` -- where (and up to which
  width) the bit-serial layout beats bit-parallel, per workload and
  geometry.  The *crossover width* of a workload is the largest swept
  width at which BS still wins (0 if it never does); ``prefix=True`` marks
  the common down-closed pattern ("BS wins below W") the published
  guidelines assume.
* :func:`hybrid_win_set` -- Table-6 applications whose optimal 2-state
  plan is genuinely hybrid (`PlannerBackend`; schedule switches layouts
  and beats both statics).
* :func:`guidelines` -- the full machine-derived report: crossover table
  at the paper geometry, geometry sensitivity over the iso-area family,
  row-overflow feasibility bounds, the hybrid-win set, and derived rule
  strings.  ``python -m repro sweep`` / ``repro guidelines`` serialize it
  to ``bench-artifacts/guidelines.json``; the ``[guidelines]`` section of
  tests/golden/paper_tables.txt pins the crossover table and hybrid set
  so guideline drift fails tier-1 loudly (regeneration: DESIGN.md Sec. 9).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.sweep.grid import (
    PAPER_GEOMETRY,
    SweepResult,
    SweepSpec,
    run_sweep,
)

BP, BS = 0, 1  # layout axis order of SweepResult.breakdown


def bs_win_mask(result: SweepResult) -> np.ndarray:
    """(K, W, G) bool: BS total cycles strictly below BP's."""
    t = result.totals
    return t[:, BS] < t[:, BP]


def _paper_geometry_index(result: SweepResult) -> int:
    try:
        return result.spec.geometries.index(PAPER_GEOMETRY)
    except ValueError:
        return 0


def crossover_table(result: SweepResult,
                    geometry_index: Optional[int] = None) -> dict:
    """Per-workload crossover record at one geometry (default: paper).

    ``{workload: {crossover_width, bs_win_widths, prefix,
    bs_feasible_widths}}``; widths are the spec's swept values.
    """
    gi = _paper_geometry_index(result) if geometry_index is None \
        else geometry_index
    t = result.totals
    wins = bs_win_mask(result)[:, :, gi]
    ties = (t[:, BS] == t[:, BP])[:, :, gi]
    widths = list(result.spec.widths)
    out = {}
    for k, name in enumerate(result.spec.workloads):
        win_ws = [w for i, w in enumerate(widths) if wins[k, i]]
        cw = max(win_ws, default=0)
        out[name] = {
            "crossover_width": cw,
            "bs_win_widths": win_ws,
            "tie_widths": [w for i, w in enumerate(widths) if ties[k, i]],
            # down-closed ("BS wins below W") -- the published rule shape
            "prefix": win_ws == [w for w in widths if w <= cw],
            "bs_feasible_widths": [
                w for i, w in enumerate(widths)
                if result.bs_feasible[k, i, gi]],
        }
    return out


def geometry_profile(result: SweepResult) -> list[dict]:
    """Per-geometry aggregate: BS-win fraction and feasibility fractions
    over the (workload x width) cells -- the iso-area sensitivity axis."""
    wins = bs_win_mask(result)
    out = []
    for g, geo in enumerate(result.spec.geometries):
        out.append({
            "geometry": geo.label(),
            "rows": geo.rows,
            "arrays": geo.arrays,
            "total_columns": geo.total_columns,
            "bs_win_frac": float(wins[:, :, g].mean()),
            "bs_feasible_frac": float(result.bs_feasible[:, :, g].mean()),
            "bp_feasible_frac": float(result.bp_feasible[:, g].mean()),
        })
    return out


def hybrid_win_set(sys: SystemParams = PAPER_SYSTEM) -> tuple[str, ...]:
    """Table-6 applications whose optimal plan is hybrid AND strictly
    beats the best static layout (PlannerBackend at `sys`)."""
    from repro.workloads import characterize, workload_names

    out = []
    for app in workload_names("table6"):
        s = characterize(app, backends=("planner",), sys=sys)["planner"] \
            .summary
        if s["is_hybrid"] and s["hybrid_cycles"] < min(s["bp_cycles"],
                                                      s["bs_cycles"]):
            out.append(app)
    return tuple(out)


def _derive_rules(result: SweepResult, cross: dict,
                  hybrid: tuple[str, ...]) -> list[str]:
    """Sec.-7-style guideline sentences, derived from the surfaces (never
    hand-written -- regenerating the sweep regenerates these)."""
    widths = list(result.spec.widths)
    always = sorted(n for n, c in cross.items()
                    if c["bs_win_widths"] == widths)
    neutral = sorted(n for n, c in cross.items()
                     if c["tie_widths"] == widths)
    never = sorted(n for n, c in cross.items()
                   if not c["bs_win_widths"] and n not in neutral)
    below = {n: c["crossover_width"] for n, c in cross.items()
             if c["bs_win_widths"] and c["bs_win_widths"] != widths
             and c["prefix"]}
    rules = []
    if always:
        rules.append(
            "BS wins at every swept width for bit-centric/predicate "
            "kernels: " + ", ".join(always) + ".")
    if neutral:
        rules.append(
            "Layout-neutral at every swept width (identical totals): "
            + ", ".join(neutral) + ".")
    if below:
        grouped: dict[int, list[str]] = {}
        for n, w in sorted(below.items()):
            grouped.setdefault(w, []).append(n)
        for w in sorted(grouped):
            rules.append(
                f"BS wins only below/at width {w} for: "
                + ", ".join(grouped[w]) + " (crossover to BP above).")
    if never:
        rules.append(
            "BP wins at every swept width for arithmetic-heavy kernels: "
            + ", ".join(never) + ".")
    non_prefix = sorted(n for n, c in cross.items()
                        if c["bs_win_widths"] and not c["prefix"])
    if non_prefix:
        rules.append(
            "Non-monotone crossover (win set is not a width prefix) for: "
            + ", ".join(non_prefix) + " -- check per-width data.")
    # geometry sensitivity over the iso-area family
    prof = geometry_profile(result)
    wins = bs_win_mask(result)
    flips = int(np.sum(wins.any(axis=2) != wins.all(axis=2)))
    if flips:
        rules.append(
            f"{flips} (workload, width) cell(s) flip winner across the "
            "iso-area family: capacity batching makes the BP/BS choice "
            "geometry-dependent at these points.")
    else:
        rules.append(
            "No (workload, width) cell flips winner across the iso-area "
            "family at the Table-5 operating points: the crossover is set "
            "by width and kernel class, not geometry, until capacity "
            "batching engages.")
    shallow = min(prof, key=lambda p: p["rows"])
    deep = max(prof, key=lambda p: p["rows"])
    rules.append(
        f"Row overflow bounds BS: at {shallow['rows']} rows only "
        f"{shallow['bs_feasible_frac']:.0%} of (workload, width) cells "
        f"keep the vertical footprint resident, vs "
        f"{deep['bs_feasible_frac']:.0%} at {deep['rows']} rows -- "
        "iso-area trades that favour array count over depth shrink the "
        "feasible BS region (Challenge 2/5).")
    if hybrid:
        rules.append(
            "Phase-diverse applications where a transpose-aware hybrid "
            "schedule beats both static layouts: "
            + ", ".join(hybrid) + " (PlannerBackend 2-state DP).")
    return rules


def guidelines(result: Optional[SweepResult] = None, *,
               spec: Optional[SweepSpec] = None,
               sys: SystemParams = PAPER_SYSTEM,
               use_cache: bool = False,
               include_hybrid: bool = True) -> dict:
    """The full machine-derived guidelines report (JSON-serializable)."""
    if result is None:
        result = run_sweep(spec or SweepSpec.default(),
                           use_cache=use_cache)
    gi = _paper_geometry_index(result)
    cross = crossover_table(result, geometry_index=gi)
    hybrid = hybrid_win_set(sys) if include_hybrid else ()
    return {
        "spec": result.spec.to_dict(),
        "paper_geometry": PAPER_GEOMETRY.to_dict(),
        # the geometry the crossover table was ACTUALLY computed at --
        # equals paper_geometry only when the sweep includes it
        "crossover_geometry": result.spec.geometries[gi].to_dict(),
        "crossover_at_paper_geometry":
            result.spec.geometries[gi] == PAPER_GEOMETRY,
        "crossover": cross,
        "hybrid_recommended": list(hybrid),
        "geometry_profile": geometry_profile(result),
        "rules": _derive_rules(result, cross, hybrid),
        "sweep_summary": result.summary(),
    }


def guidelines_lines(g: dict) -> list[str]:
    """The pinned text rendering (golden snapshot ``[guidelines]`` body).

    One line per workload -- ``name crossover_width bs_win_widths`` --
    plus the hybrid-recommended set; everything else in the report
    (rules, geometry profile) derives from these surfaces."""
    lines = []
    for name in sorted(g["crossover"]):
        c = g["crossover"][name]
        ws = "/".join(str(w) for w in c["bs_win_widths"]) or "-"
        lines.append(f"{name} {c['crossover_width']} {ws}")
    lines.append("hybrid_recommended "
                 + (" ".join(g["hybrid_recommended"]) or "-"))
    return lines
