"""Symbolic -> concrete sharding resolution and the trace-time mesh context.

Parameter and activation partitioning is written once, symbolically, in
`ParamSpec.pspec` tuples and `shard(...)` calls; this module maps those
symbols onto whatever mesh is actually present:

  * ``None``    -- replicated dim.
  * ``"batch"`` -- the data-parallel axes. Resolves to every DP mesh axis
    present, in mesh order (``("pod", "data")`` on the multi-pod mesh,
    ``"data"`` on a single pod), so the global batch shards over pods AND
    in-pod DP with one symbol.
  * any other string -- that mesh axis literally (``"model"``, ``"data"``,
    ``"pod"``).

Graceful degradation (the property the tests pin down): an axis absent
from the mesh is dropped, and an axis (or axis product) that does not
divide the dim is dropped -- the dim falls back toward replication instead
of raising. This is what lets the same model code run on the production
16x16 pod, the multi-pod 2x16x16 mesh, and an 8-device CPU test mesh.

`use_mesh(mesh)` installs the mesh for the duration of a trace;
`shard(x, *entries)` applies `with_sharding_constraint` against the current
mesh and is a silent no-op off-mesh (single-device tests, reference runs).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: Mesh axes that carry pure data parallelism, outermost first. ``"batch"``
#: resolves to whichever of these the current mesh actually has.
DATA_AXES = ("pod", "data")

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    """The mesh installed by the innermost `use_mesh`, or None."""
    stack = getattr(_state, "meshes", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Install `mesh` as the ambient mesh for `shard` constraints.

    Traces (jit lowering, `.lower()`) performed inside the block see the
    mesh; the context is thread-local so concurrent compiles don't leak
    meshes into each other. ``use_mesh(None)`` is a no-op, so callers
    with an optional mesh don't need a second code path.
    """
    if mesh is None:
        yield None
        return
    stack = getattr(_state, "meshes", None)
    if stack is None:
        stack = _state.meshes = []
    stack.append(mesh)
    try:
        yield mesh
    finally:
        stack.pop()


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _resolve_entry(entry, dim: int, sizes: dict):
    """One pspec entry -> concrete axis name, tuple of names, or None."""
    if entry is None:
        return None
    names = list(entry) if isinstance(entry, (tuple, list)) else (
        list(DATA_AXES) if entry == "batch" else [entry])
    names = [n for n in names if n in sizes]
    # drop axes (outermost first) until the shard product divides the dim
    while names:
        prod = 1
        for n in names:
            prod *= sizes[n]
        if prod and dim % prod == 0:
            break
        names.pop(0)
    if not names:
        return None
    return names[0] if len(names) == 1 else tuple(names)


def resolve_pspec(symbolic_pspec, mesh: Mesh, shape) -> P:
    """Map a symbolic pspec tuple to a concrete `PartitionSpec` for `mesh`.

    `symbolic_pspec` has one entry per dim of `shape` (see module
    docstring). Entries resolving to axes absent from the mesh, or whose
    size product does not divide the dim, degrade to replication.
    """
    assert len(symbolic_pspec) == len(shape), (symbolic_pspec, shape)
    sizes = _axis_sizes(mesh)
    return P(*(_resolve_entry(e, d, sizes)
               for e, d in zip(symbolic_pspec, shape)))


def place_on_mesh(tree, structure, mesh: Optional[Mesh]):
    """Device-put a materialized ParamSpec pytree onto `mesh` with its
    resolved shardings; identity when `mesh` is None (single device)."""
    if mesh is None:
        return tree
    from repro.models.base import param_shardings  # late: avoids cycle
    return jax.device_put(tree, param_shardings(structure, mesh))


def shard(x: jax.Array, *entries) -> jax.Array:
    """Constrain `x` to the symbolic spec on the ambient mesh (no-op
    off-mesh). `entries` is one symbolic pspec entry per dim of `x`."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = resolve_pspec(tuple(entries), mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
