"""Three-term per-chip roofline for dry-run cells.

Each compiled (arch x shape x mesh) cell reduces to three per-chip time
terms under peak-rate assumptions:

  compute_s     HLO FLOPs / peak matmul FLOP/s
  memory_s      HBM boundary bytes / HBM bandwidth
  collective_s  collective wire bytes / ICI bandwidth

The step is bound by the largest term; MFU divides the *useful* model
FLOPs (6ND analytic) by what the chip could have done in that time, and
`useful_flops_fraction` is analytic-vs-HLO FLOPs (rematerialization and
padding push it below 1).

Peak numbers are a v5e-class accelerator chip; override via the module
constants for other parts.
"""
from __future__ import annotations

import dataclasses

#: per-chip peak rates (v5e-class): bf16 matmul FLOP/s, HBM B/s, ICI B/s
PEAK_FLOPS = 197e12
HBM_BANDWIDTH = 819e9
ICI_BANDWIDTH = 9e10


@dataclasses.dataclass(frozen=True)
class Roofline:
    """One cell's roofline record (all *_per_chip inputs are per chip)."""

    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_wire_bytes_per_chip: float
    model_flops_total: float
    collective_detail: dict = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_chip / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_chip / HBM_BANDWIDTH

    @property
    def collective_s(self) -> float:
        return self.collective_wire_bytes_per_chip / ICI_BANDWIDTH

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def mfu(self) -> float:
        if self.step_s <= 0:
            return 0.0
        useful = self.model_flops_total / max(self.chips, 1)
        return useful / self.step_s / PEAK_FLOPS

    @property
    def useful_flops_fraction(self) -> float:
        if self.hlo_flops_per_chip <= 0:
            return 0.0
        return (self.model_flops_total / max(self.chips, 1)
                / self.hlo_flops_per_chip)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_chip": self.hlo_flops_per_chip,
            "hlo_bytes_per_chip": self.hlo_bytes_per_chip,
            "collective_wire_bytes_per_chip":
                self.collective_wire_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "step_s": self.step_s,
            "bound": self.bound,
            "mfu": self.mfu,
            "useful_flops_fraction": self.useful_flops_fraction,
            "collective_detail": self.collective_detail,
        }


def summarize(rl: Roofline) -> str:
    return (f"[roofline] {rl.arch} x {rl.shape} on {rl.mesh} "
            f"({rl.chips} chips): "
            f"compute {rl.compute_s * 1e3:.2f} ms, "
            f"memory {rl.memory_s * 1e3:.2f} ms, "
            f"collective {rl.collective_s * 1e3:.2f} ms "
            f"-> {rl.bound}-bound, mfu={rl.mfu:.3f}, "
            f"useful_flops={rl.useful_flops_fraction:.3f}")
