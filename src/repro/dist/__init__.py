"""Distributed-execution subsystem: mesh-aware sharding resolution and
HLO-level cost accounting (collective wire bytes, HBM boundary bytes,
roofline composition).

Modules
-------
sharding      symbolic PartitionSpec resolution (`resolve_pspec`), the
              `use_mesh` trace-time mesh context, and the `shard` activation
              constraint helper (a no-op off-mesh).
hlo_analysis  `collect_collectives`: per-collective counts and wire-byte
              estimates parsed from HLO text.
hlo_bytes     `boundary_bytes`: HBM traffic (writes + distinct reads) with
              fused-kernel scope exclusion.
roofline      three-term (compute / memory / collective) per-chip roofline
              records for the dry-run.
"""
from repro.dist import hlo_analysis, hlo_bytes, roofline, sharding  # noqa: F401
from repro.dist.sharding import resolve_pspec, shard, use_mesh  # noqa: F401
