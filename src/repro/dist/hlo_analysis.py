"""Collective-communication accounting from HLO text.

`collect_collectives` scans a (lowered or compiled) HLO module for
collective ops and estimates per-op *wire bytes* -- the bytes a chip
actually puts on the interconnect -- under the standard ring algorithms,
with `n` the tensor payload in bytes and `g` the replica-group size:

  all-reduce          2 * (g-1)/g * n   (reduce-scatter + all-gather ring)
  all-gather              (g-1)/g * n
  reduce-scatter          (g-1)/g * n
  all-to-all              (g-1)/g * n
  collective-permute              n     (every byte traverses one hop)

Group size comes from ``replica_groups=[groups,size]<=[total]`` (iota
form: the SECOND number is the per-group size) or from explicit
``replica_groups={{0,1,...},...}`` lists; `default_group` covers modules
whose collectives carry no group annotation (e.g. hand-written test HLO).

Async pairs are deduplicated: ``*-start`` is counted, ``*-done`` is
skipped, so an async collective contributes exactly once.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.dist.hlo_common import TENSOR_RE, tensor_bytes

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

#: wire-byte multiplier as a function of group size g
_WIRE = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}

# `%name = <shape> <op>(...)` where <op> is a collective, with an optional
# -start/-done suffix (async pair halves).
_INSTR_RE = re.compile(
    r"=\s*(?P<shape>[^=]*?)\s*"
    r"(?P<op>" + "|".join(COLLECTIVE_OPS) + r")(?P<suffix>-start|-done)?\(")

_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,\s]+)\}")


def _shape_bytes(shape_text: str, is_async: bool) -> int:
    """Payload bytes of the instruction's result shape.

    Non-tuple and variadic-tuple shapes sum their elements; async ``-start``
    tuples alias the operand and result (plus scalar context), so the
    largest single element is the payload.
    """
    parts = [tensor_bytes(m["dtype"], m["dims"])
             for m in TENSOR_RE.finditer(shape_text)]
    if not parts:
        return 0
    return max(parts) if is_async else sum(parts)


def _group_size(line: str, default_group: Optional[int]) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip()])
    return default_group or 1


@dataclasses.dataclass
class CollectiveStats:
    """Per-collective aggregates over one HLO module."""

    counts: dict        # op -> number of collectives
    bytes_moved: dict   # op -> summed tensor payload bytes
    wire_bytes: dict    # op -> summed ring-algorithm wire bytes

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def collect_collectives(hlo_text: str,
                        default_group: Optional[int] = None
                        ) -> CollectiveStats:
    """Parse `hlo_text` and aggregate collective counts and wire bytes."""
    counts: dict = {}
    moved: dict = {}
    wire: dict = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m["suffix"] == "-done":
            continue  # counted at the paired -start
        op = m["op"]
        n = _shape_bytes(m["shape"], is_async=m["suffix"] == "-start")
        g = _group_size(line, default_group)
        counts[op] = counts.get(op, 0) + 1
        moved[op] = moved.get(op, 0) + n
        wire[op] = wire.get(op, 0.0) + _WIRE[op](max(g, 1)) * n
    return CollectiveStats(counts=counts, bytes_moved=moved, wire_bytes=wire)
