"""HBM boundary-byte accounting from HLO text.

`boundary_bytes` estimates the HBM traffic of a module as

    sum(result bytes of every producing instruction)        -- writes
  + sum(bytes of every DISTINCT operand value read)         -- reads

Shape-only plumbing (`parameter`, `tuple`, `get-tuple-element`, `bitcast`,
`constant`) produces no traffic of its own and is skipped on both sides;
a parameter still costs a read the first time a real op consumes it.
Instructions inside already-fused computations (``%fused_computation.*``)
are internal to their fusion and skipped; the fusion instruction itself in
the caller accounts for the kernel's boundary.

Fused-kernel scope exclusion (``exclude_scope=``): ops whose
``metadata={op_name=...}`` contains the scope string (e.g. the
``flash_internal`` named_scope around the attention softmax state) are
treated as one fused kernel whose intermediate values stay in VMEM.
Because XLA drops metadata on some ops (dots, copies), the scope is closed
*backward*: a producer ALL of whose consumers are in-scope joins the scope.
What still counts toward HBM:

  * writes by out-of-scope ops, plus in-scope values read by any
    out-of-scope consumer (they *escape* the kernel);
  * distinct reads by out-of-scope ops, plus kernel *inputs* (out-of-scope
    values read by in-scope ops).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.dist.hlo_common import TENSOR_RE, tensor_bytes

#: opcodes that never touch HBM themselves
_FREE_OPS = frozenset(
    {"parameter", "tuple", "get-tuple-element", "bitcast", "constant"})

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<shape>\(?[^)=]*?\)?)\s*"
    r"(?P<op>[\w\-]+)\((?P<operands>[^)]*)\)(?P<rest>.*)$")

_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_OP_NAME_RE = re.compile(r'metadata=\{[^}]*op_name="([^"]*)"')
_COMPUTATION_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*"
                             r"(?:\([^)]*\))?\s*(?:->\s*\S+\s*)?\{\s*$")

@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    bytes: int
    operands: tuple
    op_name: str  # metadata op_name ("" when absent)
    is_root: bool = False


def _shape_bytes(shape_text: str) -> int:
    return sum(tensor_bytes(m["dtype"], m["dims"])
               for m in TENSOR_RE.finditer(shape_text))


def _parse(hlo_text: str) -> list:
    """Instructions of every non-fused computation in the module."""
    instrs: list = []
    in_fused = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "=" not in stripped:
            cm = _COMPUTATION_RE.match(line)
            in_fused = bool(cm) and cm.group(1).startswith("fused")
            continue
        if stripped == "}":
            in_fused = False
            continue
        if in_fused:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        om = _OP_NAME_RE.search(m["rest"])
        instrs.append(_Instr(
            name=m["name"], op=m["op"],
            bytes=_shape_bytes(m["shape"]),
            operands=tuple(_OPERAND_RE.findall(m["operands"])),
            op_name=om.group(1) if om else "",
            is_root=stripped.startswith("ROOT ")))
    return instrs


def boundary_bytes(hlo_text: str,
                   exclude_scope: Optional[str] = None) -> int:
    """HBM boundary bytes of `hlo_text` (see module docstring)."""
    instrs = _parse(hlo_text)
    by_name = {i.name: i for i in instrs}
    consumers: dict = {i.name: [] for i in instrs}
    for i in instrs:
        for o in i.operands:
            if o in consumers:
                consumers[o].append(i)

    in_scope = set()
    if exclude_scope:
        in_scope = {i.name for i in instrs
                    if i.op != "parameter" and exclude_scope in i.op_name}
        # backward closure: a producer whose every consumer is in-scope is
        # itself kernel-internal (XLA drops metadata on some ops)
        changed = bool(in_scope)
        while changed:
            changed = False
            for i in instrs:
                if (i.name in in_scope or i.op in _FREE_OPS
                        or not consumers[i.name]):
                    continue
                if all(c.name in in_scope for c in consumers[i.name]):
                    in_scope.add(i.name)
                    changed = True

    writes = 0
    reads: set = set()
    for i in instrs:
        if i.op in _FREE_OPS:
            continue
        if i.name not in in_scope:
            writes += i.bytes
            reads.update(o for o in i.operands
                         if by_name.get(o) is not None
                         and by_name[o].op not in {"tuple", "constant"})
        else:
            # in-scope: contributes only via escapes and kernel inputs
            # (a ROOT is the module output -- it always escapes)
            if i.is_root or any(c.name not in in_scope
                                for c in consumers[i.name]):
                writes += i.bytes  # escapes the fused kernel
            reads.update(o for o in i.operands
                         if o in by_name and o not in in_scope
                         and by_name[o].op not in {"tuple", "constant"})

    read_bytes = sum(by_name[o].bytes for o in reads)
    return int(writes + read_bytes)
