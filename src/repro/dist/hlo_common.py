"""Shared HLO-text parsing primitives for the byte-accounting analyzers.

One dtype-size table and tensor-shape regex, so `hlo_analysis` (wire
bytes) and `hlo_bytes` (HBM boundary bytes) can never drift apart on
what a tensor weighs.
"""
from __future__ import annotations

import math
import re

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

#: one tensor shape inside HLO text, e.g. ``f32[128,256]``
TENSOR_RE = re.compile(r"(?P<dtype>[a-z]\w*)\[(?P<dims>[\d,]*)\]")


def tensor_bytes(dtype: str, dims: str) -> int:
    """Payload bytes of one ``dtype[dims]`` tensor (0 for token/opaque
    pseudo-shapes, 1 element for scalars ``dtype[]``)."""
    if dtype not in DTYPE_BYTES:
        return 0
    n = math.prod(int(d) for d in dims.split(",") if d) if dims else 1
    return n * DTYPE_BYTES[dtype]
