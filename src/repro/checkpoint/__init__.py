"""checkpoint subpackage of the repro framework."""
