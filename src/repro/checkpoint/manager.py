"""Atomic, versioned, elastic checkpointing.

* **Atomic**: write to `step_XXXX.tmp/`, fsync, rename -- a preempted save
  never corrupts the latest checkpoint.
* **Versioned**: keeps the last `keep` checkpoints, garbage-collects older.
* **Elastic**: leaves are stored as host numpy arrays with their pytree
  paths; restore re-shards onto ANY mesh via device_put with the target
  shardings (mesh shape may differ from the one that saved -- tested).

At real multi-pod scale the same interface would back onto per-shard OCDBT
(orbax) writes; the manager's contract (atomicity, step indexing, resharding
restore) is what the training loop relies on.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save ----
    def save(self, step: int, tree: Any, metadata: Optional[dict] = None):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        leaves, _ = _flatten_with_paths(tree)
        arrays = {}
        for k, v in leaves.items():
            arr = np.asarray(jax.device_get(v))
            arrays[k.replace("/", "|")] = arr
        np.savez(os.path.join(tmp, "leaves.npz"), **arrays)
        meta = dict(metadata or {})
        meta.update(step=step, time=time.time(),
                    keys=sorted(arrays.keys()))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.replace(tmp, final)  # atomic publish
        self._gc()
        return final

    # ---------------------------------------------------------- restore ----
    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of `tree_like`. `shardings` (same
        structure, NamedSharding leaves) re-shards onto the current mesh --
        which may differ from the saving mesh (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "leaves.npz"))
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)

        leaves, treedef = _flatten_with_paths(tree_like)
        shard_leaves = None
        if shardings is not None:
            shard_leaves, _ = _flatten_with_paths(shardings)
        restored = {}
        for k, ref in leaves.items():
            arr = data[k.replace("/", "|")]
            if hasattr(ref, "dtype"):
                arr = arr.astype(ref.dtype)
            if shard_leaves is not None:
                restored[k] = jax.device_put(arr, shard_leaves[k])
            else:
                restored[k] = jax.numpy.asarray(arr)
        ordered = [restored[k] for k in leaves.keys()]
        return jax.tree_util.tree_unflatten(treedef, ordered), meta

    def _gc(self):
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
