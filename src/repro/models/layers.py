"""Shared neural layers: norms, RoPE, streaming flash attention, GQA
projections, dense MLP, and grouped-dispatch MoE.

All functions are pure (params passed explicitly) and insert activation
sharding constraints via `repro.dist.sharding.shard` (no-ops off-mesh).
Attention never materializes the full S x S score matrix: KV is processed in
chunks with a running (max, denom, accum) softmax state -- the standard
flash algorithm expressed in pure JAX (a Pallas TPU kernel with the same
contract lives in repro/kernels/flash_attention.py).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import shard
from repro import util

NEG_INF = -1e30


# ------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(dt) * weight


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * lax.rsqrt(var + eps)).astype(dt) * weight + bias


# -------------------------------------------------------------------- RoPE

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------- streaming (flash) attention --

def flash_attention(
    q: jax.Array,          # [B, Sq, H, hd]
    k: jax.Array,          # [B, Sk, K, hd]
    v: jax.Array,          # [B, Sk, K, hd]
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    kv_len: Optional[jax.Array] = None,  # [B] valid cache length
    window: int = 0,       # local attention window (0 => unbounded)
    chunk: int = 0,
) -> jax.Array:
    """GQA flash attention with KV-chunk streaming softmax.

    Memory: O(Sq * chunk) scores live, never O(Sq * Sk).
    """
    if not chunk:
        chunk = util.flash_chunk_default()
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    # largest divisor of Sk not exceeding the requested chunk (a naive
    # halving loop degrades e.g. Sk=1500 to chunk=4 => 375 scan bodies)
    chunk = min(chunk, Sk)
    while Sk % chunk:
        chunk -= 1
    n_chunks = Sk // chunk
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    q_pos = (jnp.asarray(q_offset) + jnp.arange(Sq))  # [Sq]

    ks = k.reshape(B, n_chunks, chunk, K, hd)
    vs = v.reshape(B, n_chunks, chunk, K, hd)
    ks = jnp.moveaxis(ks, 1, 0)  # [n, B, chunk, K, hd]
    vs = jnp.moveaxis(vs, 1, 0)

    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)

    bf16_mm = util.attn_bf16_matmuls()

    def body(carry, inp):
        m, l, o = carry
        kc, vc, idx = inp
        base = idx * chunk
        with jax.named_scope("flash_internal"):
            # "flash_internal" tags the kernel-private tensors (scores,
            # probabilities, softmax state): with the Pallas flash kernel
            # they live in VMEM, and the dry-run's fused-attention
            # accounting (REPRO_FUSED_ATTN=1) excludes them from HBM
            # traffic. See kernels/flash_attention.py + launch/dryrun.py.
            if bf16_mm:  # Perf-iteration lever: bf16 MXU ops, f32 state
                s = jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(q.dtype), kc,
                               preferred_element_type=jnp.float32)
            else:
                s = jnp.einsum("bqkgd,bckd->bkgqc", qg,
                               kc.astype(jnp.float32))
            s = s * scale
            k_pos = base + jnp.arange(chunk)  # [chunk]
            mask = jnp.ones((Sq, chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            if kv_len is not None:
                mask = mask[None] & (k_pos[None, None, :]
                                     < kv_len[:, None, None])
                s = jnp.where(mask[:, None, None], s, NEG_INF)
            else:
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(axis=-1)
            if bf16_mm:
                pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v.dtype), vc,
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bkgqc,bckd->bkgqd", p,
                                vc.astype(jnp.float32))
            o_new = o * alpha[..., None] + pv
        return (m_new, l_new, o_new), None

    (m, l, o), _ = util.scan(body, (m0, l0, o0),
                             (ks, vs, jnp.arange(n_chunks)))
    out = o / jnp.maximum(l, 1e-20)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def attention_reference(q, k, v, *, causal=True, q_offset=0, kv_len=None,
                        window=0):
    """Quadratic reference used by tests (materializes S x S)."""
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    if kv_len is not None:
        full = mask[None] & (k_pos[None, None, :] < kv_len[:, None, None])
        s = jnp.where(full[:, None, None], s, NEG_INF)
    else:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return jnp.moveaxis(o, 3, 1).reshape(B, Sq, H, hd).astype(q.dtype)


# --------------------------------------------------------- GQA attention ---

def gqa_attention(cfg, p, x, *, positions, cache=None, layer_name="attn",
                  window: int = 0, chunk: int = 0):
    """Full attention sub-block: QKV proj -> RoPE -> flash attn -> O proj.

    cache: None for train/prefill-from-scratch, else dict with
    {"k": [B, Smax, K, hd], "v": ..., "len": [B]} -- decode appends at
    position `len` and attends over the prefix.
    Returns (out, new_cache).
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    policy = cfg.attn_policy

    qkv = x @ p["wqkv"]  # [B, S, (H + 2K) * hd]
    if policy == "heads":
        qkv = shard(qkv, "batch", None, "model")
    else:  # sequence policy: shard S, replicate heads
        qkv = shard(qkv, "batch", "model", None)
    q, kk, vv = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    q = q.reshape(B, S, H, hd)
    kk = kk.reshape(B, S, K, hd)
    vv = vv.reshape(B, S, K, hd)
    q = rope(q, positions, cfg.rope_theta)
    kk = rope(kk, positions, cfg.rope_theta)

    if cache is None:
        if policy == "heads":
            q = shard(q, "batch", None, "model", None)
            kk = shard(kk, "batch", None, None, None)
            vv = shard(vv, "batch", None, None, None)
        else:
            # context parallelism: Q stays sequence-sharded, KV all-gathered
            q = shard(q, "batch", "model", None, None)
            kk = shard(kk, "batch", None, None, None)
            vv = shard(vv, "batch", None, None, None)
        out = flash_attention(q, kk, vv, causal=True, window=window,
                              chunk=chunk)
        new_cache = None
    else:
        # decode: append S (=1) new token(s) at position cache["len"].
        # k/v arrive model-sharded from the QKV split; constrain them to the
        # cache's batch-only sharding FIRST so the update (and the cache)
        # never reshards (a stray constraint here costs a full-cache
        # all-gather per layer).
        kk = shard(kk, "batch", None, None, None)
        vv = shard(vv, "batch", None, None, None)
        idx = cache["len"][0]  # uniform decode step across batch
        ck = lax.dynamic_update_slice_in_dim(cache["k"],
                                             kk.astype(cache["k"].dtype),
                                             idx, axis=1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"],
                                             vv.astype(cache["v"].dtype),
                                             idx, axis=1)
        ck = shard(ck, "batch", None, None, None)
        cv = shard(cv, "batch", None, None, None)
        if policy == "heads":
            q = shard(q, "batch", None, "model", None)
        out = flash_attention(q, ck, cv, causal=True, q_offset=idx,
                              kv_len=cache["len"] + S, window=window,
                              chunk=chunk)
        new_cache = {"k": ck, "v": cv, "len": cache["len"] + S}

    out = out.reshape(B, S, H * hd)
    out = out @ p["wo"]
    out = shard(out, "batch", None, None)
    return out, new_cache


# ------------------------------------------------------------- dense MLP ---

def swiglu_mlp(p, x):
    h = x @ p["wi_gate"]
    g = x @ p["wi_up"]
    h = shard(h, "batch", None, "model")
    g = shard(g, "batch", None, "model")
    out = (jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * g) @ p["wo"]
    return shard(out, "batch", None, None)


def gelu_mlp(p, x):
    h = x @ p["wi"] + p.get("bi", 0)
    h = shard(h, "batch", None, "model")
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out = h @ p["wo"] + p.get("bo", 0)
    return shard(out, "batch", None, None)


# ----------------------------------------------------- MoE (grouped EP) ----

def moe_block(cfg, p, x, *, group_size: int = 512):
    """Top-k MoE with grouped GShard dispatch.

    Experts are sharded over the `data` axis (EP) and their FF dim over
    `model` (TP); token groups bound the dispatch-einsum cost to
    O(tokens * group_size) instead of O(tokens * seq).
    """
    B, S, D = x.shape
    E, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    tokens = x.reshape(B * S, D)
    T = min(group_size, B * S)
    while (B * S) % T:
        T //= 2
    G = (B * S) // T
    xt = tokens.reshape(G, T, D)
    xt = shard(xt, "batch", None, None)

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)
    gate, sel = lax.top_k(logits, k)  # [G, T, k]
    gate = jax.nn.softmax(gate, axis=-1)

    C = int(math.ceil(T * k * cf / E))
    # position bookkeeping in f32 (counts up to T exceed bf16 integer
    # precision); the dispatch/combine one-hots themselves hold exactly
    # representable 0/1 (and gate weights), so they may live in bf16
    # (REPRO_MOE_BF16_DISPATCH=1) -- halving the [G,T,E,C] tensor traffic.
    from repro import util as _util
    ddt = x.dtype if _util.moe_bf16_dispatch() else jnp.float32
    onehot = jax.nn.one_hot(sel, E, dtype=jnp.float32)      # [G, T, k, E]
    per_te = onehot.sum(2)                                  # [G, T, E] (0/1)
    pos_te = jnp.cumsum(per_te, axis=1) - per_te            # exclusive count
    pos_k = jnp.einsum("gte,gtke->gtk", pos_te, onehot)     # slot per choice
    keep_k = (pos_k < C).astype(jnp.float32)                # capacity drop
    keep = (keep_k[..., None] * onehot).astype(ddt)         # [G, T, k, E]
    posc = (jax.nn.one_hot(pos_k, C, dtype=jnp.float32)
            * keep_k[..., None]).astype(ddt)
    disp = jnp.einsum("gtke,gtkc->gtec", keep, posc)        # [G, T, E, C]
    comb = jnp.einsum("gtk,gtke,gtkc->gtec", gate.astype(ddt), keep, posc)

    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), xt)
    if _util.moe_two_step_reshard():
        # materialize token-sharded first, THEN exchange g(data) -> e(data):
        # a pure dim exchange SPMD lowers as all-to-all instead of
        # all-reduce + all-gather
        xe = shard(xe, "batch", None, None, None)
    xe = shard(xe, None, "data", None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = shard(h, None, "data", None, "model")
    u = shard(u, None, "data", None, "model")
    a = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("gecf,efd->gecd", a, p["w_down"])
    ye = shard(ye, None, "data", None, None)
    if _util.moe_two_step_reshard():
        ye = shard(ye, "batch", None, None, None)  # e(data) -> g(data) A2A
    out = jnp.einsum("gtec,gecd->gtd", comb.astype(x.dtype), ye)
    out = shard(out, "batch", None, None)
    return out.reshape(B, S, D)


# ----------------------------------------------------------- lm head/loss --

def embed_tokens(p, tokens, d_model):
    emb = jnp.take(p["embedding"], tokens, axis=0)
    return shard(emb, "batch", None, None)


def lm_logits(p, x, embedding=None):
    table = embedding if embedding is not None else p["lm_head"]
    logits = x @ table.T if embedding is not None else x @ table
    return shard(logits, "batch", None, "model")


def pim_quantized_linear(x, w, *, weight_bits: int, plan=None,
                         op_name: str | None = None,
                         interpret: bool = True):
    """Quantized linear dispatched by a compiled ``repro.plan`` layout
    plan -- the model layer consumes the same BP/BS decision the cost
    model priced (falling back to the Table-8 advisor when no plan is
    given).

    x: integer activations [..., K] (int8-range); w: unsigned words
    [K, N] with values < 2^weight_bits.  Returns (y [..., N] int32, the
    Layout actually dispatched).
    """
    from repro.kernels.ops import planned_matmul

    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    y, layout = planned_matmul(x2, w, weight_bits=weight_bits, plan=plan,
                               op_name=op_name, interpret=interpret)
    return y.reshape(lead + (w.shape[1],)), layout


def chunked_cross_entropy(logits_fn, x, labels, mask, chunk: int = 512):
    """CE over S in chunks so the [B, chunk, V] logits (vocab-sharded) are
    the only live logits tensor."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk //= 2
    n = S // chunk

    def body(carry, idx):
        tot, cnt = carry
        xs = lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        ls = lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        ms = lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, axis=1)
        logits = logits_fn(xs).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = (lse - picked) * ms
        return (tot + nll.sum(), cnt + ms.sum()), None

    (tot, cnt), _ = util.scan(body, (jnp.float32(0), jnp.float32(0)),
                              jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)
