"""Model registry: family -> (param_structure, forward_train, decode_step,
cache_structure), plus analytic parameter/FLOP accounting for the roofline.
"""
from __future__ import annotations

import math
from types import SimpleNamespace

from repro.models.base import ArchConfig, param_count_of


def model_fns(cfg: ArchConfig) -> SimpleNamespace:
    if cfg.family == "ssm":
        from repro.models import mamba2 as m
    elif cfg.family == "audio":
        from repro.models import whisper as m
    else:  # dense | moe | hybrid | vlm share the decoder stack
        from repro.models import transformer as m
    return SimpleNamespace(
        param_structure=m.param_structure,
        cache_structure=m.cache_structure,
        forward_train=m.forward_train,
        forward_hidden=m.forward_hidden,
        forward_logits=m.forward_logits,
        decode_step=m.decode_step,
    )


def traced_workload(cfg: ArchConfig, *, tokens: int = 4096,
                    phase: str = "decode", weight_bits: int = 4,
                    scan_mode: str = "once"):
    """Trace the family's real forward pass into a Workload DAG.

    ``phase="decode"``: one decode step over ``tokens`` concurrent
    sequences with a ``tokens``-long KV cache -- the operating point of
    the hand-written ``arch/<id>`` serving formulas, so the two are
    directly comparable (``repro.workloads.trace_diff``).
    ``phase="prefill"``: ``forward_hidden`` over one ``tokens``-long
    sequence.

    Tracing is abstract (``jax.ShapeDtypeStruct`` pytrees): full-size
    models trace without allocating a single parameter.  Weight matrices
    (>=2-D leaves at the model dtype) resolve to ``weight_bits``; the
    RG-LRU gate matrices stay at model precision, matching the 16-bit
    ``rg_lru_gates`` formula op.
    """
    import jax
    import jax.numpy as jnp

    from repro.models.base import abstract_params
    from repro.workloads.trace import param_path_widths, trace_workload

    if phase not in ("decode", "prefill"):
        raise ValueError(f"phase must be 'decode' or 'prefill', "
                         f"got {phase!r}")
    fns = model_fns(cfg)
    params = abstract_params(fns.param_structure(cfg))
    pmap = param_path_widths(params, weight_bits=weight_bits,
                             dtype=cfg.dtype,
                             exclude=("a_gate", "input_gate"))
    if phase == "decode":
        cache = abstract_params(
            fns.cache_structure(cfg, batch=tokens, max_len=tokens))
        tok = jax.ShapeDtypeStruct((tokens, 1), jnp.int32)

        def fn(p, c, t):
            return fns.decode_step(cfg, p, c, t)
        args = (params, cache, tok)
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((1, tokens), jnp.int32)}
        if cfg.family == "audio":
            batch["frames"] = jax.ShapeDtypeStruct(
                (1, cfg.enc_seq, cfg.d_model), cfg.dtype)

        def fn(p, b):
            return fns.forward_hidden(cfg, p, b)
        args = (params, batch)
    return trace_workload(
        fn, *args, precision_map=pmap, name=f"traced/{cfg.name}",
        source="traced", scan_mode=scan_mode,
        description=(f"{cfg.name} jaxpr-traced {phase} step "
                     f"({tokens} tokens, int{weight_bits} weights)"))


def param_count(cfg: ArchConfig) -> int:
    """Exact parameter count from the parameter structure."""
    return param_count_of(model_fns(cfg).param_structure(cfg))


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top_k of n_experts experts).
    Used for MODEL_FLOPS = 6 * N_active * D (dense) in the roofline."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    st = model_fns(cfg).param_structure(cfg)
    expert_leaves = 0
    for blk in st["blocks"]:
        mlp = blk.get("mlp", {})
        for name in ("w_gate", "w_up", "w_down"):
            if name in mlp:
                expert_leaves += math.prod(mlp[name].shape)
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - expert_leaves * (1 - active_frac))


def model_flops(cfg: ArchConfig, tokens: int, *, train: bool = True) -> float:
    """6*N_active*D for training (fwd+bwd), 2*N_active*D for inference."""
    n = active_param_count(cfg)
    return (6.0 if train else 2.0) * n * tokens
