"""Model registry: family -> (param_structure, forward_train, decode_step,
cache_structure), plus analytic parameter/FLOP accounting for the roofline.
"""
from __future__ import annotations

import math
from types import SimpleNamespace

from repro.models.base import ArchConfig, param_count_of


def model_fns(cfg: ArchConfig) -> SimpleNamespace:
    if cfg.family == "ssm":
        from repro.models import mamba2 as m
    elif cfg.family == "audio":
        from repro.models import whisper as m
    else:  # dense | moe | hybrid | vlm share the decoder stack
        from repro.models import transformer as m
    return SimpleNamespace(
        param_structure=m.param_structure,
        cache_structure=m.cache_structure,
        forward_train=m.forward_train,
        forward_hidden=m.forward_hidden,
        forward_logits=m.forward_logits,
        decode_step=m.decode_step,
    )


def param_count(cfg: ArchConfig) -> int:
    """Exact parameter count from the parameter structure."""
    return param_count_of(model_fns(cfg).param_structure(cfg))


def active_param_count(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE: top_k of n_experts experts).
    Used for MODEL_FLOPS = 6 * N_active * D (dense) in the roofline."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    st = model_fns(cfg).param_structure(cfg)
    expert_leaves = 0
    for blk in st["blocks"]:
        mlp = blk.get("mlp", {})
        for name in ("w_gate", "w_up", "w_down"):
            if name in mlp:
                expert_leaves += math.prod(mlp[name].shape)
    active_frac = cfg.top_k / cfg.n_experts
    return int(total - expert_leaves * (1 - active_frac))


def model_flops(cfg: ArchConfig, tokens: int, *, train: bool = True) -> float:
    """6*N_active*D for training (fwd+bwd), 2*N_active*D for inference."""
    n = active_param_count(cfg)
    return (6.0 if train else 2.0) * n * tokens
