"""Model zoo: the 10 assigned architectures as composable JAX modules."""
from repro.models.base import (  # noqa: F401
    ArchConfig, ParamSpec, abstract_params, init_params, param_shardings,
)
from repro.models import registry  # noqa: F401
