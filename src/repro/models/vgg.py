"""Abstract VGG CIFAR-10 forward pass for the jaxpr tracer.

The Table-6 ``vgg13/16/19`` registry workloads are hand-written conv/fc
formulas (``workloads.registry._vgg_ops``).  This module provides the
*real* forward pass at the same operating point (batch-128 CIFAR-10) so
``workloads.trace.trace_workload`` can derive the same workload from a
jaxpr -- the traced-VGG-vs-formula check of the differential suite.

Parameters are ``jax.ShapeDtypeStruct`` pytrees (f32 -- the formula ops
are 16-bit default-width, and floats without a precision-map entry
resolve to 16); nothing is ever allocated.
"""
from __future__ import annotations

import math

__all__ = ["VGG_BLOCKS", "VGG_BATCH", "VGG_FCS", "abstract_inputs",
           "forward", "traced_vgg"]

#: (out_channels, input/output spatial, conv layers) per block -- the
#: same table the formula workload is built from (CIFAR-10, 32x32 input)
VGG_BLOCKS = {
    "vgg13": [(64, 32, 2), (128, 16, 2), (256, 8, 2), (512, 4, 2),
              (512, 2, 2)],
    "vgg16": [(64, 32, 2), (128, 16, 2), (256, 8, 3), (512, 4, 3),
              (512, 2, 3)],
    "vgg19": [(64, 32, 2), (128, 16, 2), (256, 8, 4), (512, 4, 4),
              (512, 2, 4)],
}
VGG_BATCH = 128  # batch inference, as in the formula workload

VGG_FCS = [(512, 512), (512, 512), (512, 10)]


def abstract_inputs(which: str = "vgg16"):
    """(params, images) ShapeDtypeStruct pytrees for :func:`forward`."""
    import jax
    import jax.numpy as jnp

    f32 = jnp.float32
    params: dict = {}
    c_in = 3
    for bi, (c, _s, reps) in enumerate(VGG_BLOCKS[which]):
        for r in range(reps):
            params[f"b{bi}c{r}"] = jax.ShapeDtypeStruct(
                (3, 3, c_in, c), f32)  # HWIO
            c_in = c
    for fi, (k, n) in enumerate(VGG_FCS):
        params[f"fc{fi}"] = jax.ShapeDtypeStruct((k, n), f32)
    images = jax.ShapeDtypeStruct((VGG_BATCH, 32, 32, 3), f32)  # NHWC
    return params, images


def forward(params, images, which: str = "vgg16"):
    """Conv blocks (3x3 SAME + relu, 2x2 max-pool per block) + FC head."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    x = images
    for bi, (_c, _s, reps) in enumerate(VGG_BLOCKS[which]):
        for r in range(reps):
            x = lax.conv_general_dilated(
                x, params[f"b{bi}c{r}"], window_strides=(1, 1),
                padding="SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x)
        x = lax.reduce_window(x, -jnp.inf, lax.max,
                              window_dimensions=(1, 2, 2, 1),
                              window_strides=(1, 2, 2, 1), padding="VALID")
    x = x.reshape(x.shape[0], math.prod(x.shape[1:]))
    for fi in range(len(VGG_FCS)):
        x = x @ params[f"fc{fi}"]
        if fi < len(VGG_FCS) - 1:
            x = jax.nn.relu(x)
    return x


def traced_vgg(which: str = "vgg16"):
    """Trace :func:`forward` into a ``traced/<which>`` Workload."""
    from repro.workloads.trace import trace_workload

    params, images = abstract_inputs(which)
    return trace_workload(
        lambda p, im: forward(p, im, which), params, images,
        name=f"traced/{which}", source="traced",
        description=f"{which.upper()} batch-{VGG_BATCH} CIFAR-10 "
                    "inference, jaxpr-traced")
