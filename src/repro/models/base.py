"""Model substrate: arch configs, parameter structures, initialization.

A model is described by an :class:`ArchConfig` plus a *parameter structure*
-- a pytree of :class:`ParamSpec` leaves carrying shape, dtype, sharding
spec, and initializer. The same structure drives:
  * random init (smoke tests, real training),
  * abstract init (`jax.ShapeDtypeStruct`, dry-run -- no allocation),
  * sharding assignment (`NamedSharding` per leaf for pjit in/out shardings).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.dist.sharding import resolve_pspec

VOCAB_PAD_MULTIPLE = 256  # Megatron convention


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture (exact published dims; see configs/)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE layer stride (llama4: every 2nd layer)
    capacity_factor: float = 1.25
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (recurrentgemma) ---
    window: int = 0  # local-attention window
    block_pattern: tuple = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    # --- enc-dec / multimodal frontends (stubs provide embeddings) ---
    enc_layers: int = 0
    enc_seq: int = 0  # frames (whisper) / patches (internvl2)
    # --- common ---
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # attention sharding policy: "heads" if n_heads % model_shards == 0
    # else "sequence" (context parallel / KV all-gather)
    attn_policy: str = "heads"
    # long-context support: sub-quadratic families run long_500k
    subquadratic: bool = False

    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return (self.vocab_size + m - 1) // m * m

    @property
    def qkv_dim(self) -> int:
        return (self.n_heads + 2 * self.n_kv_heads) * self.head_dim

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        from repro.models import registry  # late import, avoids cycle
        return registry.param_count(self)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One parameter leaf: shape/dtype/partitioning/initializer."""

    shape: tuple
    dtype: Any
    pspec: tuple  # symbolic PartitionSpec entries (see resolve_pspec)
    init: str = "normal"  # normal | zeros | ones | embed | small
    fan_in: Optional[int] = None

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh,
                             resolve_pspec(self.pspec, mesh, self.shape))


def materialize(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.fan_in or (spec.shape[-2] if len(spec.shape) >= 2
                             else spec.shape[-1])
    scale = {"normal": 1.0 / math.sqrt(max(1, fan_in)),
             # d_model^-0.5 keeps tied-head logits at unit scale
             "embed": 1.0 / math.sqrt(spec.shape[-1]),
             "small": 0.02}[spec.init]
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale
            ).astype(spec.dtype)


def init_params(structure, rng: jax.Array):
    """Materialize a ParamSpec pytree into real arrays."""
    leaves, treedef = jax.tree.flatten(
        structure, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(rng, len(leaves))
    vals = [materialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(structure):
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return jax.tree.map(lambda s: s.abstract(), structure,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shardings(structure, mesh: Mesh):
    return jax.tree.map(lambda s: s.sharding(mesh), structure,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_bytes(structure) -> int:
    leaves = jax.tree.leaves(structure,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
               for s in leaves)


def param_count_of(structure) -> int:
    leaves = jax.tree.leaves(structure,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(math.prod(s.shape) for s in leaves)
