"""Mamba-2: state-space duality (SSD) layer (arXiv:2405.21060).

Chunked SSD algorithm (the "quadratic-within-chunk, linear-across-chunks"
form of the paper's Listing 1):

  per head h, state (N = d_state, P = head_dim):
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T
    y_t = C_t . h_t + D x_t

  chunks of length Q: intra-chunk attention-like term with decay mask
  L_ij = exp(cum_i - cum_j), inter-chunk state passing via a (sequential)
  scan over chunk states -- O(S Q) work, O(S/Q) scan steps.

Decode carries (ssm state [B, H, N, P], conv window) -- O(1) per token,
which is why this family runs `long_500k`.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro import util
from repro.models.base import ArchConfig, ParamSpec
from repro.models import layers as L


# ------------------------------------------------------------- structure ---

def param_structure(cfg: ArchConfig):
    D, dt = cfg.d_model, cfg.dtype
    Din = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    V = cfg.padded_vocab
    nl = cfg.n_layers
    conv_dim = Din + 2 * N  # x, B, C share the conv (mamba2 layout)
    layer = {
        "ln": ParamSpec((nl, D), dt, (None, None), init="ones"),
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": ParamSpec((nl, D, 2 * Din + 2 * N + H), dt,
                          (None, None, "model"), fan_in=D),
        "conv_w": ParamSpec((nl, cfg.conv_width, conv_dim), dt,
                            (None, None, "model"), init="small"),
        "A_log": ParamSpec((nl, H), jnp.float32, (None, "model"),
                           init="small"),
        "D": ParamSpec((nl, H), jnp.float32, (None, "model"), init="small"),
        "dt_bias": ParamSpec((nl, H), jnp.float32, (None, "model"),
                             init="small"),
        "norm": ParamSpec((nl, Din), dt, (None, "model"), init="ones"),
        "w_out": ParamSpec((nl, Din, D), dt, (None, "model", None),
                           fan_in=Din),
    }
    return {
        "embedding": ParamSpec((V, D), dt, ("model", None), init="embed"),
        "final_ln": ParamSpec((D,), dt, (None,), init="ones"),
        "blocks": [layer],
    }


def cache_structure(cfg: ArchConfig, batch: int, max_len: int):
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    conv_dim = cfg.d_inner + 2 * N
    nl = cfg.n_layers
    return {
        "len": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros"),
        "blocks": [{
            "ssm": ParamSpec((nl, batch, H, N, P), jnp.float32,
                             (None, "batch", "model", None, None),
                             init="zeros"),
            "conv": ParamSpec((nl, batch, cfg.conv_width - 1, conv_dim),
                              cfg.dtype, (None, "batch", None, "model"),
                              init="zeros"),
        }],
    }


# ------------------------------------------------------------------- SSD ---

def _ssd_chunked(x, log_a, B, C, chunk):
    """x: [B?, S, H, P]; log_a: [B?, S, H]; B, C: [B?, S, N].
    Returns y [B?, S, H, P] and final state [B?, H, N, P].
    Single shared B/C group (mamba2-780m uses n_groups=1)."""
    Bb, S, H, P = x.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q
    xr = x.reshape(Bb, nc, Q, H, P)
    lr = log_a.reshape(Bb, nc, Q, H).astype(jnp.float32)
    Br = B.reshape(Bb, nc, Q, N).astype(jnp.float32)
    Cr = C.reshape(Bb, nc, Q, N).astype(jnp.float32)

    cum = jnp.cumsum(lr, axis=2)  # [B, nc, Q, H]
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (C_i.B_j) x_j
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)  # [B, nc, Q, Q]
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    mask = causal[None, None, :, :, None]
    lmat = jnp.where(mask, jnp.exp(decay), 0.0)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", scores, lmat,
                         xr.astype(jnp.float32))

    # chunk states: S_c = sum_j exp(cum_Q - cum_j) B_j x_j^T  [B,nc,H,N,P]
    tail_decay = jnp.exp(cum[:, :, -1:, :] - cum)  # [B, nc, Q, H]
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", Br, tail_decay,
                        xr.astype(jnp.float32))

    # inter-chunk scan: S_running (before chunk c)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # [B, nc, H]

    def step(carry, inp):
        s_c, d_c = inp  # [B,H,N,P], [B,H]
        new = carry * d_c[:, :, None, None] + s_c
        return new, carry  # emit state *before* this chunk

    s0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    final, prev_states = util.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B, nc, H, N, P]

    # inter-chunk contribution: y_i += exp(cum_i) C_i . S_prev
    in_decay = jnp.exp(cum)  # [B, nc, Q, H]
    y_inter = jnp.einsum("bcqn,bcqh,bchnp->bcqhp", Cr, in_decay, prev_states)
    y = (y_intra + y_inter).reshape(Bb, S, H, P)
    return y, final


def _ssd_decode(x, log_a, B, C, state):
    """Single-token recurrence. x: [B, 1, H, P]; state: [B, H, N, P]."""
    a = jnp.exp(log_a[:, 0].astype(jnp.float32))  # [B, H]
    Bt = B[:, 0].astype(jnp.float32)  # [B, N]
    Ct = C[:, 0].astype(jnp.float32)
    xt = x[:, 0].astype(jnp.float32)  # [B, H, P]
    new_state = state * a[:, :, None, None] + \
        jnp.einsum("bn,bhp->bhnp", Bt, xt)
    y = jnp.einsum("bn,bhnp->bhp", Ct, new_state)
    return y[:, None], new_state


# ---------------------------------------------------------------- forward --

def _mamba_layer(cfg: ArchConfig, p, x, *, cache=None):
    Bb, S, D = x.shape
    Din, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim

    h = L.rms_norm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["w_in"]  # [B, S, 2*Din + 2N + H]
    proj = shard(proj, "batch", None, "model")
    z, xin, Bc, Cc, dt = jnp.split(
        proj, [Din, 2 * Din, 2 * Din + N, 2 * Din + 2 * N], axis=-1)

    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    from repro.models.recurrent import _causal_conv
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    xin, Bc, Cc = jnp.split(conv_out, [Din, Din + N], axis=-1)

    dt_f = jax.nn.softplus(dt.astype(jnp.float32)
                           + p["dt_bias"][None, None, :])  # [B, S, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # [H] (negative)
    log_a = dt_f * A[None, None, :]
    xh = xin.reshape(Bb, S, H, P)
    xh_dt = xh.astype(jnp.float32) * dt_f[..., None]

    if cache is None:
        y, _ = _ssd_chunked(xh_dt, log_a, Bc, Cc, cfg.ssm_chunk)
        new_cache = None
    else:
        y, new_state = _ssd_decode(xh_dt, log_a, Bc, Cc,
                                   cache["ssm"].astype(jnp.float32))
        new_cache = {"ssm": new_state, "conv": new_conv}

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bb, S, Din).astype(x.dtype)
    y = L.rms_norm(y, p["norm"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = y @ p["w_out"]
    return x + shard(out, "batch", None, None), new_cache


def forward_hidden(cfg: ArchConfig, params, batch):
    x = L.embed_tokens(params, batch["tokens"], cfg.d_model)

    def scan_fn(x, lp):
        x, _ = _mamba_layer(cfg, lp, x)
        return x, None

    if util.remat_enabled():
        scan_fn = jax.checkpoint(
            scan_fn, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = util.scan(scan_fn, x, params["blocks"][0])
    return L.rms_norm(x, params["final_ln"], cfg.norm_eps)


def forward_train(cfg: ArchConfig, params, batch):
    x = forward_hidden(cfg, params, batch)
    from repro.models.transformer import _logits_fn
    return L.chunked_cross_entropy(_logits_fn(cfg, params), x,
                                   batch["labels"], batch["mask"])


def forward_logits(cfg: ArchConfig, params, batch):
    from repro.models.transformer import _logits_fn
    return _logits_fn(cfg, params)(forward_hidden(cfg, params, batch))


def decode_step(cfg: ArchConfig, params, cache, tokens):
    x = L.embed_tokens(params, tokens, cfg.d_model)

    def scan_fn(carry, inp):
        x = carry
        lp, lc = inp
        x, nc = _mamba_layer(cfg, lp, x, cache=lc)
        return x, nc

    x, new_caches = util.scan(scan_fn, x,
                              (params["blocks"][0], cache["blocks"][0]))
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    from repro.models.transformer import _logits_fn
    logits = _logits_fn(cfg, params)(x)
    return logits, {"len": cache["len"] + tokens.shape[1],
                    "blocks": [new_caches]}
