"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

    x_t' = conv1d(Wx x)_t                 (short causal depthwise conv)
    r_t  = sigmoid(Wa x_t')               (recurrence gate)
    i_t  = sigmoid(Wi x_t')               (input gate)
    a_t  = exp(-c * softplus(A) * r_t)    (per-channel decay, c = 8)
    h_t  = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t')
    out  = Wo (h * sigmoid(gate))

Training/prefill uses `lax.associative_scan` over time (parallel prefix --
the TPU-friendly form); decode carries (h, conv window) in the cache: O(1)
state, which is what makes `long_500k` runnable for this family.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import shard

_C = 8.0


def _causal_conv(x, w, conv_state=None):
    """Depthwise causal conv along time. x: [B, S, W]; w: [K, W].
    conv_state: [B, K-1, W] prefix (decode) or None (zero-pad)."""
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, W]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return out, new_state


def rg_lru_block(cfg, p, x, *, cache=None):
    """x: [B, S, D] -> ([B, S, D], new_cache)."""
    B, S, D = x.shape
    W = cfg.lru_width

    xb = x @ p["wx"]  # [B, S, W]
    gate = x @ p["wgate"]
    xb = shard(xb, "batch", None, "model")
    gate = shard(gate, "batch", None, "model")

    conv_state = cache["conv"] if cache is not None else None
    xb, new_conv = _causal_conv(xb, p["conv_w"][..., :], conv_state)

    r = jax.nn.sigmoid((xb @ p["w_a_gate"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xb @ p["w_input_gate"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)  # [B, S, W]
    gated_x = i * xb.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * gated_x

    if cache is None:
        # h_t = a_t h_{t-1} + b_t  via associative scan on (a, b) pairs
        def combine(l, r):
            (al, bl), (ar, br) = l, r
            return al * ar, ar * bl + br
        a_s, h = lax.associative_scan(combine, (a, b), axis=1)
        new_cache = None
    else:
        h0 = cache["h"].astype(jnp.float32)  # [B, W]

        def step(hprev, inp):
            at, bt = inp
            hnew = at * hprev + bt
            return hnew, hnew
        hT, hs = lax.scan(step, h0, (jnp.moveaxis(a, 1, 0),
                                     jnp.moveaxis(b, 1, 0)))
        h = jnp.moveaxis(hs, 0, 1)
        new_cache = {"h": hT, "conv": new_conv}

    h = h.astype(x.dtype) * jax.nn.sigmoid(gate.astype(jnp.float32)
                                           ).astype(x.dtype)
    out = h @ p["wo"]
    return shard(out, "batch", None, None), new_cache
