"""Decoder-only transformer family: dense (yi, tinyllama, mistral-nemo,
stablelm), MoE (dbrx, llama4-maverick), and the LM backbone reused by the
VLM/audio/hybrid models.

Layers are stacked along a leading block axis and executed with `lax.scan`
(small HLO, O(1) compile cost in depth). A block is a repeating pattern of
sub-layers (`block_layout`), so MoE-every-2 (llama4) and hybrid patterns
(recurrentgemma) reuse the same machinery.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.sharding import shard
from repro import util
from repro.models import layers as L
from repro.models.base import ArchConfig, ParamSpec


# ------------------------------------------------------------- structure ---

def block_layout(cfg: ArchConfig) -> tuple[list[str], list[str]]:
    """(repeating block layout, tail layout). Entries: 'dense' | 'moe' |
    'rec' | 'attn_local'."""
    if cfg.family == "hybrid":
        pat = list(cfg.block_pattern)
        n_full = cfg.n_layers // len(pat)
        tail_n = cfg.n_layers - n_full * len(pat)
        return pat, pat[:tail_n]
    if cfg.n_experts and cfg.moe_every == 2:
        assert cfg.n_layers % 2 == 0
        return ["dense", "moe"], []
    if cfg.n_experts:
        return ["moe"], []
    return ["dense"], []


def _attn_params(cfg: ArchConfig, n: int) -> dict:
    D, hd = cfg.d_model, cfg.head_dim
    qkv = cfg.qkv_dim
    dt = cfg.dtype
    return {
        "wqkv": ParamSpec((n, D, qkv), dt, (None, None, "model"), fan_in=D),
        "wo": ParamSpec((n, cfg.n_heads * hd, D), dt,
                        (None, "model", None), fan_in=cfg.n_heads * hd),
    }


def _mlp_params(cfg: ArchConfig, n: int) -> dict:
    D, F, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    return {
        "wi_gate": ParamSpec((n, D, F), dt, (None, None, "model"), fan_in=D),
        "wi_up": ParamSpec((n, D, F), dt, (None, None, "model"), fan_in=D),
        "wo": ParamSpec((n, F, D), dt, (None, "model", None), fan_in=F),
    }


def _moe_params(cfg: ArchConfig, n: int) -> dict:
    D, F, E, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.dtype
    return {
        "router": ParamSpec((n, D, E), jnp.float32, (None, None, None),
                            init="small"),
        "w_gate": ParamSpec((n, E, D, F), dt, (None, "data", None, "model"),
                            fan_in=D),
        "w_up": ParamSpec((n, E, D, F), dt, (None, "data", None, "model"),
                          fan_in=D),
        "w_down": ParamSpec((n, E, F, D), dt, (None, "data", "model", None),
                            fan_in=F),
    }


def _rec_params(cfg: ArchConfig, n: int) -> dict:
    """RG-LRU recurrent block (recurrentgemma)."""
    D, W, dt = cfg.d_model, cfg.lru_width, cfg.dtype
    return {
        "wx": ParamSpec((n, D, W), dt, (None, None, "model"), fan_in=D),
        "wgate": ParamSpec((n, D, W), dt, (None, None, "model"), fan_in=D),
        "conv_w": ParamSpec((n, cfg.conv_width, W), dt,
                            (None, None, "model"), init="small"),
        "a_param": ParamSpec((n, W), jnp.float32, (None, "model"),
                             init="small"),
        "w_input_gate": ParamSpec((n, W, W), dt, (None, None, "model"),
                                  fan_in=W),
        "w_a_gate": ParamSpec((n, W, W), dt, (None, None, "model"), fan_in=W),
        "wo": ParamSpec((n, W, D), dt, (None, "model", None), fan_in=W),
    }


def _sublayer_params(cfg: ArchConfig, kind: str, n: int) -> dict:
    D, dt = cfg.d_model, cfg.dtype
    ln = lambda: ParamSpec((n, D), dt, (None, None), init="ones")  # noqa: E731
    if kind in ("dense", "moe"):
        body = _mlp_params(cfg, n) if kind == "dense" else _moe_params(cfg, n)
        return {"ln1": ln(), "attn": _attn_params(cfg, n),
                "ln2": ln(), "mlp": body}
    if kind == "attn_local":
        return {"ln1": ln(), "attn": _attn_params(cfg, n),
                "ln2": ln(), "mlp": _mlp_params(cfg, n)}
    if kind == "rec":
        return {"ln1": ln(), "rec": _rec_params(cfg, n),
                "ln2": ln(), "mlp": _mlp_params(cfg, n)}
    raise ValueError(kind)


def param_structure(cfg: ArchConfig):
    layout, tail = block_layout(cfg)
    per = len(layout)
    n_blocks = (cfg.n_layers - len(tail)) // per
    V, D, dt = cfg.padded_vocab, cfg.d_model, cfg.dtype
    st = {
        "embedding": ParamSpec((V, D), dt, ("model", None), init="embed"),
        "final_ln": ParamSpec((D,), dt, (None,), init="ones"),
        "blocks": [
            _sublayer_params(cfg, kind, n_blocks) for kind in layout
        ],
    }
    if tail:
        st["tail"] = [_sublayer_params(cfg, kind, 1) for kind in tail]
    if not cfg.tie_embeddings:
        st["lm_head"] = ParamSpec((D, V), dt, (None, "model"), fan_in=D)
    return st


# ----------------------------------------------------------------- cache ---

def cache_structure(cfg: ArchConfig, batch: int, max_len: int):
    """Decode cache as a ParamSpec pytree (zeros init / abstract dry-run)."""
    layout, tail = block_layout(cfg)
    per = len(layout)
    n_blocks = (cfg.n_layers - len(tail)) // per
    K, hd, dt = cfg.n_kv_heads, cfg.head_dim, cfg.dtype

    def kv(n, length):
        return {
            "k": ParamSpec((n, batch, length, K, hd), dt,
                           (None, "batch", None, None, None), init="zeros"),
            "v": ParamSpec((n, batch, length, K, hd), dt,
                           (None, "batch", None, None, None), init="zeros"),
        }

    def sub(kind, n):
        if kind in ("dense", "moe"):
            return kv(n, max_len)
        if kind == "attn_local":
            # full-length cache with window enforced by masking; a ring
            # buffer (O(window) memory) is a recorded perf-iteration lever
            return kv(n, max_len)
        if kind == "rec":
            W = cfg.lru_width
            return {
                "h": ParamSpec((n, batch, W), jnp.float32,
                               (None, "batch", "model"), init="zeros"),
                "conv": ParamSpec((n, batch, cfg.conv_width - 1, W), dt,
                                  (None, "batch", None, "model"),
                                  init="zeros"),
            }
        raise ValueError(kind)

    st = {"len": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros"),
          "blocks": [sub(kind, n_blocks) for kind in layout]}
    if tail:
        st["tail"] = [sub(kind, 1) for kind in tail]
    return st


# ---------------------------------------------------------------- forward --

def _take_layer(tree, i):
    return jax.tree.map(lambda x: x[i], tree)


def _apply_sublayer(cfg, kind, p, x, *, positions, cache, window_override=None):
    """One residual sub-layer. Returns (x, new_cache)."""
    from repro.models import recurrent  # late import (rec blocks)

    new_cache = cache
    if kind == "rec":
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        h, new_cache = recurrent.rg_lru_block(cfg, p["rec"], h, cache=cache)
        x = x + h
    else:
        window = cfg.window if kind == "attn_local" else 0
        if window_override is not None:
            window = window_override
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_cache = None if cache is None else \
            {"k": cache["k"], "v": cache["v"], "len": cache["len"]}
        h, kv_new = L.gqa_attention(cfg, p["attn"], h, positions=positions,
                                    cache=attn_cache, window=window)
        if kv_new is not None:
            new_cache = {"k": kv_new["k"], "v": kv_new["v"]}
        x = x + h
    if util.bf16_allreduce_barrier():
        x = lax.optimization_barrier(x)  # keep TP psums in bf16
    h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        h = L.moe_block(cfg, p["mlp"], h)
    else:
        h = L.swiglu_mlp(p["mlp"], h)
    x = x + h
    if util.bf16_allreduce_barrier():
        x = lax.optimization_barrier(x)
    return x, new_cache


def _run_blocks(cfg, params, x, *, positions, cache=None):
    """Scan the repeating blocks, then the tail. Returns (x, new_cache)."""
    layout, tail = block_layout(cfg)

    def block_fn(xc, blk):
        x, step_len = xc
        blk_params, blk_cache = blk
        new_caches = []
        for kind, p, c in zip(layout, blk_params,
                              blk_cache or [None] * len(layout)):
            if c is not None:
                c = dict(c)
                c["len"] = step_len
            x, nc = _apply_sublayer(cfg, kind, p, x, positions=positions,
                                    cache=c)
            if nc is not None:
                nc = {k: v for k, v in nc.items() if k != "len"}
            new_caches.append(nc)
        return (x, step_len), new_caches

    blk_caches = cache["blocks"] if cache is not None else None
    step_len = cache["len"] if cache is not None else None
    if cache is None:
        def scan_fn(x, blk_params):
            (x, _), _ = block_fn((x, None), (blk_params, None))
            return x, None
        if util.remat_enabled():
            scan_fn = jax.checkpoint(
                scan_fn, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = util.scan(scan_fn, x, params["blocks"])
        new_cache = None
    else:
        def scan_fn(carry, xs):
            blk_params, blk_cache = xs
            (x, sl), ncs = block_fn(carry, (blk_params, blk_cache))
            return (x, sl), ncs
        (x, _), new_blk_caches = util.scan(
            scan_fn, (x, step_len), (params["blocks"], blk_caches))
        new_cache = {"len": step_len + x.shape[1],
                     "blocks": new_blk_caches}

    if tail:
        tail_caches = cache.get("tail") if cache is not None else None
        new_tail = []
        for i, kind in enumerate(tail):
            p = _take_layer(params["tail"][i], 0)
            c = None
            if tail_caches is not None:
                c = dict(_take_layer(tail_caches[i], 0))
                c["len"] = step_len
            x, nc = _apply_sublayer(cfg, kind, p, x, positions=positions,
                                    cache=c)
            if nc is not None:  # restore the leading block axis
                nc = {k: v[None] for k, v in nc.items() if k != "len"}
            new_tail.append(nc)
        if new_cache is not None:
            new_cache["tail"] = new_tail
    return x, new_cache


def _logits_fn(cfg, params):
    table = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]

    def fn(x):
        logits = x @ table
        logits = shard(logits, "batch", None, "model")
        v = jnp.arange(logits.shape[-1])
        return jnp.where(v[None, None, :] < cfg.vocab_size,
                         logits, L.NEG_INF)
    return fn


def forward_hidden(cfg: ArchConfig, params, batch):
    """Final hidden states for the token positions (prefix stripped)."""
    tokens = batch["tokens"]
    x = L.embed_tokens(params, tokens, cfg.d_model)
    if "prefix_embeds" in batch:
        pre = batch["prefix_embeds"].astype(x.dtype)
        x = jnp.concatenate([pre, x], axis=1)
    x = shard(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])
    x, _ = _run_blocks(cfg, params, x, positions=positions)
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    if "prefix_embeds" in batch:
        x = x[:, batch["prefix_embeds"].shape[1]:]
    return x


def forward_train(cfg: ArchConfig, params, batch):
    """batch: tokens [B,S], labels [B,S], mask [B,S] (+ optional
    'prefix_embeds' [B,P,D] for VLM-style prefixes)."""
    x = forward_hidden(cfg, params, batch)
    return L.chunked_cross_entropy(_logits_fn(cfg, params), x,
                                   batch["labels"], batch["mask"])


def forward_logits(cfg: ArchConfig, params, batch):
    return _logits_fn(cfg, params)(forward_hidden(cfg, params, batch))


def decode_step(cfg: ArchConfig, params, cache, tokens):
    """One decode step: tokens [B, 1] -> (logits [B, 1, Vp], new cache)."""
    B, S = tokens.shape
    x = L.embed_tokens(params, tokens, cfg.d_model)
    positions = cache["len"][:, None] + jnp.arange(S)[None, :]
    x, new_cache = _run_blocks(cfg, params, x, positions=positions,
                               cache=cache)
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = _logits_fn(cfg, params)(x)
    return logits, new_cache
