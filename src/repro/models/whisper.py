"""Whisper-small backbone: encoder-decoder transformer (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: `input_specs` provides
precomputed frame embeddings [B, enc_seq=1500, D]. Backbone dims are exact
(12+12 layers, d_model 768, 12 heads, d_ff 3072, vocab 51865->51968 padded).
Adaptations recorded in DESIGN.md: RoPE replaces learned absolute positions
(the assigned decode_32k/prefill_32k shapes exceed Whisper's 448-token
decoder), and norms are unified to RMSNorm across the zoo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard
from repro import util
from repro.models import layers as L
from repro.models.base import ArchConfig, ParamSpec
from repro.models.transformer import _logits_fn


def _attn(cfg, n):
    D, hd = cfg.d_model, cfg.head_dim
    return {
        "wqkv": ParamSpec((n, D, cfg.qkv_dim), cfg.dtype,
                          (None, None, "model"), fan_in=D),
        "wo": ParamSpec((n, cfg.n_heads * hd, D), cfg.dtype,
                        (None, "model", None), fan_in=cfg.n_heads * hd),
    }


def _xattn(cfg, n):
    D, hd, H, K = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    return {
        "wq": ParamSpec((n, D, H * hd), cfg.dtype, (None, None, "model"),
                        fan_in=D),
        "wkv": ParamSpec((n, D, 2 * K * hd), cfg.dtype, (None, None, "model"),
                         fan_in=D),
        "wo": ParamSpec((n, H * hd, D), cfg.dtype, (None, "model", None),
                        fan_in=H * hd),
    }


def _mlp(cfg, n):
    D, F = cfg.d_model, cfg.d_ff
    return {
        "wi": ParamSpec((n, D, F), cfg.dtype, (None, None, "model"),
                        fan_in=D),
        "wo": ParamSpec((n, F, D), cfg.dtype, (None, "model", None),
                        fan_in=F),
    }


def param_structure(cfg: ArchConfig):
    D, dt = cfg.d_model, cfg.dtype
    ne, nd = cfg.enc_layers, cfg.n_layers
    ln = lambda n: ParamSpec((n, D), dt, (None, None), init="ones")  # noqa
    return {
        "embedding": ParamSpec((cfg.padded_vocab, D), dt, ("model", None),
                               init="embed"),
        "enc_pos": ParamSpec((cfg.enc_seq, D), dt, (None, None),
                             init="small"),
        "encoder": {"ln1": ln(ne), "attn": _attn(cfg, ne),
                    "ln2": ln(ne), "mlp": _mlp(cfg, ne)},
        "enc_final_ln": ParamSpec((D,), dt, (None,), init="ones"),
        "decoder": {"ln1": ln(nd), "self_attn": _attn(cfg, nd),
                    "lnx": ln(nd), "cross_attn": _xattn(cfg, nd),
                    "ln2": ln(nd), "mlp": _mlp(cfg, nd)},
        "final_ln": ParamSpec((D,), dt, (None,), init="ones"),
    }


def cache_structure(cfg: ArchConfig, batch: int, max_len: int):
    K, hd, dt, nd = cfg.n_kv_heads, cfg.head_dim, cfg.dtype, cfg.n_layers

    def kv(length):
        return {
            "k": ParamSpec((nd, batch, length, K, hd), dt,
                           (None, "batch", None, None, None), init="zeros"),
            "v": ParamSpec((nd, batch, length, K, hd), dt,
                           (None, "batch", None, None, None), init="zeros"),
        }

    return {
        "len": ParamSpec((batch,), jnp.int32, ("batch",), init="zeros"),
        "self_kv": kv(max_len),
        "cross_kv": kv(cfg.enc_seq),
    }


# ----------------------------------------------------------------- encode --

def encode(cfg: ArchConfig, params, frames):
    """frames: [B, enc_seq, D] stub embeddings -> encoder states."""
    x = frames.astype(cfg.dtype) + params["enc_pos"][None]
    x = shard(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])

    def layer(x, p):
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        qkv = h @ p["attn"]["wqkv"]
        qkv = shard(qkv, "batch", "model", None)
        H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
        B, S = h.shape[:2]
        q = L.rope(q.reshape(B, S, H, hd), positions, cfg.rope_theta)
        k = L.rope(k.reshape(B, S, K, hd), positions, cfg.rope_theta)
        v = v.reshape(B, S, K, hd)
        o = L.flash_attention(q, k, v, causal=False)  # bidirectional
        x = x + shard(o.reshape(B, S, H * hd) @ p["attn"]["wo"],
                      "batch", None, None)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + L.gelu_mlp(p["mlp"], h), None

    if util.remat_enabled():
        layer = jax.checkpoint(
            layer, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = util.scan(layer, x, params["encoder"])
    return L.rms_norm(x, params["enc_final_ln"], cfg.norm_eps)


def _cross_attention(cfg, p, h, cross_k, cross_v):
    B, S, D = h.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(B, S, H, hd)
    o = L.flash_attention(q, cross_k, cross_v, causal=False)
    return shard(o.reshape(B, S, H * hd) @ p["wo"], "batch", None, None)


def build_cross_kv(cfg: ArchConfig, params, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    B, Se, D = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.head_dim

    def layer(_, p):
        kv = enc_out @ p["cross_attn"]["wkv"]
        k, v = jnp.split(kv, 2, axis=-1)
        return None, (k.reshape(B, Se, K, hd), v.reshape(B, Se, K, hd))

    _, (ks, vs) = util.scan(layer, None, params["decoder"])
    return {"k": ks, "v": vs}  # [nd, B, Se, K, hd]


def _decoder_blocks(cfg, params, x, *, positions, cross_kv, cache=None):
    def block(carry, inp):
        x, step_len = carry
        if cache is None:
            p, (ck, cv) = inp
            self_cache = None
        else:
            p, (ck, cv), (sk, sv) = inp
            self_cache = {"k": sk, "v": sv, "len": step_len}
        h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
        h, kv_new = L.gqa_attention(cfg, p["self_attn"], h,
                                    positions=positions, cache=self_cache)
        x = x + h
        h = L.rms_norm(x, p["lnx"], cfg.norm_eps)
        x = x + _cross_attention(cfg, p["cross_attn"], h, ck, cv)
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        x = x + L.gelu_mlp(p["mlp"], h)
        out = None if kv_new is None else (kv_new["k"], kv_new["v"])
        return (x, step_len), out

    if cache is None:
        blk = block
        if util.remat_enabled():
            blk = jax.checkpoint(
                blk, policy=jax.checkpoint_policies.nothing_saveable)
        (x, _), _ = util.scan(blk, (x, None),
                              (params["decoder"],
                               (cross_kv["k"], cross_kv["v"])))
        return x, None
    (x, _), new_kv = util.scan(
        block, (x, cache["len"]),
        (params["decoder"], (cross_kv["k"], cross_kv["v"]),
         (cache["self_kv"]["k"], cache["self_kv"]["v"])))
    new_cache = {"len": cache["len"] + x.shape[1],
                 "self_kv": {"k": new_kv[0], "v": new_kv[1]},
                 "cross_kv": cross_kv}
    return x, new_cache


def forward_hidden(cfg: ArchConfig, params, batch):
    enc_out = encode(cfg, params, batch["frames"])
    cross_kv = build_cross_kv(cfg, params, enc_out)
    x = L.embed_tokens(params, batch["tokens"], cfg.d_model)
    positions = jnp.arange(x.shape[1])
    x, _ = _decoder_blocks(cfg, params, x, positions=positions,
                           cross_kv=cross_kv)
    return L.rms_norm(x, params["final_ln"], cfg.norm_eps)


def forward_train(cfg: ArchConfig, params, batch):
    """batch: frames [B, enc_seq, D], tokens/labels/mask [B, S]."""
    x = forward_hidden(cfg, params, batch)
    return L.chunked_cross_entropy(_logits_fn(cfg, params), x,
                                   batch["labels"], batch["mask"])


def forward_logits(cfg: ArchConfig, params, batch):
    return _logits_fn(cfg, params)(forward_hidden(cfg, params, batch))


def decode_step(cfg: ArchConfig, params, cache, tokens):
    x = L.embed_tokens(params, tokens, cfg.d_model)
    positions = cache["len"][:, None] + jnp.arange(tokens.shape[1])[None]
    x, new_cache = _decoder_blocks(cfg, params, x, positions=positions,
                                   cross_kv=cache["cross_kv"], cache=cache)
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    return _logits_fn(cfg, params)(x), new_cache
