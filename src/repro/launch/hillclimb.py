import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Perf hillclimb driver: re-run a dry-run cell under optimization levers
and record hypothesis -> before -> after (EXPERIMENTS.md §Perf).

Levers (env-driven, so the baseline stays reproducible):
  attn_bf16   REPRO_ATTN_BF16=1   bf16 QK/PV matmuls, f32 softmax state
  fused_attn  REPRO_FUSED_ATTN=1  Pallas-flash accounting: kernel-internal
                                  tensors VMEM-resident
  chunk<k>    REPRO_FLASH_CHUNK=k larger KV chunks (fewer accumulator
                                  read/write rounds)

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch X --shape Y \
      --levers attn_bf16,fused_attn [--tag iter1]
"""
import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

LEVER_ENV = {
    "attn_bf16": ("REPRO_ATTN_BF16", "1"),
    "fused_attn": ("REPRO_FUSED_ATTN", "1"),
    "ar_bf16": ("REPRO_AR_BF16", "1"),
    "moe_bf16": ("REPRO_MOE_BF16_DISPATCH", "1"),
    "moe_a2a": ("REPRO_MOE_A2A", "1"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--levers", default="")
    ap.add_argument("--tag", default="opt")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    levers = [l for l in args.levers.split(",") if l]
    for l in levers:
        if l.startswith("chunk"):
            os.environ["REPRO_FLASH_CHUNK_OPT"] = l[5:]
        else:
            k, v = LEVER_ENV[l]
            os.environ[k] = v

    rec = run_cell(args.arch, args.shape, multi_pod=False,
                   outdir=os.path.join(args.out, args.tag))
    rl = rec.get("roofline", {})
    print(json.dumps({
        "tag": args.tag, "levers": levers,
        "compute_ms": rl.get("compute_s", 0) * 1e3,
        "memory_ms": rl.get("memory_s", 0) * 1e3,
        "collective_ms": rl.get("collective_s", 0) * 1e3,
        "bound": rl.get("bound"), "mfu": rl.get("mfu"),
        "step_ms": rl.get("step_s", 0) * 1e3,
    }, indent=1))


if __name__ == "__main__":
    main()
