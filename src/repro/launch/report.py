"""Aggregate dry-run artifacts into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
Prints the §Dry-run and §Roofline markdown tables to stdout.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCH_IDS, SHAPES


def load(dirname: str, mesh: str) -> dict:
    out = {}
    for f in glob.glob(os.path.join(dirname, mesh, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        out[(r["arch"], r["shape"])] = r
    return out


def fmt_bytes(x) -> str:
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def dryrun_table(recs: dict, mesh: str) -> str:
    lines = [
        f"### Mesh `{mesh}`",
        "",
        "| arch | shape | status | compile s | temp bytes/dev | "
        "HLO GFLOPs/dev | collectives (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_IDS:
        for s in SHAPES:
            r = recs.get((a, s))
            if r is None:
                lines.append(f"| {a} | {s} | MISSING | | | | |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | skipped (full attention at "
                             f"500k) | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | FAILED: "
                             f"{r.get('error', '?')[:60]} | | | | |")
                continue
            mem = r.get("memory_analysis", {})
            temp = fmt_bytes(mem.get("temp_size_in_bytes"))
            fl = r["roofline"]["hlo_flops_per_chip"] / 1e9
            c = r.get("collectives", {}).get("counts", {})
            cc = "/".join(str(c.get(k, 0)) for k in
                          ("all-gather", "all-reduce", "reduce-scatter",
                           "all-to-all", "collective-permute"))
            lines.append(f"| {a} | {s} | ok | {r.get('compile_s', 0):.0f} | "
                         f"{temp} | {fl:.0f} | {cc} |")
    return "\n".join(lines)


def roofline_table(recs: dict) -> str:
    lines = [
        "| arch | shape | compute ms | memory ms | collective ms | bound | "
        "MODEL_FLOPS/HLO | MFU @ bound | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "compute": "reduce recompute (remat policy) / quantized matmuls",
        "memory": "fuse attention (Pallas flash) + bf16 score matmuls",
        "collective": "reshard to cut all-gathers; overlap with compute; "
                      "int8-EF cross-pod grads",
    }
    for a in ARCH_IDS:
        for s in SHAPES:
            r = recs.get((a, s))
            if r is None or r["status"] != "ok":
                continue
            rl = r["roofline"]
            lines.append(
                f"| {a} | {s} | {rl['compute_s']*1e3:.1f} | "
                f"{rl['memory_s']*1e3:.1f} | {rl['collective_s']*1e3:.1f} | "
                f"**{rl['bound']}** | {rl['useful_flops_fraction']:.2f} | "
                f"{rl['mfu']:.1%} | {levers[rl['bound']]} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    single = load(args.dir, "pod16x16")
    multi = load(args.dir, "pod2x16x16")
    print("## §Dry-run\n")
    print(dryrun_table(single, "pod16x16 (256 chips)"))
    print()
    if multi:
        print(dryrun_table(multi, "pod2x16x16 (512 chips, multi-pod)"))
        print()
    print("## §Roofline (single-pod, per chip)\n")
    print(roofline_table(single))


if __name__ == "__main__":
    main()
