"""launch subpackage of the repro framework."""
