"""`input_specs()`: ShapeDtypeStruct stand-ins for every model input --
weak-type-correct, shardable, no device allocation (the dry-run pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs import ShapeSpec
from repro.dist.sharding import resolve_pspec
from repro.models import registry
from repro.models.base import ArchConfig, abstract_params


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if cfg.family == "vlm":
        S_text = S - cfg.enc_seq
        specs["prefix_embeds"] = _sds((B, cfg.enc_seq, cfg.d_model),
                                      jnp.bfloat16)
    else:
        S_text = S
    if cfg.family == "audio":
        specs["frames"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    specs["tokens"] = _sds((B, S_text), jnp.int32)
    specs["labels"] = _sds((B, S_text), jnp.int32)
    specs["mask"] = _sds((B, S_text), jnp.float32)
    return specs


def batch_pspecs(cfg: ArchConfig, specs: dict) -> dict:
    """Symbolic pspecs: batch dim over the data axes, rest replicated."""
    out = {}
    for k, v in specs.items():
        out[k] = ("batch",) + (None,) * (len(v.shape) - 1)
    return out


def batch_shardings(cfg: ArchConfig, specs: dict, mesh: Mesh) -> dict:
    return {k: NamedSharding(mesh, resolve_pspec(ps, mesh, specs[k].shape))
            for k, ps in batch_pspecs(cfg, specs).items()}


def decode_token_specs(cfg: ArchConfig, shape: ShapeSpec):
    return _sds((shape.global_batch, 1), jnp.int32)


def decode_cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract cache for a decode step at context length seq_len."""
    fns = registry.model_fns(cfg)
    structure = fns.cache_structure(cfg, shape.global_batch, shape.seq_len)
    return structure  # ParamSpec pytree; materialize via abstract_params


def cache_abstract(structure):
    return abstract_params(structure)
