"""Production mesh definition.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to build these meshes on a CPU host.

  single-pod: (data=16, model=16)        = 256 chips (one v5e pod)
  multi-pod : (pod=2, data=16, model=16) = 512 chips

Axis semantics: `pod` -- pure data parallelism across pods (gradient
all-reduce over DCI); `data` -- in-pod DP + ZeRO-1/FSDP/EP; `model` --
TP/SP.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-configurations)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
