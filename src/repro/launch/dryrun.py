import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory/cost/collective analysis.

MUST be run as its own process (the XLA flag above locks the device count
at first jax init -- which is why it is set before any other import).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama_1_1b \
      --shape train_4k [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    ARCH_IDS, SHAPES, cell_runnable, get_config,
)
from repro.dist import hlo_analysis, hlo_bytes, roofline  # noqa: E402
from repro.dist.sharding import use_mesh  # noqa: E402
from repro.launch import inputs as I  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import registry  # noqa: E402
from repro.models.base import (  # noqa: E402
    abstract_params, param_bytes, param_shardings,
)
from repro.optim import adamw  # noqa: E402
from repro.train.step import (  # noqa: E402
    make_prefill_step, make_serve_step, make_train_step,
)


def _cost_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` returns a list of per-program dicts on
    jax<=0.4.x CPU backends and a bare dict on newer ones -- normalize."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _abstract_moments(structure):
    ab = abstract_params(structure)
    mom = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ab)
    return {"mu": mom, "nu": mom,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ----------------------------------------------------- counting pass -------
# XLA cost analysis counts while bodies ONCE (not x trip count), so the
# scanned full-depth program under-reports FLOPs/bytes/collectives. The
# counting pass lowers depth-1 and depth-2 configs with all scans UNROLLED
# (REPRO_UNROLL_SCANS=1: no while ops => exact costs) and extrapolates
# linearly in depth -- exact, since blocks are homogeneous.

import dataclasses as _dc  # noqa: E402


def _period(cfg) -> int:
    if cfg.family == "hybrid":
        return len(cfg.block_pattern)
    if cfg.n_experts and cfg.moe_every == 2:
        return 2
    return 1


def _n_full_blocks(cfg) -> int:
    if cfg.family == "hybrid":
        per = len(cfg.block_pattern)
        return cfg.n_layers // per
    return cfg.n_layers // _period(cfg)


def depth_config(cfg, k: int):
    """Same widths, k repeating blocks (tail kept for hybrids)."""
    per = _period(cfg)
    if cfg.family == "hybrid":
        tail = cfg.n_layers % per
        return _dc.replace(cfg, n_layers=per * k + tail)
    if cfg.family == "audio":
        return _dc.replace(cfg, n_layers=k, enc_layers=k)
    return _dc.replace(cfg, n_layers=per * k)


def _count_once(cfg_k, shape, mesh):
    os.environ["REPRO_UNROLL_SCANS"] = "1"
    os.environ["REPRO_FLASH_CHUNK"] = str(
        max(512, shape.seq_len // 32))
    try:
        jitted, args = build_cell(cfg_k, shape, mesh)
        compiled = jitted.lower(*args).compile()
        cost = _cost_dict(compiled)
        txt = compiled.as_text()
        stats = hlo_analysis.collect_collectives(txt, default_group=16)
        from repro import util as _util
        scope = "flash_internal" if _util.fused_attention_accounting() \
            else None
        return {"flops": float(cost.get("flops", 0.0)),
                "bytes": hlo_bytes.boundary_bytes(txt, exclude_scope=scope),
                "bytes_hlo_raw": float(cost.get("bytes accessed", 0.0)),
                "wire_bytes": stats.total_wire_bytes,
                "wire_detail": stats.wire_bytes,
                "counts": stats.counts}
    finally:
        os.environ.pop("REPRO_UNROLL_SCANS", None)
        os.environ.pop("REPRO_FLASH_CHUNK", None)


def counting_pass(cfg, shape, mesh) -> dict:
    """Exact full-depth HLO costs via depth-1/2 unrolled lowerings."""
    nb = _n_full_blocks(cfg)
    c1 = _count_once(depth_config(cfg, 1), shape, mesh)
    c2 = _count_once(depth_config(cfg, 2), shape, mesh)
    out = {}
    for key in ("flops", "bytes", "wire_bytes"):
        out[key] = c1[key] + (nb - 1) * (c2[key] - c1[key])
    out["per_block"] = {k: c2[k] - c1[k]
                        for k in ("flops", "bytes", "wire_bytes")}
    out["depth1"] = c1
    out["depth2"] = c2
    out["n_full_blocks"] = nb
    return out


def build_cell(cfg, shape, mesh, *, remat=True, zero1=True):
    """Returns (jitted_fn, example_args) for one cell."""
    fns = registry.model_fns(cfg)
    structure = fns.param_structure(cfg)
    params_abs = abstract_params(structure)
    params_sh = param_shardings(structure, mesh)

    if shape.kind == "train":
        opt = adamw.AdamWConfig()
        step = make_train_step(cfg, opt, remat=remat)
        opt_abs = _abstract_moments(structure)
        opt_sh = adamw.moment_shardings(structure, mesh, zero1=zero1)
        bspecs = I.train_batch_specs(cfg, shape)
        bsh = I.batch_shardings(cfg, bspecs, mesh)
        jitted = jax.jit(step, in_shardings=(params_sh, opt_sh, bsh),
                         out_shardings=(params_sh, opt_sh, None))
        return jitted, (params_abs, opt_abs, bspecs)

    if shape.kind == "prefill":
        step = make_prefill_step(cfg)
        bspecs = I.train_batch_specs(cfg, shape)
        bspecs.pop("labels"), bspecs.pop("mask")
        bsh = I.batch_shardings(cfg, bspecs, mesh)
        jitted = jax.jit(step, in_shardings=(params_sh, bsh),
                         out_shardings=None)
        return jitted, (params_abs, bspecs)

    # decode
    step = make_serve_step(cfg)
    cache_struct = fns.cache_structure(cfg, shape.global_batch,
                                       shape.seq_len)
    cache_abs = abstract_params(cache_struct)
    cache_sh = param_shardings(cache_struct, mesh)
    tok = I.decode_token_specs(cfg, shape)
    tok_sh = I.batch_shardings(cfg, {"tokens": tok}, mesh)["tokens"]
    jitted = jax.jit(step, in_shardings=(params_sh, cache_sh, tok_sh),
                     out_shardings=(None, cache_sh))
    return jitted, (params_abs, cache_abs, tok)


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             outdir: str, verbose: bool = True, resume: bool = False,
             counting: bool = True) -> dict:
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "status": "pending"}
    if resume:
        path = os.path.join(outdir, mesh_name,
                            f"{arch_id}__{shape_name}.json")
        if os.path.exists(path):
            with open(path) as f:
                prev = json.load(f)
            if prev.get("status") in ("ok", "skipped"):
                if verbose:
                    print(f"resume: {arch_id} x {shape_name} already "
                          f"{prev['status']}")
                return prev
    if not cell_runnable(arch_id, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k requires sub-quadratic attention; "
                         "this arch is pure full-attention (DESIGN.md §4)")
        _save(rec, outdir)
        return rec

    cfg = get_config(arch_id)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with use_mesh(mesh):
            jitted, args = build_cell(cfg, shape, mesh)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            print(mem)  # proves it fits
            cost = _cost_dict(compiled)
            print({k: cost.get(k) for k in ("flops", "bytes accessed")})
            hlo = compiled.as_text()
    except Exception as e:  # noqa: BLE001
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        _save(rec, outdir)
        if verbose:
            print(f"FAILED {arch_id} x {shape_name} [{mesh_name}]: "
                  f"{rec['error']}")
        return rec

    stats = hlo_analysis.collect_collectives(hlo, default_group=16)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mf = registry.model_flops(cfg, tokens, train=(shape.kind == "train"))

    # exact full-depth costs (scan-aware counting pass) -- inside the mesh
    # context so activation sharding constraints stay active. The roofline
    # table is single-pod only, so multi-pod runs may skip it.
    if counting:
        try:
            with use_mesh(mesh):
                counted = counting_pass(cfg, shape, mesh)
            flops, bytes_acc = counted["flops"], counted["bytes"]
            wire = counted["wire_bytes"]
            count_status = "counted"
        except Exception as e:  # noqa: BLE001
            counted = {"error": f"{type(e).__name__}: {e}"}
            flops = float(cost.get("flops", 0.0))
            bytes_acc = float(cost.get("bytes accessed", 0.0))
            wire = stats.total_wire_bytes
            count_status = "fallback_scan_once"
    else:
        counted = {"skipped": "multi-pod run (roofline is single-pod)"}
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        wire = stats.total_wire_bytes
        count_status = "not_counted"

    rl = roofline.Roofline(
        arch=arch_id, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=bytes_acc,
        collective_wire_bytes_per_chip=wire,
        model_flops_total=mf,
        collective_detail={"counts": stats.counts,
                           "wire_bytes": stats.wire_bytes,
                           "count_status": count_status},
    )
    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)
    fns = registry.model_fns(cfg)
    pbytes = param_bytes(fns.param_structure(cfg))

    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        param_bytes_total=pbytes,
        param_bytes_per_chip_modelsharded=pbytes // 16,
        memory_analysis=mem_fields,
        cost_analysis={k: cost.get(k) for k in ("flops", "bytes accessed",
                                                "optimal_seconds")
                       if k in cost},
        collectives={"counts": stats.counts,
                     "bytes": stats.bytes_moved,
                     "wire_bytes": stats.wire_bytes},
        counting=counted,
        roofline=rl.to_dict(),
    )
    if verbose:
        print(roofline.summarize(rl))
    _save(rec, outdir)
    return rec


def _save(rec: dict, outdir: str):
    d = os.path.join(outdir, rec["mesh"])
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{rec['arch']}__{rec['shape']}.json"),
              "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact already says ok/skipped")
    ap.add_argument("--no-counting", action="store_true",
                    help="skip the depth-1/2 counting pass (multi-pod runs)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run needs the forced 512-device host platform")

    cells = ([(args.arch, args.shape)] if not args.all else
             [(a, s) for a in ARCH_IDS for s in SHAPES])
    results = []
    for arch_id, shape_name in cells:
        print(f"=== {arch_id} x {shape_name} "
              f"[{'multi-pod' if args.multi_pod else 'single-pod'}] ===",
              flush=True)
        results.append(run_cell(arch_id, shape_name,
                                multi_pod=args.multi_pod, outdir=args.out,
                                resume=args.resume,
                                counting=not args.no_counting))
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    fail = [r for r in results if r["status"] == "failed"]
    print(f"\n{ok} ok / {sk} skipped / {len(fail)} failed")
    for r in fail:
        print(f"  FAILED: {r['arch']} x {r['shape']}: {r['error']}")
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
