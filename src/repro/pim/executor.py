"""Cycle-counting micro-op executor for the CSA simulator.

Runs `repro.pim.microcode.Program`s functionally over the same substrate the
bitline/bitplane simulators model: BS plane ops replay the multi-row
activation primitives of `repro.pim.array_sim`, BP word ops replay the
word-level peripheral ALU over LSB-first word lanes of a row. Cycle charges
come from the Table-2 contract baked into the ISA (`op_cycles`), so

    executed semantics  <->  integer references      (functional oracle)
    executed cycles     <->  `repro.core.cost_model`  (differential oracle)

are both checked by tests/test_microcode.py.

The per-op step functions are pure jnp, so a whole program lowers to one
XLA computation: `run_batched` wraps the unrolled program in
``jax.jit(jax.vmap(...))`` and executes a kernel across many simulated
arrays (leading axis) in a single jitted call -- the throughput mode used by
benchmarks/executor_bench.py.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.pim.array_sim import CSArray, activate, row_to_words, words_to_row
from repro.pim.bitserial import full_adder, pack
from repro.pim.microcode import Op, Program
from repro.pim.transpose_sim import planes_to_row, row_to_planes
from repro.core.cost_model import Layout


class ExecState(NamedTuple):
    """Machine state threaded through the ops (a single CSA's view)."""

    cells: jax.Array  # (rows, cols) bool -- the cell core
    carry: jax.Array  # (cols,) bool -- the BS peripheral carry latch
    acc: jax.Array    # () uint32 -- the BS peripheral reduction accumulator


@dataclasses.dataclass(frozen=True)
class ExecResult:
    array: CSArray
    carry: jax.Array
    acc: jax.Array
    cycles: int


def _lane_mask(width: int) -> jnp.ndarray:
    return jnp.uint32((1 << width) - 1)


def _mult_lo_hi(a: jax.Array, b: jax.Array, width: int):
    """(a * b) split into `width`-bit lo/hi halves, exact up to width 32.

    Products of 32-bit lanes need 64 bits, which x64-disabled jax cannot
    hold -- so the high half comes from the standard 16-bit-limb mulhi
    (every intermediate below fits uint32 exactly).
    """
    m16 = jnp.uint32(0xFFFF)
    a0, a1 = a & m16, a >> 16
    b0, b1 = b & m16, b >> 16
    t = a0 * b0
    k = t >> 16
    w0 = t & m16
    t = a1 * b0 + k
    w1, w2 = t & m16, t >> 16
    t = a0 * b1 + w1
    k = t >> 16
    hi32 = a1 * b1 + w2 + k
    lo32 = w0 | ((t & m16) << 16)
    if width == 32:
        return lo32, hi32
    m = _lane_mask(width)
    lo = lo32 & m
    hi = ((lo32 >> width) | (hi32 << (32 - width))) & m
    return lo, hi


def _shift_lane(x: jax.Array, alu: str, k: int, width: int) -> jax.Array:
    """k-bit shift within `width`-bit lanes (l / rl logical / ra arithmetic)."""
    m = _lane_mask(width)
    if k == 0:
        return x
    if alu == "l":
        return (x << k) & m
    if alu == "rl":
        return x >> k
    if alu == "ra":
        sign = (x >> (width - 1)) & 1
        fill = jnp.uint32(((1 << width) - 1) ^ ((1 << (width - k)) - 1))
        return (x >> k) | jnp.where(sign.astype(bool), fill, jnp.uint32(0))
    raise ValueError(f"unknown shift alu {alu!r}")


def _apply_op(op: Op, st: ExecState, width: int) -> ExecState:
    cells, carry, acc = st
    cols = cells.shape[1]

    # ----- BS plane ops -----------------------------------------------------
    if op.kind == "row_op":
        res = activate(op.alu, cells, op.src0, op.src1, invert1=op.invert1)
        return st._replace(cells=cells.at[op.dst].set(res))
    if op.kind == "not":
        return st._replace(
            cells=cells.at[op.dst].set(jnp.logical_not(cells[op.src0])))
    if op.kind == "copy":
        return st._replace(cells=cells.at[op.dst].set(cells[op.src0]))
    if op.kind == "const":
        return st._replace(cells=cells.at[op.dst].set(
            jnp.full((cols,), bool(op.aux))))
    if op.kind == "setc":
        return st._replace(carry=jnp.full((cols,), bool(op.aux)))
    if op.kind == "fa":
        a = cells[op.src0]
        b = cells[op.src1] if op.src1 is not None \
            else jnp.zeros((cols,), bool)
        if op.mask is not None:           # serial-multiplier AND gate
            b = jnp.logical_and(b, cells[op.mask])
        if op.invert1:                    # complementary bitline
            b = jnp.logical_not(b)
        s, cnew = full_adder(a, b, carry)
        cells = cells.at[op.dst].set(s)
        if op.cout is not None:           # carry-save writeback
            cells = cells.at[op.cout].set(cnew)
        return ExecState(cells, cnew, acc)
    if op.kind == "mux":
        c = cells[op.src0]
        res = jnp.logical_or(jnp.logical_and(cells[op.src1], c),
                             jnp.logical_and(cells[op.src2],
                                             jnp.logical_not(c)))
        return st._replace(cells=cells.at[op.dst].set(res))
    if op.kind == "shift":
        # renaming in hardware; the simulator moves the block (aux rows)
        block = cells[op.src0:op.src0 + op.aux]
        return st._replace(cells=cells.at[op.dst:op.dst + op.aux].set(block))
    if op.kind == "col_reduce":
        w = jnp.uint32(1) << jnp.uint32(op.aux)
        return st._replace(
            acc=acc + w * jnp.sum(cells[op.src0].astype(jnp.uint32)))

    # ----- transposes -------------------------------------------------------
    if op.kind == "t_bp2bs":
        planes = row_to_planes(cells[op.src0], width)      # (width, lanes)
        lanes = planes.shape[1]
        return st._replace(
            cells=cells.at[op.dst:op.dst + width, :lanes].set(planes))
    if op.kind == "t_bs2bp":
        lanes = cols // width
        row = planes_to_row(cells[op.src0:op.src0 + width, :lanes], cols)
        return st._replace(cells=cells.at[op.dst].set(row))

    # ----- BP word ops ------------------------------------------------------
    m = _lane_mask(width)

    def words(r):
        return row_to_words(cells[r], width)

    def put(r, w):
        return st._replace(cells=cells.at[r].set(
            words_to_row(w & m, width, cols)))

    if op.kind == "wadd":
        return put(op.dst, words(op.src0) + words(op.src1))
    if op.kind == "wsub":
        return put(op.dst, words(op.src0) - words(op.src1))
    if op.kind == "wmult":
        lo, hi = _mult_lo_hi(words(op.src0), words(op.src1), width)
        cells2 = cells.at[op.dst].set(words_to_row(lo, width, cols))
        cells2 = cells2.at[op.aux].set(words_to_row(hi, width, cols))
        return st._replace(cells=cells2)
    if op.kind == "wlogic":
        a, b = words(op.src0), words(op.src1)
        if op.invert1:
            b = ~b & m
        res = {"and": a & b, "or": a | b, "xor": a ^ b}[op.alu]
        return put(op.dst, res)
    if op.kind == "wnot":
        return put(op.dst, ~words(op.src0) & m)
    if op.kind == "wcopy":
        return put(op.dst, words(op.src0))
    if op.kind == "wconst":
        return put(op.dst, jnp.full((cols // width,), op.aux, jnp.uint32))
    if op.kind == "wshift":
        return put(op.dst, _shift_lane(words(op.src0), op.alu, op.aux, width))
    if op.kind == "tree_stage":
        w = words(op.src0)
        half = op.aux
        folded = w.at[:half].set(w[:half] + w[half:2 * half])
        folded = folded.at[half:2 * half].set(0)
        return put(op.src0, folded)

    raise AssertionError(f"unhandled op kind {op.kind!r}")


def make_runner(program: Program):
    """Pure function cells -> ExecState unrolling `program` (jit-friendly)."""
    ops, width = program.ops, program.width

    def run(cells: jax.Array) -> ExecState:
        cols = cells.shape[1]
        st = ExecState(cells, jnp.zeros((cols,), bool), jnp.uint32(0))
        for op in ops:
            st = _apply_op(op, st, width)
        return st

    return run


def execute(program: Program,
            array: Union[CSArray, jax.Array]) -> ExecResult:
    """Run `program` on one array eagerly; cycle count is static."""
    cells = array.cells if isinstance(array, CSArray) else array
    if cells.shape[0] < program.rows:
        raise ValueError(
            f"{program.name} needs {program.rows} rows, array has "
            f"{cells.shape[0]}")
    st = make_runner(program)(cells)
    return ExecResult(CSArray(st.cells), st.carry, st.acc, program.cycles)


#: LRU of compiled `jit(vmap(run))` callables, keyed by the (hashable)
#: program.  Machine-level partitioning can lower thousands of distinct
#: per-partition programs; the bound keeps the host-side compilation
#: cache from growing without limit (evicted programs just recompile).
_BATCHED_CACHE: "OrderedDict" = OrderedDict()
_BATCHED_CACHE_LIMIT = 64
_BATCHED_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def set_batched_cache_limit(limit: int) -> int:
    """Resize the batched-runner LRU (evicting down if needed); returns
    the previous limit."""
    global _BATCHED_CACHE_LIMIT
    if limit < 1:
        raise ValueError(f"cache limit must be >= 1, got {limit}")
    prev, _BATCHED_CACHE_LIMIT = _BATCHED_CACHE_LIMIT, limit
    while len(_BATCHED_CACHE) > limit:
        _BATCHED_CACHE.popitem(last=False)
        _BATCHED_CACHE_STATS["evictions"] += 1
    return prev


def batched_cache_stats() -> dict:
    """Hit/miss/eviction counters plus current size and limit."""
    return dict(_BATCHED_CACHE_STATS, size=len(_BATCHED_CACHE),
                limit=_BATCHED_CACHE_LIMIT)


def clear_batched_cache() -> None:
    _BATCHED_CACHE.clear()
    _BATCHED_CACHE_STATS.update(hits=0, misses=0, evictions=0)


def run_batched(program: Program, cells: jax.Array) -> ExecState:
    """Run `program` across many arrays -- cells (n_arrays, rows, cols) --
    in ONE jitted call (`jit(vmap(run))`, compiled once per program).

    Programs are frozen/hashable, so the cache keys on the full program
    (including its ops), not just its name -- hand-built programs that
    share a name never collide."""
    fn = _BATCHED_CACHE.get(program)
    if fn is None:
        _BATCHED_CACHE_STATS["misses"] += 1
        fn = jax.jit(jax.vmap(make_runner(program)))
        _BATCHED_CACHE[program] = fn
        while len(_BATCHED_CACHE) > _BATCHED_CACHE_LIMIT:
            _BATCHED_CACHE.popitem(last=False)
            _BATCHED_CACHE_STATS["evictions"] += 1
    else:
        _BATCHED_CACHE_STATS["hits"] += 1
        _BATCHED_CACHE.move_to_end(program)
    return fn(cells)


# --------------------------------------------------------------------------
# Operand staging helpers (the load/readout phases of the cost model)
# --------------------------------------------------------------------------

def init_cells(program: Program, n: int, rows: Optional[int] = None,
               cols: Optional[int] = None) -> jax.Array:
    """Blank cell array sized for `program` over `n` elements.

    BS: one element per column. BP: one element per `width`-bit lane.
    """
    if cols is None:
        cols = n if program.layout is Layout.BS else n * program.width
    return jnp.zeros((rows or program.rows, cols), bool)


def set_input(cells: jax.Array, program: Program, name: str,
              values) -> jax.Array:
    """Stage an operand (unsigned integer view) into its program region."""
    start, n_rows = program.input_region(name)
    vals = jnp.asarray(values, jnp.uint32)
    if program.layout is Layout.BS:
        planes = pack(vals, n_rows)          # (n_rows, n)
        return cells.at[start:start + n_rows, :planes.shape[1]].set(planes)
    row = words_to_row(vals, program.width, cells.shape[1])
    return cells.at[start].set(row)


def get_output(state_cells: jax.Array, program: Program, name: str,
               n: int) -> jax.Array:
    """Read an output region back: BS -> (n_rows, n) planes, BP -> words."""
    start, n_rows = program.output_region(name)
    if program.layout is Layout.BS:
        return state_cells[start:start + n_rows, :n]
    return row_to_words(state_cells[start], program.width)[:n]
