"""Micro-op ISA for the computing-SRAM substrate (paper Table 2 contract).

A :class:`Program` is a static sequence of :class:`Op`s addressing physical
rows of one CSA. Two register files share the cell core:

* **BS (plane) ops** treat each row as one bitplane of a vertical operand
  (one element per column).  A multi-row activation plus writeback is one
  cycle; the full-adder step (the BS peripheral's 1-bit serial adder) is one
  cycle; shifts are row *renaming* and cost nothing; the synthesized MUX is
  the 4-cycle AND/OR/NOT sequence of Table 2.
* **BP (word) ops** treat each row as ``cols / width`` LSB-first word lanes
  driven by the word-level peripheral ALU: logic/ADD are 1 cycle, SUB 2,
  MULT ``width + 2``, and a k-bit shift costs k cycles.

Cycle charges are *static* per op (no data dependence), so a program's cost
is known at build time -- `Program.cycles` is the executable counterpart of
the analytic `repro.core.cost_model` compute formulas, and
`repro.pim.executor` replays the same ops functionally so semantics and
cycles are validated together (see tests/test_microcode.py).

Charging conventions (documented deviations live in DESIGN.md Sec. 8):

* ``const`` / ``wconst`` rows are free: constant planes and mask words are
  prepared by the periphery during the load phase, which the kernel cost
  model charges separately (`CycleCost.load`).
* ``setc`` (carry-latch preset) is free: the carry flip-flop lives in the
  sense amplifier, not in a row.
* ``fa`` may write its carry out to a row (`cout`) in the same cycle as the
  sum: the serial multiplier's carry-save writeback drives the row pair
  from the same activation.
* ``fa`` takes an optional ``mask`` plane ANDed into the b operand for
  free -- the AND gate in front of a serial-multiplier adder cell.
* ``invert1`` on row ops and ``invert_b`` on ``fa`` read the second operand
  through the complementary bitline (free hardware inversion).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.cost_model import BS_MUX1, Layout

#: op kind -> cycle charge (None = computed per-op, see `op_cycles`)
CYCLE_TABLE = {
    # --- BS plane ops -------------------------------------------------------
    "row_op": 1,     # multi-row activation + writeback (alu: and/or/nor/xor)
    "not": 1,        # complementary-bitline read + writeback
    "copy": 1,       # read + writeback
    "const": 0,      # peripheral row clear/set (charged to load)
    "setc": 0,       # carry-latch preset (aux = 0/1)
    "fa": 1,         # 1-bit serial full adder (Table 2: add1 = 1)
    "mux": BS_MUX1,  # synthesized per-plane MUX (Table 2: 4)
    "shift": 0,      # shift-as-renaming (Table 2: shift = 0)
    "col_reduce": 1,  # peripheral accumulator += 2^aux * popcount(row)
    # --- transposes (on-chip transpose unit; rows_read + core + written) ----
    "t_bp2bs": None,
    "t_bs2bp": None,
    # --- BP word ops --------------------------------------------------------
    "wadd": 1,
    "wsub": 2,
    "wmult": None,   # width + 2 (Table 2)
    "wlogic": 1,     # alu: and/or/xor (+ invert1 for the free complement)
    "wnot": 1,
    "wcopy": 1,
    "wconst": 0,     # mask/constant word row (charged to load)
    "wshift": None,  # k cycles for a k-bit shift (alu: l / rl / ra)
    "tree_stage": None,  # reduction fold: 1 (adjacent pairs) or 2 (move+add)
}


@dataclasses.dataclass(frozen=True)
class Op:
    """One micro-op. Fields are interpreted per `kind` (see executor)."""

    kind: str
    dst: Optional[int] = None
    src0: Optional[int] = None
    src1: Optional[int] = None
    src2: Optional[int] = None
    aux: int = 0                # shift amount / const value / weight / length
    alu: str = ""               # sub-op selector (row_op, wlogic, wshift)
    invert1: bool = False       # complement the second operand (free)
    mask: Optional[int] = None  # fa: AND-gate plane for the b operand
    cout: Optional[int] = None  # fa: carry-out row (carry-save writeback)
    cycles: Optional[int] = None  # explicit override (tree_stage)

    def __post_init__(self):
        if self.kind not in CYCLE_TABLE:
            raise ValueError(f"unknown micro-op kind {self.kind!r}")


def op_cycles(op: Op, width: int) -> int:
    """Cycle charge of one op under the Table-2 contract."""
    if op.cycles is not None:
        return op.cycles
    fixed = CYCLE_TABLE[op.kind]
    if fixed is not None:
        return fixed
    if op.kind == "wmult":
        return width + 2
    if op.kind == "wshift":
        return op.aux
    if op.kind in ("t_bp2bs", "t_bs2bp"):
        # read rows + 1 core cycle + write rows (repro.core.transpose)
        return 1 + 1 + width
    if op.kind == "tree_stage":
        raise ValueError("tree_stage needs an explicit cycle override")
    raise AssertionError(op.kind)


@dataclasses.dataclass(frozen=True)
class Program:
    """A micro-op program plus its operand map and calibration annotation.

    `inputs` / `outputs` map operand names to ``(start_row, n_rows)``
    regions: BS operands span `width` plane rows (LSB first), BP operands
    one word-lane row each. `expected_delta` records the *documented*
    difference ``executed_cycles - analytic_compute`` for this width (0 for
    an exact match); any nonzero delta carries a `calibration_note`
    explaining it per DESIGN.md Sec. 8.
    """

    name: str
    layout: Layout
    width: int
    ops: tuple
    rows: int
    inputs: tuple       # ((name, (start_row, n_rows)), ...)
    outputs: tuple
    n: Optional[int] = None       # element count baked in (BP reduction tree)
    expected_delta: int = 0
    calibration_note: str = ""

    @property
    def cycles(self) -> int:
        """Executed cycle count (static: charges are data-independent)."""
        return sum(op_cycles(op, self.width) for op in self.ops)

    @property
    def key(self):
        """Stable cache key (builders are deterministic)."""
        return (self.name, self.layout.value, self.width, self.n)

    def input_region(self, name: str):
        return dict(self.inputs)[name]

    def output_region(self, name: str):
        return dict(self.outputs)[name]

    def validate(self) -> "Program":
        """Static checks: row addresses in range (including multi-row
        spans), ALU selectors known."""
        for op in self.ops:
            for r in (op.dst, op.src0, op.src1, op.src2, op.mask, op.cout):
                if r is not None and not (0 <= r < self.rows):
                    raise ValueError(
                        f"{self.name}: op {op.kind} row {r} outside "
                        f"0..{self.rows - 1}")
            # multi-row spans: shift moves aux rows, transposes span width
            spans = []
            if op.kind == "shift":
                spans = [(op.src0, op.aux), (op.dst, op.aux)]
            elif op.kind == "t_bp2bs":
                spans = [(op.dst, self.width)]
            elif op.kind == "t_bs2bp":
                spans = [(op.src0, self.width)]
            for start, count in spans:
                if start + count > self.rows:
                    raise ValueError(
                        f"{self.name}: op {op.kind} rows "
                        f"{start}..{start + count - 1} exceed array rows "
                        f"{self.rows}")
            if op.kind == "row_op" and op.alu not in (
                    "and", "or", "nor", "xor"):
                raise ValueError(f"bad row_op alu {op.alu!r}")
            if op.kind == "wlogic" and op.alu not in ("and", "or", "xor"):
                raise ValueError(f"bad wlogic alu {op.alu!r}")
            if op.kind == "wshift" and op.alu not in ("l", "rl", "ra"):
                raise ValueError(f"bad wshift alu {op.alu!r}")
        return self
