"""Functional bitline simulator of the Computing SRAM Array (paper Fig. 1).

State is a (rows, cols) boolean JAX array. Multi-row activation discharges
each bitline through the selected cells: the sense amplifier on BL reads the
AND of the activated rows; the complementary bitline reads their NOR; an
extra gate yields XOR. All columns compute in parallel -- exactly the
in-SRAM computing primitive the cost model charges one cycle for.

This layer validates *semantics*; cycle charges live in
`repro.core.cost_model`, and `repro.pim.executor` replays micro-op programs
(`repro.pim.microcode`) over these primitives so the two can be compared
differentially.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


# -- free-function primitives (shared by CSArray and the micro-op executor) --

def activate(op: str, cells: jax.Array, r0: int, r1: int,
             invert1: bool = False) -> jax.Array:
    """Multi-row activation of rows `r0`, `r1` sensed through gate `op`.

    `invert1` reads `r1` through the complementary bitline (a free operand
    inversion in the hardware; used e.g. by two's-complement subtract and
    AND-NOT predication).
    """
    a = cells[r0]
    b = cells[r1]
    if invert1:
        b = jnp.logical_not(b)
    if op == "and":
        return jnp.logical_and(a, b)
    if op == "or":
        return jnp.logical_or(a, b)
    if op == "nor":
        return jnp.logical_not(jnp.logical_or(a, b))
    if op == "xor":
        return jnp.logical_xor(a, b)
    raise ValueError(f"unknown row op {op!r}")


def row_to_words(bits: jax.Array, width: int) -> jax.Array:
    """One BP row (cols,) bool -> (cols // width,) uint32 word lanes.

    Lanes are LSB-first within each `width`-bit slice; width <= 32 (wider
    values span two rows, see the executor's `wmult` lo/hi convention).
    """
    n = bits.shape[0] // width
    b = bits[: n * width].reshape(n, width).astype(jnp.uint32)
    ks = jnp.arange(width, dtype=jnp.uint32)
    return jnp.sum(b << ks[None, :], axis=1)


def words_to_row(words: jax.Array, width: int, cols: int) -> jax.Array:
    """(n,) uint32 word lanes -> one BP row (cols,) bool (zero-padded)."""
    ks = jnp.arange(width, dtype=jnp.uint32)
    bits = ((words[:, None] >> ks[None, :]) & 1).astype(bool).reshape(-1)
    if bits.shape[0] < cols:
        bits = jnp.concatenate(
            [bits, jnp.zeros((cols - bits.shape[0],), bool)])
    return bits[:cols]


@dataclasses.dataclass
class CSArray:
    """One computing SRAM array (default 128 x 512)."""

    cells: jax.Array  # (rows, cols) bool

    @classmethod
    def zeros(cls, rows: int = 128, cols: int = 512) -> "CSArray":
        return cls(jnp.zeros((rows, cols), dtype=bool))

    @property
    def rows(self) -> int:
        return self.cells.shape[0]

    @property
    def cols(self) -> int:
        return self.cells.shape[1]

    # -- row access ---------------------------------------------------------
    def write_row(self, r: int, bits: jax.Array) -> "CSArray":
        return CSArray(self.cells.at[r].set(bits.astype(bool)))

    def read_row(self, r: int) -> jax.Array:
        return self.cells[r]

    def write_rows(self, start: int, block: jax.Array) -> "CSArray":
        """Write a (k, cols) block of rows starting at `start`."""
        k = block.shape[0]
        return CSArray(self.cells.at[start:start + k].set(
            block.astype(bool)))

    def read_rows(self, start: int, count: int) -> jax.Array:
        return self.cells[start:start + count]

    def const_row(self, r: int, value: bool) -> "CSArray":
        """Peripheral row clear/set (charged to the load phase, not compute)."""
        return CSArray(self.cells.at[r].set(
            jnp.full((self.cols,), bool(value))))

    # -- multi-row activation primitives (Fig. 1) ----------------------------
    def activate_and(self, r0: int, r1: int) -> jax.Array:
        """BL sense: high only if every activated cell stores 1."""
        return activate("and", self.cells, r0, r1)

    def activate_nor(self, r0: int, r1: int) -> jax.Array:
        """Complementary bitline sense: high iff all activated cells store 0."""
        return activate("nor", self.cells, r0, r1)

    def activate_xor(self, r0: int, r1: int) -> jax.Array:
        """NOR(AND, NOR) of the two sensed values (Fig. 1b)."""
        return activate("xor", self.cells, r0, r1)

    def activate_or(self, r0: int, r1: int) -> jax.Array:
        return activate("or", self.cells, r0, r1)

    # -- fused op-and-writeback (one compute cycle in the cost model) --------
    def op_into(self, op: str, r0: int, r1: int, dst: int,
                invert1: bool = False) -> "CSArray":
        res = activate(op, self.cells, r0, r1, invert1=invert1)
        return CSArray(self.cells.at[dst].set(res))

    def not_into(self, src: int, dst: int) -> "CSArray":
        return CSArray(self.cells.at[dst].set(jnp.logical_not(self.cells[src])))
