"""Functional bitline simulator of the Computing SRAM Array (paper Fig. 1).

State is a (rows, cols) boolean JAX array. Multi-row activation discharges
each bitline through the selected cells: the sense amplifier on BL reads the
AND of the activated rows; the complementary bitline reads their NOR; an
extra gate yields XOR. All columns compute in parallel -- exactly the
in-SRAM computing primitive the cost model charges one cycle for.

This layer validates *semantics*; cycles live in `repro.core.cost_model`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CSArray:
    """One computing SRAM array (default 128 x 512)."""

    cells: jax.Array  # (rows, cols) bool

    @classmethod
    def zeros(cls, rows: int = 128, cols: int = 512) -> "CSArray":
        return cls(jnp.zeros((rows, cols), dtype=bool))

    @property
    def rows(self) -> int:
        return self.cells.shape[0]

    @property
    def cols(self) -> int:
        return self.cells.shape[1]

    # -- row access ---------------------------------------------------------
    def write_row(self, r: int, bits: jax.Array) -> "CSArray":
        return CSArray(self.cells.at[r].set(bits.astype(bool)))

    def read_row(self, r: int) -> jax.Array:
        return self.cells[r]

    # -- multi-row activation primitives (Fig. 1) ----------------------------
    def activate_and(self, r0: int, r1: int) -> jax.Array:
        """BL sense: high only if every activated cell stores 1."""
        return jnp.logical_and(self.cells[r0], self.cells[r1])

    def activate_nor(self, r0: int, r1: int) -> jax.Array:
        """Complementary bitline sense: high iff all activated cells store 0."""
        return jnp.logical_not(jnp.logical_or(self.cells[r0], self.cells[r1]))

    def activate_xor(self, r0: int, r1: int) -> jax.Array:
        """NOR(AND, NOR) of the two sensed values (Fig. 1b)."""
        a = self.activate_and(r0, r1)
        n = self.activate_nor(r0, r1)
        return jnp.logical_not(jnp.logical_or(a, n))

    def activate_or(self, r0: int, r1: int) -> jax.Array:
        return jnp.logical_not(self.activate_nor(r0, r1))

    # -- fused op-and-writeback (one compute cycle in the cost model) --------
    def op_into(self, op: str, r0: int, r1: int, dst: int) -> "CSArray":
        res = {
            "and": self.activate_and,
            "or": self.activate_or,
            "nor": self.activate_nor,
            "xor": self.activate_xor,
        }[op](r0, r1)
        return CSArray(self.cells.at[dst].set(res))

    def not_into(self, src: int, dst: int) -> "CSArray":
        return CSArray(self.cells.at[dst].set(jnp.logical_not(self.cells[src])))
