"""Bit-serial (BS) arithmetic on vertical bitplanes.

Data layout: an N-bit vector of `n` elements is a (N, n) boolean array --
plane k holds bit k (LSB first) of every element, one element per column
(EP-BS, Fig. 2b). Arithmetic follows the BS peripheral of Sec. 4.1: a 1-cycle
full adder per bit plane, free shifts (row renaming), and MUX synthesized
from AND/OR/NOT (the 4-cycle penalty in the cost model).

Everything is pure JAX so the simulator vmaps/jits across arrays.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pack(values: jax.Array, width: int) -> jax.Array:
    """Integers (n,) -> bitplanes (width, n), LSB first."""
    values = values.astype(jnp.uint32)
    ks = jnp.arange(width, dtype=jnp.uint32)
    return ((values[None, :] >> ks[:, None]) & 1).astype(bool)


def unpack(planes: jax.Array) -> np.ndarray:
    """Bitplanes (width, n) -> integers (n,) (unsigned, uint64).

    Decoding is the peripheral *readout* path, so it accumulates on the host
    in uint64: ``bs_mult`` products carry 2w planes, and shifting plane
    k >= 32 inside a uint32 container silently drops the high half (the
    width-32 regression in tests/test_pim_sim.py). jax's default x64-disabled
    mode cannot represent uint64, hence numpy.
    """
    p = np.asarray(planes).astype(np.uint64)
    ks = np.arange(p.shape[0], dtype=np.uint64)
    return np.sum(p << ks[:, None], axis=0, dtype=np.uint64)


def unpack_signed(planes: jax.Array) -> np.ndarray:
    """Bitplanes (width, n) -> two's-complement integers (n,) (int64).

    Plane width-1 is the sign plane. Supports width < 64 (the executor's
    operand widths plus double-width products of <= 32-bit multiplies).
    """
    width = planes.shape[0]
    if width >= 64:
        raise ValueError(f"signed decode needs width < 64, got {width}")
    u = unpack(planes).astype(np.int64)
    return u - (((u >> (width - 1)) & 1) << width)


def full_adder(a: jax.Array, b: jax.Array, c: jax.Array):
    """(sum, carry) of three bit planes -- the 1-cycle BS hardware adder."""
    s = jnp.logical_xor(jnp.logical_xor(a, b), c)
    cout = (a & b) | (c & (a ^ b))
    return s, cout


def bs_add(a: jax.Array, b: jax.Array, out_width: int | None = None):
    """Ripple add over planes: one full-adder cycle per bit (Table 2)."""
    w = a.shape[0]
    ow = out_width or w
    n = a.shape[1]
    carry = jnp.zeros((n,), bool)
    outs = []
    for k in range(ow):
        ak = a[k] if k < w else jnp.zeros((n,), bool)
        bk = b[k] if k < b.shape[0] else jnp.zeros((n,), bool)
        s, carry = full_adder(ak, bk, carry)
        outs.append(s)
    return jnp.stack(outs)


def bs_neg(a: jax.Array) -> jax.Array:
    """Two's complement: invert + add 1 (w adder cycles)."""
    inv = jnp.logical_not(a)
    one = jnp.zeros_like(a).at[0].set(True)
    return bs_add(inv, one)


def bs_sub(a: jax.Array, b: jax.Array) -> jax.Array:
    return bs_add(a, bs_neg(b))


def bs_shift_up(a: jax.Array, k: int) -> jax.Array:
    """Multiply by 2^k via row renaming -- zero cycles in the cost model."""
    w, n = a.shape
    if k == 0:
        return a
    pad = jnp.zeros((k, n), bool)
    return jnp.concatenate([pad, a], axis=0)


def bs_mult(a: jax.Array, b: jax.Array) -> jax.Array:
    """Shift-and-add multiply: w partial products, each masked by a bit of b
    and accumulated with the serial adder (w^2 cycles total)."""
    w, n = a.shape
    ow = 2 * w
    acc = jnp.zeros((ow, n), bool)
    for k in range(w):
        partial = bs_shift_up(a, k)[:ow]
        if partial.shape[0] < ow:
            partial = jnp.concatenate(
                [partial, jnp.zeros((ow - partial.shape[0], n), bool)])
        masked = jnp.logical_and(partial, b[k][None, :])
        acc = bs_add(acc, masked)
    return acc


def bs_mux(cond: jax.Array, t: jax.Array, f: jax.Array) -> jax.Array:
    """Per-bit synthesized MUX (4 primitive gates per plane -- the Table-2
    4-cycle penalty): out = (t AND c) OR (f AND NOT c)."""
    c = cond[None, :] if cond.ndim == 1 else cond
    return jnp.logical_or(jnp.logical_and(t, c),
                          jnp.logical_and(f, jnp.logical_not(c)))


def bs_ge0(a: jax.Array) -> jax.Array:
    """Sign-bit read: 1 cycle (Table 5 ge_0/BS)."""
    return jnp.logical_not(a[-1])


def bs_abs(a: jax.Array) -> jax.Array:
    neg = bs_neg(a)
    return bs_mux(a[-1], neg, a)


def bs_min(a: jax.Array, b: jax.Array) -> jax.Array:
    """sub + per-bit MUX select (6w cycles in the cost model)."""
    d = bs_sub(a, b)
    a_lt_b = d[-1]
    return bs_mux(a_lt_b, a, b)


def bs_max(a: jax.Array, b: jax.Array) -> jax.Array:
    d = bs_sub(a, b)
    a_lt_b = d[-1]
    return bs_mux(a_lt_b, b, a)


def bs_equal(a: jax.Array, b: jax.Array) -> jax.Array:
    """serial XOR + OR-reduce (2w+1 cycles)."""
    x = jnp.logical_xor(a, b)
    return jnp.logical_not(jnp.any(x, axis=0))


def bs_relu(a: jax.Array) -> jax.Array:
    return jnp.logical_and(a, bs_ge0(a)[None, :])


def bs_popcount(a: jax.Array, out_width: int | None = None) -> jax.Array:
    """Serial summation of bit planes (5w-cycle class)."""
    w, n = a.shape
    ow = out_width or max(1, w.bit_length())
    acc = jnp.zeros((ow, n), bool)
    for k in range(w):
        bit = jnp.zeros((ow, n), bool).at[0].set(a[k])
        acc = bs_add(acc, bit)
    return acc
