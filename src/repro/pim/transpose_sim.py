"""Functional model of the on-chip transpose unit (paper Sec. 4.1).

BP form: words along rows (one W-bit word per row slice).
BS form: bitplanes (W, n) with one element per column.

The hardware reads M rows (BP) or N rows (BS), flows them through the
bit/word transposer (1 core cycle), and writes the other form -- here we
reproduce the data movement exactly so layouts can be switched mid-program,
as the hybrid scheduler assumes.
"""
from __future__ import annotations

import jax

from repro.pim.bitserial import pack, unpack


def bp_to_bs(words: jax.Array, width: int) -> jax.Array:
    """(n,) unsigned words -> (width, n) bitplanes."""
    return pack(words, width)


def bs_to_bp(planes: jax.Array) -> jax.Array:
    """(width, n) bitplanes -> (n,) unsigned words."""
    return unpack(planes)


def round_trip(words: jax.Array, width: int) -> jax.Array:
    return bs_to_bp(bp_to_bs(words, width))
