"""Functional model of the on-chip transpose unit (paper Sec. 4.1).

BP form: words along rows (one W-bit word per row slice).
BS form: bitplanes (W, n) with one element per column.

The hardware reads M rows (BP) or N rows (BS), flows them through the
bit/word transposer (1 core cycle), and writes the other form -- here we
reproduce the data movement exactly so layouts can be switched mid-program,
as the hybrid scheduler assumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.pim.bitserial import pack, unpack


def bp_to_bs(words: jax.Array, width: int) -> jax.Array:
    """(n,) unsigned words -> (width, n) bitplanes."""
    return pack(words, width)


def bs_to_bp(planes: jax.Array) -> np.ndarray:
    """(width, n) bitplanes -> (n,) unsigned words (host uint64 decode).

    `unpack` accumulates on the host in uint64 (see its docstring), so this
    is an eager readout path -- not jit-traceable.  Inside traced programs
    use the bit-exact `planes_to_row` shuffle instead.
    """
    return unpack(planes)


def round_trip(words: jax.Array, width: int) -> np.ndarray:
    return bs_to_bp(bp_to_bs(words, width))


# -- bit-exact physical transposes (the executor's TRANSPOSE micro-ops) ------

def row_to_planes(row_bits: jax.Array, width: int) -> jax.Array:
    """One BP row (cols,) bool -> (width, cols // width) bitplanes.

    Pure wire-level shuffle: lane j's bit k moves to plane k, column j --
    no integer decode, so it composes under `vmap`/`jit` inside the
    micro-op executor.
    """
    n = row_bits.shape[0] // width
    return row_bits[: n * width].reshape(n, width).T


def planes_to_row(planes: jax.Array, cols: int) -> jax.Array:
    """(width, n) bitplanes -> one BP row (cols,) bool (zero-padded)."""
    bits = planes.T.reshape(-1)
    if bits.shape[0] < cols:
        bits = jnp.concatenate(
            [bits, jnp.zeros((cols - bits.shape[0],), bool)])
    return bits[:cols]
