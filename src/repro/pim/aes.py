"""Functional AES-128 under BP, BS, and hybrid layouts (paper Sec. 5.4).

Three interchangeable executions of the round function:

* **BP**: the state is a vector of 16 bytes (one byte per word-PE).
  SubBytes is a table lookup (costed as composite-field GF inversion in the
  cycle model), ShiftRows a logical remap, MixColumns word-level xtime.
* **BS**: the state is 8 bitplanes x 16 columns (EP-BS). SubBytes is a
  *bit-sliced* GF(2^8) inversion (Fermat chain: 7 squarings + 6 multiplies,
  AND/XOR plane ops only) + affine map -- the layout the paper credits with
  the 115-gate Boyar-Peralta cost. ShiftRows is a physical column shuffle.
* **Hybrid**: BP everywhere, transposing to BS for SubBytes and back --
  the paper's winning schedule.

All three must encrypt identically; validated against a from-scratch
reference and the FIPS-197 vector.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.pim.bitserial import pack, unpack
from repro.pim.transpose_sim import bp_to_bs, bs_to_bp

AES_POLY = 0x11B  # x^8 + x^4 + x^3 + x + 1


# ------------------------------------------------------------------ GF(2^8)

def gf_mul_int(a: int, b: int) -> int:
    r = 0
    while b:
        if b & 1:
            r ^= a
        a <<= 1
        if a & 0x100:
            a ^= AES_POLY
        b >>= 1
    return r


@functools.lru_cache(None)
def sbox_table() -> tuple:
    """Generate the AES S-box from GF inversion + affine (FIPS-197)."""
    inv = [0] * 256
    for x in range(1, 256):
        # brute-force inverse (table generation happens once, host-side)
        for y in range(1, 256):
            if gf_mul_int(x, y) == 1:
                inv[x] = y
                break
    out = []
    for x in range(256):
        v = inv[x]
        b = 0
        for i in range(8):
            bit = ((v >> i) ^ (v >> ((i + 4) % 8)) ^ (v >> ((i + 5) % 8))
                   ^ (v >> ((i + 6) % 8)) ^ (v >> ((i + 7) % 8))
                   ^ (0x63 >> i)) & 1
            b |= bit << i
        out.append(b)
    return tuple(out)


# --------------------------------------------------- bit-sliced GF algebra --

def _bs_gf_mult(a: jax.Array, b: jax.Array) -> jax.Array:
    """Bit-sliced carry-less multiply + modular reduction by AES_POLY.
    a, b: (8, n) planes -> (8, n) planes. AND/XOR plane ops only."""
    n = a.shape[1]
    t = [jnp.zeros((n,), bool) for _ in range(15)]
    for i in range(8):
        for j in range(8):
            t[i + j] = jnp.logical_xor(t[i + j],
                                       jnp.logical_and(a[i], b[j]))
    # reduce x^k for k = 14..8: x^8 = x^4 + x^3 + x + 1
    for k in range(14, 7, -1):
        r = t[k]
        for off in (4, 3, 1, 0):
            t[k - 8 + off] = jnp.logical_xor(t[k - 8 + off], r)
        t[k] = jnp.zeros((n,), bool)
    return jnp.stack(t[:8])


def _bs_gf_square(a: jax.Array) -> jax.Array:
    """Squaring is linear in GF(2^8): spread bits then reduce."""
    n = a.shape[1]
    t = [jnp.zeros((n,), bool) for _ in range(15)]
    for i in range(8):
        t[2 * i] = a[i]
    for k in range(14, 7, -1):
        r = t[k]
        for off in (4, 3, 1, 0):
            t[k - 8 + off] = jnp.logical_xor(t[k - 8 + off], r)
        t[k] = jnp.zeros((n,), bool)
    return jnp.stack(t[:8])


def bs_gf_inverse(a: jax.Array) -> jax.Array:
    """x^254 by the Fermat chain: product of x^(2^i), i=1..7.
    (Functionally identical to -- though not gate-optimal like -- the
    115-gate Boyar-Peralta circuit the cost model charges.)"""
    sq = _bs_gf_square(a)  # x^2
    prod = sq
    cur = sq
    for _ in range(6):  # x^4 ... x^128
        cur = _bs_gf_square(cur)
        prod = _bs_gf_mult(prod, cur)
    return prod


def bs_sub_bytes(planes: jax.Array) -> jax.Array:
    """Bit-sliced S-box: inversion + affine transform, planes (8, n)."""
    inv = bs_gf_inverse(planes)
    out = []
    for i in range(8):
        b = inv[i]
        for off in (4, 5, 6, 7):
            b = jnp.logical_xor(b, inv[(i + off) % 8])
        if (0x63 >> i) & 1:
            b = jnp.logical_not(b)
        out.append(b)
    return jnp.stack(out)


# ------------------------------------------------------------ BP primitives

# state laid out column-major (FIPS): index = r + 4c;
# ShiftRows: new[r + 4c] = old[r + 4*((c + r) % 4)]
_SR = np.zeros(16, dtype=np.int32)
for _c in range(4):
    for _r in range(4):
        _SR[_r + 4 * _c] = _r + 4 * ((_c + _r) % 4)


def bp_sub_bytes(state: jax.Array) -> jax.Array:
    table = jnp.asarray(sbox_table(), dtype=jnp.uint8)
    return table[state]


def shift_rows(state: jax.Array) -> jax.Array:
    """Logical remap in BP (zero-cost address change in the cost model)."""
    return state[jnp.asarray(_SR)]


def bp_xtime(b: jax.Array) -> jax.Array:
    hi = (b & 0x80) != 0
    return jnp.where(hi, ((b << 1) ^ 0x1B) & 0xFF, (b << 1) & 0xFF
                     ).astype(jnp.uint8)


def bp_mix_columns(state: jax.Array) -> jax.Array:
    # state index r + 4c -> reshape to (c, r) then transpose to s[r, c]
    s = state.reshape(4, 4).T
    a0, a1, a2, a3 = s[0], s[1], s[2], s[3]
    x0, x1, x2, x3 = bp_xtime(a0), bp_xtime(a1), bp_xtime(a2), bp_xtime(a3)
    r0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    r1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    r2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    r3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    return jnp.stack([r0, r1, r2, r3]).T.reshape(-1).astype(jnp.uint8)


# ---------------------------------------------------------- BS round stages

def bs_shift_rows(planes: jax.Array) -> jax.Array:
    """Physical column shuffle in EP-BS (costed as inter-column moves)."""
    return planes[:, jnp.asarray(_SR)]


def _bs_xtime(planes: jax.Array) -> jax.Array:
    n = planes.shape[1]
    hi = planes[7]
    out = [jnp.zeros((n,), bool)] + [planes[i] for i in range(7)]
    for i in (0, 1, 3, 4):  # 0x1B taps
        out[i] = jnp.logical_xor(out[i], hi)
    return jnp.stack(out)


def bs_mix_columns(planes: jax.Array) -> jax.Array:
    cols = planes.reshape(8, 4, 4)  # (bit, col, row) with index r + 4c
    a = [cols[:, :, r] for r in range(4)]
    x = [_bs_xtime(ai) for ai in a]
    X = jnp.logical_xor
    r0 = X(X(x[0], X(x[1], a[1])), X(a[2], a[3]))
    r1 = X(X(a[0], x[1]), X(X(x[2], a[2]), a[3]))
    r2 = X(X(a[0], a[1]), X(x[2], X(x[3], a[3])))
    r3 = X(X(X(x[0], a[0]), a[1]), X(a[2], x[3]))
    return jnp.stack([r0, r1, r2, r3], axis=-1).reshape(8, 16)


def bs_add_round_key(planes: jax.Array, rk_planes: jax.Array) -> jax.Array:
    return jnp.logical_xor(planes, rk_planes)


# ------------------------------------------------------------- key schedule

def expand_key(key: np.ndarray) -> np.ndarray:
    """FIPS-197 key expansion (host-side; 11 round keys of 16 bytes)."""
    sbox = sbox_table()
    rcon = [0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1B, 0x36]
    w = [list(key[4 * i:4 * i + 4]) for i in range(4)]
    for i in range(4, 44):
        temp = list(w[i - 1])
        if i % 4 == 0:
            temp = temp[1:] + temp[:1]
            temp = [sbox[b] for b in temp]
            temp[0] ^= rcon[i // 4 - 1]
        w.append([a ^ b for a, b in zip(w[i - 4], temp)])
    rks = np.array(w, dtype=np.uint8).reshape(11, 16)
    return rks


# ------------------------------------------------------------- full ciphers

def encrypt_bp(plaintext: np.ndarray, key: np.ndarray) -> np.ndarray:
    rks = expand_key(key)
    s = jnp.asarray(plaintext, dtype=jnp.uint8)
    s = s ^ jnp.asarray(rks[0])
    for r in range(1, 11):
        s = bp_sub_bytes(s)
        s = shift_rows(s)
        if r < 10:
            s = bp_mix_columns(s)
        s = s ^ jnp.asarray(rks[r])
    return np.asarray(s)


def encrypt_bs(plaintext: np.ndarray, key: np.ndarray) -> np.ndarray:
    rks = expand_key(key)
    p = pack(jnp.asarray(plaintext, dtype=jnp.uint32), 8)
    p = bs_add_round_key(p, pack(jnp.asarray(rks[0], jnp.uint32), 8))
    for r in range(1, 11):
        p = bs_sub_bytes(p)
        p = bs_shift_rows(p)
        if r < 10:
            p = bs_mix_columns(p)
        p = bs_add_round_key(p, pack(jnp.asarray(rks[r], jnp.uint32), 8))
    return np.asarray(unpack(p), dtype=np.uint8)


def encrypt_hybrid(plaintext: np.ndarray, key: np.ndarray) -> np.ndarray:
    """The paper's schedule: BS for SubBytes, BP for everything else, with
    explicit layout transpositions at the phase boundaries."""
    rks = expand_key(key)
    s = jnp.asarray(plaintext, dtype=jnp.uint8) ^ jnp.asarray(rks[0])
    for r in range(1, 11):
        planes = bp_to_bs(s.astype(jnp.uint32), 8)  # transpose BP->BS
        planes = bs_sub_bytes(planes)
        s = bs_to_bp(planes).astype(jnp.uint8)  # transpose BS->BP
        s = shift_rows(s)
        if r < 10:
            s = bp_mix_columns(s)
        s = s ^ jnp.asarray(rks[r])
    return np.asarray(s)


def encrypt_planned(plaintext: np.ndarray, key: np.ndarray,
                    layout_of) -> np.ndarray:
    """Drive the functional AES simulation with a compiled layout plan.

    ``layout_of`` maps the ``aes`` workload's op names (``ARK0``,
    ``SB1``, ``SR1``, ``MC1``, ...) to ``"BP"``/``"BS"`` (e.g.
    ``dict(compile_plan(get_workload("aes")).op_schedule())``).  The
    state transposes lazily at layout boundaries -- exactly where the
    plan inserts its explicit :class:`~repro.plan.ir.TransposeStep`s --
    so the hand-built ``encrypt_hybrid`` schedule is the special case
    ``SB* -> BS, everything else -> BP``.
    """
    rks = expand_key(key)
    state = jnp.asarray(plaintext, dtype=jnp.uint8)   # BP form
    cur = "BP"

    def in_layout(lay):
        nonlocal state, cur
        lay = getattr(lay, "value", lay)
        if lay != cur:
            state = (bp_to_bs(state.astype(jnp.uint32), 8) if lay == "BS"
                     else bs_to_bp(state).astype(jnp.uint8))
            cur = lay
        return state

    def ark(r):
        nonlocal state
        s = in_layout(layout_of[f"ARK{r}"])
        state = (s ^ jnp.asarray(rks[r]) if cur == "BP" else
                 bs_add_round_key(s, pack(jnp.asarray(rks[r], jnp.uint32),
                                          8)))

    ark(0)
    for r in range(1, 11):
        s = in_layout(layout_of[f"SB{r}"])
        state = bp_sub_bytes(s) if cur == "BP" else bs_sub_bytes(s)
        s = in_layout(layout_of[f"SR{r}"])
        state = shift_rows(s) if cur == "BP" else bs_shift_rows(s)
        if r < 10:
            s = in_layout(layout_of[f"MC{r}"])
            state = bp_mix_columns(s) if cur == "BP" else bs_mix_columns(s)
        ark(r)
    if cur == "BS":
        state = bs_to_bp(state).astype(jnp.uint8)
    return np.asarray(state)


# ------------------------------------------------------------ pure-Py oracle

def encrypt_reference(plaintext: np.ndarray, key: np.ndarray) -> np.ndarray:
    """Independent from-scratch AES-128 on Python ints (the oracle)."""
    sbox = sbox_table()
    rks = expand_key(key)
    s = [int(b) for b in plaintext]
    s = [a ^ int(b) for a, b in zip(s, rks[0])]
    for rnd in range(1, 11):
        s = [sbox[b] for b in s]
        s = [s[(r + 4 * ((c + r) % 4))] for c in range(4) for r in range(4)]
        s2 = list(s)
        if rnd < 10:
            t = list(s2)
            for c in range(4):
                a = t[4 * c:4 * c + 4]
                xt = [gf_mul_int(v, 2) for v in a]
                s2[4 * c + 0] = xt[0] ^ (xt[1] ^ a[1]) ^ a[2] ^ a[3]
                s2[4 * c + 1] = a[0] ^ xt[1] ^ (xt[2] ^ a[2]) ^ a[3]
                s2[4 * c + 2] = a[0] ^ a[1] ^ xt[2] ^ (xt[3] ^ a[3])
                s2[4 * c + 3] = (xt[0] ^ a[0]) ^ a[1] ^ a[2] ^ xt[3]
        s = [a ^ int(b) for a, b in zip(s2, rks[rnd])]
    return np.array(s, dtype=np.uint8)
