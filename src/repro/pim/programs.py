"""Executable micro-op programs for the Table-5 microkernel suite.

One builder per (kernel, layout) pair. Each program's static cycle count is
the *executable* counterpart of the analytic compute formula in
`repro.core.cost_model`; `analytic_compute` evaluates that formula at the
same operating point so the two can be differenced primitive-by-primitive
(`MicroKernel.executed_vs_analytic`). Where the published per-width
constants cannot be realized op-by-op under the Table-2 charges, the
builder hardcodes the documented delta (`expected_delta`) with a
`calibration_note` -- the catalogue lives in DESIGN.md Sec. 8.

Operand conventions (see `repro.pim.executor` staging helpers):
  BS: an operand named in `inputs` spans `width` plane rows, LSB first;
      1-bit flags (ite condition, predicates) span one row.
  BP: one row of `width`-bit LSB-first word lanes per operand; `multu`
      returns (`prod_lo`, `prod_hi`) rows.
"""
from __future__ import annotations

import math
from typing import Callable, Optional

from repro.core.cost_model import Layout
from repro.pim.microcode import Op, Program


def _prog(name, layout, width, ops, rows, inputs, outputs, n=None,
          delta=0, note=""):
    return Program(
        name=name, layout=layout, width=width, ops=tuple(ops), rows=rows,
        inputs=tuple(inputs.items()), outputs=tuple(outputs.items()), n=n,
        expected_delta=delta, calibration_note=note,
    ).validate()


# ---------------------------------------------------------------------------
# BS builders (vertical bitplanes, one element per column)
# ---------------------------------------------------------------------------

def _bs_add(w, n=None):
    a, b, s = 0, w, 2 * w
    ops = [Op("setc", aux=0)]
    ops += [Op("fa", src0=a + k, src1=b + k, dst=s + k) for k in range(w)]
    return _prog("vector_add", Layout.BS, w, ops, 3 * w,
                 {"a": (a, w), "b": (b, w)}, {"sum": (s, w)})


def _bs_sub(w, n=None):
    a, b, s = 0, w, 2 * w
    ops = [Op("setc", aux=1)]   # cin=1 completes the two's complement
    ops += [Op("fa", src0=a + k, src1=b + k, dst=s + k, invert1=True)
            for k in range(w)]
    return _prog("vector_sub", Layout.BS, w, ops, 3 * w,
                 {"a": (a, w), "b": (b, w)}, {"diff": (s, w)})


def _bs_mult(w, n=None):
    # Shift-and-add: iteration k adds (a AND b_k) into acc[k .. k+w) -- the
    # shift is pure renaming (builder indexing), the AND rides the
    # serial-multiplier gate, and the final carry-save writeback lands the
    # carry in acc[k+w] (zero until then, since the partial sum < 2^(w+k)).
    a, b, acc = 0, w, 2 * w
    ops = []
    for k in range(w):
        ops.append(Op("setc", aux=0))
        for j in range(w):
            ops.append(Op(
                "fa", src0=acc + k + j, src1=a + j, mask=b + k,
                dst=acc + k + j,
                cout=(acc + k + w) if j == w - 1 else None))
    return _prog("multu", Layout.BS, w, ops, 4 * w,
                 {"a": (a, w), "b": (b, w)}, {"prod": (acc, 2 * w)})


def _bs_minmax(name, w):
    # Paper decomposition: sub (w) + synthesized MUX select (4w) +
    # conditional copy committing into the result rows (w) = 6w.
    a, b, d, sel, res = 0, w, 2 * w, 3 * w, 4 * w
    sign = d + w - 1    # sign(a-b): 1 iff a < b (no-overflow contract)
    t, f = (a, b) if name == "min" else (b, a)
    ops = [Op("setc", aux=1)]
    ops += [Op("fa", src0=a + k, src1=b + k, dst=d + k, invert1=True)
            for k in range(w)]
    ops += [Op("mux", src0=sign, src1=t + k, src2=f + k, dst=sel + k)
            for k in range(w)]
    ops += [Op("copy", src0=sel + k, dst=res + k) for k in range(w)]
    return _prog(name, Layout.BS, w, ops, 5 * w,
                 {"a": (a, w), "b": (b, w)}, {name: (res, w)})


def _bs_abs(w, n=None):
    # Serialized conditional negate: x = a XOR sign (w), y = x + sign (w),
    # commit (w) = 3w.  Correct two's complement |a| (INT_MIN wraps).
    a, x, y, res = 0, w, 2 * w, 3 * w
    sign = a + w - 1
    ops = [Op("row_op", alu="xor", src0=a + k, src1=sign, dst=x + k)
           for k in range(w)]
    ops += [Op("setc", aux=0)]
    ops += [Op("fa", src0=x + k, src1=sign if k == 0 else None, dst=y + k)
            for k in range(w)]
    ops += [Op("copy", src0=y + k, dst=res + k) for k in range(w)]
    return _prog("abs", Layout.BS, w, ops, 4 * w,
                 {"a": (a, w)}, {"abs": (res, w)})


def _bs_relu(w, n=None):
    a, m, out = 0, w, w + 1
    ops = [Op("not", src0=a + w - 1, dst=m)]
    ops += [Op("row_op", alu="and", src0=a + k, src1=m, dst=out + k)
            for k in range(w)]
    return _prog("relu", Layout.BS, w, ops, 2 * w + 1,
                 {"a": (a, w)}, {"relu": (out, w)})


def _bs_equal(w, n=None):
    a, b, x, acc, out = 0, w, 2 * w, 2 * w + 1, 2 * w + 2
    ops = [Op("const", dst=acc, aux=0)]
    for k in range(w):
        ops.append(Op("row_op", alu="xor", src0=a + k, src1=b + k, dst=x))
        ops.append(Op("row_op", alu="or", src0=acc, src1=x, dst=acc))
    ops.append(Op("not", src0=acc, dst=out))
    return _prog("equal", Layout.BS, w, ops, 2 * w + 3,
                 {"a": (a, w), "b": (b, w)}, {"eq": (out, 1)})


def _bs_ge0(w, n=None):
    a, out = 0, w
    ops = [Op("not", src0=a + w - 1, dst=out)]
    return _prog("ge_0", Layout.BS, w, ops, w + 1,
                 {"a": (a, w)}, {"ge0": (out, 1)})


def _bs_gt0(w, n=None):
    a, acc, out = 0, w, w + 1
    ops = [Op("const", dst=acc, aux=0)]
    ops += [Op("row_op", alu="or", src0=acc, src1=a + k, dst=acc)
            for k in range(w)]
    # nonzero AND NOT sign via the complementary bitline
    ops.append(Op("row_op", alu="and", src0=acc, src1=a + w - 1,
                  invert1=True, dst=out))
    return _prog("gt_0", Layout.BS, w, ops, w + 2,
                 {"a": (a, w)}, {"gt0": (out, 1)})


def _bs_ite(w, n=None):
    c, t, f = 0, 1, w + 1
    cs, tm, fm, out = 2 * w + 1, 2 * w + 2, 3 * w + 2, 4 * w + 2
    ops = [Op("copy", src0=c, dst=cs)]   # condition staged into mask row
    ops += [Op("row_op", alu="and", src0=t + k, src1=cs, dst=tm + k)
            for k in range(w)]
    ops += [Op("row_op", alu="and", src0=f + k, src1=cs, invert1=True,
               dst=fm + k) for k in range(w)]
    ops += [Op("row_op", alu="or", src0=tm + k, src1=fm + k, dst=out + k)
            for k in range(w)]
    return _prog("if_then_else", Layout.BS, w, ops, 5 * w + 2,
                 {"cond": (c, 1), "t": (t, w), "f": (f, w)},
                 {"out": (out, w)})


def _bs_reduction(w, n=None):
    # Native serial summation: one plane pass, peripheral accumulator
    # weights plane k by 2^k.  Result is ExecState.acc (mod 2^32).
    a = 0
    ops = [Op("col_reduce", src0=a + k, aux=k) for k in range(w)]
    return _prog("reduction", Layout.BS, w, ops, w, {"a": (a, w)}, {})


def _bs_bitcount(w, n=None):
    p = w.bit_length()           # acc planes: max count w needs log2(w)+1
    a, acc = 0, w
    ops = [Op("const", dst=acc + j, aux=0) for j in range(p)]
    for k in range(w):
        ops.append(Op("setc", aux=0))
        for j in range(p):
            ops.append(Op("fa", src0=acc + j,
                          src1=(a + k) if j == 0 else None, dst=acc + j))
    delta = (p - 5) * w
    note = "" if delta == 0 else (
        f"accumulator needs ceil(log2(w+1)) = {p} planes; the published 5w "
        f"is calibrated at w=16 (DESIGN.md Sec. 8)")
    return _prog("bitcount", Layout.BS, w, ops, w + p,
                 {"a": (a, w)}, {"count": (acc, p)}, delta=delta, note=note)


# ---------------------------------------------------------------------------
# BP builders (word lanes driven by the word-level peripheral ALU)
# ---------------------------------------------------------------------------

def _bp_add(w, n=None):
    ops = [Op("wadd", src0=0, src1=1, dst=2)]
    return _prog("vector_add", Layout.BP, w, ops, 3,
                 {"a": (0, 1), "b": (1, 1)}, {"sum": (2, 1)})


def _bp_sub(w, n=None):
    ops = [Op("wsub", src0=0, src1=1, dst=2)]
    return _prog("vector_sub", Layout.BP, w, ops, 3,
                 {"a": (0, 1), "b": (1, 1)}, {"diff": (2, 1)})


def _bp_mult(w, n=None):
    ops = [Op("wmult", src0=0, src1=1, dst=2, aux=3)]
    return _prog("multu", Layout.BP, w, ops, 4,
                 {"a": (0, 1), "b": (1, 1)},
                 {"prod_lo": (2, 1), "prod_hi": (3, 1)})


def _bp_minmax(name, w):
    # Shift-mask variant: sub (2) + sign broadcast shift (w-1) + four mask
    # ops = w+5.  Matches the published 21 @16b and the w+5 fallback; the
    # published 36 @32b is one cycle less (DESIGN.md Sec. 8).
    t, f = (0, 1) if name == "min" else (1, 0)
    ops = [
        Op("wsub", src0=0, src1=1, dst=2),
        Op("wshift", alu="ra", aux=w - 1, src0=2, dst=3),
        Op("wlogic", alu="and", src0=t, src1=3, dst=4),
        Op("wnot", src0=3, dst=5),
        Op("wlogic", alu="and", src0=f, src1=5, dst=6),
        Op("wlogic", alu="or", src0=4, src1=6, dst=7),
    ]
    delta = 1 if w == 32 else 0
    note = "" if delta == 0 else (
        "published 32-bit row (36) saves one mask op vs the 16-bit-"
        "calibrated shift-mask sequence (DESIGN.md Sec. 8)")
    return _prog(name, Layout.BP, w, ops, 8,
                 {"a": (0, 1), "b": (1, 1)}, {name: (7, 1)},
                 delta=delta, note=note)


def _bp_abs(w, n=None):
    ops = [
        Op("wshift", alu="ra", aux=w - 1, src0=0, dst=1),
        Op("wlogic", alu="xor", src0=0, src1=1, dst=2),
        Op("wsub", src0=2, src1=1, dst=3),
    ]
    return _prog("abs", Layout.BP, w, ops, 4,
                 {"a": (0, 1)}, {"abs": (3, 1)})


def _bp_relu(w, n=None):
    ops = [
        Op("wshift", alu="ra", aux=w - 1, src0=0, dst=1),
        Op("wnot", src0=1, dst=2),
        Op("wlogic", alu="and", src0=0, src1=2, dst=3),
    ]
    return _prog("relu", Layout.BP, w, ops, 4,
                 {"a": (0, 1)}, {"relu": (3, 1)})


def _bp_equal(w, n=None):
    # XOR + logarithmic OR-fold + flag isolate = w + 2 + log2(w); the
    # published w+6 fixes log2(w)=4 (exact at the 16-bit calibration point).
    ops = [Op("wlogic", alu="xor", src0=0, src1=1, dst=2)]
    k = w >> 1
    while k >= 1:
        ops.append(Op("wshift", alu="rl", aux=k, src0=2, dst=3))
        ops.append(Op("wlogic", alu="or", src0=2, src1=3, dst=2))
        k >>= 1
    ops += [
        Op("wnot", src0=2, dst=4),
        Op("wconst", dst=5, aux=1),
        Op("wlogic", alu="and", src0=4, src1=5, dst=6),
    ]
    delta = int(math.log2(w)) - 4
    note = "" if delta == 0 else (
        "published w+6 hardcodes the 16-bit OR-fold depth "
        "(DESIGN.md Sec. 8)")
    return _prog("equal", Layout.BP, w, ops, 7,
                 {"a": (0, 1), "b": (1, 1)}, {"eq": (6, 1)},
                 delta=delta, note=note)


def _ge0_ops(w, src, rows):
    """Shared ge_0 sequence: sign shift + xor + flag isolate (w+1 cycles)."""
    m, ones, x, one, out = rows
    return [
        Op("wshift", alu="ra", aux=w - 1, src0=src, dst=m),
        Op("wconst", dst=ones, aux=(1 << w) - 1),
        Op("wlogic", alu="xor", src0=m, src1=ones, dst=x),
        Op("wconst", dst=one, aux=1),
        Op("wlogic", alu="and", src0=x, src1=one, dst=out),
    ]


def _bp_ge0(w, n=None):
    ops = _ge0_ops(w, 0, (1, 2, 3, 4, 5))
    return _prog("ge_0", Layout.BP, w, ops, 6,
                 {"a": (0, 1)}, {"ge0": (5, 1)})


def _bp_gt0(w, n=None):
    # ge_0 (w+1) + nonzero test (w+2) + explicit combine (1) = 2w+4; the
    # published 2w+3 folds the combine into the test's last cycle.
    ops = _ge0_ops(w, 0, (1, 2, 3, 4, 5))
    ops += [
        Op("wconst", dst=6, aux=0),
        Op("wsub", src0=6, src1=0, dst=7),
        Op("wlogic", alu="or", src0=0, src1=7, dst=8),
        Op("wshift", alu="rl", aux=w - 1, src0=8, dst=9),
        Op("wlogic", alu="and", src0=5, src1=9, dst=10),
    ]
    return _prog("gt_0", Layout.BP, w, ops, 11,
                 {"a": (0, 1)}, {"gt0": (10, 1)},
                 delta=1,
                 note="published 2w+3 dual-issues the final combine with "
                      "the nonzero test's last cycle (DESIGN.md Sec. 8)")


def _bp_ite(w, n=None):
    # Mask-0s variant, width-independent 7 cycles: mask gen (2) + not (1)
    # + two ANDs (2) + OR (1) + result commit (1).
    ops = [
        Op("wconst", dst=3, aux=0),
        Op("wsub", src0=3, src1=0, dst=4),        # mask = -cond (cond in 0/1)
        Op("wlogic", alu="and", src0=1, src1=4, dst=5),
        Op("wnot", src0=4, dst=6),
        Op("wlogic", alu="and", src0=2, src1=6, dst=7),
        Op("wlogic", alu="or", src0=5, src1=7, dst=8),
        Op("wcopy", src0=8, dst=9),
    ]
    return _prog("if_then_else", Layout.BP, w, ops, 10,
                 {"cond": (0, 1), "t": (1, 1), "f": (2, 1)},
                 {"out": (9, 1)})


def _bp_reduction(w, n=None):
    n = n or 16
    if n < 2 or n & (n - 1):
        raise ValueError(f"BP tree reduction needs a power-of-two n, got {n}")
    ops = []
    m = n // 2
    first = True
    while m >= 1:
        # adjacent pairs add directly (1); later stages move + add (2)
        ops.append(Op("tree_stage", src0=0, aux=m, cycles=1 if first else 2))
        first = False
        m //= 2
    return _prog("reduction", Layout.BP, w, ops, 1,
                 {"a": (0, 1)}, {"sum": (0, 1)}, n=n)


_BITCOUNT_MASKS = {
    8: (0x55, 0x33, 0x0F, 0x0F),
    16: (0x5555, 0x3333, 0x0F0F, 0x1F),
    32: (0x55555555, 0x33333333, 0x0F0F0F0F, 0x3F),
}
_BITCOUNT_DELTA = {8: -3, 16: 0, 32: 11}


def _bp_bitcount(w, n=None):
    # Divide-and-conquer popcount under Table-2 shift charges (a k-bit
    # shift costs k): exactly the published 25 at the 16-bit calibration
    # point; at other widths the shift terms dominate and the published
    # 6*log2(w)+1 does not track (DESIGN.md Sec. 8).
    if w not in _BITCOUNT_MASKS:
        raise ValueError(f"bitcount/BP supports widths 8/16/32, got {w}")
    m1, m2, m4, fin = _BITCOUNT_MASKS[w]
    ops = [
        Op("wconst", dst=1, aux=m1), Op("wconst", dst=2, aux=m2),
        Op("wconst", dst=3, aux=m4), Op("wconst", dst=4, aux=fin),
        # x = a - ((a >> 1) & m1)
        Op("wshift", alu="rl", aux=1, src0=0, dst=6),
        Op("wlogic", alu="and", src0=6, src1=1, dst=6),
        Op("wsub", src0=0, src1=6, dst=5),
        # x = (x & m2) + ((x >> 2) & m2)
        Op("wshift", alu="rl", aux=2, src0=5, dst=7),
        Op("wlogic", alu="and", src0=7, src1=2, dst=7),
        Op("wlogic", alu="and", src0=5, src1=2, dst=5),
        Op("wadd", src0=5, src1=7, dst=5),
        # x = (x + (x >> 4)) & m4
        Op("wshift", alu="rl", aux=4, src0=5, dst=7),
        Op("wadd", src0=5, src1=7, dst=5),
        Op("wlogic", alu="and", src0=5, src1=3, dst=5),
    ]
    if w >= 16:
        ops += [Op("wshift", alu="rl", aux=8, src0=5, dst=7),
                Op("wadd", src0=5, src1=7, dst=5)]
    if w == 32:
        ops += [Op("wshift", alu="rl", aux=16, src0=5, dst=7),
                Op("wadd", src0=5, src1=7, dst=5)]
    ops.append(Op("wlogic", alu="and", src0=5, src1=4, dst=8))
    delta = _BITCOUNT_DELTA[w]
    note = "" if delta == 0 else (
        "Table-2 k-cycle shifts make wide-word D&C diverge from the "
        "published 6*log2(w)+1, which is calibrated at w=16 "
        "(DESIGN.md Sec. 8)")
    return _prog("bitcount", Layout.BP, w, ops, 9,
                 {"a": (0, 1)}, {"count": (8, 1)}, delta=delta, note=note)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

BUILDERS: dict = {
    ("vector_add", Layout.BP): _bp_add,
    ("vector_add", Layout.BS): _bs_add,
    ("vector_sub", Layout.BP): _bp_sub,
    ("vector_sub", Layout.BS): _bs_sub,
    ("multu", Layout.BP): _bp_mult,
    ("multu", Layout.BS): _bs_mult,
    ("min", Layout.BP): lambda w, n=None: _bp_minmax("min", w),
    ("min", Layout.BS): lambda w, n=None: _bs_minmax("min", w),
    ("max", Layout.BP): lambda w, n=None: _bp_minmax("max", w),
    ("max", Layout.BS): lambda w, n=None: _bs_minmax("max", w),
    ("abs", Layout.BP): _bp_abs,
    ("abs", Layout.BS): _bs_abs,
    ("relu", Layout.BP): _bp_relu,
    ("relu", Layout.BS): _bs_relu,
    ("equal", Layout.BP): _bp_equal,
    ("equal", Layout.BS): _bs_equal,
    ("ge_0", Layout.BP): _bp_ge0,
    ("ge_0", Layout.BS): _bs_ge0,
    ("gt_0", Layout.BP): _bp_gt0,
    ("gt_0", Layout.BS): _bs_gt0,
    ("if_then_else", Layout.BP): _bp_ite,
    ("if_then_else", Layout.BS): _bs_ite,
    ("reduction", Layout.BP): _bp_reduction,
    ("reduction", Layout.BS): _bs_reduction,
    ("bitcount", Layout.BP): _bp_bitcount,
    ("bitcount", Layout.BS): _bs_bitcount,
}

#: kernels with an executable program in both layouts
EXECUTABLE_KERNELS = tuple(sorted({k for k, _ in BUILDERS}))

_CACHE: dict = {}


def build(name: str, layout: Layout, width: int = 16,
          n: Optional[int] = None) -> Program:
    """Build (and cache) the micro-op program for `name` in `layout`."""
    try:
        builder: Callable = BUILDERS[(name, Layout(layout))]
    except KeyError:
        raise KeyError(
            f"no executable program for kernel {name!r} in layout "
            f"{layout} (have: {', '.join(EXECUTABLE_KERNELS)})") from None
    key = (name, Layout(layout).value, width, n)
    if key not in _CACHE:
        _CACHE[key] = builder(width, n)
    return _CACHE[key]


def analytic_compute(name: str, layout: Layout, width: int,
                     n: Optional[int] = None) -> int:
    """The cost model's compute-cycle formula at the program's operating
    point (single batch; BP tree reduction uses the program's element
    count, everything else is element-count-free per batch)."""
    from repro.core.microkernels import MICROKERNELS
    from repro.core.params import PAPER_SYSTEM

    mk = MICROKERNELS[name]
    layout = Layout(layout)
    n_eff = (n or 16) if (name == "reduction" and layout is Layout.BP) else 1
    return mk.cost_fn(layout, n_eff, width, PAPER_SYSTEM).compute
