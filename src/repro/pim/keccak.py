"""Keccak pi-stage permutation: logical vs physical shuffle (Challenge 3).

pi: A'[x, y] = A[(x + 3y) mod 5, x]  (lane-level permutation of the 5x5x64
state). In ES-BP the permutation is a *logical* shuffle -- an address remap
with zero data movement. In EP-BS the lanes live in different columns, so
the same permutation is a *physical* shuffle: explicit lane-by-lane copies
through a scratch buffer. Both must produce identical states.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pi_index_map() -> np.ndarray:
    """dst lane (x, y) <- src lane ((x + 3y) % 5, x), flattened as x + 5y."""
    idx = np.zeros(25, dtype=np.int32)
    for x in range(5):
        for y in range(5):
            sx, sy = (x + 3 * y) % 5, x
            idx[x + 5 * y] = sx + 5 * sy
    return idx


def pi_logical(state: jax.Array) -> jax.Array:
    """Zero-cost address remap (ES-BP): one gather, no element writes."""
    return state[jnp.asarray(pi_index_map())]


def pi_physical(state: jax.Array) -> jax.Array:
    """Explicit lane-by-lane copy through a scratch buffer (EP-BS): the
    sequence of inter-column transfers the BS cost model charges."""
    idx = pi_index_map()
    out = jnp.zeros_like(state)
    for dst in range(25):
        lane = state[idx[dst]]  # read source lane to the transfer buffer
        out = out.at[dst].set(lane)  # write to destination column group
    return out


def theta(state: jax.Array) -> jax.Array:
    """theta stage (used by tests to check pi composes into a real round):
    C[x] = xor of column lanes; D[x] = C[x-1] ^ rot(C[x+1], 1)."""
    lanes = state.reshape(5, 5)  # [y, x] with index x + 5y
    C = lanes[0]
    for y in range(1, 5):
        C = C ^ lanes[y]
    D = jnp.stack([
        C[(x - 1) % 5] ^ jnp.bitwise_or(
            (C[(x + 1) % 5] << 1), (C[(x + 1) % 5] >> 63)).astype(C.dtype)
        for x in range(5)
    ])
    return (lanes ^ D[None, :]).reshape(25)
