"""FIR filter on the PIM scratchpad (Challenge 2).

Direct-form N-tap FIR: a sliding window of past samples plus coefficients
and intermediate products -- the 11-live-word working set the paper uses to
demonstrate BS row overflow. The BP functional model keeps every word-level
variable in its own row; this module validates the arithmetic against
np.convolve.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def fir_bp(samples: jax.Array, coeffs: jax.Array) -> jax.Array:
    """Word-level (BP) execution: state rows shift, 4 MACs per sample."""
    taps = coeffs.shape[0]

    def step(state, x):
        state = jnp.concatenate([x[None], state[:-1]])
        y = jnp.sum(state * coeffs)
        return state, y

    init = jnp.zeros((taps,), samples.dtype)
    _, ys = jax.lax.scan(step, init, samples)
    return ys


def fir_reference(samples: np.ndarray, coeffs: np.ndarray) -> np.ndarray:
    return np.convolve(samples, coeffs)[: len(samples)]
