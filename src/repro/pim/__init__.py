"""Functional simulator stack of the computing-SRAM substrate.

Four layers (see README.md in this package): the bitline array
(`array_sim`), bit-serial arithmetic on vertical bitplanes (`bitserial`),
the micro-op ISA (`microcode`) with its Table-5 program suite (`programs`),
and the cycle-counting executor (`executor`) that differentially validates
`repro.core.cost_model`.  Case-study programs: AES, Keccak pi, FIR.
"""
from repro.pim.array_sim import CSArray  # noqa: F401
from repro.pim.bitserial import (  # noqa: F401
    bs_add, bs_mult, bs_mux, bs_sub, pack, unpack, unpack_signed,
)
from repro.pim.executor import (  # noqa: F401
    ExecResult, execute, run_batched,
)
from repro.pim.microcode import Op, Program  # noqa: F401
from repro.pim.programs import (  # noqa: F401
    EXECUTABLE_KERNELS, analytic_compute, build,
)
from repro.pim.transpose_sim import bp_to_bs, bs_to_bp  # noqa: F401
