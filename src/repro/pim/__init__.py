"""Functional bitplane simulator of the computing-SRAM substrate.

Validates the *semantics* of both layouts (the cycle costs live in
`repro.core`): multi-row activation logic, bit-serial arithmetic, the
transpose unit, and the paper's case-study programs (AES, Keccak pi, FIR).
"""
from repro.pim.array_sim import CSArray  # noqa: F401
from repro.pim.bitserial import (  # noqa: F401
    bs_add, bs_mult, bs_mux, bs_sub, pack, unpack,
)
from repro.pim.transpose_sim import bp_to_bs, bs_to_bp  # noqa: F401
