"""Cross-cutting knobs: scan unrolling (HLO cost counting) and remat.

XLA's cost analysis counts a `while` body ONCE, not x trip-count, so a
scanned-layers model under-reports FLOPs/bytes/collectives. The dry-run's
counting pass therefore lowers reduced-depth configs with
``REPRO_UNROLL_SCANS=1`` -- every `util.scan` becomes a Python loop, the HLO
contains no while ops, and cost analysis is exact -- then extrapolates
linearly in depth (layers are homogeneous). See launch/dryrun.py.

Also home to the content-address provenance primitives shared by the
caching layers (sweep grid, plan cache, executable cache) -- this module
sits below every subsystem, so none of them has to import another just
to fingerprint sources.
"""
from __future__ import annotations

import hashlib
import inspect
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

_REMAT = False


def source_fingerprint(*modules, digest_len: int = 16) -> str:
    """sha256 over the concatenated source of ``modules``.

    The provenance half of every content address in the repo (sweep
    cache, plan cache, executable cache): editing any fingerprinted
    module changes the address, so stale cached artifacts can never be
    served after a code change.
    """
    h = hashlib.sha256()
    for mod in modules:
        h.update(inspect.getsource(mod).encode())
    return h.hexdigest()[:digest_len]


def rand_words(rng: np.random.Generator, width: int, shape) -> np.ndarray:
    """Unsigned ``width``-bit weight words in the canonical int32 storage
    (the word form every kernel path consumes).

    ``width >= 32`` draws the full uint32 range and reinterprets the bits
    as int32: the old ``1 << min(width, 31)`` bound could never generate
    the top bit, so width-32 paths were only ever exercised at 31-bit
    range.  The signed view is lossless -- every kernel path agrees
    mod 2^32 (DESIGN.md Sec. 14), so a negative int32 is just the same
    32-bit word.
    """
    if width >= 32:
        raw = rng.integers(0, 1 << 32, shape, dtype=np.uint64)
        return raw.astype(np.uint32).view(np.int32)
    return rng.integers(0, 1 << width, shape).astype(np.int32)


def set_remat(value: bool) -> None:
    """Per-layer rematerialization for the training step (set by
    make_train_step before tracing)."""
    global _REMAT
    _REMAT = bool(value)


def remat_enabled() -> bool:
    return _REMAT


def unroll_scans() -> bool:
    return os.environ.get("REPRO_UNROLL_SCANS", "") == "1"


def flash_chunk_default() -> int:
    return int(os.environ.get("REPRO_FLASH_CHUNK", "512"))


def attn_bf16_matmuls() -> bool:
    """Perf lever: bf16 QK/PV matmuls with f32 softmax state (the paper's
    precision-vs-layout trade applied to attention operand width)."""
    return os.environ.get("REPRO_ATTN_BF16", "") == "1"


def fused_attention_accounting() -> bool:
    """Perf lever: account flash-internal tensors as VMEM-resident (the
    Pallas kernel in kernels/flash_attention.py), excluding them from the
    boundary-bytes memory term."""
    return os.environ.get("REPRO_FUSED_ATTN", "") == "1"


def moe_bf16_dispatch() -> bool:
    """Perf lever: bf16 dispatch/combine one-hots (exactly representable)."""
    return os.environ.get("REPRO_MOE_BF16_DISPATCH", "") == "1"


def moe_two_step_reshard() -> bool:
    """Perf lever: explicit g(data)->e(data) dim exchange so SPMD emits
    all-to-all for MoE token routing instead of all-reduce+all-gather."""
    return os.environ.get("REPRO_MOE_A2A", "") == "1"


def bf16_allreduce_barrier() -> bool:
    """Perf lever: optimization_barrier after residual adds, preventing XLA
    from hoisting the rms_norm f32 convert above the row-parallel psum
    (which doubles TP all-reduce wire bytes)."""
    return os.environ.get("REPRO_AR_BF16", "") == "1"


def scan(f, init, xs, length=None):
    """lax.scan, or an unrolled Python loop under REPRO_UNROLL_SCANS=1."""
    if not unroll_scans():
        return lax.scan(f, init, xs, length=length)
    if xs is None:
        n = length
    else:
        n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked
