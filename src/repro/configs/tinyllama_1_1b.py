"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632
vocab=32000 [arXiv:2401.02385]. head_dim = 2048/32 = 64."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="tinyllama_1_1b", family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
        vocab_size=32000, head_dim=64,
        attn_policy="heads", dtype=jnp.bfloat16,
    )
