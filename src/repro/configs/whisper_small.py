"""whisper-small [audio]: enc-dec 12+12L d_model=768 12H d_ff=3072
vocab=51865; conv/mel frontend is a STUB (input_specs provides 1500 frame
embeddings) [arXiv:2212.04356]."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper_small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
        vocab_size=51865, head_dim=64,
        enc_layers=12, enc_seq=1500,
        tie_embeddings=True, attn_policy="sequence", dtype=jnp.bfloat16,
    )
