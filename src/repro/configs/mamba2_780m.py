"""mamba2-780m [ssm]: 48L d_model=1536, SSD, d_state=128, vocab 50280
[arXiv:2405.21060]. Attention-free => long_500k runs."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2_780m", family="ssm",
        n_layers=48, d_model=1536, n_heads=48, n_kv_heads=0, d_ff=0,
        vocab_size=50280, head_dim=64,
        ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
        tie_embeddings=True, subquadratic=True, attn_policy="heads",
        dtype=jnp.bfloat16,
    )
