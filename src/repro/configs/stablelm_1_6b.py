"""stablelm-1.6b [dense]: 24L d_model=2048 32H (MHA kv=32) d_ff=5632
vocab=100352 [hf:stabilityai/stablelm-2-1_6b]. head_dim = 64."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm_1_6b", family="dense",
        n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
        vocab_size=100352, head_dim=64,
        attn_policy="heads", dtype=jnp.bfloat16,
    )
