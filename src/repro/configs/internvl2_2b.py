"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553; InternViT frontend is a STUB (input_specs provides 256 patch
embeddings) [arXiv:2404.16821]."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2_2b", family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
        vocab_size=92553, head_dim=128,
        enc_seq=256,  # patch tokens per image (stub frontend)
        attn_policy="heads", dtype=jnp.bfloat16,
    )
