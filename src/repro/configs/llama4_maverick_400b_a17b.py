"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, interleaved every 2nd layer
[hf:meta-llama/Llama-4]. 40 heads % 16 != 0 => sequence attention policy."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llama4_maverick_400b_a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
        vocab_size=202048, head_dim=128,
        n_experts=128, top_k=1, moe_every=2,
        attn_policy="sequence", dtype=jnp.bfloat16,
    )
