"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention (2 recurrent : 1 local, window 2048)
[arXiv:2402.19427]. 10 heads % 16 != 0 => sequence policy; O(1) recurrent
state + windowed cache => long_500k runs."""
import jax.numpy as jnp
from repro.models.base import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma_2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
        vocab_size=256000, head_dim=256,
        window=2048, block_pattern=("rec", "rec", "attn_local"),
        lru_width=2560, conv_width=4, tie_embeddings=True,
        subquadratic=True, attn_policy="sequence", dtype=jnp.bfloat16,
    )
