"""Assigned architecture configs (--arch <id>) + input-shape registry.

Every config reproduces the published dims exactly; vocab sizes are padded
to a multiple of 256 at the embedding (base.ArchConfig.padded_vocab).
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.base import ArchConfig

ARCH_IDS = [
    "mamba2_780m",
    "dbrx_132b",
    "llama4_maverick_400b_a17b",
    "yi_6b",
    "tinyllama_1_1b",
    "mistral_nemo_12b",
    "stablelm_1_6b",
    "internvl2_2b",
    "recurrentgemma_2b",
    "whisper_small",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: long_500k needs sub-quadratic attention; pure full-attention archs skip
#: it (DESIGN.md Sec. 4).
LONG_CONTEXT_ARCHS = {"mamba2_780m", "recurrentgemma_2b"}


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.config()


def all_cells() -> list[tuple[str, str]]:
    """The 40 (arch x shape) cells; long_500k marked runnable or skip."""
    cells = []
    for a in ARCH_IDS:
        for s in SHAPES:
            cells.append((a, s))
    return cells


def cell_runnable(arch_id: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True


def reduced_config(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test scale: same family/structure, tiny dims."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        head_dim=16,
        rope_theta=cfg.rope_theta,
        dtype="float32",
    )
    import jax.numpy as jnp
    kw["dtype"] = jnp.float32
    if cfg.family == "ssm":
        kw.update(n_heads=4, n_kv_heads=0, ssm_state=16, ssm_head_dim=16,
                  ssm_expand=2, ssm_chunk=8)
        kw["n_layers"] = 2
    else:
        kw.update(n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)))
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2),
                  moe_every=cfg.moe_every)
        kw["n_layers"] = 2 * cfg.moe_every
    if cfg.family == "hybrid":
        kw.update(window=8, lru_width=64,
                  block_pattern=cfg.block_pattern, conv_width=cfg.conv_width)
        kw["n_layers"] = len(cfg.block_pattern) + 2  # one full block + tail
    if cfg.family == "audio":
        kw.update(enc_layers=2, enc_seq=8)
        kw["n_layers"] = 2
    if cfg.family == "vlm":
        kw.update(enc_seq=4)
        kw["n_layers"] = 2
    return dataclasses.replace(cfg, **kw)
