"""repro.workloads: one workload IR, many evaluation backends.

Public surface (see README.md in this directory and DESIGN.md Sec. 5):

    from repro.workloads import (
        Op, Workload,                 # the IR
        get_workload, list_workloads, workload_names,  # the registry
        Backend, Report, OpReport,    # the protocol (versioned to_dict)
        BACKENDS, get_backend,        # backend registry + THE factory
        backend_names, register_backend,
        characterize,                 # the entry point
    )

    characterize("vgg", backends=("analytic", "planner", "executor"))
    get_backend("planner", execute=True)   # the supported construction API

Construct backends through ``get_backend(name, **opts)`` -- direct class
imports (``PlannerBackend(...)``) still work but are a deprecated
construction path kept for existing callers.

CLI: ``python -m repro list | characterize | tables``.
"""
from repro.workloads.backends import (  # noqa: F401
    AnalyticBackend,
    Backend,
    BACKENDS,
    ExecutorBackend,
    OpReport,
    PallasBackend,
    PlannerBackend,
    Report,
    REPORT_SCHEMA_VERSION,
    backend_names,
    characterize,
    get_backend,
    register_backend,
)
from repro.workloads.ir import (  # noqa: F401
    Op,
    Workload,
    matmul_working_set_bits,
    op_cost,
    op_phases,
)
from repro.workloads.registry import (  # noqa: F401
    AES_STAGE,
    ARCH_IDS,
    arch_workload,
    get_workload,
    list_workloads,
    microkernel_workload,
    workload_names,
)
from repro.workloads.trace import (  # noqa: F401
    param_path_widths,
    trace_workload,
)
