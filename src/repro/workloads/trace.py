"""jaxpr -> Workload tracer: derive the workload IR from a real model.

The registry's ``arch/<id>`` traces are hand-transcribed formulas; the
real forward passes live in ``repro.models``.  This module closes the
gap: :func:`trace_workload` runs ``jax.make_jaxpr`` over *abstract*
arguments (``jax.ShapeDtypeStruct`` pytrees -- no allocation, so
full-size models trace in milliseconds), walks the equations, and lowers
every primitive to the workload IR:

====================== ====================================================
jax primitive          Op lowering
====================== ====================================================
``dot_general``        ``matmul`` with the true contraction dims
                       (m = batch x lhs-free, k = contracting, n =
                       rhs-free) and a precision resolved from the
                       per-param-path width map
``conv_general_dilated`` ``conv`` (n = output elements, k = taps x
                       C_in/groups, ``in_elems`` = input elements)
``gather`` / ``scatter`` / ``movement`` of the transferred elements at the
``dynamic_update_slice`` operand's dtype width
elementwise / reduce   ``compute`` with explicit per-layout cycles from
                       the Table-2/3 primitive costs (baked at ``sys``,
                       like the registry's ``compute`` ops)
shape/layout plumbing  transparent (reshape, transpose, broadcast, slice,
                       convert_element_type, ...): zero cost, origins and
                       producer edges propagate through
====================== ====================================================

``deps`` edges come from the jaxpr def-use graph, so
``plan.compile_plan`` sees the true DAG (min-cut scheduling), not a
chain.  Nested jaxprs (pjit / custom_jvp / remat / cond / while) are
inlined; ``scan`` bodies are lowered **once** by default
(``scan_mode="once"``) -- the traced workload describes one
representative layer / KV chunk, matching the per-layer semantics of the
hand-written ``arch/<id>`` formulas.

Precision resolution order (normative; DESIGN.md Sec. 12):

1. ``precision_map`` -- ``{path-substring: width_bits}`` matched against
   the operand's *origin paths* (the flattened-arg key paths its value
   was derived from through transparent ops); the minimum width over all
   matching entries wins.
2. integer operands: the dtype's bit width.
3. ``default_width`` (16) -- floats without a map entry, including f32
   softmax/router arithmetic, model at the paper's 16-bit word width.

A matmul's width is the minimum over its operands (a 4-bit weight makes
the op 4-bit, matching the quantized-serving formulas).
"""
from __future__ import annotations

import math
from typing import Callable, Optional

from repro.core import cost_model as cm
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.workloads.ir import Op, Workload

__all__ = ["trace_workload", "param_path_widths"]

# primitives that neither cost cycles nor break origin/dep propagation
TRANSPARENT_PRIMITIVES = frozenset({
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "expand_dims",
    "convert_element_type", "bitcast_convert_type", "slice",
    "dynamic_slice", "concatenate", "pad", "rev", "iota",
    "stop_gradient", "copy", "device_put", "sharding_constraint",
    "reduce_precision", "split", "real", "imag", "tie_in",
})

#: primitives lowered to ``movement`` ops (row-serial bus transfer of the
#: produced / updated elements)
MOVEMENT_PRIMITIVES = frozenset({
    "gather", "dynamic_update_slice", "scatter", "scatter-add",
    "scatter_add", "scatter-mul", "scatter-min", "scatter-max",
})

#: call-like primitives whose inner jaxpr is inlined 1:1
_CALL_PRIMITIVES = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "remat", "remat2",
    "checkpoint", "custom_jvp_call", "custom_vjp_call",
    "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
})

# per-element compute-cost table: primitive -> width -> (bp, bs) cycles
_TRANSCENDENTALS = frozenset({
    "exp", "exp2", "expm1", "log", "log1p", "log2", "tanh", "logistic",
    "erf", "erfc", "erf_inv", "sin", "cos", "tan", "asin", "acos",
    "atan", "atan2", "sinh", "cosh", "rsqrt", "sqrt", "cbrt",
})
_CMP = frozenset({"lt", "le", "gt", "ge"})
_LOGIC = frozenset({"and", "or", "xor", "not", "population_count"})
_ROUNDING = frozenset({"floor", "ceil", "round", "nextafter",
                       "is_finite", "sign"})


def _elem_cost(prim: str, w: int) -> tuple[int, int]:
    """Per-element (BP, BS) cycles of one elementwise primitive at width
    ``w`` (Table-2/3 vocabulary; DESIGN.md Sec. 12)."""
    if prim == "add" or prim in _ROUNDING:
        return cm.BP_ADD, cm.bs_add(w)
    if prim in ("sub", "neg"):
        return cm.BP_SUB, cm.bs_sub(w)
    if prim == "mul":
        return cm.bp_mult(w), cm.bs_mult(w)
    if prim in ("div", "rem"):
        return cm.div_bp(w), cm.div_bs(w)
    if prim in ("pow", "integer_pow"):
        return 2 * cm.bp_mult(w), 2 * cm.bs_mult(w)
    if prim in _TRANSCENDENTALS:
        # 4-term polynomial/Newton evaluation: 4 x (mult + add)
        return (4 * (cm.bp_mult(w) + cm.BP_ADD),
                4 * (cm.bs_mult(w) + cm.bs_add(w)))
    if prim in ("max", "min"):
        return cm.minmax_bp(w), cm.minmax_bs(w)
    if prim == "clamp":
        return 2 * cm.minmax_bp(w), 2 * cm.minmax_bs(w)
    if prim == "select_n":
        return cm.if_then_else_bp(w), cm.if_then_else_bs(w)
    if prim in ("eq", "ne"):
        return cm.equal_bp(w), cm.equal_bs(w)
    if prim in _CMP:
        # general compare = subtract + sign test
        return cm.BP_SUB + cm.ge0_bp(w), cm.bs_sub(w) + cm.ge0_bs(w)
    if prim in _LOGIC:
        return cm.BP_LOGIC, w
    if prim in ("shift_left", "shift_right_logical",
                "shift_right_arithmetic"):
        return cm.bp_shift(w), cm.BS_SHIFT
    if prim == "abs":
        return cm.abs_bp(w), cm.abs_bs(w)
    # unknown elementwise primitive: conservatively a multiply
    return cm.bp_mult(w), cm.bs_mult(w)


def _ceil_log2(n: int) -> int:
    return max(1, math.ceil(math.log2(max(2, int(n)))))


def _dtype_bits(dtype) -> int:
    import numpy as np

    if dtype == bool or getattr(dtype, "kind", "") == "b":
        return 1
    return np.dtype(dtype).itemsize * 8


def _elems(aval) -> int:
    return max(1, int(math.prod(aval.shape)))


# ---------------------------------------------------------------------------
# Precision maps
# ---------------------------------------------------------------------------

def _format_path(path) -> str:
    """Key path -> canonical ``a/b/0/c`` string (the precision-map and
    origin-path vocabulary)."""
    from jax import tree_util as jtu

    parts = []
    for k in path:
        if isinstance(k, jtu.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jtu.SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, jtu.GetAttrKey):
            parts.append(str(k.name))
        else:  # FlattenedIndexKey and friends
            parts.append(str(getattr(k, "key", k)))
    return "/".join(parts)


def param_path_widths(params, *, weight_bits: int, dtype=None,
                      exclude: tuple[str, ...] = ()) -> dict[str, int]:
    """Build a precision map: every >=2-D leaf of ``params`` whose dtype
    matches ``dtype`` (default: the leaf dtype of the first such leaf)
    maps to ``weight_bits``; paths containing any ``exclude`` substring
    are left at model precision.  This is the quantized-serving
    convention of ``registry.arch_workload`` (weight matrices at
    ``weight_bits``, activations/normalizers at 16-bit).
    """
    from jax import tree_util as jtu

    leaves = jtu.tree_flatten_with_path(params)[0]
    if dtype is None:
        for _, leaf in leaves:
            if getattr(leaf, "ndim", 0) >= 2:
                dtype = leaf.dtype
                break
    out: dict[str, int] = {}
    for path, leaf in leaves:
        if getattr(leaf, "ndim", 0) < 2 or leaf.dtype != dtype:
            continue
        p = _format_path(path)
        if any(tok in p for tok in exclude):
            continue
        out[p] = weight_bits
    return out


# ---------------------------------------------------------------------------
# The tracer
# ---------------------------------------------------------------------------

class _VarInfo:
    """What the tracer knows about one jaxpr value: which flattened-arg
    paths it derives from (through transparent ops only) and which
    emitted op indices produced it."""

    __slots__ = ("origins", "producers")

    def __init__(self, origins=frozenset(), producers=frozenset()):
        self.origins = origins      # frozenset[str] arg key paths
        self.producers = producers  # frozenset[int] op indices

    @staticmethod
    def union(infos) -> "_VarInfo":
        o: frozenset = frozenset()
        p: frozenset = frozenset()
        for i in infos:
            o = o | i.origins
            p = p | i.producers
        return _VarInfo(o, p)


_EMPTY = _VarInfo()


class _Tracer:
    def __init__(self, *, precision_map, default_width, sys, scan_mode,
                 matmul_chunk, matmul_working_set):
        self.precision_map = dict(precision_map or {})
        self.default_width = default_width
        self.sys = sys
        self.scan_mode = scan_mode
        self.matmul_chunk = matmul_chunk
        self.matmul_working_set = matmul_working_set
        self.ops: list[Op] = []
        self.deps: set[tuple[int, int]] = set()
        self.env: dict = {}          # jaxpr Var -> _VarInfo
        self._name_counts: dict[str, int] = {}

    # ----------------------------------------------------------- plumbing
    def read(self, atom) -> _VarInfo:
        from jax.core import Literal

        if isinstance(atom, Literal):
            return _EMPTY
        return self.env.get(atom, _EMPTY)

    def write(self, var, info: _VarInfo) -> None:
        self.env[var] = info

    def _unique(self, base: str) -> str:
        n = self._name_counts.get(base, 0)
        self._name_counts[base] = n + 1
        return base if n == 0 else f"{base}#{n}"

    def emit(self, op: Op, inputs: list[_VarInfo]) -> _VarInfo:
        idx = len(self.ops)
        self.ops.append(op)
        for producer in sorted(_VarInfo.union(inputs).producers):
            if producer < idx:
                self.deps.add((producer, idx))
        return _VarInfo(frozenset(), frozenset({idx}))

    # ---------------------------------------------------------- precision
    def _operand_width(self, info: _VarInfo, aval) -> int:
        matched = [w for key, w in self.precision_map.items()
                   if any(key in path for path in info.origins)]
        if matched:
            return min(matched)
        if aval.dtype.kind in ("i", "u"):
            return _dtype_bits(aval.dtype)
        return self.default_width

    # ------------------------------------------------------------ lowering
    def trace(self, jaxpr, invar_infos) -> None:
        for var, info in zip(jaxpr.invars, invar_infos):
            self.write(var, info)
        for var in jaxpr.constvars:
            self.write(var, _EMPTY)
        for eqn in jaxpr.eqns:
            self.eqn(eqn)

    def _inline(self, inner, eqn_invars, eqn_outvars) -> None:
        """Inline a nested jaxpr with a positional invar mapping."""
        jx = inner.jaxpr if hasattr(inner, "jaxpr") else inner
        self.trace(jx, [self.read(v) for v in eqn_invars])
        for outer, inner_out in zip(eqn_outvars, jx.outvars):
            self.write(outer, self.read(inner_out))

    def eqn(self, eqn) -> None:
        prim = eqn.primitive.name
        infos = [self.read(v) for v in eqn.invars]

        if prim in TRANSPARENT_PRIMITIVES:
            merged = _VarInfo.union(infos)
            for v in eqn.outvars:
                self.write(v, merged)
            return
        if prim in _CALL_PRIMITIVES:
            inner = eqn.params.get("jaxpr", eqn.params.get("call_jaxpr"))
            self._inline(inner, eqn.invars, eqn.outvars)
            return
        if prim == "scan":
            return self._scan(eqn)
        if prim == "while":
            return self._while(eqn)
        if prim == "cond":
            return self._cond(eqn)
        if prim == "dot_general":
            return self._dot_general(eqn, infos)
        if prim == "conv_general_dilated":
            return self._conv(eqn, infos)
        if prim in MOVEMENT_PRIMITIVES:
            return self._movement(eqn, infos, prim)
        if prim.startswith("reduce_window"):
            return self._reduce_window(eqn, infos, prim)
        if prim.startswith(("reduce_", "argmax", "argmin")):
            return self._reduce(eqn, infos, prim)
        if prim in ("cumsum", "cumprod", "cummax", "cummin",
                    "cumlogsumexp"):
            return self._cumulative(eqn, infos, prim)
        if prim in ("top_k", "sort", "approx_top_k"):
            return self._topk(eqn, infos, prim)
        return self._elementwise(eqn, infos, prim)

    # ------------------------------------------------------- control flow
    def _scan(self, eqn) -> None:
        body = eqn.params["jaxpr"]
        n_iter = int(eqn.params.get("length") or 1)
        reps = n_iter if self.scan_mode == "unroll" else 1
        for _ in range(reps):
            jx = body.jaxpr
            self.trace(jx, [self.read(v) for v in eqn.invars])
            # feed carries back so unrolled iterations chain correctly
            n_consts = eqn.params["num_consts"]
            n_carry = eqn.params["num_carry"]
            carry_out = jx.outvars[:n_carry]
            for outer, inner_out in zip(eqn.invars[n_consts:
                                                   n_consts + n_carry],
                                        carry_out):
                self.write(outer, self.read(inner_out))
        jx = body.jaxpr
        for outer, inner_out in zip(eqn.outvars, jx.outvars):
            self.write(outer, self.read(inner_out))

    def _while(self, eqn) -> None:
        body = eqn.params["body_jaxpr"]
        n_cond = eqn.params["cond_nconsts"]
        self._inline(body, eqn.invars[n_cond:], eqn.outvars)

    def _cond(self, eqn) -> None:
        branches = eqn.params["branches"]
        biggest = max(branches, key=lambda b: len(b.jaxpr.eqns))
        self._inline(biggest, eqn.invars[1:], eqn.outvars)

    # ------------------------------------------------------------ matmuls
    def _dot_general(self, eqn, infos) -> None:
        (lhs_c, rhs_c), (lhs_b, rhs_b) = eqn.params["dimension_numbers"]
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        batch = math.prod(lhs.shape[d] for d in lhs_b) if lhs_b else 1
        lhs_free = math.prod(
            lhs.shape[d] for d in range(lhs.ndim)
            if d not in lhs_c and d not in lhs_b) or 1
        rhs_free = math.prod(
            rhs.shape[d] for d in range(rhs.ndim)
            if d not in rhs_c and d not in rhs_b) or 1
        k = math.prod(lhs.shape[d] for d in lhs_c) or 1
        m = max(1, batch * lhs_free)
        n = max(1, rhs_free)
        widths = [self._operand_width(i, v.aval)
                  for i, v in zip(infos, eqn.invars)]
        width = min(widths)
        # name after the weight operand's param leaf when unambiguous
        leaves = sorted({path.rsplit("/", 1)[-1]
                         for i in infos for path in i.origins})
        base = leaves[0] if len(leaves) == 1 else "dot"
        ws = (self.matmul_working_set(width)
              if self.matmul_working_set else None)
        op = Op(name=self._unique(base), kind="matmul", m=m, k=k, n=n,
                width=width, chunk=min(self.matmul_chunk, k),
                mixed_precision=(len(set(widths)) > 1),
                working_set_bits=ws)
        info = self.emit(op, infos)
        for v in eqn.outvars:
            self.write(v, info)

    def _conv(self, eqn, infos) -> None:
        dn = eqn.params["dimension_numbers"]
        groups = int(eqn.params.get("feature_group_count", 1))
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        out = eqn.outvars[0].aval
        spatial_taps = math.prod(rhs.shape[d] for d in dn.rhs_spec[2:])
        c_in = rhs.shape[dn.rhs_spec[1]]
        k = max(1, spatial_taps * c_in)  # taps per output (C_in included)
        del groups  # C_in is already the per-group input-channel count
        widths = [self._operand_width(i, v.aval)
                  for i, v in zip(infos, eqn.invars)]
        leaves = sorted({path.rsplit("/", 1)[-1]
                         for i in infos for path in i.origins})
        base = leaves[0] if len(leaves) == 1 else "conv"
        op = Op(name=self._unique(base), kind="conv",
                n=_elems(out), k=k, in_elems=_elems(lhs),
                width=min(widths))
        info = self.emit(op, infos)
        for v in eqn.outvars:
            self.write(v, info)

    # ----------------------------------------------------------- movement
    def _movement(self, eqn, infos, prim) -> None:
        if prim == "dynamic_update_slice":
            moved = eqn.invars[1].aval  # the update operand
        elif prim.startswith("scatter"):
            moved = eqn.invars[2].aval  # updates
        else:  # gather
            moved = eqn.outvars[0].aval
        bits = _elems(moved) * _dtype_bits(moved.dtype)
        op = Op(name=self._unique(prim), kind="movement", bits=float(bits))
        info = self.emit(op, infos)
        for v in eqn.outvars:
            if prim == "dynamic_update_slice" or prim.startswith("scatter"):
                # the destination's origins survive the in-place update
                self.write(v, _VarInfo(infos[0].origins, info.producers))
            else:
                self.write(v, info)

    # --------------------------------------------------------- reductions
    def _compute(self, eqn, infos, name, bp, bs, width,
                 control=0.0) -> None:
        op = Op(name=self._unique(name), kind="compute",
                bp_cycles=int(bp), bs_cycles=int(bs), width=width,
                control_intensity=control)
        info = self.emit(op, infos)
        for v in eqn.outvars:
            self.write(v, info)

    def _reduce(self, eqn, infos, prim) -> None:
        src = eqn.invars[0].aval
        out = eqn.outvars[0].aval
        outs = _elems(out)
        ratio = max(2, _elems(src) // outs)
        w = _dtype_bits(src.dtype)
        bpb = self.sys.bp_batches(outs, min(w, 32))
        bsb = self.sys.bs_batches(outs)
        if prim in ("reduce_sum", "reduce_prod"):
            bp = cm.reduction_bp(ratio) * bpb
            bs = cm.reduction_bs(w) * bsb
            if prim == "reduce_prod":
                bp *= cm.bp_mult(w)
                bs *= cm.bs_mult(w)
            return self._compute(eqn, infos, prim, bp, bs, w)
        if prim in ("reduce_and", "reduce_or", "reduce_xor"):
            steps = _ceil_log2(ratio)
            return self._compute(eqn, infos, prim,
                                 steps * cm.BP_LOGIC * bpb,
                                 steps * w * bsb, w)
        # reduce_max / reduce_min / argmax / argmin: comparison trees
        steps = _ceil_log2(ratio)
        bp = steps * cm.minmax_bp(w) * bpb
        bs = steps * cm.minmax_bs(w) * bsb
        control = 0.4 if prim.startswith("arg") else 0.0
        return self._compute(eqn, infos, prim, bp, bs, w, control)

    def _reduce_window(self, eqn, infos, prim) -> None:
        out = eqn.outvars[0].aval
        src = eqn.invars[0].aval
        window = max(2, _elems(src) // _elems(out))
        w = _dtype_bits(src.dtype)
        per = (window - 1)
        bp = per * cm.minmax_bp(w) * self.sys.bp_batches(_elems(out),
                                                         min(w, 32))
        bs = per * cm.minmax_bs(w) * self.sys.bs_batches(_elems(out))
        return self._compute(eqn, infos, prim, bp, bs, w)

    def _cumulative(self, eqn, infos, prim) -> None:
        src = eqn.invars[0].aval
        axis = eqn.params.get("axis", 0)
        length = src.shape[axis] if src.shape else 1
        steps = _ceil_log2(max(2, length))
        n = _elems(src)
        w = _dtype_bits(src.dtype)
        per_bp, per_bs = _elem_cost(
            "mul" if prim == "cumprod" else "add", w)
        bp = steps * per_bp * self.sys.bp_batches(n, min(w, 32))
        bs = steps * per_bs * self.sys.bs_batches(n)
        return self._compute(eqn, infos, prim, bp, bs, w)

    def _topk(self, eqn, infos, prim) -> None:
        src = eqn.invars[0].aval
        w = _dtype_bits(src.dtype)
        kk = int(eqn.params.get("k", 1)) if prim != "sort" else 1
        length = src.shape[-1] if src.shape else 1
        outs = max(1, _elems(src) // max(1, length))
        steps = (kk * _ceil_log2(max(2, length)) if prim != "sort"
                 else _ceil_log2(max(2, length)) ** 2)
        bp = steps * cm.minmax_bp(w) * self.sys.bp_batches(outs, min(w, 32))
        bs = steps * cm.minmax_bs(w) * self.sys.bs_batches(outs)
        return self._compute(eqn, infos, prim, bp, bs, w, control=0.4)

    def _elementwise(self, eqn, infos, prim) -> None:
        out = eqn.outvars[0].aval
        n = _elems(out)
        w = _dtype_bits(out.dtype)
        per_bp, per_bs = _elem_cost(prim, w)
        bp = per_bp * self.sys.bp_batches(n, min(w, 32))
        bs = per_bs * self.sys.bs_batches(n)
        if bp == 0 and bs == 0:
            merged = _VarInfo.union(infos)
            for v in eqn.outvars:
                self.write(v, merged)
            return
        return self._compute(eqn, infos, prim, bp, bs, w)


def trace_workload(fn: Callable, *example_args,
                   precision_map: Optional[dict[str, int]] = None,
                   name: str = "traced", description: str = "",
                   source: str = "traced", default_width: int = 16,
                   sys: SystemParams = PAPER_SYSTEM,
                   scan_mode: str = "once", matmul_chunk: int = 64,
                   matmul_streamed_working_set: bool = True) -> Workload:
    """Trace ``fn(*example_args)`` into a :class:`Workload` DAG.

    ``example_args`` may be (pytrees of) ``jax.ShapeDtypeStruct`` --
    tracing is abstract, nothing is allocated.  ``precision_map`` maps
    param-path substrings (``blocks/0/attn/wqkv``; see
    :func:`param_path_widths`) to operand widths in bits.

    ``scan_mode``: ``"once"`` (default) lowers every ``lax.scan`` body a
    single time -- the traced workload is one representative layer / KV
    chunk, directly comparable to the per-layer ``arch/<id>`` formulas;
    ``"unroll"`` replicates the body ``length`` times.

    ``matmul_streamed_working_set=True`` pins matmul
    ``working_set_bits`` to the streamed-MAC live set (``8 * width``),
    the serving convention of ``registry.arch_workload``; pass False to
    keep the weight-stationary default of ``Op.features()``.
    """
    import jax

    if scan_mode not in ("once", "unroll"):
        raise ValueError(f"scan_mode must be 'once' or 'unroll', "
                         f"got {scan_mode!r}")
    closed = jax.make_jaxpr(fn)(*example_args)
    paths = jax.tree_util.tree_flatten_with_path(example_args)[0]
    t = _Tracer(precision_map=precision_map, default_width=default_width,
                sys=sys, scan_mode=scan_mode, matmul_chunk=matmul_chunk,
                matmul_working_set=(
                    (lambda w: w * 8) if matmul_streamed_working_set
                    else None))
    invar_infos = [
        _VarInfo(origins=frozenset({_format_path(path)}))
        for path, _leaf in paths]
    if len(invar_infos) != len(closed.jaxpr.invars):  # pragma: no cover
        raise AssertionError(
            f"flattened args ({len(invar_infos)}) != jaxpr invars "
            f"({len(closed.jaxpr.invars)})")
    t.trace(closed.jaxpr, invar_infos)
    if not t.ops:
        raise ValueError(f"trace of {name!r} produced no ops "
                         "(nothing costable in the jaxpr)")
    return Workload(name=name, ops=tuple(t.ops), source=source,
                    description=description or
                    f"jaxpr-traced workload ({len(t.ops)} ops)",
                    deps=tuple(sorted(t.deps)))
