"""Backend protocol + the four evaluation backends over the workload IR.

Every backend answers the same question -- "what does this workload cost?"
-- through a different lens, behind one protocol::

    class Backend(Protocol):
        name: str
        def supports(self, workload) -> bool
        def estimate(self, workload, sys=PAPER_SYSTEM) -> Report

* :class:`AnalyticBackend`  -- the paper's closed-form cycle model
  (``core.cost_model`` / ``core.microkernels``): per-op
  load/compute/readout in both static layouts.
* :class:`PlannerBackend`   -- compiles the workload DAG into an
  executable ``repro.plan`` LayoutPlan (per-step BP/BS assignment with
  explicit transposes; chains == the legacy 2-state DP bit-for-bit):
  BP/BS/hybrid + schedule, optional executor replay (``execute=True``).
* :class:`ExecutorBackend`  -- lowers ops to ``repro.pim.programs``
  micro-op programs where available and reports *executed* cycle counts;
  matmul/conv MACs decompose into ``multu`` + ``vector_add`` programs.
  Documented executed-vs-analytic calibration deltas (DESIGN.md Sec. 8)
  surface in ``OpReport.note`` and ``Report.notes``.
* :class:`PallasBackend`    -- dispatches the grid-tiled ``kernels.ops``
  Pallas matmuls over the *whole op* (padded only to hardware-minimum
  tiles, true widths, honest ``supported=False`` for over-budget or
  over-width ops) and measures wall-clock (on CPU these are
  interpret-mode correctness-path timings, as in benchmarks/).

``Report.summary`` keys shared by the cycle backends: ``bp_cycles``,
``bs_cycles`` (static totals over supported ops) plus backend-specific
extras (``hybrid_cycles``/``schedule`` for the planner, ``coverage`` for
the executor).  ``OpReport.energy_nj`` is reserved: the source paper
publishes no energy model, so no backend populates it yet.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Union, runtime_checkable

from repro.core.cost_model import Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.workloads.ir import Op, Workload, op_cost

#: version of the Report/OpReport dict schema (bump on breaking field
#: changes; ``Report.from_dict`` refuses newer versions).  Every committed
#: bench artifact (characterize.json, plans.json, serve.json) carries this
#: same version inside the ``repro.artifacts`` envelope.
REPORT_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class OpReport:
    """Per-op result row of one backend."""

    op: str
    kind: str
    supported: bool = True
    bp_cycles: Optional[int] = None
    bs_cycles: Optional[int] = None
    #: layout -> (load, compute, readout); analytic backend only
    breakdown: Optional[dict] = None
    #: wall-clock microseconds (Pallas backend)
    bp_us: Optional[float] = None
    bs_us: Optional[float] = None
    #: reserved -- the paper publishes no energy model (DESIGN.md Sec. 5)
    energy_nj: Optional[float] = None
    #: true (m, k, n) the op lowers to, and the dims actually run after
    #: hardware-minimum tile padding (Pallas backend; additive in schema
    #: v1 -- measurements must never misstate what was run)
    dims: Optional[tuple[int, int, int]] = None
    padded_dims: Optional[tuple[int, int, int]] = None
    note: str = ""

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if d["breakdown"] is not None:
            d["breakdown"] = {k: list(v) for k, v in d["breakdown"].items()}
        for key in ("dims", "padded_dims"):
            if d[key] is not None:
                d[key] = list(d[key])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "OpReport":
        d = dict(d)
        if d.get("breakdown"):
            d["breakdown"] = {k: tuple(v)
                              for k, v in d["breakdown"].items()}
        for key in ("dims", "padded_dims"):
            if d.get(key) is not None:
                d[key] = tuple(d[key])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class Report:
    """One backend's estimate for one workload."""

    workload: str
    backend: str
    ops: tuple[OpReport, ...]
    summary: dict
    notes: tuple[str, ...] = ()

    def to_dict(self) -> dict:
        """Versioned dict form -- the one schema all bench-artifact
        consumers parse (round-trip pinned in tests/test_serve.py)."""
        return {
            "schema_version": REPORT_SCHEMA_VERSION,
            "workload": self.workload,
            "backend": self.backend,
            "ops": [op.to_dict() for op in self.ops],
            "summary": dict(self.summary),
            "notes": list(self.notes),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Report":
        ver = d.get("schema_version", REPORT_SCHEMA_VERSION)
        if ver > REPORT_SCHEMA_VERSION:
            raise ValueError(
                f"report schema v{ver} is newer than this reader "
                f"(v{REPORT_SCHEMA_VERSION})")
        return cls(workload=d["workload"], backend=d["backend"],
                   ops=tuple(OpReport.from_dict(o) for o in d["ops"]),
                   summary=dict(d["summary"]),
                   notes=tuple(d.get("notes", ())))


@runtime_checkable
class Backend(Protocol):
    """The protocol all evaluation surfaces implement.

    `sys` is explicit everywhere: backends never silently assume
    `PAPER_SYSTEM` beyond the default argument, so sweeps can re-cost the
    same workload under any geometry (tests/test_sweep.py pins that a
    non-default geometry actually changes reported cycles).
    """

    name: str

    def supports(self, workload: Workload) -> bool:
        """Can this backend say anything useful about the workload?"""
        ...

    def estimate(self, workload: Workload,
                 sys: SystemParams = PAPER_SYSTEM) -> Report:
        ...

    def estimate_many(self, workloads,
                      sys: SystemParams = PAPER_SYSTEM) -> list[Report]:
        """Batched estimates (one geometry, many workloads)."""
        ...


class _SequentialEstimateMany:
    """Default `estimate_many`: sequential `estimate` calls.

    Backends with a vectorizable cost surface override this (the analytic
    backend batches single-kernel workloads into one jitted evaluation via
    `repro.sweep.vectorized`); the DP/replay/wall-clock backends keep the
    loop -- their per-workload state is inherently sequential.
    """

    def estimate_many(self, workloads,
                      sys: SystemParams = PAPER_SYSTEM) -> list[Report]:
        return [self.estimate(w, sys) for w in workloads]


# ---------------------------------------------------------------------------
# Analytic
# ---------------------------------------------------------------------------

class AnalyticBackend(_SequentialEstimateMany):
    """Closed-form paper cost model: per-op CycleCost in both layouts."""

    name = "analytic"

    def supports(self, workload: Workload) -> bool:
        return True

    def estimate_many(self, workloads,
                      sys: SystemParams = PAPER_SYSTEM) -> list[Report]:
        """One jitted batched evaluation when every workload is a single
        Table-5 kernel op (the ``mk/*`` registry shape); bit-for-bit equal
        to the scalar `estimate` loop (pinned by tests/test_sweep.py).
        Mixed-op workloads fall back to the sequential default."""
        workloads = list(workloads)
        if not workloads or not all(
                len(w.ops) == 1 and w.ops[0].kind == "kernel"
                for w in workloads):
            return super().estimate_many(workloads, sys)
        from repro.sweep.vectorized import eval_points

        triples = tuple((w.ops[0].kernel, w.ops[0].n, w.ops[0].width)
                        for w in workloads)
        try:
            table = eval_points(triples, cols=sys.array.cols,
                                arrays=sys.num_arrays,
                                row_bw=sys.row_bandwidth_bits)
        except ValueError:
            # operating point exceeds the int32 vectorized range --
            # the python-int scalar path has no such limit
            return super().estimate_many(workloads, sys)
        out = []
        for w, cell in zip(workloads, table):
            op = w.ops[0]
            bd = {lay.value: tuple(int(x) for x in cell[i])
                  for i, lay in enumerate((Layout.BP, Layout.BS))}
            bp = sum(bd["BP"])
            bs = sum(bd["BS"])
            out.append(Report(
                workload=w.name, backend=self.name,
                ops=(OpReport(op=op.name, kind=op.kind, bp_cycles=bp,
                              bs_cycles=bs, breakdown=bd),),
                summary={"bp_cycles": bp, "bs_cycles": bs,
                         "bs_over_bp": bs / bp if bp else float("inf")}))
        return out

    def estimate(self, workload: Workload,
                 sys: SystemParams = PAPER_SYSTEM) -> Report:
        rows = []
        tot = {Layout.BP: 0, Layout.BS: 0}
        for op in workload.ops:
            costs = {lay: op_cost(op, lay, sys)
                     for lay in (Layout.BP, Layout.BS)}
            for lay, c in costs.items():
                tot[lay] += c.total
            rows.append(OpReport(
                op=op.name, kind=op.kind,
                bp_cycles=costs[Layout.BP].total,
                bs_cycles=costs[Layout.BS].total,
                breakdown={lay.value: (c.load, c.compute, c.readout)
                           for lay, c in costs.items()}))
        bp, bs = tot[Layout.BP], tot[Layout.BS]
        return Report(
            workload=workload.name, backend=self.name, ops=tuple(rows),
            summary={"bp_cycles": bp, "bs_cycles": bs,
                     "bs_over_bp": bs / bp if bp else float("inf")})


# ---------------------------------------------------------------------------
# Planner (hybrid DP)
# ---------------------------------------------------------------------------

class PlannerBackend(_SequentialEstimateMany):
    """Compile the workload DAG into an executable ``repro.plan``
    :class:`~repro.plan.ir.LayoutPlan` (per-step BP/BS assignment with
    explicit transposes at layout boundaries; linear chains reproduce the
    legacy 2-state DP bit-for-bit).

    ``execute=True`` additionally lowers the plan's executable ops to
    their ``pim.programs`` micro-op programs in the *assigned* layout and
    replays them on the simulated-array executor; the predicted (analytic)
    vs executed cycle pairs land in ``Report.notes`` (deltas must equal
    the documented Sec.-8 calibration catalogue).
    """

    name = "planner"

    def __init__(self, execute: bool = False):
        self.execute = execute

    def supports(self, workload: Workload) -> bool:
        return True

    def compile(self, workload: Workload,
                sys: SystemParams = PAPER_SYSTEM, **kwargs):
        """Compile the workload into its :class:`~repro.plan.ir.LayoutPlan`
        (the artifact ``estimate`` summarizes).  The serving path
        (``repro.serve.PlanService``) resolves this backend through
        :func:`get_backend` and calls ``compile`` per request."""
        from repro.plan import compile_plan

        return compile_plan(workload, sys, **kwargs)

    def estimate(self, workload: Workload,
                 sys: SystemParams = PAPER_SYSTEM) -> Report:
        from repro.plan import replay_plan

        p = self.compile(workload, sys)
        rows, notes = [], []
        for oi, op in enumerate(workload.ops):
            steps = [s for s in p.steps if s.op_index == oi]
            rows.append(OpReport(
                op=op.name, kind=op.kind,
                bp_cycles=sum(s.bp_cycles for s in steps),
                bs_cycles=sum(s.bs_cycles for s in steps),
                note="sched=" + "/".join(s.layout.value for s in steps)))
        if not p.feasible:
            bad = p.infeasible_steps
            notes.append(
                f"{len(bad)} step(s) overflow the {p.geometry.label()} "
                "row budget in their assigned layout (modelled via "
                f"explicit spills): {', '.join(s.phase for s in bad[:4])}"
                + (" ..." if len(bad) > 4 else ""))
        if self.execute:
            for r in replay_plan(p, workload, sys):
                if r["predicted"] is None:
                    notes.append(f"replay {r['op']} [{r['layout']}]: "
                                 f"executed={r['executed']} ({r['note']})")
                else:
                    notes.append(
                        f"replay {r['op']} [{r['layout']}]: "
                        f"predicted={r['predicted']} "
                        f"executed={r['executed']} delta={r['delta']:+d} "
                        f"(expected {r['expected_delta']:+d})")
        return Report(
            workload=workload.name, backend=self.name, ops=tuple(rows),
            summary={
                "bp_cycles": p.static_bp,
                "bs_cycles": p.static_bs,
                "hybrid_cycles": p.total_cycles,
                "hybrid_speedup": p.hybrid_speedup,
                "is_hybrid": p.is_hybrid,
                "n_transposes": p.n_transposes,
                "transpose_cycles": p.transpose_cycles_total,
                "best_static_layout": p.best_static_layout.value,
            },
            notes=tuple(notes))


# ---------------------------------------------------------------------------
# Executor (micro-op programs on the simulated array)
# ---------------------------------------------------------------------------

class ExecutorBackend(_SequentialEstimateMany):
    """Executed micro-op cycle counts (``repro.pim.programs``).

    Coverage: ``kernel`` ops with a builder in ``programs.BUILDERS`` run
    directly; ``matmul``/``conv`` MACs lower to k x ``multu`` +
    (k-1) x ``vector_add`` programs per output batch.  ``movement`` and
    bespoke ``compute`` ops have no micro-op program (the bus and the
    hand-calibrated crypto rounds are modelled analytically only) and are
    reported unsupported; ``summary["coverage"]`` is the supported-op
    fraction.
    """

    name = "executor"

    def supports(self, workload: Workload) -> bool:
        return any(self._op_supported(op) for op in workload.ops)

    @staticmethod
    def _op_supported(op: Op) -> bool:
        from repro.pim import programs as pr

        if op.kind in ("matmul", "conv"):
            return True
        return (op.kind == "kernel"
                and (op.kernel, Layout.BP) in pr.BUILDERS
                and (op.kernel, Layout.BS) in pr.BUILDERS)

    @staticmethod
    def _mac_cycles(op: Op, layout: Layout, sys: SystemParams) -> int:
        """k multiplies + (k-1) double-width accumulates per output,
        times capacity batches over the outputs."""
        from repro.pim import programs as pr

        k = op.k
        outs = op.m * op.n if op.kind == "matmul" else op.n
        mult = pr.build("multu", layout, width=op.width).cycles
        add = pr.build("vector_add", layout, width=2 * op.width).cycles
        batches = (sys.bp_batches(outs, op.width) if layout is Layout.BP
                   else sys.bs_batches(outs))
        return (k * mult + (k - 1) * add) * batches

    def estimate(self, workload: Workload,
                 sys: SystemParams = PAPER_SYSTEM) -> Report:
        from repro.pim import programs as pr

        rows, notes = [], []
        tot = {Layout.BP: 0, Layout.BS: 0}
        supported = 0
        for op in workload.ops:
            if op.kind == "kernel" and self._op_supported(op):
                cyc, note_parts = {}, []
                for lay in (Layout.BP, Layout.BS):
                    n_eff = op.n if op.kernel == "reduction" \
                        and lay is Layout.BP else None
                    prog = pr.build(op.kernel, lay, width=op.width, n=n_eff)
                    batches = (sys.bp_batches(op.n, op.width)
                               if lay is Layout.BP else sys.bs_batches(op.n))
                    cyc[lay] = prog.cycles * batches
                    if prog.expected_delta:
                        note_parts.append(
                            f"{lay.value}: delta={prog.expected_delta:+d} "
                            f"({prog.calibration_note})")
                note = "; ".join(note_parts)
                if note:
                    notes.append(f"{op.name}: {note}")
                rows.append(OpReport(op=op.name, kind=op.kind,
                                     bp_cycles=cyc[Layout.BP],
                                     bs_cycles=cyc[Layout.BS], note=note))
            elif op.kind in ("matmul", "conv"):
                cyc = {lay: self._mac_cycles(op, lay, sys)
                       for lay in (Layout.BP, Layout.BS)}
                rows.append(OpReport(
                    op=op.name, kind=op.kind, bp_cycles=cyc[Layout.BP],
                    bs_cycles=cyc[Layout.BS],
                    note="lowered to multu + vector_add programs"))
            else:
                why = ("no micro-op program for kernel "
                       f"{op.kernel!r}" if op.kind == "kernel" else
                       f"{op.kind} ops are modelled analytically only")
                rows.append(OpReport(op=op.name, kind=op.kind,
                                     supported=False, note=why))
                continue
            supported += 1
            tot[Layout.BP] += rows[-1].bp_cycles
            tot[Layout.BS] += rows[-1].bs_cycles
        return Report(
            workload=workload.name, backend=self.name, ops=tuple(rows),
            summary={"bp_cycles": tot[Layout.BP], "bs_cycles": tot[Layout.BS],
                     "coverage": supported / len(workload.ops),
                     "supported_ops": supported, "total_ops": len(workload.ops)},
            notes=tuple(notes))


# ---------------------------------------------------------------------------
# Pallas (measured wall-clock of the TPU-analogue kernels)
# ---------------------------------------------------------------------------

#: widest BS weight the bitplane kernels support (uint32 plane words)
PALLAS_MAX_BS_WIDTH = 32
#: default per-launch padded-MAC budget (x plane passes for BS):
#: interpret-mode throughput is ~10^8 MAC/s, so 2^31 bounds one launch
#: to tens of seconds instead of silently clamping the problem
PALLAS_MAX_MACS = 2 ** 31


class PallasBackend(_SequentialEstimateMany):
    """Measure wall-clock of the grid-tiled Pallas kernels over the
    *whole op* in both layouts: the BP word kernel vs the BS bitplane
    kernel at the op's **true** weight precision (one plane pass per
    bit -- never capped).  Dims are padded only up to each kernel's
    hardware-minimum tile multiples (``kernels.tiling``); both the true
    and the padded dims land in the ``OpReport`` so a report can never
    misstate what was run.  Ops whose padded MAC volume exceeds
    ``max_macs`` -- or whose width exceeds the kernels' 32-plane limit --
    report ``supported=False`` with an honest note instead of a clamped
    or understated number.  Timings are the median of ``reps``
    post-warmup calls with ``block_until_ready`` (never a single cold
    wall-clock sample).  ``fused=True`` (default) times the BS side as
    the one-kernel fused bitpack-matmul; ``fused=False`` times the
    unfused pack->matmul pipeline, pack pass included."""

    name = "pallas"

    def __init__(self, tile: int = 128, interpret: bool = True,
                 reps: int = 5, max_macs: int = PALLAS_MAX_MACS,
                 fused: bool = True):
        self.tile = tile
        self.interpret = interpret
        self.reps = reps
        self.max_macs = max_macs
        self.fused = fused

    def supports(self, workload: Workload) -> bool:
        return any(op.kind in ("matmul", "conv") for op in workload.ops)

    def _dims(self, op: Op) -> tuple[int, int, int]:
        """True (m, k, n) of the matmul the op lowers to -- un-clamped.

        Conv follows the same lowering ``ExecutorBackend`` prices:
        ``op.n`` im2col output elements, each a ``op.k``-deep MAC chain,
        i.e. a GEMV ``(op.n, op.k) @ (op.k, 1)``.
        """
        if op.kind == "conv":
            return op.n, op.k, 1
        return op.m, op.k, op.n

    def _tilings(self, m: int, k: int, n: int):
        """(BP tiling, BS tiling) at this backend's block-size hint."""
        from repro.kernels import tiling as tl

        t = self.tile
        bp = tl.bp_tiling(m, k, n, block_m=t, block_n=t, block_k=t)
        bs = (tl.fused_tiling(m, k, n, block_m=t, block_n=t, block_k=t)
              if self.fused else
              tl.bs_tiling(m, k, n, block_m=t, block_n=t,
                           block_k=max(t, 256)))
        return bp, bs

    def estimate(self, workload: Workload,
                 sys: SystemParams = PAPER_SYSTEM) -> Report:
        import statistics
        import time

        import numpy as np
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        del sys  # wall-clock backend: the host, not the modelled system
        rng = np.random.default_rng(0)
        rows = []
        tot_bp = tot_bs = 0.0
        measured = 0

        def clock(fn):
            """Median of `reps` timed calls after a compile/warmup call;
            `block_until_ready` keeps async dispatch out of the sample."""
            jax.block_until_ready(fn())  # warmup / compile
            samples = []
            for _ in range(self.reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                samples.append((time.perf_counter() - t0) * 1e6)
            return statistics.median(samples)

        bk = dict(block_m=self.tile, block_n=self.tile, block_k=self.tile)
        for op in workload.ops:
            if op.kind not in ("matmul", "conv"):
                rows.append(OpReport(op=op.name, kind=op.kind,
                                     supported=False,
                                     note="no Pallas kernel for this op"))
                continue
            m, k, n = self._dims(op)
            bits = max(1, op.width)
            if bits > PALLAS_MAX_BS_WIDTH:
                rows.append(OpReport(
                    op=op.name, kind=op.kind, supported=False,
                    dims=(m, k, n),
                    note=f"unsupported: width {bits} > "
                         f"{PALLAS_MAX_BS_WIDTH} plane passes "
                         "(uint32 plane words) -- not measured"))
                continue
            bp_t, bs_t = self._tilings(m, k, n)
            work = max(bp_t.padded_macs, bs_t.padded_macs * bits)
            if work > self.max_macs:
                rows.append(OpReport(
                    op=op.name, kind=op.kind, supported=False,
                    dims=(m, k, n), padded_dims=bp_t.padded_dims,
                    note=f"over budget: {work} padded MACs (BS work = "
                         f"{bits} planes) > max_macs={self.max_macs} "
                         "-- not measured"))
                continue
            x = jnp.asarray(rng.integers(-8, 8, (m, k), dtype=np.int32)
                            ).astype(jnp.int8)
            w = jnp.asarray(rng.integers(0, 1 << min(bits, 31),
                                         (k, n)).astype(np.int32))
            wp = w.astype(kops.bp_weight_dtype(bits))
            bp_us = clock(lambda: kops.matmul_bp(
                x, wp, interpret=self.interpret, **bk))
            if self.fused:
                bs_us = clock(lambda: kops.matmul_bs_fused(
                    x, w, bits, interpret=self.interpret, **bk))
                bs_note = "fused"
            else:
                # unfused: the pack pass is part of the measured BS path
                def bs_fn():
                    planes = kops.pack_weights(w.astype(jnp.uint32), bits,
                                               interpret=self.interpret)
                    return kops.matmul_bs(x, planes,
                                          interpret=self.interpret)
                bs_us = clock(bs_fn)
                bs_note = "unfused (pack on path)"
            rec = kops.choose_layout(weight_bits=bits, m=m, n=n, k=k)
            rows.append(OpReport(
                op=op.name, kind=op.kind, bp_us=bp_us, bs_us=bs_us,
                dims=(m, k, n), padded_dims=bp_t.padded_dims,
                note=f"{m}x{k}x{n}@{bits}b "
                     f"padded_bp={'x'.join(map(str, bp_t.padded_dims))} "
                     f"padded_bs={'x'.join(map(str, bs_t.padded_dims))} "
                     f"bs={bs_note}; choose_layout={rec.value}"))
            tot_bp += bp_us
            tot_bs += bs_us
            measured += 1
        return Report(
            workload=workload.name, backend=self.name, ops=tuple(rows),
            summary={"bp_us": tot_bp, "bs_us": tot_bs,
                     "measured_ops": measured, "total_ops": len(workload.ops),
                     "coverage": measured / len(workload.ops)},
            notes=("wall-clock of interpret-mode Pallas kernels over full "
                   "op dims (correctness-path on CPU; see "
                   "benchmarks/pallas_bench)",)
            if measured else ())


# ---------------------------------------------------------------------------
# Registry + the single entry point
# ---------------------------------------------------------------------------

#: the registered name -> class table every construction site resolves
#: through (:func:`get_backend`); CLI ``--backends`` choices are generated
#: from it.  Register new backends here (or via :func:`register_backend`)
#: instead of importing classes directly -- direct backend imports are a
#: deprecated construction path (DESIGN.md Sec. 5).
BACKENDS: dict[str, type] = {
    "analytic": AnalyticBackend,
    "planner": PlannerBackend,
    "executor": ExecutorBackend,
    "pallas": PallasBackend,
}


def register_backend(name: str, cls: type) -> None:
    """Register a Backend class under ``name`` (overwrites allowed so
    tests can shadow a backend with an instrumented double)."""
    BACKENDS[name] = cls


def backend_names() -> list[str]:
    """Registered backend names, sorted (the CLI choice list)."""
    return sorted(BACKENDS)


def get_backend(spec: Union[str, Backend], **opts) -> Backend:
    """THE backend factory: resolve a registry name (with constructor
    options) or pass an already-built instance through.

    ``get_backend("planner", execute=True)`` ==
    ``PlannerBackend(execute=True)`` without importing the class --
    `__main__`, ``characterize``, benchmarks, and the serving path all
    construct backends this way.
    """
    if isinstance(spec, str):
        try:
            cls = BACKENDS[spec]
        except KeyError:
            raise KeyError(f"unknown backend {spec!r} "
                           f"(known: {', '.join(backend_names())})") from None
        return cls(**opts)
    if opts:
        raise TypeError("constructor options only apply to registry names, "
                        f"not already-built instances ({spec!r})")
    return spec


def characterize(workload: Union[str, Workload],
                 backends=("analytic", "planner"),
                 sys: SystemParams = PAPER_SYSTEM) -> dict[str, Report]:
    """THE entry point: one workload, many backends -> {backend: Report}.

    `workload` is a registry name (e.g. ``"vgg"``, ``"mk/multu"``,
    ``"arch/tinyllama_1_1b"``) or a :class:`Workload` instance; `backends`
    is a sequence of registry names and/or Backend instances.
    """
    from repro.workloads.registry import get_workload

    w = get_workload(workload) if isinstance(workload, str) else workload
    out: dict[str, Report] = {}
    for spec in backends:
        b = get_backend(spec)
        out[b.name] = b.estimate(w, sys)
    return out
