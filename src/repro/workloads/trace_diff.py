"""Differential reconciliation: ``traced/<id>`` vs the ``arch/<id>`` formulas.

The hand-written serving formulas (``registry.arch_workload``) and the
jaxpr tracer (``trace.trace_workload``) describe the same forward pass
from opposite ends.  This module pins them against each other, op by op:

* every formula op is matched to a traced op by its *predicted* traced
  dims (:func:`expected_matmuls` -- the normative catalogue, mirrored in
  DESIGN.md Sec. 12);
* ``exact`` matches (identical m/k/n/width, so identical cost inputs)
  must agree to the cycle on every static backend
  (:data:`GATED_BACKENDS`);
* ``divergent`` matches carry a documented reason (flash chunking,
  capacity-grouped experts, all-head SSD contraction, ...) and their
  deltas are recorded, never asserted;
* every *remaining* traced op must be explained by a lowering rule
  (:func:`_extra_note`) -- sibling projections, PV chunks, MoE
  dispatch/combine, cache movement -- or the gate fails.

:func:`run_diff` drives the full matrix and :func:`write_csv` emits the
``bench-artifacts/traced_vs_formula.csv`` artifact (per-op and TOTAL
rows per backend).  CLI: ``python -m repro trace-diff``.
"""
from __future__ import annotations

import csv
import dataclasses
import math
from typing import Optional, Sequence

from repro.core.params import PAPER_SYSTEM, SystemParams
from repro.workloads.backends import characterize
from repro.workloads.ir import Op, Workload
from repro.workloads.registry import ARCH_IDS, arch_workload, get_workload

__all__ = ["GATED_BACKENDS", "CSV_COLUMNS", "Expected", "OpRow",
           "expected_matmuls", "expected_vgg", "reconcile",
           "reconcile_vgg", "gate_failures", "run_diff", "write_csv"]

#: static backends on which an ``exact`` match must agree to the cycle
GATED_BACKENDS = ("analytic", "planner", "executor")


@dataclasses.dataclass(frozen=True)
class Expected:
    """Predicted traced counterpart of one formula op."""

    formula: str  # formula op name (arch_workload / _vgg_ops)
    kind: str  # "matmul" | "conv"
    dims: tuple  # matmul: (m, k, n, width); conv: (n, k)
    status: str  # "exact" | "divergent"
    note: str = ""


@dataclasses.dataclass(frozen=True)
class OpRow:
    """One CSV row: a formula/traced op pair (or one unmatched side)."""

    arch: str
    backend: str
    status: str  # exact | divergent | missing | traced-only | total
    op_formula: str
    op_traced: str
    kind: str
    m_formula: Optional[int] = None
    k_formula: Optional[int] = None
    n_formula: Optional[int] = None
    w_formula: Optional[int] = None
    m_traced: Optional[int] = None
    k_traced: Optional[int] = None
    n_traced: Optional[int] = None
    w_traced: Optional[int] = None
    bp_formula: Optional[float] = None
    bs_formula: Optional[float] = None
    bp_traced: Optional[float] = None
    bs_traced: Optional[float] = None
    bp_delta: Optional[float] = None
    bs_delta: Optional[float] = None
    unit: str = "cycles"  # cycles | us
    explained: bool = True
    note: str = ""


CSV_COLUMNS = [f.name for f in dataclasses.fields(OpRow)]


# ---------------------------------------------------------------------------
# The expected-dims catalogue (DESIGN.md Sec. 12)
# ---------------------------------------------------------------------------

def _flash_chunk(seq: int) -> int:
    """KV chunk used by ``models.layers.flash_attention``: the largest
    divisor of ``seq`` that is <= ``util.flash_chunk_default()``."""
    from repro.util import flash_chunk_default

    chunk = min(flash_chunk_default(), seq)
    while seq % chunk:
        chunk -= 1
    return chunk


def _moe_grouping(cfg, tokens: int) -> tuple[int, int, int]:
    """(group_tokens, n_groups, capacity) as ``models.layers.moe_block``
    computes them for ``tokens`` decode sequences (B*S = tokens)."""
    t_grp = min(512, tokens)
    while tokens % t_grp:
        t_grp //= 2
    groups = tokens // t_grp
    cap = int(math.ceil(t_grp * cfg.top_k * cfg.capacity_factor
                        / cfg.n_experts))
    return t_grp, groups, cap


def expected_matmuls(cfg, *, tokens: int = 4096,
                     weight_bits: int = 4) -> list[Expected]:
    """Predicted traced dims for every ``arch_workload`` formula op, in
    formula order.  ``exact`` entries equal the formula's own dims;
    ``divergent`` entries are the documented lowering differences."""
    T, D, wb = tokens, cfg.d_model, weight_bits
    out: list[Expected] = []
    if cfg.family == "ssm":
        din = cfg.d_inner
        proj = 2 * din + 2 * cfg.ssm_state + cfg.ssm_heads
        out.append(Expected("in_proj", "matmul", (T, D, proj, wb), "exact"))
        out.append(Expected(
            "ssd_scan", "matmul",
            (T, cfg.ssm_state, cfg.ssm_heads * cfg.ssm_head_dim, 16),
            "divergent",
            "formula scores one SSM head (n=head_dim); the trace contracts "
            "all heads in one state-readout einsum (n = heads x head_dim)"))
        out.append(Expected("out_proj", "matmul", (T, din, D, wb), "exact"))
        return out
    if cfg.n_heads and cfg.n_kv_heads:
        chunk = _flash_chunk(T)
        group = cfg.n_heads // cfg.n_kv_heads
        out.append(Expected("qkv_proj", "matmul",
                            (T, D, cfg.qkv_dim, wb), "exact"))
        out.append(Expected(
            "attn_scores", "matmul",
            (T * cfg.n_kv_heads * chunk, cfg.head_dim, group, 16),
            "divergent",
            f"formula scores a dense TxT map; the trace is flash-chunked "
            f"(chunk={chunk}) per KV head, {group} query heads per KV "
            f"head"))
        out.append(Expected("o_proj", "matmul",
                            (T, cfg.n_heads * cfg.head_dim, D, wb),
                            "exact"))
    if cfg.n_experts:
        _t_grp, groups, cap = _moe_grouping(cfg, T)
        out.append(Expected("router", "matmul",
                            (T, D, cfg.n_experts, 16), "exact"))
        out.append(Expected(
            "expert_ffn", "matmul",
            (cfg.n_experts * cfg.d_ff, D, groups * cap, wb), "divergent",
            "formula scores a token-major top_k*T GEMM; the trace is the "
            "capacity-grouped expert einsum (lhs = stacked expert "
            f"weights, rhs = {groups} groups x capacity {cap})"))
    elif cfg.d_ff:
        out.append(Expected("ffn", "matmul", (T, D, cfg.d_ff, wb),
                            "exact"))
    if cfg.family == "hybrid":
        width = cfg.lru_width
        out.append(Expected("rg_lru_gates", "matmul",
                            (T, width, width, 16), "exact"))
    return out


def expected_vgg(which: str = "vgg16") -> list[Expected]:
    """Predicted traced dims for the Table-6 VGG formula ops."""
    from repro.models.vgg import VGG_BATCH, VGG_BLOCKS, VGG_FCS

    out: list[Expected] = []
    c_in = 3
    for bi, (c, s, reps) in enumerate(VGG_BLOCKS[which]):
        n_out = c * s * s * VGG_BATCH
        for r in range(reps):
            out.append(Expected(
                f"b{bi}c{r}", "conv", (n_out, 9 * c_in), "divergent",
                "formula counts the 3x3 spatial taps (k=9); the trace "
                "contracts taps x C_in"))
            c_in = c
    for fi, (k, n) in enumerate(VGG_FCS):
        out.append(Expected(
            f"fc{fi}", "matmul", (VGG_BATCH, k, n, 16), "divergent",
            "formula scores one image (m=1); the trace batches "
            f"{VGG_BATCH} images"))
    return out


def _extra_note(op: Op, cfg, tokens: int,
                weight_bits: int) -> Optional[str]:
    """Explain a traced op with no formula counterpart; None = unexplained
    (gate failure)."""
    if op.kind == "compute":
        return ("activation/normalization arithmetic the formulas fold "
                "into control_intensity")
    if op.kind == "movement":
        return "KV/state cache update; the formulas model compute only"
    if op.kind != "matmul":
        return None
    T, D, wb = tokens, cfg.d_model, weight_bits
    fdims = {D, cfg.qkv_dim, cfg.n_heads * cfg.head_dim, cfg.d_ff,
             cfg.padded_vocab, cfg.lru_width}
    if cfg.ssm_state:
        fdims |= {cfg.d_inner,
                  2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads}
    fdims.discard(0)
    if (op.m == T and op.k in fdims and op.n in fdims
            and op.width in (wb, 16)):
        return ("per-token linear projection (sibling/down/head of a "
                "formula op)")
    chunks = {_flash_chunk(T)}
    if cfg.enc_seq:  # cross-attention reads the encoder sequence
        chunks.add(_flash_chunk(cfg.enc_seq))
    if cfg.n_heads and cfg.n_kv_heads:
        group = cfg.n_heads // cfg.n_kv_heads
        for chunk in chunks:
            if (op.width == 16 and op.m == T * cfg.n_kv_heads * chunk
                    and op.k == cfg.head_dim and op.n == group):
                return f"flash-attention score chunk (chunk={chunk})"
            if (op.width == 16 and op.m == T * cfg.n_heads
                    and op.k == chunk and op.n == cfg.head_dim):
                return f"flash-attention PV chunk (chunk={chunk})"
    if cfg.ssm_state:
        if (op.width == 16 and op.k == 1 and op.n == cfg.ssm_state
                and op.m == T * cfg.d_inner):
            return "SSD state outer-product update (rank-1 per channel)"
    if cfg.n_experts:
        t_grp, groups, cap = _moe_grouping(cfg, T)
        e, f = cfg.n_experts, cfg.d_ff
        if op.width == wb and (op.m, op.k, op.n) == (e * f, D,
                                                     groups * cap):
            return "stacked expert up/gate projection (expert_ffn sibling)"
        if op.width == wb and (op.m, op.k, op.n) == (e * D, f,
                                                     groups * cap):
            return "stacked expert down projection"
        if op.width == 16 and (op.m, op.k, op.n) == (e * groups * cap,
                                                     t_grp, D):
            return "MoE capacity dispatch (one-hot gather matmul)"
        if op.width == 16 and (op.m, op.k, op.n) == (T, e * cap, D):
            return "MoE capacity combine (weighted scatter matmul)"
        bound = groups * t_grp * e * max(cfg.top_k, 1) * cap
        if op.width == 16 and op.m * op.k * op.n <= bound:
            return "MoE routing bookkeeping (top-k/one-hot select dots)"
    return None


# ---------------------------------------------------------------------------
# Matching + cost rows
# ---------------------------------------------------------------------------

def _op_dims(op: Op) -> tuple:
    if op.kind == "conv":
        return (op.n, op.k)
    return (op.m, op.k, op.n, op.width)


def _match(traced: Workload,
           expected: Sequence[Expected]) -> tuple[dict, set]:
    """{formula_index: traced_index | None}, consumed traced indices.
    First unconsumed traced op with exactly the predicted dims wins."""
    consumed: set[int] = set()
    pairs: dict[int, Optional[int]] = {}
    for fi, exp in enumerate(expected):
        hit = None
        for ti, op in enumerate(traced.ops):
            if (ti not in consumed and op.kind == exp.kind
                    and _op_dims(op) == exp.dims):
                hit = ti
                break
        if hit is not None:
            consumed.add(hit)
        pairs[fi] = hit
    return pairs, consumed


def _cost(report, idx: int, pallas: bool) -> tuple:
    """(bp, bs) of op `idx` in a backend Report; (None, None) if the
    backend skipped it."""
    opr = report.ops[idx]
    if not opr.supported:
        return None, None
    if pallas:
        return opr.bp_us, opr.bs_us
    return opr.bp_cycles, opr.bs_cycles


def _delta(a, b):
    if a is None or b is None:
        return None
    d = b - a
    return round(d, 3) if isinstance(d, float) else d


def reconcile(arch_id: str, *, tokens: int = 4096, weight_bits: int = 4,
              backends: Sequence[str] = GATED_BACKENDS,
              sys: SystemParams = PAPER_SYSTEM,
              traced: Optional[Workload] = None) -> list[OpRow]:
    """Per-op rows (plus a TOTAL row per backend) for one architecture."""
    from repro.configs import get_config
    from repro.models.registry import traced_workload

    cfg = get_config(arch_id)
    formula = arch_workload(cfg, tokens=tokens, weight_bits=weight_bits)
    if traced is None:
        traced = traced_workload(cfg, tokens=tokens,
                                 weight_bits=weight_bits)
    expected = expected_matmuls(cfg, tokens=tokens,
                                weight_bits=weight_bits)
    names = [e.formula for e in expected]
    assert names == [op.name for op in formula.ops], \
        f"catalogue out of sync with arch_workload: {names}"

    def extra(op):
        return _extra_note(op, cfg, tokens, weight_bits)

    return _rows(arch_id, formula, traced, expected, extra, backends, sys)


def reconcile_vgg(which: str = "vgg16", *,
                  backends: Sequence[str] = GATED_BACKENDS,
                  sys: SystemParams = PAPER_SYSTEM) -> list[OpRow]:
    """Rows for traced VGG vs the Table-6 conv/fc formula workload."""
    from repro.models.vgg import traced_vgg

    formula = get_workload(which)
    traced = traced_vgg(which)
    expected = expected_vgg(which)

    def extra(op):
        if op.kind == "compute":
            return "relu / max-pool arithmetic outside the conv formulas"
        return None

    return _rows(which, formula, traced, expected, extra, backends, sys)


def _rows(arch: str, formula: Workload, traced: Workload,
          expected: Sequence[Expected], extra_note, backends,
          sys) -> list[OpRow]:
    pairs, consumed = _match(traced, expected)
    reports_f = characterize(formula, backends, sys)
    reports_t = characterize(traced, backends, sys)
    rows: list[OpRow] = []
    for backend in reports_f:
        rep_f, rep_t = reports_f[backend], reports_t[backend]
        pallas = backend == "pallas"
        unit = "us" if pallas else "cycles"
        tot_f = [0.0, 0.0]
        tot_t = [0.0, 0.0]

        def add(tot, bp, bs):
            if bp is not None:
                tot[0] += bp
            if bs is not None:
                tot[1] += bs

        for fi, exp in enumerate(expected):
            fop = formula.ops[fi]
            bp_f, bs_f = _cost(rep_f, fi, pallas)
            add(tot_f, bp_f, bs_f)
            ti = pairs[fi]
            if ti is None:
                rows.append(OpRow(
                    arch=arch, backend=backend, status="missing",
                    op_formula=fop.name, op_traced="", kind=fop.kind,
                    m_formula=fop.m, k_formula=fop.k, n_formula=fop.n,
                    w_formula=fop.width, bp_formula=bp_f, bs_formula=bs_f,
                    unit=unit, explained=False,
                    note=f"no traced op with predicted dims {exp.dims}"))
                continue
            top = traced.ops[ti]
            bp_t, bs_t = _cost(rep_t, ti, pallas)
            add(tot_t, bp_t, bs_t)
            rows.append(OpRow(
                arch=arch, backend=backend, status=exp.status,
                op_formula=fop.name, op_traced=top.name, kind=fop.kind,
                m_formula=fop.m, k_formula=fop.k, n_formula=fop.n,
                w_formula=fop.width, m_traced=top.m, k_traced=top.k,
                n_traced=top.n, w_traced=top.width, bp_formula=bp_f,
                bs_formula=bs_f, bp_traced=bp_t, bs_traced=bs_t,
                bp_delta=_delta(bp_f, bp_t), bs_delta=_delta(bs_f, bs_t),
                unit=unit, explained=True, note=exp.note))
        for ti, top in enumerate(traced.ops):
            if ti in consumed:
                continue
            bp_t, bs_t = _cost(rep_t, ti, pallas)
            add(tot_t, bp_t, bs_t)
            note = extra_note(top)
            rows.append(OpRow(
                arch=arch, backend=backend, status="traced-only",
                op_formula="", op_traced=top.name, kind=top.kind,
                m_traced=top.m, k_traced=top.k, n_traced=top.n,
                w_traced=top.width, bp_traced=bp_t, bs_traced=bs_t,
                unit=unit, explained=note is not None,
                note=note or "UNEXPLAINED traced op"))
        rows.append(OpRow(
            arch=arch, backend=backend, status="total", op_formula="TOTAL",
            op_traced="TOTAL", kind="", bp_formula=round(tot_f[0], 3),
            bs_formula=round(tot_f[1], 3), bp_traced=round(tot_t[0], 3),
            bs_traced=round(tot_t[1], 3),
            bp_delta=_delta(tot_f[0], tot_t[0]),
            bs_delta=_delta(tot_f[1], tot_t[1]), unit=unit,
            note=f"{len(formula.ops)} formula ops vs "
                 f"{len(traced.ops)} traced ops"))
    return rows


def gate_failures(rows: Sequence[OpRow]) -> list[str]:
    """Hard failures: unexplained traced ops, unmatched formula ops, or
    an ``exact`` pair whose static-backend cycles differ."""
    fails = []
    for r in rows:
        where = f"{r.arch}/{r.backend}"
        if not r.explained:
            who = r.op_traced or r.op_formula
            fails.append(f"{where}: {r.status} op {who!r}: {r.note}")
        elif (r.status == "exact" and r.backend in GATED_BACKENDS
              and (r.bp_delta or r.bs_delta)):
            fails.append(
                f"{where}: exact op {r.op_formula!r} disagrees "
                f"(bp {r.bp_delta:+} bs {r.bs_delta:+} {r.unit})")
    return sorted(set(fails))


def run_diff(archs: Optional[Sequence[str]] = None, *,
             tokens: int = 4096, weight_bits: int = 4,
             backends: Sequence[str] = GATED_BACKENDS,
             pallas_archs: Sequence[str] = (), include_vgg: bool = True,
             sys: SystemParams = PAPER_SYSTEM
             ) -> tuple[list[OpRow], list[str]]:
    """Reconcile ``archs`` (default: all 10) + VGG; -> (rows, failures)."""
    rows: list[OpRow] = []
    for arch in archs or ARCH_IDS:
        bks = tuple(backends)
        if arch in pallas_archs:
            bks += ("pallas",)
        rows += reconcile(arch, tokens=tokens, weight_bits=weight_bits,
                          backends=bks, sys=sys)
    if include_vgg:
        rows += reconcile_vgg(backends=backends, sys=sys)
    return rows, gate_failures(rows)


def write_csv(rows: Sequence[OpRow], path) -> None:
    with open(path, "w", newline="") as fh:
        writer = csv.writer(fh)
        writer.writerow(CSV_COLUMNS)
        for r in rows:
            writer.writerow(
                ["" if v is None else v
                 for v in dataclasses.astuple(r)])
