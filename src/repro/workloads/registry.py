"""The canonical workload registry: every evaluation surface as IR.

Re-expresses, as :class:`repro.workloads.ir.Workload` instances:

* the Table-5 microkernels (``mk/<name>``, paper operating point; arbitrary
  operating points via :func:`microkernel_workload`),
* the 22 Table-6 applications (paper Sec. 4.3.2; the trace formulas moved
  here verbatim from the old ``core.apps`` builders, which are now
  deprecation shims over this registry),
* the per-architecture LM op traces (``arch/<id>``) the layout advisor
  consumes (moved from ``core.advisor.arch_op_trace``).

Movement accounting follows the paper: iterative algorithms keep state
resident (load once, compute many; Challenge 2), BS pays row-overflow
spills when vertical footprints exceed 128 rows, and BS convolutions
replicate window elements across columns while ES-BP reuses them via
logical row addressing (Challenge 3).  The per-app input sizes are the
documented representative choices of the original trace builders; the
validation target is the published Table-6 classification plus the exact
AES totals (Table 7), pinned bit-for-bit by tests/golden/paper_tables.txt.
"""
from __future__ import annotations

import math
from typing import Callable, Optional

from repro.core import cost_model as cm
from repro.core.cost_model import Layout
from repro.core.params import PAPER_SYSTEM
from repro.workloads.ir import Op, Workload

SYS = PAPER_SYSTEM

# ---------------------------------------------------------------------------
# Registry plumbing
# ---------------------------------------------------------------------------

#: name -> (source, description, builder)
_REGISTRY: dict[str, tuple[str, str, Callable[[], Workload]]] = {}
_CACHE: dict[str, Workload] = {}

ALIASES = {
    "vgg": "vgg16",  # the paper's Tier-2 setup: "CIFAR-10 for VGG-16"
}


def _register(name: str, source: str, description: str = ""):
    def deco(fn: Callable[[], list[Op]]):
        _REGISTRY[name] = (source, description,
                           lambda: Workload(name=name, ops=tuple(fn()),
                                            source=source,
                                            description=description))
        return fn
    return deco


def get_workload(name: str) -> Workload:
    """Resolve a registered workload by name (aliases allowed)."""
    name = ALIASES.get(name, name)
    if name not in _REGISTRY:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown workload {name!r} (known: {known})")
    if name not in _CACHE:
        _CACHE[name] = _REGISTRY[name][2]()
    return _CACHE[name]


def list_workloads(source: Optional[str] = None) -> list[dict]:
    """Registry listing: [{name, source, description}]."""
    rows = [{"name": n, "source": s, "description": d}
            for n, (s, d, _) in sorted(_REGISTRY.items())]
    if source is not None:
        rows = [r for r in rows if r["source"] == source]
    return rows


def workload_names(source: Optional[str] = None) -> list[str]:
    return [r["name"] for r in list_workloads(source)]


# ---------------------------------------------------------------------------
# Op shorthands (the old `core.apps` `_phase` / `_movement` helpers)
# ---------------------------------------------------------------------------

def _c(name, bp, bs, rows_bp=16, rows_bs=128, **feat) -> Op:
    """Explicit per-layout compute step."""
    return Op(name=name, kind="compute", bp_cycles=int(bp), bs_cycles=int(bs),
              rows_bp=rows_bp, rows_bs=rows_bs, **feat)


def _mv(name, bits, rows_bp=16, rows_bs=128) -> Op:
    """Layout-neutral data movement (row-serial bus)."""
    return Op(name=name, kind="movement", bits=bits,
              rows_bp=rows_bp, rows_bs=rows_bs)


def _xfer(bits: float) -> int:
    return SYS.xfer_cycles(bits)


def _bp_batches(n: int, w: int) -> int:
    return SYS.bp_batches(n, w)


def _bs_batches(n: int) -> int:
    return SYS.bs_batches(n)


# ---------------------------------------------------------------------------
# Table-5 microkernels (source="table5")
# ---------------------------------------------------------------------------

def microkernel_workload(name: str, n: int = 1024, width: int = 16) -> Workload:
    """A single-kernel workload at an arbitrary operating point."""
    from repro.core.microkernels import MICROKERNELS

    mk = MICROKERNELS[name]
    op = Op(name=name, kind="kernel", kernel=name, n=n, width=width,
            rows_bp=max(1, int(math.ceil(mk.footprint[Layout.BP].rows_per_elem))),
            rows_bs=min(128, n * width))
    return Workload(name=f"mk/{name}", ops=(op,), source="table5",
                    description=f"Table-5 microkernel (N={n}, {width}-bit)")


def _register_microkernels():
    from repro.core.microkernels import MICROKERNELS

    for name in MICROKERNELS:
        n = 8192 if name == "relu" else 1024
        desc = f"Table-5 microkernel (N={n}, 16-bit operating point)"
        # default argument binds the current loop values
        _REGISTRY[f"mk/{name}"] = (
            "table5", desc,
            lambda name=name, n=n: microkernel_workload(name, n=n, width=16))


# ---------------------------------------------------------------------------
# AES-128 (paper Sec. 5.4, Table 7) -- the canonical hybrid case study
# ---------------------------------------------------------------------------

AES_STAGE = {  # per-round costs, 16-byte state (paper Table 7)
    "add_round_key": (16, 128),
    "sub_bytes": (1568, 115),
    "shift_rows": (32, 256),
    "mix_columns": (272, 2176),
}
# AES state: 16 rows in BP (1 byte/row) vs 128 rows in BS (1 bit/row)
_AES_ROWS = dict(rows_bp=16, rows_bs=128)


@_register("aes", "table6",
           "AES-128 CTR bulk encryption (hybrid case study, Table 7)")
def aes_workload() -> list[Op]:
    """Faithful AES-128: initial ARK, 9 full rounds, final round w/o
    MixColumns."""
    ops = [_c("ARK0", *AES_STAGE["add_round_key"], **_AES_ROWS)]
    for r in range(1, 11):
        ops.append(_c(f"SB{r}", *AES_STAGE["sub_bytes"], **_AES_ROWS))
        ops.append(_c(f"SR{r}", *AES_STAGE["shift_rows"], **_AES_ROWS))
        if r < 10:
            ops.append(_c(f"MC{r}", *AES_STAGE["mix_columns"], **_AES_ROWS))
        ops.append(_c(f"ARK{r}", *AES_STAGE["add_round_key"], **_AES_ROWS))
    return ops


# ---------------------------------------------------------------------------
# Strong-BP applications (band 1.5 - 3.0x)
# ---------------------------------------------------------------------------

@_register("brightness", "table6",
           "Per-tile brightness with saturation (real-time, low-DoP tiles)")
def brightness_workload() -> list[Op]:
    """64 tiles x 1024 px, 16-bit; per tile: stream in, offset (add),
    saturate (if-then-else), stream out (Challenge 1/6)."""
    w, n, tiles = 16, 1024, 64
    ops = []
    for t in range(tiles):
        ops.append(_mv(f"load{t}", n * w))
        ops.append(_c(f"offset{t}", cm.BP_ADD, cm.bs_add(w)))
        ops.append(_c(f"sat{t}", cm.if_then_else_bp(w),
                      cm.if_then_else_bs(w), control_intensity=0.5))
        ops.append(_mv(f"store{t}", n * w))
    return ops


@_register("kmeans", "table6", "K-means, 1M points in 48K resident tiles")
def kmeans_workload() -> list[Op]:
    """d=2, k=8, 10 iterations; distance = sub+mult+reduce, argmin = k-1
    iterative min, per-iter centroid broadcast (state resident;
    Challenge 2)."""
    w, k, iters = 16, 8, 10
    n = 49152
    ops = [_mv("load_points", n * w)]
    bpb, bsb = _bp_batches(n, w), _bs_batches(n)
    for i in range(iters):
        ops.append(_mv(f"bcast_centroids{i}", k * 2 * w * 4096))
        dist_bp = k * (cm.BP_SUB + cm.bp_mult(w) + cm.reduction_bp(2)) * bpb
        dist_bs = k * (cm.bs_sub(w) + cm.bs_mult(w) + cm.reduction_bs(w)) * bsb
        ops.append(_c(f"dist{i}", dist_bp, dist_bs))
        amin_bp = (k - 1) * cm.minmax_bp(w) * bpb
        amin_bs = (k - 1) * cm.minmax_bs(w) * bsb
        ops.append(_c(f"argmin{i}", amin_bp, amin_bs, control_intensity=0.4))
    ops.append(_mv("labels_out", n * 8))
    return ops


@_register("keccak", "table6", "Keccak-f[1600], 24 rounds x 512 instances")
def keccak_workload() -> list[Op]:
    """BP keeps 25 64-bit lanes in ES-BP rows; pi is a zero-cost logical
    shuffle, rho costs word shifts.  BS is forced into EP-BS (1600
    vertical rows overflow 128): pi is a physical inter-column shuffle
    and the state spills every round (Challenge 3)."""
    w, rounds = 64, 24
    lanes = 25
    ops = [_mv("absorb", 1088 * 512)]  # rate x 512 parallel instances
    spill_bits = (lanes * w - 128) * 512  # per-round BS working-set spill
    rows = dict(rows_bp=lanes, rows_bs=128)
    for r in range(rounds):
        theta_bp = 5 * 4 * cm.BP_LOGIC + 5 * (1 + cm.BP_LOGIC) + lanes
        theta_bs = (5 * 4 + 5 + lanes) * 1  # row-wise ops, shifts free
        ops.append(_c(f"theta{r}", theta_bp, theta_bs, **rows))
        ops.append(_c(f"rho{r}", 24 * (w // 2), 0, **rows))
        ops.append(_c(f"pi{r}", 0, 2 * lanes * 2, **rows))
        ops.append(_c(f"chi{r}", lanes * 3 * cm.BP_LOGIC, lanes * 3, **rows))
        ops.append(_c(f"spill{r}", 0, _xfer(spill_bits), **rows))
    ops.append(_mv("squeeze", 256 * 512))
    return ops


@_register("fir", "table6", "4-tap FIR over 64k samples (row overflow)")
def fir_workload() -> list[Op]:
    """16-bit samples / 24-bit accumulators; 11 live words fit 11 BP rows
    but need 265 vertical BS rows -- the BS layout parks the overflowed
    accumulator plane in a neighbour array and evicts/reloads it once
    per tap phase (Challenge 2)."""
    w, acc_w, taps, n = 16, 24, 4, 65536
    live_words = 11
    assert SYS.bs_row_overflow(live_words, acc_w)
    spill_bits = acc_w * n  # one word-plane evict+reload per tap phase
    rows = dict(rows_bp=11, rows_bs=128)
    ops = [_mv("coeffs", taps * w * 512)]
    for t in range(taps):
        ops.append(_mv(f"tap{t}.in", n * w))
        mac_bp = cm.bp_mult(w) * _bp_batches(n, w)
        mac_bs = cm.bs_mult(w) * _bs_batches(n)
        ops.append(_c(f"tap{t}.mac", mac_bp, mac_bs, **rows))
        ops.append(_c(f"tap{t}.spill", 0, _xfer(spill_bits), **rows))
    for t in range(taps - 1):
        add_bp = cm.BP_ADD * _bp_batches(n, w)
        add_bs = cm.bs_add(acc_w) * _bs_batches(n)
        ops.append(_c(f"acc{t}", add_bp, add_bs, **rows))
    ops.append(_mv("out", n * acc_w))
    return ops


# ---------------------------------------------------------------------------
# Moderate-BP applications (band 1.2 - 1.5x)
# ---------------------------------------------------------------------------

_VGG_BLOCKS = {  # (channels, spatial, convs) per block, CIFAR-10 input
    "vgg13": [(64, 32, 2), (128, 16, 2), (256, 8, 2), (512, 4, 2), (512, 2, 2)],
    "vgg16": [(64, 32, 2), (128, 16, 2), (256, 8, 3), (512, 4, 3), (512, 2, 3)],
    "vgg19": [(64, 32, 2), (128, 16, 2), (256, 8, 4), (512, 4, 4), (512, 2, 4)],
}
_VGG_BATCH = 128  # batch inference


def _vgg_ops(which: str) -> list[Op]:
    ops: list[Op] = []
    for bi, (c, s, reps) in enumerate(_VGG_BLOCKS[which]):
        n_out = c * s * s * _VGG_BATCH
        for r in range(reps):
            ops.append(Op(name=f"b{bi}c{r}", kind="conv", n=n_out, k=9))
    # CIFAR classifier: FC 512->512->10 as chunked-tree matmuls
    for fi, (m, n) in enumerate([(512, 512), (512, 512), (512, 10)]):
        ops.append(Op(name=f"fc{fi}", kind="matmul", m=1, k=m, n=n, chunk=64))
    return ops


for _which in ("vgg13", "vgg16", "vgg19"):
    _REGISTRY[_which] = (
        "table6", f"{_which.upper()} batch-128 CIFAR-10 inference",
        lambda which=_which: Workload(
            name=which, ops=tuple(_vgg_ops(which)), source="table6",
            description=f"{which.upper()} batch-128 CIFAR-10 inference"))


@_register("gemm", "table6", "400x400 16-bit GEMM, output-stationary")
def gemm_workload() -> list[Op]:
    """The 160k outputs fill only 61% of the BS columns while BP batches
    10x (limited batching -- the moderate-BP regime of Table 6)."""
    w, dim = 16, 400
    return [
        _mv("loadAB", 2 * dim * dim * w),
        Op(name="mac", kind="matmul", m=dim, k=dim, n=dim, width=w, chunk=0),
        _mv("storeC", dim * dim * 2 * w),
    ]


@_register("gemv", "table6", "4096-deep GEMV, 512 outputs (low DoP)")
def gemv_workload() -> list[Op]:
    return [Op(name="gemv", kind="matmul", m=1, k=4096, n=512, chunk=64)]


@_register("conv2d", "table6", "Single 3x3 conv, 256x56x56 output")
def conv2d_workload() -> list[Op]:
    return [Op(name="conv", kind="conv", n=256 * 56 * 56, k=9)]


@_register("downsample", "table6", "2x2 average downsample, 1024x1024 image")
def downsample_workload() -> list[Op]:
    """3 adds + shift per output; the stride-2 window regroup is a
    zero-cost logical remap in ES-BP but a physical inter-column shuffle
    in EP-BS (Challenge 3), costing a half-density restream."""
    w = 16
    n_out = 512 * 512
    comp_bp = (3 * cm.BP_ADD + cm.bp_shift(2)) * _bp_batches(n_out, w)
    comp_bs = 3 * cm.bs_add(w) * _bs_batches(n_out)
    return [
        _mv("in", 4 * n_out * w),
        _c("regroup", 0, _xfer(4 * n_out * w * 0.5)),
        _c("avg", comp_bp, comp_bs),
        _mv("out", n_out * w),
    ]


# ---------------------------------------------------------------------------
# Balanced applications (band 1.0 - 1.15x)
# ---------------------------------------------------------------------------

@_register("vector_add", "table6", "Table-4 running example at 2K elements")
def vector_add_workload() -> list[Op]:
    """Band-interior size (the 1K point sits exactly at the published
    1.15x band edge)."""
    return [Op(name="vadd", kind="kernel", kernel="vector_add", n=2048,
               width=16)]


@_register("axpy", "table6", "y = a*x + y, 64K elements, 32-bit")
def axpy_workload() -> list[Op]:
    w, n = 32, 65536
    comp_bp = (cm.bp_mult(w) + cm.BP_ADD) * _bp_batches(n, w)
    comp_bs = (cm.bs_mult(w) + cm.bs_add(w)) * _bs_batches(n)
    return [_mv("load", 2 * n * w), _c("fma", comp_bp, comp_bs),
            _mv("store", n * w)]


@_register("pooling", "table6", "2x2 max-pool over 512x512, 16-bit")
def pooling_workload() -> list[Op]:
    w, n_out = 16, 256 * 256
    comp_bp = 3 * cm.minmax_bp(w) * _bp_batches(n_out, w)
    comp_bs = 3 * cm.minmax_bs(w) * _bs_batches(n_out)
    return [_mv("in", 4 * n_out * w), _c("max", comp_bp, comp_bs),
            _mv("out", n_out * w)]


@_register("prefix_sum", "table6", "Hillis-Steele scan, 64k 16-bit elements")
def prefix_sum_workload() -> list[Op]:
    """log2(n) add sweeps, movement-dominated (Challenge 2 batching)."""
    w, n = 16, 65536
    steps = int(math.log2(n))
    comp_bp = steps * cm.BP_ADD * _bp_batches(n, w)
    comp_bs = steps * cm.bs_add(w) * _bs_batches(n)
    return [
        _mv("in", n * w),
        _mv("shift_streams", steps * n * w / 8),
        _c("sweeps", comp_bp, comp_bs),
        _mv("out", n * w),
    ]


# ---------------------------------------------------------------------------
# BS-preference applications (band 0.6 - 0.9x: BS faster)
# ---------------------------------------------------------------------------

@_register("histogram", "table6", "256-bin histogram of 64k 8-bit samples")
def histogram_workload() -> list[Op]:
    """Bit-sliced bin matching (equal) + popcount accumulation:
    bit-centric, full-density (Challenge 1 favours BS)."""
    w, n, bins_groups = 8, 65536, 16
    ops = [_mv("in", n * w)]
    for g in range(bins_groups):
        eq_bp = cm.equal_bp(w) * _bp_batches(n, w)
        eq_bs = cm.equal_bs(w) * _bs_batches(n)
        ops.append(_c(f"match{g}", eq_bp, eq_bs, bit_level_fraction=0.8,
                      width=w))
        # BP must popcount the match masks (D&C); BS counts serially
        ops.append(_c(f"count{g}", cm.bitcount_bp(w) * _bp_batches(n, w),
                      cm.reduction_bs(w) * _bs_batches(n),
                      bit_level_fraction=0.9, width=w))
    ops.append(_mv("bins_out", 256 * 32))
    return ops


@_register("hdc", "table6", "Hyperdimensional hamming search (8192-bit)")
def hdc_workload() -> list[Op]:
    """XOR + popcount over 4096 class vectors: bit-level DoP saturates
    the 1-bit PEs; BS also emits half-width counts (Table-5 bitcount
    convention)."""
    d, classes, w = 8192, 4096, 16
    n_bits = d * classes
    n_words = n_bits // w
    xor_bp = cm.BP_LOGIC * _bp_batches(n_words, w)
    xor_bs = 1 * _bs_batches(n_bits)
    pc_bp = cm.bitcount_bp(w) * _bp_batches(n_words, w)
    pc_bs = cm.bitcount_bs(w) * _bs_batches(n_bits)
    red_bp = cm.reduction_bp(d // w) * _bp_batches(classes, w)
    red_bs = cm.reduction_bs(w) * _bs_batches(classes)
    return [
        _mv("load_vectors", n_bits),
        _c("xor", xor_bp, xor_bs, bit_level_fraction=1.0, width=1),
        _c("popcount", pc_bp, pc_bs, bit_level_fraction=1.0, width=1),
        _c("reduce", red_bp, red_bs),
        _c("scores_out", _xfer(n_words * w), _xfer(n_words * w / 2)),
    ]


@_register("bitweave_db", "table6", "BitWeaving column scans (2b/4b codes)")
def bitweave_db_workload() -> list[Op]:
    """Database predicates over 64k-row columns: BS streams full-density
    vertical bit planes; BP must pad codes to byte containers."""
    ops = []
    n = 65536
    for reps, bits in [(4, 2), (4, 4)]:
        for r in range(reps):
            load_bp = _xfer(n * 8)  # byte-padded codes
            load_bs = _xfer(n * bits * 1.5)  # code + predicate planes
            comp = cm.bitweave_compute(bits, Layout.BP)
            ops.append(_c(f"scan{bits}b_{r}.load", load_bp, load_bs,
                          width=bits))
            ops.append(_c(f"scan{bits}b_{r}.pred", comp, comp, width=bits))
            ops.append(_mv(f"scan{bits}b_{r}.out", n / 8))
    return ops


@_register("xnor_net", "table6", "Binary conv net (XNOR-Net), 2 conv layers")
def xnor_net_workload() -> list[Op]:
    """xnor + popcount MACs, binary activations (the paper's canonical
    BS-friendly AI workload).  Same density/readout conventions as HDC."""
    w = 16
    ops = []
    for name, n_out, k in [("c1", 128 * 28 * 28, 288), ("c2", 256 * 14 * 14, 576)]:
        n_macs = n_out * k
        n_words = n_macs // w
        xnor_bp = cm.BP_LOGIC * _bp_batches(n_words, w)
        xnor_bs = 1 * _bs_batches(n_macs)
        pc_bp = cm.bitcount_bp(w) * _bp_batches(n_words, w)
        pc_bs = cm.bitcount_bs(w) * _bs_batches(n_macs)
        ops.append(_mv(f"{name}.in", n_macs))
        ops.append(_c(f"{name}.xnor", xnor_bp, xnor_bs,
                      bit_level_fraction=1.0, width=1))
        ops.append(_c(f"{name}.popc", pc_bp, pc_bs,
                      bit_level_fraction=1.0, width=1))
        ops.append(_c(f"{name}.out", _xfer(n_words * w),
                      _xfer(n_words * w / 2)))
    return ops


# ---------------------------------------------------------------------------
# Hybrid-recommended applications
# ---------------------------------------------------------------------------

@_register("radix_sort", "table6", "LSD radix sort, 64k 16-bit keys")
def radix_sort_workload() -> list[Op]:
    """Per 4-bit pass: digit extraction + match counting is bit-level
    (BS-friendly); the scatter is a word-level permutation (BP-friendly
    logical shuffle)."""
    w, n, digit = 16, 65536, 4
    passes = w // digit
    rows = dict(rows_bp=8, rows_bs=64)
    ops = [_mv("keys_in", n * w)]
    for p in range(passes):
        cnt_bp = (16 * cm.equal_bp(digit) + cm.bitcount_bp(16)) \
            * _bp_batches(n, w)
        cnt_bs = (16 * cm.equal_bs(digit) + cm.reduction_bs(digit)) \
            * _bs_batches(n)
        ops.append(_c(f"count{p}", cnt_bp, cnt_bs, bit_level_fraction=0.8,
                      **rows))
        scan_bp = cm.reduction_bp(16) * 2
        scan_bs = cm.reduction_bs(16) * 16
        ops.append(_c(f"scan{p}", scan_bp, scan_bs, **rows))
        scat_bp = _xfer(n * w / 4)  # logical-shuffle assisted gather
        scat_bs = _xfer(n * w) + 2 * n // 512  # physical inter-column moves
        ops.append(_c(f"scatter{p}", scat_bp, scat_bs, **rows))
    ops.append(_mv("keys_out", n * w))
    return ops


@_register("db_query", "table6", "SELECT-WHERE-GROUP-BY over 64k rows")
def db_query_workload() -> list[Op]:
    """Bitweave scan (BS) feeding a word-level aggregation (BP)."""
    n = 65536
    rows = dict(rows_bp=32, rows_bs=96)
    load_bp = _xfer(n * 16 * 2 * 1.25)
    load_bs = _xfer(n * 16 * 2 * 0.5)
    comp = cm.bitweave_compute(4, Layout.BP) * 8
    agg_bp = (cm.BP_ADD + cm.minmax_bp(32)) * 64
    agg_bs = (cm.bs_add(32) + cm.minmax_bs(32)) * 64
    return [
        _c("scan.load", load_bp, load_bs, **rows),
        _c("scan.pred", int(comp * 1.6), comp, bit_level_fraction=0.8,
           **rows),
        _c("aggregate", agg_bp, agg_bs, **rows),
        _mv("out", n),
    ]


# ---------------------------------------------------------------------------
# Per-architecture LM op traces (source="arch")
# ---------------------------------------------------------------------------

def arch_workload(cfg, *, tokens: int = 4096,
                  weight_bits: int = 4) -> Workload:
    """Representative per-layer ops for quantized serving at
    ``weight_bits`` (moved from ``core.advisor.arch_op_trace``; the
    advisor now consumes this IR route).

    ``working_set_bits`` is pinned to the streamed-MAC live set (8 live
    words at the op's precision: operands + double-width accumulator +
    scratch), not the weight-stationary footprint -- LM weight matrices
    never fit a column, so serving tiles stream them (the Table-8
    classification the advisor has always used)."""
    D = cfg.d_model

    def mm(name, m, k, n, width, control=0.0):
        return Op(name=name, kind="matmul", m=m, k=k, n=n, width=width,
                  control_intensity=control, working_set_bits=width * 8)

    ops: list[Op] = []
    if cfg.family == "ssm":
        Din = cfg.d_inner
        ops.append(mm("in_proj", tokens, D, 2 * Din + 2 * cfg.ssm_state
                      + cfg.ssm_heads, weight_bits))
        ops.append(mm("ssd_scan", tokens, cfg.ssm_state, cfg.ssm_head_dim,
                      16, control=0.3))
        ops.append(mm("out_proj", tokens, Din, D, weight_bits))
        return Workload(name=f"arch/{cfg.name}", ops=tuple(ops),
                        source="arch",
                        description=f"{cfg.name} int{weight_bits} serving")
    if cfg.n_heads and cfg.n_kv_heads:
        ops.append(mm("qkv_proj", tokens, D, cfg.qkv_dim, weight_bits))
        ops.append(mm("attn_scores", tokens, cfg.head_dim, tokens, 16,
                      control=0.25))  # softmax/masking
        ops.append(mm("o_proj", tokens, cfg.n_heads * cfg.head_dim, D,
                      weight_bits))
    if cfg.n_experts:
        ops.append(mm("router", tokens, D, cfg.n_experts, 16,
                      control=0.6))  # top-k / dispatch
        ops.append(mm("expert_ffn", tokens * cfg.top_k, D, cfg.d_ff,
                      weight_bits))
    elif cfg.d_ff:
        ops.append(mm("ffn", tokens, D, cfg.d_ff, weight_bits))
    if cfg.family == "hybrid":
        W = cfg.lru_width
        ops.append(mm("rg_lru_gates", tokens, W, W, 16, control=0.4))
    return Workload(name=f"arch/{cfg.name}", ops=tuple(ops), source="arch",
                    description=f"{cfg.name} int{weight_bits} serving")


#: the 10 serving architectures (each registered as arch/<id> and
#: traced/<id>)
ARCH_IDS = [
    "mamba2_780m", "dbrx_132b", "llama4_maverick_400b_a17b", "yi_6b",
    "tinyllama_1_1b", "mistral_nemo_12b", "stablelm_1_6b",
    "internvl2_2b", "recurrentgemma_2b", "whisper_small",
]


def _register_archs():
    # configs import jax transitively (models.base); resolve lazily so the
    # pure-analytic registry stays importable without the jax stack.
    def builder(arch_id):
        def build() -> Workload:
            from repro.configs import get_config
            return arch_workload(get_config(arch_id))
        return build

    for arch_id in ARCH_IDS:
        _REGISTRY[f"arch/{arch_id}"] = (
            "arch", f"{arch_id} per-layer int4 serving trace",
            builder(arch_id))


# ---------------------------------------------------------------------------
# jaxpr-traced workloads (source="traced")
# ---------------------------------------------------------------------------

def _register_traced():
    """``traced/<id>``: the real forward pass of each arch, traced from
    its jaxpr at the same operating point as ``arch/<id>`` (one decode
    step, 4096 concurrent sequences, int4 weights), plus ``traced/vgg16``
    for the Table-6 cross-check.  Builders import the jax model stack
    lazily, like the ``arch/`` entries."""
    def builder(arch_id):
        def build() -> Workload:
            from repro.configs import get_config
            from repro.models.registry import traced_workload
            return traced_workload(get_config(arch_id))
        return build

    for arch_id in ARCH_IDS:
        _REGISTRY[f"traced/{arch_id}"] = (
            "traced", f"{arch_id} jaxpr-traced int4 decode step",
            builder(arch_id))

    def build_vgg() -> Workload:
        from repro.models.vgg import traced_vgg
        return traced_vgg("vgg16")

    _REGISTRY["traced/vgg16"] = (
        "traced", "VGG-16 batch-128 CIFAR-10 inference, jaxpr-traced",
        build_vgg)


_register_microkernels()
_register_archs()
_register_traced()
