"""Canonical workload IR: one description, many evaluation backends.

The paper's thesis is *workload-driven* characterization, but the repo
historically described workloads five incompatible ways (Table-5
``MicroKernel``s, hand-built ``core.apps`` phase lists, ``pim.programs``
micro-op programs, the advisor's ``OpTrace``, and the Pallas entry points).
This module is the one canonical representation the others now lower from:

* :class:`Op` -- one layout-homogeneous step of a workload, carrying dims,
  precision, control intensity, and footprint.  Five kinds:

  ========== ==============================================================
  ``kernel``    a Table-5 microkernel invocation (``kernel``, ``n`` elems,
                ``width``); costed by ``repro.core.microkernels``
  ``movement``  layout-neutral row-serial bus transfer of ``bits``
  ``compute``   explicit per-layout compute cycles (``bp_cycles`` /
                ``bs_cycles``) for bespoke phases (crypto rounds, spills)
  ``matmul``    ``y[m,n] = x[m,k] @ W[k,n]`` at ``width``-bit precision;
                ``chunk>0`` lowers to the chunked-tree dot-product phases
                (load / mac / out), ``chunk=0`` to a single streamed MAC
                phase (movement charged by explicit ``movement`` ops)
  ``conv``      ``n`` window MACs of ``k`` taps each (ES-BP window reuse vs
                EP-BS column replication; Challenge 3)
  ========== ==============================================================

* :class:`Workload` -- a DAG-ordered op sequence (list order = the one
  topological order the 2-state planner DP consumes).

Lowering rules (normative; see DESIGN.md Sec. 5):

* ``op_cost(op, layout)`` -> :class:`CycleCost` (load/compute/readout) is
  the analytic lowering; for ``kernel`` ops it is exactly
  ``microkernels.kernel_cost``, so the IR path reproduces the legacy
  numbers bit-for-bit (tests/test_workloads.py golden-equivalence suite).
* ``op_phases(op)`` -> planner :class:`Phase` list is the hybrid-DP
  lowering; ``Workload.to_phases`` concatenates it over the op sequence
  and is what the deprecated ``core.apps`` trace constructors now return.
* ``Op.features()`` -> ``taxonomy.WorkloadFeatures`` is the classification
  lowering used by ``core.advisor`` and ``kernels.ops.choose_layout``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

from repro.core import cost_model as cm
from repro.core.cost_model import CycleCost, Layout
from repro.core.params import SystemParams, PAPER_SYSTEM
from repro.core.planner import Phase
from repro.core.taxonomy import WorkloadFeatures

OP_KINDS = ("kernel", "movement", "compute", "matmul", "conv")


def matmul_working_set_bits(k: int, width: int) -> int:
    """Resident per-lane footprint of a weight-stationary k-deep dot
    product: the k-element weight column held in the array (the point of
    PIM -- compute where the weights live) plus the double-width
    accumulator with its log2(k) carry growth.  This is the footprint
    ``choose_layout`` feeds the Table-8 row-overflow rule, so deep
    contractions (large k) correctly flip the recommendation to BP
    (Challenge 2) instead of the old fixed ``width * 4`` placeholder.
    """
    acc_bits = 2 * width + max(1, math.ceil(math.log2(max(2, k))))
    return k * width + acc_bits


@dataclasses.dataclass(frozen=True)
class Op:
    """One layout-homogeneous step of a workload (fields per ``kind``)."""

    name: str
    kind: str
    # -- kernel ---------------------------------------------------------
    kernel: str = ""        # Table-5 microkernel name
    # -- dims -----------------------------------------------------------
    m: int = 1              # matmul: output rows (tokens / batch)
    k: int = 0              # matmul: contraction depth; conv: window taps
    n: int = 0              # matmul: output cols; conv/kernel: elements
    width: int = 16         # operand precision (bits)
    chunk: int = 64         # matmul: tree-split chunk (0 = streamed MAC)
    in_elems: Optional[int] = None  # conv: input elements (default n)
    # -- movement -------------------------------------------------------
    bits: float = 0.0
    # -- compute (explicit per-layout cycles) ---------------------------
    bp_cycles: int = 0
    bs_cycles: int = 0
    # -- planner footprint ----------------------------------------------
    rows_bp: int = 16
    rows_bs: int = 128
    # -- classification features (None = derived from dims) -------------
    control_intensity: float = 0.0
    bit_level_fraction: Optional[float] = None
    mixed_precision: bool = False
    working_set_bits: Optional[int] = None
    latency_critical: bool = False

    def __post_init__(self):
        if self.kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.kind!r} "
                             f"(one of {OP_KINDS})")
        if self.kind == "kernel" and not self.kernel:
            raise ValueError(f"op {self.name!r}: kind='kernel' needs a "
                             "microkernel name")
        if self.kind in ("matmul", "conv") and (self.k < 1 or self.n < 1
                                                or self.m < 1):
            raise ValueError(
                f"op {self.name!r}: kind={self.kind!r} needs positive dims "
                f"(got m={self.m}, k={self.k}, n={self.n})")

    # ------------------------------------------------------------------
    def dop(self) -> int:
        """Degree of parallelism (concurrent independent word-level ops)."""
        if self.kind == "matmul":
            return max(1, self.m * self.n)
        if self.kind in ("conv", "kernel"):
            return max(1, self.n)
        return 1

    def to_dict(self) -> dict:
        """Canonical field dump (every field, declaration order) -- the
        serialization the serving plan cache content-addresses, so two
        structurally identical ops always hash identically."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Op":
        return cls(**d)

    def features(self) -> WorkloadFeatures:
        """Lower to the Table-8 feature vector (``taxonomy.classify``)."""
        blf = self.bit_level_fraction
        if blf is None:
            # low-bit operands are bit-level by construction; wider ops
            # default to word-level unless annotated
            blf = 1.0 if self.width <= 2 else 0.7 if self.width <= 4 else 0.0
        ws = self.working_set_bits
        if ws is None:
            if self.kind == "matmul":
                ws = matmul_working_set_bits(self.k, self.width)
            elif self.kind == "conv":
                ws = matmul_working_set_bits(max(1, self.k), self.width)
            else:
                ws = 3 * self.width  # two operands + result resident
        return WorkloadFeatures(
            precision_bits=self.width,
            dop=self.dop(),
            control_intensity=self.control_intensity,
            bit_level_fraction=blf,
            working_set_bits=ws,
            latency_critical=self.latency_critical,
            mixed_precision=self.mixed_precision,
        )


# ---------------------------------------------------------------------------
# Analytic lowering: Op -> CycleCost / planner Phases
# ---------------------------------------------------------------------------

def _matmul_chunked_cost(op: Op, layout: Layout,
                         sys: SystemParams) -> CycleCost:
    """Chunked-tree dot products (the `core.apps` GEMV/FC lowering):
    y[m,n] = x[m,k] @ W[k,n], each length-k dot split into `chunk`-way
    partial sums reduced by a tree."""
    w, chunk = op.width, min(op.chunk, op.k)
    dop = op.m * op.n * chunk
    outs = op.m * op.n
    load = sys.xfer_cycles(op.k * op.n * w + op.m * op.k * w)
    if layout is Layout.BP:
        comp = (op.k // chunk) * (cm.bp_mult(w) + cm.BP_ADD) \
            * sys.bp_batches(dop, w) \
            + cm.reduction_bp(chunk) * sys.bp_batches(outs, w)
    else:
        comp = (op.k // chunk) * (cm.bs_mult(w) + cm.bs_add(2 * w)) \
            * sys.bs_batches(dop) \
            + cm.reduction_bs(2 * w) * sys.bs_batches(outs)
    out = sys.xfer_cycles(outs * 2 * w)
    return CycleCost(load, comp, out)


def _matmul_streamed_compute(op: Op, layout: Layout,
                             sys: SystemParams) -> int:
    """Output-stationary MAC stream (the `core.apps` GEMM lowering): k
    multiply-accumulates per output, movement charged separately."""
    w, outs = op.width, op.m * op.n
    if layout is Layout.BP:
        return op.k * (cm.bp_mult(w) + cm.BP_ADD) * sys.bp_batches(outs, w)
    return op.k * (cm.bs_mult(w) + cm.bs_add(2 * w)) * sys.bs_batches(outs)


def _conv_cost(op: Op, layout: Layout, sys: SystemParams) -> CycleCost:
    """Window MACs: ES-BP reuses window elements via logical row
    addressing (1x load); EP-BS replicates across columns for the
    horizontal extent (2x load; Challenge 3)."""
    w, n_out, taps = op.width, op.n, op.k
    in_e = n_out if op.in_elems is None else op.in_elems
    if layout is Layout.BP:
        load = sys.xfer_cycles(in_e * w + taps * w * 512)
        comp = (taps * cm.bp_mult(w) + (taps - 1) * cm.BP_ADD) \
            * sys.bp_batches(n_out, w)
    else:
        load = sys.xfer_cycles(in_e * w * 2.0 + taps * w * 512)
        comp = (taps * cm.bs_mult(w) + (taps - 1) * cm.bs_add(2 * w)) \
            * sys.bs_batches(n_out)
    out = sys.xfer_cycles(n_out * 2 * w)
    return CycleCost(load, comp, out)


def op_cost(op: Op, layout: Layout,
            sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
    """Analytic load/compute/readout of one op in one static layout."""
    layout = Layout(layout)
    if op.kind == "kernel":
        from repro.core.microkernels import kernel_cost
        return kernel_cost(op.kernel, layout, n=op.n, width=op.width, sys=sys)
    if op.kind == "movement":
        return CycleCost(sys.xfer_cycles(op.bits), 0, 0)
    if op.kind == "compute":
        c = op.bp_cycles if layout is Layout.BP else op.bs_cycles
        return CycleCost(0, c, 0)
    if op.kind == "matmul":
        if op.chunk > 0:
            return _matmul_chunked_cost(op, layout, sys)
        return CycleCost(0, _matmul_streamed_compute(op, layout, sys), 0)
    if op.kind == "conv":
        return _conv_cost(op, layout, sys)
    raise AssertionError(op.kind)


def op_phases(op: Op, sys: SystemParams = PAPER_SYSTEM) -> list[Phase]:
    """Planner lowering: one op -> 1..3 layout-choice points (Phases)."""
    rows = dict(rows_bp=op.rows_bp, rows_bs=op.rows_bs)
    if op.kind in ("kernel", "compute", "movement"):
        bp = op_cost(op, Layout.BP, sys)
        bs = op_cost(op, Layout.BS, sys)
        return [Phase(op.name, bp.total, bs.total, **rows)]
    if op.kind == "conv" or (op.kind == "matmul" and op.chunk > 0):
        bp = op_cost(op, Layout.BP, sys)
        bs = op_cost(op, Layout.BS, sys)
        return [
            Phase(f"{op.name}.load", bp.load, bs.load, **rows),
            Phase(f"{op.name}.mac", bp.compute, bs.compute, **rows),
            Phase(f"{op.name}.out", bp.readout, bs.readout, **rows),
        ]
    if op.kind == "matmul":  # chunk == 0: streamed MAC only
        return [Phase(op.name, _matmul_streamed_compute(op, Layout.BP, sys),
                      _matmul_streamed_compute(op, Layout.BS, sys), **rows)]
    raise AssertionError(op.kind)


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    """A DAG-ordered op sequence plus provenance metadata.

    ``deps`` are explicit dependence edges ``(producer, consumer)`` over
    op indices; empty means the default linear chain (op *i* feeds op
    *i+1*).  List order must stay a topological order either way -- the
    invariant every consumer (the 2-state planner, ``repro.plan``'s DAG
    scheduler, the executor lowering) relies on, so edges must point
    forward (``producer < consumer``).
    """

    name: str
    ops: tuple[Op, ...]
    source: str = "table6"  # "table5" | "table6" | "arch" | "traced"
    description: str = ""
    #: explicit DAG edges over op indices; () = linear chain
    deps: tuple[tuple[int, int], ...] = ()

    def __post_init__(self):
        if not self.ops:
            raise ValueError(f"workload {self.name!r} has no ops")
        for a, b in self.deps:
            if not (0 <= a < b < len(self.ops)):
                raise ValueError(
                    f"workload {self.name!r}: bad dep edge ({a}, {b}) -- "
                    f"need 0 <= producer < consumer < {len(self.ops)} "
                    "(list order is the topological order)")
        if len(set(self.deps)) != len(self.deps):
            dupes = sorted({e for e in self.deps if self.deps.count(e) > 1})
            raise ValueError(
                f"workload {self.name!r}: duplicate dep edge(s) {dupes} "
                "would double-charge the boundary transpose")
        # canonicalize: deps in sorted order, as plain int tuples --
        # `to_dict()` feeds the serving plan-cache hash, which must not
        # depend on trace iteration order (the jaxpr def-use walk emits
        # edges in discovery order)
        object.__setattr__(
            self, "deps",
            tuple(sorted((int(a), int(b)) for a, b in self.deps)))

    def to_dict(self) -> dict:
        """Canonical JSON-ready form (ops in DAG order, explicit deps).

        This is the normative workload-IR serialization: the serving
        layer's plan-cache key is ``sha256`` over this dict (plus geometry
        and scheduler source), so field additions extend it automatically
        and structurally identical workloads hash identically."""
        return {
            "name": self.name,
            "source": self.source,
            "description": self.description,
            "ops": [op.to_dict() for op in self.ops],
            "deps": [list(e) for e in self.deps],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "Workload":
        return cls(name=d["name"], source=d.get("source", "table6"),
                   description=d.get("description", ""),
                   ops=tuple(Op.from_dict(o) for o in d["ops"]),
                   deps=tuple((a, b) for a, b in d.get("deps", ())))

    def edges(self) -> tuple[tuple[int, int], ...]:
        """Dependence edges: ``deps`` if given, else the linear chain."""
        if self.deps:
            return self.deps
        return tuple((i, i + 1) for i in range(len(self.ops) - 1))

    def to_phases(self, sys: SystemParams = PAPER_SYSTEM) -> list[Phase]:
        """Lower to the planner's phase sequence (hybrid-DP input).

        Note: ``compute``-kind op cycles are explicit constants baked by
        the workload author (the registry bakes them at PAPER_SYSTEM
        calibration); only ``kernel``/``movement``/``matmul``/``conv``
        ops re-lower under a non-default `sys`."""
        out: list[Phase] = []
        for op in self.ops:
            out.extend(op_phases(op, sys))
        return out

    def cost(self, layout: Layout,
             sys: SystemParams = PAPER_SYSTEM) -> CycleCost:
        """Static single-layout analytic cost (summed over ops)."""
        total = CycleCost(0, 0, 0)
        for op in self.ops:
            total = total + op_cost(op, layout, sys)
        return total


def workload(name: str, ops: Sequence[Op], source: str = "table6",
             description: str = "",
             deps: Sequence[tuple[int, int]] = ()) -> Workload:
    return Workload(name=name, ops=tuple(ops), source=source,
                    description=description, deps=tuple(deps))
