"""Shared benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import os
import time


def quick() -> bool:
    """CI smoke mode (``--quick`` / REPRO_BENCH_QUICK=1): single timed
    iteration per bench so entrypoints are exercised without the full
    timing budget. Numbers are correctness-path only in this mode."""
    return os.environ.get("REPRO_BENCH_QUICK", "") == "1"


def time_us(fn, *args, repeat: int = 5, **kw) -> float:
    if quick():
        repeat = 1
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6


def emit(name: str, us: float, derived) -> str:
    row = f"{name},{us:.2f},{derived}"
    print(row)
    return row
