"""Shared benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time


def time_us(fn, *args, repeat: int = 5, **kw) -> float:
    fn(*args, **kw)  # warmup
    t0 = time.perf_counter()
    for _ in range(repeat):
        fn(*args, **kw)
    return (time.perf_counter() - t0) / repeat * 1e6


def emit(name: str, us: float, derived) -> str:
    row = f"{name},{us:.2f},{derived}"
    print(row)
    return row
