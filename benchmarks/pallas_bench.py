"""pallas-bench entrypoint for the benchmark driver.

Thin wrapper over :func:`repro.kernels.bench.run_pallas_bench` -- the
full-problem (un-clamped) BP vs fused/unfused BS trajectory that
``python -m repro pallas-bench`` commits to ``BENCH_pallas.json``.  Here
it runs a reduced case set (smallest shape, one low and one full width)
so ``benchmarks/run.py --quick`` exercises the entrypoint without the
full timing budget; the derived field carries the fused/unfused ratio
the fusion exists to improve.
"""
from __future__ import annotations

from benchmarks.common import emit, quick
from repro.kernels.bench import run_pallas_bench


def pallas_trajectory() -> list[str]:
    shapes = (("vgg_fc", (1, 512, 512)),)
    widths = (4, 16) if quick() else (1, 4, 8, 16)
    payload = run_pallas_bench(quick=quick(), reps=1 if quick() else 3,
                               shapes=shapes, widths=widths)
    rows = []
    by_name = {c["name"]: c for c in payload["cases"]}
    for c in payload["cases"]:
        derived = f"path={c['path']};width={c['width']}"
        if c["path"] == "bs_fused":
            unfused = by_name.get(
                c["name"].replace("bs_fused", "bs_unfused"))
            if unfused and c["us"]:
                derived += f";unfused_over_fused={unfused['us'] / c['us']:.2f}"
        rows.append(emit(f"pallas.{c['name'].replace('/', '.')}",
                         c["us"], derived))
    return rows


ALL = [pallas_trajectory]
