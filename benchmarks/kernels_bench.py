"""Kernel micro-benchmarks: Pallas (interpret mode) vs pure-jnp oracle.

On this CPU container the numbers are correctness-path timings, not TPU
performance; the TPU roofline lives in benchmarks/roofline_bench.py.
Derived fields report the BS-vs-BP plane-pass arithmetic the paper predicts
(b-bit weights => b plane passes vs one full-width pass).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.kernels import ref
from repro.kernels.bitpack import bitpack
from repro.kernels.bitparallel_matmul import bitparallel_matmul
from repro.kernels.bitserial_matmul import bitserial_matmul
from repro.kernels.flash_attention import flash_attention


def kernels() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    M, K, N = 64, 128, 128
    x = jnp.asarray(rng.integers(-32, 32, (M, K), dtype=np.int32)
                    ).astype(jnp.int8)
    for bits in (1, 2, 4):
        w = jnp.asarray(rng.integers(0, 2 ** bits, (K, N), dtype=np.uint32))
        planes = ref.bitpack_ref(w, bits)
        us = time_us(lambda: np.asarray(
            bitserial_matmul(x, planes, block_m=64, block_n=64)), repeat=2)
        ok = bool(np.array_equal(
            np.asarray(bitserial_matmul(x, planes, block_m=64, block_n=64)),
            np.asarray(ref.bitserial_matmul_ref(x.astype(jnp.int32),
                                                planes))))
        rows.append(emit(f"kern.bitserial_matmul.{bits}b", us,
                         f"plane_passes={bits};match={ok}"))
    w8 = jnp.asarray(rng.integers(-128, 128, (K, N), dtype=np.int32)
                     ).astype(jnp.int8)
    us = time_us(lambda: np.asarray(
        bitparallel_matmul(x, w8, block_m=64, block_n=64, block_k=64)),
        repeat=2)
    ok = bool(np.array_equal(
        np.asarray(bitparallel_matmul(x, w8, block_m=64, block_n=64,
                                      block_k=64)),
        np.asarray(ref.bitparallel_matmul_ref(x, w8))))
    rows.append(emit("kern.bitparallel_matmul.8b", us,
                     f"plane_passes=1(full-width);match={ok}"))

    w4 = jnp.asarray(rng.integers(0, 16, (K, N), dtype=np.uint32))
    us = time_us(lambda: np.asarray(bitpack(w4, 4)), repeat=2)
    ok = bool(np.array_equal(np.asarray(bitpack(w4, 4)),
                             np.asarray(ref.bitpack_ref(w4, 4))))
    rows.append(emit("kern.bitpack.4b", us, f"transpose_unit;match={ok}"))

    q = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    us = time_us(lambda: np.asarray(
        flash_attention(q, k, v, block_q=64, block_k=64)), repeat=2)
    close = bool(np.allclose(
        np.asarray(flash_attention(q, k, v, block_q=64, block_k=64)),
        np.asarray(ref.flash_attention_ref(q, k, v)), rtol=2e-5, atol=2e-5))
    rows.append(emit("kern.flash_attention", us, f"match={close}"))
    return rows


ALL = [kernels]
